//! Quickstart: the Figure 1 lifecycle in one binary.
//!
//! Registers the paper's testbed infrastructure, starts per-cluster
//! message services with EC<->CC bridges, deploys a small ECC
//! *processing* pipeline (pattern 1 of §2: filter -> aggregate ->
//! store) from a topology file, pushes data through the resource-level
//! services, and tears everything down.
//!
//! Run: `cargo run --release --example quickstart`

use ace::inapp::control::{ControlOp, ControlPipeline};
use ace::infra::agent::Agent;
use ace::infra::paper_testbed;
use ace::json::Value;
use ace::platform::api::ApiServer;
use ace::platform::{Controller, Monitor};
use ace::pubsub::{Bridge, Broker};
use ace::storage::{FileService, Lifecycle, ObjectStore};
use ace::topology::Topology;
use std::collections::BTreeMap;
use std::time::Duration;

const PIPELINE_TOPOLOGY: &str = "
app: iot-anomaly
version: 1
components:
  - name: sensor-filter
    location: edge
    placement: per-ec
    resources:
      cpu: 200
      mem: 64
    connections: [aggregator]
  - name: aggregator
    location: edge
    placement: per-ec
    resources:
      cpu: 400
      mem: 128
    connections: [store]
  - name: store
    location: cloud
    resources:
      cpu: 500
      mem: 512
";

fn main() -> anyhow::Result<()> {
    // ---- user registration (§4.3.1) ----
    let infra = paper_testbed("quickstart");
    println!(
        "registered infrastructure {} ({} ECs + CC, {} nodes)",
        infra.id,
        infra.ecs.len(),
        infra.all_nodes().count()
    );

    // ---- resource layer: per-cluster brokers + bridges (§4.3.2) ----
    let brokers: BTreeMap<String, Broker> = infra
        .clusters()
        .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
        .collect();
    let _bridges: Vec<Bridge> = infra
        .ecs
        .iter()
        .map(|ec| {
            Bridge::start(&brokers[ec.id.leaf()], &brokers["cc"], &["cloud/#"], &["edge/#"])
                .unwrap()
        })
        .collect();
    println!("message services up; {} EC<->CC bridges established", infra.ecs.len());

    // agents on all nodes
    let agents: Vec<Agent> = infra
        .all_nodes()
        .map(|(c, n)| Agent::start(n.id.clone(), brokers[c.id.leaf()].clone()).unwrap())
        .collect();

    // ---- platform layer ----
    let api = ApiServer::new();
    let monitor = Monitor::start(api.clone(), &brokers).unwrap();
    let ctl = Controller::new(api.clone(), brokers.clone());

    // ---- application development + deployment (§4.4) ----
    let topo = Topology::parse(PIPELINE_TOPOLOGY)?;
    let plan = ctl.deploy(&topo, &infra)?;
    println!("deployed '{}': {} instances", plan.app, plan.instances.len());
    for inst in &plan.instances {
        println!("  {} -> {}", inst.id, inst.node);
    }
    std::thread::sleep(Duration::from_millis(300));
    println!("monitor sees: {:?}", monitor.component_health().keys().collect::<Vec<_>>());

    // ---- in-app control plane: the reusable pipeline (§4.4.2) ----
    let mut pipeline = ControlPipeline::new("anomaly")
        .op(
            "filter>0.9",
            ControlOp::Filter(Box::new(|v| v.get("reading").as_f64().unwrap_or(0.0) > 0.9)),
        )
        .op(
            "window4-mean",
            ControlOp::Aggregate {
                window: 4,
                f: Box::new(|items| {
                    let vals: Vec<f64> = items
                        .iter()
                        .filter_map(|v| v.get("reading").as_f64())
                        .collect();
                    Value::obj(vec![
                        ("anomaly_mean", Value::num(vals.iter().sum::<f64>() / vals.len() as f64)),
                        ("count", Value::num(vals.len() as f64)),
                    ])
                }),
            },
        );

    // sensors publish over the local broker; the EC-side filter runs
    // the control pipeline; aggregates land in the CC file service
    let cc_store = FileService::new(ObjectStore::new(), brokers["cc"].clone(), "cc");
    let mut anomalies = 0;
    for i in 0..200 {
        let reading = (i as f64 * 0.37).sin().abs();
        let msg = Value::obj(vec![("reading", Value::num(reading))]);
        for out in pipeline.push(msg) {
            anomalies += 1;
            cc_store.put(
                "anomalies",
                &format!("window-{anomalies}"),
                ace::json::to_string(&out).into_bytes(),
                Lifecycle::Permanent,
            );
        }
    }
    println!(
        "pipeline stats: {:?}; {} anomaly windows persisted",
        pipeline.monitor(),
        cc_store.store.list("anomalies").len()
    );

    // ---- teardown ----
    ctl.remove("iot-anomaly")?;
    std::thread::sleep(Duration::from_millis(200));
    let still_running: usize = agents.iter().map(|a| a.running().len()).sum();
    println!("application removed; {still_running} instances remain across agents");
    Ok(())
}
