//! END-TO-END driver: the full ACE stack serving a real video query.
//!
//! This is the repository's headline example (EXPERIMENTS.md §E2E): it
//! composes ALL layers on a real workload —
//!
//!   1. registers the §5.1.1 testbed infrastructure;
//!   2. brings up per-cluster message services, EC<->CC bridges, node
//!      agents, monitoring, controller;
//!   3. submits the §5 video-query topology; the orchestrator binds
//!      DG/OD on the camera RPis, EOC+LIC per EC, COC/IC/RS on the CC;
//!   4. loads the AOT-compiled EOC/COC HLO artifacts through the PJRT
//!      runtime (L1 Pallas kernels inside L2 JAX graphs — python was
//!      only alive at `make artifacts` time);
//!   5. serves a 30-virtual-second motorcycle query over synthetic
//!      camera streams under ACE+ (AP), with REAL batched inference for
//!      every crop, and reports F1 / BWC / EIL / throughput;
//!   6. tears the application down.
//!
//! Run: `cargo run --release --example video_query_e2e`

use ace::app::videoquery::{run_cell, CellConfig, Compute, InferCache, Paradigm, ServiceTimes};
use ace::infra::agent::Agent;
use ace::infra::paper_testbed;
use ace::platform::api::ApiServer;
use ace::platform::{Controller, Monitor};
use ace::pubsub::{Bridge, Broker};
use ace::runtime::{artifacts_dir, Engine, ModelBank};
use ace::topology::{Topology, VIDEOQUERY_TOPOLOGY};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let wall0 = Instant::now();

    // ---- phase 1: infrastructure registration ----
    let infra = paper_testbed("e2e");
    println!(
        "[1/6] infrastructure {}: {} ECs x 4 nodes + CC",
        infra.id,
        infra.ecs.len()
    );

    // ---- phase 2: resource + platform layers ----
    let brokers: BTreeMap<String, Broker> = infra
        .clusters()
        .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
        .collect();
    let _bridges: Vec<Bridge> = infra
        .ecs
        .iter()
        .map(|ec| {
            Bridge::start(&brokers[ec.id.leaf()], &brokers["cc"], &["cloud/#"], &["edge/#"])
                .unwrap()
        })
        .collect();
    let agents: Vec<Agent> = infra
        .all_nodes()
        .map(|(c, n)| Agent::start(n.id.clone(), brokers[c.id.leaf()].clone()).unwrap())
        .collect();
    let api = ApiServer::new();
    let monitor = Monitor::start(api.clone(), &brokers).unwrap();
    let ctl = Controller::new(api.clone(), brokers.clone());
    println!("[2/6] message services + bridges + {} agents + monitor up", agents.len());

    // ---- phase 3: application deployment ----
    let topo = Topology::parse(VIDEOQUERY_TOPOLOGY)?;
    let plan = ctl.deploy(&topo, &infra)?;
    std::thread::sleep(Duration::from_millis(300));
    let health = monitor.component_health();
    println!(
        "[3/6] '{}' deployed: {} instances ({} components healthy)",
        plan.app,
        plan.instances.len(),
        health.len()
    );
    for (comp, h) in &health {
        println!("      {comp}: {} running", h.running);
    }

    // ---- phase 4: AOT runtime ----
    let engine = Engine::cpu()?;
    let dir = artifacts_dir()?;
    let mut bank = ModelBank::load(&engine, &dir)?;
    bank.calibrate(3)?;
    println!(
        "[4/6] PJRT runtime: platform={}, eoc {} params ({} exes), coc {} params ({} exes)",
        engine.platform(),
        bank.manifest.models["eoc"].params,
        bank.eoc.batch_sizes.len(),
        bank.manifest.models["coc"].params,
        bank.coc.batch_sizes.len(),
    );
    let svc = ServiceTimes::calibrated_to_paper(&bank);

    // ---- phase 5: serve the query (ACE+, practical network) ----
    let cfg = CellConfig {
        paradigm: Paradigm::AceAp,
        interval_s: 0.2,
        wan_delay_ms: 50.0,
        duration_s: 30.0,
        seed: 7,
        ..Default::default()
    };
    let bank = Arc::new(bank);
    let cache = Arc::new(Mutex::new(InferCache::new()));
    let t0 = Instant::now();
    let m = run_cell(
        cfg.clone(),
        svc,
        Compute::Real { bank: bank.clone(), cache: cache.clone() },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let eil_ms = m.eil_ms();
    let eil_p99 = m.eil_p99_ms();
    println!(
        "[5/6] query served ({} virtual s in {:.1} wall s):",
        cfg.duration_s, wall
    );
    println!("      crops extracted : {}", m.crops);
    println!("      edge-decided    : {} ({} uploaded to COC)", m.edge_decided, m.cloud_decided);
    println!("      F1 vs COC-posthoc ground truth: {:.3} (precision {:.3}, recall {:.3})",
        m.f1.f1(), m.f1.precision(), m.f1.recall());
    println!("      BWC (WAN bytes) : {:.2} MB", m.bwc_mb());
    println!("      EIL mean/p99    : {:.1} / {:.1} ms", eil_ms, eil_p99);
    println!(
        "      throughput      : {:.1} crops/s virtual, {:.1} crops/s wall",
        m.crops as f64 / cfg.duration_s,
        m.crops as f64 / wall
    );
    // one guard: two lock() calls in a single statement would deadlock
    let c = cache.lock().unwrap();
    println!(
        "      real XLA execs  : {} eoc + {} coc batches",
        c.eoc_execs, c.coc_execs
    );
    drop(c);

    // ---- phase 6: teardown ----
    ctl.remove("videoquery")?;
    std::thread::sleep(Duration::from_millis(200));
    println!(
        "[6/6] removed; agents now run {} instances total; {:.1}s end to end",
        agents.iter().map(|a| a.running().len()).sum::<usize>(),
        wall0.elapsed().as_secs_f64()
    );
    Ok(())
}
