//! §4.2.2 Validation Testbed: evaluate the video-query app under
//! edge-cloud channel dynamics BEFORE deployment.
//!
//! Runs the same ACE+ workload (real XLA inference) under four WAN
//! profiles — the paper's ideal and practical channels, a mid-run
//! bandwidth collapse, and a high-jitter channel — and prints the
//! side-by-side F1/BWC/EIL report a developer would use to understand
//! "the actual performance of an ECCI application in real-world
//! networks".
//!
//! Run: `cargo run --release --example validation_testbed`

use ace::app::videoquery::{CellConfig, Compute, InferCache, Paradigm, ServiceTimes};
use ace::runtime::{artifacts_dir, Engine, ModelBank};
use ace::testbed::{evaluate, report, ChannelProfile};
use std::sync::{Arc, Mutex};

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let mut bank = ModelBank::load(&engine, &artifacts_dir()?)?;
    bank.calibrate(3)?;
    let svc = ServiceTimes::calibrated_to_paper(&bank);
    let bank = Arc::new(bank);
    let cache = Arc::new(Mutex::new(InferCache::new()));

    let base = CellConfig {
        paradigm: Paradigm::AceAp,
        interval_s: 0.15,
        duration_s: 24.0,
        seed: 3,
        ..Default::default()
    };
    let profiles = vec![
        ChannelProfile::paper_wan(0.0),
        ChannelProfile::paper_wan(50.0),
        ChannelProfile::degraded(8.0, 16.0, 0.3), // WAN squeezed to 0.3 Mbps mid-run
        ChannelProfile::jittery(50.0, 100.0),     // 50 +- [0,100] ms delay
    ];

    eprintln!(
        "[testbed] evaluating '{}' under {} channel profiles ({}s virtual each)...",
        "videoquery/ACE+",
        profiles.len(),
        base.duration_s
    );
    let results = evaluate(&base, &profiles, &svc, || Compute::Real {
        bank: bank.clone(),
        cache: cache.clone(),
    })?;

    println!("\n# Validation testbed report — videoquery under ACE+\n");
    println!("{}", report(&results));
    println!(
        "(profiles: paper ideal/practical WAN; 2 Mbps squeeze during [8s,16s); 50±100 ms jitter)"
    );

    // the squeeze under the NON-adaptive Basic Policy, for contrast —
    // exactly the what-if a developer runs on the testbed before
    // choosing a policy
    let mut bp = base.clone();
    bp.paradigm = Paradigm::AceBp;
    let bp_results = evaluate(
        &bp,
        &[ChannelProfile::paper_wan(0.0), ChannelProfile::degraded(8.0, 16.0, 0.3)],
        &svc,
        || Compute::Real { bank: bank.clone(), cache: cache.clone() },
    )?;
    println!("\n# Same squeeze under the Basic Policy (no adaptation)\n");
    println!("{}", report(&bp_results));

    // developer-takeaway checks, asserted so regressions get caught
    let eil_ap: Vec<f64> = results.iter().map(|(_, m)| m.eil.mean()).collect();
    assert!(eil_ap[1] > eil_ap[0], "practical delay should cost EIL");
    let p99_jitter = results[3].1.eil.quantile(0.99);
    let p99_stable = results[1].1.eil.quantile(0.99);
    assert!(p99_jitter > p99_stable, "jitter should widen the p99 tail");
    // the squeeze shows up in AP's tail latency (its load-balancing
    // diversion keeps using the WAN), while BP's narrow upload band
    // sails under even 0.3 Mbps — exactly the kind of policy-selection
    // insight the validation testbed exists to surface (§4.2.2)
    let ap_p99_squeeze = results[2].1.eil.quantile(0.99);
    let ap_p99_base = results[0].1.eil.quantile(0.99);
    assert!(
        ap_p99_squeeze > ap_p99_base * 1.5,
        "squeeze invisible in AP p99: {ap_p99_squeeze} vs {ap_p99_base}"
    );
    let bp_cost = bp_results[1].1.eil.mean() / bp_results[0].1.eil.mean();
    println!(
        "\nOK: delay + jitter visible; 0.3 Mbps squeeze widens AP's p99 {:.1}x while BP \
         (narrow upload band) pays only {bp_cost:.2}x mean — the testbed exposes the \
         policy's bandwidth appetite before deployment",
        ap_p99_squeeze / ap_p99_base
    );
    Ok(())
}
