//! ECC *training* pattern (§2): federated learning across ECs.
//!
//! The CC coordinates FedAvg rounds over three ECs. Each round:
//!   1. the CC publishes the global model to every EC's file service
//!      (control over the bridged message bus, data via object store —
//!      the Figure 2 split);
//!   2. each EC runs LOCAL SGD steps on its private shard using the
//!      AOT-compiled `fl_train_step.hlo.txt` (one XLA executable, the
//!      same artifact pattern as the classifiers);
//!   3. ECs upload their updates; the CC federated-averages them.
//!
//! Client data is non-IID (each EC sees a biased slice), so the
//! federated model must beat every client-only model on the global
//! test set — which the example asserts.
//!
//! Run: `cargo run --release --example federated_training_sim`

use ace::app::fedtrain::{self, Model, DIM};
use ace::pubsub::{Bridge, Broker};
use ace::runtime::{artifacts_dir, literal_f32, literal_i32, Engine};
use ace::storage::{FileService, Lifecycle, ObjectStore};

const BATCH: usize = 32;
const ECS: usize = 3;
const ROUNDS: usize = 12;
const LOCAL_STEPS: usize = 4;

/// Same non-IID shard generator as the in-DES `app/fedtrain` workload
/// (one definition, so the example and the simulation cannot drift).
fn make_shard(ec: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    fedtrain::make_shard(ec, ECS, n, seed)
}

fn accuracy(w: &[f32], b: &[f32], x: &[f32], y: &[i32]) -> f64 {
    fedtrain::accuracy(&Model { w: w.to_vec(), b: b.to_vec() }, x, y)
}

fn serialize_f32(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|f| f.to_le_bytes()).collect()
}

fn deserialize_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn main() -> anyhow::Result<()> {
    // resource layer: CC + per-EC brokers/stores, bridged
    let cc_broker = Broker::new("cc");
    let ec_brokers: Vec<Broker> = (0..ECS).map(|i| Broker::new(format!("ec-{i}"))).collect();
    let _bridges: Vec<Bridge> = ec_brokers
        .iter()
        .map(|ec| Bridge::start(ec, &cc_broker, &["cloud/#"], &["edge/#"]).unwrap())
        .collect();
    let cc_files = FileService::new(ObjectStore::new(), cc_broker.clone(), "cc");
    let ec_files: Vec<FileService> = ec_brokers
        .iter()
        .enumerate()
        .map(|(i, b)| FileService::new(ObjectStore::new(), b.clone(), format!("ec-{i}")))
        .collect();

    // runtime: the per-client train step is ONE AOT artifact
    let engine = Engine::cpu()?;
    let dir = artifacts_dir()?;
    let manifest = ace::runtime::Manifest::load(&dir.join("manifest.json"))?;
    let step = engine.load(&dir.join(&manifest.fl_file))?;
    println!(
        "loaded {} (dim={} batch={})",
        manifest.fl_file, manifest.fl_dim, manifest.fl_batch
    );
    assert_eq!(manifest.fl_dim, DIM);
    assert_eq!(manifest.fl_batch, BATCH);

    // data: non-IID shards + a global test set
    let shards: Vec<(Vec<f32>, Vec<i32>)> =
        (0..ECS).map(|ec| make_shard(ec, 256, 42)).collect();
    let mut test_x = Vec::new();
    let mut test_y = Vec::new();
    for ec in 0..ECS {
        let (x, y) = make_shard(ec, 128, 777);
        test_x.extend(x);
        test_y.extend(y);
    }

    // TRUE client-only baselines: same step budget, own shard only,
    // never federated — what each EC could do without the CC.
    let mut client_only_acc = vec![0.0f64; ECS];
    for ec in 0..ECS {
        let mut lw = vec![0.0f32; DIM * 2];
        let mut lb = vec![0.0f32; 2];
        let (x, y) = &shards[ec];
        let nb = x.len() / (BATCH * DIM);
        for step_i in 0..ROUNDS * LOCAL_STEPS {
            let bi = step_i % nb;
            let xs = &x[bi * BATCH * DIM..(bi + 1) * BATCH * DIM];
            let ys = &y[bi * BATCH..(bi + 1) * BATCH];
            let out = step.run(&[
                literal_f32(&lw, &[DIM as i64, 2])?,
                literal_f32(&lb, &[2])?,
                literal_f32(xs, &[BATCH as i64, DIM as i64])?,
                literal_i32(ys, &[BATCH as i64])?,
                literal_f32(&[0.3], &[])?,
            ])?;
            lw = out[0].to_vec::<f32>()?;
            lb = out[1].to_vec::<f32>()?;
        }
        client_only_acc[ec] = accuracy(&lw, &lb, &test_x, &test_y);
    }

    let mut w = vec![0.0f32; DIM * 2];
    let mut b = vec![0.0f32; 2];

    for round in 0..ROUNDS {
        // 1. CC -> ECs: global model via file services (data plane) +
        //    announcement (control plane rides the bridge)
        cc_files.put("fl", "global", serialize_f32(&w), Lifecycle::Temporary);
        cc_files.put("fl", "global_b", serialize_f32(&b), Lifecycle::Temporary);
        for fs in &ec_files {
            fs.put("fl", "global", serialize_f32(&w), Lifecycle::Temporary);
            fs.put("fl", "global_b", serialize_f32(&b), Lifecycle::Temporary);
        }

        // 2. local training on each EC (real XLA steps)
        let mut sum_w = vec![0.0f32; DIM * 2];
        let mut sum_b = vec![0.0f32; 2];
        let mut last_losses = Vec::new();
        for (ec, fs) in ec_files.iter().enumerate() {
            let mut lw = deserialize_f32(&fs.get("fl", "global").unwrap());
            let mut lb = deserialize_f32(&fs.get("fl", "global_b").unwrap());
            let (x, y) = &shards[ec];
            let nb = x.len() / (BATCH * DIM);
            let mut loss = 0.0f32;
            for step_i in 0..LOCAL_STEPS {
                let bi = (round * LOCAL_STEPS + step_i) % nb;
                let xs = &x[bi * BATCH * DIM..(bi + 1) * BATCH * DIM];
                let ys = &y[bi * BATCH..(bi + 1) * BATCH];
                let out = step.run(&[
                    literal_f32(&lw, &[DIM as i64, 2])?,
                    literal_f32(&lb, &[2])?,
                    literal_f32(xs, &[BATCH as i64, DIM as i64])?,
                    literal_i32(ys, &[BATCH as i64])?,
                    literal_f32(&[0.3], &[])?,
                ])?;
                lw = out[0].to_vec::<f32>()?;
                lb = out[1].to_vec::<f32>()?;
                loss = out[2].to_vec::<f32>()?[0];
            }
            last_losses.push(loss);
            // 3. upload update (object store data plane)
            fs.put("fl", "update", serialize_f32(&lw), Lifecycle::Temporary);
            fs.put("fl", "update_b", serialize_f32(&lb), Lifecycle::Temporary);
            for (acc, v) in sum_w.iter_mut().zip(&lw) {
                *acc += v;
            }
            for (acc, v) in sum_b.iter_mut().zip(&lb) {
                *acc += v;
            }
        }

        // FedAvg at the CC
        for v in sum_w.iter_mut() {
            *v /= ECS as f32;
        }
        for v in sum_b.iter_mut() {
            *v /= ECS as f32;
        }
        w = sum_w;
        b = sum_b;
        let acc = accuracy(&w, &b, &test_x, &test_y);
        println!(
            "round {round:>2}: losses {:?}  global acc {:.3}",
            last_losses.iter().map(|l| format!("{l:.3}")).collect::<Vec<_>>(),
            acc
        );
    }

    let fed_acc = accuracy(&w, &b, &test_x, &test_y);
    println!("\nfederated model accuracy : {fed_acc:.3}");
    for (ec, acc) in client_only_acc.iter().enumerate() {
        println!("client-only (EC {ec})      : {acc:.3}");
    }
    // gc temporary round files (lifecycle policy, §4.3.2)
    let purged: usize = ec_files.iter().map(|f| f.store.gc()).sum::<usize>() + cc_files.store.gc();
    println!("gc purged {purged} temporary objects");
    let best_client = client_only_acc.iter().cloned().fold(0.0f64, f64::max);
    let mean_client =
        client_only_acc.iter().sum::<f64>() / client_only_acc.len() as f64;
    assert!(
        fed_acc > mean_client,
        "federation ({fed_acc:.3}) failed to beat the mean client-only model ({mean_client:.3})"
    );
    println!(
        "OK: federated {fed_acc:.3} vs client-only mean {mean_client:.3} / best {best_client:.3}"
    );
    Ok(())
}
