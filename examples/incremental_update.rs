//! §4.4.3 application management: thorough vs incremental updates.
//!
//! Deploys the video-query topology, then pushes three successive
//! topology changes and shows what each update style touches:
//!
//!   v2 — od image bump            -> incremental touches ONLY the 9
//!                                     camera nodes;
//!   v3 — rs resources + new comp  -> incremental adds the new
//!                                     component without disturbing od;
//!   v4 — thorough update          -> full redeploy (every node).
//!
//! Run: `cargo run --release --example incremental_update`

use ace::infra::agent::Agent;
use ace::infra::paper_testbed;
use ace::platform::api::ApiServer;
use ace::platform::Controller;
use ace::pubsub::Broker;
use ace::topology::{Topology, VIDEOQUERY_TOPOLOGY};
use std::collections::BTreeMap;
use std::time::Duration;

fn wait_settle() {
    std::thread::sleep(Duration::from_millis(250));
}

fn main() -> anyhow::Result<()> {
    let infra = paper_testbed("upd");
    let brokers: BTreeMap<String, Broker> = infra
        .clusters()
        .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
        .collect();
    let agents: Vec<Agent> = infra
        .all_nodes()
        .map(|(c, n)| Agent::start(n.id.clone(), brokers[c.id.leaf()].clone()).unwrap())
        .collect();
    let ctl = Controller::new(ApiServer::new(), brokers.clone());

    // v1: initial deployment
    let topo = Topology::parse(VIDEOQUERY_TOPOLOGY)?;
    let plan = ctl.deploy(&topo, &infra)?;
    wait_settle();
    println!(
        "v1 deployed: {} instances across {} nodes",
        plan.instances.len(),
        plan.nodes().len()
    );

    // v2: bump only od's image -> incremental touches the camera nodes
    let mut v2 = topo.clone();
    v2.version = 2;
    for c in &mut v2.components {
        if c.name == "od" {
            c.image = "ace/object-detector:2".into();
        }
    }
    let (_, touched) = ctl.update_incremental(&v2, &infra)?;
    wait_settle();
    let od2 = agents
        .iter()
        .flat_map(|a| a.running())
        .filter(|r| r.component == "od" && r.image.ends_with(":2"))
        .count();
    println!("v2 incremental: touched {touched} nodes (expect 9); {od2}/9 od instances on :2");

    // v3: add an alerting component on the CC; nothing else moves
    let mut v3_doc = String::from(VIDEOQUERY_TOPOLOGY.trim_end().to_string());
    v3_doc.push_str(
        "
  - name: alert
    image: ace/alerter:1
    location: cloud
    resources:
      cpu: 200
      mem: 128
    connections: [rs]
",
    );
    let mut v3 = Topology::parse(&v3_doc)?;
    v3.version = 3;
    for c in &mut v3.components {
        if c.name == "od" {
            c.image = "ace/object-detector:2".into(); // keep v2's od
        }
    }
    let (_, touched) = ctl.update_incremental(&v3, &infra)?;
    wait_settle();
    println!("v3 incremental: touched {touched} node(s) (expect 1 — the CC)");

    // v4: thorough update re-deploys everything
    let mut v4 = v3.clone();
    v4.version = 4;
    let plan4 = ctl.update_thorough(&v4, &infra)?;
    wait_settle();
    println!(
        "v4 thorough: full redeploy of {} instances across {} nodes",
        plan4.instances.len(),
        plan4.nodes().len()
    );

    // final state check
    let total: usize = agents.iter().map(|a| a.running().len()).sum();
    println!("agents now run {total} instances (expect {})", plan4.instances.len());
    ctl.remove("videoquery")?;
    wait_settle();
    println!("removed; agents empty: {}", agents.iter().all(|a| a.running().is_empty()));
    Ok(())
}
