//! Topology files (§4.4.3, Figure 4): the application specification.
//!
//! A topology file is an "extended YAML" document describing the app,
//! its components (images, resource requirements, placement labels,
//! connections) and how many instances to run. The orchestrator turns
//! it into a deployment plan; submitting an updated file triggers a
//! thorough or incremental update (`deploy::diff_plans`).
//!
//! Example (matches Figure 4's fields):
//!
//! ```yaml
//! app: videoquery
//! version: 2
//! components:
//!   - name: od
//!     image: ace/od:2
//!     location: edge
//!     placement: per-label
//!     label: camera
//!     resources:
//!       cpu: 500
//!       mem: 256
//!     connections: [lic, eoc, coc]
//! ```

use crate::infra::Resources;
use crate::json::Value;
use crate::yamlite;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Where a component may run (the paper's edge/cloud user requirement).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    Edge,
    Cloud,
    Any,
}

impl Location {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "edge" => Location::Edge,
            "cloud" => Location::Cloud,
            "any" => Location::Any,
            other => bail!("bad location '{other}' (edge|cloud|any)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Location::Edge => "edge",
            Location::Cloud => "cloud",
            Location::Any => "any",
        }
    }
}

/// Placement mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Placement {
    /// N instances anywhere satisfying the constraints.
    Replicas(usize),
    /// One instance on EVERY matching node (e.g. OD on each camera
    /// node); `label` is required.
    PerLabel,
    /// One instance per EC (e.g. the EC-local in-app controller).
    PerEc,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ComponentSpec {
    pub name: String,
    pub image: String,
    pub location: Location,
    pub placement: Placement,
    /// node label filter, `key` or `key=value`
    pub label: Option<String>,
    pub resources: Resources,
    pub connections: Vec<String>,
    /// free-form parameters forwarded to the component
    pub params: BTreeMap<String, String>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub app: String,
    pub version: u64,
    pub components: Vec<ComponentSpec>,
}

impl Topology {
    pub fn component(&self, name: &str) -> Option<&ComponentSpec> {
        self.components.iter().find(|c| c.name == name)
    }

    /// Parse + validate a topology document.
    pub fn parse(src: &str) -> Result<Topology> {
        let doc = yamlite::parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_value(&doc)
    }

    pub fn from_value(doc: &Value) -> Result<Topology> {
        let app = doc
            .get("app")
            .as_str()
            .context("topology: missing 'app'")?
            .to_string();
        let version = doc.get("version").as_i64().unwrap_or(1) as u64;
        let comps = doc
            .get("components")
            .as_arr()
            .context("topology: missing 'components'")?;
        let mut components = Vec::new();
        for (i, c) in comps.iter().enumerate() {
            let name = c
                .get("name")
                .as_str()
                .with_context(|| format!("component #{i}: missing 'name'"))?
                .to_string();
            let image = c
                .get("image")
                .as_str()
                .unwrap_or(&format!("ace/{name}:latest"))
                .to_string();
            let location = Location::parse(c.get("location").as_str().unwrap_or("any"))?;
            let label = c.get("label").as_str().map(|s| s.to_string());
            let placement = match c.get("placement").as_str().unwrap_or("replicas") {
                "per-label" => {
                    if label.is_none() {
                        bail!("component '{name}': per-label placement requires 'label'");
                    }
                    Placement::PerLabel
                }
                "per-ec" => Placement::PerEc,
                "replicas" => {
                    Placement::Replicas(c.get("replicas").as_usize().unwrap_or(1))
                }
                other => bail!("component '{name}': bad placement '{other}'"),
            };
            let resources = Resources {
                cpu_millis: c.get("resources").get("cpu").as_usize().unwrap_or(100) as u32,
                mem_mb: c.get("resources").get("mem").as_usize().unwrap_or(64) as u32,
            };
            if resources.cpu_millis == 0 || resources.mem_mb == 0 {
                bail!("component '{name}': zero resource request");
            }
            let connections = c
                .get("connections")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                .collect();
            let mut params = BTreeMap::new();
            if let Some(obj) = c.get("params").as_obj() {
                for (k, v) in obj {
                    let s = match v {
                        Value::Str(s) => s.clone(),
                        other => crate::json::to_string(other),
                    };
                    params.insert(k.clone(), s);
                }
            }
            components.push(ComponentSpec {
                name,
                image,
                location,
                placement,
                label,
                resources,
                connections,
                params,
            });
        }
        let topo = Topology { app, version, components };
        topo.validate()?;
        Ok(topo)
    }

    /// Structural validation: unique names, resolvable connections, no
    /// self-connection.
    pub fn validate(&self) -> Result<()> {
        let mut names = BTreeSet::new();
        for c in &self.components {
            if !names.insert(c.name.as_str()) {
                bail!("duplicate component name '{}'", c.name);
            }
        }
        for c in &self.components {
            for conn in &c.connections {
                if conn == &c.name {
                    bail!("component '{}' connects to itself", c.name);
                }
                if !names.contains(conn.as_str()) {
                    bail!("component '{}' connects to unknown '{conn}'", c.name);
                }
            }
        }
        if self.components.is_empty() {
            bail!("topology has no components");
        }
        Ok(())
    }

    /// Connection edges (unordered pairs, deduped).
    pub fn edges(&self) -> BTreeSet<(String, String)> {
        let mut out = BTreeSet::new();
        for c in &self.components {
            for conn in &c.connections {
                let (a, b) = if c.name < *conn {
                    (c.name.clone(), conn.clone())
                } else {
                    (conn.clone(), c.name.clone())
                };
                out.insert((a, b));
            }
        }
        out
    }
}

/// The video-query application topology used throughout §5 (DG, OD,
/// EOC, COC, IC [global + per-EC local], RS).
pub const VIDEOQUERY_TOPOLOGY: &str = r#"
app: videoquery
version: 1
components:
  - name: dg
    image: ace/datagen:1
    location: edge
    placement: per-label
    label: camera
    resources:
      cpu: 200
      mem: 128
    connections: [od]
  - name: od
    image: ace/object-detector:1
    location: edge
    placement: per-label
    label: camera
    resources:
      cpu: 1000
      mem: 256
    connections: [lic, eoc, coc]
    params:
      interval: "0.5"
  - name: eoc
    image: ace/edge-classifier:1
    location: edge
    placement: per-ec
    resources:
      cpu: 4000
      mem: 2048
    connections: [lic, coc]
  - name: lic
    image: ace/inapp-controller:1
    location: edge
    placement: per-ec
    resources:
      cpu: 500
      mem: 256
    connections: [ic]
  - name: coc
    image: ace/cloud-classifier:1
    location: cloud
    resources:
      cpu: 16000
      mem: 8192
    connections: [ic, rs]
  - name: ic
    image: ace/inapp-controller:1
    location: cloud
    resources:
      cpu: 1000
      mem: 512
    connections: [rs]
  - name: rs
    image: ace/result-storage:1
    location: cloud
    resources:
      cpu: 500
      mem: 1024
    connections: []
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_videoquery_topology() {
        let t = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        assert_eq!(t.app, "videoquery");
        assert_eq!(t.components.len(), 7);
        let od = t.component("od").unwrap();
        assert_eq!(od.location, Location::Edge);
        assert_eq!(od.placement, Placement::PerLabel);
        assert_eq!(od.label.as_deref(), Some("camera"));
        assert_eq!(od.resources.cpu_millis, 1000);
        assert_eq!(od.connections, vec!["lic", "eoc", "coc"]);
        assert_eq!(od.params.get("interval").map(|s| s.as_str()), Some("0.5"));
        let coc = t.component("coc").unwrap();
        assert_eq!(coc.location, Location::Cloud);
        assert_eq!(coc.placement, Placement::Replicas(1));
    }

    #[test]
    fn rejects_duplicate_names() {
        let bad = "
app: x
components:
  - name: a
  - name: a
";
        assert!(Topology::parse(bad).is_err());
    }

    #[test]
    fn rejects_unknown_connection() {
        let bad = "
app: x
components:
  - name: a
    connections: [ghost]
";
        assert!(Topology::parse(bad).is_err());
    }

    #[test]
    fn rejects_self_connection() {
        let bad = "
app: x
components:
  - name: a
    connections: [a]
";
        assert!(Topology::parse(bad).is_err());
    }

    #[test]
    fn rejects_per_label_without_label() {
        let bad = "
app: x
components:
  - name: a
    placement: per-label
";
        assert!(Topology::parse(bad).is_err());
    }

    #[test]
    fn edges_are_deduped_and_unordered() {
        let t = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let edges = t.edges();
        assert!(edges.contains(&("coc".to_string(), "od".to_string())));
        // od->coc and no duplicate reverse edge
        assert_eq!(
            edges.iter().filter(|(a, b)| (a == "coc" && b == "od") || (a == "od" && b == "coc")).count(),
            1
        );
    }

    #[test]
    fn defaults_fill_in() {
        let t = Topology::parse("app: mini\ncomponents:\n  - name: solo\n").unwrap();
        let c = t.component("solo").unwrap();
        assert_eq!(c.location, Location::Any);
        assert_eq!(c.placement, Placement::Replicas(1));
        assert_eq!(c.resources.cpu_millis, 100);
        assert_eq!(t.version, 1);
    }
}
