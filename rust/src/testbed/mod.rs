//! Validation testbed (§4.2.2): evaluate an ECCI application under
//! controlled edge-cloud channel dynamics before deploying it.
//!
//! "The impact of edge-cloud channel dynamics (e.g., bandwidth, delay,
//! jitter) on the testbed can help users understand the actual
//! performance of an ECCI application in real-world networks." A
//! `ChannelProfile` is a piecewise schedule of WAN shapes; the
//! video-query world applies each phase to its uplinks/downlinks at the
//! scheduled virtual time (the SDN-reconfiguration analogue), and
//! `evaluate` runs the same workload under several profiles for
//! comparison.

use crate::app::videoquery::{run_cell, CellConfig, Compute, ServiceTimes};
use crate::metrics::CellMetrics;
use crate::util::SimTime;
use anyhow::Result;

/// One WAN shape, active from `start_s` until the next phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    pub start_s: f64,
    pub uplink_mbps: f64,
    pub downlink_mbps: f64,
    pub delay_ms: f64,
    pub jitter_ms: f64,
}

impl Phase {
    pub fn stable(uplink_mbps: f64, downlink_mbps: f64, delay_ms: f64) -> Self {
        Phase { start_s: 0.0, uplink_mbps, downlink_mbps, delay_ms, jitter_ms: 0.0 }
    }

    pub fn delay_us(&self) -> SimTime {
        crate::util::millis(self.delay_ms)
    }

    pub fn jitter_us(&self) -> SimTime {
        crate::util::millis(self.jitter_ms)
    }
}

/// A named piecewise channel schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelProfile {
    pub name: String,
    /// must be sorted by start_s; phase 0 should start at 0
    pub phases: Vec<Phase>,
}

impl ChannelProfile {
    pub fn new(name: impl Into<String>, phases: Vec<Phase>) -> Self {
        let mut phases = phases;
        phases.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
        ChannelProfile { name: name.into(), phases }
    }

    /// The paper's baseline: 20/40 Mbps, fixed delay, no jitter.
    pub fn paper_wan(delay_ms: f64) -> Self {
        ChannelProfile::new(
            format!("paper-{delay_ms}ms"),
            vec![Phase::stable(20.0, 40.0, delay_ms)],
        )
    }

    /// Mid-run degradation: bandwidth collapses for `[from_s, to_s)`.
    pub fn degraded(from_s: f64, to_s: f64, mbps: f64) -> Self {
        ChannelProfile::new(
            format!("degraded-{mbps}mbps"),
            vec![
                Phase::stable(20.0, 40.0, 0.0),
                Phase { start_s: from_s, uplink_mbps: mbps, downlink_mbps: mbps * 2.0, delay_ms: 0.0, jitter_ms: 0.0 },
                Phase { start_s: to_s, ..Phase::stable(20.0, 40.0, 0.0) },
            ],
        )
    }

    /// Jittery channel: fixed bandwidth, delay with +/- jitter.
    pub fn jittery(delay_ms: f64, jitter_ms: f64) -> Self {
        ChannelProfile::new(
            format!("jittery-{delay_ms}+-{jitter_ms}ms"),
            vec![Phase { start_s: 0.0, uplink_mbps: 20.0, downlink_mbps: 40.0, delay_ms, jitter_ms }],
        )
    }

    /// Phase active at time `t` (seconds).
    pub fn phase_at(&self, t: f64) -> &Phase {
        let mut cur = &self.phases[0];
        for p in &self.phases {
            if p.start_s <= t {
                cur = p;
            }
        }
        cur
    }
}

/// Run one workload cell under each profile; returns (profile name,
/// metrics) pairs for a side-by-side report.
pub fn evaluate(
    base: &CellConfig,
    profiles: &[ChannelProfile],
    svc: &ServiceTimes,
    mut compute: impl FnMut() -> Compute,
) -> Result<Vec<(String, CellMetrics)>> {
    let mut out = Vec::new();
    for profile in profiles {
        let mut cfg = base.clone();
        cfg.channel = Some(profile.clone());
        let m = run_cell(cfg, svc.clone(), compute())?;
        out.push((profile.name.clone(), m));
    }
    Ok(out)
}

/// Markdown report for an `evaluate` result.
pub fn report(results: &[(String, CellMetrics)]) -> String {
    let mut out = String::from(
        "| profile | F1 | BWC (MB) | EIL mean ms | EIL p99 ms |\n|---|---|---|---|---|\n",
    );
    for (name, m) in results.iter() {
        let eil = m.eil_ms();
        let p99 = m.eil_p99_ms();
        out.push_str(&format!(
            "| {name} | {:.3} | {:.2} | {eil:.1} | {p99:.1} |\n",
            m.f1.f1(),
            m.bwc_mb()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::videoquery::Paradigm;

    #[test]
    fn profile_phase_lookup() {
        let p = ChannelProfile::degraded(10.0, 20.0, 5.0);
        assert_eq!(p.phase_at(0.0).uplink_mbps, 20.0);
        assert_eq!(p.phase_at(12.0).uplink_mbps, 5.0);
        assert_eq!(p.phase_at(25.0).uplink_mbps, 20.0);
    }

    #[test]
    fn phases_sorted_on_construction() {
        let p = ChannelProfile::new(
            "x",
            vec![
                Phase { start_s: 10.0, ..Phase::stable(1.0, 1.0, 0.0) },
                Phase::stable(20.0, 40.0, 0.0),
            ],
        );
        assert_eq!(p.phases[0].start_s, 0.0);
    }

    #[test]
    fn degraded_channel_raises_upload_latency() {
        let base = CellConfig {
            paradigm: Paradigm::Ci, // every crop crosses the WAN
            interval_s: 0.5,
            duration_s: 12.0,
            ..Default::default()
        };
        let svc = ServiceTimes::synthetic();
        let results = evaluate(
            &base,
            &[
                ChannelProfile::paper_wan(0.0),
                ChannelProfile::degraded(3.0, 12.0, 1.0),
            ],
            &svc,
            || Compute::Synthetic { target_bias: 0.05 },
        )
        .unwrap();
        let stable = results[0].1.eil.mean();
        let degraded = results[1].1.eil.mean();
        assert!(
            degraded > stable * 1.3,
            "1 Mbps squeeze had no effect: {degraded} vs {stable}"
        );
        let text = report(&results);
        assert!(text.contains("degraded-1mbps"), "{text}");
    }

    #[test]
    fn jitter_widens_tail_latency() {
        let base = CellConfig {
            paradigm: Paradigm::Ci,
            interval_s: 0.5,
            duration_s: 12.0,
            ..Default::default()
        };
        let svc = ServiceTimes::synthetic();
        let results = evaluate(
            &base,
            &[ChannelProfile::paper_wan(20.0), ChannelProfile::jittery(20.0, 80.0)],
            &svc,
            || Compute::Synthetic { target_bias: 0.05 },
        )
        .unwrap();
        let stable_p99 = results[0].1.eil.quantile(0.99);
        let jitter_p99 = results[1].1.eil.quantile(0.99);
        assert!(
            jitter_p99 > stable_p99 + 0.020,
            "jitter invisible in p99: {jitter_p99} vs {stable_p99}"
        );
    }
}
