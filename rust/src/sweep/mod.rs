//! Parallel multi-cell sweep engine.
//!
//! Figure-5 cells (and fedtrain multi-seed runs) are embarrassingly
//! parallel: each cell is an independent DES over its own world, so
//! sweep wall-clock should be max-of-cells, not sum-of-cells. This
//! module provides the worker pool that makes that true — plain std
//! threads (no external deps), a shared work queue, and results
//! written back by input index so output order is deterministic and
//! identical to the serial path.
//!
//! Determinism argument: each job runs a complete, self-contained
//! simulation — all scheduling through `des::Scheduler`, all
//! randomness through seed-indexed `util::prng` streams. Threads share
//! nothing but the job queue and the result slots, so interleaving can
//! only change *when* a cell computes, never *what* it computes.
//! `tests/svcgraph_integration.rs` pins this with a byte-identical
//! serial-vs-parallel `figure5_csv` golden.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Worker count to use when the caller does not specify one.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `items` on `workers` threads, with one worker-local
/// state created per thread by `init` (e.g. a per-worker inference
/// cache, so workers never contend on a shared lock in their compute
/// hot path). Results come back in input order. A `workers <= 1` call
/// degenerates to a plain serial loop on the calling thread.
///
/// Panics in `f` propagate (the scope joins all workers first).
pub fn parallel_map_init<T, R, S, I, F>(items: Vec<T>, workers: usize, init: I, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        let mut state = init();
        return items.into_iter().map(|item| f(&mut state, item)).collect();
    }
    let workers = workers.min(n);
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    // lock released before the (long) job runs
                    let job = queue.lock().unwrap().pop_front();
                    let Some((i, item)) = job else { break };
                    let r = f(&mut state, item);
                    slots.lock().unwrap()[i] = Some(r);
                }
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every queued job completes"))
        .collect()
}

/// Stateless convenience wrapper over [`parallel_map_init`].
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_init(items, workers, || (), |_, item| f(item))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_come_back_in_input_order() {
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(items.clone(), 8, |i| {
            // stagger so completion order differs from input order
            std::thread::sleep(std::time::Duration::from_micros(((i * 37) % 64) as u64));
            i * 2
        });
        assert_eq!(out, items.iter().map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..33).collect();
        let serial = parallel_map(items.clone(), 1, |i| i * i + 1);
        let parallel = parallel_map(items, 4, |i| i * i + 1);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn init_runs_once_per_worker() {
        let inits = AtomicUsize::new(0);
        let out = parallel_map_init(
            (0..16).collect::<Vec<usize>>(),
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |state, i| {
                *state += 1;
                i
            },
        );
        assert_eq!(out.len(), 16);
        let n = inits.load(Ordering::Relaxed);
        assert!((1..=4).contains(&n), "{n} inits for 4 workers");
    }

    #[test]
    fn degenerate_shapes() {
        assert!(parallel_map(Vec::<u8>::new(), 4, |v| v).is_empty());
        assert_eq!(parallel_map(vec![7], 16, |v| v + 1), vec![8]);
        assert_eq!(parallel_map(vec![1, 2], 0, |v| v), vec![1, 2]);
    }

    #[test]
    fn every_item_processed_exactly_once() {
        let hits = AtomicUsize::new(0);
        let out = parallel_map((0..100).collect::<Vec<usize>>(), 7, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }
}
