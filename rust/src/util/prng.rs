//! Deterministic, stateless cross-language PRNG (SplitMix64-indexed).
//!
//! Bit-exact mirror of `python/compile/prng.py`. Value `i` of stream
//! `seed` is `splitmix64(seed + (i+1) * GOLDEN)`. The synthetic scene
//! renderer on both sides draws from these streams, which is what makes
//! the python-trained classifiers see the same pixel distribution the
//! rust data generator produces (and lets `tests/golden_scenes.rs`
//! assert bit-identical crops).

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
const M1: u64 = 0xBF58_476D_1CE4_E5B9;
const M2: u64 = 0x94D0_49BB_1331_11EB;

/// SplitMix64 finalizer (wrapping).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x;
    z ^= z >> 30;
    z = z.wrapping_mul(M1);
    z ^= z >> 27;
    z = z.wrapping_mul(M2);
    z ^= z >> 31;
    z
}

/// Raw 64-bit value `i` of stream `seed`.
#[inline]
pub fn u64_at(seed: u64, i: u64) -> u64 {
    splitmix64(seed.wrapping_add((i.wrapping_add(1)).wrapping_mul(GOLDEN)))
}

/// Top 32 bits — matches python `u32_at`.
#[inline]
pub fn u32_at(seed: u64, i: u64) -> u32 {
    (u64_at(seed, i) >> 32) as u32
}

/// Uniform `[0, 1)` f32 from the top 24 bits — matches python `f32_at`.
#[inline]
pub fn f32_at(seed: u64, i: u64) -> f32 {
    (u32_at(seed, i) >> 8) as f32 * (1.0 / (1 << 24) as f32)
}

/// Integer in `[lo, hi)` — matches python `range_at` (modulo reduction).
#[inline]
pub fn range_at(seed: u64, i: u64, lo: i64, hi: i64) -> i64 {
    debug_assert!(hi > lo);
    lo + (u32_at(seed, i) as u64 % (hi - lo) as u64) as i64
}

/// A cheap stateful convenience wrapper over a stream (sequential reads).
#[derive(Debug, Clone)]
pub struct Stream {
    seed: u64,
    next: u64,
}

impl Stream {
    pub fn new(seed: u64) -> Self {
        Stream { seed, next: 0 }
    }

    pub fn next_u32(&mut self) -> u32 {
        let v = u32_at(self.seed, self.next);
        self.next += 1;
        v
    }

    pub fn next_f32(&mut self) -> f32 {
        let v = f32_at(self.seed, self.next);
        self.next += 1;
        v
    }

    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        let v = range_at(self.seed, self.next, lo, hi);
        self.next += 1;
        v
    }

    /// Exponentially-distributed sample with the given mean (for
    /// workload inter-arrival jitter in the DES).
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        let u = (self.next_f32() as f64).max(1e-9);
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_stable() {
        // Frozen reference values — if these change, the python renderer
        // and the rust renderer have diverged and every golden breaks.
        assert_eq!(u64_at(0, 0), splitmix64(GOLDEN));
        let v: Vec<u32> = (0..4).map(|i| u32_at(42, i)).collect();
        let again: Vec<u32> = (0..4).map(|i| u32_at(42, i)).collect();
        assert_eq!(v, again);
        // stateless == stateful
        let mut s = Stream::new(42);
        for i in 0..4 {
            assert_eq!(s.next_u32(), v[i as usize]);
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        for i in 0..1000 {
            let f = f32_at(7, i);
            assert!((0.0..1.0).contains(&f), "{f}");
        }
    }

    #[test]
    fn range_bounds() {
        for i in 0..1000 {
            let r = range_at(9, i, -3, 4);
            assert!((-3..4).contains(&r));
        }
    }

    #[test]
    fn streams_are_independent() {
        let a: Vec<u32> = (0..8).map(|i| u32_at(1, i)).collect();
        let b: Vec<u32> = (0..8).map(|i| u32_at(2, i)).collect();
        assert_ne!(a, b);
    }
}
