//! Shared utilities: deterministic PRNG, simulated/real time, stats.

pub mod prng;
pub mod stats;

use std::fmt;

/// Simulated time in microseconds (the DES clock unit).
pub type SimTime = u64;

pub const MICROS_PER_SEC: u64 = 1_000_000;

/// Convert seconds (f64) to simulated microseconds.
pub fn secs(s: f64) -> SimTime {
    (s * MICROS_PER_SEC as f64).round() as SimTime
}

/// Convert milliseconds (f64) to simulated microseconds.
pub fn millis(ms: f64) -> SimTime {
    secs(ms / 1e3)
}

/// Simulated microseconds back to seconds.
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / MICROS_PER_SEC as f64
}

/// Simulated microseconds to milliseconds.
pub fn to_millis(t: SimTime) -> f64 {
    t as f64 / 1e3
}

/// Hierarchical ACE entity id (§4.3.1): infrastructure -> EC/CC -> node.
///
/// Rendered as e.g. `infra-7/ec-1/rpi-2`. The three-level scheme is the
/// paper's id assignment: ACE assigns a unique infrastructure id, a
/// second-layer id per EC/CC, and a third-layer id per node.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AceId {
    parts: Vec<String>,
}

impl AceId {
    pub fn root(infra: impl Into<String>) -> Self {
        AceId { parts: vec![infra.into()] }
    }

    pub fn child(&self, part: impl Into<String>) -> Self {
        let mut parts = self.parts.clone();
        parts.push(part.into());
        AceId { parts }
    }

    pub fn depth(&self) -> usize {
        self.parts.len()
    }

    pub fn parent(&self) -> Option<AceId> {
        if self.parts.len() <= 1 {
            None
        } else {
            Some(AceId { parts: self.parts[..self.parts.len() - 1].to_vec() })
        }
    }

    pub fn leaf(&self) -> &str {
        self.parts.last().map(|s| s.as_str()).unwrap_or("")
    }

    pub fn is_ancestor_of(&self, other: &AceId) -> bool {
        other.parts.len() > self.parts.len()
            && other.parts[..self.parts.len()] == self.parts[..]
    }

    pub fn parse(s: &str) -> Self {
        AceId { parts: s.split('/').map(|p| p.to_string()).collect() }
    }
}

impl fmt::Display for AceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.parts.join("/"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(secs(1.0), MICROS_PER_SEC);
        assert_eq!(millis(50.0), 50_000);
        assert!((to_secs(secs(3.25)) - 3.25).abs() < 1e-9);
        assert!((to_millis(millis(12.5)) - 12.5).abs() < 1e-9);
    }

    #[test]
    fn ace_id_hierarchy() {
        let infra = AceId::root("infra-1");
        let ec = infra.child("ec-1");
        let node = ec.child("rpi-2");
        assert_eq!(node.to_string(), "infra-1/ec-1/rpi-2");
        assert_eq!(node.depth(), 3);
        assert!(infra.is_ancestor_of(&node));
        assert!(ec.is_ancestor_of(&node));
        assert!(!node.is_ancestor_of(&ec));
        assert_eq!(node.parent().unwrap(), ec);
        assert_eq!(AceId::parse("infra-1/ec-1/rpi-2"), node);
        assert_eq!(node.leaf(), "rpi-2");
    }
}
