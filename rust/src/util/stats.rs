//! Small statistics helpers used by metrics and the bench harnesses.

/// Online mean/min/max/count accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Reservoir-free percentile helper: stores all samples (fine at the
/// scales the experiments run at) and answers arbitrary quantiles.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { samples: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sort the sample buffer in place (idempotent). Call once after
    /// the last `add`; every later `quantile` is then an O(1) index.
    pub fn sort_samples(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// q in [0, 1]; nearest-rank on the sorted samples. Readers that
    /// called `sort_samples` first hit the indexed fast path; on an
    /// unsorted buffer this selects on a scratch copy instead (correct
    /// but O(n) per call), so shared `&` access never observes a
    /// half-sorted buffer.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let idx = ((self.samples.len() as f64 - 1.0) * q).round() as usize;
        let idx = idx.min(self.samples.len() - 1);
        if self.sorted {
            self.samples[idx]
        } else {
            let mut scratch = self.samples.clone();
            let (_, v, _) =
                scratch.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
            *v
        }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_extrema() {
        let mut s = Summary::new();
        for v in [3.0, 1.0, 2.0] {
            s.add(v);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_monotone() {
        let mut p = Percentiles::new();
        for i in 0..100 {
            p.add(i as f64);
        }
        assert_eq!(p.quantile(0.0), 0.0);
        assert_eq!(p.quantile(1.0), 99.0);
        let p50 = p.quantile(0.5);
        let p99 = p.quantile(0.99);
        assert!(p50 <= p99);
        assert!((p.mean() - 49.5).abs() < 1e-9);
    }

    #[test]
    fn sorted_fast_path_matches_unsorted_selection() {
        let mut p = Percentiles::new();
        for v in [5.0, 1.0, 9.0, 3.0, 7.0, 2.0] {
            p.add(v);
        }
        let qs = [0.0, 0.25, 0.5, 0.75, 0.99, 1.0];
        let cold: Vec<f64> = qs.iter().map(|&q| p.quantile(q)).collect();
        p.sort_samples();
        let hot: Vec<f64> = qs.iter().map(|&q| p.quantile(q)).collect();
        assert_eq!(cold, hot);
    }

    #[test]
    fn empty_is_zero() {
        let p = Percentiles::new();
        assert_eq!(p.quantile(0.5), 0.0);
        assert_eq!(p.mean(), 0.0);
        assert!(p.is_empty());
    }
}
