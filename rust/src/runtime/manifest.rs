//! Typed view over `artifacts/manifest.json` (written by aot.py).

use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub files: Vec<String>,
    pub batch_sizes: Vec<usize>,
    pub outputs: usize,
    pub params: usize,
    /// COC: top-1 accuracy; EOC: 1 - binary_error (as reported by aot).
    pub accuracy: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub crop: usize,
    pub classes: Vec<String>,
    pub target_class: usize,
    pub frame_h: usize,
    pub frame_w: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub framediff_file: String,
    pub fl_file: String,
    pub fl_dim: usize,
    pub fl_batch: usize,
    pub quick: bool,
}

impl Manifest {
    pub fn parse(v: &Value) -> Result<Self> {
        let mut models = BTreeMap::new();
        let mobj = v
            .get("models")
            .as_obj()
            .context("manifest: missing models")?;
        for (name, m) in mobj {
            let acc = if name == "eoc" {
                1.0 - m.get("binary_error").as_f64().unwrap_or(0.0)
            } else {
                m.get("top1").as_f64().unwrap_or(0.0)
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    files: m
                        .get("files")
                        .as_arr()
                        .context("files")?
                        .iter()
                        .filter_map(|f| f.as_str().map(|s| s.to_string()))
                        .collect(),
                    batch_sizes: m
                        .get("batch_sizes")
                        .as_arr()
                        .context("batch_sizes")?
                        .iter()
                        .filter_map(|b| b.as_usize())
                        .collect(),
                    outputs: m.get("outputs").as_usize().context("outputs")?,
                    params: m.get("params").as_usize().unwrap_or(0),
                    accuracy: acc,
                },
            );
        }
        Ok(Manifest {
            crop: v.get("crop").as_usize().context("crop")?,
            classes: v
                .get("classes")
                .as_arr()
                .context("classes")?
                .iter()
                .filter_map(|c| c.as_str().map(|s| s.to_string()))
                .collect(),
            target_class: v.get("target_class").as_usize().context("target_class")?,
            frame_h: v.get("frame").get("h").as_usize().context("frame.h")?,
            frame_w: v.get("frame").get("w").as_usize().context("frame.w")?,
            models,
            framediff_file: v
                .get("framediff")
                .get("file")
                .as_str()
                .unwrap_or("framediff.hlo.txt")
                .to_string(),
            fl_file: v
                .get("fl")
                .get("file")
                .as_str()
                .unwrap_or("fl_train_step.hlo.txt")
                .to_string(),
            fl_dim: v.get("fl").get("dim").as_usize().unwrap_or(16),
            fl_batch: v.get("fl").get("batch").as_usize().unwrap_or(32),
            quick: v.get("quick").as_bool().unwrap_or(false),
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::parse(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "crop": 32,
      "classes": ["background", "motorcycle"],
      "target_class": 1,
      "frame": {"h": 96, "w": 160},
      "models": {
        "eoc": {"files": ["eoc_b1.hlo.txt"], "batch_sizes": [1, 4],
                 "outputs": 2, "params": 2202, "binary_error": 0.11},
        "coc": {"files": ["coc_b1.hlo.txt"], "batch_sizes": [1],
                 "outputs": 8, "params": 272000, "top1": 0.95}
      },
      "framediff": {"file": "framediff.hlo.txt", "h": 96, "w": 160},
      "fl": {"file": "fl_train_step.hlo.txt", "dim": 16, "classes": 2, "batch": 32},
      "quick": false
    }"#;

    #[test]
    fn parses_sample() {
        let v = crate::json::parse(SAMPLE).unwrap();
        let m = Manifest::parse(&v).unwrap();
        assert_eq!(m.crop, 32);
        assert_eq!(m.target_class, 1);
        assert_eq!(m.frame_w, 160);
        assert_eq!(m.models["eoc"].batch_sizes, vec![1, 4]);
        assert!((m.models["eoc"].accuracy - 0.89).abs() < 1e-9);
        assert!((m.models["coc"].accuracy - 0.95).abs() < 1e-9);
        assert_eq!(m.models["coc"].outputs, 8);
    }

    #[test]
    fn missing_fields_error() {
        let v = crate::json::parse(r#"{"crop": 32}"#).unwrap();
        assert!(Manifest::parse(&v).is_err());
    }
}
