//! Real PJRT/XLA execution backend (feature `pjrt`).
//!
//! Requires the vendored `xla` crate (see Cargo.toml). This is the
//! original runtime implementation: parse HLO text, compile one
//! executable per artifact on the PJRT CPU client, execute batched
//! inference.

use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

pub type Literal = xla::Literal;

/// Shared PJRT client (CPU).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable { exe, path: path.to_path_buf() })
    }
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with the given inputs; outputs are the flattened tuple
    /// elements (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("execute {:?}: {e:?}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {:?}: {e:?}", self.path))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

/// f32 tensor input helper.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {dims:?} != data len {}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {dims:?} != data len {}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}
