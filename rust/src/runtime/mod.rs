//! PJRT runtime: load AOT artifacts, execute them on the request path.
//!
//! This is the rust half of the AOT bridge: `python/compile/aot.py`
//! lowers the L2 JAX graphs (which call the L1 Pallas kernels) to HLO
//! *text*; this module parses the text, compiles one executable per
//! (model, batch-size) on the PJRT CPU client, caches them, and serves
//! batched inference. Python never runs here.
//!
//! Also provides `calibrate`, which measures real per-batch service
//! times — the DES (Figure 5 experiments) charges these measured times
//! (scaled by a node speed factor) as virtual service times, so the
//! latency curves are grounded in actual XLA execution cost.

pub mod manifest;

use crate::util::stats::Summary;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

pub use manifest::Manifest;

/// Shared PJRT client (CPU).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable { exe, path: path.to_path_buf() })
    }
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with the given inputs; outputs are the flattened tuple
    /// elements (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {:?}: {e:?}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {:?}: {e:?}", self.path))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

/// f32 tensor input helper.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {dims:?} != data len {}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {dims:?} != data len {}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// A classifier with one compiled executable per exported batch size
/// (the paper's EOC or COC).
pub struct Classifier {
    pub name: String,
    pub crop: usize,
    pub outputs: usize,
    /// sorted ascending
    pub batch_sizes: Vec<usize>,
    exes: HashMap<usize, Executable>,
    /// measured mean service seconds per batch size (after calibrate)
    pub service_secs: HashMap<usize, f64>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Classifier {
    /// Load `<name>_b{B}.hlo.txt` for every batch size in the manifest.
    pub fn load(engine: &Engine, dir: &Path, manifest: &Manifest, name: &str) -> Result<Self> {
        let m = manifest
            .models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))?;
        let mut exes = HashMap::new();
        for &b in &m.batch_sizes {
            let path = dir.join(format!("{name}_b{b}.hlo.txt"));
            exes.insert(b, engine.load(&path)?);
        }
        let mut batch_sizes = m.batch_sizes.clone();
        batch_sizes.sort_unstable();
        Ok(Classifier {
            name: name.to_string(),
            crop: manifest.crop,
            outputs: m.outputs,
            batch_sizes,
            exes,
            service_secs: HashMap::new(),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Largest exported batch size <= n (or the smallest exported).
    pub fn pick_batch(&self, n: usize) -> usize {
        let mut best = self.batch_sizes[0];
        for &b in &self.batch_sizes {
            if b <= n {
                best = b;
            }
        }
        best
    }

    /// Classify `crops` (each crop*crop*3 f32s). Splits into exported
    /// batch sizes, padding the tail batch by repeating its last real
    /// crop (padded outputs are discarded). Returns one probability
    /// vector per crop.
    pub fn classify(&self, crops: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let pix = self.crop * self.crop * 3;
        let mut out = Vec::with_capacity(crops.len());
        let mut i = 0;
        while i < crops.len() {
            let remaining = crops.len() - i;
            let b = self.pick_batch(remaining);
            let take = b.min(remaining);
            let mut flat = Vec::with_capacity(b * pix);
            for j in 0..b {
                let c = &crops[i + j.min(take - 1)];
                if c.len() != pix {
                    bail!("crop {} has {} floats, want {pix}", i + j, c.len());
                }
                flat.extend_from_slice(c);
            }
            let lit = literal_f32(&flat, &[b as i64, self.crop as i64, self.crop as i64, 3])?;
            let exe = self.exes.get(&b).unwrap();
            let probs = exe.run(std::slice::from_ref(&lit))?;
            self.exec_count.set(self.exec_count.get() + 1);
            let v = probs[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output: {e:?}"))?;
            for j in 0..take {
                out.push(v[j * self.outputs..(j + 1) * self.outputs].to_vec());
            }
            i += take;
        }
        Ok(out)
    }

    /// Measure mean wall-clock service time per batch size.
    pub fn calibrate(&mut self, reps: usize) -> Result<()> {
        let pix = self.crop * self.crop * 3;
        let sizes = self.batch_sizes.clone();
        for b in sizes {
            let crop = vec![0.5f32; pix];
            let flat: Vec<f32> = (0..b).flat_map(|_| crop.iter().copied()).collect();
            let lit = literal_f32(&flat, &[b as i64, self.crop as i64, self.crop as i64, 3])?;
            let exe = self.exes.get(&b).unwrap();
            exe.run(std::slice::from_ref(&lit))?; // warmup
            let mut s = Summary::new();
            for _ in 0..reps {
                let t0 = Instant::now();
                exe.run(std::slice::from_ref(&lit))?;
                s.add(t0.elapsed().as_secs_f64());
            }
            self.service_secs.insert(b, s.mean());
        }
        Ok(())
    }

    /// Calibrated mean service seconds for batch size `b`.
    pub fn service_time(&self, b: usize) -> f64 {
        *self
            .service_secs
            .get(&b)
            .unwrap_or_else(|| panic!("batch {b} not calibrated for {}", self.name))
    }
}

/// Everything the coordinator loads from `artifacts/`.
pub struct ModelBank {
    pub manifest: Manifest,
    pub eoc: Classifier,
    pub coc: Classifier,
    pub dir: PathBuf,
}

impl ModelBank {
    pub fn load(engine: &Engine, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let eoc = Classifier::load(engine, dir, &manifest, "eoc")?;
        let coc = Classifier::load(engine, dir, &manifest, "coc")?;
        Ok(ModelBank { manifest, eoc, coc, dir: dir.to_path_buf() })
    }

    pub fn calibrate(&mut self, reps: usize) -> Result<()> {
        self.eoc.calibrate(reps)?;
        self.coc.calibrate(reps)?;
        Ok(())
    }
}

/// Locate the artifacts directory: `$ACE_ARTIFACTS` or an `artifacts/`
/// dir found walking up from cwd (so tests work from any subdir).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("ACE_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!("artifacts/ not found; run `make artifacts` or set ACE_ARTIFACTS");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[3]).is_ok());
        assert!(literal_i32(&[1, 2], &[2, 2]).is_err());
    }

    // Full artifact round-trip tests live in rust/tests/runtime_golden.rs
    // (they require `make artifacts` to have run).
}
