//! PJRT runtime: load AOT artifacts, execute them on the request path.
//!
//! This is the rust half of the AOT bridge: `python/compile/aot.py`
//! lowers the L2 JAX graphs (which call the L1 Pallas kernels) to HLO
//! *text*; this module parses the text, compiles one executable per
//! (model, batch-size) on the PJRT CPU client, caches them, and serves
//! batched inference. Python never runs here.
//!
//! The execution backend is feature-gated: `pjrt` selects the real
//! XLA-backed `backend_pjrt` (requires the vendored `xla` crate, see
//! Cargo.toml); the default offline build compiles `backend_stub`,
//! which keeps the whole API surface (so the platform, DES, and the
//! svcgraph apps build and run with synthetic compute) but reports the
//! backend as unavailable if real inference is requested.
//!
//! Also provides `calibrate`, which measures real per-batch service
//! times — the DES (Figure 5 experiments) charges these measured times
//! (scaled by a node speed factor) as virtual service times, so the
//! latency curves are grounded in actual XLA execution cost.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod backend_pjrt;
#[cfg(feature = "pjrt")]
pub use backend_pjrt::{literal_f32, literal_i32, Engine, Executable, Literal};

#[cfg(not(feature = "pjrt"))]
mod backend_stub;
#[cfg(not(feature = "pjrt"))]
pub use backend_stub::{literal_f32, literal_i32, Element, Engine, Executable, Literal};

use crate::util::stats::Summary;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

pub use manifest::Manifest;

/// A classifier with one compiled executable per exported batch size
/// (the paper's EOC or COC).
pub struct Classifier {
    pub name: String,
    pub crop: usize,
    pub outputs: usize,
    /// sorted ascending
    pub batch_sizes: Vec<usize>,
    exes: HashMap<usize, Executable>,
    /// measured mean service seconds per batch size (after calibrate)
    pub service_secs: HashMap<usize, f64>,
    /// Atomic so a `ModelBank` behind an `Arc` can serve concurrent
    /// sweep workers (`sweep::parallel_map`) through `&self`.
    pub exec_count: std::sync::atomic::AtomicU64,
}

impl Classifier {
    /// Load `<name>_b{B}.hlo.txt` for every batch size in the manifest.
    pub fn load(engine: &Engine, dir: &Path, manifest: &Manifest, name: &str) -> Result<Self> {
        let m = manifest
            .models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))?;
        let mut exes = HashMap::new();
        for &b in &m.batch_sizes {
            let path = dir.join(format!("{name}_b{b}.hlo.txt"));
            exes.insert(b, engine.load(&path)?);
        }
        let mut batch_sizes = m.batch_sizes.clone();
        batch_sizes.sort_unstable();
        Ok(Classifier {
            name: name.to_string(),
            crop: manifest.crop,
            outputs: m.outputs,
            batch_sizes,
            exes,
            service_secs: HashMap::new(),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Largest exported batch size <= n (or the smallest exported).
    pub fn pick_batch(&self, n: usize) -> usize {
        let mut best = self.batch_sizes[0];
        for &b in &self.batch_sizes {
            if b <= n {
                best = b;
            }
        }
        best
    }

    /// Classify `crops` (each crop*crop*3 f32s). Splits into exported
    /// batch sizes, padding the tail batch by repeating its last real
    /// crop (padded outputs are discarded). Returns one probability
    /// vector per crop.
    pub fn classify(&self, crops: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let pix = self.crop * self.crop * 3;
        let mut out = Vec::with_capacity(crops.len());
        let mut i = 0;
        while i < crops.len() {
            let remaining = crops.len() - i;
            let b = self.pick_batch(remaining);
            let take = b.min(remaining);
            let mut flat = Vec::with_capacity(b * pix);
            for j in 0..b {
                let c = &crops[i + j.min(take - 1)];
                if c.len() != pix {
                    bail!("crop {} has {} floats, want {pix}", i + j, c.len());
                }
                flat.extend_from_slice(c);
            }
            let lit = literal_f32(&flat, &[b as i64, self.crop as i64, self.crop as i64, 3])?;
            let exe = self.exes.get(&b).unwrap();
            let probs = exe.run(std::slice::from_ref(&lit))?;
            self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let v = probs[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output: {e:?}"))?;
            for j in 0..take {
                out.push(v[j * self.outputs..(j + 1) * self.outputs].to_vec());
            }
            i += take;
        }
        Ok(out)
    }

    /// Measure mean wall-clock service time per batch size.
    pub fn calibrate(&mut self, reps: usize) -> Result<()> {
        let pix = self.crop * self.crop * 3;
        let sizes = self.batch_sizes.clone();
        for b in sizes {
            let crop = vec![0.5f32; pix];
            let flat: Vec<f32> = (0..b).flat_map(|_| crop.iter().copied()).collect();
            let lit = literal_f32(&flat, &[b as i64, self.crop as i64, self.crop as i64, 3])?;
            let exe = self.exes.get(&b).unwrap();
            exe.run(std::slice::from_ref(&lit))?; // warmup
            let mut s = Summary::new();
            for _ in 0..reps {
                let t0 = Instant::now();
                exe.run(std::slice::from_ref(&lit))?;
                s.add(t0.elapsed().as_secs_f64());
            }
            self.service_secs.insert(b, s.mean());
        }
        Ok(())
    }

    /// Calibrated mean service seconds for batch size `b`.
    pub fn service_time(&self, b: usize) -> f64 {
        *self
            .service_secs
            .get(&b)
            .unwrap_or_else(|| panic!("batch {b} not calibrated for {}", self.name))
    }
}

/// Everything the coordinator loads from `artifacts/`.
pub struct ModelBank {
    pub manifest: Manifest,
    pub eoc: Classifier,
    pub coc: Classifier,
    pub dir: PathBuf,
}

impl ModelBank {
    pub fn load(engine: &Engine, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let eoc = Classifier::load(engine, dir, &manifest, "eoc")?;
        let coc = Classifier::load(engine, dir, &manifest, "coc")?;
        Ok(ModelBank { manifest, eoc, coc, dir: dir.to_path_buf() })
    }

    pub fn calibrate(&mut self, reps: usize) -> Result<()> {
        self.eoc.calibrate(reps)?;
        self.coc.calibrate(reps)?;
        Ok(())
    }
}

/// Locate the artifacts directory: `$ACE_ARTIFACTS` or an `artifacts/`
/// dir found walking up from cwd (so tests work from any subdir).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("ACE_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!("artifacts/ not found; run `make artifacts` or set ACE_ARTIFACTS");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[3]).is_ok());
        assert!(literal_i32(&[1, 2], &[2, 2]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let err = Engine::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_literal_roundtrips_values() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert_eq!(lit.dims(), &[2, 2]);
        // scalars: empty dims == one element
        assert!(literal_f32(&[0.5], &[]).is_ok());
    }

    // Full artifact round-trip tests live in rust/tests/runtime_golden.rs
    // (they require `make artifacts` and the `pjrt` feature).
}
