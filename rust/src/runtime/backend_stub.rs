//! Offline stand-in for the PJRT/XLA execution backend.
//!
//! The build image ships no vendored `xla` crate, so the default build
//! compiles this stub: the full `runtime` API surface exists (types,
//! signatures, shape validation), but `Engine::cpu()` reports that the
//! backend is unavailable instead of constructing a PJRT client.
//! `Engine` is uninhabited and is the only producer of `Executable`s,
//! so every execution path is statically unreachable — simulated
//! workloads (`svcrun`, `Compute::Synthetic`) never get here. Enable
//! the `pjrt` feature (plus the vendored `xla` dependency declared in
//! Cargo.toml) for real execution.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// Uninhabited engine: construction always fails in stub builds.
pub enum Engine {}

impl Engine {
    pub fn cpu() -> Result<Self> {
        bail!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (requires a vendored `xla` crate); simulated workloads \
             (`ace svcrun`, synthetic compute) do not need it"
        )
    }

    pub fn platform(&self) -> String {
        match *self {}
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, _path: &Path) -> Result<Executable> {
        match *self {}
    }
}

/// One compiled computation. Only an `Engine` can produce one, so in
/// stub builds this type is uninhabited too.
pub struct Executable {
    never: std::convert::Infallible,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with the given inputs; outputs are the flattened tuple
    /// elements (aot.py lowers with return_tuple=True).
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        match self.never {}
    }
}

/// Host-side literal: data + dims, so experiment code can build inputs
/// (and tests can validate shapes) without a PJRT client.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LitData,
    dims: Vec<i64>,
}

#[derive(Debug, Clone, PartialEq)]
enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Literal {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Mirror of `xla::Literal::to_vec` for the element types ACE uses.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }
}

/// Element types extractable from a stub `Literal`.
pub trait Element: Sized {
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LitData::F32(v) => Ok(v.clone()),
            LitData::I32(_) => bail!("literal holds i32, asked for f32"),
        }
    }
}

impl Element for i32 {
    fn from_literal(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LitData::I32(v) => Ok(v.clone()),
            LitData::F32(_) => bail!("literal holds f32, asked for i32"),
        }
    }
}

fn check_shape(len: usize, dims: &[i64]) -> Result<()> {
    let n: i64 = dims.iter().product();
    if n as usize != len {
        bail!("literal shape {dims:?} != data len {len}");
    }
    Ok(())
}

/// f32 tensor input helper.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    check_shape(data.len(), dims)?;
    Ok(Literal { data: LitData::F32(data.to_vec()), dims: dims.to_vec() })
}

pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    check_shape(data.len(), dims)?;
    Ok(Literal { data: LitData::I32(data.to_vec()), dims: dims.to_vec() })
}
