//! Discrete-event simulation engine (virtual time).
//!
//! DESIGN.md §Substitutions: the paper's 13-node physical testbed with
//! `tc`-shaped WAN links is replaced by a DES so the Figure 5 sweeps are
//! fast and deterministic. The engine is generic over a `World` type —
//! the experiment owns its state, the scheduler owns virtual time and
//! the event heap. Events are boxed `FnOnce(&mut Scheduler<W>, &mut W)`
//! so handlers can schedule follow-up events.
//!
//! Determinism: ties are broken by insertion sequence number, so a given
//! seed always produces the same trajectory (asserted by property tests).

use crate::util::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub type EventFn<W> = Box<dyn FnOnce(&mut Scheduler<W>, &mut W)>;

struct Entry<W> {
    at: SimTime,
    seq: u64,
    ev: EventFn<W>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<W> Eq for Entry<W> {}

impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Virtual-time event scheduler.
pub struct Scheduler<W> {
    heap: BinaryHeap<Entry<W>>,
    now: SimTime,
    seq: u64,
    executed: u64,
}

impl<W> Default for Scheduler<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Scheduler<W> {
    pub fn new() -> Self {
        Scheduler { heap: BinaryHeap::new(), now: 0, seq: 0, executed: 0 }
    }

    /// Current virtual time (microseconds).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Pending events.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `at` (clamped to now).
    pub fn at(&mut self, at: SimTime, ev: impl FnOnce(&mut Scheduler<W>, &mut W) + 'static) {
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Entry { at, seq: self.seq, ev: Box::new(ev) });
    }

    /// Schedule `ev` after a relative delay.
    pub fn after(&mut self, delay: SimTime, ev: impl FnOnce(&mut Scheduler<W>, &mut W) + 'static) {
        self.at(self.now + delay, ev);
    }

    /// Run until the heap empties or virtual time would exceed `until`,
    /// then advance the clock to the horizon (never backwards).
    /// Returns the number of events executed by this call.
    pub fn run_until(&mut self, world: &mut W, until: SimTime) -> u64 {
        let start = self.executed;
        while let Some(top) = self.heap.peek() {
            if top.at > until {
                break;
            }
            let entry = self.heap.pop().unwrap();
            debug_assert!(entry.at >= self.now, "time went backwards");
            self.now = entry.at;
            self.executed += 1;
            (entry.ev)(self, world);
        }
        self.now = self.now.max(until);
        self.executed - start
    }

    /// Run to exhaustion (with an event-count safety valve).
    pub fn run(&mut self, world: &mut W, max_events: u64) -> u64 {
        let start = self.executed;
        while let Some(entry) = self.heap.pop() {
            debug_assert!(entry.at >= self.now);
            self.now = entry.at;
            self.executed += 1;
            (entry.ev)(self, world);
            if self.executed - start >= max_events {
                break;
            }
        }
        self.executed - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_in_time_order() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(30, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(20, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run(&mut w, 1000);
        assert_eq!(w, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut s: Scheduler<Vec<u32>> = Scheduler::new();
        let mut w = Vec::new();
        for i in 0..10u32 {
            s.at(5, move |_, w: &mut Vec<u32>| w.push(i));
        }
        s.run(&mut w, 1000);
        assert_eq!(w, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(1, |sc, _w: &mut Vec<u64>| {
            sc.after(4, |sc2, w2: &mut Vec<u64>| w2.push(sc2.now()));
        });
        s.run(&mut w, 1000);
        assert_eq!(w, vec![5]);
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(100, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        let n = s.run_until(&mut w, 50);
        assert_eq!(n, 1);
        assert_eq!(w, vec![10]);
        assert_eq!(s.pending(), 1);
        s.run(&mut w, 10);
        assert_eq!(w, vec![10, 100]);
    }

    #[test]
    fn run_until_advances_now_to_horizon() {
        // regression: after draining every event at or before `until`,
        // the clock must sit exactly AT the horizon, so back-to-back
        // run_until windows tile virtual time without gaps
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run_until(&mut w, 50);
        assert_eq!(s.now(), 50);
        // an empty window still advances the clock
        s.run_until(&mut w, 75);
        assert_eq!(s.now(), 75);
        // a horizon in the past never moves the clock backwards
        s.run_until(&mut w, 10);
        assert_eq!(s.now(), 75);
        // and events scheduled "now" relative to the advanced clock run
        // at the advanced time
        s.after(5, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run(&mut w, 10);
        assert_eq!(w, vec![10, 80]);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(50, |sc, _w: &mut Vec<u64>| {
            // scheduling "in the past" clamps to now instead of panicking
            sc.at(1, |sc2, w2: &mut Vec<u64>| w2.push(sc2.now()));
        });
        s.run(&mut w, 100);
        assert_eq!(w, vec![50]);
    }

    #[test]
    fn max_events_safety_valve() {
        // self-perpetuating event chain must stop at the valve
        fn tick(sc: &mut Scheduler<u64>, w: &mut u64) {
            *w += 1;
            sc.after(1, tick);
        }
        let mut s: Scheduler<u64> = Scheduler::new();
        let mut w = 0u64;
        s.after(1, tick);
        let n = s.run(&mut w, 500);
        assert_eq!(n, 500);
        assert_eq!(w, 500);
    }
}
