//! Discrete-event simulation engine (virtual time).
//!
//! DESIGN.md §Substitutions: the paper's 13-node physical testbed with
//! `tc`-shaped WAN links is replaced by a DES so the Figure 5 sweeps are
//! fast and deterministic. The engine is generic over a `World` type —
//! the experiment owns its state, the scheduler owns virtual time and
//! the event queue.
//!
//! Two event lanes (DESIGN.md §Event-engine):
//!
//! * **Typed lane** — `Scheduler<W, E>` where `E: SimEvent<W>` stores
//!   events *by value* in the queue, so scheduling is allocation-free
//!   (`push_at`/`push_after`). This is the hot path: `svcgraph` runs
//!   millions of `Event::{Start, Msg, Timer, Bridge}` per cell through
//!   it without a single per-event heap allocation.
//! * **Boxed closure lane** — the default `E = BoxedEvent<W>` wraps a
//!   `Box<dyn FnOnce>`, trading one allocation per event for ad-hoc
//!   ergonomics (`at`/`after`). Setup-time and rare events (validation
//!   testbed channel phases) ride this lane; a typed-event engine can
//!   embed it as one enum variant (see `svcgraph::Event::Call`).
//!
//! The pending-event store is a [`queue::CalendarQueue`] — a timing
//! wheel sized for the dense-timer regime (heartbeats, deadlines,
//! periodic publishes land O(1) in a day bucket) with an overflow heap
//! for far-future events. The PR-5 global `BinaryHeap` survives as
//! [`queue::HeapQueue`], the reference implementation the wheel is
//! differentially tested against (`tests/properties.rs`) and raced
//! against (`des_timer_storm` in `benchkit`).
//!
//! Determinism: ties are broken by insertion sequence number, and the
//! wheel's `(at, seq)` merge rule reproduces the global heap's pop
//! order exactly (see `queue`'s module docs for the argument), so a
//! given seed always produces the same trajectory regardless of lane
//! or queue (asserted by the typed-vs-boxed and heap-vs-wheel
//! differentials in `tests/properties.rs`).

pub mod par;
pub mod queue;

use crate::util::SimTime;
use queue::{CalendarQueue, EventQueue};
use std::marker::PhantomData;

/// A value-typed simulation event: `fire` consumes the event and may
/// schedule follow-ups through the scheduler it ran on.
pub trait SimEvent<W>: Sized {
    fn fire(self, sch: &mut Scheduler<W, Self>, world: &mut W);
}

/// The boxed-closure event payload (the default lane).
pub type EventFn<W> = Box<dyn FnOnce(&mut Scheduler<W>, &mut W)>;

/// Adapter making a boxed closure a [`SimEvent`]; the default event
/// type, so `Scheduler<W>` keeps the original closure-only API.
pub struct BoxedEvent<W>(pub EventFn<W>);

impl<W> SimEvent<W> for BoxedEvent<W> {
    fn fire(self, sch: &mut Scheduler<W>, world: &mut W) {
        (self.0)(sch, world)
    }
}

/// Virtual-time event scheduler, generic over the event type `E`
/// (typed lane). `Scheduler<W>` defaults `E` to [`BoxedEvent`], the
/// closure lane.
///
/// The pending store is one or more **partition lanes**, each its own
/// [`CalendarQueue`] (PR 8). The default is a single lane — exactly the
/// PR-6 engine. A multi-lane scheduler files each push into the lane
/// its caller names ([`Scheduler::push_at_lane`]) and pops the k-way
/// `(at, seq)` minimum across lanes; because `seq` is GLOBAL across
/// lanes, the merged pop order is identical to a single queue holding
/// every event, for ANY lane assignment (pinned by
/// `lane_merge_matches_single_queue`). That is what lets a
/// cluster-partitioned `svcgraph` run replay single-queue goldens
/// byte-for-byte, and it is the substrate `des::par` cuts along when it
/// actually goes wide.
pub struct Scheduler<W, E: SimEvent<W> = BoxedEvent<W>> {
    lanes: Vec<CalendarQueue<E>>,
    now: SimTime,
    seq: u64,
    executed: u64,
    _world: PhantomData<fn(&mut W)>,
}

impl<W, E: SimEvent<W>> Default for Scheduler<W, E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W, E: SimEvent<W>> Scheduler<W, E> {
    pub fn new() -> Self {
        Self::with_lanes(1)
    }

    /// A scheduler with `n` partition lanes (clamped to >= 1). Lane 0
    /// is the default lane [`Scheduler::push_at`] files into.
    pub fn with_lanes(n: usize) -> Self {
        let n = n.max(1);
        Scheduler {
            lanes: (0..n).map(|_| CalendarQueue::new()).collect(),
            now: 0,
            seq: 0,
            executed: 0,
            _world: PhantomData,
        }
    }

    /// Number of partition lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Lane index holding the earliest `(at, seq)` key, or `None` when
    /// every lane is empty. The single-lane fast path skips the scan.
    fn argmin_lane(&mut self) -> Option<usize> {
        if self.lanes.len() == 1 {
            return if self.lanes[0].is_empty() { None } else { Some(0) };
        }
        let mut best: Option<((SimTime, u64), usize)> = None;
        for (i, q) in self.lanes.iter_mut().enumerate() {
            if let Some(key) = q.peek_key() {
                if best.is_none_or(|(bk, _)| key < bk) {
                    best = Some((key, i));
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Current virtual time (microseconds).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Pending events (summed over lanes).
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|q| q.len()).sum()
    }

    /// Earliest pending event time across every lane, without popping.
    /// `des::par` uses this as the partition's local clock floor when
    /// computing the conservative safe window.
    pub fn peek_next(&mut self) -> Option<SimTime> {
        self.lanes.iter_mut().filter_map(|q| q.peek_time()).min()
    }

    /// Pre-size the event queue for at least `additional` more pending
    /// events. Deployment-shaped workloads know their steady-state
    /// in-flight event count up front (a few events per placed
    /// instance), so reserving once at deploy time means the queue never
    /// reallocates mid-run — `tests/zero_alloc.rs` pins this by
    /// asserting the capacity is unchanged across the steady-state
    /// window.
    pub fn reserve_events(&mut self, additional: usize) {
        // an event can be filed into any lane, so each lane is sized
        // for the full reservation (single-lane: identical to PR 6)
        for q in &mut self.lanes {
            q.reserve(additional);
        }
    }

    /// Current event-queue capacity, summed over every lane's wheel
    /// slab and current/overflow heaps (for pre-sizing / no-regrowth
    /// assertions; see [`reserve_events`](Self::reserve_events)).
    pub fn heap_capacity(&self) -> usize {
        self.lanes.iter().map(|q| q.capacity()).sum()
    }

    /// Schedule a typed event at absolute time `at` (clamped to now).
    /// The event is stored by value — no allocation beyond amortized
    /// queue growth. Files into lane 0.
    pub fn push_at(&mut self, at: SimTime, ev: E) {
        self.push_at_lane(0, at, ev);
    }

    /// Schedule a typed event after a relative delay (lane 0).
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        self.push_at_lane(0, self.now + delay, ev);
    }

    /// Schedule a typed event at absolute time `at` (clamped to now)
    /// into partition lane `lane` (clamped into range: a caller keyed
    /// by a cluster index may address fewer lanes than clusters — the
    /// `lane % lane_count` fold is applied here, once).
    pub fn push_at_lane(&mut self, lane: usize, at: SimTime, ev: E) {
        let at = at.max(self.now);
        self.seq += 1;
        let lane = if self.lanes.len() == 1 { 0 } else { lane % self.lanes.len() };
        self.lanes[lane].push(at, self.seq, ev);
    }

    /// Schedule a typed event after a relative delay into lane `lane`.
    pub fn push_after_lane(&mut self, lane: usize, delay: SimTime, ev: E) {
        self.push_at_lane(lane, self.now + delay, ev);
    }

    /// Run until the queue empties or virtual time would exceed `until`,
    /// then advance the clock to the horizon (never backwards).
    /// Returns the number of events executed by this call.
    pub fn run_until(&mut self, world: &mut W, until: SimTime) -> u64 {
        let start = self.executed;
        while let Some(lane) = self.argmin_lane() {
            let top = self.lanes[lane].peek_time().expect("argmin lane is non-empty");
            if top > until {
                break;
            }
            let (at, _seq, ev) = self.lanes[lane].pop().unwrap();
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            self.executed += 1;
            ev.fire(self, world);
        }
        self.now = self.now.max(until);
        self.executed - start
    }

    /// Run to exhaustion (with an event-count safety valve).
    pub fn run(&mut self, world: &mut W, max_events: u64) -> u64 {
        let start = self.executed;
        while let Some(lane) = self.argmin_lane() {
            let (at, _seq, ev) = self.lanes[lane].pop().unwrap();
            debug_assert!(at >= self.now);
            self.now = at;
            self.executed += 1;
            ev.fire(self, world);
            if self.executed - start >= max_events {
                break;
            }
        }
        self.executed - start
    }
}

/// Closure-lane sugar (only on the default `E = BoxedEvent<W>`): each
/// call boxes the closure — fine for setup, wrong for per-message hot
/// paths (use a typed event engine there).
impl<W> Scheduler<W> {
    /// Schedule `ev` at absolute time `at` (clamped to now).
    pub fn at(&mut self, at: SimTime, ev: impl FnOnce(&mut Scheduler<W>, &mut W) + 'static) {
        self.push_at(at, BoxedEvent(Box::new(ev)));
    }

    /// Schedule `ev` after a relative delay.
    pub fn after(&mut self, delay: SimTime, ev: impl FnOnce(&mut Scheduler<W>, &mut W) + 'static) {
        let at = self.now + delay;
        self.at(at, ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_in_time_order() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(30, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(20, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run(&mut w, 1000);
        assert_eq!(w, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut s: Scheduler<Vec<u32>> = Scheduler::new();
        let mut w = Vec::new();
        for i in 0..10u32 {
            s.at(5, move |_, w: &mut Vec<u32>| w.push(i));
        }
        s.run(&mut w, 1000);
        assert_eq!(w, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(1, |sc, _w: &mut Vec<u64>| {
            sc.after(4, |sc2, w2: &mut Vec<u64>| w2.push(sc2.now()));
        });
        s.run(&mut w, 1000);
        assert_eq!(w, vec![5]);
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(100, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        let n = s.run_until(&mut w, 50);
        assert_eq!(n, 1);
        assert_eq!(w, vec![10]);
        assert_eq!(s.pending(), 1);
        s.run(&mut w, 10);
        assert_eq!(w, vec![10, 100]);
    }

    #[test]
    fn run_until_advances_now_to_horizon() {
        // regression: after draining every event at or before `until`,
        // the clock must sit exactly AT the horizon, so back-to-back
        // run_until windows tile virtual time without gaps
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(10, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run_until(&mut w, 50);
        assert_eq!(s.now(), 50);
        // an empty window still advances the clock
        s.run_until(&mut w, 75);
        assert_eq!(s.now(), 75);
        // a horizon in the past never moves the clock backwards
        s.run_until(&mut w, 10);
        assert_eq!(s.now(), 75);
        // and events scheduled "now" relative to the advanced clock run
        // at the advanced time
        s.after(5, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run(&mut w, 10);
        assert_eq!(w, vec![10, 80]);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(50, |sc, _w: &mut Vec<u64>| {
            // scheduling "in the past" clamps to now instead of panicking
            sc.at(1, |sc2, w2: &mut Vec<u64>| w2.push(sc2.now()));
        });
        s.run(&mut w, 100);
        assert_eq!(w, vec![50]);
    }

    #[test]
    fn reserve_events_presizes_the_heap() {
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        s.reserve_events(1000);
        let cap = s.heap_capacity();
        assert!(cap >= 1000);
        let mut w = Vec::new();
        // a workload smaller than the reservation never regrows the queue
        for i in 0..1000u64 {
            s.at(i, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        }
        assert_eq!(s.heap_capacity(), cap, "pre-sized queue must not regrow");
        s.run(&mut w, 2000);
        assert_eq!(w.len(), 1000);
    }

    #[test]
    fn max_events_safety_valve() {
        // self-perpetuating event chain must stop at the valve
        fn tick(sc: &mut Scheduler<u64>, w: &mut u64) {
            *w += 1;
            sc.after(1, tick);
        }
        let mut s: Scheduler<u64> = Scheduler::new();
        let mut w = 0u64;
        s.after(1, tick);
        let n = s.run(&mut w, 500);
        assert_eq!(n, 500);
        assert_eq!(w, 500);
    }

    #[test]
    fn clock_jumps_cleanly_across_the_wheel_horizon() {
        // a lone event far past the wheel's ~4.19 virtual seconds rides
        // the overflow heap and the cursor jump, not a bucket scan
        let far = (queue::NB as u64) << queue::WIDTH_SHIFT;
        let mut s: Scheduler<Vec<u64>> = Scheduler::new();
        let mut w = Vec::new();
        s.at(10 * far + 3, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.at(2, |sc, w: &mut Vec<u64>| w.push(sc.now()));
        s.run(&mut w, 10);
        assert_eq!(w, vec![2, 10 * far + 3]);
    }

    // --- typed lane ---

    /// Minimal typed event: records (now, id) or chains a follow-up.
    enum Ev {
        Emit(u32),
        Chain { delay: SimTime, id: u32, hops: u8 },
    }

    impl SimEvent<Vec<(SimTime, u32)>> for Ev {
        fn fire(self, sc: &mut Scheduler<Vec<(SimTime, u32)>, Ev>, w: &mut Vec<(SimTime, u32)>) {
            match self {
                Ev::Emit(id) => w.push((sc.now(), id)),
                Ev::Chain { delay, id, hops } => {
                    w.push((sc.now(), id));
                    if hops > 0 {
                        sc.push_after(delay, Ev::Chain { delay, id, hops: hops - 1 });
                    }
                }
            }
        }
    }

    #[test]
    fn typed_events_execute_in_time_order() {
        let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::new();
        let mut w = Vec::new();
        s.push_at(30, Ev::Emit(3));
        s.push_at(10, Ev::Emit(1));
        s.push_at(20, Ev::Emit(2));
        s.run(&mut w, 1000);
        assert_eq!(w, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn typed_ties_break_by_push_order() {
        let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::new();
        let mut w = Vec::new();
        for i in 0..10u32 {
            s.push_at(5, Ev::Emit(i));
        }
        s.run(&mut w, 1000);
        assert_eq!(w, (0..10).map(|i| (5, i)).collect::<Vec<_>>());
    }

    #[test]
    fn typed_events_can_chain_and_respect_horizon() {
        let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::new();
        let mut w = Vec::new();
        s.push_at(10, Ev::Chain { delay: 20, id: 7, hops: 5 });
        let n = s.run_until(&mut w, 55);
        assert_eq!(n, 3); // at 10, 30, 50
        assert_eq!(w, vec![(10, 7), (30, 7), (50, 7)]);
        assert_eq!(s.now(), 55);
        s.run(&mut w, 100);
        assert_eq!(w.last(), Some(&(110, 7)));
    }

    #[test]
    fn lane_merge_matches_single_queue() {
        // the SAME push trace filed into 1..=5 partition lanes
        // (round-robin by an arbitrary key) must pop in the identical
        // order: the global seq counter makes the k-way merge exact
        let plan: Vec<(SimTime, u32)> = (0..500u32)
            .map(|i| {
                let at = (i as u64 * 7919) % 50_000; // ties included
                (at - at % 5, i)
            })
            .collect();
        let reference = {
            let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::new();
            let mut w = Vec::new();
            for &(at, id) in &plan {
                s.push_at(at, Ev::Emit(id));
            }
            s.run(&mut w, u64::MAX);
            w
        };
        for lanes in 1..=5usize {
            let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::with_lanes(lanes);
            assert_eq!(s.lane_count(), lanes);
            let mut w = Vec::new();
            for &(at, id) in &plan {
                s.push_at_lane(id as usize % 3, at, Ev::Emit(id));
            }
            assert_eq!(s.pending(), plan.len());
            assert_eq!(s.peek_next(), Some(0));
            s.run(&mut w, u64::MAX);
            assert_eq!(w, reference, "{lanes} lanes diverged from the single queue");
        }
    }

    #[test]
    fn lane_indices_fold_modulo_lane_count() {
        // a caller keyed by cluster index may address more lanes than
        // the scheduler has; the fold happens inside push_at_lane
        let mut s: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::with_lanes(2);
        let mut w = Vec::new();
        s.push_at_lane(7, 10, Ev::Emit(1)); // lane 1
        s.push_at_lane(100, 5, Ev::Emit(2)); // lane 0
        s.push_after_lane(3, 20, Ev::Emit(3)); // lane 1, at 20
        s.run(&mut w, 100);
        assert_eq!(w, vec![(5, 2), (10, 1), (20, 3)]);
    }

    #[test]
    fn typed_and_boxed_lanes_share_trajectory_semantics() {
        // the same workload scheduled on each lane yields the same
        // (time, id) trajectory — the per-lane seq counters assign
        // identical tie-breaks for identical push orders
        let plan: Vec<(SimTime, u32)> = vec![(5, 0), (5, 1), (3, 2), (9, 3), (3, 4)];

        let mut typed: Scheduler<Vec<(SimTime, u32)>, Ev> = Scheduler::new();
        let mut tw = Vec::new();
        for &(at, id) in &plan {
            typed.push_at(at, Ev::Emit(id));
        }
        typed.run(&mut tw, 1000);

        let mut boxed: Scheduler<Vec<(SimTime, u32)>> = Scheduler::new();
        let mut bw = Vec::new();
        for &(at, id) in &plan {
            boxed.at(at, move |sc, w: &mut Vec<(SimTime, u32)>| w.push((sc.now(), id)));
        }
        boxed.run(&mut bw, 1000);

        assert_eq!(tw, bw);
        assert_eq!(typed.executed(), boxed.executed());
    }
}
