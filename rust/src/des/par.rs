//! Conservative parallel DES: lock-stepped safe windows over
//! lookahead-separated partitions (DESIGN.md §Parallel-DES).
//!
//! The classic conservative (Chandy–Misra–Bryant-style) window: cut the
//! simulation into partitions whose ONLY mutual influence is messages
//! that arrive at least `lookahead` after they were caused. Then every
//! partition can safely execute all events strictly before
//!
//! ```text
//! H = min_i( peek_i + lookahead_i )
//! ```
//!
//! without ever seeing a cross-partition message "from the past": a
//! message emitted by partition `j` while executing an event at time
//! `e >= peek_j` arrives no earlier than `e + lookahead_j >= H`. In our
//! topology the partitions are clusters and the lookahead is the WAN
//! bridge delay — bridge hops are the only cross-cluster edges, and
//! `simnet::Link::ser_time` floors every charge at 1 µs, so lookahead
//! is always nonzero and every window makes progress (the driver
//! additionally clamps reported lookaheads to >= 1).
//!
//! Determinism: each partition's trajectory is a pure function of its
//! blueprint, the horizon sequence, and its inbox sequence. Horizons
//! are computed from (peek, lookahead, undelivered-envelope) state that
//! evolves identically whether windows run on one thread or many, and
//! envelopes are merged in the fixed order `(at, src partition, outbox
//! index)` before delivery. So the serial reference driver and the
//! threaded driver are bit-identical by construction — pinned here by
//! the toy-ring test and at system scale by `tests/par_des.rs`.
//!
//! Threading model: partitions are built INSIDE worker threads from
//! `Send` blueprints, so a partition itself (typically an `Rc`-laden
//! `svcgraph` runtime) never crosses a thread boundary. Only envelopes,
//! peeks, digests, and final results — all `Send` — move over channels.

use crate::util::SimTime;
use std::sync::mpsc;

/// FNV-1a offset basis — the starting value for window-digest folds.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a-style mix step folding `x` into `h` (shared by the
/// window-digest folds here and partition `digest` implementations).
pub fn fnv_mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x0000_0100_0000_01b3)
}

/// A cross-partition message: deliver `msg` to partition `dst` at
/// virtual time `at`. The conservative contract requires
/// `at >= H` for the window that emitted it (see module docs).
pub struct Envelope<M> {
    pub dst: usize,
    pub at: SimTime,
    pub msg: M,
}

/// One partition of the simulation. NOT required to be `Send` — the
/// driver builds each partition inside the thread that runs it.
pub trait Partition {
    /// The cross-partition message payload.
    type Msg: Send;

    /// Earliest pending local event time (`None` = locally idle).
    fn peek(&mut self) -> Option<SimTime>;

    /// Minimum virtual-time distance between executing an event and the
    /// earliest cross-partition arrival it can cause (the WAN delay +
    /// serialization floor for cluster partitions). The driver clamps
    /// this to >= 1.
    fn lookahead(&self) -> SimTime;

    /// Execute every local event with `at < horizon`, appending any
    /// cross-partition messages to `out` in a deterministic local
    /// order (their position is the merge tiebreak).
    fn run_window(&mut self, horizon: SimTime, out: &mut Vec<Envelope<Self::Msg>>);

    /// Accept a cross-partition message (delivered before the next
    /// window runs; `at` is always in that window's future).
    fn absorb(&mut self, at: SimTime, msg: Self::Msg);

    /// Order-sensitive state digest, folded across partitions after
    /// every window and handed to the driver's `on_window` hook — the
    /// probe the serial-vs-parallel differential compares.
    fn digest(&mut self) -> u64;
}

/// Shared lock-step state: peeks/lookaheads per partition plus the
/// envelopes delivered at the end of the previous window (absorbed at
/// the start of the next). Identical between the serial and threaded
/// drivers — this is where determinism lives.
struct SyncState<M> {
    peeks: Vec<Option<SimTime>>,
    looks: Vec<SimTime>,
    inboxes: Vec<Vec<(SimTime, M)>>,
}

impl<M> SyncState<M> {
    fn new(n: usize) -> Self {
        SyncState {
            peeks: vec![None; n],
            looks: vec![1; n],
            inboxes: (0..n).map(|_| Vec::new()).collect(),
        }
    }

    /// Next safe horizon: `min_i(eff_peek_i + look_i)` clamped to
    /// `until + 1`, where `eff_peek` folds in undelivered envelopes.
    /// `None` when no partition has work at or before `until`.
    fn horizon(&self, until: SimTime) -> Option<SimTime> {
        let mut h: Option<SimTime> = None;
        let mut work = false;
        for i in 0..self.peeks.len() {
            let inbox_min = self.inboxes[i].iter().map(|(at, _)| *at).min();
            let eff = match (self.peeks[i], inbox_min) {
                (Some(p), Some(m)) => Some(p.min(m)),
                (p, m) => p.or(m),
            };
            let Some(p) = eff else { continue };
            if p <= until {
                work = true;
            }
            let hi = p.saturating_add(self.looks[i].max(1));
            h = Some(h.map_or(hi, |x| x.min(hi)));
        }
        if !work {
            return None;
        }
        Some(h.expect("work implies a peek").min(until.saturating_add(1)))
    }

    /// Merge one window's outboxes into the per-partition inboxes in
    /// the canonical order: `(at, src partition, outbox index)`.
    fn deliver(&mut self, routed: &mut Vec<(usize, usize, Envelope<M>)>) {
        routed.sort_by_key(|(src, idx, env)| (env.at, *src, *idx));
        for (_, _, env) in routed.drain(..) {
            self.inboxes[env.dst].push((env.at, env.msg));
        }
    }
}

/// Messages between the lock-step driver and a worker thread.
enum ToWorker<M> {
    /// Run one window: absorb `inbox` (pre-sorted delivery order,
    /// tagged with the destination partition), then execute to
    /// `horizon`.
    Window { horizon: SimTime, inbox: Vec<(usize, SimTime, M)> },
    Stop,
}

enum FromWorker<M, R> {
    /// Partitions built: initial `(partition, peek, lookahead)`.
    Hello(Vec<(usize, Option<SimTime>, SimTime)>),
    /// Window done: `(partition, peek, digest)` plus the outbox as
    /// `(src partition, outbox index, envelope)`.
    Report {
        parts: Vec<(usize, Option<SimTime>, u64)>,
        outbox: Vec<(usize, usize, Envelope<M>)>,
    },
    /// Finished: `(partition, result)`.
    Done(Vec<(usize, R)>),
}

/// Run `blueprints.len()` partitions to virtual time `until` under
/// conservative lock-stepped windows, on `threads` worker threads
/// (`<= 1`, or a single partition, runs the serial reference path on
/// the caller's thread — same windows, same merge order, same
/// digests). `build` turns a blueprint into a live partition inside
/// its owning thread; `finish` reduces each partition to a `Send`
/// result after the last window. `on_window(horizon, digest)` fires on
/// the caller's thread after every window with the partition-ordered
/// digest fold.
pub fn run_partitioned<B, P, R, FB, FF>(
    blueprints: Vec<B>,
    threads: usize,
    until: SimTime,
    build: FB,
    finish: FF,
    mut on_window: impl FnMut(SimTime, u64),
) -> Vec<R>
where
    B: Send,
    P: Partition,
    R: Send,
    FB: Fn(usize, B) -> P + Sync,
    FF: Fn(usize, P) -> R + Sync,
{
    let n = blueprints.len();
    if n == 0 {
        return Vec::new();
    }
    if threads <= 1 || n == 1 {
        return run_serial(blueprints, until, build, finish, on_window);
    }

    let nw = threads.min(n);
    let mut per_worker: Vec<Vec<(usize, B)>> = (0..nw).map(|_| Vec::new()).collect();
    for (i, b) in blueprints.into_iter().enumerate() {
        per_worker[i % nw].push((i, b));
    }

    std::thread::scope(|s| {
        let (res_tx, res_rx) = mpsc::channel::<FromWorker<P::Msg, R>>();
        let mut to_workers = Vec::with_capacity(nw);
        let (build, finish) = (&build, &finish);
        for my in per_worker {
            let (tx, rx) = mpsc::channel::<ToWorker<P::Msg>>();
            to_workers.push(tx);
            let res_tx = res_tx.clone();
            s.spawn(move || {
                let mut parts: Vec<(usize, P)> =
                    my.into_iter().map(|(i, b)| (i, build(i, b))).collect();
                let hello = parts
                    .iter_mut()
                    .map(|(i, p)| (*i, p.peek(), p.lookahead()))
                    .collect();
                if res_tx.send(FromWorker::Hello(hello)).is_err() {
                    return;
                }
                let mut out: Vec<Envelope<P::Msg>> = Vec::new();
                for msg in rx {
                    match msg {
                        ToWorker::Window { horizon, inbox } => {
                            for (dst, at, m) in inbox {
                                let (_, p) = parts
                                    .iter_mut()
                                    .find(|(gi, _)| *gi == dst)
                                    .expect("envelope routed to a partition this worker owns");
                                p.absorb(at, m);
                            }
                            let mut report = Vec::with_capacity(parts.len());
                            let mut outbox = Vec::new();
                            for (gi, p) in parts.iter_mut() {
                                out.clear();
                                p.run_window(horizon, &mut out);
                                for (idx, env) in out.drain(..).enumerate() {
                                    outbox.push((*gi, idx, env));
                                }
                                report.push((*gi, p.peek(), p.digest()));
                            }
                            if res_tx
                                .send(FromWorker::Report { parts: report, outbox })
                                .is_err()
                            {
                                return;
                            }
                        }
                        ToWorker::Stop => break,
                    }
                }
                let done = parts.drain(..).map(|(i, p)| (i, finish(i, p))).collect();
                res_tx.send(FromWorker::Done(done)).ok();
            });
        }
        drop(res_tx); // recv() must error (not hang) if every worker dies

        let mut st: SyncState<P::Msg> = SyncState::new(n);
        let recv = |rx: &mpsc::Receiver<FromWorker<P::Msg, R>>| {
            rx.recv().expect("a partition worker thread died")
        };
        for _ in 0..nw {
            match recv(&res_rx) {
                FromWorker::Hello(parts) => {
                    for (i, peek, look) in parts {
                        st.peeks[i] = peek;
                        st.looks[i] = look;
                    }
                }
                _ => unreachable!("hello precedes every report"),
            }
        }

        let mut digests = vec![0u64; n];
        let mut routed: Vec<(usize, usize, Envelope<P::Msg>)> = Vec::new();
        while let Some(h) = st.horizon(until) {
            for (w, tx) in to_workers.iter().enumerate() {
                let mut inbox = Vec::new();
                for gi in (w..n).step_by(nw) {
                    for (at, m) in st.inboxes[gi].drain(..) {
                        inbox.push((gi, at, m));
                    }
                }
                tx.send(ToWorker::Window { horizon: h, inbox })
                    .expect("a partition worker thread died");
            }
            for _ in 0..nw {
                match recv(&res_rx) {
                    FromWorker::Report { parts, outbox } => {
                        for (i, peek, digest) in parts {
                            st.peeks[i] = peek;
                            digests[i] = digest;
                        }
                        routed.extend(outbox);
                    }
                    _ => unreachable!("workers report exactly once per window"),
                }
            }
            st.deliver(&mut routed);
            let fold = digests.iter().fold(FNV_OFFSET, |h, &d| fnv_mix(h, d));
            on_window(h, fold);
        }

        for tx in &to_workers {
            tx.send(ToWorker::Stop).expect("a partition worker thread died");
        }
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..nw {
            match recv(&res_rx) {
                FromWorker::Done(rs) => {
                    for (i, r) in rs {
                        results[i] = Some(r);
                    }
                }
                _ => unreachable!("stop is answered only by done"),
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every partition reports a result"))
            .collect()
    })
}

/// The serial reference path: identical windows, merge order, and
/// digest folds to the threaded driver, on the caller's thread.
fn run_serial<B, P, R, FB, FF>(
    blueprints: Vec<B>,
    until: SimTime,
    build: FB,
    finish: FF,
    mut on_window: impl FnMut(SimTime, u64),
) -> Vec<R>
where
    P: Partition,
    FB: Fn(usize, B) -> P,
    FF: Fn(usize, P) -> R,
{
    let n = blueprints.len();
    let mut parts: Vec<P> =
        blueprints.into_iter().enumerate().map(|(i, b)| build(i, b)).collect();
    let mut st: SyncState<P::Msg> = SyncState::new(n);
    for (i, p) in parts.iter_mut().enumerate() {
        st.peeks[i] = p.peek();
        st.looks[i] = p.lookahead();
    }
    let mut out: Vec<Envelope<P::Msg>> = Vec::new();
    let mut routed: Vec<(usize, usize, Envelope<P::Msg>)> = Vec::new();
    let mut digests = vec![0u64; n];
    while let Some(h) = st.horizon(until) {
        for (i, p) in parts.iter_mut().enumerate() {
            for (at, m) in st.inboxes[i].drain(..) {
                p.absorb(at, m);
            }
            out.clear();
            p.run_window(h, &mut out);
            for (idx, env) in out.drain(..).enumerate() {
                routed.push((i, idx, env));
            }
            st.peeks[i] = p.peek();
            digests[i] = p.digest();
        }
        st.deliver(&mut routed);
        let fold = digests.iter().fold(FNV_OFFSET, |h, &d| fnv_mix(h, d));
        on_window(h, fold);
    }
    parts.into_iter().enumerate().map(|(i, p)| finish(i, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{Scheduler, SimEvent};

    /// Toy ring: each partition is a typed-event scheduler whose events
    /// mix into an order-sensitive accumulator and forward a decremented
    /// hop counter to the next partition `look` later.
    struct ToyWorld {
        idx: usize,
        n: usize,
        look: SimTime,
        acc: u64,
        out: Vec<Envelope<u64>>,
    }

    struct Hop(u64);

    impl SimEvent<ToyWorld> for Hop {
        fn fire(self, sch: &mut Scheduler<ToyWorld, Hop>, w: &mut ToyWorld) {
            w.acc = fnv_mix(w.acc, sch.now() ^ (self.0 << 17) ^ w.idx as u64);
            if self.0 > 0 {
                w.out.push(Envelope {
                    dst: (w.idx + 1) % w.n,
                    at: sch.now() + w.look,
                    msg: self.0 - 1,
                });
            }
        }
    }

    struct ToyPart {
        sch: Scheduler<ToyWorld, Hop>,
        w: ToyWorld,
    }

    impl Partition for ToyPart {
        type Msg = u64;

        fn peek(&mut self) -> Option<SimTime> {
            self.sch.peek_next()
        }

        fn lookahead(&self) -> SimTime {
            self.w.look
        }

        fn run_window(&mut self, horizon: SimTime, out: &mut Vec<Envelope<u64>>) {
            // run_until processes events at <= its bound, the window
            // contract is at < horizon
            self.sch.run_until(&mut self.w, horizon - 1);
            out.append(&mut self.w.out);
        }

        fn absorb(&mut self, at: SimTime, msg: u64) {
            self.sch.push_at(at, Hop(msg));
        }

        fn digest(&mut self) -> u64 {
            fnv_mix(self.w.acc, self.sch.executed())
        }
    }

    fn build_toy(n: usize, look: SimTime) -> impl Fn(usize, u64) -> ToyPart + Sync {
        move |idx, seed| {
            let mut sch = Scheduler::new();
            let w = ToyWorld { idx, n, look, acc: FNV_OFFSET, out: Vec::new() };
            // a burst of initial hops, times scattered by the seed
            for k in 0..8u64 {
                let at = (seed.wrapping_mul(2654435761).wrapping_add(k * 977)) % 5_000;
                sch.push_at(at, Hop(6 + (k % 3)));
            }
            ToyPart { sch, w }
        }
    }

    fn run_toy(n: usize, threads: usize, until: SimTime) -> (Vec<(u64, u64)>, Vec<(SimTime, u64)>) {
        let mut windows = Vec::new();
        let results = run_partitioned(
            (0..n as u64).collect::<Vec<_>>(),
            threads,
            until,
            build_toy(n, 120),
            |_, p: ToyPart| (p.w.acc, p.sch.executed()),
            |h, d| windows.push((h, d)),
        );
        (results, windows)
    }

    #[test]
    fn serial_and_threaded_drivers_are_bit_identical() {
        let (r1, w1) = run_toy(5, 1, 400_000);
        assert!(!w1.is_empty(), "toy ring must produce windows");
        assert!(r1.iter().any(|&(_, ex)| ex > 8), "hops must actually chain");
        for threads in [2, 3, 8] {
            let (rt, wt) = run_toy(5, threads, 400_000);
            assert_eq!(r1, rt, "{threads} threads: results diverged");
            assert_eq!(w1, wt, "{threads} threads: window digests diverged");
        }
    }

    #[test]
    fn horizons_are_monotone_and_make_progress() {
        let (_, windows) = run_toy(4, 2, 300_000);
        for pair in windows.windows(2) {
            assert!(pair[0].0 < pair[1].0, "horizons must strictly advance");
        }
    }

    #[test]
    fn zero_lookahead_reports_are_clamped() {
        // a partition reporting lookahead 0 must not wedge the driver
        struct Lazy {
            sch: Scheduler<ToyWorld, Hop>,
            w: ToyWorld,
        }
        impl Partition for Lazy {
            type Msg = u64;
            fn peek(&mut self) -> Option<SimTime> {
                self.sch.peek_next()
            }
            fn lookahead(&self) -> SimTime {
                0
            }
            fn run_window(&mut self, horizon: SimTime, out: &mut Vec<Envelope<u64>>) {
                self.sch.run_until(&mut self.w, horizon - 1);
                out.append(&mut self.w.out);
            }
            fn absorb(&mut self, at: SimTime, msg: u64) {
                self.sch.push_at(at, Hop(msg));
            }
            fn digest(&mut self) -> u64 {
                self.w.acc
            }
        }
        let results = run_partitioned(
            vec![0u64, 1],
            1,
            10_000,
            |idx, _| {
                let mut sch = Scheduler::new();
                sch.push_at(5, Hop(3));
                Lazy {
                    sch,
                    w: ToyWorld { idx, n: 2, look: 50, acc: 0, out: Vec::new() },
                }
            },
            |_, p: Lazy| p.sch.executed(),
            |_, _| {},
        );
        assert_eq!(results.len(), 2);
        assert!(results.iter().sum::<u64>() >= 4, "hops crossed partitions");
    }

    #[test]
    fn empty_blueprints_yield_empty_results() {
        let results: Vec<u64> = run_partitioned(
            Vec::<u64>::new(),
            4,
            1_000,
            |_, _| unreachable!("no partitions to build"),
            |_, _p: ToyPart| unreachable!("no partitions to finish"),
            |_, _| {},
        );
        assert!(results.is_empty());
    }
}
