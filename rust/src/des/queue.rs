//! Event-queue implementations behind [`super::Scheduler`].
//!
//! Two queues with identical `(at, seq)`-lexicographic pop order:
//!
//! * [`CalendarQueue`] — a single-level timing wheel (calendar queue)
//!   with an overflow heap. This is what the scheduler runs on: for the
//!   dense-timer regime (heartbeats, round deadlines, periodic
//!   publishes) insert and pop are O(1) amortized because an event only
//!   ever sits in a small per-day heap, never in one global comparison
//!   structure.
//! * [`HeapQueue`] — the plain `BinaryHeap` the scheduler used through
//!   PR 5, kept as the reference implementation. The heap-vs-wheel
//!   differential in `tests/properties.rs` and the `des_timer_storm`
//!   bench drive both through [`EventQueue`] and demand identical
//!   trajectories / report the speed ratio.
//!
//! Bucket math (DESIGN.md §Event-engine): virtual time is microseconds;
//! a **day** is `2^WIDTH_SHIFT` = 1024 µs of virtual time, and the
//! wheel holds `NB` = 4096 days ≈ 4.19 virtual seconds. An event lands
//! in one of three places by its day `d = at >> WIDTH_SHIFT` relative
//! to the cursor day:
//!
//! * `d <= day`      → the `current` heap (orders the cursor day),
//! * `d <  day + NB` → wheel bucket `d & (NB-1)`, an UNORDERED
//!   slab-linked list — this is the O(1) fast path,
//! * otherwise       → the `overflow` heap (far future).
//!
//! Determinism argument: every event in `current` has `at` strictly
//! below `(day+1) << WIDTH_SHIFT`, and every wheel/overflow event has
//! `at` at or above it — so whenever `current` is non-empty its top is
//! the global `(at, seq)` minimum, and same-`at` events always meet in
//! the same `current` heap where `seq` breaks the tie. Pop order is
//! therefore identical to a single global heap, byte-for-byte.
//!
//! Rollover: advancing the cursor drains bucket `day & (NB-1)` into
//! `current`. A bucket never mixes days — an entry is filed only when
//! its day is within `NB` of the cursor, and the cursor reaches a
//! bucket exactly once per `NB` days — so the drain is unconditional.
//! After each step, overflow events whose day fell inside the new
//! horizon are promoted (into `current` if their day is the cursor day:
//! that bucket was already drained). When the wheel is empty the cursor
//! jumps straight to the overflow's earliest day instead of scanning.

use crate::util::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// log2 of the bucket width: one day = 1024 µs of virtual time.
pub const WIDTH_SHIFT: u32 = 10;
/// Number of wheel buckets (must be a power of two).
pub const NB: usize = 4096;
const MASK: u64 = NB as u64 - 1;
const NIL: u32 = u32::MAX;

/// A pending event: absolute time, insertion sequence, payload.
pub struct Entry<E> {
    pub at: SimTime,
    pub seq: u64,
    pub ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Common surface of the two queue implementations, so the differential
/// tests and the `des_timer_storm` bench are generic over them.
pub trait EventQueue<E>: Default {
    fn push(&mut self, at: SimTime, seq: u64, ev: E);
    fn pop(&mut self) -> Option<(SimTime, u64, E)>;
    /// Earliest pending time. `&mut` because the calendar queue may
    /// reposition events internally (never dropping or reordering any).
    fn peek_time(&mut self) -> Option<SimTime>;
    /// Earliest pending `(at, seq)` key — what [`EventQueue::pop`] would
    /// return next. The multi-lane scheduler's k-way merge argmins over
    /// this, so it must agree with `pop` exactly (pinned by
    /// `peek_time_matches_next_pop_and_loses_nothing`).
    fn peek_key(&mut self) -> Option<(SimTime, u64)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn reserve(&mut self, additional: usize);
    fn capacity(&self) -> usize;
}

/// The PR-3–PR-5 scheduler queue: one global binary heap. Reference
/// implementation for the wheel differential; also the "before" side of
/// the `des_timer_storm` bench.
pub struct HeapQueue<E> {
    heap: BinaryHeap<Entry<E>>,
}

impl<E> Default for HeapQueue<E> {
    fn default() -> Self {
        HeapQueue { heap: BinaryHeap::new() }
    }
}

impl<E> EventQueue<E> for HeapQueue<E> {
    fn push(&mut self, at: SimTime, seq: u64, ev: E) {
        self.heap.push(Entry { at, seq, ev });
    }

    fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        self.heap.pop().map(|e| (e.at, e.seq, e.ev))
    }

    fn peek_time(&mut self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    fn peek_key(&mut self) -> Option<(SimTime, u64)> {
        self.heap.peek().map(|e| (e.at, e.seq))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    fn capacity(&self) -> usize {
        self.heap.capacity()
    }
}

/// One wheel-resident event. Slots live in a slab `Vec` and chain into
/// per-bucket singly-linked lists through `next` — filing and draining
/// never allocate once the slab has reached its working size (the
/// free-list recycles slots), which is what keeps `tests/zero_alloc.rs`
/// honest on the new engine.
struct Slot<E> {
    at: SimTime,
    seq: u64,
    next: u32,
    ev: Option<E>,
}

/// Single-level timing wheel + overflow heap. See the module docs for
/// the bucket math and the determinism argument.
pub struct CalendarQueue<E> {
    /// Per-bucket head index into `slab` (`NIL` = empty).
    buckets: Box<[u32]>,
    slab: Vec<Slot<E>>,
    /// Free-list head into `slab`.
    free: u32,
    /// Cursor: the day whose events have been merged into `current`.
    day: u64,
    /// Orders the cursor day (and anything pushed at or before it).
    current: BinaryHeap<Entry<E>>,
    /// Events at least `NB` days out.
    overflow: BinaryHeap<Entry<E>>,
    wheel_len: usize,
    len: usize,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        CalendarQueue {
            buckets: vec![NIL; NB].into_boxed_slice(),
            slab: Vec::new(),
            free: NIL,
            day: 0,
            current: BinaryHeap::new(),
            overflow: BinaryHeap::new(),
            wheel_len: 0,
            len: 0,
        }
    }
}

impl<E> CalendarQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// File an event into the wheel (precondition: its day is in
    /// `(self.day, self.day + NB)`).
    fn push_wheel(&mut self, at: SimTime, seq: u64, ev: E) {
        let idx = if self.free != NIL {
            let idx = self.free;
            let slot = &mut self.slab[idx as usize];
            self.free = slot.next;
            slot.at = at;
            slot.seq = seq;
            slot.ev = Some(ev);
            idx
        } else {
            let idx = self.slab.len() as u32;
            assert!(idx != NIL, "event slab exhausted");
            self.slab.push(Slot { at, seq, next: NIL, ev: Some(ev) });
            idx
        };
        let b = ((at >> WIDTH_SHIFT) & MASK) as usize;
        self.slab[idx as usize].next = self.buckets[b];
        self.buckets[b] = idx;
        self.wheel_len += 1;
    }

    /// Move every event of bucket `b` (all of one day) into `current`.
    fn drain_bucket(&mut self, b: usize) {
        let mut idx = self.buckets[b];
        self.buckets[b] = NIL;
        while idx != NIL {
            let slot = &mut self.slab[idx as usize];
            let next = slot.next;
            let ev = slot.ev.take().expect("bucket chained a free slot");
            debug_assert_eq!(slot.at >> WIDTH_SHIFT, self.day, "bucket mixed days");
            self.current.push(Entry { at: slot.at, seq: slot.seq, ev });
            slot.next = self.free;
            self.free = idx;
            self.wheel_len -= 1;
            idx = next;
        }
    }

    /// Pull overflow events whose day is now within the wheel horizon.
    /// An event landing exactly on the cursor day goes to `current` —
    /// its bucket was already drained this round and won't be visited
    /// again for `NB` days.
    fn migrate_overflow(&mut self) {
        while let Some(top) = self.overflow.peek() {
            let d = top.at >> WIDTH_SHIFT;
            if d >= self.day + NB as u64 {
                break;
            }
            let e = self.overflow.pop().expect("peeked entry");
            if d <= self.day {
                self.current.push(e);
            } else {
                self.push_wheel(e.at, e.seq, e.ev);
            }
        }
    }

    /// Advance the cursor until `current` is non-empty (precondition:
    /// `len > 0`). Only repositions events between the three homes;
    /// nothing is dropped or reordered.
    fn advance(&mut self) {
        while self.current.is_empty() {
            if self.wheel_len == 0 {
                // nothing this side of the horizon: jump straight to
                // the overflow's earliest day
                let d = self
                    .overflow
                    .peek()
                    .map(|e| e.at >> WIDTH_SHIFT)
                    .expect("len > 0 with empty current and wheel implies overflow");
                self.day = d;
            } else {
                self.day += 1;
            }
            let b = (self.day & MASK) as usize;
            self.drain_bucket(b);
            self.migrate_overflow();
        }
    }
}

impl<E> EventQueue<E> for CalendarQueue<E> {
    fn push(&mut self, at: SimTime, seq: u64, ev: E) {
        self.len += 1;
        let d = at >> WIDTH_SHIFT;
        if d <= self.day {
            self.current.push(Entry { at, seq, ev });
        } else if d < self.day + NB as u64 {
            self.push_wheel(at, seq, ev);
        } else {
            self.overflow.push(Entry { at, seq, ev });
        }
    }

    fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        if self.len == 0 {
            return None;
        }
        self.advance();
        let e = self.current.pop().expect("advance leaves current non-empty");
        self.len -= 1;
        Some((e.at, e.seq, e.ev))
    }

    fn peek_time(&mut self) -> Option<SimTime> {
        if self.len == 0 {
            return None;
        }
        self.advance();
        self.current.peek().map(|e| e.at)
    }

    fn peek_key(&mut self) -> Option<(SimTime, u64)> {
        if self.len == 0 {
            return None;
        }
        // `advance` leaves the `current` top as the global minimum
        // (module docs: determinism argument), so its key IS the pop key
        self.advance();
        self.current.peek().map(|e| (e.at, e.seq))
    }

    fn len(&self) -> usize {
        self.len
    }

    fn reserve(&mut self, additional: usize) {
        // a pending event lives in exactly one of the three homes, but
        // it can MOVE between them (wheel→current, overflow→either), so
        // each home is sized for the full reservation
        self.slab.reserve(additional);
        self.current.reserve(additional);
        self.overflow.reserve(additional);
    }

    fn capacity(&self) -> usize {
        self.slab.capacity() + self.current.capacity() + self.overflow.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<Q: EventQueue<u32>>(q: &mut Q) -> Vec<(SimTime, u64, u32)> {
        let mut out = Vec::new();
        while let Some(t) = q.pop() {
            out.push(t);
        }
        out
    }

    const DAY: u64 = 1 << WIDTH_SHIFT;
    const HORIZON: u64 = DAY * NB as u64;

    #[test]
    fn pops_in_time_order_within_a_day() {
        let mut q = CalendarQueue::new();
        q.push(30, 1, 0);
        q.push(10, 2, 1);
        q.push(20, 3, 2);
        assert_eq!(drain(&mut q), vec![(10, 2, 1), (20, 3, 2), (30, 1, 0)]);
    }

    #[test]
    fn same_tick_pops_in_seq_order() {
        // ties meet in the same `current` heap wherever they started:
        // cursor day, a wheel day, and beyond the horizon
        for base in [0, DAY * 7, HORIZON * 3 + DAY / 2] {
            let mut q = CalendarQueue::new();
            for seq in (1..=16u64).rev() {
                q.push(base + 5, seq, seq as u32);
            }
            let order: Vec<u64> = drain(&mut q).into_iter().map(|(_, s, _)| s).collect();
            assert_eq!(order, (1..=16).collect::<Vec<_>>(), "base {base}");
        }
    }

    #[test]
    fn wheel_rollover_crosses_bucket_reuse() {
        // two events NB days apart share a bucket index; the second
        // must not surface until the wheel has gone all the way around
        let mut q = CalendarQueue::new();
        q.push(DAY * 2 + 1, 1, 1);
        assert_eq!(q.pop(), Some((DAY * 2 + 1, 1, 1)));
        // cursor now sits at day 2; same bucket, one revolution later
        q.push(DAY * 2 + 1 + HORIZON - DAY, 2, 2); // last wheel-filable day
        q.push(DAY * 5, 3, 3);
        assert_eq!(q.pop(), Some((DAY * 5, 3, 3)));
        assert_eq!(q.pop(), Some((DAY * 2 + 1 + HORIZON - DAY, 2, 2)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn far_future_events_promote_out_of_overflow() {
        let mut q = CalendarQueue::new();
        // three rounds past the horizon, plus one near event
        q.push(HORIZON * 3 + 17, 1, 1);
        q.push(40, 2, 2);
        assert_eq!(q.pop(), Some((40, 2, 2)));
        // the far event is reached by the empty-wheel jump, not a scan
        assert_eq!(q.pop(), Some((HORIZON * 3 + 17, 1, 1)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn overflow_promotes_into_the_wheel_when_near() {
        // overflow event whose day enters the horizon while other wheel
        // events still pace the cursor day-by-day
        let mut q = CalendarQueue::new();
        q.push(HORIZON + DAY * 3, 1, 1); // overflow at push time
        q.push(DAY * 2, 2, 2); // wheel
        q.push(7, 3, 3); // current day
        assert_eq!(q.pop(), Some((7, 3, 3)));
        assert_eq!(q.pop(), Some((DAY * 2, 2, 2)));
        assert_eq!(q.pop(), Some((HORIZON + DAY * 3, 1, 1)));
    }

    #[test]
    fn peek_time_matches_next_pop_and_loses_nothing() {
        let mut q = CalendarQueue::new();
        let times = [5u64, HORIZON + 3, DAY * 9, 5, DAY * 9 + 1];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i as u64 + 1, i as u32);
        }
        let mut seen = Vec::new();
        while let Some(at) = q.peek_time() {
            let key = q.peek_key().unwrap();
            let (pat, pseq, id) = q.pop().unwrap();
            assert_eq!(at, pat, "peek disagreed with pop");
            assert_eq!(key, (pat, pseq), "peek_key disagreed with pop");
            seen.push(id);
        }
        assert_eq!(seen.len(), times.len());
        assert_eq!(seen, vec![0, 3, 2, 4, 1]);
    }

    #[test]
    fn matches_heap_queue_on_a_mixed_workload() {
        // deterministic mixed push/pop trace spanning ties, wheel days
        // and overflow; the big randomized differential lives in
        // tests/properties.rs
        let mut wheel = CalendarQueue::new();
        let mut heap = HeapQueue::default();
        let mut seq = 0u64;
        let mut now = 0u64;
        let mut id = 0u32;
        for step in 0u64..4_000 {
            for k in 0..3u64 {
                let delay = match (step + k) % 5 {
                    0 => 0,
                    1 => (step * 37 + k) % DAY,
                    2 => (step * 911) % (HORIZON / 2),
                    3 => HORIZON + (step * 131) % HORIZON,
                    _ => (step * 7919) % (HORIZON * 4),
                };
                seq += 1;
                id += 1;
                wheel.push(now + delay, seq, id);
                heap.push(now + delay, seq, id);
            }
            let (a, b) = (wheel.pop(), heap.pop());
            assert_eq!(a, b, "diverged at step {step}");
            now = a.map(|(at, _, _)| at).unwrap_or(now);
            assert_eq!(wheel.len(), heap.len());
        }
        assert_eq!(drain(&mut wheel), drain(&mut heap));
    }

    #[test]
    fn free_list_recycles_slots_without_slab_growth() {
        let mut q = CalendarQueue::new();
        let mut seq = 0u64;
        let mut now = 0u64;
        // warm up a periodic 64-timer population, then assert the
        // capacity no longer moves (the zero-alloc property in miniature)
        for _ in 0..64u32 {
            seq += 1;
            q.push(now + 1 + seq % 700, seq, 0);
        }
        for _ in 0..2_000 {
            let (at, _, _) = q.pop().unwrap();
            now = at;
            seq += 1;
            q.push(now + 700, seq, 0);
        }
        let cap = q.capacity();
        for _ in 0..20_000 {
            let (at, _, _) = q.pop().unwrap();
            now = at;
            seq += 1;
            q.push(now + 700, seq, 0);
        }
        assert_eq!(q.capacity(), cap, "steady periodic load regrew the queue");
    }

    #[test]
    fn reserve_presizes_every_home() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        q.reserve(100);
        let cap = q.capacity();
        assert!(cap >= 300, "all three homes must be sized: {cap}");
        for i in 0..100u64 {
            q.push(i * 17, i + 1, i as u32);
        }
        assert_eq!(q.capacity(), cap, "reserved queue must not regrow");
    }
}
