//! YAML-subset parser + emitter ("yamlite").
//!
//! The paper's topology files are "extended YAML" (§5.1.3, Fig 4) and
//! the controller renders deployment instructions as docker-compose
//! YAML. With serde_yaml unavailable offline we implement the subset
//! those files need:
//!
//!   * block mappings + block sequences nested by indentation (spaces);
//!   * `- ` list items, including inline `- key: value` mapping starts;
//!   * flow sequences `[a, b, c]` of scalars;
//!   * scalars: quoted/unquoted strings, ints, floats, bools, null;
//!   * `#` comments and blank lines.
//!
//! Anchors, multi-doc, flow mappings, and block scalars are rejected
//! with an error rather than mis-parsed. Values land in `json::Value`,
//! so topology code shares one data model with the JSON manifest.

use crate::json::Value;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

struct Line {
    indent: usize,
    text: String, // content with indent stripped
    no: usize,    // 1-based source line number
}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, YamlError> {
    Err(YamlError { line, msg: msg.into() })
}

fn scan_lines(src: &str) -> Result<Vec<Line>, YamlError> {
    let mut out = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let no = i + 1;
        if raw.contains('\t') {
            return err(no, "tabs are not allowed for indentation");
        }
        // strip comments that are not inside quotes
        let mut text = String::new();
        let mut in_s = false;
        let mut in_d = false;
        for c in raw.chars() {
            match c {
                '\'' if !in_d => in_s = !in_s,
                '"' if !in_s => in_d = !in_d,
                '#' if !in_s && !in_d => break,
                _ => {}
            }
            text.push(c);
        }
        let trimmed_end = text.trim_end();
        let indent = trimmed_end.len() - trimmed_end.trim_start().len();
        let content = trimmed_end.trim_start().to_string();
        if content.is_empty() {
            continue;
        }
        if content.starts_with("---") || content.starts_with('&') || content.starts_with('*') {
            return err(no, "unsupported yaml feature (multi-doc/anchor)");
        }
        out.push(Line { indent, text: content, no });
    }
    Ok(out)
}

/// Parse an unquoted or quoted scalar.
pub fn parse_scalar(s: &str) -> Value {
    let t = s.trim();
    if t.is_empty() || t == "~" || t == "null" {
        return Value::Null;
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Value::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "true" | "True" => return Value::Bool(true),
        "false" | "False" => return Value::Bool(false),
        _ => {}
    }
    if let Ok(n) = t.parse::<i64>() {
        return Value::Num(n as f64);
    }
    if let Ok(f) = t.parse::<f64>() {
        return Value::Num(f);
    }
    Value::Str(t.to_string())
}

fn parse_flow_seq(s: &str, line: usize) -> Result<Value, YamlError> {
    let inner = &s[1..s.len() - 1];
    let mut items = Vec::new();
    if !inner.trim().is_empty() {
        for part in inner.split(',') {
            if part.contains('[') || part.contains('{') {
                return err(line, "nested flow collections unsupported");
            }
            items.push(parse_scalar(part));
        }
    }
    Ok(Value::Arr(items))
}

fn parse_rhs(s: &str, line: usize) -> Result<Value, YamlError> {
    let t = s.trim();
    if t.starts_with('[') && t.ends_with(']') {
        parse_flow_seq(t, line)
    } else if t == "{}" {
        // the one flow mapping we accept: the empty one (emitted for
        // empty containers, e.g. a node with no services left)
        Ok(Value::Obj(BTreeMap::new()))
    } else if t.starts_with('{') {
        err(line, "flow mappings unsupported")
    } else if t.starts_with('|') || t.starts_with('>') {
        err(line, "block scalars unsupported")
    } else {
        Ok(parse_scalar(t))
    }
}

/// Split `key: value` at the first unquoted `: ` (or trailing `:`).
fn split_kv(s: &str) -> Option<(&str, &str)> {
    let b = s.as_bytes();
    let mut in_s = false;
    let mut in_d = false;
    for i in 0..b.len() {
        match b[i] {
            b'\'' if !in_d => in_s = !in_s,
            b'"' if !in_s => in_d = !in_d,
            b':' if !in_s && !in_d => {
                if i + 1 == b.len() {
                    return Some((&s[..i], ""));
                }
                if b[i + 1] == b' ' {
                    return Some((&s[..i], &s[i + 2..]));
                }
            }
            _ => {}
        }
    }
    None
}

struct P {
    lines: Vec<Line>,
    pos: usize,
}

impl P {
    fn peek(&self) -> Option<&Line> {
        self.lines.get(self.pos)
    }

    /// Parse a block (mapping or sequence) whose items sit at `indent`.
    fn block(&mut self, indent: usize) -> Result<Value, YamlError> {
        let first = match self.peek() {
            Some(l) => l,
            None => return Ok(Value::Null),
        };
        if first.text.starts_with("- ") || first.text == "-" {
            self.sequence(indent)
        } else {
            self.mapping(indent)
        }
    }

    fn mapping(&mut self, indent: usize) -> Result<Value, YamlError> {
        let mut map = BTreeMap::new();
        while let Some(l) = self.peek() {
            if l.indent < indent {
                break;
            }
            if l.indent > indent {
                return err(l.no, "unexpected indent");
            }
            if l.text.starts_with("- ") || l.text == "-" {
                return err(l.no, "sequence item inside mapping");
            }
            let no = l.no;
            let (k, v) = match split_kv(&l.text) {
                Some(kv) => kv,
                None => return err(no, format!("expected 'key: value', got '{}'", l.text)),
            };
            let key = match parse_scalar(k) {
                Value::Str(s) => s,
                other => match other {
                    Value::Num(n) => format!("{n}"),
                    Value::Bool(b) => format!("{b}"),
                    _ => return err(no, "bad mapping key"),
                },
            };
            let vtrim = v.trim().to_string();
            self.pos += 1;
            let val = if vtrim.is_empty() {
                // nested block (or empty value if no deeper lines)
                match self.peek() {
                    Some(n) if n.indent > indent => self.block(n.indent)?,
                    _ => Value::Null,
                }
            } else {
                parse_rhs(&vtrim, no)?
            };
            if map.insert(key.clone(), val).is_some() {
                return err(no, format!("duplicate key '{key}'"));
            }
        }
        Ok(Value::Obj(map))
    }

    fn sequence(&mut self, indent: usize) -> Result<Value, YamlError> {
        let mut arr = Vec::new();
        while let Some(l) = self.peek() {
            if l.indent < indent {
                break;
            }
            if l.indent > indent {
                return err(l.no, "unexpected indent in sequence");
            }
            if !(l.text.starts_with("- ") || l.text == "-") {
                break;
            }
            let no = l.no;
            let rest = if l.text == "-" { "" } else { &l.text[2..] }.trim().to_string();
            // virtual indent of inline content after "- "
            let vindent = indent + 2;
            self.pos += 1;
            if rest.is_empty() {
                // nested block item
                match self.peek() {
                    Some(n) if n.indent >= vindent => {
                        let ni = n.indent;
                        arr.push(self.block(ni)?);
                    }
                    _ => arr.push(Value::Null),
                }
            } else if let Some((k, v)) = split_kv(&rest) {
                // inline mapping start: `- key: value` then continuation
                // lines at vindent
                let mut map = BTreeMap::new();
                let key = match parse_scalar(k) {
                    Value::Str(s) => s,
                    _ => return err(no, "bad mapping key in sequence item"),
                };
                let vtrim = v.trim();
                let val = if vtrim.is_empty() {
                    match self.peek() {
                        Some(n) if n.indent > vindent => self.block(n.indent)?,
                        _ => Value::Null,
                    }
                } else {
                    parse_rhs(vtrim, no)?
                };
                map.insert(key, val);
                // continuation keys
                if let Some(n) = self.peek() {
                    if n.indent == vindent && !(n.text.starts_with("- ") || n.text == "-") {
                        if let Value::Obj(rest_map) = self.mapping(vindent)? {
                            for (k, v) in rest_map {
                                if map.insert(k.clone(), v).is_some() {
                                    return err(no, format!("duplicate key '{k}'"));
                                }
                            }
                        }
                    }
                }
                arr.push(Value::Obj(map));
            } else {
                arr.push(parse_rhs(&rest, no)?);
            }
        }
        Ok(Value::Arr(arr))
    }
}

/// Parse a yamlite document into a `json::Value`.
pub fn parse(src: &str) -> Result<Value, YamlError> {
    let lines = scan_lines(src)?;
    if lines.is_empty() {
        return Ok(Value::Null);
    }
    let indent = lines[0].indent;
    let mut p = P { lines, pos: 0 };
    let v = p.block(indent)?;
    if let Some(l) = p.peek() {
        return err(l.no, "trailing content at lower indent");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Emitter — block style, deterministic key order (BTreeMap)
// ---------------------------------------------------------------------------

fn needs_quotes(s: &str) -> bool {
    s.is_empty()
        || s.contains(": ")
        || s.ends_with(':')
        || s.starts_with(['-', '[', ']', '{', '}', '#', '&', '*', '!', '|', '>', '\'', '"', '%', '@'])
        || s.parse::<f64>().is_ok()
        || matches!(s, "true" | "false" | "null" | "~" | "True" | "False")
        || s.contains('\n')
}

fn emit_scalar(v: &Value) -> String {
    match v {
        Value::Null => "null".to_string(),
        Value::Bool(b) => format!("{b}"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                format!("{}", *n as i64)
            } else {
                format!("{n}")
            }
        }
        Value::Str(s) => {
            if needs_quotes(s) {
                format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
            } else {
                s.clone()
            }
        }
        _ => unreachable!("emit_scalar on container"),
    }
}

fn emit_into(v: &Value, indent: usize, out: &mut String) {
    let pad = " ".repeat(indent);
    match v {
        Value::Obj(o) => {
            for (k, val) in o {
                match val {
                    Value::Obj(inner) if !inner.is_empty() => {
                        out.push_str(&format!("{pad}{k}:\n"));
                        emit_into(val, indent + 2, out);
                    }
                    Value::Arr(inner) if !inner.is_empty() => {
                        out.push_str(&format!("{pad}{k}:\n"));
                        emit_into(val, indent + 2, out);
                    }
                    Value::Obj(_) => out.push_str(&format!("{pad}{k}: {{}}\n")),
                    Value::Arr(_) => out.push_str(&format!("{pad}{k}: []\n")),
                    _ => out.push_str(&format!("{pad}{k}: {}\n", emit_scalar(val))),
                }
            }
        }
        Value::Arr(a) => {
            for item in a {
                match item {
                    Value::Obj(o) if !o.is_empty() => {
                        // `- key: value` first line, rest indented
                        let mut first = true;
                        for (k, val) in o {
                            let lead = if first {
                                format!("{pad}- ")
                            } else {
                                format!("{pad}  ")
                            };
                            first = false;
                            match val {
                                Value::Obj(inner) if !inner.is_empty() => {
                                    out.push_str(&format!("{lead}{k}:\n"));
                                    emit_into(val, indent + 4, out);
                                }
                                Value::Arr(inner) if !inner.is_empty() => {
                                    out.push_str(&format!("{lead}{k}:\n"));
                                    emit_into(val, indent + 4, out);
                                }
                                Value::Obj(_) => out.push_str(&format!("{lead}{k}: {{}}\n")),
                                Value::Arr(_) => out.push_str(&format!("{lead}{k}: []\n")),
                                _ => out.push_str(&format!("{lead}{k}: {}\n", emit_scalar(val))),
                            }
                        }
                    }
                    Value::Arr(_) | Value::Obj(_) => {
                        out.push_str(&format!("{pad}-\n"));
                        emit_into(item, indent + 2, out);
                    }
                    _ => out.push_str(&format!("{pad}- {}\n", emit_scalar(item))),
                }
            }
        }
        _ => out.push_str(&format!("{pad}{}\n", emit_scalar(v))),
    }
}

/// Emit a yamlite document (parseable by `parse`).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    emit_into(v, 0, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_mapping() {
        let doc = "
app: videoquery
resources:
  cpu: 2
  mem: 512
labels: [edge, camera]
enabled: true
ratio: 0.5
";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("app").as_str(), Some("videoquery"));
        assert_eq!(v.get("resources").get("cpu").as_i64(), Some(2));
        assert_eq!(v.get("labels").idx(1).as_str(), Some("camera"));
        assert_eq!(v.get("enabled").as_bool(), Some(true));
        assert_eq!(v.get("ratio").as_f64(), Some(0.5));
    }

    #[test]
    fn parses_sequences_of_mappings() {
        let doc = "
components:
  - name: od
    kind: detector
    resources:
      cpu: 1
  - name: eoc
    kind: classifier
";
        let v = parse(doc).unwrap();
        let comps = v.get("components").as_arr().unwrap();
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].get("name").as_str(), Some("od"));
        assert_eq!(comps[0].get("resources").get("cpu").as_i64(), Some(1));
        assert_eq!(comps[1].get("kind").as_str(), Some("classifier"));
    }

    #[test]
    fn comments_and_quotes() {
        let doc = "
name: \"a # not comment\" # real comment
note: 'single # kept'
";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").as_str(), Some("a # not comment"));
        assert_eq!(v.get("note").as_str(), Some("single # kept"));
    }

    #[test]
    fn scalar_sequence() {
        let v = parse("- 1\n- two\n- false\n").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        assert_eq!(a[1].as_str(), Some("two"));
        assert_eq!(a[2].as_bool(), Some(false));
    }

    #[test]
    fn empty_flow_containers() {
        let v = parse("services: {}\nitems: []\n").unwrap();
        assert_eq!(v.get("services"), &Value::Obj(BTreeMap::new()));
        assert_eq!(v.get("items"), &Value::Arr(vec![]));
    }

    #[test]
    fn rejects_unsupported() {
        assert!(parse("a: |\n  block\n").is_err());
        assert!(parse("x: {a: 1}").is_err());
        assert!(parse("a: 1\na: 2\n").is_err());
        assert!(parse("\tfoo: 1").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = "
app: vq
components:
  - name: od
    labels: [edge, camera]
    resources:
      cpu: 1
      mem: 128
  - name: coc
    resources:
      cpu: 8
      gpu: true
";
        let v = parse(doc).unwrap();
        let emitted = to_string(&v);
        let v2 = parse(&emitted).unwrap();
        assert_eq!(v, v2, "emitted:\n{emitted}");
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("  \n# only comment\n").unwrap(), Value::Null);
    }
}
