//! Deployment plans + update diffing (§4.4.3).
//!
//! The orchestrator binds components to nodes producing a
//! `DeploymentPlan` ("a topology replica modified by the orchestrator",
//! Figure 4 'instances'); the controller transforms it into per-node
//! compose-style instructions. Submitting a new topology triggers
//! either a *thorough* update (remove everything, redeploy) or an
//! *incremental* update (diff the plans and only touch changed
//! instances) — both from §4.4.3.

use crate::util::AceId;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Instance {
    /// unique within the app, e.g. "od-ec-1-rpi2"
    pub id: String,
    pub component: String,
    pub node: AceId,
    pub image: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    pub app: String,
    pub version: u64,
    pub instances: Vec<Instance>,
}

impl DeploymentPlan {
    /// Instances grouped per node (for instruction generation).
    pub fn by_node(&self) -> BTreeMap<AceId, Vec<&Instance>> {
        let mut map: BTreeMap<AceId, Vec<&Instance>> = BTreeMap::new();
        for inst in &self.instances {
            map.entry(inst.node.clone()).or_default().push(inst);
        }
        map
    }

    pub fn instances_of(&self, component: &str) -> Vec<&Instance> {
        self.instances.iter().filter(|i| i.component == component).collect()
    }

    pub fn nodes(&self) -> Vec<AceId> {
        self.by_node().into_keys().collect()
    }
}

/// Incremental-update diff between two plans.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanDiff {
    /// instances present only in the old plan
    pub remove: Vec<Instance>,
    /// instances present only in the new plan
    pub add: Vec<Instance>,
    /// same (component, node) but different image -> redeploy in place
    pub replace: Vec<Instance>,
    /// untouched
    pub unchanged: Vec<Instance>,
}

impl PlanDiff {
    pub fn is_noop(&self) -> bool {
        self.remove.is_empty() && self.add.is_empty() && self.replace.is_empty()
    }

    /// Nodes whose instruction must be re-sent.
    pub fn touched_nodes(&self) -> Vec<AceId> {
        let mut nodes: Vec<AceId> = self
            .remove
            .iter()
            .chain(self.add.iter())
            .chain(self.replace.iter())
            .map(|i| i.node.clone())
            .collect();
        nodes.sort();
        nodes.dedup();
        nodes
    }
}

/// Compute the incremental update between `old` and `new`.
pub fn diff_plans(old: &DeploymentPlan, new: &DeploymentPlan) -> PlanDiff {
    let key = |i: &Instance| (i.component.clone(), i.node.clone());
    let old_map: BTreeMap<_, &Instance> = old.instances.iter().map(|i| (key(i), i)).collect();
    let new_map: BTreeMap<_, &Instance> = new.instances.iter().map(|i| (key(i), i)).collect();
    let mut diff = PlanDiff::default();
    for (k, i) in &old_map {
        if !new_map.contains_key(k) {
            diff.remove.push((*i).clone());
        }
    }
    for (k, i) in &new_map {
        match old_map.get(k) {
            None => diff.add.push((*i).clone()),
            Some(o) if o.image != i.image => diff.replace.push((*i).clone()),
            Some(_) => diff.unchanged.push((*i).clone()),
        }
    }
    diff
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(c: &str, node: &str, image: &str) -> Instance {
        Instance {
            id: format!("{c}-{}", node.replace('/', "-")),
            component: c.to_string(),
            node: AceId::parse(node),
            image: image.to_string(),
        }
    }

    fn plan(v: u64, instances: Vec<Instance>) -> DeploymentPlan {
        DeploymentPlan { app: "vq".into(), version: v, instances }
    }

    #[test]
    fn groups_by_node() {
        let p = plan(
            1,
            vec![
                inst("od", "i/ec-1/rpi1", "a"),
                inst("dg", "i/ec-1/rpi1", "b"),
                inst("coc", "i/cc/gpu", "c"),
            ],
        );
        let by = p.by_node();
        assert_eq!(by.len(), 2);
        assert_eq!(by[&AceId::parse("i/ec-1/rpi1")].len(), 2);
        assert_eq!(p.instances_of("od").len(), 1);
    }

    #[test]
    fn diff_detects_all_cases() {
        let old = plan(
            1,
            vec![
                inst("od", "i/ec-1/rpi1", "v1"),
                inst("eoc", "i/ec-1/minipc", "v1"),
                inst("rs", "i/cc/gpu", "v1"),
            ],
        );
        let new = plan(
            2,
            vec![
                inst("od", "i/ec-1/rpi1", "v2"),  // replace (new image)
                inst("eoc", "i/ec-1/minipc", "v1"), // unchanged
                inst("ic", "i/cc/gpu", "v1"),     // add
                // rs removed
            ],
        );
        let d = diff_plans(&old, &new);
        assert_eq!(d.replace.len(), 1);
        assert_eq!(d.replace[0].component, "od");
        assert_eq!(d.unchanged.len(), 1);
        assert_eq!(d.add.len(), 1);
        assert_eq!(d.add[0].component, "ic");
        assert_eq!(d.remove.len(), 1);
        assert_eq!(d.remove[0].component, "rs");
        assert!(!d.is_noop());
        // touched: rpi1 (replace), gpu (add+remove) — not minipc
        let touched = d.touched_nodes();
        assert_eq!(touched.len(), 2);
        assert!(!touched.contains(&AceId::parse("i/ec-1/minipc")));
    }

    #[test]
    fn identical_plans_are_noop() {
        let p = plan(1, vec![inst("od", "i/ec-1/rpi1", "v1")]);
        let d = diff_plans(&p, &p.clone());
        assert!(d.is_noop());
        assert_eq!(d.unchanged.len(), 1);
    }
}
