//! Deployment plans + update diffing (§4.4.3).
//!
//! The orchestrator binds components to nodes producing a
//! `DeploymentPlan` ("a topology replica modified by the orchestrator",
//! Figure 4 'instances'); the controller transforms it into per-node
//! compose-style instructions. Submitting a new topology triggers
//! either a *thorough* update (remove everything, redeploy) or an
//! *incremental* update (diff the plans and only touch changed
//! instances) — both from §4.4.3.

use crate::util::AceId;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Instance {
    /// unique within the app, e.g. "od-ec-1-rpi2"
    pub id: String,
    pub component: String,
    pub node: AceId,
    pub image: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    pub app: String,
    pub version: u64,
    pub instances: Vec<Instance>,
}

impl DeploymentPlan {
    /// Instances grouped per node (for instruction generation).
    pub fn by_node(&self) -> BTreeMap<AceId, Vec<&Instance>> {
        let mut map: BTreeMap<AceId, Vec<&Instance>> = BTreeMap::new();
        for inst in &self.instances {
            map.entry(inst.node.clone()).or_default().push(inst);
        }
        map
    }

    pub fn instances_of(&self, component: &str) -> Vec<&Instance> {
        self.instances.iter().filter(|i| i.component == component).collect()
    }

    pub fn nodes(&self) -> Vec<AceId> {
        self.by_node().into_keys().collect()
    }
}

/// Incremental-update diff between two plans.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanDiff {
    /// instances present only in the old plan
    pub remove: Vec<Instance>,
    /// instances present only in the new plan
    pub add: Vec<Instance>,
    /// same (component, node) but different image -> redeploy in place
    pub replace: Vec<Instance>,
    /// untouched
    pub unchanged: Vec<Instance>,
}

impl PlanDiff {
    pub fn is_noop(&self) -> bool {
        self.remove.is_empty() && self.add.is_empty() && self.replace.is_empty()
    }

    /// Nodes whose instruction must be re-sent.
    pub fn touched_nodes(&self) -> Vec<AceId> {
        let mut nodes: Vec<AceId> = self
            .remove
            .iter()
            .chain(self.add.iter())
            .chain(self.replace.iter())
            .map(|i| i.node.clone())
            .collect();
        nodes.sort();
        nodes.dedup();
        nodes
    }
}

/// Compute the incremental update between `old` and `new`.
///
/// Instances are grouped per `(component, node)` and matched as a
/// MULTISET within each group, so scale-out (several instances of one
/// component on one node) diffs correctly:
///
///   * a new instance matching an old one's image consumes that slot
///     → `unchanged`;
///   * an image-mismatched new instance consumes a leftover old slot
///     → `replace` (in-place redeploy);
///   * new instances beyond the old count → `add`;
///   * old instances beyond the new count → `remove`.
///
/// With at most one instance per `(component, node)` — every placement
/// mode except scaled `replicas` — this reduces exactly to the
/// original one-slot semantics.
///
/// Caveat: the diff matches by image, but agents converge by INSTANCE
/// ID, and the orchestrator suffixes replica ids with `-{i}` only when
/// n > 1 — so scaling `replicas: 1` → `replicas: 2` renames the kept
/// instance and the agent restarts it even though the diff calls it
/// unchanged. Scaling between multi-replica counts keeps ids stable.
pub fn diff_plans(old: &DeploymentPlan, new: &DeploymentPlan) -> PlanDiff {
    let key = |i: &Instance| (i.component.clone(), i.node.clone());
    let mut old_map: BTreeMap<(String, AceId), Vec<&Instance>> = BTreeMap::new();
    for i in &old.instances {
        old_map.entry(key(i)).or_default().push(i);
    }
    let mut new_map: BTreeMap<(String, AceId), Vec<&Instance>> = BTreeMap::new();
    for i in &new.instances {
        new_map.entry(key(i)).or_default().push(i);
    }
    let mut diff = PlanDiff::default();
    for (k, olds) in &old_map {
        if !new_map.contains_key(k) {
            diff.remove.extend(olds.iter().map(|i| (*i).clone()));
        }
    }
    for (k, news) in &new_map {
        let olds: &[&Instance] = old_map.get(k).map(|v| v.as_slice()).unwrap_or(&[]);
        let mut old_used = vec![false; olds.len()];
        let mut pending: Vec<&Instance> = Vec::new();
        for &n in news {
            match (0..olds.len()).find(|&j| !old_used[j] && olds[j].image == n.image) {
                Some(j) => {
                    old_used[j] = true;
                    diff.unchanged.push(n.clone());
                }
                None => pending.push(n),
            }
        }
        for n in pending {
            match old_used.iter().position(|u| !u) {
                Some(j) => {
                    old_used[j] = true;
                    diff.replace.push((*n).clone());
                }
                None => diff.add.push((*n).clone()),
            }
        }
        for (j, o) in olds.iter().enumerate() {
            if !old_used[j] {
                diff.remove.push((*o).clone());
            }
        }
    }
    diff
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(c: &str, node: &str, image: &str) -> Instance {
        Instance {
            id: format!("{c}-{}", node.replace('/', "-")),
            component: c.to_string(),
            node: AceId::parse(node),
            image: image.to_string(),
        }
    }

    fn plan(v: u64, instances: Vec<Instance>) -> DeploymentPlan {
        DeploymentPlan { app: "vq".into(), version: v, instances }
    }

    #[test]
    fn groups_by_node() {
        let p = plan(
            1,
            vec![
                inst("od", "i/ec-1/rpi1", "a"),
                inst("dg", "i/ec-1/rpi1", "b"),
                inst("coc", "i/cc/gpu", "c"),
            ],
        );
        let by = p.by_node();
        assert_eq!(by.len(), 2);
        assert_eq!(by[&AceId::parse("i/ec-1/rpi1")].len(), 2);
        assert_eq!(p.instances_of("od").len(), 1);
    }

    #[test]
    fn diff_detects_all_cases() {
        let old = plan(
            1,
            vec![
                inst("od", "i/ec-1/rpi1", "v1"),
                inst("eoc", "i/ec-1/minipc", "v1"),
                inst("rs", "i/cc/gpu", "v1"),
            ],
        );
        let new = plan(
            2,
            vec![
                inst("od", "i/ec-1/rpi1", "v2"),  // replace (new image)
                inst("eoc", "i/ec-1/minipc", "v1"), // unchanged
                inst("ic", "i/cc/gpu", "v1"),     // add
                // rs removed
            ],
        );
        let d = diff_plans(&old, &new);
        assert_eq!(d.replace.len(), 1);
        assert_eq!(d.replace[0].component, "od");
        assert_eq!(d.unchanged.len(), 1);
        assert_eq!(d.add.len(), 1);
        assert_eq!(d.add[0].component, "ic");
        assert_eq!(d.remove.len(), 1);
        assert_eq!(d.remove[0].component, "rs");
        assert!(!d.is_noop());
        // touched: rpi1 (replace), gpu (add+remove) — not minipc
        let touched = d.touched_nodes();
        assert_eq!(touched.len(), 2);
        assert!(!touched.contains(&AceId::parse("i/ec-1/minipc")));
    }

    #[test]
    fn identical_plans_are_noop() {
        let p = plan(1, vec![inst("od", "i/ec-1/rpi1", "v1")]);
        let d = diff_plans(&p, &p.clone());
        assert!(d.is_noop());
        assert_eq!(d.unchanged.len(), 1);
    }

    #[test]
    fn instance_moved_between_nodes_is_remove_plus_add() {
        let old = plan(1, vec![inst("od", "i/ec-1/rpi1", "v1")]);
        let new = plan(2, vec![inst("od", "i/ec-1/rpi2", "v1")]);
        let d = diff_plans(&old, &new);
        assert_eq!(d.remove.len(), 1);
        assert_eq!(d.remove[0].node, AceId::parse("i/ec-1/rpi1"));
        assert_eq!(d.add.len(), 1);
        assert_eq!(d.add[0].node, AceId::parse("i/ec-1/rpi2"));
        assert!(d.replace.is_empty() && d.unchanged.is_empty());
        // both the vacated and the newly occupied node get instructions
        let touched = d.touched_nodes();
        assert_eq!(touched.len(), 2);
        assert!(touched.contains(&AceId::parse("i/ec-1/rpi1")));
        assert!(touched.contains(&AceId::parse("i/ec-1/rpi2")));
    }

    #[test]
    fn version_bump_with_identical_instances_is_noop() {
        // §4.4.3: a topology resubmission that places identically must
        // touch zero nodes, regardless of the version counter
        let instances = vec![
            inst("od", "i/ec-1/rpi1", "v1"),
            inst("coc", "i/cc/gpu", "v1"),
        ];
        let d = diff_plans(&plan(1, instances.clone()), &plan(7, instances));
        assert!(d.is_noop());
        assert_eq!(d.unchanged.len(), 2);
        assert!(d.touched_nodes().is_empty());
    }

    #[test]
    fn empty_to_full_is_all_adds_and_back_is_all_removes() {
        let empty = plan(1, vec![]);
        let full = plan(
            2,
            vec![inst("od", "i/ec-1/rpi1", "v1"), inst("eoc", "i/ec-1/minipc", "v1")],
        );
        let up = diff_plans(&empty, &full);
        assert_eq!(up.add.len(), 2);
        assert!(up.remove.is_empty() && up.replace.is_empty() && up.unchanged.is_empty());
        assert_eq!(up.touched_nodes().len(), 2);
        let down = diff_plans(&full, &empty);
        assert_eq!(down.remove.len(), 2);
        assert!(down.add.is_empty() && down.replace.is_empty() && down.unchanged.is_empty());
        assert_eq!(down.touched_nodes().len(), 2);
        // empty vs empty: nothing at all
        assert!(diff_plans(&empty, &empty.clone()).is_noop());
    }

    fn inst_n(c: &str, node: &str, image: &str, i: usize) -> Instance {
        let mut x = inst(c, node, image);
        x.id = format!("{}-{i}", x.id);
        x
    }

    #[test]
    fn scale_out_on_one_node_diffs_as_multiset() {
        // 1 trainer -> 2 trainers on the SAME node, same image: one
        // unchanged slot + one add (the old single-slot diff collapsed
        // both into one key and called it unchanged)
        let old = plan(1, vec![inst_n("trainer", "i/ec-1/minipc", "v1", 0)]);
        let new = plan(
            2,
            vec![
                inst_n("trainer", "i/ec-1/minipc", "v1", 0),
                inst_n("trainer", "i/ec-1/minipc", "v1", 1),
            ],
        );
        let d = diff_plans(&old, &new);
        assert_eq!(d.unchanged.len(), 1);
        assert_eq!(d.add.len(), 1);
        assert!(d.remove.is_empty() && d.replace.is_empty());
        assert_eq!(d.touched_nodes().len(), 1);
        // and scale-in reverses to one remove
        let d = diff_plans(&new, &old);
        assert_eq!(d.unchanged.len(), 1);
        assert_eq!(d.remove.len(), 1);
        assert!(d.add.is_empty() && d.replace.is_empty());
    }

    #[test]
    fn image_bump_on_one_of_two_colocated_instances() {
        let old = plan(
            1,
            vec![
                inst_n("w", "i/ec-1/minipc", "v1", 0),
                inst_n("w", "i/ec-1/minipc", "v1", 1),
            ],
        );
        let new = plan(
            2,
            vec![
                inst_n("w", "i/ec-1/minipc", "v1", 0),
                inst_n("w", "i/ec-1/minipc", "v2", 1),
            ],
        );
        let d = diff_plans(&old, &new);
        assert_eq!(d.unchanged.len(), 1, "the image-stable instance stays");
        assert_eq!(d.replace.len(), 1, "the bumped one redeploys in place");
        assert_eq!(d.replace[0].image, "v2");
        assert!(d.add.is_empty() && d.remove.is_empty());
    }
}
