//! Minimal JSON value + parser + serializer.
//!
//! serde is unavailable offline (DESIGN.md §Substitutions); this module
//! covers everything ACE needs: the artifact `manifest.json`, golden
//! files, the API server's wire format, and metric dumps. Full RFC 8259
//! syntax is supported (strings with escapes incl. `\uXXXX`, numbers,
//! nested containers); serialization escapes control characters.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `v.get("models").get("eoc")`-style chaining.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", to_string(self))
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(format!("expected literal '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or(ParseError { pos: self.pos, msg: "bad surrogate".into() })?,
                            );
                        } else {
                            out.push(char::from_u32(cp).ok_or(ParseError {
                                pos: self.pos,
                                msg: "bad codepoint".into(),
                            })?);
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > self.b.len() {
                        return self.err("truncated utf8");
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| ParseError { pos: start, msg: "bad utf8".into() })?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or(ParseError { pos: self.pos, msg: "eof in \\u".into() })?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return self.err("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError { pos: start, msg: format!("bad number '{s}'") })
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => esc(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, false], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(1).get("b").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(2).as_bool(), Some(false));
        assert_eq!(*v.get("c"), Value::Null);
        assert_eq!(*v.get("missing"), Value::Null);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        // raw multi-byte passthrough
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-7}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn escapes_in_output() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}
