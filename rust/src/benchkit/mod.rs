//! Shared micro-benchmark measurements.
//!
//! criterion is unavailable offline (DESIGN.md §Substitutions), so the
//! repo's benches are plain `main()` programs. The measurement bodies
//! live here so `benches/*.rs` and the `ace bench --json` CLI (the
//! machine-readable `BENCH_*.json` perf trajectory CI emits) run the
//! SAME code — a bench number and a CI number are never two different
//! experiments.
//!
//! Everything here measures the PR-3 hot paths: typed by-value DES
//! events vs the boxed closure lane, trie match collection with vs
//! without a reused scratch buffer, and the end-to-end 10k-component
//! fabric publish storm (DESIGN.md §Event-engine) — plus, since PR 4,
//! the THREADED plane's broker (publish/deliver throughput and
//! filter-directed retained replay), so `BENCH_*.json` covers both
//! planes, and, since PR 7, the chaos-ready control plane's full
//! deploy → fail → rejoin cycle under seeded message loss
//! (`churn_convergence`). The sharded broker adds a MULTI-producer
//! row (`broker_contention`): N threads publishing disjoint topic
//! spaces, which the per-first-level shard locks let scale where the
//! old single `Mutex<Inner>` serialized everything. The same object
//! carries `serve_rtt_per_sec` — publish round-trips through the
//! pooled `ace serve` TCP front end ([`serve_rtt`]).

use crate::des::queue::{CalendarQueue, EventQueue, HeapQueue};
use crate::des::{Scheduler, SimEvent};
use crate::json::Value;
use crate::pubsub::Broker;
use crate::pubsub::topic::{SymbolTable, TopicTrie};
use crate::simnet::{NetConfig, NetFabric, NicSpec};
use crate::svcgraph::{ClusterRef, Component, Ctx, GraphMsg, GraphRuntime, Site};
use crate::util::prng::Stream;
use crate::util::SimTime;
use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// DES engine: typed lane vs boxed closure lane
// ---------------------------------------------------------------------------

/// Minimal typed event for the engine benches — the same two patterns
/// the closure lane runs, but by value (no `Box` per event).
pub enum TickEvent {
    /// Self-rescheduling tick (the sampling-tick pattern).
    Tick { period: SimTime },
    /// One-shot counter bump (the transfer-completion pattern).
    Once,
}

impl SimEvent<u64> for TickEvent {
    fn fire(self, sch: &mut Scheduler<u64, TickEvent>, w: &mut u64) {
        match self {
            TickEvent::Tick { period } => {
                *w += 1;
                sch.push_after(period, TickEvent::Tick { period });
            }
            TickEvent::Once => *w += 1,
        }
    }
}

/// Events/second for each (lane, pattern) combination.
pub struct DesNumbers {
    pub events: u64,
    pub typed_chain_eps: f64,
    pub boxed_chain_eps: f64,
    pub typed_heap_eps: f64,
    pub boxed_heap_eps: f64,
}

pub fn des_throughput(events: u64) -> DesNumbers {
    // chained ticks, typed lane
    let typed_chain_eps = {
        let mut sched: Scheduler<u64, TickEvent> = Scheduler::new();
        let mut world = 0u64;
        sched.push_after(1, TickEvent::Tick { period: 10 });
        let t0 = Instant::now();
        sched.run(&mut world, events);
        events as f64 / t0.elapsed().as_secs_f64()
    };
    // chained ticks, boxed closure lane. The closure CAPTURES its
    // period (like the pre-PR-3 svcgraph closures captured a
    // GraphMsg/target): boxing a capturing closure allocates per
    // event, whereas a non-capturing closure or fn item is a ZST and
    // `Box::new` would never touch the allocator — a baseline that
    // would measure only dispatch, not the allocation under test.
    let boxed_chain_eps = {
        fn schedule_tick(sc: &mut Scheduler<u64>, period: SimTime) {
            sc.after(period, move |sc, w: &mut u64| {
                *w += 1;
                schedule_tick(sc, period);
            });
        }
        let mut sched: Scheduler<u64> = Scheduler::new();
        let mut world = 0u64;
        schedule_tick(&mut sched, 10);
        let t0 = Instant::now();
        sched.run(&mut world, events);
        events as f64 / t0.elapsed().as_secs_f64()
    };
    // pre-seeded random heap, typed lane
    let typed_heap_eps = {
        let mut sched: Scheduler<u64, TickEvent> = Scheduler::new();
        let mut world = 0u64;
        let mut s = Stream::new(7);
        for _ in 0..events {
            let at = s.next_range(0, 1_000_000_000) as u64;
            sched.push_at(at, TickEvent::Once);
        }
        let t0 = Instant::now();
        sched.run(&mut world, events + 1);
        events as f64 / t0.elapsed().as_secs_f64()
    };
    // pre-seeded random heap, boxed closure lane (capturing closure —
    // see the chained-ticks note; `inc` makes each box a real
    // per-event allocation)
    let boxed_heap_eps = {
        let mut sched: Scheduler<u64> = Scheduler::new();
        let mut world = 0u64;
        let mut s = Stream::new(7);
        for _ in 0..events {
            let at = s.next_range(0, 1_000_000_000) as u64;
            // a captured u64 is part of the closure's layout, so each
            // Box::new is a real 8-byte allocation
            let inc = 1u64;
            sched.at(at, move |_, w: &mut u64| *w += inc);
        }
        let t0 = Instant::now();
        sched.run(&mut world, events + 1);
        events as f64 / t0.elapsed().as_secs_f64()
    };
    DesNumbers {
        events,
        typed_chain_eps,
        boxed_chain_eps,
        typed_heap_eps,
        boxed_heap_eps,
    }
}

/// Events/second for the timer-dense heartbeat workload on each queue
/// backend (PR 6): the calendar queue's O(1) amortized push/pop vs the
/// binary heap's O(log n) sift with `timers` concurrent periodic
/// timers resident.
pub struct TimerStormNumbers {
    pub timers: usize,
    pub events: u64,
    pub wheel_events_per_sec: f64,
    pub heap_events_per_sec: f64,
}

fn timer_storm_eps<Q: EventQueue<u64>>(timers: usize, period: SimTime, events: u64) -> f64 {
    let mut q = Q::default();
    let mut seq = 0u64;
    // phases spread uniformly over one period, like real heartbeats
    for i in 0..timers {
        q.push(i as SimTime * period / timers as SimTime, seq, i as u64);
        seq += 1;
    }
    let t0 = Instant::now();
    for _ in 0..events {
        let (at, _, id) = q.pop().expect("storm queue never drains");
        q.push(at + period, seq, id);
        seq += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(q.len(), timers, "pop/re-push must conserve the timer population");
    events as f64 / dt
}

/// The `des_timer_storm` bench: `timers` concurrent 0.1 s heartbeat
/// timers (well inside the wheel horizon), each pop immediately
/// re-arming — the steady-state lifecycle/heartbeat shape of the ACE
/// control plane. Runs the SAME workload on both queue backends so the
/// ratio is backend cost alone.
pub fn des_timer_storm(timers: usize, events: u64) -> TimerStormNumbers {
    const PERIOD: SimTime = 100_000; // 0.1 s
    TimerStormNumbers {
        timers,
        events,
        wheel_events_per_sec: timer_storm_eps::<CalendarQueue<u64>>(timers, PERIOD, events),
        heap_events_per_sec: timer_storm_eps::<HeapQueue<u64>>(timers, PERIOD, events),
    }
}

// ---------------------------------------------------------------------------
// topic corpora + trie match collection with vs without scratch reuse
// ---------------------------------------------------------------------------

/// Wildcard-heavy filter table: ~60% exact, ~20% `+`, ~20% `#`,
/// spread over `groups` topic groups (tenants/apps).
pub fn make_filters(n: usize, groups: usize, s: &mut Stream) -> Vec<String> {
    (0..n)
        .map(|i| {
            let g = i % groups;
            let t = s.next_range(0, 50);
            match s.next_range(0, 10) {
                0 | 1 => format!("app/g{g}/#"),
                2 => format!("app/+/t{t}/data"),
                3 => format!("app/g{g}/+/data"),
                _ => format!("app/g{g}/t{t}/data"),
            }
        })
        .collect()
}

pub fn make_names(n: usize, groups: usize, s: &mut Stream) -> Vec<String> {
    (0..n)
        .map(|_| {
            let g = s.next_range(0, groups as i64);
            let t = s.next_range(0, 50);
            format!("app/g{g}/t{t}/data")
        })
        .collect()
}

/// Publishes/second through `collect_matches` (fresh `Vec` per call)
/// vs `collect_matches_into` (one reused scratch buffer) — the
/// `Fabric::route` allocation ablation.
pub struct RouteNumbers {
    pub subs: usize,
    pub pubs: usize,
    pub hits: usize,
    pub alloc_pubs_per_s: f64,
    pub scratch_pubs_per_s: f64,
}

pub fn route_scratch(n_subs: usize, n_pubs: usize) -> RouteNumbers {
    let groups = 64;
    let mut s = Stream::new(7);
    let filters = make_filters(n_subs, groups, &mut s);
    let names = make_names(n_pubs, groups, &mut s);
    let mut table = SymbolTable::new();
    let mut trie = TopicTrie::new();
    for (i, f) in filters.iter().enumerate() {
        trie.insert(&mut table, f, i);
    }

    // untimed warm-up over the full corpus so the first TIMED loop is
    // not additionally paying to fault the trie into cache (both timed
    // loops then see the same warmed state)
    let mut warm_hits = 0usize;
    for name in &names {
        warm_hits += trie.collect_matches(&table, name).len();
    }

    let t0 = Instant::now();
    let mut alloc_hits = 0usize;
    for name in &names {
        alloc_hits += trie.collect_matches(&table, name).len();
    }
    let alloc_s = t0.elapsed().as_secs_f64();

    let mut scratch: Vec<(u64, usize)> = Vec::new();
    let t0 = Instant::now();
    let mut scratch_hits = 0usize;
    for name in &names {
        trie.collect_matches_into(&table, name, &mut scratch);
        scratch_hits += scratch.len();
    }
    let scratch_s = t0.elapsed().as_secs_f64();

    assert_eq!(warm_hits, alloc_hits, "warm-up and timed passes must agree");
    assert_eq!(alloc_hits, scratch_hits, "scratch path must agree with the allocating path");
    RouteNumbers {
        subs: n_subs,
        pubs: n_pubs,
        hits: alloc_hits,
        alloc_pubs_per_s: n_pubs as f64 / alloc_s,
        scratch_pubs_per_s: n_pubs as f64 / scratch_s,
    }
}

// ---------------------------------------------------------------------------
// threaded broker: publish/deliver throughput + retained replay
// ---------------------------------------------------------------------------

/// Broker-side numbers (the threaded control plane), so the perf
/// trajectory covers both planes: trie-routed publish throughput with
/// a wildcard-heavy subscription table, and filter-directed
/// retained-message replay on subscribe.
pub struct BrokerNumbers {
    pub subs: usize,
    pub pubs: usize,
    /// Deliveries performed by the publish pass (from broker stats).
    pub delivered: u64,
    pub publish_per_s: f64,
    pub deliver_per_s: f64,
    /// Retained publishes stored before the replay pass (distinct
    /// topics may be fewer: last-writer-wins).
    pub retained_topics: usize,
    /// Wildcard subscribes timed against the retained trie.
    pub replay_subscribes: usize,
    /// Messages replayed to those subscribers.
    pub replayed: u64,
    pub replay_subscribes_per_s: f64,
}

/// Measure the threaded `pubsub::Broker`: `n_subs` subscriptions from
/// the shared wildcard-heavy corpus, `n_pubs` publishes through the
/// trie router, then `replay_subscribes` wildcard subscribes against
/// `retained_topics` retained messages (the name-keyed retained trie's
/// filter-directed replay).
pub fn broker_throughput(
    n_subs: usize,
    n_pubs: usize,
    retained_topics: usize,
    replay_subscribes: usize,
) -> BrokerNumbers {
    let groups = 64;
    let mut s = Stream::new(13);

    // publish/deliver throughput
    let b = Broker::new("bench");
    let filters = make_filters(n_subs, groups, &mut s);
    let mut handles = Vec::with_capacity(filters.len());
    for f in &filters {
        handles.push(b.subscribe(f).expect("bench filter"));
    }
    let names = make_names(n_pubs, groups, &mut s);
    let payload = vec![0u8; 64];
    let t0 = Instant::now();
    for name in &names {
        b.publish(name, payload.clone()).expect("bench publish");
    }
    let pub_secs = t0.elapsed().as_secs_f64();
    let delivered = b.stats().deliver_count;
    assert!(delivered > 0, "publish storm must reach subscribers");
    drop(handles);

    // retained replay: R retained names, K filter-directed subscribes
    let br = Broker::new("bench-retained");
    let rnames = make_names(retained_topics, groups, &mut s);
    for (i, name) in rnames.iter().enumerate() {
        br.publish_retained(name, vec![(i & 0xff) as u8])
            .expect("bench retain");
    }
    let mut replayed = 0u64;
    let t0 = Instant::now();
    for k in 0..replay_subscribes {
        // group-scoped wildcard: replays only that group's trie paths
        let sub = br
            .subscribe(&format!("app/g{}/#", k % groups))
            .expect("bench replay filter");
        while sub.rx.try_recv().is_ok() {
            replayed += 1;
        }
        br.unsubscribe(sub.id);
    }
    let replay_secs = t0.elapsed().as_secs_f64();
    assert!(replayed > 0, "retained replay must deliver");

    BrokerNumbers {
        subs: n_subs,
        pubs: n_pubs,
        delivered,
        publish_per_s: n_pubs as f64 / pub_secs,
        deliver_per_s: delivered as f64 / pub_secs,
        retained_topics,
        replay_subscribes,
        replayed,
        replay_subscribes_per_s: replay_subscribes as f64 / replay_secs,
    }
}

// ---------------------------------------------------------------------------
// threaded broker: multi-producer contention (the sharded lock story)
// ---------------------------------------------------------------------------

/// One producer-count measurement from [`broker_contention`].
#[derive(Debug, Clone)]
pub struct ContentionRow {
    pub producers: usize,
    /// Total publishes across all producers in this row.
    pub pubs: u64,
    /// Aggregate publish rate across all producers.
    pub publishes_per_sec: f64,
}

/// The multi-producer broker numbers (`BENCH_*.json` →
/// `broker_contention`). The single-threaded `broker` rows cannot show
/// the lock: this one publishes from N threads into N disjoint
/// first-level topic spaces ("lanes"), which the sharded broker routes
/// under N independent locks. CI asserts the multi-producer aggregate
/// rate beats the single-producer rate (the old single-mutex broker
/// could only LOSE throughput with more producers).
#[derive(Debug, Clone)]
pub struct ContentionNumbers {
    pub shards: usize,
    pub lanes: usize,
    pub pubs_per_producer: usize,
    /// Producer count of the gated row (the last in `rows`).
    pub producers: usize,
    /// Gated metric: aggregate rate with `producers` producers.
    pub publishes_per_sec: f64,
    /// The 1-producer reference rate over the SAME workload shape.
    pub single_producer_per_sec: f64,
    pub rows: Vec<ContentionRow>,
}

/// Measure aggregate publish throughput at 1 and `producers` producer
/// threads. Every lane has one `lane{i}/#` subscriber whose receiver a
/// dedicated drainer thread empties (deliveries are part of the
/// measured publish path, exactly as in the single-threaded `broker`
/// row). Producers own disjoint lane sets, so with N producers the
/// sharded broker takes N independent locks; the 1-producer row walks
/// ALL lanes round-robin so the workload shape (topics, fan-out,
/// payload) is identical. Delivery completeness is asserted, not
/// assumed: drained messages must equal published messages.
pub fn broker_contention(producers: usize, pubs_per_producer: usize) -> ContentionNumbers {
    use std::sync::Barrier;
    let producers = producers.max(2);
    let lanes = producers;
    let shards = 16;
    let b = Broker::with_shards("contention", shards);

    let mut drainers = Vec::new();
    let mut sub_ids = Vec::new();
    for lane in 0..lanes {
        let sub = b.subscribe(&format!("lane{lane}/#")).expect("bench filter");
        sub_ids.push(sub.id);
        let rx = sub.rx;
        drainers.push(std::thread::spawn(move || {
            let mut n = 0u64;
            while rx.recv().is_ok() {
                n += 1;
            }
            n
        }));
    }

    let run = |n_producers: usize| -> ContentionRow {
        let barrier = std::sync::Arc::new(Barrier::new(n_producers + 1));
        let mut joins = Vec::new();
        for p in 0..n_producers {
            let b = b.clone();
            let barrier = barrier.clone();
            // disjoint lane ownership: producer p gets lanes p, p+N, ...
            let my_lanes: Vec<usize> = (0..lanes).filter(|l| l % n_producers == p).collect();
            joins.push(std::thread::spawn(move || {
                // pre-build topics so the measured loop is publish cost,
                // not format! cost (identical across rows)
                let topics: Vec<String> = (0..pubs_per_producer)
                    .map(|i| format!("lane{}/t{}/data", my_lanes[i % my_lanes.len()], i % 32))
                    .collect();
                let payload = vec![0u8; 64];
                barrier.wait();
                for t in &topics {
                    b.publish(t, payload.clone()).expect("bench publish");
                }
            }));
        }
        barrier.wait();
        let t0 = Instant::now();
        for j in joins {
            j.join().expect("producer thread");
        }
        let dt = t0.elapsed().as_secs_f64();
        let pubs = (n_producers * pubs_per_producer) as u64;
        ContentionRow {
            producers: n_producers,
            pubs,
            publishes_per_sec: pubs as f64 / dt,
        }
    };

    // untimed warm-up (page faults, lazy shard init), then the rows
    run(producers);
    let rows = vec![run(1), run(producers)];

    // each publish matches exactly its lane's one subscriber: drained
    // must equal published (no lost or duplicated deliveries)
    // warm-up row (N producers) + measured rows (1 and N producers)
    let expected: u64 = (2 * producers + 1) as u64 * pubs_per_producer as u64;
    for id in sub_ids {
        b.unsubscribe(id);
    }
    drop(b);
    let drained: u64 = drainers.into_iter().map(|d| d.join().expect("drainer")).sum();
    assert_eq!(
        drained, expected,
        "every publish (warm-up + rows) must be delivered exactly once"
    );

    ContentionNumbers {
        shards,
        lanes,
        pubs_per_producer,
        producers,
        publishes_per_sec: rows[1].publishes_per_sec,
        single_producer_per_sec: rows[0].publishes_per_sec,
        rows,
    }
}

// ---------------------------------------------------------------------------
// serve front end: publish round-trip rate
// ---------------------------------------------------------------------------

/// The serve-engine row (`BENCH_*.json` → `broker_contention` →
/// `serve_rtt_per_sec`): publish → `publish_ok` round-trips per second
/// for one client against an in-process `serve::Server` on a real TCP
/// loopback socket. This is the end-to-end path a connected client
/// pays — frame codec, poll loop, worker pool, broker dispatch,
/// response queue — so a regression here catches engine overhead the
/// raw broker rows cannot see.
#[derive(Debug, Clone)]
pub struct ServeRttNumbers {
    pub pubs: usize,
    /// Gated metric: publish round-trips per second.
    pub rtt_per_sec: f64,
}

/// Measure `pubs` publish round-trips against an ephemeral loopback
/// server, then shut it down cleanly (the `shutdown` op, so the bench
/// also exercises the drain-and-join path every run).
pub fn serve_rtt(pubs: usize) -> ServeRttNumbers {
    use crate::serve::{client::Client, ServeConfig, Server};
    let cfg = ServeConfig {
        shards: 4,
        broker_name: "bench".into(),
        ..ServeConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", &cfg).expect("bench serve bind");
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run().expect("bench serve run"));
    let mut c = Client::connect(&addr).open().expect("bench serve connect");
    // pre-build topics so the measured loop is round-trip cost, not
    // format! cost; a warm-up burst absorbs lazy shard/pool init
    let topics: Vec<String> = (0..32).map(|i| format!("bench/t{i}/data")).collect();
    let payload = vec![0u8; 64];
    for t in &topics {
        c.publish(t, &payload, false).expect("bench warm-up publish");
    }
    let t0 = Instant::now();
    for i in 0..pubs {
        c.publish(&topics[i % topics.len()], &payload, false).expect("bench publish");
    }
    let dt = t0.elapsed().as_secs_f64();
    c.shutdown().expect("bench serve shutdown");
    handle.join().expect("bench serve thread");
    ServeRttNumbers {
        pubs,
        rtt_per_sec: pubs as f64 / dt.max(1e-9),
    }
}

// ---------------------------------------------------------------------------
// end-to-end fabric publish storm
// ---------------------------------------------------------------------------

/// Sink component: counts deliveries.
struct Sink {
    filters: Vec<String>,
    hits: Rc<Cell<u64>>,
}

impl Component for Sink {
    fn subscriptions(&self) -> Vec<String> {
        self.filters.clone()
    }
    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {
        self.hits.set(self.hits.get() + 1);
    }
}

/// Publisher component: one publish per timer tick until done.
struct Blaster {
    topics: Vec<String>,
    i: usize,
}

impl Component for Blaster {
    fn subscriptions(&self) -> Vec<String> {
        Vec::new()
    }
    fn on_start(&mut self, ctx: &mut Ctx) {
        ctx.set_timer(1, 0);
    }
    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        if self.i >= self.topics.len() {
            return;
        }
        let t = self.topics[self.i].clone();
        self.i += 1;
        ctx.publish(&t, 256, Rc::new(()));
        ctx.set_timer(1, 0);
    }
}

/// Publisher that republishes ONE topic with ONE shared body forever
/// (timer-paced) — nothing app-owned allocates per publish, so an
/// allocation-counting harness can isolate the fabric's own cost.
struct Repeater {
    topic: String,
    body: Rc<()>,
}

impl Component for Repeater {
    fn subscriptions(&self) -> Vec<String> {
        Vec::new()
    }
    fn on_start(&mut self, ctx: &mut Ctx) {
        ctx.set_timer(50, 0);
    }
    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        // &str from the stored String, Rc bump for the body: the
        // publish itself is the only machinery under test
        ctx.publish(&self.topic, 8, self.body.clone());
        ctx.set_timer(50, 0);
    }
}

/// A runtime exercising EVERY steady-state hot-path arm, forever
/// (timer-paced, one publish per topic per 50 µs): an EC-local topic
/// fanning out to `n_sinks` subscribers over 4 EC nodes (same-node
/// hand-offs + LAN-charged hops) AND a `cloud/...` topic riding the
/// `Event::Bridge` arm over the WAN uplink to a CC subscriber. Drive
/// it with `run_until` windows: warm one window (interner, scratch,
/// heap capacity), then assert the next window performs ZERO heap
/// allocations — the `tests/zero_alloc.rs` enforcement of DESIGN.md
/// §Event-engine's allocation budget, bridge-forwarding row included.
/// Returns the runtime and the delivery counter.
pub fn steady_state_runtime(n_sinks: usize) -> (GraphRuntime, Rc<Cell<u64>>) {
    let mut rt = GraphRuntime::new(NetFabric::new(&NetConfig {
        num_ecs: 1,
        ..Default::default()
    }));
    let hits = Rc::new(Cell::new(0u64));
    for i in 0..n_sinks {
        rt.add(
            Site { cluster: ClusterRef::Ec(0), node: format!("node{}", i % 4).into() },
            Box::new(Sink { filters: vec!["app/steady/data".into()], hits: hits.clone() }),
        );
    }
    rt.add(
        Site { cluster: ClusterRef::Cc, node: "gpu-ws".into() },
        Box::new(Sink { filters: vec!["cloud/steady/data".into()], hits: hits.clone() }),
    );
    rt.add(
        Site { cluster: ClusterRef::Ec(0), node: "node0".into() },
        Box::new(Repeater { topic: "app/steady/data".into(), body: Rc::new(()) }),
    );
    rt.add(
        Site { cluster: ClusterRef::Ec(0), node: "node0".into() },
        Box::new(Repeater { topic: "cloud/steady/data".into(), body: Rc::new(()) }),
    );
    (rt, hits)
}

pub struct StormNumbers {
    pub components: usize,
    pub publishes: usize,
    pub deliveries: u64,
    pub des_events: u64,
    pub pubs_per_s: f64,
}

/// End-to-end: `n_comps` components subscribed on a 4-EC fabric, one
/// publisher per EC blasting timer-paced publishes through the
/// zero-allocation `Fabric::route` path (typed events, interned
/// topics, scratch reuse).
pub fn fabric_storm(n_comps: usize, pubs_per_ec: usize) -> StormNumbers {
    let num_ecs = 4;
    let groups = 64;
    let mut s = Stream::new(11);
    let mut rt = GraphRuntime::new(NetFabric::new(&NetConfig {
        num_ecs,
        ..Default::default()
    }));
    let hits = Rc::new(Cell::new(0u64));
    let filters = make_filters(n_comps, groups, &mut s);
    for (i, f) in filters.into_iter().enumerate() {
        let ec = i % num_ecs;
        rt.add(
            Site { cluster: ClusterRef::Ec(ec), node: format!("node{}", i % 7).into() },
            Box::new(Sink { filters: vec![f], hits: hits.clone() }),
        );
    }
    let mut total_pubs = 0usize;
    for ec in 0..num_ecs {
        let topics = make_names(pubs_per_ec, groups, &mut s);
        total_pubs += topics.len();
        rt.add(
            Site { cluster: ClusterRef::Ec(ec), node: "pub".into() },
            Box::new(Blaster { topics, i: 0 }),
        );
    }
    let t0 = Instant::now();
    rt.run(u64::MAX);
    let dt = t0.elapsed().as_secs_f64();
    assert!(hits.get() > 0, "storm must reach subscribers");
    StormNumbers {
        components: n_comps,
        publishes: total_pubs,
        deliveries: hits.get(),
        des_events: rt.executed(),
        pubs_per_s: total_pubs as f64 / dt,
    }
}

// ---------------------------------------------------------------------------
// hop-charged routing: flat degenerate fabric vs per-node link graph
// ---------------------------------------------------------------------------

pub struct HopNumbers {
    pub pubs: usize,
    pub sinks: usize,
    /// Deliveries on each fabric (must agree: the NIC legs change
    /// arrival TIMES and counters, never who receives what).
    pub deliveries: u64,
    pub flat_pubs_per_s: f64,
    pub hop_pubs_per_s: f64,
}

/// Same cross-node publish storm on two fabrics: the degenerate flat
/// model (no NICs) vs a per-node link graph where EVERY node has a
/// shaped access link — so each delivery pays src NIC → LAN → dst NIC
/// instead of one LAN send. The ratio is the hop-charging overhead of
/// the PR-5 `NetFabric` on the routing hot path.
pub fn netfabric_hops(n_pubs: usize, n_sinks: usize) -> HopNumbers {
    let run = |nics: Vec<NicSpec>| -> (u64, f64) {
        let mut rt = GraphRuntime::new(NetFabric::new(&NetConfig {
            num_ecs: 1,
            nics,
            ..Default::default()
        }));
        let hits = Rc::new(Cell::new(0u64));
        for i in 0..n_sinks {
            rt.add(
                Site { cluster: ClusterRef::Ec(0), node: format!("node{}", i % 4).into() },
                Box::new(Sink { filters: vec!["hop/data".into()], hits: hits.clone() }),
            );
        }
        rt.add(
            Site { cluster: ClusterRef::Ec(0), node: "node0".into() },
            Box::new(Blaster {
                topics: (0..n_pubs).map(|_| "hop/data".to_string()).collect(),
                i: 0,
            }),
        );
        let t0 = Instant::now();
        rt.run(u64::MAX);
        (hits.get(), t0.elapsed().as_secs_f64())
    };
    let (flat_deliveries, flat_s) = run(Vec::new());
    let shaped: Vec<NicSpec> = (0..4)
        .map(|i| NicSpec {
            cluster: "ec-1".into(),
            node: format!("node{i}"),
            mbps: 1000.0,
            delay_us: 10.0,
        })
        .collect();
    let (hop_deliveries, hop_s) = run(shaped);
    assert_eq!(
        flat_deliveries, hop_deliveries,
        "hop charging must not change who receives what"
    );
    assert!(flat_deliveries > 0, "hop storm must reach subscribers");
    HopNumbers {
        pubs: n_pubs,
        sinks: n_sinks,
        deliveries: flat_deliveries,
        flat_pubs_per_s: n_pubs as f64 / flat_s,
        hop_pubs_per_s: n_pubs as f64 / hop_s,
    }
}

// ---------------------------------------------------------------------------
// churn convergence: fail -> rejoin under instruction loss (PR 7)
// ---------------------------------------------------------------------------

/// Control-plane churn numbers: how fast the simulator replays a full
/// deploy → fail-node → rejoin cycle with the at-least-once channel
/// retrying under seeded message loss, plus the chaos metrics the
/// cycle produced (identical on every run — the fault processes are
/// seeded, so only the wall-clock rate varies).
pub struct ChurnNumbers {
    pub nodes: usize,
    pub loss: f64,
    pub runs: u64,
    /// Full chaos cycles (60 virtual seconds each) per wall second —
    /// the gated throughput row.
    pub runs_per_sec: f64,
    /// Worst virtual-time fault→all-acked convergence across the run
    /// (informational: loss/seed-dependent, not a throughput).
    pub convergence_ms: f64,
    /// Instruction retries one cycle needed under `loss`.
    pub retries: u64,
    /// Messages the fault plane dropped in one cycle.
    pub msgs_lost: u64,
}

/// Benchmark the chaos-ready control plane end to end: a platform-only
/// world (null instance factory — every wire message is an
/// instruction, heartbeat, or ack) of 2 ECs x `nodes` mini-PC nodes
/// runs deploy → fail-node → rejoin under `loss` i.i.d. message loss,
/// exercising the seq-stamped instruction path, agent acks, the
/// capped-backoff retry timer, and the monitor sweep. Seeded: every
/// cycle replays the identical trajectory, so the timed loop measures
/// engine cost, not chaos variance.
pub fn churn_convergence(nodes: usize, loss: f64, runs: u64) -> ChurnNumbers {
    use crate::infra::{InfraBuilder, NodeKind};
    use crate::platform::orchestrator::NetHints;
    use crate::simnet::faults::FaultSpec;
    use crate::svcgraph::lifecycle::{
        ControlPlane, ControlPlaneConfig, InstanceFactory, LifecycleOp, LifecycleReport,
        LifecycleScenario, ScenarioStep,
    };
    use crate::topology::Topology;
    use crate::util::{secs, AceId};

    let topo_src = format!(
        "
app: churn
version: 1
components:
  - name: w
    image: img:1
    location: edge
    replicas: {}
    resources:
      cpu: 500
      mem: 128
    connections: []
",
        2 * nodes
    );
    let cycle = |seed: u64| -> LifecycleReport {
        let mut net = NetFabric::new(&NetConfig { num_ecs: 2, ..Default::default() });
        if loss > 0.0 {
            net.arm_faults(FaultSpec { seed, loss, dup: 0.0 });
        }
        let hints = NetHints::from_net(&net);
        let mut rt = GraphRuntime::new(net);
        let mut b = InfraBuilder::register("churnbench");
        for _ in 0..2 {
            let ec = b.claim_ec();
            for j in 0..nodes {
                b.add_edge_node(&ec, &format!("n{j}"), NodeKind::MiniPc, Default::default());
            }
        }
        b.add_cloud_node("gpu-ws", NodeKind::GpuWorkstation, Default::default());
        let infra = b.build();
        let factory: InstanceFactory = Rc::new(|_inst, _site| Ok(None));
        let node = AceId::parse("infra-churnbench/ec-1/n0");
        let scenario = LifecycleScenario {
            steps: vec![
                ScenarioStep {
                    at: secs(0.0),
                    op: LifecycleOp::Deploy(Topology::parse(&topo_src).expect("bench topology")),
                },
                ScenarioStep { at: secs(10.0), op: LifecycleOp::FailNode(node.clone()) },
                ScenarioStep { at: secs(30.0), op: LifecycleOp::RejoinNode(node.clone()) },
            ],
            duration: secs(60.0),
            network: None,
            faults: None, // armed directly on the fabric above
        };
        // long failure timeout vs the heartbeat, as in the property
        // test: only the scripted node ever gets shielded
        let cfg = ControlPlaneConfig {
            heartbeat_period_s: 1.0,
            failure_timeout_s: 12.0,
            sweep_period_s: 4.0,
            ..Default::default()
        };
        let plane = ControlPlane::install(&mut rt, infra, factory, None, &scenario, cfg, hints)
            .expect("bench control plane");
        rt.run_until(scenario.duration);
        let mut report = plane.report();
        report.msgs_lost = rt.net().msgs_lost();
        report
    };

    // untimed warm-up cycle, which also supplies the chaos metrics
    // (identical on every timed cycle: same seed, same trajectory)
    let warm = cycle(7);
    assert!(
        !warm.convergence_us.is_empty(),
        "churn cycle must record a fault→all-acked convergence"
    );
    if loss > 0.0 {
        assert!(warm.retries > 0, "lossy churn cycle must exercise the retry path");
    }

    let t0 = Instant::now();
    for _ in 0..runs {
        cycle(7);
    }
    let dt = t0.elapsed().as_secs_f64();
    ChurnNumbers {
        nodes,
        loss,
        runs,
        runs_per_sec: runs as f64 / dt,
        convergence_ms: warm.max_convergence_ms(),
        retries: warm.retries,
        msgs_lost: warm.msgs_lost,
    }
}

/// One serial-or-parallel metro measurement (see [`metro_scale`]).
#[derive(Debug, Clone)]
pub struct MetroScaleRow {
    pub partitions: usize,
    pub threads: usize,
    /// DES events executed across all shards (identical app work per
    /// row — partitioning only changes which runtime executes it).
    pub events: u64,
    pub wall_secs: f64,
    pub events_per_sec: f64,
}

/// The serial-vs-parallel metro comparison (`BENCH_*.json` →
/// `metro_scale`).
#[derive(Debug, Clone)]
pub struct MetroScaleNumbers {
    pub ecs: usize,
    pub cams: usize,
    pub virtual_secs: f64,
    /// Row 0 is ALWAYS the serial reference (1 partition, 1 thread).
    pub rows: Vec<MetroScaleRow>,
    pub serial_events_per_sec: f64,
    /// Best parallel rate — the gated `metro_events_per_sec` number.
    pub best_events_per_sec: f64,
    pub best_partitions: usize,
}

/// Run the metro workload serially, then partitioned at each count in
/// `partition_counts` with one thread per partition, measuring
/// events/sec for the `metro_scale` row of `BENCH_*.json`. CI's bench
/// job asserts the parallel rate beats the serial one at >= 4
/// partitions (see `.github/workflows/ci.yml`).
pub fn metro_scale(cfg: &crate::app::MetroConfig, partition_counts: &[usize]) -> MetroScaleNumbers {
    let mut rows = Vec::new();
    let run = |partitions: usize, threads: usize| -> MetroScaleRow {
        let m = crate::app::run_metro(&crate::app::MetroConfig {
            partitions,
            threads,
            ..cfg.clone()
        });
        MetroScaleRow {
            partitions: m.partitions,
            threads: m.threads,
            events: m.events,
            wall_secs: m.wall_secs,
            events_per_sec: m.events_per_sec,
        }
    };
    // untimed warm-up so first-touch costs (thread pool, page faults)
    // don't land on the serial row
    run(1, 1);
    rows.push(run(1, 1));
    for &p in partition_counts {
        if p <= 1 {
            continue;
        }
        rows.push(run(p, p));
    }
    let serial = rows[0].events_per_sec;
    let best = rows[1..]
        .iter()
        .max_by(|a, b| a.events_per_sec.total_cmp(&b.events_per_sec))
        .cloned()
        .unwrap_or_else(|| rows[0].clone());
    MetroScaleNumbers {
        ecs: cfg.ecs,
        cams: cfg.cams(),
        virtual_secs: cfg.duration_s,
        serial_events_per_sec: serial,
        best_events_per_sec: best.events_per_sec,
        best_partitions: best.partitions,
        rows,
    }
}

// ---------------------------------------------------------------------------
// bench-regression gate (`ace bench --check BASELINE.json`)
// ---------------------------------------------------------------------------

/// The throughput metrics the regression gate compares, as
/// `(object, key)` paths into the `BENCH_*.json` record. All are
/// higher-is-better rates.
pub const CHECKED_METRICS: &[(&str, &str)] = &[
    ("des_events_per_sec", "typed_chain"),
    ("des_events_per_sec", "typed_heap"),
    ("des_timer_storm", "wheel_events_per_sec"),
    ("route_match_collection", "scratch_pubs_per_sec"),
    ("fabric_storm", "pubs_per_sec"),
    ("broker", "publish_per_sec"),
    ("broker", "deliver_per_sec"),
    ("broker", "replay_subscribes_per_sec"),
    ("broker_contention", "publishes_per_sec"),
    ("broker_contention", "serve_rtt_per_sec"),
    ("netfabric", "hop_pubs_per_sec"),
    ("churn_convergence", "runs_per_sec"),
    ("metro_scale", "metro_events_per_sec"),
];

/// Outcome of comparing a fresh bench record against a baseline.
#[derive(Debug, Default)]
pub struct BenchCheck {
    /// `(metric path, baseline, fresh)` for every compared metric.
    pub compared: Vec<(String, f64, f64)>,
    /// Metric paths the baseline had no number for (e.g. the committed
    /// placeholder records, or a baseline predating a new row).
    pub skipped: Vec<String>,
    /// Human-readable lines for metrics below `baseline * (1 - tol)`.
    pub regressions: Vec<String>,
}

/// Fold several `BENCH_*.json` records into one baseline value taking
/// the per-metric MEDIAN (lower-middle for even counts). This is what
/// CI gates against — a rolling window of recent successful runs —
/// because shared runners vary: a single fast-runner outlier must not
/// ratchet the floor up and fail every later median-runner run.
/// Records missing a metric simply don't vote on it; a metric nobody
/// has a number for stays absent (skipped by the check).
pub fn median_baseline(records: &[Value]) -> Value {
    use std::collections::BTreeMap;
    let mut objs: BTreeMap<String, Value> = BTreeMap::new();
    for (obj, key) in CHECKED_METRICS {
        let mut vals: Vec<f64> = records
            .iter()
            .filter_map(|r| r.get(obj).get(key).as_f64())
            .filter(|v| *v > 0.0)
            .collect();
        if vals.is_empty() {
            continue;
        }
        vals.sort_by(f64::total_cmp);
        let median = vals[(vals.len() - 1) / 2];
        let entry = objs
            .entry(obj.to_string())
            .or_insert_with(|| Value::Obj(Default::default()));
        if let Value::Obj(o) = entry {
            o.insert(key.to_string(), Value::Num(median));
        }
    }
    Value::Obj(objs)
}

/// Per-metric MAX of two baseline records — how the gate anchors the
/// rolling median against the committed NUMERIC floor
/// (`BENCH_FLOOR.json`): `max(rolling median, committed record)`. The
/// rolling window keeps the gate tolerant of runner noise; the floor
/// keeps a slow STREAK of runs from walking the baseline down until a
/// real regression passes vacuously. A metric absent from one record
/// takes the other's number; absent from both stays absent (skipped).
pub fn max_baseline(a: &Value, b: &Value) -> Value {
    use std::collections::BTreeMap;
    let mut objs: BTreeMap<String, Value> = BTreeMap::new();
    for (obj, key) in CHECKED_METRICS {
        let va = a.get(obj).get(key).as_f64().filter(|v| *v > 0.0);
        let vb = b.get(obj).get(key).as_f64().filter(|v| *v > 0.0);
        let merged = match (va, vb) {
            (Some(x), Some(y)) => x.max(y),
            (Some(x), None) | (None, Some(x)) => x,
            (None, None) => continue,
        };
        let entry = objs
            .entry(obj.to_string())
            .or_insert_with(|| Value::Obj(Default::default()));
        if let Value::Obj(o) = entry {
            o.insert(key.to_string(), Value::Num(merged));
        }
    }
    Value::Obj(objs)
}

/// Compare `fresh` against `baseline` (both `BENCH_*.json` values):
/// a metric regresses when it falls below `baseline * (1 - tolerance)`.
/// Metrics absent from the baseline are skipped, so a placeholder
/// baseline (no toolchain in the authoring container — numbers only
/// ever come from CI) passes vacuously until a numeric record lands.
pub fn check_regression(baseline: &Value, fresh: &Value, tolerance: f64) -> BenchCheck {
    let mut out = BenchCheck::default();
    for (obj, key) in CHECKED_METRICS {
        let path = format!("{obj}.{key}");
        let base = baseline.get(obj).get(key).as_f64();
        let Some(base) = base.filter(|b| *b > 0.0) else {
            out.skipped.push(path);
            continue;
        };
        let now = fresh.get(obj).get(key).as_f64().unwrap_or(0.0);
        let floor = base * (1.0 - tolerance);
        if now < floor {
            out.regressions.push(format!(
                "{path}: {now:.0}/s < floor {floor:.0}/s (baseline {base:.0}/s, tolerance {:.0}%)",
                tolerance * 100.0
            ));
        }
        out.compared.push((path, base, now));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(scale: f64) -> Value {
        Value::obj(vec![
            (
                "des_events_per_sec",
                Value::obj(vec![
                    ("typed_chain", Value::num(1_000_000.0 * scale)),
                    ("typed_heap", Value::num(800_000.0 * scale)),
                ]),
            ),
            (
                "des_timer_storm",
                Value::obj(vec![("wheel_events_per_sec", Value::num(2_000_000.0 * scale))]),
            ),
            (
                "route_match_collection",
                Value::obj(vec![("scratch_pubs_per_sec", Value::num(500_000.0 * scale))]),
            ),
            ("fabric_storm", Value::obj(vec![("pubs_per_sec", Value::num(50_000.0 * scale))])),
            (
                "broker",
                Value::obj(vec![
                    ("publish_per_sec", Value::num(200_000.0 * scale)),
                    ("deliver_per_sec", Value::num(900_000.0 * scale)),
                    ("replay_subscribes_per_sec", Value::num(30_000.0 * scale)),
                ]),
            ),
            (
                "broker_contention",
                Value::obj(vec![
                    ("publishes_per_sec", Value::num(400_000.0 * scale)),
                    ("serve_rtt_per_sec", Value::num(20_000.0 * scale)),
                ]),
            ),
            ("netfabric", Value::obj(vec![("hop_pubs_per_sec", Value::num(40_000.0 * scale))])),
            (
                "churn_convergence",
                Value::obj(vec![("runs_per_sec", Value::num(100.0 * scale))]),
            ),
            (
                "metro_scale",
                Value::obj(vec![("metro_events_per_sec", Value::num(900_000.0 * scale))]),
            ),
        ])
    }

    #[test]
    fn within_tolerance_passes() {
        // 20% down on a 25% tolerance: noisy but acceptable
        let check = check_regression(&record(1.0), &record(0.8), 0.25);
        assert!(check.regressions.is_empty(), "{:?}", check.regressions);
        assert_eq!(check.compared.len(), CHECKED_METRICS.len());
        assert!(check.skipped.is_empty());
        // and improvements are obviously fine
        assert!(check_regression(&record(1.0), &record(1.5), 0.25).regressions.is_empty());
    }

    #[test]
    fn injected_regression_fails() {
        // a >25% drop on every metric: the gate must name each one
        let check = check_regression(&record(1.0), &record(0.5), 0.25);
        assert_eq!(check.regressions.len(), CHECKED_METRICS.len());
        assert!(check.regressions[0].contains("typed_chain"), "{}", check.regressions[0]);
        // a single-metric regression is also caught
        let mut fresh = record(1.0);
        if let Value::Obj(o) = &mut fresh {
            o.insert(
                "netfabric".to_string(),
                Value::obj(vec![("hop_pubs_per_sec", Value::num(1_000.0))]),
            );
        }
        let check = check_regression(&record(1.0), &fresh, 0.25);
        assert_eq!(check.regressions.len(), 1);
        assert!(check.regressions[0].contains("netfabric.hop_pubs_per_sec"));
    }

    #[test]
    fn median_baseline_resists_a_single_outlier() {
        // window of 1.0x, 1.0x, 1.4x (a fast-runner fluke): the median
        // stays 1.0x, so a fresh 0.85x run passes a 25% gate instead
        // of being measured against the outlier
        let window = [record(1.0), record(1.4), record(1.0)];
        let base = median_baseline(&window);
        assert_eq!(
            base.get("des_events_per_sec").get("typed_chain").as_f64(),
            Some(1_000_000.0)
        );
        let check = check_regression(&base, &record(0.85), 0.25);
        assert!(check.regressions.is_empty(), "{:?}", check.regressions);
        // even count takes the lower middle (conservative floor)
        let base = median_baseline(&[record(1.0), record(1.4)]);
        assert_eq!(
            base.get("fabric_storm").get("pubs_per_sec").as_f64(),
            Some(50_000.0)
        );
        // records without a metric don't vote; all-placeholder windows
        // produce an empty baseline (vacuous check)
        let placeholder = Value::obj(vec![("status", Value::str("pending-ci-run"))]);
        let base = median_baseline(&[placeholder.clone(), record(2.0)]);
        assert_eq!(
            base.get("broker").get("publish_per_sec").as_f64(),
            Some(400_000.0),
            "the one numeric record decides"
        );
        let empty = median_baseline(&[placeholder]);
        assert!(check_regression(&empty, &record(1.0), 0.25).compared.is_empty());
    }

    #[test]
    fn max_baseline_anchors_the_rolling_median() {
        // a slow streak (0.6x median) cannot drag the gate below the
        // committed floor: the merged baseline keeps the floor's number
        let merged = max_baseline(&record(0.6), &record(1.0));
        assert_eq!(
            merged.get("des_timer_storm").get("wheel_events_per_sec").as_f64(),
            Some(2_000_000.0)
        );
        let check = check_regression(&merged, &record(0.5), 0.25);
        assert_eq!(check.regressions.len(), CHECKED_METRICS.len());
        // a placeholder floor contributes nothing: the rolling side
        // decides every metric
        let placeholder = Value::obj(vec![("status", Value::str("pending-ci-run"))]);
        let merged = max_baseline(&record(0.8), &placeholder);
        assert_eq!(
            merged.get("fabric_storm").get("pubs_per_sec").as_f64(),
            Some(40_000.0)
        );
        // and two placeholders merge to an empty (vacuous) baseline
        let empty = max_baseline(&placeholder, &placeholder);
        let check = check_regression(&empty, &record(1.0), 0.25);
        assert!(check.compared.is_empty());
        assert_eq!(check.skipped.len(), CHECKED_METRICS.len());
    }

    #[test]
    fn timer_storm_runs_both_backends_and_conserves_timers() {
        // small but real: 64 timers, 5k pops per backend (the per-pop
        // conservation assert lives inside timer_storm_eps)
        let n = des_timer_storm(64, 5_000);
        assert_eq!(n.timers, 64);
        assert_eq!(n.events, 5_000);
        assert!(n.wheel_events_per_sec > 0.0);
        assert!(n.heap_events_per_sec > 0.0);
    }

    #[test]
    fn metro_scale_measures_serial_and_parallel_rows() {
        let cfg = crate::app::MetroConfig {
            ecs: 2,
            nodes_per_ec: 1,
            cams_per_node: 1,
            duration_s: 2.0,
            ..Default::default()
        };
        let n = metro_scale(&cfg, &[1, 2]);
        assert_eq!(n.rows.len(), 2, "serial row + one parallel row");
        assert_eq!((n.rows[0].partitions, n.rows[0].threads), (1, 1));
        assert_eq!((n.rows[1].partitions, n.rows[1].threads), (2, 2));
        assert!(n.rows.iter().all(|r| r.events > 0 && r.events_per_sec > 0.0));
        assert!(n.serial_events_per_sec > 0.0 && n.best_events_per_sec > 0.0);
        assert_eq!(n.best_partitions, 2);
    }

    #[test]
    fn broker_contention_measures_both_rows_and_loses_nothing() {
        // tiny run: the delivery-completeness assertion inside
        // broker_contention is the real check here
        let n = broker_contention(2, 400);
        assert_eq!(n.lanes, 2);
        assert_eq!(n.rows.len(), 2);
        assert_eq!(n.rows[0].producers, 1);
        assert_eq!(n.rows[1].producers, 2);
        assert_eq!(n.rows[0].pubs, 400);
        assert_eq!(n.rows[1].pubs, 800);
        assert!(n.publishes_per_sec > 0.0 && n.single_producer_per_sec > 0.0);
    }

    #[test]
    fn churn_convergence_runs_a_lossy_cycle() {
        // small but real: 2 nodes per EC, one timed cycle at 20% loss
        // (the retry/convergence asserts live inside churn_convergence)
        let n = churn_convergence(2, 0.2, 1);
        assert_eq!(n.nodes, 2);
        assert_eq!(n.runs, 1);
        assert!(n.runs_per_sec > 0.0);
        assert!(n.convergence_ms > 0.0, "chaos cycle must converge in measurable time");
        assert!(n.retries > 0);
        assert!(n.msgs_lost > 0);
    }

    #[test]
    fn placeholder_baseline_skips_everything() {
        // the committed BENCH_*.json placeholders carry no numbers:
        // every metric is skipped, none compared, gate passes
        let placeholder = Value::obj(vec![("status", Value::str("pending-ci-run"))]);
        let check = check_regression(&placeholder, &record(1.0), 0.25);
        assert!(check.regressions.is_empty());
        assert!(check.compared.is_empty());
        assert_eq!(check.skipped.len(), CHECKED_METRICS.len());
    }
}
