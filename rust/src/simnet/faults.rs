//! Deterministic per-link fault processes (PR 7).
//!
//! ACE's operational claim (§4.2) is that the PLATFORM absorbs
//! infrastructure dynamics; to test that, the simulation must be able
//! to make messages disappear. This module gives every named link in
//! the [`NetFabric`](super::NetFabric) an optional [`FaultProcess`]:
//! i.i.d. message loss, i.i.d. duplication, and scheduled outage
//! windows (link down ⇒ drop). Verdicts are consulted at the event
//! SCHEDULING sites (`svcgraph::Fabric::route`, the lifecycle
//! instruction sender) — the link still charges time and bytes exactly
//! as today, the verdict only decides whether the delivery event is
//! pushed (or pushed twice).
//!
//! Determinism discipline — the same contract as `Link` jitter:
//!
//! * every random decision is a stateless indexed draw
//!   (`util::prng::f32_at(seed, n)`) off a per-link seed derived from
//!   the link NAME and the scenario-level fault seed, indexed by a
//!   per-link monotonic decision counter — same seed ⇒ bit-identical
//!   drop/duplicate sequences, independent of wall-clock or map order;
//! * a knob at zero draws NOTHING (no PRNG stream is even consulted),
//!   so a fault-free run is byte-for-byte identical to a build without
//!   this module — every pre-PR-7 golden replays unchanged;
//! * outage windows are plain interval arithmetic (no randomness).
//!
//! The per-link seed folds the link name with a different constant
//! (`0xFA17`) than jitter's `0xACE`, then mixes the scenario seed, so
//! the fault stream is decorrelated from the jitter stream even on the
//! same link.

use crate::json::Value;
use crate::util::{prng, SimTime};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Per-link fault seed: link name folded with a fault-specific
/// constant, mixed with the scenario seed (SplitMix64 odd multiplier).
pub fn link_fault_seed(scenario_seed: u64, link: &str) -> u64 {
    let name_hash = link
        .bytes()
        .fold(0xFA17u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
    name_hash ^ scenario_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xFA17)
}

/// What happens to one scheduled delivery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Push the delivery event as today.
    Deliver,
    /// Do not push the delivery event (message lost on the link).
    Drop,
    /// Push the delivery event TWICE (the second copy at the same
    /// arrival time, a later scheduler sequence number).
    Duplicate,
}

/// Scenario-level fault knobs, parsed from a `faults:` yamlite block:
///
/// ```yaml
/// faults:
///   seed: 7
///   loss: 0.1        # i.i.d. per-message drop probability, [0, 1)
///   dup: 0.02        # i.i.d. per-message duplication probability
/// ```
///
/// `loss`/`dup` default to 0.0 (draw nothing); `seed` defaults to 0.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    pub seed: u64,
    pub loss: f64,
    pub dup: f64,
}

impl FaultSpec {
    /// Parse a `faults:` block. Unknown keys and mistyped/out-of-range
    /// values are loud errors, never silent fallbacks (same contract
    /// as `NetOverrides::from_value`).
    pub fn from_value(doc: &Value) -> Result<FaultSpec> {
        let obj = doc
            .as_obj()
            .context("faults: expected a mapping of {seed, loss, dup}")?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "seed" | "loss" | "dup") {
                bail!("faults.{key}: unknown field (expected seed|loss|dup)");
            }
        }
        let prob = |key: &str| -> Result<f64> {
            match doc.get(key) {
                Value::Null => Ok(0.0),
                v => {
                    let p = v.as_f64().with_context(|| {
                        format!("faults.{key}: expected a number, got {v}")
                    })?;
                    if !(p.is_finite() && (0.0..1.0).contains(&p)) {
                        bail!("faults.{key}: probability must be in [0, 1), got {p}");
                    }
                    Ok(p)
                }
            }
        };
        let seed = match doc.get("seed") {
            Value::Null => 0,
            v => {
                let s = v
                    .as_f64()
                    .with_context(|| format!("faults.seed: expected a number, got {v}"))?;
                if s.fract() != 0.0 || s < 0.0 {
                    bail!("faults.seed: expected a non-negative integer, got {s}");
                }
                s as u64
            }
        };
        Ok(FaultSpec { seed, loss: prob("loss")?, dup: prob("dup")? })
    }

    /// Any knob set? False = the plane stays completely inert.
    pub fn is_active(&self) -> bool {
        self.loss > 0.0 || self.dup > 0.0
    }
}

/// One link's fault state: the i.i.d. knobs, the indexed-draw cursor,
/// scheduled outage windows, and loss/duplication counters.
#[derive(Debug, Clone, Default)]
pub struct FaultProcess {
    pub loss: f64,
    pub dup: f64,
    /// Stream seed for fault draws (see [`link_fault_seed`]).
    pub seed: u64,
    /// Monotonic decision counter — each consulted draw consumes one
    /// index, so the decision sequence is a pure function of the seed.
    decisions: u64,
    /// Scheduled outages, `[from, until)` in virtual µs: a delivery
    /// whose SEND time falls inside any window is dropped (no draw).
    pub outages: Vec<(SimTime, SimTime)>,
    /// Messages dropped (i.i.d. loss + outage windows).
    pub lost: u64,
    /// Messages duplicated.
    pub duplicated: u64,
}

impl FaultProcess {
    pub fn new(seed: u64, loss: f64, dup: f64) -> Self {
        FaultProcess { loss, dup, seed, ..Default::default() }
    }

    /// Is `now` inside a scheduled outage window?
    pub fn in_outage(&self, now: SimTime) -> bool {
        self.outages.iter().any(|&(from, until)| from <= now && now < until)
    }

    /// Decide the fate of one delivery sent at `now`. Zero knobs and
    /// no matching outage ⇒ `Deliver` without consuming any draw.
    pub fn verdict(&mut self, now: SimTime) -> Verdict {
        if self.in_outage(now) {
            self.lost += 1;
            return Verdict::Drop;
        }
        if self.loss > 0.0 {
            let n = self.decisions;
            self.decisions += 1;
            if (prng::f32_at(self.seed, n) as f64) < self.loss {
                self.lost += 1;
                return Verdict::Drop;
            }
        }
        if self.dup > 0.0 {
            let n = self.decisions;
            self.decisions += 1;
            if (prng::f32_at(self.seed, n) as f64) < self.dup {
                self.duplicated += 1;
                return Verdict::Duplicate;
            }
        }
        Verdict::Deliver
    }
}

/// The fabric-wide fault plane: one optional [`FaultProcess`] per link
/// name. Completely inert (and allocation-free on the hot path) until
/// a [`FaultSpec`] is armed or an outage is scheduled — the zero-knob
/// configuration is indistinguishable from the plane not existing.
#[derive(Debug, Clone, Default)]
pub struct FaultPlane {
    /// The scenario-level knobs, if armed.
    spec: Option<FaultSpec>,
    /// Per-link processes, keyed by canonical link name (`lan-ec0`,
    /// `up-ec0`, `down-ec0`, `lan-cc`). Created lazily on first
    /// verdict (spec armed) or first scheduled outage.
    links: BTreeMap<String, FaultProcess>,
}

impl FaultPlane {
    /// Arm scenario-level i.i.d. loss/duplication. A spec with both
    /// knobs at zero still arms the plane (the seed is recorded for
    /// later outage-only links) but draws nothing.
    pub fn arm(&mut self, spec: FaultSpec) {
        self.spec = Some(spec);
    }

    /// The hot-path short-circuit: nothing armed, nothing scheduled.
    #[inline]
    pub fn is_idle(&self) -> bool {
        self.spec.is_none() && self.links.is_empty()
    }

    /// Schedule an outage window `[from, until)` on `link`.
    pub fn schedule_outage(&mut self, link: &str, from: SimTime, until: SimTime) {
        self.process_mut(link).outages.push((from, until));
    }

    /// Decide the fate of one delivery on `link` sent at `now`.
    pub fn verdict(&mut self, link: &str, now: SimTime) -> Verdict {
        if self.is_idle() {
            return Verdict::Deliver;
        }
        // spec armed: every link gets a process on first use; spec not
        // armed: only links with scheduled outages have state, the
        // rest deliver without allocating.
        if self.spec.is_some() {
            return self.process_mut(link).verdict(now);
        }
        match self.links.get_mut(link) {
            Some(p) => p.verdict(now),
            None => Verdict::Deliver,
        }
    }

    fn process_mut(&mut self, link: &str) -> &mut FaultProcess {
        let spec = self.spec.unwrap_or_default();
        self.links.entry(link.to_string()).or_insert_with(|| {
            FaultProcess::new(link_fault_seed(spec.seed, link), spec.loss, spec.dup)
        })
    }

    /// Total messages dropped across all links.
    pub fn lost(&self) -> u64 {
        self.links.values().map(|p| p.lost).sum()
    }

    /// Total messages duplicated across all links.
    pub fn duplicated(&self) -> u64 {
        self.links.values().map(|p| p.duplicated).sum()
    }

    /// Per-link state, if any (tests / reporting).
    pub fn link(&self, name: &str) -> Option<&FaultProcess> {
        self.links.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yamlite;

    #[test]
    fn spec_parses_and_rejects_garbage() {
        let doc = yamlite::parse("seed: 7\nloss: 0.1\ndup: 0.02\n").unwrap();
        let spec = FaultSpec::from_value(&doc).unwrap();
        assert_eq!(spec, FaultSpec { seed: 7, loss: 0.1, dup: 0.02 });
        assert!(spec.is_active());
        // defaults: absent knobs are zero
        let doc = yamlite::parse("seed: 3\n").unwrap();
        let spec = FaultSpec::from_value(&doc).unwrap();
        assert_eq!((spec.loss, spec.dup), (0.0, 0.0));
        assert!(!spec.is_active());
        for bad in [
            "loss: 1.5\n",
            "loss: -0.1\n",
            "loss: maybe\n",
            "dup: 1\n", // 1.0 would duplicate EVERY message forever
            "seed: -1\n",
            "seed: 1.5\n",
            "seed: 7\ntypo_knob: 1\n",
        ] {
            let v = yamlite::parse(bad).unwrap();
            assert!(FaultSpec::from_value(&v).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn zero_knobs_consume_no_draws() {
        let mut p = FaultProcess::new(123, 0.0, 0.0);
        for now in 0..10_000u64 {
            assert_eq!(p.verdict(now), Verdict::Deliver);
        }
        assert_eq!(p.decisions, 0, "zero knobs must not touch the PRNG stream");
        assert_eq!((p.lost, p.duplicated), (0, 0));
    }

    #[test]
    fn verdicts_are_a_pure_function_of_the_seed() {
        let run = || {
            let mut p = FaultProcess::new(link_fault_seed(7, "up-ec0"), 0.2, 0.05);
            (0..2_000u64).map(|now| p.verdict(now * 17)).collect::<Vec<_>>()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed must yield identical decision sequences");
        assert!(a.contains(&Verdict::Drop), "20% loss over 2000 msgs must drop");
        assert!(a.contains(&Verdict::Duplicate));
        // and a different scenario seed decorrelates the stream
        let mut p = FaultProcess::new(link_fault_seed(8, "up-ec0"), 0.2, 0.05);
        let c: Vec<_> = (0..2_000u64).map(|now| p.verdict(now * 17)).collect();
        assert_ne!(a, c, "different seeds must yield different sequences");
    }

    #[test]
    fn loss_rate_is_roughly_honoured() {
        let mut p = FaultProcess::new(link_fault_seed(42, "lan-ec1"), 0.1, 0.0);
        let n = 20_000u64;
        for now in 0..n {
            p.verdict(now);
        }
        let rate = p.lost as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.02, "empirical loss {rate} vs 0.1");
    }

    #[test]
    fn outage_windows_drop_without_drawing() {
        let mut p = FaultProcess::new(9, 0.0, 0.0);
        p.outages.push((1_000, 2_000));
        assert_eq!(p.verdict(999), Verdict::Deliver);
        assert_eq!(p.verdict(1_000), Verdict::Drop, "window start is inclusive");
        assert_eq!(p.verdict(1_999), Verdict::Drop);
        assert_eq!(p.verdict(2_000), Verdict::Deliver, "window end is exclusive");
        assert_eq!(p.lost, 2);
        assert_eq!(p.decisions, 0, "outage drops are interval arithmetic, not draws");
    }

    #[test]
    fn idle_plane_allocates_no_link_state() {
        let mut plane = FaultPlane::default();
        assert!(plane.is_idle());
        for i in 0..1_000u64 {
            assert_eq!(plane.verdict("lan-ec0", i), Verdict::Deliver);
        }
        assert!(plane.is_idle(), "idle verdicts must not materialize link state");
        assert_eq!((plane.lost(), plane.duplicated()), (0, 0));
    }

    #[test]
    fn armed_plane_faults_per_link_independently() {
        let mut plane = FaultPlane::default();
        plane.arm(FaultSpec { seed: 7, loss: 0.3, dup: 0.0 });
        for i in 0..2_000u64 {
            plane.verdict("up-ec0", i);
            plane.verdict("down-ec0", i);
        }
        let up = plane.link("up-ec0").unwrap();
        let down = plane.link("down-ec0").unwrap();
        assert!(up.lost > 0 && down.lost > 0);
        assert_ne!(up.seed, down.seed, "per-link seeds must differ");
        assert_eq!(plane.lost(), up.lost + down.lost);
    }

    #[test]
    fn outage_only_plane_faults_just_the_scheduled_link() {
        let mut plane = FaultPlane::default();
        plane.schedule_outage("up-ec1", 100, 200);
        assert!(!plane.is_idle());
        assert_eq!(plane.verdict("up-ec1", 150), Verdict::Drop);
        assert_eq!(plane.verdict("up-ec1", 250), Verdict::Deliver);
        assert_eq!(plane.verdict("up-ec0", 150), Verdict::Deliver);
        assert!(plane.link("up-ec0").is_none(), "unscheduled links stay stateless");
        assert_eq!(plane.lost(), 1);
    }
}
