//! Simulated network links: bandwidth serialization + one-way delay.
//!
//! Models the paper's testbed network (§5.1.1): each EC has a 100 Mbps
//! LAN; every EC reaches the CC over a WAN shaped to 20 Mbps uplink /
//! 40 Mbps downlink with a configurable one-way delay (0 ms ideal,
//! 50 ms practical). A `Link` is a FIFO serialization queue: a message
//! of `n` bytes occupies the link for `n*8/bw` seconds starting when the
//! link frees up, then arrives `delay` later. Per-link byte counters
//! feed the BWC metric (edge-cloud bandwidth consumption, Figure 5 mid
//! row).
//!
//! The struct is plain data (no coupling to the DES): `send` returns the
//! delivery time and the caller schedules the delivery event.

use crate::util::{SimTime, MICROS_PER_SEC};

/// One directed link.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Bits per second (mutable: the §4.2.2 validation testbed applies
    /// time-varying channel profiles through `set_bw_bps`).
    pub bw_bps: u64,
    /// One-way propagation delay (µs).
    pub delay: SimTime,
    /// Max extra per-message delay (µs); each message gets a
    /// deterministic uniform sample in [0, jitter] (§4.2.2 "the impact
    /// of edge-cloud channel dynamics (bandwidth, delay, jitter)").
    pub jitter: SimTime,
    /// Stream seed for jitter samples (indexed by message count).
    pub jitter_seed: u64,
    /// Time the serialization queue frees up.
    busy_until: SimTime,
    /// Latest delivery time handed out — the FIFO guard: per-message
    /// jitter (or a mid-run delay re-shape) must never let message n+1
    /// arrive before message n on the same link.
    last_delivery: SimTime,
    /// Total payload bytes accepted (the BWC counter).
    pub bytes_sent: u64,
    /// Messages accepted.
    pub msgs_sent: u64,
}

impl Link {
    pub fn new(name: impl Into<String>, bw_bps: u64, delay: SimTime) -> Self {
        let name = name.into();
        let jitter_seed = name
            .bytes()
            .fold(0xACEu64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
        Link {
            name,
            bw_bps,
            delay,
            jitter: 0,
            jitter_seed,
            busy_until: 0,
            last_delivery: 0,
            bytes_sent: 0,
            msgs_sent: 0,
        }
    }

    /// Re-shape the link (validation-testbed channel dynamics).
    pub fn set_bw_bps(&mut self, bw_bps: u64) {
        self.bw_bps = bw_bps.max(1);
    }

    /// Convenience: megabit/s link.
    pub fn mbps(name: impl Into<String>, mbps: f64, delay: SimTime) -> Self {
        Link::new(name, (mbps * 1e6) as u64, delay)
    }

    /// Serialization time of `bytes` on this link (µs, >= 1).
    pub fn ser_time(&self, bytes: u64) -> SimTime {
        ((bytes as u128 * 8 * MICROS_PER_SEC as u128) / self.bw_bps as u128).max(1) as SimTime
    }

    /// Enqueue `bytes` at `now`; returns the delivery time. Deliveries
    /// on one link are FIFO: when a small jitter sample (or a delay
    /// re-shape) would land message n+1 before message n, the delivery
    /// is clamped to the previous one — jitter can stretch gaps, never
    /// reorder a serialization queue.
    pub fn send(&mut self, now: SimTime, bytes: u64) -> SimTime {
        let start = self.busy_until.max(now);
        let done = start + self.ser_time(bytes);
        self.busy_until = done;
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
        let j = if self.jitter > 0 {
            crate::util::prng::u32_at(self.jitter_seed, self.msgs_sent) as u64
                % (self.jitter + 1)
        } else {
            0
        };
        let delivery = (done + self.delay + j).max(self.last_delivery);
        self.last_delivery = delivery;
        delivery
    }

    /// Queueing delay a new message would currently experience (µs).
    pub fn backlog(&self, now: SimTime) -> SimTime {
        self.busy_until.saturating_sub(now)
    }

    /// Reset counters (between experiment repetitions).
    pub fn reset(&mut self) {
        self.busy_until = 0;
        self.last_delivery = 0;
        self.bytes_sent = 0;
        self.msgs_sent = 0;
    }
}

/// The §5.1.1 testbed topology: per-EC LAN + EC<->CC WAN pairs.
#[derive(Debug, Clone)]
pub struct EdgeCloudNet {
    /// Per-EC node->local links (LAN, symmetric). Indexed by EC.
    pub lan: Vec<Link>,
    /// EC -> CC uplinks (20 Mbps in the paper).
    pub uplink: Vec<Link>,
    /// CC -> EC downlinks (40 Mbps in the paper).
    pub downlink: Vec<Link>,
}

/// Network parameters mirroring §5.1.1.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    pub num_ecs: usize,
    pub lan_mbps: f64,
    pub uplink_mbps: f64,
    pub downlink_mbps: f64,
    /// One-way WAN delay (µs): 0 = ideal, 50_000 = practical.
    pub wan_delay: SimTime,
    /// LAN delay (µs); small but nonzero.
    pub lan_delay: SimTime,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            num_ecs: 3,
            lan_mbps: 100.0,
            uplink_mbps: 20.0,
            downlink_mbps: 40.0,
            wan_delay: 0,
            lan_delay: 500, // 0.5 ms switch+stack latency
        }
    }
}

impl EdgeCloudNet {
    pub fn new(cfg: &NetConfig) -> Self {
        let mut lan = Vec::new();
        let mut uplink = Vec::new();
        let mut downlink = Vec::new();
        for ec in 0..cfg.num_ecs {
            lan.push(Link::mbps(format!("lan-ec{ec}"), cfg.lan_mbps, cfg.lan_delay));
            uplink.push(Link::mbps(format!("up-ec{ec}"), cfg.uplink_mbps, cfg.wan_delay));
            downlink.push(Link::mbps(format!("down-ec{ec}"), cfg.downlink_mbps, cfg.wan_delay));
        }
        EdgeCloudNet { lan, uplink, downlink }
    }

    /// Total WAN bytes (up + down) — the paper's BWC metric.
    pub fn wan_bytes(&self) -> u64 {
        self.uplink.iter().map(|l| l.bytes_sent).sum::<u64>()
            + self.downlink.iter().map(|l| l.bytes_sent).sum::<u64>()
    }

    /// Uplink-only bytes (crop uploads dominate; reported separately).
    pub fn wan_up_bytes(&self) -> u64 {
        self.uplink.iter().map(|l| l.bytes_sent).sum()
    }

    pub fn reset(&mut self) {
        for l in self
            .lan
            .iter_mut()
            .chain(self.uplink.iter_mut())
            .chain(self.downlink.iter_mut())
        {
            l.reset();
        }
    }
}

/// Standard sizes used by the video-query experiment (bytes).
pub mod sizes {
    /// One 32x32 RGB crop, 8-bit per channel, plus framing metadata.
    pub const CROP_BYTES: u64 = 32 * 32 * 3 + 64;
    /// A small control / metadata message (result record, EIL report).
    pub const META_BYTES: u64 = 128;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::millis;

    #[test]
    fn serialization_time_matches_bandwidth() {
        let l = Link::mbps("l", 20.0, 0);
        // 20 Mbps = 2.5 MB/s; 2500 bytes -> 1 ms
        assert_eq!(l.ser_time(2500), 1000);
    }

    #[test]
    fn fifo_queueing_accumulates() {
        let mut l = Link::mbps("l", 20.0, millis(50.0));
        let d1 = l.send(0, 2500);
        let d2 = l.send(0, 2500);
        assert_eq!(d1, 1000 + 50_000);
        assert_eq!(d2, 2000 + 50_000); // waits behind the first
        assert_eq!(l.bytes_sent, 5000);
        assert_eq!(l.backlog(0), 2000);
    }

    #[test]
    fn idle_link_restarts_at_now() {
        let mut l = Link::mbps("l", 20.0, 0);
        l.send(0, 2500);
        let d = l.send(10_000, 2500);
        assert_eq!(d, 11_000); // no residual backlog
    }

    #[test]
    fn edge_cloud_net_shape() {
        let net = EdgeCloudNet::new(&NetConfig {
            num_ecs: 3,
            wan_delay: millis(50.0),
            ..Default::default()
        });
        assert_eq!(net.lan.len(), 3);
        assert_eq!(net.uplink.len(), 3);
        assert_eq!(net.uplink[0].delay, 50_000);
        assert_eq!(net.wan_bytes(), 0);
    }

    #[test]
    fn wan_accounting_sums_both_directions() {
        let mut net = EdgeCloudNet::new(&NetConfig::default());
        net.uplink[0].send(0, 1000);
        net.downlink[2].send(0, 234);
        assert_eq!(net.wan_bytes(), 1234);
        assert_eq!(net.wan_up_bytes(), 1000);
        net.reset();
        assert_eq!(net.wan_bytes(), 0);
    }

    #[test]
    fn tiny_message_still_takes_time() {
        let l = Link::mbps("l", 1000.0, 0);
        assert!(l.ser_time(1) >= 1);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mk = || {
            let mut l = Link::mbps("j", 100.0, 1000);
            l.jitter = 5000;
            l
        };
        let (mut a, mut b) = (mk(), mk());
        for i in 0..200u64 {
            let da = a.send(i * 10_000, 100);
            let db = b.send(i * 10_000, 100);
            assert_eq!(da, db, "jitter must be deterministic");
            // base delivery = start + ser + delay; jitter adds <= 5000
            let base = i * 10_000 + a.ser_time(100).max(1) + 1000;
            assert!(da >= base && da <= base + 5000, "msg {i}: {da} vs {base}");
        }
    }

    #[test]
    fn jitter_never_reorders_a_fifo_link() {
        // regression: with jitter much larger than serialization time,
        // back-to-back sends used to get independent jitter samples, so
        // message n+1 (small sample) could arrive before message n
        // (large sample) — impossible on a FIFO serialization queue.
        // The clamp makes delivery times monotonic per link.
        let mut l = Link::mbps("fifo-jitter", 1000.0, 1000);
        l.jitter = 50_000; // 50 ms of jitter vs ~1 us serialization
        let mut last = 0;
        let mut clamped = false;
        for i in 0..500u64 {
            let d = l.send(i, 100); // near-simultaneous sends
            assert!(d >= last, "msg {i}: delivery {d} before previous {last}");
            if d == last && i > 0 {
                clamped = true;
            }
            last = d;
        }
        // the clamp must actually have fired for this jitter profile,
        // otherwise the regression test tests nothing
        assert!(clamped, "expected at least one clamped delivery");
    }

    #[test]
    fn reshaping_bandwidth_changes_ser_time() {
        let mut l = Link::mbps("r", 20.0, 0);
        let before = l.ser_time(2500);
        l.set_bw_bps((5.0 * 1e6) as u64); // degrade to 5 Mbps
        assert_eq!(l.ser_time(2500), before * 4);
        l.set_bw_bps(0); // clamps, never div-by-zero
        assert!(l.ser_time(1) > 0);
    }
}
