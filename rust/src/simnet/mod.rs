//! Simulated network fabric: per-node access links (NICs) feeding
//! per-cluster LAN segments, bridged to the CC over shaped WAN pairs.
//!
//! Models the paper's testbed network (§5.1.1) — and its generalization
//! to heterogeneous nodes. Each cluster (every EC, and since PR 5 the
//! CC too) is a shared LAN segment; every node MAY have its own access
//! [`Link`] (NIC) in front of that segment, so two RPis saturating the
//! same EC contend on their own uplinks before they contend on the
//! LAN. Every EC reaches the CC over a WAN shaped to 20 Mbps uplink /
//! 40 Mbps downlink with a configurable one-way delay (0 ms ideal,
//! 50 ms practical).
//!
//! A message crossing nodes is charged HOP BY HOP (the src NIC at
//! most once per publish — the one transmit up to the cluster message
//! service — however many receivers/bridges fan out from the bus):
//!
//! | hop | legs charged |
//! |---|---|
//! | same node | none (in-process hand-off) |
//! | same cluster, other node | src NIC → cluster LAN → dst NIC |
//! | EC → CC (bridged) | src NIC → WAN uplink → CC LAN (gateway) |
//! | CC → EC (bridged) | src NIC → CC LAN (gateway) → WAN downlink |
//! | bridge arrival → local subscriber | dst NIC |
//!
//! The "CC LAN (gateway)" leg models the CC border router sitting ON
//! the CC backbone segment: bridged traffic crosses that segment
//! between the router and the CC bus ([`NetFabric::gateway_hop`]).
//! When the CC LAN is unmodelled (`cc_lan_mbps: None`, the degenerate
//! configuration) the leg charges nothing and adds zero time.
//!
//! The DEGENERATE configuration — no NIC entries, free CC backplane,
//! one CC node — is exactly the pre-PR-5 flat model (one shared FIFO
//! LAN per EC, free CC, WAN pairs): every absent NIC charges nothing
//! and adds zero time, so all pre-refactor golden trajectories replay
//! byte-for-byte (`tests/netfabric.rs`).
//!
//! A `Link` is a FIFO serialization queue: a message of `n` bytes
//! occupies the link for `n*8/bw` seconds starting when the link frees
//! up, then arrives `delay` later. Per-link byte counters feed the BWC
//! metric (edge-cloud bandwidth consumption, Figure 5 mid row).
//!
//! The structs are plain data (no coupling to the DES): the charge
//! methods return the delivery time and the caller schedules the
//! delivery event.

use crate::json::Value;
use crate::util::{SimTime, MICROS_PER_SEC};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

pub mod faults;

use faults::{FaultPlane, FaultSpec, Verdict};

/// One directed link.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Bits per second (mutable: the §4.2.2 validation testbed applies
    /// time-varying channel profiles through `set_bw_bps`).
    pub bw_bps: u64,
    /// One-way propagation delay (µs).
    pub delay: SimTime,
    /// Max extra per-message delay (µs); each message gets a
    /// deterministic uniform sample in [0, jitter] (§4.2.2 "the impact
    /// of edge-cloud channel dynamics (bandwidth, delay, jitter)").
    pub jitter: SimTime,
    /// Stream seed for jitter samples (indexed by message count).
    pub jitter_seed: u64,
    /// Time the serialization queue frees up.
    busy_until: SimTime,
    /// Latest delivery time handed out — the FIFO guard: per-message
    /// jitter (or a mid-run delay re-shape) must never let message n+1
    /// arrive before message n on the same link.
    last_delivery: SimTime,
    /// Total payload bytes accepted (the BWC counter).
    pub bytes_sent: u64,
    /// Messages accepted.
    pub msgs_sent: u64,
    /// Total serialization occupancy (µs): time the link spent
    /// actually transmitting. busy_time / sim_duration is the link's
    /// utilization share; unlimited NICs never accumulate any.
    pub busy_time: SimTime,
}

impl Link {
    pub fn new(name: impl Into<String>, bw_bps: u64, delay: SimTime) -> Self {
        let name = name.into();
        let jitter_seed = name
            .bytes()
            .fold(0xACEu64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
        Link {
            name,
            bw_bps,
            delay,
            jitter: 0,
            jitter_seed,
            busy_until: 0,
            last_delivery: 0,
            bytes_sent: 0,
            msgs_sent: 0,
            busy_time: 0,
        }
    }

    /// Re-shape the link (validation-testbed channel dynamics).
    pub fn set_bw_bps(&mut self, bw_bps: u64) {
        self.bw_bps = bw_bps.max(1);
    }

    /// Convenience: megabit/s link with an f64 one-way delay in µs —
    /// both shaping knobs in f64, consistently. Clamped like
    /// [`Link::set_bw_bps`]: non-positive/NaN bandwidth becomes 1 bps
    /// and negative delays zero, so no scenario-supplied value can
    /// reach [`Link::ser_time`]'s division as 0.
    pub fn mbps(name: impl Into<String>, mbps: f64, delay_us: f64) -> Self {
        Link::new(name, ((mbps * 1e6) as u64).max(1), delay_us.max(0.0).round() as SimTime)
    }

    /// Serialization time of `bytes` on this link (µs, >= 1).
    pub fn ser_time(&self, bytes: u64) -> SimTime {
        ((bytes as u128 * 8 * MICROS_PER_SEC as u128) / self.bw_bps as u128).max(1) as SimTime
    }

    /// Enqueue `bytes` at `now`; returns the delivery time. Deliveries
    /// on one link are FIFO: when a small jitter sample (or a delay
    /// re-shape) would land message n+1 before message n, the delivery
    /// is clamped to the previous one — jitter can stretch gaps, never
    /// reorder a serialization queue.
    pub fn send(&mut self, now: SimTime, bytes: u64) -> SimTime {
        let start = self.busy_until.max(now);
        let done = start + self.ser_time(bytes);
        self.busy_until = done;
        self.busy_time += done - start;
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
        let j = if self.jitter > 0 {
            crate::util::prng::u32_at(self.jitter_seed, self.msgs_sent) as u64
                % (self.jitter + 1)
        } else {
            0
        };
        let delivery = (done + self.delay + j).max(self.last_delivery);
        self.last_delivery = delivery;
        delivery
    }

    /// Queueing delay a new message would currently experience (µs).
    pub fn backlog(&self, now: SimTime) -> SimTime {
        self.busy_until.saturating_sub(now)
    }

    /// Reset counters (between experiment repetitions).
    pub fn reset(&mut self) {
        self.busy_until = 0;
        self.last_delivery = 0;
        self.bytes_sent = 0;
        self.msgs_sent = 0;
        self.busy_time = 0;
    }
}

/// A node's access link. `unlimited` is the degenerate NIC: it still
/// counts traffic (saturation observability) but never delays — the
/// EXACT infinite-bandwidth limit, with no 1 µs serialization floor,
/// which is what lets an explicitly-listed unlimited NIC reproduce the
/// no-NIC trajectories byte-for-byte.
#[derive(Debug, Clone)]
pub struct Nic {
    pub link: Link,
    /// Count traffic, never delay.
    pub unlimited: bool,
}

impl Nic {
    /// A shaped (bandwidth-constrained) NIC.
    pub fn shaped(link: Link) -> Self {
        Nic { link, unlimited: false }
    }

    /// A count-only NIC (the infinite-bandwidth degenerate case).
    pub fn unlimited(name: impl Into<String>) -> Self {
        Nic { link: Link::new(name, u64::MAX, 0), unlimited: true }
    }

    /// Charge `bytes` at `now`; unlimited NICs count and return `now`.
    pub fn send(&mut self, now: SimTime, bytes: u64) -> SimTime {
        if self.unlimited {
            self.link.bytes_sent += bytes;
            self.link.msgs_sent += 1;
            now
        } else {
            self.link.send(now, bytes)
        }
    }

    /// Access bandwidth in Mbps; `None` when unlimited.
    pub fn mbps(&self) -> Option<f64> {
        if self.unlimited {
            None
        } else {
            Some(self.link.bw_bps as f64 / 1e6)
        }
    }
}

/// Slot value meaning "this node has no NIC" — the free fast path.
/// Callers that cache a node's NIC slot (`svcgraph::Fabric` caches one
/// per component at bind time) use this sentinel so the per-message
/// charge is a dense `Vec` index, never a name lookup.
pub const NO_NIC: u32 = u32::MAX;

/// One cluster's internal network: an optional shared LAN segment
/// (`None` = free backplane, the degenerate single-node CC) plus the
/// access links of the nodes that have one. Nodes without a NIC are
/// unconstrained AND uncounted — the flat-model fast path.
///
/// NICs live in a dense slab (`Vec<Nic>`) with a name → slot map used
/// only on admin paths (bind, `degrade-nic`, reports); the per-message
/// charge methods index the slab directly (PR 8: the routing hot path
/// must not hash or compare strings).
#[derive(Debug, Clone, Default)]
pub struct ClusterNet {
    pub lan: Option<Link>,
    /// Dense NIC storage; a slot is never reused for another node.
    nics: Vec<Nic>,
    /// node leaf name → slot into `nics` (admin-path only).
    by_node: BTreeMap<String, u32>,
}

impl ClusterNet {
    /// A cluster segment: `mbps: None` = free backplane.
    pub fn segment(name: String, mbps: Option<f64>, delay: SimTime) -> Self {
        ClusterNet {
            lan: mbps.map(|m| Link::mbps(name, m, delay as f64)),
            nics: Vec::new(),
            by_node: BTreeMap::new(),
        }
    }

    /// Slot of `node`'s NIC, or `None` when the node has none.
    pub fn nic_slot(&self, node: &str) -> Option<u32> {
        self.by_node.get(node).copied()
    }

    /// NIC at `slot` (`NO_NIC` or out-of-range = none).
    pub fn nic_at(&self, slot: u32) -> Option<&Nic> {
        self.nics.get(slot as usize)
    }

    fn nic_at_mut(&mut self, slot: u32) -> Option<&mut Nic> {
        self.nics.get_mut(slot as usize)
    }

    /// Insert or replace `node`'s NIC, returning its slot.
    pub fn upsert_nic(&mut self, node: &str, nic: Nic) -> u32 {
        match self.by_node.get(node) {
            Some(&slot) => {
                self.nics[slot as usize] = nic;
                slot
            }
            None => {
                let slot = self.nics.len() as u32;
                assert!(slot != NO_NIC, "NIC slab exhausted");
                self.nics.push(nic);
                self.by_node.insert(node.to_string(), slot);
                slot
            }
        }
    }

    /// Get-or-create `node`'s NIC (the `degrade-nic` path), returning
    /// a mutable reference.
    fn nic_entry(&mut self, node: &str, make: impl FnOnce() -> Nic) -> &mut Nic {
        let slot = match self.by_node.get(node) {
            Some(&slot) => slot,
            None => self.upsert_nic(node, make()),
        };
        &mut self.nics[slot as usize]
    }

    /// All NICs in node-name order (deterministic reports).
    pub fn iter_nics(&self) -> impl Iterator<Item = (&str, &Nic)> {
        self.by_node.iter().map(|(name, &slot)| (name.as_str(), &self.nics[slot as usize]))
    }

    fn iter_nics_mut(&mut self) -> impl Iterator<Item = &mut Nic> {
        self.nics.iter_mut()
    }
}

/// One node's access-link shape, as configured in scenario/topology
/// yamlite (`network: { nics: [...] }`).
#[derive(Debug, Clone)]
pub struct NicSpec {
    /// Cluster leaf: `ec-1`..`ec-N` or `cc` (the infra id layer).
    pub cluster: String,
    /// Node leaf name (`rpi1`, `gpu-ws`).
    pub node: String,
    /// Access bandwidth in Mbps; non-finite or <= 0 = unlimited
    /// (count-only).
    pub mbps: f64,
    /// One-way delay (µs).
    pub delay_us: f64,
}

/// Network parameters mirroring §5.1.1, extended with the per-node
/// link graph (PR 5). The default is the DEGENERATE configuration: no
/// NICs, free single-node CC backplane — the pre-refactor flat model.
#[derive(Debug, Clone)]
pub struct NetConfig {
    pub num_ecs: usize,
    pub lan_mbps: f64,
    pub uplink_mbps: f64,
    pub downlink_mbps: f64,
    /// One-way WAN delay (µs): 0 = ideal, 50_000 = practical.
    pub wan_delay: SimTime,
    /// LAN delay (µs); small but nonzero.
    pub lan_delay: SimTime,
    /// CC LAN segment bandwidth; `None` = free backplane (degenerate
    /// single-node CC).
    pub cc_lan_mbps: Option<f64>,
    /// CC LAN delay (µs), used only when `cc_lan_mbps` is set.
    pub cc_lan_delay: SimTime,
    /// Per-node access links. Nodes not listed are unconstrained and
    /// uncounted; specs naming clusters outside `num_ecs`/`cc` are
    /// ignored (a scenario may configure more ECs than the run uses).
    pub nics: Vec<NicSpec>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            num_ecs: 3,
            lan_mbps: 100.0,
            uplink_mbps: 20.0,
            downlink_mbps: 40.0,
            wan_delay: 0,
            lan_delay: 500, // 0.5 ms switch+stack latency
            cc_lan_mbps: None,
            cc_lan_delay: 100,
            nics: Vec::new(),
        }
    }
}

/// Parse an EC cluster leaf (`ec-N`, N >= 1) to its 1-based ordinal —
/// THE copy of the leaf-naming convention shared by config parsing,
/// fabric build, `svcgraph::site_of_node`, and the placement hints
/// ([`cluster_leaf`] is the reverse mapping).
pub fn parse_ec_leaf(leaf: &str) -> Option<usize> {
    let n: usize = leaf.strip_prefix("ec-")?.parse().ok()?;
    (n >= 1).then_some(n)
}

/// Cluster index (ECs first, CC last) → leaf name (`ec-1`.. / `cc`).
pub fn cluster_leaf(ci: usize, num_ecs: usize) -> String {
    if ci == num_ecs {
        "cc".to_string()
    } else {
        format!("ec-{}", ci + 1)
    }
}

impl NetConfig {
    /// Cluster leaf (`ec-1`.. / `cc`) → cluster index (ECs first, CC
    /// last — the same convention `svcgraph` uses).
    pub fn cluster_index(&self, leaf: &str) -> Option<usize> {
        if leaf == "cc" {
            return Some(self.num_ecs);
        }
        let n = parse_ec_leaf(leaf)?;
        if n <= self.num_ecs {
            Some(n - 1)
        } else {
            None
        }
    }
}

/// Overrides parsed from a scenario's `network:` yamlite block —
/// everything optional, applied on top of the run's base [`NetConfig`]
/// (see `svcgraph::lifecycle::LifecycleScenario`):
///
/// ```yaml
/// network:
///   lan_mbps: 100
///   uplink_mbps: 20
///   downlink_mbps: 40
///   wan_delay_ms: 0
///   lan_delay_ms: 0.5
///   cc_nodes: 2            # CC cluster size (consumed by the app driver)
///   cc_lan_mbps: 1000
///   cc_lan_delay_ms: 0.1
///   nics:
///     - cluster: ec-1
///       node: rpi1
///       mbps: 2            # a starved camera-node access link
///       delay_ms: 0.2
/// ```
#[derive(Debug, Clone, Default)]
pub struct NetOverrides {
    pub lan_mbps: Option<f64>,
    pub uplink_mbps: Option<f64>,
    pub downlink_mbps: Option<f64>,
    pub wan_delay_ms: Option<f64>,
    pub lan_delay_ms: Option<f64>,
    /// CC cluster size — consumed by the app driver (infrastructure
    /// shape), not by `NetFabric` itself.
    pub cc_nodes: Option<usize>,
    pub cc_lan_mbps: Option<f64>,
    pub cc_lan_delay_ms: Option<f64>,
    pub nics: Vec<NicSpec>,
}

impl NetOverrides {
    /// Parse a `network:` block (yamlite/JSON value). Present fields
    /// must be the right TYPE (a quoted `"50"` or a stray word is an
    /// error, never a silent fallback to the base value), and link
    /// bandwidths must be finite and positive (per-NIC `mbps` is the
    /// exception: non-finite/<= 0 means "unlimited", documented on
    /// [`NicSpec`]).
    pub fn from_value(doc: &Value) -> Result<NetOverrides> {
        // present-but-non-numeric is a loud error, absent is None
        let num = |key: &str| -> Result<Option<f64>> {
            match doc.get(key) {
                Value::Null => Ok(None),
                v => Ok(Some(v.as_f64().with_context(|| {
                    format!("network.{key}: expected a number, got {v}")
                })?)),
            }
        };
        let bw = |key: &str| -> Result<Option<f64>> {
            match num(key)? {
                Some(v) if !(v.is_finite() && v > 0.0) => {
                    bail!("network.{key}: bandwidth must be a positive number, got {v}")
                }
                v => Ok(v),
            }
        };
        let mut ov = NetOverrides {
            lan_mbps: bw("lan_mbps")?,
            uplink_mbps: bw("uplink_mbps")?,
            downlink_mbps: bw("downlink_mbps")?,
            wan_delay_ms: num("wan_delay_ms")?,
            lan_delay_ms: num("lan_delay_ms")?,
            cc_nodes: match num("cc_nodes")? {
                Some(v) if v.fract() != 0.0 || v < 0.0 => {
                    bail!("network.cc_nodes: expected a non-negative integer, got {v}")
                }
                v => v.map(|x| x as usize),
            },
            cc_lan_mbps: bw("cc_lan_mbps")?,
            cc_lan_delay_ms: num("cc_lan_delay_ms")?,
            nics: Vec::new(),
        };
        if let Some(list) = doc.get("nics").as_arr() {
            for (i, n) in list.iter().enumerate() {
                let cluster = n
                    .get("cluster")
                    .as_str()
                    .with_context(|| format!("network.nics[{i}]: missing 'cluster'"))?;
                // validate the leaf SHAPE here (via the shared
                // `parse_ec_leaf` convention) so typos like
                // `ec-0`/`ec-abc` fail the parse instead of being
                // silently dropped at fabric build; whether ec-N
                // exists in the RUN's shape is only known later and
                // out-of-shape specs stay ignorable.
                if cluster != "cc" && parse_ec_leaf(cluster).is_none() {
                    bail!("network.nics[{i}]: bad cluster '{cluster}' (ec-N|cc)");
                }
                let node = n
                    .get("node")
                    .as_str()
                    .with_context(|| format!("network.nics[{i}]: missing 'node'"))?;
                let mbps = n
                    .get("mbps")
                    .as_f64()
                    .with_context(|| format!("network.nics[{i}]: missing 'mbps'"))?;
                let delay_us = match n.get("delay_ms") {
                    Value::Null => 0.0,
                    v => {
                        v.as_f64().with_context(|| {
                            format!("network.nics[{i}].delay_ms: expected a number, got {v}")
                        })? * 1e3
                    }
                };
                ov.nics.push(NicSpec {
                    cluster: cluster.to_string(),
                    node: node.to_string(),
                    mbps,
                    delay_us,
                });
            }
        }
        Ok(ov)
    }

    /// [`NetOverrides::apply`] plus the knob `NetFabric` itself cannot
    /// consume: resolves the CC cluster size the app driver should
    /// build (the override clamped to >= 1, else `base_cc_nodes`).
    pub fn apply_with_cc(&self, cfg: &mut NetConfig, base_cc_nodes: usize) -> usize {
        self.apply(cfg);
        self.cc_nodes.map_or(base_cc_nodes, |n| n.max(1))
    }

    /// Apply on top of `cfg` (absent fields keep the base value).
    pub fn apply(&self, cfg: &mut NetConfig) {
        if let Some(v) = self.lan_mbps {
            cfg.lan_mbps = v;
        }
        if let Some(v) = self.uplink_mbps {
            cfg.uplink_mbps = v;
        }
        if let Some(v) = self.downlink_mbps {
            cfg.downlink_mbps = v;
        }
        if let Some(v) = self.wan_delay_ms {
            cfg.wan_delay = crate::util::millis(v);
        }
        if let Some(v) = self.lan_delay_ms {
            cfg.lan_delay = crate::util::millis(v);
        }
        if let Some(v) = self.cc_lan_mbps {
            cfg.cc_lan_mbps = Some(v);
        }
        if let Some(v) = self.cc_lan_delay_ms {
            cfg.cc_lan_delay = crate::util::millis(v);
        }
        cfg.nics.extend(self.nics.iter().cloned());
    }
}

/// The per-node link graph: one [`ClusterNet`] per cluster (ECs
/// 0..n-1, the CC last) plus the EC↔CC WAN pairs. All charge methods
/// take the CLUSTER INDEX in that order — the same `cidx` convention
/// `svcgraph` routes by.
#[derive(Debug, Clone)]
pub struct NetFabric {
    /// Per-cluster segments: ECs first, the CC last.
    pub clusters: Vec<ClusterNet>,
    /// EC → CC uplinks (20 Mbps in the paper).
    pub uplink: Vec<Link>,
    /// CC → EC downlinks (40 Mbps in the paper).
    pub downlink: Vec<Link>,
    /// Per-link fault processes (PR 7): loss / duplication / outage
    /// windows. Inert by default — the verdict methods short-circuit
    /// to `Deliver` without formatting a link name or touching a PRNG
    /// stream, so fault-free runs are byte-for-byte unchanged.
    pub faults: FaultPlane,
}

impl NetFabric {
    pub fn new(cfg: &NetConfig) -> Self {
        // one construction loop for all three per-EC links (LAN
        // segment + WAN pair), CC segment after — no copy-pasted
        // near-identical loops
        let mut clusters = Vec::with_capacity(cfg.num_ecs + 1);
        let mut uplink = Vec::with_capacity(cfg.num_ecs);
        let mut downlink = Vec::with_capacity(cfg.num_ecs);
        for ec in 0..cfg.num_ecs {
            clusters.push(ClusterNet::segment(
                format!("lan-ec{ec}"),
                Some(cfg.lan_mbps),
                cfg.lan_delay,
            ));
            uplink.push(Link::mbps(format!("up-ec{ec}"), cfg.uplink_mbps, cfg.wan_delay as f64));
            downlink.push(Link::mbps(
                format!("down-ec{ec}"),
                cfg.downlink_mbps,
                cfg.wan_delay as f64,
            ));
        }
        clusters.push(ClusterNet::segment(
            "lan-cc".to_string(),
            cfg.cc_lan_mbps,
            cfg.cc_lan_delay,
        ));
        let mut fab =
            NetFabric { clusters, uplink, downlink, faults: FaultPlane::default() };
        for spec in &cfg.nics {
            let Some(ci) = cfg.cluster_index(&spec.cluster) else {
                continue; // cluster not present in this run's shape
            };
            let name = format!("nic-{}-{}", spec.cluster, spec.node);
            let nic = if spec.mbps.is_finite() && spec.mbps > 0.0 {
                Nic::shaped(Link::mbps(name, spec.mbps, spec.delay_us))
            } else {
                Nic::unlimited(name)
            };
            fab.clusters[ci].upsert_nic(&spec.node, nic);
        }
        fab
    }

    /// Number of ECs (the CC is `clusters[num_ecs()]`).
    pub fn num_ecs(&self) -> usize {
        self.uplink.len()
    }

    /// Cluster index of the CC.
    pub fn cc_index(&self) -> usize {
        self.clusters.len() - 1
    }

    /// The shared LAN segment of cluster `ci`, if it has one.
    pub fn lan(&self, ci: usize) -> Option<&Link> {
        self.clusters.get(ci).and_then(|c| c.lan.as_ref())
    }

    /// Node `node`'s NIC in cluster `ci`, if it has one.
    pub fn nic(&self, ci: usize, node: &str) -> Option<&Nic> {
        self.clusters
            .get(ci)
            .and_then(|c| c.nic_slot(node).and_then(|s| c.nic_at(s)))
    }

    /// Slot of `node`'s NIC in cluster `ci` — [`NO_NIC`] when the node
    /// has none (or the cluster is out of shape). Resolve once at bind
    /// time, then charge through the `*_slot` methods.
    pub fn nic_slot(&self, ci: usize, node: &str) -> u32 {
        self.clusters
            .get(ci)
            .and_then(|c| c.nic_slot(node))
            .unwrap_or(NO_NIC)
    }

    /// Any bandwidth-constrained NIC anywhere? False = the flat
    /// degenerate model. (Placement activation uses the same
    /// predicate through `orchestrator::NetHints::is_degenerate`,
    /// whose entries are derived from these NICs via
    /// `NetHints::from_net` — keep the two in sync.)
    pub fn has_constrained_nics(&self) -> bool {
        self.clusters
            .iter()
            .any(|c| c.iter_nics().any(|(_, n)| !n.unlimited))
    }

    /// Charge `node`'s NIC at `now`; nodes without one are free.
    fn nic_send(&mut self, ci: usize, node: &str, now: SimTime, bytes: u64) -> SimTime {
        let slot = self.clusters[ci].nic_slot(node).unwrap_or(NO_NIC);
        self.nic_send_slot(ci, slot, now, bytes)
    }

    /// Charge the NIC in `slot` of cluster `ci` at `now`; [`NO_NIC`]
    /// is free. The dense-index twin of [`NetFabric::egress`] /
    /// [`NetFabric::ingress`] name lookups — the per-message hot path.
    fn nic_send_slot(&mut self, ci: usize, slot: u32, now: SimTime, bytes: u64) -> SimTime {
        match self.clusters[ci].nic_at_mut(slot) {
            Some(nic) => nic.send(now, bytes),
            None => now,
        }
    }

    /// Slot-indexed [`NetFabric::egress`]: src NIC only.
    pub fn egress_slot(&mut self, ci: usize, slot: u32, now: SimTime, bytes: u64) -> SimTime {
        self.nic_send_slot(ci, slot, now, bytes)
    }

    /// Slot-indexed [`NetFabric::lan_hop`]: cluster LAN, then the
    /// receiver's NIC.
    pub fn lan_hop_slot(&mut self, ci: usize, slot: u32, at: SimTime, bytes: u64) -> SimTime {
        let t = match &mut self.clusters[ci].lan {
            Some(lan) => lan.send(at, bytes),
            None => at,
        };
        self.nic_send_slot(ci, slot, t, bytes)
    }

    /// Slot-indexed [`NetFabric::ingress`]: dst NIC only.
    pub fn ingress_slot(&mut self, ci: usize, slot: u32, now: SimTime, bytes: u64) -> SimTime {
        self.nic_send_slot(ci, slot, now, bytes)
    }

    /// The egress leg of a publish leaving its node: src NIC only.
    /// One publish pays this AT MOST ONCE — the single physical
    /// transmit up to the cluster message service — however many
    /// receivers and bridges then fan out from the bus
    /// (`svcgraph::Fabric::route` charges it lazily on the first hop
    /// that leaves the node).
    pub fn egress(&mut self, ci: usize, src: &str, now: SimTime, bytes: u64) -> SimTime {
        self.nic_send(ci, src, now, bytes)
    }

    /// Bus → same-cluster receiver on another node: cluster LAN, then
    /// the receiver's NIC, each leg a FIFO queue starting where the
    /// previous one delivered.
    pub fn lan_hop(&mut self, ci: usize, dst: &str, at: SimTime, bytes: u64) -> SimTime {
        let slot = self.clusters[ci].nic_slot(dst).unwrap_or(NO_NIC);
        self.lan_hop_slot(ci, slot, at, bytes)
    }

    /// A complete same-cluster cross-node hop (src NIC → LAN → dst
    /// NIC) — the single-receiver convenience over
    /// [`NetFabric::egress`] + [`NetFabric::lan_hop`].
    pub fn intra_hop(
        &mut self,
        ci: usize,
        src: &str,
        dst: &str,
        now: SimTime,
        bytes: u64,
    ) -> SimTime {
        let t = self.egress(ci, src, now, bytes);
        self.lan_hop(ci, dst, t, bytes)
    }

    /// The local delivery leg after a bridge arrival: dst NIC only
    /// (the cluster message service sits on the receiving segment).
    pub fn ingress(&mut self, ci: usize, dst: &str, now: SimTime, bytes: u64) -> SimTime {
        self.nic_send(ci, dst, now, bytes)
    }

    /// EC `ec` → CC over the WAN uplink, starting at `at` (the
    /// sender-side egress delivery time). The WAN leg itself is
    /// unchanged from the flat model.
    pub fn wan_up(&mut self, ec: usize, at: SimTime, bytes: u64) -> SimTime {
        self.uplink[ec].send(at, bytes)
    }

    /// CC → EC `ec` over the WAN downlink, starting at `at`.
    pub fn wan_down(&mut self, ec: usize, at: SimTime, bytes: u64) -> SimTime {
        self.downlink[ec].send(at, bytes)
    }

    /// The CC-backbone leg between the border router and the CC bus —
    /// charged on every bridged message, AFTER the uplink (EC → CC)
    /// or BEFORE the downlink (CC → EC). A free backplane
    /// (`cc_lan_mbps: None`, the degenerate configuration) charges
    /// nothing and returns `at` unchanged, preserving the flat-model
    /// trajectories byte-for-byte.
    pub fn gateway_hop(&mut self, at: SimTime, bytes: u64) -> SimTime {
        let cc = self.cc_index();
        match &mut self.clusters[cc].lan {
            Some(lan) => lan.send(at, bytes),
            None => at,
        }
    }

    // --- fault plane (PR 7) -------------------------------------------
    //
    // Verdicts are consulted by the event-scheduling sites
    // (`svcgraph::Fabric::route`, the lifecycle instruction sender)
    // AFTER the link charged time/bytes: a lost message still occupied
    // the serialization queue (it was transmitted, then corrupted /
    // blackholed), only its delivery event is never pushed.

    /// Arm scenario-level i.i.d. loss / duplication on every link.
    pub fn arm_faults(&mut self, spec: FaultSpec) {
        self.faults.arm(spec);
    }

    /// Fate of one delivery on cluster `ci`'s LAN segment at `now`.
    pub fn lan_verdict(&mut self, ci: usize, now: SimTime) -> Verdict {
        if self.faults.is_idle() {
            return Verdict::Deliver;
        }
        let name = if ci == self.cc_index() {
            "lan-cc".to_string()
        } else {
            format!("lan-ec{ci}")
        };
        self.faults.verdict(&name, now)
    }

    /// Fate of one delivery on the EC `ec` → CC uplink at `now`.
    pub fn up_verdict(&mut self, ec: usize, now: SimTime) -> Verdict {
        if self.faults.is_idle() {
            return Verdict::Deliver;
        }
        self.faults.verdict(&format!("up-ec{ec}"), now)
    }

    /// Fate of one delivery on the CC → EC `ec` downlink at `now`.
    pub fn down_verdict(&mut self, ec: usize, now: SimTime) -> Verdict {
        if self.faults.is_idle() {
            return Verdict::Deliver;
        }
        self.faults.verdict(&format!("down-ec{ec}"), now)
    }

    /// Does `name` refer to one of this fabric's shared links?
    /// (NIC outages are expressed as `degrade-nic` instead.)
    pub fn has_link(&self, name: &str) -> bool {
        if name == "lan-cc" {
            return true;
        }
        for prefix in ["lan-ec", "up-ec", "down-ec"] {
            if let Some(k) = name.strip_prefix(prefix) {
                return k.parse::<usize>().is_ok_and(|k| k < self.num_ecs());
            }
        }
        false
    }

    /// Schedule a full outage `[from, until)` on a named shared link:
    /// every delivery sent inside the window is dropped (the `fail-
    /// link` scenario op). Unknown names are loud errors.
    pub fn fail_link(&mut self, link: &str, from: SimTime, until: SimTime) -> Result<()> {
        if !self.has_link(link) {
            bail!(
                "fail-link: unknown link '{link}' (lan-ec0..{}, up-ec*, down-ec*, lan-cc)",
                self.num_ecs().saturating_sub(1)
            );
        }
        self.faults.schedule_outage(link, from, until);
        Ok(())
    }

    /// Re-shape (or create) node `node`'s access link to `mbps` — the
    /// `degrade-nic` scenario op. Non-finite / non-positive `mbps`
    /// lifts the constraint back to an unlimited (count-only) NIC.
    pub fn degrade_nic(&mut self, cluster: &str, node: &str, mbps: f64) -> Result<()> {
        let ci = if cluster == "cc" {
            self.cc_index()
        } else {
            match parse_ec_leaf(cluster) {
                Some(n) if n <= self.num_ecs() => n - 1,
                _ => bail!("degrade-nic: unknown cluster '{cluster}' (ec-N|cc)"),
            }
        };
        let name = format!("nic-{cluster}-{node}");
        let nic = self.clusters[ci].nic_entry(node, || Nic::unlimited(name));
        if mbps.is_finite() && mbps > 0.0 {
            nic.unlimited = false;
            nic.link.set_bw_bps((mbps * 1e6) as u64);
        } else {
            nic.unlimited = true;
        }
        Ok(())
    }

    /// Messages dropped by the fault plane (loss + outages).
    pub fn msgs_lost(&self) -> u64 {
        self.faults.lost()
    }

    /// Messages duplicated by the fault plane.
    pub fn msgs_duplicated(&self) -> u64 {
        self.faults.duplicated()
    }

    /// Total WAN bytes (up + down) — the paper's BWC metric.
    pub fn wan_bytes(&self) -> u64 {
        self.uplink.iter().map(|l| l.bytes_sent).sum::<u64>()
            + self.downlink.iter().map(|l| l.bytes_sent).sum::<u64>()
    }

    /// Uplink-only bytes (crop uploads dominate; reported separately).
    pub fn wan_up_bytes(&self) -> u64 {
        self.uplink.iter().map(|l| l.bytes_sent).sum()
    }

    pub fn reset(&mut self) {
        for l in self.uplink.iter_mut().chain(self.downlink.iter_mut()) {
            l.reset();
        }
        for c in self.clusters.iter_mut() {
            if let Some(lan) = &mut c.lan {
                lan.reset();
            }
            for nic in c.iter_nics_mut() {
                nic.link.reset();
            }
        }
    }

    /// Per-NIC traffic/occupancy report — one [`LinkUtil`] per
    /// configured NIC, cluster order then node-name order, so the
    /// listing is deterministic. Unlimited NICs report their byte
    /// counters with zero busy time.
    pub fn nic_utilization(&self) -> Vec<LinkUtil> {
        let num_ecs = self.num_ecs();
        let mut out = Vec::new();
        for (ci, c) in self.clusters.iter().enumerate() {
            for (node, nic) in c.iter_nics() {
                out.push(LinkUtil {
                    cluster: cluster_leaf(ci, num_ecs),
                    node: node.clone(),
                    mbps: nic.mbps(),
                    bytes: nic.link.bytes_sent,
                    msgs: nic.link.msgs_sent,
                    busy_us: nic.link.busy_time,
                });
            }
        }
        out
    }
}

/// One NIC's traffic/occupancy summary (see
/// [`NetFabric::nic_utilization`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkUtil {
    /// Cluster leaf (`ec-1`.. / `cc`).
    pub cluster: String,
    /// Node leaf name.
    pub node: String,
    /// Access bandwidth in Mbps; `None` = unlimited (count-only).
    pub mbps: Option<f64>,
    /// Payload bytes accepted.
    pub bytes: u64,
    /// Messages accepted.
    pub msgs: u64,
    /// Serialization occupancy (µs).
    pub busy_us: SimTime,
}

impl LinkUtil {
    /// Fraction of `duration_us` the link spent transmitting, in
    /// [0, 1] (clamped: warm-up queues can carry occupancy past the
    /// measured window).
    pub fn busy_share(&self, duration_us: SimTime) -> f64 {
        if duration_us == 0 {
            0.0
        } else {
            (self.busy_us as f64 / duration_us as f64).min(1.0)
        }
    }
}

/// Standard sizes used by the video-query experiment (bytes).
pub mod sizes {
    /// One 32x32 RGB crop, 8-bit per channel, plus framing metadata.
    pub const CROP_BYTES: u64 = 32 * 32 * 3 + 64;
    /// A small control / metadata message (result record, EIL report).
    pub const META_BYTES: u64 = 128;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::millis;

    #[test]
    fn serialization_time_matches_bandwidth() {
        let l = Link::mbps("l", 20.0, 0.0);
        // 20 Mbps = 2.5 MB/s; 2500 bytes -> 1 ms
        assert_eq!(l.ser_time(2500), 1000);
    }

    #[test]
    fn fifo_queueing_accumulates() {
        let mut l = Link::mbps("l", 20.0, 50_000.0);
        let d1 = l.send(0, 2500);
        let d2 = l.send(0, 2500);
        assert_eq!(d1, 1000 + 50_000);
        assert_eq!(d2, 2000 + 50_000); // waits behind the first
        assert_eq!(l.bytes_sent, 5000);
        assert_eq!(l.backlog(0), 2000);
    }

    #[test]
    fn idle_link_restarts_at_now() {
        let mut l = Link::mbps("l", 20.0, 0.0);
        l.send(0, 2500);
        let d = l.send(10_000, 2500);
        assert_eq!(d, 11_000); // no residual backlog
    }

    #[test]
    fn degenerate_fabric_matches_flat_shape() {
        let net = NetFabric::new(&NetConfig {
            num_ecs: 3,
            wan_delay: millis(50.0),
            ..Default::default()
        });
        assert_eq!(net.num_ecs(), 3);
        assert_eq!(net.clusters.len(), 4, "3 ECs + the CC");
        assert_eq!(net.cc_index(), 3);
        assert_eq!(net.uplink.len(), 3);
        assert_eq!(net.uplink[0].delay, 50_000);
        assert!(net.lan(0).is_some(), "ECs keep their shared LAN");
        assert!(net.lan(3).is_none(), "degenerate CC is a free backplane");
        assert!(!net.has_constrained_nics());
        assert_eq!(net.wan_bytes(), 0);
    }

    #[test]
    fn wan_accounting_sums_both_directions() {
        let mut net = NetFabric::new(&NetConfig::default());
        net.uplink[0].send(0, 1000);
        net.downlink[2].send(0, 234);
        assert_eq!(net.wan_bytes(), 1234);
        assert_eq!(net.wan_up_bytes(), 1000);
        net.reset();
        assert_eq!(net.wan_bytes(), 0);
    }

    #[test]
    fn tiny_message_still_takes_time() {
        let l = Link::mbps("l", 1000.0, 0.0);
        assert!(l.ser_time(1) >= 1);
    }

    fn contended_cfg() -> NetConfig {
        NetConfig {
            num_ecs: 1,
            lan_mbps: 100.0,
            lan_delay: 500,
            cc_lan_mbps: Some(1000.0),
            cc_lan_delay: 100,
            nics: vec![
                NicSpec {
                    cluster: "ec-1".into(),
                    node: "rpi1".into(),
                    mbps: 8.0,
                    delay_us: 100.0,
                },
                NicSpec {
                    cluster: "cc".into(),
                    node: "srv1".into(),
                    mbps: 1000.0,
                    delay_us: 10.0,
                },
                NicSpec {
                    cluster: "ec-9".into(), // outside the shape: ignored
                    node: "ghost".into(),
                    mbps: 1.0,
                    delay_us: 0.0,
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn intra_hop_charges_src_nic_then_lan_then_dst_nic() {
        let mut net = NetFabric::new(&contended_cfg());
        assert!(net.has_constrained_nics());
        assert!(net.nic(0, "rpi1").is_some());
        assert!(net.nic(0, "ghost").is_none(), "out-of-shape spec ignored");
        // 10_000 B from rpi1 (8 Mbps NIC, 100 µs) through the 100 Mbps
        // LAN (500 µs) to a node with no NIC:
        //   NIC ser 10_000*8/8e6 s = 10 ms, +100 µs → t=10_100
        //   LAN ser 800 µs, +500 µs → t=11_400; dst free
        let d = net.intra_hop(0, "rpi1", "rpi2", 0, 10_000);
        assert_eq!(d, 10_000 + 100 + 800 + 500);
        assert_eq!(net.nic(0, "rpi1").unwrap().link.bytes_sent, 10_000);
        assert_eq!(net.lan(0).unwrap().bytes_sent, 10_000);
        assert_eq!(net.wan_bytes(), 0, "intra-cluster hop must not touch the WAN");
        // reverse direction: src has no NIC, dst NIC queues AFTER the
        // LAN delivered (hop-by-hop FIFO legs)
        let d2 = net.intra_hop(0, "rpi2", "rpi1", 0, 10_000);
        // LAN busy until 10_800+800=... the LAN is FIFO: second send at
        // t=0 starts when the first frees it (800*2 ser) then +500;
        // then rpi1's NIC (busy until 10_100) takes 10 ms more.
        assert!(d2 > d, "dst NIC must queue behind the earlier egress");
    }

    #[test]
    fn wan_legs_start_at_the_egress_delivery_time() {
        let mut net = NetFabric::new(&contended_cfg());
        // at = now (no NIC upstream): exactly the flat model's charge
        let d = net.wan_up(0, 0, 2_500);
        assert_eq!(d, net.uplink[0].ser_time(2_500));
        // a constrained src pays its NIC through `egress` first, and
        // the uplink leg starts at that delivery time: 2.5 kB at
        // 8 Mbps = 2.5 ms, + 100 µs
        let nic_d = net.egress(0, "rpi1", 0, 2_500);
        assert_eq!(nic_d, 2_500 + 100);
        // uplink was busy until d; second message queues behind it
        let d2 = net.wan_up(0, nic_d, 2_500);
        assert_eq!(d2, d.max(nic_d) + net.uplink[0].ser_time(2_500));
        // CC-side egress feeds the downlink: 2.5 kB at 1000 Mbps =
        // 20 µs, + 10 µs
        let cc = net.cc_index();
        let srv_nic = net.egress(cc, "srv1", 0, 2_500);
        assert_eq!(srv_nic, 20 + 10);
        let d3 = net.wan_down(0, srv_nic, 2_500);
        assert_eq!(d3, srv_nic + net.downlink[0].ser_time(2_500));
    }

    #[test]
    fn lan_hop_is_bus_to_receiver_only() {
        // `egress` + N x `lan_hop` is the fan-out shape: the source
        // NIC is paid once, every receiver then pays LAN + own NIC
        let mut net = NetFabric::new(&contended_cfg());
        let bus_at = net.egress(0, "rpi1", 0, 10_000);
        assert_eq!(bus_at, 10_000 + 100);
        let d1 = net.lan_hop(0, "rpi2", bus_at, 10_000);
        assert_eq!(d1, bus_at + 800 + 500);
        // the second receiver queues on the LAN, not on rpi1's NIC
        let d2 = net.lan_hop(0, "rpi3", bus_at, 10_000);
        assert_eq!(d2, bus_at + 2 * 800 + 500);
        assert_eq!(
            net.nic(0, "rpi1").unwrap().link.msgs_sent,
            1,
            "one publish = one egress serialization, however many receivers"
        );
    }

    #[test]
    fn ingress_charges_only_the_destination_nic() {
        let mut net = NetFabric::new(&contended_cfg());
        let free = net.ingress(0, "rpi2", 1000, 50_000);
        assert_eq!(free, 1000, "no NIC: bridge fan-out is free");
        let nic = net.ingress(0, "rpi1", 1000, 8_000);
        assert_eq!(nic, 1000 + 8_000 + 100); // 8 Mbps → 1 µs/byte, +100 µs
        assert_eq!(net.lan(0).unwrap().bytes_sent, 0, "ingress must not touch the LAN");
    }

    #[test]
    fn unlimited_nic_counts_but_never_delays() {
        let mut cfg = contended_cfg();
        cfg.nics.push(NicSpec {
            cluster: "ec-1".into(),
            node: "rpi3".into(),
            mbps: f64::INFINITY,
            delay_us: 0.0,
        });
        let mut net = NetFabric::new(&cfg);
        assert_eq!(net.nic(0, "rpi3").unwrap().mbps(), None);
        let d = net.ingress(0, "rpi3", 777, 1 << 30);
        assert_eq!(d, 777, "unlimited NIC must add zero time");
        assert_eq!(net.nic(0, "rpi3").unwrap().link.bytes_sent, 1 << 30);
        assert_eq!(net.nic(0, "rpi3").unwrap().link.msgs_sent, 1);
    }

    #[test]
    fn cc_lan_charges_cross_node_cc_hops() {
        let mut net = NetFabric::new(&contended_cfg());
        let cc = net.cc_index();
        // 125_000 B on a 1000 Mbps CC LAN = 1 ms ser + 100 µs delay,
        // srv1's NIC (1000 Mbps, 10 µs) pays first: 1 ms + 10 µs
        let d = net.intra_hop(cc, "srv1", "srv2", 0, 125_000);
        assert_eq!(d, (1000 + 10) + (1000 + 100));
        assert_eq!(net.lan(cc).unwrap().bytes_sent, 125_000);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mk = || {
            let mut l = Link::mbps("j", 100.0, 1000.0);
            l.jitter = 5000;
            l
        };
        let (mut a, mut b) = (mk(), mk());
        for i in 0..200u64 {
            let da = a.send(i * 10_000, 100);
            let db = b.send(i * 10_000, 100);
            assert_eq!(da, db, "jitter must be deterministic");
            // base delivery = start + ser + delay; jitter adds <= 5000
            let base = i * 10_000 + a.ser_time(100).max(1) + 1000;
            assert!(da >= base && da <= base + 5000, "msg {i}: {da} vs {base}");
        }
    }

    #[test]
    fn jitter_never_reorders_a_fifo_link() {
        // regression: with jitter much larger than serialization time,
        // back-to-back sends used to get independent jitter samples, so
        // message n+1 (small sample) could arrive before message n
        // (large sample) — impossible on a FIFO serialization queue.
        // The clamp makes delivery times monotonic per link.
        let mut l = Link::mbps("fifo-jitter", 1000.0, 1000.0);
        l.jitter = 50_000; // 50 ms of jitter vs ~1 us serialization
        let mut last = 0;
        let mut clamped = false;
        for i in 0..500u64 {
            let d = l.send(i, 100); // near-simultaneous sends
            assert!(d >= last, "msg {i}: delivery {d} before previous {last}");
            if d == last && i > 0 {
                clamped = true;
            }
            last = d;
        }
        // the clamp must actually have fired for this jitter profile,
        // otherwise the regression test tests nothing
        assert!(clamped, "expected at least one clamped delivery");
    }

    #[test]
    fn reshaping_bandwidth_changes_ser_time() {
        let mut l = Link::mbps("r", 20.0, 0.0);
        let before = l.ser_time(2500);
        l.set_bw_bps((5.0 * 1e6) as u64); // degrade to 5 Mbps
        assert_eq!(l.ser_time(2500), before * 4);
        l.set_bw_bps(0); // clamps, never div-by-zero
        assert!(l.ser_time(1) > 0);
    }

    #[test]
    fn net_overrides_parse_and_apply() {
        let doc = crate::yamlite::parse(
            "
lan_mbps: 50
wan_delay_ms: 25
cc_nodes: 2
cc_lan_mbps: 1000
nics:
  - cluster: ec-1
    node: rpi1
    mbps: 2
    delay_ms: 0.2
  - cluster: cc
    node: gpu-ws
    mbps: 1000
",
        )
        .unwrap();
        let ov = NetOverrides::from_value(&doc).unwrap();
        assert_eq!(ov.cc_nodes, Some(2));
        assert_eq!(ov.nics.len(), 2);
        assert_eq!(ov.nics[0].delay_us, 200.0);
        assert_eq!(ov.nics[1].delay_us, 0.0);
        let mut cfg = NetConfig::default();
        ov.apply(&mut cfg);
        assert_eq!(cfg.lan_mbps, 50.0);
        assert_eq!(cfg.wan_delay, 25_000);
        assert_eq!(cfg.uplink_mbps, 20.0, "absent fields keep the base value");
        assert_eq!(cfg.cc_lan_mbps, Some(1000.0));
        assert_eq!(cfg.nics.len(), 2);
        let net = NetFabric::new(&cfg);
        assert!(net.has_constrained_nics());
        assert!(net.nic(0, "rpi1").is_some());
        assert!(net.nic(3, "gpu-ws").is_some());
    }

    #[test]
    fn net_overrides_reject_garbage() {
        let bad = crate::yamlite::parse("nics:\n  - node: rpi1\n    mbps: 2\n").unwrap();
        assert!(NetOverrides::from_value(&bad).is_err(), "missing cluster");
        for leaf in ["lan-7", "ec-0", "ec-abc", "ec-"] {
            let bad = crate::yamlite::parse(&format!(
                "nics:\n  - cluster: {leaf}\n    node: x\n    mbps: 2\n"
            ))
            .unwrap();
            assert!(NetOverrides::from_value(&bad).is_err(), "bad cluster leaf '{leaf}'");
        }
        let bad =
            crate::yamlite::parse("nics:\n  - cluster: ec-1\n    node: x\n").unwrap();
        assert!(NetOverrides::from_value(&bad).is_err(), "missing mbps");
        // zero/negative link bandwidths would divide by zero downstream
        for field in ["lan_mbps", "uplink_mbps", "downlink_mbps", "cc_lan_mbps"] {
            for v in ["0", "-5"] {
                let bad = crate::yamlite::parse(&format!("{field}: {v}\n")).unwrap();
                assert!(
                    NetOverrides::from_value(&bad).is_err(),
                    "{field}: {v} must be rejected"
                );
            }
        }
        // present-but-mistyped fields are loud errors, never a silent
        // fallback to the base value
        for doc in [
            "wan_delay_ms: \"50\"\n",
            "lan_mbps: fast\n",
            "cc_nodes: two\n",
            "cc_nodes: 2.9\n",
            "cc_nodes: -2\n",
            "nics:\n  - cluster: ec-1\n    node: x\n    mbps: 2\n    delay_ms: abc\n",
        ] {
            let v = crate::yamlite::parse(doc).unwrap();
            assert!(NetOverrides::from_value(&v).is_err(), "must reject: {doc}");
        }
        // and the Link constructor clamps even if one slips through
        assert!(Link::mbps("z", 0.0, 0.0).ser_time(1) >= 1);
        assert!(Link::mbps("n", f64::NAN, 0.0).ser_time(1_000_000) > 0);
    }

    #[test]
    fn gateway_hop_charges_the_cc_lan_only_when_modelled() {
        // degenerate CC (free backplane): zero time, zero counters
        let mut flat = NetFabric::new(&NetConfig::default());
        assert_eq!(flat.gateway_hop(4_321, 1 << 20), 4_321);
        assert!(flat.lan(flat.cc_index()).is_none());
        // shaped CC LAN: 2.5 kB at 1000 Mbps = 20 µs ser + 100 µs
        let mut net = NetFabric::new(&contended_cfg());
        assert_eq!(net.gateway_hop(0, 2_500), 120);
        let cc = net.cc_index();
        assert_eq!(net.lan(cc).unwrap().bytes_sent, 2_500);
        // FIFO: a second bridged message queues behind the first
        assert_eq!(net.gateway_hop(0, 2_500), 140);
    }

    #[test]
    fn busy_time_counts_serialization_occupancy_only() {
        let mut l = Link::mbps("b", 20.0, 50_000.0);
        l.send(0, 2500); // 1 ms ser
        l.send(0, 2500); // queues: 1 ms more ser, zero idle between
        assert_eq!(l.busy_time, 2_000, "delay/jitter are not occupancy");
        l.send(10_000, 2500); // idle 2 ms..10 ms gap is not counted
        assert_eq!(l.busy_time, 3_000);
        l.reset();
        assert_eq!(l.busy_time, 0);
    }

    #[test]
    fn nic_utilization_reports_every_nic_deterministically() {
        let mut cfg = contended_cfg();
        cfg.nics.push(NicSpec {
            cluster: "ec-1".into(),
            node: "cam2".into(),
            mbps: f64::INFINITY, // unlimited: counted, never busy
            delay_us: 0.0,
        });
        let mut net = NetFabric::new(&cfg);
        net.egress(0, "rpi1", 0, 2_500); // 8 Mbps → 2.5 ms busy
        net.ingress(0, "cam2", 0, 9_999);
        let util = net.nic_utilization();
        // BTreeMap order within the cluster: cam2 before rpi1
        let names: Vec<_> =
            util.iter().map(|u| (u.cluster.as_str(), u.node.as_str())).collect();
        assert_eq!(names, vec![("ec-1", "cam2"), ("ec-1", "rpi1"), ("cc", "srv1")]);
        assert_eq!(util[0].bytes, 9_999);
        assert_eq!(util[0].busy_us, 0, "unlimited NICs are never busy");
        assert_eq!(util[0].mbps, None);
        assert_eq!(util[1].bytes, 2_500);
        assert_eq!(util[1].busy_us, 2_500);
        assert!((util[1].busy_share(1_000_000) - 0.0025).abs() < 1e-12);
        assert_eq!(util[1].busy_share(0), 0.0);
        assert_eq!(util[2].bytes, 0, "idle NICs still show up");
    }

    #[test]
    fn fabric_verdicts_idle_by_default_and_fault_when_armed() {
        let mut net = NetFabric::new(&NetConfig::default());
        assert!(net.faults.is_idle());
        for i in 0..100u64 {
            assert_eq!(net.up_verdict(0, i), Verdict::Deliver);
            assert_eq!(net.lan_verdict(0, i), Verdict::Deliver);
            assert_eq!(net.down_verdict(2, i), Verdict::Deliver);
        }
        assert!(net.faults.is_idle(), "idle verdicts must not materialize state");
        net.arm_faults(FaultSpec { seed: 7, loss: 0.3, dup: 0.0 });
        let mut dropped = 0;
        for i in 0..2_000u64 {
            if net.up_verdict(0, i) == Verdict::Drop {
                dropped += 1;
            }
        }
        assert!(dropped > 0);
        assert_eq!(net.msgs_lost(), dropped);
        // the CC LAN rides its own stream under the canonical name
        net.lan_verdict(net.cc_index(), 0);
        assert!(net.faults.link("lan-cc").is_some());
    }

    #[test]
    fn fail_link_schedules_an_outage_on_known_links_only() {
        let mut net = NetFabric::new(&NetConfig::default());
        assert!(net.fail_link("up-ec0", 1_000, 2_000).is_ok());
        assert!(net.fail_link("lan-cc", 0, 10).is_ok());
        for bad in ["up-ec3", "lan-ec9", "wan-up-0", "nic-ec-1-rpi1", ""] {
            assert!(net.fail_link(bad, 0, 1).is_err(), "must reject '{bad}'");
        }
        assert_eq!(net.up_verdict(0, 1_500), Verdict::Drop);
        assert_eq!(net.up_verdict(0, 2_500), Verdict::Deliver);
        assert_eq!(net.up_verdict(1, 1_500), Verdict::Deliver, "other links unaffected");
        assert_eq!(net.msgs_lost(), 1);
    }

    #[test]
    fn degrade_nic_reshapes_or_creates_the_access_link() {
        let mut net = NetFabric::new(&contended_cfg());
        // reshape the existing 8 Mbps NIC down to 2 Mbps
        net.degrade_nic("ec-1", "rpi1", 2.0).unwrap();
        let nic = net.nic(0, "rpi1").unwrap();
        assert_eq!(nic.mbps(), Some(2.0));
        // create a constraint on a previously-unmodelled node
        assert!(net.nic(0, "rpi2").is_none());
        net.degrade_nic("ec-1", "rpi2", 1.0).unwrap();
        assert_eq!(net.nic(0, "rpi2").unwrap().mbps(), Some(1.0));
        // lift the constraint back to unlimited
        net.degrade_nic("ec-1", "rpi2", f64::INFINITY).unwrap();
        assert_eq!(net.nic(0, "rpi2").unwrap().mbps(), None);
        assert!(net.degrade_nic("ec-9", "x", 1.0).is_err());
        assert!(net.degrade_nic("lan", "x", 1.0).is_err());
    }

    #[test]
    fn cluster_index_maps_leafs() {
        let cfg = NetConfig { num_ecs: 2, ..Default::default() };
        assert_eq!(cfg.cluster_index("ec-1"), Some(0));
        assert_eq!(cfg.cluster_index("ec-2"), Some(1));
        assert_eq!(cfg.cluster_index("cc"), Some(2));
        assert_eq!(cfg.cluster_index("ec-3"), None);
        assert_eq!(cfg.cluster_index("ec-0"), None);
        assert_eq!(cfg.cluster_index("nope"), None);
    }
}
