//! Video substrate: synthetic camera streams (Data Generator) and the
//! frame-differencing Object Detector (§5.1.2).

pub mod od;
pub mod synth;

pub use od::{Crop, ObjectDetector, OdConfig};
pub use synth::{CameraStream, Image};
