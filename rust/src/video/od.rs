//! Object Detector (OD): three-frame differencing + crop extraction.
//!
//! §5.1.2: "OD on edge nodes was implemented using frame differencing
//! (cropping regions with salient pixel differences across frames)
//! instead of accurate but complex object detectors like YOLOv3 for
//! rapid crop extraction on resource-limited edge nodes."
//!
//! The motion score is identical to the L1 Pallas `framediff` kernel
//! (min of consecutive abs-diffs, 3x3 box mean — see
//! `python/compile/kernels/framediff.py`); this native implementation
//! is the hot path, the XLA artifact is the offload variant used by the
//! kernel-parity integration test and the OD ablation bench.

use super::synth::{Image, CROP};

#[derive(Debug, Clone, Copy)]
pub struct OdConfig {
    /// motion-score threshold for the binary mask
    pub threshold: f32,
    /// minimum connected-component area (pixels) to become a crop
    pub min_area: usize,
    /// cap on crops per detection (the busiest frames)
    pub max_crops: usize,
}

impl Default for OdConfig {
    fn default() -> Self {
        // min_area 16 merges edge fragments of one object; max_crops 2
        // matches the few-moving-objects-per-frame regime of the
        // paper's surveillance streams (2 object slots per camera).
        OdConfig { threshold: 0.06, min_area: 16, max_crops: 2 }
    }
}

/// Motion score map — the native mirror of the framediff kernel.
pub fn motion_map(f0: &[f32], f1: &[f32], f2: &[f32], h: usize, w: usize) -> Vec<f32> {
    debug_assert_eq!(f0.len(), h * w);
    let mut m = vec![0.0f32; h * w];
    for i in 0..h * w {
        let d1 = (f1[i] - f0[i]).abs();
        let d2 = (f2[i] - f1[i]).abs();
        m[i] = d1.min(d2);
    }
    // 3x3 box mean with zero padding
    let mut out = vec![0.0f32; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let yy = y as i64 + dy;
                    let xx = x as i64 + dx;
                    if yy >= 0 && yy < h as i64 && xx >= 0 && xx < w as i64 {
                        acc += m[yy as usize * w + xx as usize];
                    }
                }
            }
            out[y * w + x] = acc * (1.0 / 9.0);
        }
    }
    out
}

/// A connected motion region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Region {
    pub cy: usize,
    pub cx: usize,
    pub area: usize,
    pub score: f32,
}

/// 4-connected components over `map > threshold`, centroid + area.
pub fn find_regions(map: &[f32], h: usize, w: usize, cfg: &OdConfig) -> Vec<Region> {
    let mut seen = vec![false; h * w];
    let mut regions = Vec::new();
    let mut stack = Vec::new();
    for start in 0..h * w {
        if seen[start] || map[start] <= cfg.threshold {
            continue;
        }
        // flood fill
        let mut area = 0usize;
        let mut sum_y = 0usize;
        let mut sum_x = 0usize;
        let mut score = 0.0f32;
        stack.push(start);
        seen[start] = true;
        while let Some(i) = stack.pop() {
            let y = i / w;
            let x = i % w;
            area += 1;
            sum_y += y;
            sum_x += x;
            score += map[i];
            if y > 0 && !seen[i - w] && map[i - w] > cfg.threshold {
                seen[i - w] = true;
                stack.push(i - w);
            }
            if y + 1 < h && !seen[i + w] && map[i + w] > cfg.threshold {
                seen[i + w] = true;
                stack.push(i + w);
            }
            if x > 0 && !seen[i - 1] && map[i - 1] > cfg.threshold {
                seen[i - 1] = true;
                stack.push(i - 1);
            }
            if x + 1 < w && !seen[i + 1] && map[i + 1] > cfg.threshold {
                seen[i + 1] = true;
                stack.push(i + 1);
            }
        }
        if area >= cfg.min_area {
            regions.push(Region {
                cy: sum_y / area,
                cx: sum_x / area,
                area,
                score,
            });
        }
    }
    // strongest motion first; cap
    regions.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    regions.truncate(cfg.max_crops);
    regions
}

/// Extract a CROPxCROP RGB window centered at (cy, cx), clamped to the
/// frame (flattened (y, x, c) f32s — the classifier input layout).
pub fn extract_crop(frame: &Image, cy: usize, cx: usize) -> Vec<f32> {
    let half = CROP / 2;
    let y0 = (cy as i64 - half as i64).clamp(0, (frame.h - CROP) as i64) as usize;
    let x0 = (cx as i64 - half as i64).clamp(0, (frame.w - CROP) as i64) as usize;
    let mut out = Vec::with_capacity(CROP * CROP * 3);
    for y in y0..y0 + CROP {
        let row = (y * frame.w + x0) * 3;
        out.extend_from_slice(&frame.data[row..row + CROP * 3]);
    }
    out
}

/// The OD component: detect moving objects across three frames and
/// return classifier-ready crops (taken from the middle frame).
pub struct ObjectDetector {
    pub cfg: OdConfig,
}

#[derive(Debug, Clone)]
pub struct Crop {
    pub pixels: Vec<f32>,
    pub region: Region,
}

impl ObjectDetector {
    pub fn new(cfg: OdConfig) -> Self {
        ObjectDetector { cfg }
    }

    pub fn detect(&self, f0: &Image, f1: &Image, f2: &Image) -> Vec<Crop> {
        let (h, w) = (f1.h, f1.w);
        let map = motion_map(&f0.gray(), &f1.gray(), &f2.gray(), h, w);
        find_regions(&map, h, w, &self.cfg)
            .into_iter()
            .map(|r| Crop { pixels: extract_crop(f1, r.cy, r.cx), region: r })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::synth::{render_object, CameraStream, Image};

    /// Synthetic motion: object at two positions over a static bg.
    fn frames_with_moving_object() -> (Image, Image, Image) {
        let mk = |x: i64| {
            let mut img = Image::zeros(96, 160);
            for v in &mut img.data {
                *v = 0.5;
            }
            render_object(&mut img, 2, 77, x, 30, 8);
            img
        };
        (mk(40), mk(46), mk(52))
    }

    #[test]
    fn detects_moving_object() {
        let (f0, f1, f2) = frames_with_moving_object();
        let od = ObjectDetector::new(OdConfig::default());
        let crops = od.detect(&f0, &f1, &f2);
        assert!(!crops.is_empty(), "no motion detected");
        // centroid near the middle frame's object center (46+16, 30+16)
        let r = crops[0].region;
        assert!((r.cx as i64 - 62).abs() < 16, "cx={}", r.cx);
        assert!((r.cy as i64 - 46).abs() < 16, "cy={}", r.cy);
        assert_eq!(crops[0].pixels.len(), CROP * CROP * 3);
    }

    #[test]
    fn static_scene_yields_nothing() {
        let mut img = Image::zeros(96, 160);
        for v in &mut img.data {
            *v = 0.5;
        }
        let od = ObjectDetector::new(OdConfig::default());
        assert!(od.detect(&img, &img.clone(), &img.clone()).is_empty());
    }

    #[test]
    fn temporal_noise_is_suppressed() {
        // camera frames with no objects: only sensor noise differs
        let mut s = CameraStream::new(55, 0);
        s.advance_to(0.0);
        let f0 = s.frame_at(0.0);
        let f1 = s.frame_at(1.0 / 30.0);
        let f2 = s.frame_at(2.0 / 30.0);
        let od = ObjectDetector::new(OdConfig::default());
        let crops = od.detect(&f0, &f1, &f2);
        assert!(crops.is_empty(), "noise produced {} crops", crops.len());
    }

    #[test]
    fn live_stream_objects_are_detected() {
        let mut s = CameraStream::new(9, 3);
        let mut hits = 0;
        for i in 0..10 {
            let t = 1.0 + i as f64 * 0.5;
            s.advance_to(t + 0.2);
            let f0 = s.frame_at(t);
            let f1 = s.frame_at(t + 0.1);
            let f2 = s.frame_at(t + 0.2);
            let od = ObjectDetector::new(OdConfig::default());
            hits += od.detect(&f0, &f1, &f2).len();
        }
        assert!(hits >= 5, "only {hits} crops across 10 samples");
    }

    #[test]
    fn crop_window_clamps_at_borders() {
        let img = Image::zeros(96, 160);
        let c1 = extract_crop(&img, 0, 0);
        let c2 = extract_crop(&img, 95, 159);
        assert_eq!(c1.len(), CROP * CROP * 3);
        assert_eq!(c2.len(), CROP * CROP * 3);
    }

    #[test]
    fn min_area_filters_specks() {
        let mut map = vec![0.0f32; 96 * 160];
        map[50 * 160 + 50] = 1.0; // single-pixel spark
        let cfg = OdConfig::default();
        assert!(find_regions(&map, 96, 160, &cfg).is_empty());
    }

    #[test]
    fn motion_map_matches_kernel_semantics() {
        // hand-check one pixel: constant frames -> zero map
        let f = vec![0.3f32; 6 * 8];
        let m = motion_map(&f, &f, &f, 6, 8);
        assert!(m.iter().all(|v| *v == 0.0));
    }
}
