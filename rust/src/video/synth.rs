//! Procedural scene renderer — bit-exact mirror of
//! `python/compile/scenes.py` (the shared python<->rust scene spec).
//!
//! Determinism contract (see scenes.py): integer geometry, f32 colors
//! computed in f64 then rounded once (matching numpy's
//! `np.float32(py_float_expr)`), noise drawn from the indexed SplitMix64
//! streams in `util::prng`, primitives applied in a fixed order.
//! `rust/tests/golden_scenes.rs` asserts bit-identical crops against
//! `artifacts/golden/crops.bin`.

use crate::util::prng;

pub const CROP: usize = 32;
pub const NUM_CLASSES: usize = 8;
/// "motorcycle" — the §5 query target.
pub const TARGET_CLASS: u8 = 1;

pub const CLASSES: [&str; 8] = [
    "background",
    "motorcycle",
    "car",
    "person",
    "bus",
    "bicycle",
    "truck",
    "dog",
];

pub const DARK: [f32; 3] = [0.08, 0.08, 0.10];
pub const LIGHT: [f32; 3] = [0.85, 0.88, 0.92];

/// Row-major (y, x, c) RGB f32 image.
#[derive(Debug, Clone)]
pub struct Image {
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Image {
    pub fn zeros(h: usize, w: usize) -> Self {
        Image { h, w, data: vec![0.0; h * w * 3] }
    }

    #[inline]
    pub fn idx(&self, y: usize, x: usize, c: usize) -> usize {
        (y * self.w + x) * 3 + c
    }

    #[inline]
    pub fn set_px(&mut self, y: usize, x: usize, color: &[f32; 3]) {
        let i = self.idx(y, x, 0);
        self.data[i] = color[0];
        self.data[i + 1] = color[1];
        self.data[i + 2] = color[2];
    }

    pub fn clip01(&mut self) {
        for v in &mut self.data {
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// Grayscale plane: (r + g + b) / 3 per pixel.
    pub fn gray(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.h * self.w);
        for p in self.data.chunks_exact(3) {
            out.push((p[0] + p[1] + p[2]) * (1.0 / 3.0));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Primitives (mirror of scenes.py; same names, same semantics)
// ---------------------------------------------------------------------------

pub fn fill_rect(img: &mut Image, x0: i64, y0: i64, x1: i64, y1: i64, color: &[f32; 3]) {
    let ys = y0.max(0) as usize;
    let ye = y1.clamp(0, img.h as i64) as usize;
    let xs = x0.max(0) as usize;
    let xe = x1.clamp(0, img.w as i64) as usize;
    for y in ys..ye {
        for x in xs..xe {
            img.set_px(y, x, color);
        }
    }
}

pub fn fill_disk(img: &mut Image, cx: i64, cy: i64, r: i64, color: &[f32; 3]) {
    let ys = (cy - r).max(0) as usize;
    let ye = (cy + r + 1).clamp(0, img.h as i64) as usize;
    let xs = (cx - r).max(0) as usize;
    let xe = (cx + r + 1).clamp(0, img.w as i64) as usize;
    for y in ys..ye {
        for x in xs..xe {
            let dx = x as i64 - cx;
            let dy = y as i64 - cy;
            if dx * dx + dy * dy <= r * r {
                img.set_px(y, x, color);
            }
        }
    }
}

pub fn fill_ring(img: &mut Image, cx: i64, cy: i64, r: i64, w: i64, color: &[f32; 3]) {
    let inner = (r - w).max(0);
    let ys = (cy - r).max(0) as usize;
    let ye = (cy + r + 1).clamp(0, img.h as i64) as usize;
    let xs = (cx - r).max(0) as usize;
    let xe = (cx + r + 1).clamp(0, img.w as i64) as usize;
    for y in ys..ye {
        for x in xs..xe {
            let dx = x as i64 - cx;
            let dy = y as i64 - cy;
            let d2 = dx * dx + dy * dy;
            if d2 <= r * r && d2 >= inner * inner {
                img.set_px(y, x, color);
            }
        }
    }
}

#[inline]
fn sc(v: i64, s8: i64) -> i64 {
    (v * s8).div_euclid(8)
}

/// Draw one object of class `cls` at offset (ox, oy) with scale s8/8.
/// Stream index map matches scenes.py: 3,4,5 = body RGB.
pub fn render_object(img: &mut Image, cls: u8, seed: u64, ox: i64, oy: i64, s8: i64) {
    if cls == 0 {
        return;
    }
    // numpy computes f(i)*0.8+0.1 in f64 then casts to f32 once
    let f = |i: u64| -> f32 { (prng::f32_at(seed, i) as f64 * 0.8 + 0.1) as f32 };
    let body = [f(3), f(4), f(5)];
    let xx = |v: i64| ox + sc(v, s8);
    let yy = |v: i64| oy + sc(v, s8);
    let rr = |v: i64| sc(v, s8).max(1);
    match cls {
        1 => {
            // motorcycle: two small filled wheels, low body, handlebar
            fill_rect(img, xx(6), yy(14), xx(26), yy(19), &body);
            fill_rect(img, xx(10), yy(10), xx(18), yy(14), &body);
            fill_rect(img, xx(22), yy(8), xx(24), yy(16), &DARK);
            fill_disk(img, xx(8), yy(24), rr(4), &DARK);
            fill_disk(img, xx(24), yy(24), rr(4), &DARK);
        }
        2 => {
            // car: wide body + cabin + two wheels
            fill_rect(img, xx(3), yy(12), xx(29), yy(22), &body);
            fill_rect(img, xx(9), yy(6), xx(23), yy(12), &body);
            fill_rect(img, xx(11), yy(7), xx(21), yy(11), &LIGHT);
            fill_disk(img, xx(9), yy(23), rr(3), &DARK);
            fill_disk(img, xx(23), yy(23), rr(3), &DARK);
        }
        3 => {
            // person: head + torso + two legs
            fill_disk(img, xx(16), yy(7), rr(3), &body);
            fill_rect(img, xx(13), yy(10), xx(19), yy(22), &body);
            fill_rect(img, xx(13), yy(22), xx(15), yy(29), &DARK);
            fill_rect(img, xx(17), yy(22), xx(19), yy(29), &DARK);
        }
        4 => {
            // bus: large box, window strip, two wheels
            fill_rect(img, xx(3), yy(6), xx(29), yy(24), &body);
            fill_rect(img, xx(5), yy(9), xx(27), yy(13), &LIGHT);
            fill_disk(img, xx(9), yy(25), rr(3), &DARK);
            fill_disk(img, xx(23), yy(25), rr(3), &DARK);
        }
        5 => {
            // bicycle: two RINGS (vs motorcycle's disks) + thin frame
            fill_ring(img, xx(9), yy(22), rr(5), sc(2, s8).max(1), &DARK);
            fill_ring(img, xx(23), yy(22), rr(5), sc(2, s8).max(1), &DARK);
            fill_rect(img, xx(9), yy(13), xx(23), yy(15), &body);
            fill_rect(img, xx(15), yy(9), xx(17), yy(14), &body);
        }
        6 => {
            // truck: trailer + cab + three wheels
            fill_rect(img, xx(3), yy(8), xx(20), yy(22), &body);
            fill_rect(img, xx(21), yy(12), xx(29), yy(22), &body);
            fill_rect(img, xx(23), yy(13), xx(28), yy(17), &LIGHT);
            fill_disk(img, xx(8), yy(23), rr(3), &DARK);
            fill_disk(img, xx(16), yy(23), rr(3), &DARK);
            fill_disk(img, xx(25), yy(23), rr(3), &DARK);
        }
        7 => {
            // dog: body + head + four legs + tail
            fill_rect(img, xx(8), yy(14), xx(24), yy(20), &body);
            fill_disk(img, xx(25), yy(12), rr(3), &body);
            fill_rect(img, xx(9), yy(20), xx(11), yy(26), &body);
            fill_rect(img, xx(13), yy(20), xx(15), yy(26), &body);
            fill_rect(img, xx(17), yy(20), xx(19), yy(26), &body);
            fill_rect(img, xx(21), yy(20), xx(23), yy(26), &body);
            fill_rect(img, xx(6), yy(12), xx(8), yy(16), &body);
        }
        _ => panic!("unknown class {cls}"),
    }
}

pub const NOISE_SIGMA: f32 = 0.06;

/// Textured background: base gray + horizontal gradient + pixel noise.
/// Noise index for (y, x, c) is `(y*W + x)*3 + c`, starting at 16.
pub fn paint_background(img: &mut Image, seed: u64, sigma: f32) {
    let g = (prng::f32_at(seed, 0) as f64 * 0.3 + 0.35) as f32;
    let grad = (prng::f32_at(seed, 1) as f64 * 0.2 - 0.1) as f32;
    let w = img.w;
    let h = img.h;
    let scale = 2.0f32 * sigma;
    for y in 0..h {
        for x in 0..w {
            let base = g + grad * (x as f32 / w as f32);
            for c in 0..3 {
                let i = ((y * w + x) * 3 + c) as u64;
                let n = prng::f32_at(seed, 16 + i);
                img.data[(y * w + x) * 3 + c] = base + (n - 0.5) * scale;
            }
        }
    }
}

/// Render one 32x32 crop — MUST match scenes.make_crop bit-exactly.
pub fn make_crop(cls: u8, seed: u64) -> Image {
    let j = 2 * seed + 1;
    let b = 2 * seed;
    let mut img = Image::zeros(CROP, CROP);
    paint_background(&mut img, b, NOISE_SIGMA);
    let ox = prng::range_at(j, 0, -3, 4);
    let oy = prng::range_at(j, 1, -3, 4);
    let s8 = prng::range_at(j, 2, 6, 11);
    render_object(&mut img, cls, j, ox, oy, s8);
    img.clip01();
    img
}

// ---------------------------------------------------------------------------
// Frame synthesis (rust-only: the Data Generator's video streams)
// ---------------------------------------------------------------------------

/// Default synthetic frame geometry (matches artifacts manifest).
pub const FRAME_H: usize = 96;
pub const FRAME_W: usize = 160;

/// A moving object in a camera's scene.
#[derive(Debug, Clone)]
pub struct MovingObject {
    pub cls: u8,
    pub seed: u64,
    /// x position of the object's base-box origin at `t0` (pixels).
    pub x0: f64,
    pub y: i64,
    /// horizontal speed (px/s)
    pub vx: f64,
    pub s8: i64,
    pub t0: f64,
}

impl MovingObject {
    pub fn x_at(&self, t: f64) -> i64 {
        (self.x0 + self.vx * (t - self.t0)).round() as i64
    }

    /// Object center in frame coordinates at time `t`.
    pub fn center_at(&self, t: f64) -> (i64, i64) {
        (self.y + sc(16, self.s8), self.x_at(t) + sc(16, self.s8))
    }
}

/// Deterministic synthetic camera stream: a static textured background
/// with per-frame temporal noise and `slots` moving objects that respawn
/// with new classes once they exit. Class mix matches the EOC training
/// distribution (target + confuser boosted) so the classifiers operate
/// in distribution.
#[derive(Debug, Clone)]
pub struct CameraStream {
    pub cam_seed: u64,
    pub h: usize,
    pub w: usize,
    pub fps: f64,
    slots: Vec<MovingObject>,
    respawns: Vec<u64>,
}

/// Class sampling weights (percent) — mirrors aot.py EOC_WEIGHTS.
const CLASS_PCT: [u64; 8] = [14, 25, 8, 8, 8, 21, 8, 8];

fn sample_class(u: u32) -> u8 {
    let mut v = (u as u64) % 100;
    for (c, p) in CLASS_PCT.iter().enumerate() {
        if v < *p {
            return c as u8;
        }
        v -= p;
    }
    7
}

impl CameraStream {
    pub fn new(cam_seed: u64, slots: usize) -> Self {
        let mut s = CameraStream {
            cam_seed,
            h: FRAME_H,
            w: FRAME_W,
            fps: 30.0,
            slots: Vec::new(),
            respawns: vec![0; slots],
        };
        for i in 0..slots {
            s.slots.push(s.spawn(i, 0, 0.0));
        }
        s
    }

    /// Deterministic object for (slot, respawn#).
    fn spawn(&self, slot: usize, respawn: u64, t: f64) -> MovingObject {
        let seed = prng::u64_at(self.cam_seed, (slot as u64) << 32 | respawn);
        let cls = sample_class(prng::u32_at(seed, 0));
        let lanes = self.h as i64 / 36;
        let lane = prng::range_at(seed, 1, 0, lanes.max(1));
        let vx = 25.0 + prng::f32_at(seed, 2) as f64 * 55.0; // 25..80 px/s
        let s8 = prng::range_at(seed, 3, 6, 11);
        // stagger initial spawns across the frame; respawns enter left
        let x0 = if respawn == 0 {
            prng::range_at(seed, 4, -20, self.w as i64 - 20) as f64
        } else {
            -36.0
        };
        MovingObject {
            cls,
            seed,
            x0,
            y: lane * 36 + 2,
            vx,
            s8,
            t0: t,
        }
    }

    /// Advance respawn state up to time `t` (monotonic calls).
    pub fn advance_to(&mut self, t: f64) {
        for i in 0..self.slots.len() {
            while self.slots[i].x_at(t) > self.w as i64 + 8 {
                self.respawns[i] += 1;
                self.slots[i] = self.spawn(i, self.respawns[i], t);
            }
        }
    }

    /// Objects currently visible (their center inside the frame).
    pub fn visible_at(&self, t: f64) -> Vec<&MovingObject> {
        self.slots
            .iter()
            .filter(|o| {
                let (_, cx) = o.center_at(t);
                cx >= 0 && cx < self.w as i64
            })
            .collect()
    }

    /// Render the frame at time `t` (frame index = round(t * fps)).
    pub fn frame_at(&self, t: f64) -> Image {
        let mut img = Image::zeros(self.h, self.w);
        let fidx = (t * self.fps).round() as u64;
        // static base pattern + temporal noise: background stream is
        // fixed per camera, noise stream varies per frame
        let noise_seed = prng::u64_at(self.cam_seed ^ 0xBACC_0FF5, fidx);
        paint_background_split(&mut img, self.cam_seed, noise_seed, NOISE_SIGMA);
        for o in &self.slots {
            render_object(&mut img, o.cls, o.seed, o.x_at(t), o.y, o.s8);
        }
        img.clip01();
        img
    }
}

/// Background where the base pattern and the per-frame noise come from
/// different streams (static scene + temporal sensor noise).
pub fn paint_background_split(img: &mut Image, base_seed: u64, noise_seed: u64, sigma: f32) {
    let g = (prng::f32_at(base_seed, 0) as f64 * 0.3 + 0.35) as f32;
    let grad = (prng::f32_at(base_seed, 1) as f64 * 0.2 - 0.1) as f32;
    let w = img.w;
    let scale = 2.0f32 * sigma;
    for y in 0..img.h {
        for x in 0..w {
            let base = g + grad * (x as f32 / w as f32);
            for c in 0..3 {
                let i = ((y * w + x) * 3 + c) as u64;
                let n = prng::f32_at(noise_seed, 16 + i);
                img.data[(y * w + x) * 3 + c] = base + (n - 0.5) * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crops_are_deterministic() {
        let a = make_crop(1, 42);
        let b = make_crop(1, 42);
        assert_eq!(a.data, b.data);
        let c = make_crop(1, 43);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn crop_values_in_unit_range() {
        for cls in 0..8u8 {
            let img = make_crop(cls, 7);
            assert!(img.data.iter().all(|v| (0.0..=1.0).contains(v)));
            assert_eq!(img.data.len(), CROP * CROP * 3);
        }
    }

    #[test]
    fn objects_change_pixels() {
        let bg = make_crop(0, 5);
        for cls in 1..8u8 {
            let obj = make_crop(cls, 5);
            let diff = bg
                .data
                .iter()
                .zip(&obj.data)
                .filter(|(a, b)| a != b)
                .count();
            assert!(diff > 50, "class {cls} changed only {diff} px");
        }
    }

    #[test]
    fn classes_are_distinct() {
        // motorcycle vs bicycle must differ (rings vs disks)
        let m = make_crop(1, 9);
        let b = make_crop(5, 9);
        assert_ne!(m.data, b.data);
    }

    #[test]
    fn stream_respawns_deterministically() {
        let mut s1 = CameraStream::new(100, 2);
        let mut s2 = CameraStream::new(100, 2);
        for i in 0..20 {
            let t = i as f64 * 0.5;
            s1.advance_to(t);
            s2.advance_to(t);
        }
        let f1 = s1.frame_at(10.0);
        let f2 = s2.frame_at(10.0);
        assert_eq!(f1.data, f2.data);
    }

    #[test]
    fn stream_has_visible_objects() {
        let mut s = CameraStream::new(3, 3);
        let mut total = 0;
        for i in 0..20 {
            let t = i as f64;
            s.advance_to(t);
            total += s.visible_at(t).len();
        }
        assert!(total > 10, "only {total} object-sightings in 20s");
    }

    #[test]
    fn gray_is_mean_of_channels() {
        let img = make_crop(2, 3);
        let g = img.gray();
        let i = 5 * CROP + 7;
        let want = (img.data[i * 3] + img.data[i * 3 + 1] + img.data[i * 3 + 2]) / 3.0;
        assert!((g[i] - want).abs() < 1e-6);
    }

    #[test]
    fn moving_object_moves() {
        let mut s = CameraStream::new(8, 1);
        s.advance_to(0.0);
        let f0 = s.frame_at(0.0);
        let f1 = s.frame_at(0.5);
        assert_ne!(f0.data, f1.data);
    }
}
