//! ACE: Application-Centric Edge-Cloud Collaborative Intelligence.
//!
//! Full-system reproduction of the ACE platform (DOI 10.1145/3529087):
//! a rust L3 coordinator (platform/resource/application layers + DES
//! testbed simulation) executing AOT-compiled JAX/Pallas classifiers
//! via the PJRT C API. See DESIGN.md for the module inventory and the
//! experiment index.

pub mod app;
pub mod benchkit;
pub mod deploy;
pub mod des;
pub mod inapp;
pub mod infra;
pub mod json;
pub mod metrics;
pub mod platform;
pub mod pubsub;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod storage;
pub mod svcgraph;
pub mod sweep;
pub mod testbed;
pub mod topology;
pub mod util;
pub mod video;
pub mod yamlite;
