//! Resource-level storage: object store + file service (§4.3.2).
//!
//! Figure 2's file service separates CONTROL flow from DATA flow: file
//! operations are announced over the message service (links ③/④) while
//! payload bytes move through the object storage service (links ⑤/⑥) —
//! "for transmission simplification". We reproduce that structure:
//!
//! * `ObjectStore` — bucketed KV blob store (one per EC + one on CC);
//! * `FileService` — put/get/delete + lifecycle (temporary vs permanent
//!   objects, §4.3.2's "temporary storage for intermittent models and
//!   data, permanent storage for final trained models"), announcing
//!   every mutation on the message service so remote peers can mirror.

use crate::pubsub::Broker;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Object lifecycle class (§4.3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Intermittent models/data — purged by `gc()`.
    Temporary,
    /// Final trained models — survives gc.
    Permanent,
}

#[derive(Debug, Clone)]
struct Object {
    data: Vec<u8>,
    lifecycle: Lifecycle,
    version: u64,
}

#[derive(Default)]
struct StoreInner {
    buckets: BTreeMap<String, BTreeMap<String, Object>>,
    put_bytes: u64,
    get_bytes: u64,
}

/// Thread-safe bucketed blob store.
#[derive(Clone, Default)]
pub struct ObjectStore {
    inner: Arc<Mutex<StoreInner>>,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, bucket: &str, key: &str, data: Vec<u8>, lifecycle: Lifecycle) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.put_bytes += data.len() as u64;
        let b = inner.buckets.entry(bucket.to_string()).or_default();
        let version = b.get(key).map(|o| o.version + 1).unwrap_or(1);
        b.insert(key.to_string(), Object { data, lifecycle, version });
        version
    }

    pub fn get(&self, bucket: &str, key: &str) -> Option<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let data = inner.buckets.get(bucket)?.get(key)?.data.clone();
        inner.get_bytes += data.len() as u64;
        Some(data)
    }

    pub fn version(&self, bucket: &str, key: &str) -> Option<u64> {
        let inner = self.inner.lock().unwrap();
        Some(inner.buckets.get(bucket)?.get(key)?.version)
    }

    pub fn delete(&self, bucket: &str, key: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner
            .buckets
            .get_mut(bucket)
            .map(|b| b.remove(key).is_some())
            .unwrap_or(false)
    }

    pub fn list(&self, bucket: &str) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .buckets
            .get(bucket)
            .map(|b| b.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Purge all Temporary objects; returns number purged.
    pub fn gc(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut purged = 0;
        for b in inner.buckets.values_mut() {
            let before = b.len();
            b.retain(|_, o| o.lifecycle == Lifecycle::Permanent);
            purged += before - b.len();
        }
        purged
    }

    /// (bytes written, bytes read) so far.
    pub fn traffic(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.put_bytes, inner.get_bytes)
    }
}

/// File service: object store data plane + message-service control
/// plane. `announce_topic` is where mutations are published (Figure 2
/// links ③/④); payloads never touch the broker.
pub struct FileService {
    pub store: ObjectStore,
    broker: Broker,
    scope: String,
}

impl FileService {
    pub fn new(store: ObjectStore, broker: Broker, scope: impl Into<String>) -> Self {
        FileService { store, broker, scope: scope.into() }
    }

    fn announce(&self, op: &str, bucket: &str, key: &str, size: usize, version: u64) {
        let topic = format!("svc/file/{}/{}", self.scope, op);
        let payload = format!(
            "{{\"bucket\":\"{bucket}\",\"key\":\"{key}\",\"size\":{size},\"version\":{version}}}"
        );
        let _ = self.broker.publish(&topic, payload.into_bytes());
    }

    pub fn put(&self, bucket: &str, key: &str, data: Vec<u8>, lifecycle: Lifecycle) -> u64 {
        let size = data.len();
        let v = self.store.put(bucket, key, data, lifecycle);
        self.announce("put", bucket, key, size, v);
        v
    }

    pub fn get(&self, bucket: &str, key: &str) -> Option<Vec<u8>> {
        let data = self.store.get(bucket, key)?;
        self.announce("get", bucket, key, data.len(), 0);
        Some(data)
    }

    pub fn delete(&self, bucket: &str, key: &str) -> bool {
        let ok = self.store.delete(bucket, key);
        if ok {
            self.announce("delete", bucket, key, 0, 0);
        }
        ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn put_get_roundtrip_and_versions() {
        let s = ObjectStore::new();
        assert_eq!(s.put("models", "eoc", vec![1, 2, 3], Lifecycle::Permanent), 1);
        assert_eq!(s.put("models", "eoc", vec![4, 5], Lifecycle::Permanent), 2);
        assert_eq!(s.get("models", "eoc"), Some(vec![4, 5]));
        assert_eq!(s.version("models", "eoc"), Some(2));
        assert_eq!(s.get("models", "missing"), None);
    }

    #[test]
    fn gc_purges_temporary_only() {
        let s = ObjectStore::new();
        s.put("b", "tmp", vec![0], Lifecycle::Temporary);
        s.put("b", "final", vec![1], Lifecycle::Permanent);
        assert_eq!(s.gc(), 1);
        assert_eq!(s.get("b", "tmp"), None);
        assert_eq!(s.get("b", "final"), Some(vec![1]));
    }

    #[test]
    fn traffic_accounting() {
        let s = ObjectStore::new();
        s.put("b", "k", vec![0u8; 10], Lifecycle::Permanent);
        s.get("b", "k");
        s.get("b", "k");
        assert_eq!(s.traffic(), (10, 20));
    }

    #[test]
    fn list_and_delete() {
        let s = ObjectStore::new();
        s.put("b", "a", vec![], Lifecycle::Permanent);
        s.put("b", "c", vec![], Lifecycle::Permanent);
        assert_eq!(s.list("b"), vec!["a".to_string(), "c".to_string()]);
        assert!(s.delete("b", "a"));
        assert!(!s.delete("b", "a"));
        assert_eq!(s.list("b"), vec!["c".to_string()]);
    }

    #[test]
    fn file_service_announces_control_flow() {
        let broker = Broker::new("ec-1");
        let sub = broker.subscribe("svc/file/ec-1/#").unwrap();
        let fs = FileService::new(ObjectStore::new(), broker, "ec-1");
        fs.put("models", "eoc-v1", vec![0u8; 2048], Lifecycle::Temporary);
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.topic, "svc/file/ec-1/put");
        assert!(m.utf8().contains("\"size\":2048"));
        // control message is small — data plane stayed in the store
        assert!(m.payload.len() < 200);
        let got = fs.get("models", "eoc-v1").unwrap();
        assert_eq!(got.len(), 2048);
        let m2 = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m2.topic, "svc/file/ec-1/get");
    }
}
