//! EC<->CC topic bridging — the long-lasting service link of Figure 2.
//!
//! §4.3.2: "the long-lasting link between EC and CC message services is
//! established using MQTT topic-bridging". A `Bridge` forwards messages
//! matching configured filters between two brokers, in both directions,
//! with origin-based loop prevention (a message is never forwarded back
//! into a broker it has already visited — mirroring mosquitto's
//! `local`/`remote` prefix behaviour).
//!
//! The bridge is what lets an EC client publish to `cloud/...` against
//! its LOCAL broker and have the CC client receive it — the paper's
//! argument for why developers stop hand-wiring per-client CC
//! authorization (evaluated in `benches/bridge_vs_direct.rs`).

use super::broker::{Broker, Message};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Forwarding rule: messages matching `filter` flow `a -> b` (and a
/// mirrored rule handles `b -> a` if added).
#[derive(Debug, Clone)]
pub struct Rule {
    /// Topic filter selecting what this rule forwards.
    pub filter: String,
}

/// A running pair of forwarding loops between two brokers (one thread
/// per direction per filter), with origin-based loop prevention.
pub struct Bridge {
    stop: Arc<AtomicBool>,
    forwarded: Arc<AtomicU64>,
    forwarded_bytes: Arc<AtomicU64>,
    threads: Vec<JoinHandle<()>>,
}

impl Bridge {
    /// Bridge `a` and `b`: `a_to_b` filters forward a->b, `b_to_a`
    /// filters forward b->a. Forwarding threads run until `shutdown`.
    pub fn start(
        a: &Broker,
        b: &Broker,
        a_to_b: &[&str],
        b_to_a: &[&str],
    ) -> Result<Bridge, String> {
        let stop = Arc::new(AtomicBool::new(false));
        let forwarded = Arc::new(AtomicU64::new(0));
        let forwarded_bytes = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();
        for (src, dst, filters) in [(a, b, a_to_b), (b, a, b_to_a)] {
            for f in filters {
                let sub = src.subscribe(f)?;
                let dst = dst.clone();
                let dst_name = dst.name();
                let stop = stop.clone();
                let fwd = forwarded.clone();
                let fwd_b = forwarded_bytes.clone();
                threads.push(std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match sub.rx.recv_timeout(std::time::Duration::from_millis(20)) {
                            Ok(msg) => {
                                // loop prevention: never forward into the
                                // broker the message originated from
                                if msg.origin == dst_name {
                                    continue;
                                }
                                let bytes = msg.payload.len() as u64;
                                let m = Message {
                                    topic: msg.topic,
                                    payload: msg.payload,
                                    origin: msg.origin,
                                };
                                if dst.publish_opts(m, false).is_ok() {
                                    fwd.fetch_add(1, Ordering::Relaxed);
                                    fwd_b.fetch_add(bytes, Ordering::Relaxed);
                                }
                            }
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                }));
            }
        }
        Ok(Bridge { stop, forwarded, forwarded_bytes, threads })
    }

    /// Messages forwarded so far (both directions).
    pub fn forwarded(&self) -> u64 {
        self.forwarded.load(Ordering::Relaxed)
    }

    /// Payload bytes forwarded so far — the bridged-WAN counter.
    pub fn forwarded_bytes(&self) -> u64 {
        self.forwarded_bytes.load(Ordering::Relaxed)
    }

    /// Stop the forwarding threads and wait for them to exit.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Bridge {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn recv(sub: &crate::pubsub::broker::SubHandle) -> Message {
        sub.rx.recv_timeout(Duration::from_secs(2)).expect("message")
    }

    #[test]
    fn forwards_ec_to_cc() {
        let ec = Broker::new("ec-1");
        let cc = Broker::new("cc");
        let _bridge = Bridge::start(&ec, &cc, &["cloud/#"], &["edge/ec-1/#"]).unwrap();
        let cc_sub = cc.subscribe("cloud/#").unwrap();
        // EC client talks to its LOCAL broker only
        ec.publish("cloud/results/q1", b"crop-meta".to_vec()).unwrap();
        let m = recv(&cc_sub);
        assert_eq!(m.topic, "cloud/results/q1");
        assert_eq!(&*m.origin, "ec-1");
    }

    #[test]
    fn forwards_cc_to_ec() {
        let ec = Broker::new("ec-1");
        let cc = Broker::new("cc");
        let _bridge = Bridge::start(&ec, &cc, &["cloud/#"], &["edge/ec-1/#"]).unwrap();
        let ec_sub = ec.subscribe("edge/ec-1/ctrl").unwrap();
        cc.publish("edge/ec-1/ctrl", b"deploy".to_vec()).unwrap();
        assert_eq!(recv(&ec_sub).utf8(), "deploy");
    }

    #[test]
    fn no_forwarding_loop() {
        let ec = Broker::new("ec-1");
        let cc = Broker::new("cc");
        // symmetric filters would loop without origin tracking
        let bridge = Bridge::start(&ec, &cc, &["shared/#"], &["shared/#"]).unwrap();
        let _cc_sub = cc.subscribe("shared/x").unwrap();
        ec.publish("shared/x", b"once".to_vec()).unwrap();
        std::thread::sleep(Duration::from_millis(200));
        // exactly one forward (ec->cc); the echo back is suppressed
        assert_eq!(bridge.forwarded(), 1);
        assert_eq!(bridge.forwarded_bytes(), 4);
    }

    #[test]
    fn multi_ec_fanin() {
        let cc = Broker::new("cc");
        let ecs: Vec<Broker> = (0..3).map(|i| Broker::new(format!("ec-{i}"))).collect();
        let _bridges: Vec<Bridge> = ecs
            .iter()
            .map(|ec| Bridge::start(ec, &cc, &["cloud/#"], &[]).unwrap())
            .collect();
        let sub = cc.subscribe("cloud/#").unwrap();
        for (i, ec) in ecs.iter().enumerate() {
            ec.publish("cloud/up", format!("m{i}").into_bytes()).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(recv(&sub).utf8());
        }
        got.sort();
        assert_eq!(got, vec!["m0", "m1", "m2"]);
    }
}
