//! Sharded broker interior — per-first-level topic-trie subtrees, each
//! behind its own lock, plus one shared wildcard shard.
//!
//! The shard map: a topic name routes to `FNV-1a(first level) % N`.
//! A subscription filter whose level 0 is a LITERAL can only ever match
//! names sharing that exact first level ([`topic::matches`] compares
//! level 0 first), so storing it in the same shard as those names keeps
//! shard-local routing *complete*: every (filter, name) pair that can
//! match meets inside one shard. Hash collisions put unrelated first
//! levels in one shard — that is harmless (the trie walk compares
//! symbols, a co-resident filter for another first level simply never
//! matches), it only costs a shared lock.
//!
//! Filters that start with `+` or `#` ([`topic::filter_crosses_shards`])
//! can match names with ANY first level, so they live in one shared
//! *wildcard shard*. A publish then needs at most two locks: its
//! literal shard, and — only when the wildcard shard is non-empty
//! (an atomic gauge, checked lock-free) — the wildcard shard. The old
//! single `Mutex<Inner>` is gone entirely; N producers publishing to
//! distinct first levels never contend.
//!
//! Lock ORDER (deadlock freedom): literal shards ascending by index,
//! wildcard shard strictly last. Every multi-lock path follows it —
//! publish takes (literal i, then wildcard), a cross-shard subscribe
//! takes (literal 0..N ascending, then wildcard) so its retained-replay
//! snapshot + insertion is atomic against every concurrent publish
//! (no missed or duplicated delivery around the subscribe boundary).
//! Publish holds its literal-shard lock ACROSS the wildcard delivery
//! for the same reason: releasing it mid-publish would let a `#`
//! subscribe replay a just-retained message AND then receive it live.
//!
//! Ordering: per-subscriber delivery order equals the single-mutex
//! broker's. A subscriber lives in exactly one shard, so its deliveries
//! serialize under that shard's lock; each producer publishes
//! sequentially, so its messages enter every shard in program order.
//! Retained replay order is pinned by a GLOBAL `retain_seq` stamp
//! (one atomic fetch-add per retain), so a wildcard subscribe that
//! merges retained messages from all shards replays them in exactly
//! the order the retains were accepted — byte-identical to the
//! reference broker (see `tests/broker_shard.rs`).

use super::broker::Message;
use super::topic::{self, SymbolTable, TopicTrie};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};

/// Default literal-shard count for `Broker::new`.
pub(crate) const DEFAULT_SHARDS: usize = 8;

/// Shard counts are clamped to this, so a subscription id — shard
/// index in the bits above [`LOCAL_BITS`] — stays below 2^53 and
/// round-trips exactly through a JSON `f64` (the `ace serve` wire
/// format).
pub(crate) const MAX_SHARDS: usize = 1024;

/// Low bits of a subscription id hold the shard-local counter; the
/// bits above hold `shard index + 1`.
const LOCAL_BITS: u32 = 40;

/// Where a subscription's matches go: the classic mpsc channel, or a
/// callback sink invoked inline under the owning shard's lock (the
/// `serve` engine's shard-side dispatch — no forwarder thread per
/// subscription). A sink returning `false` is dead and gets pruned
/// exactly like a channel whose receiver was dropped.
///
/// The `bool` argument is "retain as published": `true` both for
/// retained replays at subscribe time and for live publishes that
/// asked to retain — what a federation link needs to re-retain on the
/// peer (MQTT's retain-as-published). Sinks run under the shard lock,
/// so they MUST NOT call back into broker APIs (publish, subscribe,
/// unsubscribe would deadlock); enqueue-and-wake only.
pub(crate) enum SubSink {
    Chan(Sender<Message>),
    Fn(Arc<dyn Fn(u64, &Message, bool) -> bool + Send + Sync>),
}

impl SubSink {
    /// Deliver one message; `false` means the sink is dead.
    fn send(&self, id: u64, msg: &Message, retained: bool) -> bool {
        match self {
            // Arc payload: the per-subscriber clone is a refcount bump
            SubSink::Chan(tx) => tx.send(msg.clone()).is_ok(),
            SubSink::Fn(f) => f(id, msg, retained),
        }
    }
}

struct Subscription {
    sink: SubSink,
    id: u64,
}

/// A retained message stamped with its GLOBAL retain sequence, so
/// cross-shard replays merge into one total retain order.
struct Retained {
    seq: u64,
    msg: Message,
}

/// One shard: its own subscription trie, retained trie, and symbol
/// table (shards never share interned symbols, so their vocabularies
/// stay small and their locks independent).
struct ShardInner {
    subs: TopicTrie<Subscription>,
    /// id -> filter, so unsubscribe/pruning can address the trie path.
    filters: HashMap<u64, String>,
    retained: TopicTrie<Retained>,
    table: SymbolTable,
    next_local: u64,
}

impl ShardInner {
    fn new() -> Self {
        ShardInner {
            subs: TopicTrie::new(),
            filters: HashMap::new(),
            retained: TopicTrie::new(),
            table: SymbolTable::new(),
            next_local: 1,
        }
    }
}

/// Aggregate effect of routing one publish (the caller folds these
/// into the broker's lock-free counters).
#[derive(Default)]
pub(crate) struct RouteOutcome {
    pub reached: usize,
    pub delivered_bytes: u64,
    /// Dead (receiver-dropped) subscriptions garbage-collected.
    pub pruned: usize,
}

/// Aggregate effect of one subscribe (id + retained replay volume).
pub(crate) struct SubscribeOutcome {
    pub id: u64,
    pub replayed: u64,
    pub replayed_bytes: u64,
}

/// The sharded broker interior. All locking lives here; the `Broker`
/// wrapper owns name + counters and validates inputs.
pub(crate) struct ShardSet {
    literal: Box<[Mutex<ShardInner>]>,
    /// Filters with `+`/`#` at level 0 — consulted by every publish,
    /// but only when `wildcard_subs` says it is non-empty.
    wildcard: Mutex<ShardInner>,
    /// Lock-free mirror of `wildcard.subs.len()`: the publish fast
    /// path reads this instead of taking the wildcard lock.
    wildcard_subs: AtomicUsize,
    /// Global retain-order stamp (see module doc).
    retain_seq: AtomicU64,
}

/// FNV-1a over one topic level — deterministic across processes (the
/// differential suite replays identical workloads at several shard
/// counts), unlike `std`'s seeded `RandomState`.
fn fnv1a(level: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in level.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn make_id(shard_idx: usize, local: u64) -> u64 {
    ((shard_idx as u64 + 1) << LOCAL_BITS) | local
}

impl ShardSet {
    pub fn new(shards: usize) -> Self {
        let n = shards.clamp(1, MAX_SHARDS);
        ShardSet {
            literal: (0..n).map(|_| Mutex::new(ShardInner::new())).collect(),
            wildcard: Mutex::new(ShardInner::new()),
            wildcard_subs: AtomicUsize::new(0),
            retain_seq: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.literal.len()
    }

    fn shard_of(&self, first_level: &str) -> usize {
        (fnv1a(first_level) % self.literal.len() as u64) as usize
    }

    /// Deliver `msg` to every matching subscription (and retain it
    /// first if asked). Takes the literal shard lock, then — only if
    /// the wildcard shard has subscribers — the wildcard lock, in the
    /// global lock order.
    pub fn route(&self, msg: &Message, retain: bool) -> RouteOutcome {
        let mut out = RouteOutcome::default();
        let si = self.shard_of(topic::first_level(&msg.topic));
        let mut guard = self.literal[si].lock().unwrap();
        if retain {
            // last-writer-wins per topic, stamped with the GLOBAL
            // retain seq so cross-shard replays merge in retain order
            let seq = self.retain_seq.fetch_add(1, Ordering::Relaxed);
            let inner = &mut *guard;
            inner.retained.remove(&inner.table, &msg.topic, |_| true);
            inner
                .retained
                .insert(&mut inner.table, &msg.topic, Retained { seq, msg: msg.clone() });
        }
        deliver(&mut guard, msg, retain, &mut out);
        // the fast path: no wildcard subscribers, no second lock. The
        // literal guard stays held so a concurrent `#` subscribe
        // cannot slip between the two delivery phases (module doc).
        if self.wildcard_subs.load(Ordering::Acquire) > 0 {
            let mut wg = self.wildcard.lock().unwrap();
            deliver(&mut wg, msg, retain, &mut out);
            self.wildcard_subs.store(wg.subs.len(), Ordering::Release);
        }
        drop(guard);
        out
    }

    /// Insert a (validated) filter, replaying retained messages in
    /// global retain order first. Literal-level-0 filters touch one
    /// shard; `+`/`#`-level-0 filters lock every shard (ascending,
    /// wildcard last) so snapshot + insert is atomic against all
    /// concurrent publishes. The subscription id is assigned BEFORE
    /// the replay, so a callback sink already knows its id while the
    /// retained messages stream through it.
    pub fn subscribe(&self, filter: &str, sink: SubSink) -> SubscribeOutcome {
        let mut replayed: Vec<(u64, Message)> = Vec::new();
        if topic::filter_crosses_shards(filter) {
            let guards: Vec<MutexGuard<'_, ShardInner>> =
                self.literal.iter().map(|s| s.lock().unwrap()).collect();
            let mut wg = self.wildcard.lock().unwrap();
            for g in &guards {
                g.retained
                    .for_each_name_match(&g.table, filter, |_, r| replayed.push((r.seq, r.msg.clone())));
            }
            let inner = &mut *wg;
            let id = make_id(self.literal.len(), inner.next_local);
            inner.next_local += 1;
            let (count, bytes) = send_replay(&mut replayed, id, &sink);
            inner.subs.insert(&mut inner.table, filter, Subscription { sink, id });
            inner.filters.insert(id, filter.to_string());
            self.wildcard_subs.store(inner.subs.len(), Ordering::Release);
            drop(guards);
            SubscribeOutcome { id, replayed: count, replayed_bytes: bytes }
        } else {
            let si = self.shard_of(topic::first_level(filter));
            let mut guard = self.literal[si].lock().unwrap();
            let inner = &mut *guard;
            inner
                .retained
                .for_each_name_match(&inner.table, filter, |_, r| replayed.push((r.seq, r.msg.clone())));
            let id = make_id(si, inner.next_local);
            inner.next_local += 1;
            let (count, bytes) = send_replay(&mut replayed, id, &sink);
            inner.subs.insert(&mut inner.table, filter, Subscription { sink, id });
            inner.filters.insert(id, filter.to_string());
            SubscribeOutcome { id, replayed: count, replayed_bytes: bytes }
        }
    }

    /// Remove subscription `id`. The owning shard is encoded in the id
    /// itself, so this takes exactly one lock. Returns the number of
    /// subscriptions removed (0 or 1).
    pub fn unsubscribe(&self, id: u64) -> usize {
        let Some(idx) = ((id >> LOCAL_BITS) as usize).checked_sub(1) else {
            return 0;
        };
        let shard = if idx == self.literal.len() {
            &self.wildcard
        } else if let Some(s) = self.literal.get(idx) {
            s
        } else {
            return 0;
        };
        let mut guard = shard.lock().unwrap();
        let inner = &mut *guard;
        let mut removed = 0;
        if let Some(filter) = inner.filters.remove(&id) {
            removed = inner.subs.remove(&inner.table, &filter, |s| s.id == id);
        }
        if idx == self.literal.len() {
            self.wildcard_subs.store(inner.subs.len(), Ordering::Release);
        }
        removed
    }
}

/// Deliver to one shard's matches; dead receivers are pruned (each a
/// targeted trie-path removal, as in the pre-shard broker). `retained`
/// is the publish's retain flag, handed to callback sinks verbatim
/// (retain-as-published).
fn deliver(inner: &mut ShardInner, msg: &Message, retained: bool, out: &mut RouteOutcome) {
    let mut dead: Vec<u64> = Vec::new();
    // O(topic depth) trie walk; matches come back in insertion
    // (i.e. subscription) order
    for s in inner.subs.collect_matches(&inner.table, &msg.topic) {
        if s.sink.send(s.id, msg, retained) {
            out.reached += 1;
            out.delivered_bytes += msg.payload.len() as u64;
        } else {
            dead.push(s.id);
        }
    }
    for id in dead {
        if let Some(filter) = inner.filters.remove(&id) {
            out.pruned += inner.subs.remove(&inner.table, &filter, |s| s.id == id);
        }
    }
}

/// Sort a replay batch into global retain order and send it (replays
/// are retained by definition, so sinks see `retained == true`); a
/// channel receiver cannot be dropped yet (the caller holds both
/// ends), a callback sink may already refuse.
fn send_replay(replayed: &mut Vec<(u64, Message)>, id: u64, sink: &SubSink) -> (u64, u64) {
    replayed.sort_unstable_by_key(|&(seq, _)| seq);
    let (mut count, mut bytes) = (0u64, 0u64);
    for (_, m) in replayed.drain(..) {
        let b = m.payload.len() as u64;
        if sink.send(id, &m, true) {
            count += 1;
            bytes += b;
        }
    }
    (count, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn ids_encode_their_shard_and_stay_f64_exact() {
        let set = ShardSet::new(MAX_SHARDS);
        // the largest id the first subscription in the last (wildcard)
        // shard can get must survive an f64 round trip
        let id = make_id(set.shard_count(), 1);
        assert_eq!(id as f64 as u64, id);
        assert!((id as f64) < 2f64.powi(53));
    }

    #[test]
    fn shard_count_is_clamped() {
        assert_eq!(ShardSet::new(0).shard_count(), 1);
        assert_eq!(ShardSet::new(5).shard_count(), 5);
        assert_eq!(ShardSet::new(1 << 20).shard_count(), MAX_SHARDS);
    }

    #[test]
    fn unsubscribe_routes_by_id_without_scanning() {
        let set = ShardSet::new(4);
        let (tx, _rx) = channel();
        let a = set.subscribe("alpha/x", SubSink::Chan(tx.clone()));
        let b = set.subscribe("#", SubSink::Chan(tx));
        assert_ne!(a.id, b.id);
        assert_eq!(set.unsubscribe(a.id), 1);
        assert_eq!(set.unsubscribe(a.id), 0, "second removal is a no-op");
        assert_eq!(set.unsubscribe(b.id), 1);
        assert_eq!(set.unsubscribe(0), 0, "bogus id is rejected, not a panic");
        assert_eq!(set.unsubscribe(u64::MAX), 0);
    }

    #[test]
    fn wildcard_gauge_tracks_level0_wildcards_only() {
        let set = ShardSet::new(4);
        let (tx, _rx) = channel();
        set.subscribe("alpha/#", SubSink::Chan(tx.clone()));
        assert_eq!(set.wildcard_subs.load(Ordering::Acquire), 0, "literal level 0");
        let w = set.subscribe("+/status", SubSink::Chan(tx));
        assert_eq!(set.wildcard_subs.load(Ordering::Acquire), 1);
        set.unsubscribe(w.id);
        assert_eq!(set.wildcard_subs.load(Ordering::Acquire), 0);
    }
}
