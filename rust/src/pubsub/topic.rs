//! MQTT-style topic names and filters (`+` and `#` wildcards).
//!
//! Shared by the threaded broker (platform control plane) and the DES
//! message router (experiment data plane), so both agree on semantics.

/// Is `name` a valid concrete topic (no wildcards, non-empty levels)?
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && !name.contains(['+', '#'])
        && name.split('/').all(|l| !l.is_empty())
}

/// Is `filter` a valid subscription filter?
/// `+` matches one level; `#` matches the rest and must be last.
pub fn valid_filter(filter: &str) -> bool {
    if filter.is_empty() {
        return false;
    }
    let levels: Vec<&str> = filter.split('/').collect();
    for (i, l) in levels.iter().enumerate() {
        if l.is_empty() {
            return false;
        }
        if l.contains('#') && (*l != "#" || i != levels.len() - 1) {
            return false;
        }
        if l.contains('+') && *l != "+" {
            return false;
        }
    }
    true
}

/// MQTT topic matching: does `filter` match concrete `name`?
pub fn matches(filter: &str, name: &str) -> bool {
    let mut f = filter.split('/');
    let mut n = name.split('/');
    loop {
        match (f.next(), n.next()) {
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => continue,
            (Some(fl), Some(nl)) if fl == nl => continue,
            (None, None) => return true,
            _ => return false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        assert!(matches("a/b/c", "a/b/c"));
        assert!(!matches("a/b/c", "a/b"));
        assert!(!matches("a/b", "a/b/c"));
    }

    #[test]
    fn plus_matches_one_level() {
        assert!(matches("a/+/c", "a/b/c"));
        assert!(matches("+/b/c", "a/b/c"));
        assert!(!matches("a/+", "a/b/c"));
        assert!(!matches("a/+/c", "a/c"));
    }

    #[test]
    fn hash_matches_rest() {
        assert!(matches("a/#", "a/b/c"));
        assert!(matches("#", "anything/at/all"));
        assert!(matches("a/#", "a/b"));
        // MQTT spec: `a/#` matches the parent `a` itself too.
        assert!(matches("a/#", "a"));
        assert!(!matches("a/#", "b"));
    }

    #[test]
    fn validity() {
        assert!(valid_name("a/b/c"));
        assert!(!valid_name("a//c"));
        assert!(!valid_name("a/+/c"));
        assert!(!valid_name(""));
        assert!(valid_filter("a/+/c"));
        assert!(valid_filter("a/#"));
        assert!(valid_filter("#"));
        assert!(!valid_filter("a/#/c"));
        assert!(!valid_filter("a/b+"));
        assert!(!valid_filter("a//b"));
    }
}
