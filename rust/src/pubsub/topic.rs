//! MQTT-style topic names and filters (`+` and `#` wildcards).
//!
//! Shared by the threaded broker (platform control plane) and the DES
//! message router (experiment data plane), so both agree on semantics.
//!
//! Two matching engines live here and MUST agree:
//!
//! * [`matches`] — the reference scalar matcher, O(filter levels) per
//!   (filter, name) pair; a router holding N subscriptions pays O(N)
//!   per publish with it.
//! * [`TopicTrie`] — the subscription *index*: filters are stored as
//!   paths in a level trie (literal edges, a `+` edge, `#` terminals),
//!   so one publish walks O(topic depth) nodes regardless of N. Both
//!   `svcgraph::Fabric` (DES data plane) and `pubsub::Broker`
//!   (threaded control plane) route through it.
//!
//! Literal levels are interned to dense `u32` symbols through a
//! [`SymbolTable`] the trie's owner supplies (the Fabric shares ONE
//! table across its per-cluster subscription tries, its bridge tries
//! and its topic cache; the broker keeps its own behind its mutex).
//! Trie edges are keyed by symbol in sorted parallel vectors, so the
//! steady-state walk compares integers over two cache-adjacent arrays
//! instead of hashing strings — and a publisher that pre-interned its
//! topic (`for_each_match_syms`) never touches the string at all.
//!
//! Agreement (including `+`/`#` edge cases like `a/#` matching the
//! parent `a`) is enforced by a differential property test in
//! `tests/properties.rs`.

use std::collections::HashMap;

/// Is `name` a valid concrete topic (no wildcards, non-empty levels)?
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && !name.contains(['+', '#'])
        && name.split('/').all(|l| !l.is_empty())
}

/// Is `filter` a valid subscription filter?
/// `+` matches one level; `#` matches the rest and must be last.
pub fn valid_filter(filter: &str) -> bool {
    if filter.is_empty() {
        return false;
    }
    let levels: Vec<&str> = filter.split('/').collect();
    for (i, l) in levels.iter().enumerate() {
        if l.is_empty() {
            return false;
        }
        if l.contains('#') && (*l != "#" || i != levels.len() - 1) {
            return false;
        }
        if l.contains('+') && *l != "+" {
            return false;
        }
    }
    true
}

/// First `/`-separated level of a topic name or filter.
///
/// This is the broker's shard key: every filter whose level 0 is a
/// LITERAL can only ever match names sharing that first level, so
/// co-locating names and filters by first level keeps shard-local
/// routing complete (see `pubsub::shard`).
pub fn first_level(topic: &str) -> &str {
    topic.split('/').next().unwrap_or("")
}

/// Does `filter` start with a wildcard level (`+` or `#` at level 0)?
///
/// Such a filter can match names with ANY first level, i.e. it crosses
/// the broker's first-level shard map and must live in the shared
/// wildcard shard instead of a literal shard.
pub fn filter_crosses_shards(filter: &str) -> bool {
    matches!(first_level(filter), "+" | "#")
}

/// MQTT topic matching: does `filter` match concrete `name`?
pub fn matches(filter: &str, name: &str) -> bool {
    let mut f = filter.split('/');
    let mut n = name.split('/');
    loop {
        match (f.next(), n.next()) {
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => continue,
            (Some(fl), Some(nl)) if fl == nl => continue,
            (None, None) => return true,
            _ => return false,
        }
    }
}

/// Dense id of one interned topic level.
pub type Sym = u32;

/// Interns topic levels to dense [`Sym`]s. Interning is stable and
/// append-only: the same string always maps to the same symbol, so
/// symbol sequences cached at publish time (`svcgraph::Fabric`'s topic
/// cache) never go stale when later subscriptions extend the table.
///
/// Wildcards are STRUCTURAL in the trie (`+` edge, `#` terminal) and
/// are never interned from filters; a level that happens to contain a
/// wildcard character (invalid per [`valid_filter`], e.g. `a+b`) is
/// interned literally, which is exactly the reference matcher's
/// compare-literally behaviour.
#[derive(Default)]
pub struct SymbolTable {
    map: HashMap<Box<str>, Sym>,
}

impl SymbolTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distinct levels interned so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Symbol for `level`, allocating the next dense id on first sight.
    pub fn intern(&mut self, level: &str) -> Sym {
        if let Some(&s) = self.map.get(level) {
            return s;
        }
        let s = self.map.len() as Sym;
        assert!(s != Sym::MAX, "symbol space exhausted");
        self.map.insert(level.into(), s);
        s
    }

    /// Read-only probe: `None` means the level was never interned, so
    /// no literal trie edge anywhere can be keyed by it.
    pub fn lookup(&self, level: &str) -> Option<Sym> {
        self.map.get(level).copied()
    }

    /// Intern every level of the concrete `name` into the reused `out`
    /// buffer — the publish-side half of the symbol fast path.
    pub fn intern_levels_into(&mut self, name: &str, out: &mut Vec<Sym>) {
        out.clear();
        for level in name.split('/') {
            out.push(self.intern(level));
        }
    }
}

/// One stored subscription: `seq` is the global insertion sequence,
/// used to report matches in insertion order (delivery-order parity
/// with the linear scan the trie replaced — and, through the DES
/// scheduler's insertion-sequence tie-breaking, determinism).
struct TrieEntry<T> {
    seq: u64,
    value: T,
}

/// One trie node = one topic level. Filters terminate either exactly
/// here (`here`) or with a `#` that swallows this node's subtree AND
/// the node itself (`hash` — MQTT: `a/#` matches the parent `a`).
///
/// Literal edges live in `keys`/`nodes`, two parallel vectors sorted
/// by symbol: a child lookup is one binary search over a dense `u32`
/// array (a handful of cache lines even for wide nodes), not a string
/// hash + equality probe.
struct TrieNode<T> {
    keys: Vec<Sym>,
    nodes: Vec<TrieNode<T>>,
    plus: Option<Box<TrieNode<T>>>,
    here: Vec<TrieEntry<T>>,
    hash: Vec<TrieEntry<T>>,
}

impl<T> TrieNode<T> {
    fn new() -> Self {
        TrieNode {
            keys: Vec::new(),
            nodes: Vec::new(),
            plus: None,
            here: Vec::new(),
            hash: Vec::new(),
        }
    }

    fn is_unused(&self) -> bool {
        self.keys.is_empty() && self.plus.is_none() && self.here.is_empty() && self.hash.is_empty()
    }

    fn child(&self, sym: Sym) -> Option<&TrieNode<T>> {
        self.keys.binary_search(&sym).ok().map(|i| &self.nodes[i])
    }

    fn child_entry(&mut self, sym: Sym) -> &mut TrieNode<T> {
        match self.keys.binary_search(&sym) {
            Ok(i) => &mut self.nodes[i],
            Err(i) => {
                self.keys.insert(i, sym);
                self.nodes.insert(i, TrieNode::new());
                &mut self.nodes[i]
            }
        }
    }
}

impl<T> Default for TrieNode<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Topic-trie subscription index: `insert`/`remove` filters, then
/// `collect_matches(name)` returns every stored value whose filter
/// matches `name`, in insertion order, walking O(topic depth) nodes
/// instead of scanning all subscriptions.
///
/// Every string-keyed operation takes the owner's [`SymbolTable`]:
/// mutating ones (`insert`) intern new literal levels, read-only ones
/// probe (`lookup`) — a level the table has never seen cannot key any
/// edge, so the probe failing is itself the answer.
///
/// Semantics mirror [`matches`] verbatim for ANY filter string, valid
/// or not: levels are compared literally, `+` matches exactly one
/// level, and a `#` level terminates the filter (the reference matcher
/// also ignores anything after a `#`).
pub struct TopicTrie<T> {
    root: TrieNode<T>,
    next_seq: u64,
    len: usize,
}

impl<T> Default for TopicTrie<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TopicTrie<T> {
    pub fn new() -> Self {
        TopicTrie { root: TrieNode::new(), next_seq: 0, len: 0 }
    }

    /// Stored subscriptions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store `value` under `filter`, interning its literal levels into
    /// `tab`. Returns the insertion sequence number (monotonic; also
    /// the delivery-order key).
    pub fn insert(&mut self, tab: &mut SymbolTable, filter: &str, value: T) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let entry = TrieEntry { seq, value };
        let mut node = &mut self.root;
        for level in filter.split('/') {
            if level == "#" {
                // `#` terminates the filter; like the reference
                // matcher, anything after it is ignored
                node.hash.push(entry);
                return seq;
            }
            node = if level == "+" {
                &mut **node.plus.get_or_insert_with(Box::default)
            } else {
                node.child_entry(tab.intern(level))
            };
        }
        node.here.push(entry);
        seq
    }

    /// Remove every entry under `filter` whose value satisfies `pred`;
    /// returns how many were removed. Emptied trie branches are pruned.
    pub fn remove(
        &mut self,
        tab: &SymbolTable,
        filter: &str,
        mut pred: impl FnMut(&T) -> bool,
    ) -> usize {
        let levels: Vec<&str> = filter.split('/').collect();
        let removed = Self::remove_rec(&mut self.root, tab, &levels, &mut pred);
        self.len -= removed;
        removed
    }

    fn remove_rec(
        node: &mut TrieNode<T>,
        tab: &SymbolTable,
        levels: &[&str],
        pred: &mut impl FnMut(&T) -> bool,
    ) -> usize {
        let Some((level, rest)) = levels.split_first() else {
            let before = node.here.len();
            node.here.retain(|e| !pred(&e.value));
            return before - node.here.len();
        };
        if *level == "#" {
            let before = node.hash.len();
            node.hash.retain(|e| !pred(&e.value));
            return before - node.hash.len();
        }
        if *level == "+" {
            let Some(plus) = node.plus.as_mut() else { return 0 };
            let n = Self::remove_rec(plus, tab, rest, pred);
            if plus.is_unused() {
                node.plus = None;
            }
            n
        } else {
            // a level the table never interned cannot key an edge
            let Some(sym) = tab.lookup(level) else { return 0 };
            let Ok(i) = node.keys.binary_search(&sym) else { return 0 };
            let n = Self::remove_rec(&mut node.nodes[i], tab, rest, pred);
            if node.nodes[i].is_unused() {
                node.keys.remove(i);
                node.nodes.remove(i);
            }
            n
        }
    }

    /// Visit every stored value whose filter matches the concrete
    /// `name`, in *trie-walk* order (NOT insertion order) — the
    /// zero-allocation primitive under `collect_matches*`. `f` receives
    /// each entry's insertion sequence so callers needing delivery
    /// order can sort. One walk visits at most 2^w paths where w is
    /// the number of `+`-branches taken — O(topic depth) for the
    /// exact-and-`#` filters that dominate real tables.
    pub fn for_each_match<'a>(&'a self, tab: &SymbolTable, name: &str, mut f: impl FnMut(u64, &'a T)) {
        Self::walk(&self.root, tab, name.split('/'), &mut f);
    }

    /// [`for_each_match`](Self::for_each_match) for a pre-interned
    /// name (see [`SymbolTable::intern_levels_into`]): the hot route
    /// path — no string in sight, every level is one `u32` compare.
    pub fn for_each_match_syms<'a>(&'a self, name: &[Sym], mut f: impl FnMut(u64, &'a T)) {
        Self::walk_syms(&self.root, name, &mut f);
    }

    /// Every stored value whose filter matches the concrete `name`,
    /// in insertion order. Allocates the result vector; steady-state
    /// routers should use [`collect_matches_into`] with a reused
    /// scratch buffer instead.
    ///
    /// [`collect_matches_into`]: TopicTrie::collect_matches_into
    pub fn collect_matches(&self, tab: &SymbolTable, name: &str) -> Vec<&T> {
        let mut hits: Vec<(u64, &T)> = Vec::new();
        self.for_each_match(tab, name, |seq, v| hits.push((seq, v)));
        // insertion order == linear-scan delivery order
        hits.sort_unstable_by_key(|&(seq, _)| seq);
        hits.into_iter().map(|(_, v)| v).collect()
    }

    /// Zero-allocation match collection for `Copy` values: clears
    /// `out` and refills it with `(insertion seq, value)` pairs sorted
    /// by seq (delivery order), reusing the buffer's capacity.
    pub fn collect_matches_into(&self, tab: &SymbolTable, name: &str, out: &mut Vec<(u64, T)>)
    where
        T: Copy,
    {
        out.clear();
        self.for_each_match(tab, name, |seq, v| out.push((seq, *v)));
        out.sort_unstable_by_key(|&(seq, _)| seq);
    }

    /// [`collect_matches_into`](Self::collect_matches_into) for a
    /// pre-interned name — the router hot path (`svcgraph::Fabric`
    /// keeps both the scratch vector and the symbol sequence across
    /// publishes).
    pub fn collect_matches_into_syms(&self, name: &[Sym], out: &mut Vec<(u64, T)>)
    where
        T: Copy,
    {
        out.clear();
        self.for_each_match_syms(name, |seq, v| out.push((seq, *v)));
        out.sort_unstable_by_key(|&(seq, _)| seq);
    }

    fn walk<'a>(
        node: &'a TrieNode<T>,
        tab: &SymbolTable,
        mut rest: std::str::Split<'_, char>,
        f: &mut impl FnMut(u64, &'a T),
    ) {
        // `#` at this depth matches the remaining levels — including
        // zero of them (`a/#` matches `a`)
        for e in &node.hash {
            f(e.seq, &e.value);
        }
        match rest.next() {
            None => {
                for e in &node.here {
                    f(e.seq, &e.value);
                }
            }
            Some(level) => {
                if let Some(child) = tab.lookup(level).and_then(|s| node.child(s)) {
                    Self::walk(child, tab, rest.clone(), f);
                }
                if let Some(plus) = &node.plus {
                    Self::walk(plus, tab, rest, f);
                }
            }
        }
    }

    fn walk_syms<'a>(node: &'a TrieNode<T>, rest: &[Sym], f: &mut impl FnMut(u64, &'a T)) {
        for e in &node.hash {
            f(e.seq, &e.value);
        }
        match rest.split_first() {
            None => {
                for e in &node.here {
                    f(e.seq, &e.value);
                }
            }
            Some((&sym, tail)) => {
                if let Some(child) = node.child(sym) {
                    Self::walk_syms(child, tail, f);
                }
                if let Some(plus) = &node.plus {
                    Self::walk_syms(plus, tail, f);
                }
            }
        }
    }

    /// The INVERSE lookup direction: treat stored keys as concrete
    /// topic *names* and walk the trie directed by the wildcard
    /// `filter`, visiting every stored value whose name the filter
    /// matches (visit order is unspecified; `f` receives the insertion
    /// seq for deterministic ordering). This is retained-message
    /// replay: the broker keys retained messages by name and a new
    /// subscription replays only the trie paths its filter selects,
    /// instead of scanning every retained topic.
    ///
    /// Assumes stored keys are wildcard-free (the broker validates
    /// names before retaining); entries stored under `+`/`#` filter
    /// keys are not visited.
    pub fn for_each_name_match<'a>(
        &'a self,
        tab: &SymbolTable,
        filter: &str,
        mut f: impl FnMut(u64, &'a T),
    ) {
        Self::name_walk(&self.root, tab, filter.split('/'), &mut f);
    }

    fn name_walk<'a>(
        node: &'a TrieNode<T>,
        tab: &SymbolTable,
        mut rest: std::str::Split<'_, char>,
        f: &mut impl FnMut(u64, &'a T),
    ) {
        match rest.next() {
            None => {
                for e in &node.here {
                    f(e.seq, &e.value);
                }
            }
            // `#` swallows the rest INCLUDING zero levels: this node's
            // own entry and its entire literal subtree
            Some("#") => Self::collect_name_subtree(node, f),
            Some("+") => {
                for child in &node.nodes {
                    Self::name_walk(child, tab, rest.clone(), f);
                }
            }
            Some(level) => {
                if let Some(child) = tab.lookup(level).and_then(|s| node.child(s)) {
                    Self::name_walk(child, tab, rest, f);
                }
            }
        }
    }

    fn collect_name_subtree<'a>(node: &'a TrieNode<T>, f: &mut impl FnMut(u64, &'a T)) {
        for e in &node.here {
            f(e.seq, &e.value);
        }
        for child in &node.nodes {
            Self::collect_name_subtree(child, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_level_and_shard_crossing() {
        assert_eq!(first_level("a/b/c"), "a");
        assert_eq!(first_level("a"), "a");
        assert_eq!(first_level("+/b"), "+");
        assert_eq!(first_level("#"), "#");
        assert!(filter_crosses_shards("#"));
        assert!(filter_crosses_shards("+/b/c"));
        assert!(!filter_crosses_shards("a/#"));
        assert!(!filter_crosses_shards("a/+/c"));
        assert!(!filter_crosses_shards("a/b"));
    }

    #[test]
    fn exact_match() {
        assert!(matches("a/b/c", "a/b/c"));
        assert!(!matches("a/b/c", "a/b"));
        assert!(!matches("a/b", "a/b/c"));
    }

    #[test]
    fn plus_matches_one_level() {
        assert!(matches("a/+/c", "a/b/c"));
        assert!(matches("+/b/c", "a/b/c"));
        assert!(!matches("a/+", "a/b/c"));
        assert!(!matches("a/+/c", "a/c"));
    }

    #[test]
    fn hash_matches_rest() {
        assert!(matches("a/#", "a/b/c"));
        assert!(matches("#", "anything/at/all"));
        assert!(matches("a/#", "a/b"));
        // MQTT spec: `a/#` matches the parent `a` itself too.
        assert!(matches("a/#", "a"));
        assert!(!matches("a/#", "b"));
    }

    #[test]
    fn validity() {
        assert!(valid_name("a/b/c"));
        assert!(!valid_name("a//c"));
        assert!(!valid_name("a/+/c"));
        assert!(!valid_name(""));
        assert!(valid_filter("a/+/c"));
        assert!(valid_filter("a/#"));
        assert!(valid_filter("#"));
        assert!(!valid_filter("a/#/c"));
        assert!(!valid_filter("a/b+"));
        assert!(!valid_filter("a//b"));
    }

    #[test]
    fn symbols_are_dense_and_stable() {
        let mut tab = SymbolTable::new();
        let a = tab.intern("a");
        let b = tab.intern("b");
        assert_eq!((a, b), (0, 1));
        assert_eq!(tab.intern("a"), a, "re-interning must be stable");
        assert_eq!(tab.lookup("b"), Some(b));
        assert_eq!(tab.lookup("never-seen"), None);
        assert_eq!(tab.len(), 2);
        let mut syms = Vec::new();
        tab.intern_levels_into("a/b/c", &mut syms);
        assert_eq!(syms, vec![0, 1, 2]);
        tab.intern_levels_into("c/a", &mut syms);
        assert_eq!(syms, vec![2, 0], "buffer is cleared and refilled");
    }

    #[test]
    fn trie_exact_plus_hash() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "a/b/c", 0usize);
        t.insert(&mut tab, "a/+/c", 1);
        t.insert(&mut tab, "a/#", 2);
        t.insert(&mut tab, "#", 3);
        t.insert(&mut tab, "x/y", 4);
        assert_eq!(t.len(), 5);
        let got: Vec<usize> = t.collect_matches(&tab, "a/b/c").into_iter().copied().collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        let got: Vec<usize> = t.collect_matches(&tab, "x/y").into_iter().copied().collect();
        assert_eq!(got, vec![3, 4]);
    }

    #[test]
    fn trie_hash_matches_parent_level() {
        // the MQTT edge case: `a/#` matches `a` itself
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "a/#", 0usize);
        t.insert(&mut tab, "+/#", 1);
        assert_eq!(
            t.collect_matches(&tab, "a").into_iter().copied().collect::<Vec<_>>(),
            vec![0, 1]
        );
        assert!(t.collect_matches(&tab, "b").into_iter().copied().collect::<Vec<_>>() == vec![1]);
    }

    #[test]
    fn trie_plus_is_exactly_one_level() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "a/+", 0usize);
        assert_eq!(t.collect_matches(&tab, "a/b").len(), 1);
        assert!(t.collect_matches(&tab, "a").is_empty());
        assert!(t.collect_matches(&tab, "a/b/c").is_empty());
    }

    #[test]
    fn trie_reports_matches_in_insertion_order() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        // interleave filters so trie layout differs from insertion order
        t.insert(&mut tab, "z/#", 10usize);
        t.insert(&mut tab, "a/b", 11);
        t.insert(&mut tab, "#", 12);
        t.insert(&mut tab, "a/+", 13);
        t.insert(&mut tab, "a/b", 14);
        let got: Vec<usize> = t.collect_matches(&tab, "a/b").into_iter().copied().collect();
        assert_eq!(got, vec![11, 12, 13, 14]);
    }

    #[test]
    fn trie_remove_prunes_and_recounts() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "a/b/c", 1usize);
        t.insert(&mut tab, "a/b/c", 2);
        t.insert(&mut tab, "a/+/c", 3);
        t.insert(&mut tab, "a/#", 4);
        assert_eq!(t.remove(&tab, "a/b/c", |v| *v == 1), 1);
        assert_eq!(t.len(), 3);
        let got: Vec<usize> = t.collect_matches(&tab, "a/b/c").into_iter().copied().collect();
        assert_eq!(got, vec![2, 3, 4]);
        // removing a filter that is not stored is a no-op
        assert_eq!(t.remove(&tab, "a/b", |_| true), 0);
        // ... including one whose levels were never interned at all
        assert_eq!(t.remove(&tab, "ghost/topic", |_| true), 0);
        assert_eq!(t.remove(&tab, "a/+/c", |_| true), 1);
        assert_eq!(t.remove(&tab, "a/#", |_| true), 1);
        assert_eq!(t.remove(&tab, "a/b/c", |_| true), 1);
        assert!(t.is_empty());
        // branches were pruned: root is empty again
        assert!(t.root.is_unused());
    }

    #[test]
    fn collect_matches_into_reuses_scratch_and_agrees() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "z/#", 10usize);
        t.insert(&mut tab, "a/b", 11);
        t.insert(&mut tab, "#", 12);
        t.insert(&mut tab, "a/+", 13);
        t.insert(&mut tab, "a/b", 14);
        let mut scratch: Vec<(u64, usize)> = Vec::with_capacity(8);
        t.collect_matches_into(&tab, "a/b", &mut scratch);
        let got: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
        assert_eq!(got, vec![11, 12, 13, 14]);
        // reuse: cleared and refilled, old contents never leak
        t.collect_matches_into(&tab, "z/q", &mut scratch);
        let got: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
        assert_eq!(got, vec![10, 12]);
        // agreement with the allocating API on every query
        for name in ["a/b", "a/x", "z", "q/r/s"] {
            t.collect_matches_into(&tab, name, &mut scratch);
            let fast: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
            let slow: Vec<usize> = t.collect_matches(&tab, name).into_iter().copied().collect();
            assert_eq!(fast, slow, "{name}");
        }
    }

    #[test]
    fn symbol_walk_agrees_with_string_walk() {
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "app/+/data", 0usize);
        t.insert(&mut tab, "app/#", 1);
        t.insert(&mut tab, "app/x/data", 2);
        t.insert(&mut tab, "#", 3);
        let mut syms = Vec::new();
        let mut scratch: Vec<(u64, usize)> = Vec::new();
        for name in ["app/x/data", "app/y/data", "app", "other/x"] {
            tab.intern_levels_into(name, &mut syms);
            t.collect_matches_into_syms(&syms, &mut scratch);
            let fast: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
            let slow: Vec<usize> = t.collect_matches(&tab, name).into_iter().copied().collect();
            assert_eq!(fast, slow, "{name}");
        }
    }

    #[test]
    fn unknown_levels_still_match_wildcard_branches() {
        // a name level the table has never interned can't reach any
        // literal edge, but `+` and `#` must still swallow it
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "a/+", 0usize);
        t.insert(&mut tab, "a/#", 1);
        t.insert(&mut tab, "a/b", 2);
        let got: Vec<usize> = t.collect_matches(&tab, "a/unseen").into_iter().copied().collect();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn name_match_walks_only_filter_directed_paths() {
        // retained-replay direction: keys are concrete names, the
        // query is a filter
        let mut tab = SymbolTable::new();
        let mut t = TopicTrie::new();
        t.insert(&mut tab, "cfg/a", 0usize);
        t.insert(&mut tab, "cfg/b", 1);
        t.insert(&mut tab, "cfg/b/deep", 2);
        t.insert(&mut tab, "other/x", 3);
        let collect = |filter: &str| {
            let mut got: Vec<(u64, usize)> = Vec::new();
            t.for_each_name_match(&tab, filter, |seq, v| got.push((seq, *v)));
            got.sort_unstable();
            got.into_iter().map(|(_, v)| v).collect::<Vec<_>>()
        };
        assert_eq!(collect("cfg/a"), vec![0]);
        assert_eq!(collect("cfg/+"), vec![0, 1]);
        assert_eq!(collect("cfg/#"), vec![0, 1, 2]);
        assert_eq!(collect("#"), vec![0, 1, 2, 3]);
        assert_eq!(collect("cfg/b/#"), vec![1, 2], "b/# matches parent b too");
        assert_eq!(collect("+/x"), vec![3]);
        assert_eq!(collect("nope/#"), Vec::<usize>::new());
    }

    #[test]
    fn trie_mirrors_reference_on_the_spec_examples() {
        for (filter, name, want) in [
            ("a/b/c", "a/b/c", true),
            ("a/b/c", "a/b", false),
            ("a/+/c", "a/b/c", true),
            ("a/+/c", "a/c", false),
            ("a/#", "a/b/c", true),
            ("a/#", "a", true),
            ("a/#", "b", false),
            ("#", "anything/at/all", true),
        ] {
            let mut tab = SymbolTable::new();
            let mut t = TopicTrie::new();
            t.insert(&mut tab, filter, ());
            assert_eq!(matches(filter, name), want, "reference {filter} vs {name}");
            assert_eq!(
                !t.collect_matches(&tab, name).is_empty(),
                want,
                "trie {filter} vs {name}"
            );
        }
    }
}
