//! MQTT-style topic names and filters (`+` and `#` wildcards).
//!
//! Shared by the threaded broker (platform control plane) and the DES
//! message router (experiment data plane), so both agree on semantics.
//!
//! Two matching engines live here and MUST agree:
//!
//! * [`matches`] — the reference scalar matcher, O(filter levels) per
//!   (filter, name) pair; a router holding N subscriptions pays O(N)
//!   per publish with it.
//! * [`TopicTrie`] — the subscription *index*: filters are stored as
//!   paths in a level trie (literal edges, a `+` edge, `#` terminals),
//!   so one publish walks O(topic depth) nodes regardless of N. Both
//!   `svcgraph::Fabric` (DES data plane) and `pubsub::Broker`
//!   (threaded control plane) route through it.
//!
//! Agreement (including `+`/`#` edge cases like `a/#` matching the
//! parent `a`) is enforced by a differential property test in
//! `tests/properties.rs`.

use std::collections::HashMap;

/// Is `name` a valid concrete topic (no wildcards, non-empty levels)?
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && !name.contains(['+', '#'])
        && name.split('/').all(|l| !l.is_empty())
}

/// Is `filter` a valid subscription filter?
/// `+` matches one level; `#` matches the rest and must be last.
pub fn valid_filter(filter: &str) -> bool {
    if filter.is_empty() {
        return false;
    }
    let levels: Vec<&str> = filter.split('/').collect();
    for (i, l) in levels.iter().enumerate() {
        if l.is_empty() {
            return false;
        }
        if l.contains('#') && (*l != "#" || i != levels.len() - 1) {
            return false;
        }
        if l.contains('+') && *l != "+" {
            return false;
        }
    }
    true
}

/// MQTT topic matching: does `filter` match concrete `name`?
pub fn matches(filter: &str, name: &str) -> bool {
    let mut f = filter.split('/');
    let mut n = name.split('/');
    loop {
        match (f.next(), n.next()) {
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => continue,
            (Some(fl), Some(nl)) if fl == nl => continue,
            (None, None) => return true,
            _ => return false,
        }
    }
}

/// One stored subscription: `seq` is the global insertion sequence,
/// used to report matches in insertion order (delivery-order parity
/// with the linear scan the trie replaced — and, through the DES
/// scheduler's insertion-sequence tie-breaking, determinism).
struct TrieEntry<T> {
    seq: u64,
    value: T,
}

/// One trie node = one topic level. Filters terminate either exactly
/// here (`here`) or with a `#` that swallows this node's subtree AND
/// the node itself (`hash` — MQTT: `a/#` matches the parent `a`).
struct TrieNode<T> {
    children: HashMap<String, TrieNode<T>>,
    plus: Option<Box<TrieNode<T>>>,
    here: Vec<TrieEntry<T>>,
    hash: Vec<TrieEntry<T>>,
}

impl<T> TrieNode<T> {
    fn new() -> Self {
        TrieNode { children: HashMap::new(), plus: None, here: Vec::new(), hash: Vec::new() }
    }

    fn is_unused(&self) -> bool {
        self.children.is_empty()
            && self.plus.is_none()
            && self.here.is_empty()
            && self.hash.is_empty()
    }
}

impl<T> Default for TrieNode<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Topic-trie subscription index: `insert`/`remove` filters, then
/// `collect_matches(name)` returns every stored value whose filter
/// matches `name`, in insertion order, walking O(topic depth) nodes
/// instead of scanning all subscriptions.
///
/// Semantics mirror [`matches`] verbatim for ANY filter string, valid
/// or not: levels are compared literally, `+` matches exactly one
/// level, and a `#` level terminates the filter (the reference matcher
/// also ignores anything after a `#`).
pub struct TopicTrie<T> {
    root: TrieNode<T>,
    next_seq: u64,
    len: usize,
}

impl<T> Default for TopicTrie<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TopicTrie<T> {
    pub fn new() -> Self {
        TopicTrie { root: TrieNode::new(), next_seq: 0, len: 0 }
    }

    /// Stored subscriptions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store `value` under `filter`. Returns the insertion sequence
    /// number (monotonic; also the delivery-order key).
    pub fn insert(&mut self, filter: &str, value: T) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let entry = TrieEntry { seq, value };
        let mut node = &mut self.root;
        for level in filter.split('/') {
            if level == "#" {
                // `#` terminates the filter; like the reference
                // matcher, anything after it is ignored
                node.hash.push(entry);
                return seq;
            }
            node = if level == "+" {
                &mut **node.plus.get_or_insert_with(Box::default)
            } else {
                node.children.entry(level.to_string()).or_default()
            };
        }
        node.here.push(entry);
        seq
    }

    /// Remove every entry under `filter` whose value satisfies `pred`;
    /// returns how many were removed. Emptied trie branches are pruned.
    pub fn remove(&mut self, filter: &str, mut pred: impl FnMut(&T) -> bool) -> usize {
        let levels: Vec<&str> = filter.split('/').collect();
        let removed = Self::remove_rec(&mut self.root, &levels, &mut pred);
        self.len -= removed;
        removed
    }

    fn remove_rec(
        node: &mut TrieNode<T>,
        levels: &[&str],
        pred: &mut impl FnMut(&T) -> bool,
    ) -> usize {
        let Some((level, rest)) = levels.split_first() else {
            let before = node.here.len();
            node.here.retain(|e| !pred(&e.value));
            return before - node.here.len();
        };
        if *level == "#" {
            let before = node.hash.len();
            node.hash.retain(|e| !pred(&e.value));
            return before - node.hash.len();
        }
        if *level == "+" {
            let Some(plus) = node.plus.as_mut() else { return 0 };
            let n = Self::remove_rec(plus, rest, pred);
            if plus.is_unused() {
                node.plus = None;
            }
            n
        } else {
            let Some(child) = node.children.get_mut(*level) else { return 0 };
            let n = Self::remove_rec(child, rest, pred);
            if child.is_unused() {
                node.children.remove(*level);
            }
            n
        }
    }

    /// Visit every stored value whose filter matches the concrete
    /// `name`, in *trie-walk* order (NOT insertion order) — the
    /// zero-allocation primitive under `collect_matches*`. `f` receives
    /// each entry's insertion sequence so callers needing delivery
    /// order can sort. One walk visits at most 2^w paths where w is
    /// the number of `+`-branches taken — O(topic depth) for the
    /// exact-and-`#` filters that dominate real tables.
    pub fn for_each_match<'a>(&'a self, name: &str, mut f: impl FnMut(u64, &'a T)) {
        Self::walk(&self.root, name.split('/'), &mut f);
    }

    /// Every stored value whose filter matches the concrete `name`,
    /// in insertion order. Allocates the result vector; steady-state
    /// routers should use [`collect_matches_into`] with a reused
    /// scratch buffer instead.
    ///
    /// [`collect_matches_into`]: TopicTrie::collect_matches_into
    pub fn collect_matches(&self, name: &str) -> Vec<&T> {
        let mut hits: Vec<(u64, &T)> = Vec::new();
        self.for_each_match(name, |seq, v| hits.push((seq, v)));
        // insertion order == linear-scan delivery order
        hits.sort_unstable_by_key(|&(seq, _)| seq);
        hits.into_iter().map(|(_, v)| v).collect()
    }

    /// Zero-allocation match collection for `Copy` values: clears
    /// `out` and refills it with `(insertion seq, value)` pairs sorted
    /// by seq (delivery order), reusing the buffer's capacity. The
    /// router hot path (`svcgraph::Fabric` keeps the scratch vectors
    /// across publishes).
    pub fn collect_matches_into(&self, name: &str, out: &mut Vec<(u64, T)>)
    where
        T: Copy,
    {
        out.clear();
        self.for_each_match(name, |seq, v| out.push((seq, *v)));
        out.sort_unstable_by_key(|&(seq, _)| seq);
    }

    fn walk<'a>(
        node: &'a TrieNode<T>,
        mut rest: std::str::Split<'_, char>,
        f: &mut impl FnMut(u64, &'a T),
    ) {
        // `#` at this depth matches the remaining levels — including
        // zero of them (`a/#` matches `a`)
        for e in &node.hash {
            f(e.seq, &e.value);
        }
        match rest.next() {
            None => {
                for e in &node.here {
                    f(e.seq, &e.value);
                }
            }
            Some(level) => {
                if let Some(child) = node.children.get(level) {
                    Self::walk(child, rest.clone(), f);
                }
                if let Some(plus) = &node.plus {
                    Self::walk(plus, rest, f);
                }
            }
        }
    }

    /// The INVERSE lookup direction: treat stored keys as concrete
    /// topic *names* and walk the trie directed by the wildcard
    /// `filter`, visiting every stored value whose name the filter
    /// matches (visit order is unspecified; `f` receives the insertion
    /// seq for deterministic ordering). This is retained-message
    /// replay: the broker keys retained messages by name and a new
    /// subscription replays only the trie paths its filter selects,
    /// instead of scanning every retained topic.
    ///
    /// Assumes stored keys are wildcard-free (the broker validates
    /// names before retaining); entries stored under `+`/`#` filter
    /// keys are not visited.
    pub fn for_each_name_match<'a>(&'a self, filter: &str, mut f: impl FnMut(u64, &'a T)) {
        Self::name_walk(&self.root, filter.split('/'), &mut f);
    }

    fn name_walk<'a>(
        node: &'a TrieNode<T>,
        mut rest: std::str::Split<'_, char>,
        f: &mut impl FnMut(u64, &'a T),
    ) {
        match rest.next() {
            None => {
                for e in &node.here {
                    f(e.seq, &e.value);
                }
            }
            // `#` swallows the rest INCLUDING zero levels: this node's
            // own entry and its entire literal subtree
            Some("#") => Self::collect_name_subtree(node, f),
            Some("+") => {
                for child in node.children.values() {
                    Self::name_walk(child, rest.clone(), f);
                }
            }
            Some(level) => {
                if let Some(child) = node.children.get(level) {
                    Self::name_walk(child, rest, f);
                }
            }
        }
    }

    fn collect_name_subtree<'a>(node: &'a TrieNode<T>, f: &mut impl FnMut(u64, &'a T)) {
        for e in &node.here {
            f(e.seq, &e.value);
        }
        for child in node.children.values() {
            Self::collect_name_subtree(child, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        assert!(matches("a/b/c", "a/b/c"));
        assert!(!matches("a/b/c", "a/b"));
        assert!(!matches("a/b", "a/b/c"));
    }

    #[test]
    fn plus_matches_one_level() {
        assert!(matches("a/+/c", "a/b/c"));
        assert!(matches("+/b/c", "a/b/c"));
        assert!(!matches("a/+", "a/b/c"));
        assert!(!matches("a/+/c", "a/c"));
    }

    #[test]
    fn hash_matches_rest() {
        assert!(matches("a/#", "a/b/c"));
        assert!(matches("#", "anything/at/all"));
        assert!(matches("a/#", "a/b"));
        // MQTT spec: `a/#` matches the parent `a` itself too.
        assert!(matches("a/#", "a"));
        assert!(!matches("a/#", "b"));
    }

    #[test]
    fn validity() {
        assert!(valid_name("a/b/c"));
        assert!(!valid_name("a//c"));
        assert!(!valid_name("a/+/c"));
        assert!(!valid_name(""));
        assert!(valid_filter("a/+/c"));
        assert!(valid_filter("a/#"));
        assert!(valid_filter("#"));
        assert!(!valid_filter("a/#/c"));
        assert!(!valid_filter("a/b+"));
        assert!(!valid_filter("a//b"));
    }

    #[test]
    fn trie_exact_plus_hash() {
        let mut t = TopicTrie::new();
        t.insert("a/b/c", 0usize);
        t.insert("a/+/c", 1);
        t.insert("a/#", 2);
        t.insert("#", 3);
        t.insert("x/y", 4);
        assert_eq!(t.len(), 5);
        let got: Vec<usize> = t.collect_matches("a/b/c").into_iter().copied().collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        let got: Vec<usize> = t.collect_matches("x/y").into_iter().copied().collect();
        assert_eq!(got, vec![3, 4]);
    }

    #[test]
    fn trie_hash_matches_parent_level() {
        // the MQTT edge case: `a/#` matches `a` itself
        let mut t = TopicTrie::new();
        t.insert("a/#", 0usize);
        t.insert("+/#", 1);
        assert_eq!(
            t.collect_matches("a").into_iter().copied().collect::<Vec<_>>(),
            vec![0, 1]
        );
        assert!(t.collect_matches("b").into_iter().copied().collect::<Vec<_>>() == vec![1]);
    }

    #[test]
    fn trie_plus_is_exactly_one_level() {
        let mut t = TopicTrie::new();
        t.insert("a/+", 0usize);
        assert_eq!(t.collect_matches("a/b").len(), 1);
        assert!(t.collect_matches("a").is_empty());
        assert!(t.collect_matches("a/b/c").is_empty());
    }

    #[test]
    fn trie_reports_matches_in_insertion_order() {
        let mut t = TopicTrie::new();
        // interleave filters so trie layout differs from insertion order
        t.insert("z/#", 10usize);
        t.insert("a/b", 11);
        t.insert("#", 12);
        t.insert("a/+", 13);
        t.insert("a/b", 14);
        let got: Vec<usize> = t.collect_matches("a/b").into_iter().copied().collect();
        assert_eq!(got, vec![11, 12, 13, 14]);
    }

    #[test]
    fn trie_remove_prunes_and_recounts() {
        let mut t = TopicTrie::new();
        t.insert("a/b/c", 1usize);
        t.insert("a/b/c", 2);
        t.insert("a/+/c", 3);
        t.insert("a/#", 4);
        assert_eq!(t.remove("a/b/c", |v| *v == 1), 1);
        assert_eq!(t.len(), 3);
        let got: Vec<usize> = t.collect_matches("a/b/c").into_iter().copied().collect();
        assert_eq!(got, vec![2, 3, 4]);
        // removing a filter that is not stored is a no-op
        assert_eq!(t.remove("a/b", |_| true), 0);
        assert_eq!(t.remove("a/+/c", |_| true), 1);
        assert_eq!(t.remove("a/#", |_| true), 1);
        assert_eq!(t.remove("a/b/c", |_| true), 1);
        assert!(t.is_empty());
        // branches were pruned: root is empty again
        assert!(t.root.is_unused());
    }

    #[test]
    fn collect_matches_into_reuses_scratch_and_agrees() {
        let mut t = TopicTrie::new();
        t.insert("z/#", 10usize);
        t.insert("a/b", 11);
        t.insert("#", 12);
        t.insert("a/+", 13);
        t.insert("a/b", 14);
        let mut scratch: Vec<(u64, usize)> = Vec::with_capacity(8);
        t.collect_matches_into("a/b", &mut scratch);
        let got: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
        assert_eq!(got, vec![11, 12, 13, 14]);
        // reuse: cleared and refilled, old contents never leak
        t.collect_matches_into("z/q", &mut scratch);
        let got: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
        assert_eq!(got, vec![10, 12]);
        // agreement with the allocating API on every query
        for name in ["a/b", "a/x", "z", "q/r/s"] {
            t.collect_matches_into(name, &mut scratch);
            let fast: Vec<usize> = scratch.iter().map(|&(_, v)| v).collect();
            let slow: Vec<usize> = t.collect_matches(name).into_iter().copied().collect();
            assert_eq!(fast, slow, "{name}");
        }
    }

    #[test]
    fn name_match_walks_only_filter_directed_paths() {
        // retained-replay direction: keys are concrete names, the
        // query is a filter
        let mut t = TopicTrie::new();
        t.insert("cfg/a", 0usize);
        t.insert("cfg/b", 1);
        t.insert("cfg/b/deep", 2);
        t.insert("other/x", 3);
        let collect = |filter: &str| {
            let mut got: Vec<(u64, usize)> = Vec::new();
            t.for_each_name_match(filter, |seq, v| got.push((seq, *v)));
            got.sort_unstable();
            got.into_iter().map(|(_, v)| v).collect::<Vec<_>>()
        };
        assert_eq!(collect("cfg/a"), vec![0]);
        assert_eq!(collect("cfg/+"), vec![0, 1]);
        assert_eq!(collect("cfg/#"), vec![0, 1, 2]);
        assert_eq!(collect("#"), vec![0, 1, 2, 3]);
        assert_eq!(collect("cfg/b/#"), vec![1, 2], "b/# matches parent b too");
        assert_eq!(collect("+/x"), vec![3]);
        assert_eq!(collect("nope/#"), Vec::<usize>::new());
    }

    #[test]
    fn trie_mirrors_reference_on_the_spec_examples() {
        for (filter, name, want) in [
            ("a/b/c", "a/b/c", true),
            ("a/b/c", "a/b", false),
            ("a/+/c", "a/b/c", true),
            ("a/+/c", "a/c", false),
            ("a/#", "a/b/c", true),
            ("a/#", "a", true),
            ("a/#", "b", false),
            ("#", "anything/at/all", true),
        ] {
            let mut t = TopicTrie::new();
            t.insert(filter, ());
            assert_eq!(matches(filter, name), want, "reference {filter} vs {name}");
            assert_eq!(
                !t.collect_matches(name).is_empty(),
                want,
                "trie {filter} vs {name}"
            );
        }
    }
}
