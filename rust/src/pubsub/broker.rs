//! In-process MQTT-like broker — the resource-level message service.
//!
//! §4.3.2: ACE deploys a message service on every EC and on the CC;
//! application components only ever talk to their *local* broker, and
//! EC<->CC unicast rides the long-lasting bridge (see `bridge.rs`,
//! Figure 2 link ②). QoS-0 semantics, retained messages, `+`/`#`
//! filters. Subscribers receive on std mpsc channels; byte counters
//! support the bridged-vs-direct ablation bench.
//!
//! Routing is indexed: subscriptions live in a [`topic::TopicTrie`],
//! so a publish walks O(topic depth) trie nodes instead of scanning
//! every subscription (the same index `svcgraph::Fabric` uses on the
//! DES data plane). Delivery order stays insertion order.
//!
//! Hot-path economics (DESIGN.md §Event-engine): the broker name lives
//! in an `Arc<str>` OUTSIDE the lock, so stamping `Message::origin` is
//! a refcount bump, not a `String` clone per publish; counters are
//! atomics, so `name()`/`stats()` never contend with the publish path;
//! retained messages live in a name-keyed [`TopicTrie`], so subscribe
//! replays only the trie paths its filter selects instead of scanning
//! every retained topic.

use super::topic::{self, SymbolTable, TopicTrie};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

/// The shared empty origin (allocated once per process), so
/// `Message::new` itself allocates nothing for the origin slot.
fn no_origin() -> Arc<str> {
    static EMPTY: OnceLock<Arc<str>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from("")).clone()
}

/// A published message. The payload sits behind an `Arc` so fanning a
/// message out to N subscribers shares one buffer instead of cloning N
/// copies (the broker's hot path); `origin` shares the broker's name
/// allocation the same way.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub topic: String,
    pub payload: Arc<[u8]>,
    /// Broker the message FIRST entered (loop prevention in bridges);
    /// empty until the first broker stamps it.
    pub origin: Arc<str>,
}

impl Message {
    /// A fresh message with no origin stamp (the first broker it
    /// enters stamps it).
    pub fn new(topic: impl Into<String>, payload: impl Into<Vec<u8>>) -> Self {
        Message {
            topic: topic.into(),
            payload: Arc::from(payload.into()),
            origin: no_origin(),
        }
    }

    /// Payload decoded as (lossy) UTF-8 — JSON/yamlite wire documents.
    pub fn utf8(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }
}

struct Subscription {
    tx: Sender<Message>,
    id: u64,
}

struct Inner {
    /// Subscription index: one publish routes in O(topic depth).
    subs: TopicTrie<Subscription>,
    /// id -> filter, so unsubscribe/pruning can address the trie path.
    filters: HashMap<u64, String>,
    /// Retained messages keyed by topic NAME; subscribe walks the trie
    /// directed by its filter (`for_each_name_match`) instead of
    /// scanning the whole map.
    retained: TopicTrie<Message>,
    /// Level symbols shared by BOTH tries (subscription filters and
    /// retained names draw from the same level vocabulary).
    table: SymbolTable,
    next_id: u64,
}

/// Publish/delivery counters — atomics outside the lock, so stats
/// reads never contend with the publish path.
#[derive(Default)]
struct Counters {
    /// (messages, payload bytes) accepted by publish.
    pub_count: AtomicU64,
    pub_bytes: AtomicU64,
    /// (messages, payload bytes) delivered to subscribers.
    deliver_count: AtomicU64,
    deliver_bytes: AtomicU64,
    /// Live subscriptions (mirrors `subs.len()`, maintained under the
    /// lock, readable without it).
    subscriptions: AtomicUsize,
}

/// Handle to a broker (cheaply cloneable).
#[derive(Clone)]
pub struct Broker {
    inner: Arc<Mutex<Inner>>,
    name: Arc<str>,
    counters: Arc<Counters>,
}

/// A subscription handle; dropping it does NOT unsubscribe (call
/// `Broker::unsubscribe`), but a closed receiver is garbage-collected on
/// the next publish that routes to it.
pub struct SubHandle {
    /// Subscription id (for [`Broker::unsubscribe`]).
    pub id: u64,
    /// Receiving end: matching messages (and retained replays).
    pub rx: Receiver<Message>,
}

/// Snapshot of a broker's publish/delivery counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BrokerStats {
    /// Messages accepted by publish.
    pub pub_count: u64,
    /// Payload bytes accepted by publish.
    pub pub_bytes: u64,
    /// Messages delivered to subscribers.
    pub deliver_count: u64,
    /// Payload bytes delivered to subscribers.
    pub deliver_bytes: u64,
    /// Live subscriptions.
    pub subscriptions: usize,
}

impl Broker {
    /// A fresh broker named `name` (the per-cluster message service
    /// instance of §4.3.2).
    pub fn new(name: impl Into<String>) -> Self {
        Broker {
            inner: Arc::new(Mutex::new(Inner {
                subs: TopicTrie::new(),
                filters: HashMap::new(),
                retained: TopicTrie::new(),
                table: SymbolTable::new(),
                next_id: 1,
            })),
            name: Arc::from(name.into()),
            counters: Arc::new(Counters::default()),
        }
    }

    /// Broker name — lock-free (shared `Arc<str>`, no contention with
    /// the publish path).
    pub fn name(&self) -> Arc<str> {
        self.name.clone()
    }

    /// Subscribe to `filter`; retained messages matching the filter are
    /// delivered immediately (in retain order).
    pub fn subscribe(&self, filter: &str) -> Result<SubHandle, String> {
        if !topic::valid_filter(filter) {
            return Err(format!("invalid filter '{filter}'"));
        }
        let (tx, rx) = channel();
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let id = inner.next_id;
        inner.next_id += 1;
        // replay retained: a filter-directed trie walk visits only the
        // matching paths, not every retained topic; sorting by the
        // insertion seq makes replay order deterministic (retain order)
        // where the old full map scan was HashMap-ordered
        let mut replayed: Vec<(u64, Message)> = Vec::new();
        inner
            .retained
            .for_each_name_match(&inner.table, filter, |seq, m| replayed.push((seq, m.clone())));
        replayed.sort_unstable_by_key(|&(seq, _)| seq);
        for (_, m) in replayed {
            let bytes = m.payload.len() as u64;
            if tx.send(m).is_ok() {
                self.counters.deliver_count.fetch_add(1, Ordering::Relaxed);
                self.counters.deliver_bytes.fetch_add(bytes, Ordering::Relaxed);
            }
        }
        inner.subs.insert(&mut inner.table, filter, Subscription { tx, id });
        inner.filters.insert(id, filter.to_string());
        self.counters
            .subscriptions
            .store(inner.subs.len(), Ordering::Relaxed);
        Ok(SubHandle { id, rx })
    }

    /// Drop subscription `id`: a targeted trie-path removal, not a
    /// scan over every subscription.
    pub fn unsubscribe(&self, id: u64) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if let Some(filter) = inner.filters.remove(&id) {
            inner.subs.remove(&inner.table, &filter, |s| s.id == id);
        }
        self.counters
            .subscriptions
            .store(inner.subs.len(), Ordering::Relaxed);
    }

    /// Publish; `retain` keeps the last message per topic for future
    /// subscribers. Returns the number of subscribers reached.
    pub fn publish_opts(&self, mut msg: Message, retain: bool) -> Result<usize, String> {
        if !topic::valid_name(&msg.topic) {
            return Err(format!("invalid topic '{}'", msg.topic));
        }
        if msg.origin.is_empty() {
            // refcount bump on the broker's shared name, no String clone
            msg.origin = self.name.clone();
        }
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        self.counters.pub_count.fetch_add(1, Ordering::Relaxed);
        self.counters
            .pub_bytes
            .fetch_add(msg.payload.len() as u64, Ordering::Relaxed);
        if retain {
            // last-writer-wins per topic: drop any previous retained
            // message for this name, then store under a fresh seq
            inner.retained.remove(&inner.table, &msg.topic, |_| true);
            inner.retained.insert(&mut inner.table, &msg.topic, msg.clone());
        }
        let mut reached = 0;
        let mut dead: Vec<u64> = Vec::new();
        let mut delivered_bytes = 0u64;
        // O(topic depth) trie walk; matches come back in insertion
        // (i.e. subscription) order
        for s in inner.subs.collect_matches(&inner.table, &msg.topic) {
            // Arc payload: per-subscriber clone is a refcount bump
            if s.tx.send(msg.clone()).is_ok() {
                reached += 1;
                delivered_bytes += msg.payload.len() as u64;
            } else {
                dead.push(s.id);
            }
        }
        self.counters
            .deliver_count
            .fetch_add(reached as u64, Ordering::Relaxed);
        self.counters
            .deliver_bytes
            .fetch_add(delivered_bytes, Ordering::Relaxed);
        // garbage-collect closed receivers: each is one targeted trie
        // path removal, not a scan over every subscription
        if !dead.is_empty() {
            for id in dead {
                if let Some(filter) = inner.filters.remove(&id) {
                    inner.subs.remove(&inner.table, &filter, |s| s.id == id);
                }
            }
            self.counters
                .subscriptions
                .store(inner.subs.len(), Ordering::Relaxed);
        }
        Ok(reached)
    }

    /// Publish without retaining. Returns the subscribers reached.
    pub fn publish(&self, topic: &str, payload: impl Into<Vec<u8>>) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), false)
    }

    /// Publish and retain (last-writer-wins per topic) for future
    /// subscribers. Returns the subscribers reached now.
    pub fn publish_retained(
        &self,
        topic: &str,
        payload: impl Into<Vec<u8>>,
    ) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), true)
    }

    /// Lock-free counter snapshot (atomics; never contends with the
    /// publish path).
    pub fn stats(&self) -> BrokerStats {
        BrokerStats {
            pub_count: self.counters.pub_count.load(Ordering::Relaxed),
            pub_bytes: self.counters.pub_bytes.load(Ordering::Relaxed),
            deliver_count: self.counters.deliver_count.load(Ordering::Relaxed),
            deliver_bytes: self.counters.deliver_bytes.load(Ordering::Relaxed),
            subscriptions: self.counters.subscriptions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pub_sub_roundtrip() {
        let b = Broker::new("cc");
        let sub = b.subscribe("query/+/result").unwrap();
        let n = b.publish("query/42/result", b"hit".to_vec()).unwrap();
        assert_eq!(n, 1);
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.topic, "query/42/result");
        assert_eq!(&m.payload[..], b"hit");
        assert_eq!(&*m.origin, "cc");
    }

    #[test]
    fn no_match_no_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("a/b").unwrap();
        assert_eq!(b.publish("a/c", b"x".to_vec()).unwrap(), 0);
        assert!(sub.rx.try_recv().is_err());
    }

    #[test]
    fn retained_replay_on_subscribe() {
        let b = Broker::new("b");
        b.publish_retained("cfg/threshold", b"0.8".to_vec()).unwrap();
        let sub = b.subscribe("cfg/#").unwrap();
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.utf8(), "0.8");
    }

    #[test]
    fn retained_keeps_only_the_last_message_per_topic() {
        let b = Broker::new("b");
        b.publish_retained("cfg/threshold", b"0.5".to_vec()).unwrap();
        b.publish_retained("cfg/threshold", b"0.8".to_vec()).unwrap();
        let sub = b.subscribe("cfg/threshold").unwrap();
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.utf8(), "0.8", "last retain wins");
        assert!(sub.rx.try_recv().is_err(), "old retained must be replaced");
    }

    #[test]
    fn retained_replay_is_filter_directed_and_in_retain_order() {
        let b = Broker::new("b");
        for i in 0..20 {
            b.publish_retained(&format!("cfg/k{i}"), format!("{i}").into_bytes())
                .unwrap();
        }
        b.publish_retained("other/x", b"nope".to_vec()).unwrap();
        // narrow filter: exactly one retained topic replays
        let sub = b.subscribe("cfg/k7").unwrap();
        assert_eq!(sub.rx.recv_timeout(Duration::from_secs(1)).unwrap().utf8(), "7");
        assert!(sub.rx.try_recv().is_err());
        // wildcard filter: all cfg topics replay, in retain order
        let sub = b.subscribe("cfg/+").unwrap();
        let got: Vec<String> = (0..20)
            .map(|_| sub.rx.recv_timeout(Duration::from_secs(1)).unwrap().utf8())
            .collect();
        assert_eq!(got, (0..20).map(|i| i.to_string()).collect::<Vec<_>>());
        assert!(sub.rx.try_recv().is_err(), "other/x must not replay");
    }

    #[test]
    fn name_and_stats_are_lock_free_reads() {
        // hold the inner lock hostage on another thread via a long
        // publish storm while name()/stats() keep returning — they
        // read the Arc'd name and atomic counters, not the Mutex
        let b = Broker::new("contended");
        assert_eq!(&*b.name(), "contended");
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..10_000 {
                b2.publish("t/x", b"x".to_vec()).unwrap();
            }
        });
        for _ in 0..100 {
            let _ = b.stats();
            let _ = b.name();
        }
        t.join().unwrap();
        let st = b.stats();
        assert_eq!(st.pub_count, 10_000);
        assert_eq!(st.pub_bytes, 10_000);
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        b.unsubscribe(sub.id);
        assert_eq!(b.publish("t/x", b"1".to_vec()).unwrap(), 0);
    }

    #[test]
    fn dead_receivers_are_pruned() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        drop(sub.rx);
        b.publish("t/x", b"1".to_vec()).unwrap();
        assert_eq!(b.stats().subscriptions, 0);
    }

    #[test]
    fn rejects_invalid() {
        let b = Broker::new("b");
        assert!(b.subscribe("a/#/b").is_err());
        assert!(b.publish("a/+/b", b"".to_vec()).is_err());
    }

    #[test]
    fn stats_count_bytes() {
        let b = Broker::new("b");
        let _s1 = b.subscribe("t/#").unwrap();
        let _s2 = b.subscribe("t/x").unwrap();
        b.publish("t/x", vec![0u8; 100]).unwrap();
        let st = b.stats();
        assert_eq!(st.pub_count, 1);
        assert_eq!(st.pub_bytes, 100);
        assert_eq!(st.deliver_count, 2);
        assert_eq!(st.deliver_bytes, 200);
    }
}
