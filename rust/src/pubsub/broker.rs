//! In-process MQTT-like broker — the resource-level message service.
//!
//! §4.3.2: ACE deploys a message service on every EC and on the CC;
//! application components only ever talk to their *local* broker, and
//! EC<->CC unicast rides the long-lasting bridge (see `bridge.rs`,
//! Figure 2 link ②). QoS-0 semantics, retained messages, `+`/`#`
//! filters. Subscribers receive on std mpsc channels; byte counters
//! support the bridged-vs-direct ablation bench.
//!
//! Routing is indexed AND sharded: subscriptions live in per-shard
//! [`topic::TopicTrie`]s keyed by the topic's FIRST level (see
//! `shard.rs` for the shard map and the correctness argument), so a
//! publish walks O(topic depth) trie nodes under ONE shard lock —
//! concurrent producers on distinct first levels never contend, which
//! is what the multi-producer `broker_contention` bench measures.
//! Filters starting with `+`/`#` live in a shared wildcard shard the
//! publish path consults only when it is non-empty (a lock-free gauge
//! read). Per-subscriber delivery order still equals the old
//! single-mutex broker's, byte for byte (`tests/broker_shard.rs`).
//!
//! Hot-path economics (DESIGN.md §Event-engine, §Broker-sharding): the
//! broker name lives in an `Arc<str>` OUTSIDE the locks, so stamping
//! `Message::origin` is a refcount bump, not a `String` clone per
//! publish; counters are atomics, so `name()`/`stats()` never contend
//! with the publish path; retained messages live in per-shard
//! name-keyed [`TopicTrie`]s stamped with a GLOBAL retain sequence, so
//! subscribe replays only the trie paths its filter selects — in
//! retain order even when the filter spans shards.

use super::shard::{ShardSet, SubSink, DEFAULT_SHARDS};
use super::topic;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, OnceLock};

/// The shared empty origin (allocated once per process), so
/// `Message::new` itself allocates nothing for the origin slot.
fn no_origin() -> Arc<str> {
    static EMPTY: OnceLock<Arc<str>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from("")).clone()
}

/// A published message. The payload sits behind an `Arc` so fanning a
/// message out to N subscribers shares one buffer instead of cloning N
/// copies (the broker's hot path); `origin` shares the broker's name
/// allocation the same way.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub topic: String,
    pub payload: Arc<[u8]>,
    /// Broker the message FIRST entered (loop prevention in bridges);
    /// empty until the first broker stamps it.
    pub origin: Arc<str>,
}

impl Message {
    /// A fresh message with no origin stamp (the first broker it
    /// enters stamps it).
    pub fn new(topic: impl Into<String>, payload: impl Into<Vec<u8>>) -> Self {
        Message {
            topic: topic.into(),
            payload: Arc::from(payload.into()),
            origin: no_origin(),
        }
    }

    /// Payload decoded as (lossy) UTF-8 — JSON/yamlite wire documents.
    pub fn utf8(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }
}

/// Publish/delivery counters — atomics outside the locks, so stats
/// reads never contend with the publish path.
#[derive(Default)]
struct Counters {
    /// (messages, payload bytes) accepted by publish.
    pub_count: AtomicU64,
    pub_bytes: AtomicU64,
    /// (messages, payload bytes) delivered to subscribers.
    deliver_count: AtomicU64,
    deliver_bytes: AtomicU64,
    /// Live subscriptions across all shards (maintained by exact
    /// add/sub deltas — shards mutate concurrently, so there is no
    /// single `len()` to mirror).
    subscriptions: AtomicUsize,
}

/// Handle to a broker (cheaply cloneable).
#[derive(Clone)]
pub struct Broker {
    shards: Arc<ShardSet>,
    name: Arc<str>,
    counters: Arc<Counters>,
}

/// A subscription handle; dropping it does NOT unsubscribe (call
/// `Broker::unsubscribe`), but a closed receiver is garbage-collected on
/// the next publish that routes to it.
pub struct SubHandle {
    /// Subscription id (for [`Broker::unsubscribe`]).
    pub id: u64,
    /// Receiving end: matching messages (and retained replays).
    pub rx: Receiver<Message>,
}

/// Snapshot of a broker's publish/delivery counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BrokerStats {
    /// Messages accepted by publish.
    pub pub_count: u64,
    /// Payload bytes accepted by publish.
    pub pub_bytes: u64,
    /// Messages delivered to subscribers.
    pub deliver_count: u64,
    /// Payload bytes delivered to subscribers.
    pub deliver_bytes: u64,
    /// Live subscriptions.
    pub subscriptions: usize,
}

impl Broker {
    /// A fresh broker named `name` (the per-cluster message service
    /// instance of §4.3.2), with the default shard count.
    pub fn new(name: impl Into<String>) -> Self {
        Self::with_shards(name, DEFAULT_SHARDS)
    }

    /// A broker with an explicit literal-shard count (clamped to
    /// 1..=1024; the differential suite pins behaviour invariant over
    /// {1, 4, 16}). One extra wildcard shard always exists on top.
    pub fn with_shards(name: impl Into<String>, shards: usize) -> Self {
        Broker {
            shards: Arc::new(ShardSet::new(shards)),
            name: Arc::from(name.into()),
            counters: Arc::new(Counters::default()),
        }
    }

    /// Broker name — lock-free (shared `Arc<str>`, no contention with
    /// the publish path).
    pub fn name(&self) -> Arc<str> {
        self.name.clone()
    }

    /// Literal-shard count (the wildcard shard is extra).
    pub fn shard_count(&self) -> usize {
        self.shards.shard_count()
    }

    /// Subscribe to `filter`; retained messages matching the filter are
    /// delivered immediately (in retain order, across all shards).
    pub fn subscribe(&self, filter: &str) -> Result<SubHandle, String> {
        if !topic::valid_filter(filter) {
            return Err(format!("invalid filter '{filter}'"));
        }
        let (tx, rx) = channel();
        let out = self.shards.subscribe(filter, SubSink::Chan(tx));
        self.counters.subscriptions.fetch_add(1, Ordering::Relaxed);
        self.counters
            .deliver_count
            .fetch_add(out.replayed, Ordering::Relaxed);
        self.counters
            .deliver_bytes
            .fetch_add(out.replayed_bytes, Ordering::Relaxed);
        Ok(SubHandle { id: out.id, rx })
    }

    /// Subscribe with a callback sink instead of a channel — the
    /// shard-side dispatch path the `serve` engine and TCP federation
    /// ride (no forwarder thread per subscription).
    ///
    /// `sink(id, message, retained)` runs INLINE under the owning
    /// shard's lock: for retained replays (before this call returns)
    /// and for every later matching publish, from the publisher's
    /// thread. `retained` is retain-as-published — `true` for replays
    /// AND for live publishes that asked to retain (what a federation
    /// link forwards so the peer re-retains). The sink must be quick
    /// and must NOT call back into broker APIs (publish, subscribe,
    /// unsubscribe — that deadlocks on the shard lock); enqueue into
    /// your own queue and wake your own loop instead. Returning
    /// `false` marks the sink dead: it is pruned like a dropped
    /// channel receiver on the next matching publish. Returns the
    /// subscription id (valid for [`Broker::unsubscribe`]).
    pub fn subscribe_sink<F>(&self, filter: &str, sink: F) -> Result<u64, String>
    where
        F: Fn(u64, &Message, bool) -> bool + Send + Sync + 'static,
    {
        if !topic::valid_filter(filter) {
            return Err(format!("invalid filter '{filter}'"));
        }
        let out = self.shards.subscribe(filter, SubSink::Fn(Arc::new(sink)));
        self.counters.subscriptions.fetch_add(1, Ordering::Relaxed);
        self.counters
            .deliver_count
            .fetch_add(out.replayed, Ordering::Relaxed);
        self.counters
            .deliver_bytes
            .fetch_add(out.replayed_bytes, Ordering::Relaxed);
        Ok(out.id)
    }

    /// Drop subscription `id`: the owning shard is encoded in the id,
    /// so this takes exactly one shard lock and removes one trie path.
    pub fn unsubscribe(&self, id: u64) {
        let removed = self.shards.unsubscribe(id);
        if removed > 0 {
            self.counters
                .subscriptions
                .fetch_sub(removed, Ordering::Relaxed);
        }
    }

    /// Publish; `retain` keeps the last message per topic for future
    /// subscribers. Returns the number of subscribers reached.
    pub fn publish_opts(&self, mut msg: Message, retain: bool) -> Result<usize, String> {
        if !topic::valid_name(&msg.topic) {
            return Err(format!("invalid topic '{}'", msg.topic));
        }
        if msg.origin.is_empty() {
            // refcount bump on the broker's shared name, no String clone
            msg.origin = self.name.clone();
        }
        self.counters.pub_count.fetch_add(1, Ordering::Relaxed);
        self.counters
            .pub_bytes
            .fetch_add(msg.payload.len() as u64, Ordering::Relaxed);
        let out = self.shards.route(&msg, retain);
        self.counters
            .deliver_count
            .fetch_add(out.reached as u64, Ordering::Relaxed);
        self.counters
            .deliver_bytes
            .fetch_add(out.delivered_bytes, Ordering::Relaxed);
        if out.pruned > 0 {
            self.counters
                .subscriptions
                .fetch_sub(out.pruned, Ordering::Relaxed);
        }
        Ok(out.reached)
    }

    /// Publish without retaining. Returns the subscribers reached.
    pub fn publish(&self, topic: &str, payload: impl Into<Vec<u8>>) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), false)
    }

    /// Publish and retain (last-writer-wins per topic) for future
    /// subscribers. Returns the subscribers reached now.
    pub fn publish_retained(
        &self,
        topic: &str,
        payload: impl Into<Vec<u8>>,
    ) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), true)
    }

    /// Lock-free counter snapshot (atomics; never contends with the
    /// publish path).
    pub fn stats(&self) -> BrokerStats {
        BrokerStats {
            pub_count: self.counters.pub_count.load(Ordering::Relaxed),
            pub_bytes: self.counters.pub_bytes.load(Ordering::Relaxed),
            deliver_count: self.counters.deliver_count.load(Ordering::Relaxed),
            deliver_bytes: self.counters.deliver_bytes.load(Ordering::Relaxed),
            subscriptions: self.counters.subscriptions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pub_sub_roundtrip() {
        let b = Broker::new("cc");
        let sub = b.subscribe("query/+/result").unwrap();
        let n = b.publish("query/42/result", b"hit".to_vec()).unwrap();
        assert_eq!(n, 1);
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.topic, "query/42/result");
        assert_eq!(&m.payload[..], b"hit");
        assert_eq!(&*m.origin, "cc");
    }

    #[test]
    fn no_match_no_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("a/b").unwrap();
        assert_eq!(b.publish("a/c", b"x".to_vec()).unwrap(), 0);
        assert!(sub.rx.try_recv().is_err());
    }

    #[test]
    fn retained_replay_on_subscribe() {
        let b = Broker::new("b");
        b.publish_retained("cfg/threshold", b"0.8".to_vec()).unwrap();
        let sub = b.subscribe("cfg/#").unwrap();
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.utf8(), "0.8");
    }

    #[test]
    fn retained_keeps_only_the_last_message_per_topic() {
        let b = Broker::new("b");
        b.publish_retained("cfg/threshold", b"0.5".to_vec()).unwrap();
        b.publish_retained("cfg/threshold", b"0.8".to_vec()).unwrap();
        let sub = b.subscribe("cfg/threshold").unwrap();
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.utf8(), "0.8", "last retain wins");
        assert!(sub.rx.try_recv().is_err(), "old retained must be replaced");
    }

    #[test]
    fn retained_replay_is_filter_directed_and_in_retain_order() {
        let b = Broker::new("b");
        for i in 0..20 {
            b.publish_retained(&format!("cfg/k{i}"), format!("{i}").into_bytes())
                .unwrap();
        }
        b.publish_retained("other/x", b"nope".to_vec()).unwrap();
        // narrow filter: exactly one retained topic replays
        let sub = b.subscribe("cfg/k7").unwrap();
        assert_eq!(sub.rx.recv_timeout(Duration::from_secs(1)).unwrap().utf8(), "7");
        assert!(sub.rx.try_recv().is_err());
        // wildcard filter: all cfg topics replay, in retain order
        let sub = b.subscribe("cfg/+").unwrap();
        let got: Vec<String> = (0..20)
            .map(|_| sub.rx.recv_timeout(Duration::from_secs(1)).unwrap().utf8())
            .collect();
        assert_eq!(got, (0..20).map(|i| i.to_string()).collect::<Vec<_>>());
        assert!(sub.rx.try_recv().is_err(), "other/x must not replay");
    }

    #[test]
    fn cross_shard_retained_replay_merges_in_retain_order() {
        // retained topics spread over MANY first levels (=> many
        // shards); a `#` subscribe must replay them in the exact
        // global retain order, not shard-by-shard
        let b = Broker::with_shards("b", 16);
        for i in 0..32 {
            b.publish_retained(&format!("lvl{i}/cfg"), format!("{i}").into_bytes())
                .unwrap();
        }
        let sub = b.subscribe("#").unwrap();
        let got: Vec<String> = (0..32)
            .map(|_| sub.rx.recv_timeout(Duration::from_secs(1)).unwrap().utf8())
            .collect();
        assert_eq!(got, (0..32).map(|i| i.to_string()).collect::<Vec<_>>());
    }

    #[test]
    fn level0_wildcards_see_every_shard() {
        let b = Broker::with_shards("b", 16);
        let hash = b.subscribe("#").unwrap();
        let plus = b.subscribe("+/status").unwrap();
        assert_eq!(b.publish("nodeA/status", b"up".to_vec()).unwrap(), 2);
        assert_eq!(b.publish("nodeB/metrics", b"m".to_vec()).unwrap(), 1);
        let topics: Vec<String> = (0..2)
            .map(|_| hash.rx.recv_timeout(Duration::from_secs(1)).unwrap().topic)
            .collect();
        assert_eq!(topics, ["nodeA/status", "nodeB/metrics"]);
        assert_eq!(
            plus.rx.recv_timeout(Duration::from_secs(1)).unwrap().topic,
            "nodeA/status"
        );
        assert!(plus.rx.try_recv().is_err());
    }

    #[test]
    fn name_and_stats_are_lock_free_reads() {
        // hold the shard locks hostage on another thread via a long
        // publish storm while name()/stats() keep returning — they
        // read the Arc'd name and atomic counters, not the mutexes
        let b = Broker::new("contended");
        assert_eq!(&*b.name(), "contended");
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..10_000 {
                b2.publish("t/x", b"x".to_vec()).unwrap();
            }
        });
        for _ in 0..100 {
            let _ = b.stats();
            let _ = b.name();
        }
        t.join().unwrap();
        let st = b.stats();
        assert_eq!(st.pub_count, 10_000);
        assert_eq!(st.pub_bytes, 10_000);
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        b.unsubscribe(sub.id);
        assert_eq!(b.publish("t/x", b"1".to_vec()).unwrap(), 0);
    }

    #[test]
    fn dead_receivers_are_pruned() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        drop(sub.rx);
        b.publish("t/x", b"1".to_vec()).unwrap();
        assert_eq!(b.stats().subscriptions, 0);
    }

    #[test]
    fn dead_wildcard_receivers_are_pruned_too() {
        let b = Broker::with_shards("b", 4);
        let sub = b.subscribe("#").unwrap();
        drop(sub.rx);
        assert_eq!(b.publish("t/x", b"1".to_vec()).unwrap(), 0);
        assert_eq!(b.stats().subscriptions, 0);
        // and the fast path re-arms: the next publish skips the
        // wildcard shard again (observable only as still-correct
        // routing)
        assert_eq!(b.publish("t/x", b"2".to_vec()).unwrap(), 0);
    }

    #[test]
    fn rejects_invalid() {
        let b = Broker::new("b");
        assert!(b.subscribe("a/#/b").is_err());
        assert!(b.subscribe_sink("a/#/b", |_, _, _| true).is_err());
        assert!(b.publish("a/+/b", b"".to_vec()).is_err());
    }

    #[test]
    fn sink_subscriptions_deliver_inline_with_retain_flags() {
        // the serve engine's shard-side dispatch: replays arrive inside
        // subscribe_sink itself (retained=true), live publishes arrive
        // from the publisher's thread with retain-as-published flags
        let b = Broker::with_shards("b", 4);
        b.publish_retained("cfg/a", b"old".to_vec()).unwrap();
        let seen: Arc<std::sync::Mutex<Vec<(String, bool)>>> = Arc::default();
        let sink_log = seen.clone();
        let id = b
            .subscribe_sink("cfg/#", move |_, m, retained| {
                sink_log.lock().unwrap().push((m.utf8(), retained));
                true
            })
            .unwrap();
        b.publish("cfg/live", b"x".to_vec()).unwrap();
        b.publish_retained("cfg/keep", b"y".to_vec()).unwrap();
        assert_eq!(
            *seen.lock().unwrap(),
            vec![
                ("old".to_string(), true),
                ("x".to_string(), false),
                ("y".to_string(), true)
            ]
        );
        b.unsubscribe(id);
        assert_eq!(b.publish("cfg/live", b"z".to_vec()).unwrap(), 0);
    }

    #[test]
    fn refusing_sinks_are_pruned_like_dropped_receivers() {
        let b = Broker::new("b");
        b.subscribe_sink("t/x", |_, _, _| false).unwrap();
        assert_eq!(b.publish("t/x", b"1".to_vec()).unwrap(), 0);
        assert_eq!(b.stats().subscriptions, 0);
    }

    #[test]
    fn stats_count_bytes() {
        let b = Broker::new("b");
        let _s1 = b.subscribe("t/#").unwrap();
        let _s2 = b.subscribe("t/x").unwrap();
        b.publish("t/x", vec![0u8; 100]).unwrap();
        let st = b.stats();
        assert_eq!(st.pub_count, 1);
        assert_eq!(st.pub_bytes, 100);
        assert_eq!(st.deliver_count, 2);
        assert_eq!(st.deliver_bytes, 200);
    }

    #[test]
    fn behaviour_is_shard_count_invariant_smoke() {
        // the heavyweight version lives in tests/broker_shard.rs; this
        // pins the basics for `cargo test -p` on this module alone
        for shards in [1, 4, 16] {
            let b = Broker::with_shards("b", shards);
            let wide = b.subscribe("#").unwrap();
            let narrow = b.subscribe("a/b").unwrap();
            assert_eq!(b.publish("a/b", b"1".to_vec()).unwrap(), 2);
            assert_eq!(b.publish("c/d", b"2".to_vec()).unwrap(), 1);
            assert_eq!(wide.rx.try_iter().count(), 2);
            assert_eq!(narrow.rx.try_iter().count(), 1);
            assert_eq!(b.stats().subscriptions, 2);
        }
    }
}
