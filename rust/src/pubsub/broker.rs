//! In-process MQTT-like broker — the resource-level message service.
//!
//! §4.3.2: ACE deploys a message service on every EC and on the CC;
//! application components only ever talk to their *local* broker, and
//! EC<->CC unicast rides the long-lasting bridge (see `bridge.rs`,
//! Figure 2 link ②). QoS-0 semantics, retained messages, `+`/`#`
//! filters. Subscribers receive on std mpsc channels; byte counters
//! support the bridged-vs-direct ablation bench.
//!
//! Routing is indexed: subscriptions live in a [`topic::TopicTrie`],
//! so a publish walks O(topic depth) trie nodes instead of scanning
//! every subscription (the same index `svcgraph::Fabric` uses on the
//! DES data plane). Delivery order stays insertion order.

use super::topic::{self, TopicTrie};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// A published message. The payload sits behind an `Arc` so fanning a
/// message out to N subscribers shares one buffer instead of cloning N
/// copies (the broker's hot path).
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub topic: String,
    pub payload: Arc<[u8]>,
    /// Broker the message FIRST entered (loop prevention in bridges).
    pub origin: String,
}

impl Message {
    pub fn new(topic: impl Into<String>, payload: impl Into<Vec<u8>>) -> Self {
        Message {
            topic: topic.into(),
            payload: Arc::from(payload.into()),
            origin: String::new(),
        }
    }

    pub fn utf8(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }
}

struct Subscription {
    tx: Sender<Message>,
    id: u64,
}

struct Inner {
    name: String,
    /// Subscription index: one publish routes in O(topic depth).
    subs: TopicTrie<Subscription>,
    /// id -> filter, so unsubscribe/pruning can address the trie path.
    filters: HashMap<u64, String>,
    retained: HashMap<String, Message>,
    next_id: u64,
    /// (messages, payload bytes) accepted by publish.
    pub_count: u64,
    pub_bytes: u64,
    /// (messages, payload bytes) delivered to subscribers.
    deliver_count: u64,
    deliver_bytes: u64,
}

/// Handle to a broker (cheaply cloneable).
#[derive(Clone)]
pub struct Broker {
    inner: Arc<Mutex<Inner>>,
}

/// A subscription handle; dropping it does NOT unsubscribe (call
/// `Broker::unsubscribe`), but a closed receiver is garbage-collected on
/// the next publish that routes to it.
pub struct SubHandle {
    pub id: u64,
    pub rx: Receiver<Message>,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BrokerStats {
    pub pub_count: u64,
    pub pub_bytes: u64,
    pub deliver_count: u64,
    pub deliver_bytes: u64,
    pub subscriptions: usize,
}

impl Broker {
    pub fn new(name: impl Into<String>) -> Self {
        Broker {
            inner: Arc::new(Mutex::new(Inner {
                name: name.into(),
                subs: TopicTrie::new(),
                filters: HashMap::new(),
                retained: HashMap::new(),
                next_id: 1,
                pub_count: 0,
                pub_bytes: 0,
                deliver_count: 0,
                deliver_bytes: 0,
            })),
        }
    }

    pub fn name(&self) -> String {
        self.inner.lock().unwrap().name.clone()
    }

    /// Subscribe to `filter`; retained messages matching the filter are
    /// delivered immediately.
    pub fn subscribe(&self, filter: &str) -> Result<SubHandle, String> {
        if !topic::valid_filter(filter) {
            return Err(format!("invalid filter '{filter}'"));
        }
        let (tx, rx) = channel();
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        // replay retained
        let mut replayed = Vec::new();
        for (t, m) in inner.retained.iter() {
            if topic::matches(filter, t) {
                replayed.push(m.clone());
            }
        }
        for m in replayed {
            let bytes = m.payload.len() as u64;
            if tx.send(m).is_ok() {
                inner.deliver_count += 1;
                inner.deliver_bytes += bytes;
            }
        }
        inner.subs.insert(filter, Subscription { tx, id });
        inner.filters.insert(id, filter.to_string());
        Ok(SubHandle { id, rx })
    }

    pub fn unsubscribe(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(filter) = inner.filters.remove(&id) {
            inner.subs.remove(&filter, |s| s.id == id);
        }
    }

    /// Publish; `retain` keeps the last message per topic for future
    /// subscribers. Returns the number of subscribers reached.
    pub fn publish_opts(&self, mut msg: Message, retain: bool) -> Result<usize, String> {
        if !topic::valid_name(&msg.topic) {
            return Err(format!("invalid topic '{}'", msg.topic));
        }
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if msg.origin.is_empty() {
            msg.origin = inner.name.clone();
        }
        inner.pub_count += 1;
        inner.pub_bytes += msg.payload.len() as u64;
        if retain {
            inner.retained.insert(msg.topic.clone(), msg.clone());
        }
        let mut reached = 0;
        let mut dead: Vec<u64> = Vec::new();
        let mut delivered_bytes = 0u64;
        // O(topic depth) trie walk; matches come back in insertion
        // (i.e. subscription) order
        for s in inner.subs.collect_matches(&msg.topic) {
            // Arc payload: per-subscriber clone is a refcount bump
            if s.tx.send(msg.clone()).is_ok() {
                reached += 1;
                delivered_bytes += msg.payload.len() as u64;
            } else {
                dead.push(s.id);
            }
        }
        inner.deliver_count += reached as u64;
        inner.deliver_bytes += delivered_bytes;
        // garbage-collect closed receivers: each is one targeted trie
        // path removal, not a scan over every subscription
        for id in dead {
            if let Some(filter) = inner.filters.remove(&id) {
                inner.subs.remove(&filter, |s| s.id == id);
            }
        }
        Ok(reached)
    }

    pub fn publish(&self, topic: &str, payload: impl Into<Vec<u8>>) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), false)
    }

    pub fn publish_retained(&self, topic: &str, payload: impl Into<Vec<u8>>) -> Result<usize, String> {
        self.publish_opts(Message::new(topic, payload), true)
    }

    pub fn stats(&self) -> BrokerStats {
        let inner = self.inner.lock().unwrap();
        BrokerStats {
            pub_count: inner.pub_count,
            pub_bytes: inner.pub_bytes,
            deliver_count: inner.deliver_count,
            deliver_bytes: inner.deliver_bytes,
            subscriptions: inner.subs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn pub_sub_roundtrip() {
        let b = Broker::new("cc");
        let sub = b.subscribe("query/+/result").unwrap();
        let n = b.publish("query/42/result", b"hit".to_vec()).unwrap();
        assert_eq!(n, 1);
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.topic, "query/42/result");
        assert_eq!(&m.payload[..], b"hit");
        assert_eq!(m.origin, "cc");
    }

    #[test]
    fn no_match_no_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("a/b").unwrap();
        assert_eq!(b.publish("a/c", b"x".to_vec()).unwrap(), 0);
        assert!(sub.rx.try_recv().is_err());
    }

    #[test]
    fn retained_replay_on_subscribe() {
        let b = Broker::new("b");
        b.publish_retained("cfg/threshold", b"0.8".to_vec()).unwrap();
        let sub = b.subscribe("cfg/#").unwrap();
        let m = sub.rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.utf8(), "0.8");
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        b.unsubscribe(sub.id);
        assert_eq!(b.publish("t/x", b"1".to_vec()).unwrap(), 0);
    }

    #[test]
    fn dead_receivers_are_pruned() {
        let b = Broker::new("b");
        let sub = b.subscribe("t/x").unwrap();
        drop(sub.rx);
        b.publish("t/x", b"1".to_vec()).unwrap();
        assert_eq!(b.stats().subscriptions, 0);
    }

    #[test]
    fn rejects_invalid() {
        let b = Broker::new("b");
        assert!(b.subscribe("a/#/b").is_err());
        assert!(b.publish("a/+/b", b"".to_vec()).is_err());
    }

    #[test]
    fn stats_count_bytes() {
        let b = Broker::new("b");
        let _s1 = b.subscribe("t/#").unwrap();
        let _s2 = b.subscribe("t/x").unwrap();
        b.publish("t/x", vec![0u8; 100]).unwrap();
        let st = b.stats();
        assert_eq!(st.pub_count, 1);
        assert_eq!(st.pub_bytes, 100);
        assert_eq!(st.deliver_count, 2);
        assert_eq!(st.deliver_bytes, 200);
    }
}
