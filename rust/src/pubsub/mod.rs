//! Resource-level message service (§4.3.2, Figure 2).
//!
//! * `topic` — MQTT-style topic matching + the `TopicTrie`
//!   subscription index shared by all routers (broker AND the DES
//!   `svcgraph::Fabric`), so one publish routes in O(topic depth)
//!   instead of O(subscriptions).
//! * `broker` — per-EC / per-CC in-process broker (QoS-0, retained).
//! * `shard` — the broker's sharded interior: per-first-level trie
//!   subtrees, each behind its own lock, plus one shared wildcard
//!   shard, so concurrent producers on distinct topic spaces never
//!   contend (DESIGN.md §Broker-sharding).
//! * `bridge` — the long-lasting EC<->CC topic bridge (link ② in
//!   Figure 2) with loop prevention.

pub mod bridge;
pub mod broker;
mod shard;
pub mod topic;

pub use bridge::Bridge;
pub use broker::{Broker, BrokerStats, Message, SubHandle};
pub use topic::{Sym, SymbolTable, TopicTrie};
