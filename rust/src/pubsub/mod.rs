//! Resource-level message service (§4.3.2, Figure 2).
//!
//! * `topic` — MQTT-style topic matching, shared by all routers.
//! * `broker` — per-EC / per-CC in-process broker (QoS-0, retained).
//! * `bridge` — the long-lasting EC<->CC topic bridge (link ② in
//!   Figure 2) with loop prevention.

pub mod bridge;
pub mod broker;
pub mod topic;

pub use bridge::Bridge;
pub use broker::{Broker, BrokerStats, Message, SubHandle};
