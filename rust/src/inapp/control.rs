//! Generic in-app control operations (§4.4.2).
//!
//! "ACE constructs a series of general in-app control operations (e.g.,
//! start, filter, aggregate, and terminate), component monitoring
//! operations, and a basic control policy." This module is that generic
//! layer: a small dataflow of control operations over `json::Value`
//! items with monitoring counters, deployed at the CC (global
//! coordination) and per EC (local coordination), talking over the
//! resource-level message service.

use crate::json::Value;
use std::collections::BTreeMap;

/// One general control operation.
pub enum ControlOp {
    /// Pass items through until terminated.
    Start,
    /// Keep items satisfying the predicate.
    Filter(Box<dyn Fn(&Value) -> bool + Send>),
    /// Fold every `window` items into one via the aggregator.
    Aggregate {
        window: usize,
        f: Box<dyn Fn(&[Value]) -> Value + Send>,
    },
    /// Stop the pipeline; subsequent items are discarded.
    Terminate,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpStats {
    pub seen: u64,
    pub emitted: u64,
}

/// A linear pipeline of control ops with per-op monitoring counters —
/// the reusable skeleton the CC controller (global) and EC controllers
/// (local) instantiate.
pub struct ControlPipeline {
    name: String,
    ops: Vec<(String, ControlOp)>,
    stats: Vec<OpStats>,
    buffer: Vec<Vec<Value>>,
    terminated: bool,
}

impl ControlPipeline {
    pub fn new(name: impl Into<String>) -> Self {
        ControlPipeline {
            name: name.into(),
            ops: Vec::new(),
            stats: Vec::new(),
            buffer: Vec::new(),
            terminated: false,
        }
    }

    pub fn op(mut self, label: impl Into<String>, op: ControlOp) -> Self {
        self.ops.push((label.into(), op));
        self.stats.push(OpStats::default());
        self.buffer.push(Vec::new());
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn is_terminated(&self) -> bool {
        self.terminated
    }

    /// Push one item through the pipeline; returns emitted items.
    pub fn push(&mut self, item: Value) -> Vec<Value> {
        if self.terminated {
            return Vec::new();
        }
        let mut current = vec![item];
        for i in 0..self.ops.len() {
            if current.is_empty() {
                break;
            }
            let mut next = Vec::new();
            for item in current {
                self.stats[i].seen += 1;
                match &self.ops[i].1 {
                    ControlOp::Start => next.push(item),
                    ControlOp::Filter(pred) => {
                        if pred(&item) {
                            next.push(item);
                        }
                    }
                    ControlOp::Aggregate { window, f } => {
                        self.buffer[i].push(item);
                        if self.buffer[i].len() >= *window {
                            let agg = f(&self.buffer[i]);
                            self.buffer[i].clear();
                            next.push(agg);
                        }
                    }
                    ControlOp::Terminate => {
                        self.terminated = true;
                        return Vec::new();
                    }
                }
            }
            self.stats[i].emitted += next.len() as u64;
            current = next;
        }
        current
    }

    /// Monitoring snapshot: per-op (label, seen, emitted).
    pub fn monitor(&self) -> BTreeMap<String, OpStats> {
        self.ops
            .iter()
            .zip(&self.stats)
            .map(|((label, _), s)| (label.clone(), *s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn num(v: f64) -> Value {
        Value::num(v)
    }

    #[test]
    fn filter_drops_items() {
        let mut p = ControlPipeline::new("t")
            .op("start", ControlOp::Start)
            .op(
                "conf>0.5",
                ControlOp::Filter(Box::new(|v| v.as_f64().unwrap_or(0.0) > 0.5)),
            );
        assert_eq!(p.push(num(0.9)), vec![num(0.9)]);
        assert_eq!(p.push(num(0.2)), vec![]);
        let m = p.monitor();
        assert_eq!(m["conf>0.5"], OpStats { seen: 2, emitted: 1 });
    }

    #[test]
    fn aggregate_windows() {
        let mut p = ControlPipeline::new("t").op(
            "sum3",
            ControlOp::Aggregate {
                window: 3,
                f: Box::new(|items| {
                    Value::num(items.iter().filter_map(|v| v.as_f64()).sum::<f64>())
                }),
            },
        );
        assert_eq!(p.push(num(1.0)), vec![]);
        assert_eq!(p.push(num(2.0)), vec![]);
        assert_eq!(p.push(num(3.0)), vec![num(6.0)]);
        assert_eq!(p.push(num(4.0)), vec![]);
    }

    #[test]
    fn terminate_stops_pipeline() {
        let mut p = ControlPipeline::new("t")
            .op("start", ControlOp::Start)
            .op("stop", ControlOp::Terminate);
        assert_eq!(p.push(num(1.0)), vec![]);
        assert!(p.is_terminated());
        assert_eq!(p.push(num(2.0)), vec![]);
        assert_eq!(p.monitor()["start"].seen, 1); // second push never entered
    }

    #[test]
    fn chained_ops_compose() {
        let mut p = ControlPipeline::new("t")
            .op(
                "pos",
                ControlOp::Filter(Box::new(|v| v.as_f64().unwrap_or(-1.0) >= 0.0)),
            )
            .op(
                "avg2",
                ControlOp::Aggregate {
                    window: 2,
                    f: Box::new(|items| {
                        Value::num(
                            items.iter().filter_map(|v| v.as_f64()).sum::<f64>()
                                / items.len() as f64,
                        )
                    }),
                },
            );
        assert_eq!(p.push(num(-5.0)), vec![]);
        assert_eq!(p.push(num(1.0)), vec![]);
        assert_eq!(p.push(num(3.0)), vec![num(2.0)]);
    }
}
