//! Reusable in-app controller (§4.4.2) + the video-query policies (§5).
//!
//! ACE requires developers to decouple the control plane (in-app
//! control operations, component monitoring, policy execution) from the
//! workload plane (computation/storage/transmission). This module is
//! the reusable controller: generic control operations (start, filter,
//! aggregate, terminate), monitoring counters, and a `QueryPolicy`
//! trait that applications inherit and override for customized
//! optimization — exactly how §5.1.2's Advanced Policy (AP) extends the
//! Basic Policy (BP).
//!
//! Policies:
//!   * `BasicPolicy` (BP): crops always go OD->EOC; EOC confidence
//!     >= 0.8 -> positive, <= 0.1 -> drop, else upload to COC.
//!   * `AdvancedPolicy` (AP): BP + (a) load balancing — OD sends each
//!     crop to whichever of EOC/COC currently has the lower *estimated*
//!     EIL; (b) threshold shrinking — when either EIL deteriorates, the
//!     [0.1, 0.8] band narrows so fewer crops are uploaded from EOC.

pub mod control;

use crate::util::stats::Summary;

/// Exponentially-weighted moving average — the EIL estimator AP runs
/// from the monitoring reports of EOC (links ⑤④) and COC (⑨⑪④).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, v: f64) {
        self.value = Some(match self.value {
            None => v,
            Some(old) => self.alpha * v + (1.0 - self.alpha) * old,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Where the IC routes a fresh crop from OD.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Eoc,
    Coc,
}

/// What the IC does with an EOC confidence score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeDecision {
    /// confidence >= hi: targeted object identified, metadata to RS.
    Positive,
    /// confidence <= lo: crop dropped.
    Drop,
    /// otherwise: crop uploaded to COC for accurate classification.
    Upload,
}

/// The in-app control policy interface (§4.4.2: "developers can inherit
/// the general in-app controller and override optimization methods").
pub trait QueryPolicy: Send {
    fn name(&self) -> &'static str;

    /// Route a fresh crop from OD (BP: always EOC).
    fn route_crop(&mut self) -> Route {
        Route::Eoc
    }

    /// Decide on an EOC confidence.
    fn edge_decision(&mut self, confidence: f32) -> EdgeDecision;

    /// Monitoring feedback: observed end-to-end inference latencies.
    fn observe_eoc_eil(&mut self, _secs: f64) {}
    fn observe_coc_eil(&mut self, _secs: f64) {}

    /// Current [lo, hi] confidence thresholds (for introspection).
    fn thresholds(&self) -> (f32, f32);
}

/// BP — the §5.1.2 Basic Policy with the paper's 0.8 / 0.1 thresholds.
#[derive(Debug, Clone)]
pub struct BasicPolicy {
    pub hi: f32,
    pub lo: f32,
}

impl Default for BasicPolicy {
    fn default() -> Self {
        BasicPolicy { hi: 0.8, lo: 0.1 }
    }
}

impl QueryPolicy for BasicPolicy {
    fn name(&self) -> &'static str {
        "BP"
    }

    fn edge_decision(&mut self, confidence: f32) -> EdgeDecision {
        if confidence >= self.hi {
            EdgeDecision::Positive
        } else if confidence <= self.lo {
            EdgeDecision::Drop
        } else {
            EdgeDecision::Upload
        }
    }

    fn thresholds(&self) -> (f32, f32) {
        (self.lo, self.hi)
    }
}

/// AP — the §5.1.2 Advanced Policy: EIL-estimating load balancer +
/// threshold shrinking. "Inherits" BP by embedding it and overriding
/// the routing/adaptation methods.
#[derive(Debug, Clone)]
pub struct AdvancedPolicy {
    base: BasicPolicy,
    pub eoc_eil: Ewma,
    pub coc_eil: Ewma,
    /// Prior unloaded-EIL guesses (before any observation arrives).
    pub eoc_baseline: f64,
    pub coc_baseline: f64,
    /// Self-calibrated floors: the minimum EIL ever observed per path.
    /// Deterioration is measured against these, so a constant WAN
    /// propagation delay is learned as "nominal" rather than read as
    /// congestion (§5.2: AP reacts to *deteriorated* EILs).
    eoc_floor: f64,
    coc_floor: f64,
    /// maximum fraction of the band to shrink away (0..1)
    pub max_shrink: f64,
    /// sensitivity of shrinking to deterioration
    pub gain: f64,
    /// hysteresis: divert OD->COC only when EOC's estimate exceeds
    /// COC's by this factor (prevents route flapping on noisy EWMAs)
    pub route_margin: f64,
}

impl AdvancedPolicy {
    /// Baselines come from calibration: the unloaded EIL of each path
    /// (service time + one LAN/WAN round trip).
    pub fn new(eoc_baseline: f64, coc_baseline: f64) -> Self {
        AdvancedPolicy {
            base: BasicPolicy::default(),
            eoc_eil: Ewma::new(0.2),
            coc_eil: Ewma::new(0.2),
            eoc_baseline,
            coc_baseline,
            eoc_floor: f64::INFINITY,
            coc_floor: f64::INFINITY,
            max_shrink: 0.7,
            gain: 0.15,
            route_margin: 1.1,
        }
    }

    fn floor(observed_floor: f64, prior: f64) -> f64 {
        if observed_floor.is_finite() {
            observed_floor
        } else {
            prior
        }
    }

    /// Deterioration factor: how much worse the worst path is vs its
    /// self-calibrated floor (1.0 = nominal).
    fn deterioration(&self) -> f64 {
        let ef = Self::floor(self.eoc_floor, self.eoc_baseline);
        let cf = Self::floor(self.coc_floor, self.coc_baseline);
        let e = self.eoc_eil.get_or(ef) / ef;
        let c = self.coc_eil.get_or(cf) / cf;
        e.max(c).max(1.0)
    }

    /// Shrunk [lo, hi]: the band collapses toward its midpoint as EIL
    /// deteriorates, cutting EOC->COC uploads (§5.1.2).
    fn band(&self) -> (f32, f32) {
        let d = self.deterioration();
        let shrink = ((d - 1.0) * self.gain).min(self.max_shrink) as f32;
        let (lo0, hi0) = (self.base.lo, self.base.hi);
        let mid = 0.5 * (lo0 + hi0);
        (lo0 + shrink * (mid - lo0), hi0 - shrink * (hi0 - mid))
    }
}

impl QueryPolicy for AdvancedPolicy {
    fn name(&self) -> &'static str {
        "AP"
    }

    /// Load balancing (§5.1.2): "always sent to the one with a lower
    /// estimated EIL" — with hysteresis so the default stays EOC (the
    /// BP behaviour) until the edge path is clearly the slower one.
    fn route_crop(&mut self) -> Route {
        // before any feedback arrives, behave like BP (everything via
        // EOC) — diversion is an *informed* decision
        let (e, c) = match (self.eoc_eil.get(), self.coc_eil.get()) {
            (Some(e), Some(c)) => (e, c),
            _ => return Route::Eoc,
        };
        if e > c * self.route_margin {
            Route::Coc
        } else {
            Route::Eoc
        }
    }

    fn edge_decision(&mut self, confidence: f32) -> EdgeDecision {
        let (lo, hi) = self.band();
        if confidence >= hi {
            EdgeDecision::Positive
        } else if confidence <= lo {
            EdgeDecision::Drop
        } else {
            EdgeDecision::Upload
        }
    }

    fn observe_eoc_eil(&mut self, secs: f64) {
        self.eoc_eil.observe(secs);
        self.eoc_floor = self.eoc_floor.min(secs);
    }

    fn observe_coc_eil(&mut self, secs: f64) {
        self.coc_eil.observe(secs);
        self.coc_floor = self.coc_floor.min(secs);
    }

    fn thresholds(&self) -> (f32, f32) {
        self.band()
    }
}

/// Per-policy monitoring counters (the control plane's component
/// monitoring duty).
#[derive(Debug, Clone, Default)]
pub struct PolicyStats {
    pub routed_eoc: u64,
    pub routed_coc: u64,
    pub positives_edge: u64,
    pub drops_edge: u64,
    pub uploads: u64,
    pub eoc_eil: Summary,
    pub coc_eil: Summary,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.observe(10.0);
        assert_eq!(e.get(), Some(10.0));
        e.observe(0.0);
        assert_eq!(e.get(), Some(5.0));
        for _ in 0..64 {
            e.observe(3.0);
        }
        assert!((e.get().unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn bp_thresholds_match_paper() {
        let mut bp = BasicPolicy::default();
        assert_eq!(bp.edge_decision(0.85), EdgeDecision::Positive);
        assert_eq!(bp.edge_decision(0.8), EdgeDecision::Positive);
        assert_eq!(bp.edge_decision(0.5), EdgeDecision::Upload);
        assert_eq!(bp.edge_decision(0.1), EdgeDecision::Drop);
        assert_eq!(bp.edge_decision(0.05), EdgeDecision::Drop);
        assert_eq!(bp.route_crop(), Route::Eoc); // BP never load-balances
    }

    #[test]
    fn ap_load_balances_on_estimated_eil() {
        let mut ap = AdvancedPolicy::new(0.050, 0.040);
        // nominal: within the hysteresis margin -> stick with EOC (BP
        // behaviour)
        assert_eq!(ap.route_crop(), Route::Eoc);
        // EOC deteriorates well past the margin -> divert to COC
        for _ in 0..20 {
            ap.observe_eoc_eil(2.0);
        }
        ap.observe_coc_eil(0.040);
        assert_eq!(ap.route_crop(), Route::Coc);
        // COC backlog explodes -> back to EOC
        for _ in 0..20 {
            ap.observe_coc_eil(10.0);
        }
        assert_eq!(ap.route_crop(), Route::Eoc);
    }

    #[test]
    fn ap_learns_propagation_delay_as_nominal() {
        // constant 50 ms WAN delay must NOT be read as deterioration
        let mut ap = AdvancedPolicy::new(0.050, 0.040);
        for _ in 0..30 {
            ap.observe_coc_eil(0.090); // 40 ms service + 50 ms delay
            ap.observe_eoc_eil(0.050);
        }
        let (lo, hi) = ap.thresholds();
        assert!((lo - 0.1).abs() < 0.02, "lo drifted: {lo}");
        assert!((hi - 0.8).abs() < 0.02, "hi drifted: {hi}");
    }

    #[test]
    fn ap_shrinks_band_under_deterioration() {
        let mut ap = AdvancedPolicy::new(0.050, 0.040);
        let (lo0, hi0) = ap.thresholds();
        assert!((lo0 - 0.1).abs() < 1e-6 && (hi0 - 0.8).abs() < 1e-6);
        // nominal observations first (the floor self-calibrates), then
        // a 5x deterioration on COC
        ap.observe_coc_eil(0.040);
        ap.observe_eoc_eil(0.050);
        for _ in 0..20 {
            ap.observe_coc_eil(0.200);
        }
        let (lo1, hi1) = ap.thresholds();
        assert!(lo1 > lo0, "lo should rise: {lo1} vs {lo0}");
        assert!(hi1 < hi0, "hi should fall: {hi1} vs {hi0}");
        assert!(lo1 < hi1, "band never inverts");
        // a borderline crop that BP would upload is now decided locally
        assert_eq!(ap.edge_decision(0.79), EdgeDecision::Positive);
    }

    #[test]
    fn ap_band_never_collapses_past_max_shrink() {
        let mut ap = AdvancedPolicy::new(0.050, 0.040);
        for _ in 0..100 {
            ap.observe_eoc_eil(50.0); // 1000x deterioration
        }
        let (lo, hi) = ap.thresholds();
        assert!(lo < hi);
        let width = hi - lo;
        assert!(width >= (0.8 - 0.1) * (1.0 - 0.85) - 1e-6);
    }

    #[test]
    fn dyn_policy_dispatch() {
        // the app holds policies as trait objects (reusable controller)
        let mut policies: Vec<Box<dyn QueryPolicy>> = vec![
            Box::new(BasicPolicy::default()),
            Box::new(AdvancedPolicy::new(0.05, 0.04)),
        ];
        for p in policies.iter_mut() {
            let _ = p.route_crop();
            let _ = p.edge_decision(0.5);
            assert!(!p.name().is_empty());
        }
    }
}
