//! The §5 intelligent video query application + Figure 5 experiment.
//!
//! Wires the paper's components over the simulated testbed:
//!   DG  — synthetic camera streams (one per RPi, 3 per EC x 3 ECs);
//!   OD  — frame differencing on three frames per sample (native rust);
//!   EOC — edge binary classifier (real XLA inference, one per EC's
//!         mini PC, service time = calibrated x edge factor);
//!   COC — cloud multi-class classifier (real XLA inference on the CC);
//!   IC  — in-app controller executing BP or AP (per-EC LIC + global);
//!   RS  — result storage on the CC (metadata sink).
//!
//! The DES charges virtual time for LAN/WAN transfers (token-bucket
//! links from `simnet`) and for classifier service (measured PJRT times
//! scaled to the paper's §5.2 operating point: COC ~= 32.3 ms/crop on
//! the CC, EOC ~= 44 ms/crop on the mini PC). Classifier OUTPUTS are
//! real: every crop is pushed through the compiled HLO artifacts, so
//! F1 is measured, not modeled. Ground truth follows footnote 1 (COC
//! post-hoc labels over all extracted crops).

use crate::des::Scheduler;
use crate::inapp::{AdvancedPolicy, BasicPolicy, EdgeDecision, QueryPolicy, Route};
use crate::metrics::{CellMetrics, F1};
use crate::runtime::{Classifier, ModelBank};
use crate::simnet::{sizes, EdgeCloudNet, NetConfig};
use crate::util::stats::Percentiles;
use crate::util::{millis, secs, SimTime};
use crate::video::{CameraStream, ObjectDetector, OdConfig};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

/// Implementation paradigm under comparison (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Paradigm {
    /// Cloud Intelligence: every crop goes to COC.
    Ci,
    /// Edge Intelligence: EOC only; unconfident crops are dropped.
    Ei,
    /// ACE with the Basic Policy.
    AceBp,
    /// ACE with the customized Advanced Policy.
    AceAp,
}

impl Paradigm {
    pub fn name(self) -> &'static str {
        match self {
            Paradigm::Ci => "CI",
            Paradigm::Ei => "EI",
            Paradigm::AceBp => "ACE",
            Paradigm::AceAp => "ACE+",
        }
    }
}

/// Experiment cell configuration.
#[derive(Debug, Clone)]
pub struct CellConfig {
    pub paradigm: Paradigm,
    /// OD sampling interval in seconds — the system-load knob
    /// (paper sweeps 0.5 -> 0.1).
    pub interval_s: f64,
    /// One-way WAN delay in ms (0 ideal, 50 practical).
    pub wan_delay_ms: f64,
    /// Virtual experiment duration (paper: 5-minute clips).
    pub duration_s: f64,
    pub num_ecs: usize,
    pub cams_per_ec: usize,
    pub seed: u64,
    /// Classifier batch caps. The paper's COC serves crops individually
    /// (32.3 ms each — and our interpret-mode COC artifact has
    /// super-linear batch cost, see EXPERIMENTS.md §Perf L1), so COC
    /// runs per-crop; EOC batches up to 2 (its measured per-crop cost
    /// improves to ~36 ms there), leaving the EC borderline at peak
    /// load — which is what activates AP's load balancing, as in §5.2.
    pub eoc_max_batch: usize,
    pub coc_max_batch: usize,
    /// Optional §4.2.2 validation-testbed channel schedule; when set it
    /// overrides `wan_delay_ms` and reshapes the WAN links per phase.
    pub channel: Option<crate::testbed::ChannelProfile>,
}

impl Default for CellConfig {
    fn default() -> Self {
        CellConfig {
            paradigm: Paradigm::AceBp,
            interval_s: 0.5,
            wan_delay_ms: 0.0,
            duration_s: 30.0,
            num_ecs: 3,
            cams_per_ec: 3,
            seed: 1,
            eoc_max_batch: 2,
            coc_max_batch: 1,
            channel: None,
        }
    }
}

/// Calibrated service times scaled to the paper's operating point.
#[derive(Debug, Clone)]
pub struct ServiceTimes {
    /// batch size -> seconds, EOC on a mini PC
    pub eoc: HashMap<usize, f64>,
    /// batch size -> seconds, COC on the CC workstation
    pub coc: HashMap<usize, f64>,
}

/// §5.2: "the inference time of COC is about 32.3 ms on CC, and that of
/// EOC on edge node is above 44 ms".
pub const PAPER_COC_B1_SECS: f64 = 0.0323;
pub const PAPER_EOC_B1_SECS: f64 = 0.0440;

impl ServiceTimes {
    /// Scale measured PJRT times so b=1 matches the paper's §5.2
    /// numbers; the batching-efficiency CURVE stays measured (see
    /// DESIGN.md §Substitutions).
    pub fn calibrated_to_paper(bank: &ModelBank) -> Self {
        let se = PAPER_EOC_B1_SECS / bank.eoc.service_time(1);
        let sc = PAPER_COC_B1_SECS / bank.coc.service_time(1);
        let eoc = bank
            .eoc
            .service_secs
            .iter()
            .map(|(b, t)| (*b, t * se))
            .collect();
        let coc = bank
            .coc
            .service_secs
            .iter()
            .map(|(b, t)| (*b, t * sc))
            .collect();
        ServiceTimes { eoc, coc }
    }

    /// Synthetic service-time table (unit tests without artifacts):
    /// linear-ish batching gains.
    pub fn synthetic() -> Self {
        let mk = |b1: f64| -> HashMap<usize, f64> {
            [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&b| (b, b1 * (0.55 + 0.45 * b as f64)))
                .collect()
        };
        ServiceTimes { eoc: mk(PAPER_EOC_B1_SECS), coc: mk(PAPER_COC_B1_SECS) }
    }

    fn pick(table: &HashMap<usize, f64>, n: usize, cap: usize) -> (usize, f64) {
        let mut best = *table.keys().min().unwrap();
        for &b in table.keys() {
            if b <= n.min(cap) && b > best {
                best = b;
            }
        }
        (best, table[&best])
    }
}

/// Classifier outputs for the DES: real XLA inference with a
/// cross-paradigm cache (identical crops recur across cells; caching
/// the OUTPUT changes nothing observable but cuts wall-clock ~4x).
pub struct InferCache {
    /// pixel-hash -> EOC target-confidence
    eoc: HashMap<u64, f32>,
    /// pixel-hash -> COC top-1 class
    coc: HashMap<u64, u8>,
    pub eoc_execs: u64,
    pub coc_execs: u64,
}

fn pixel_hash(px: &[f32]) -> u64 {
    // FNV-1a over the f32 bit patterns
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in px {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

impl InferCache {
    pub fn new() -> Self {
        InferCache { eoc: HashMap::new(), coc: HashMap::new(), eoc_execs: 0, coc_execs: 0 }
    }

    /// EOC confidences (P[target]) for a batch of crops.
    pub fn eoc_conf(&mut self, clf: &Classifier, crops: &[&Vec<f32>]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; crops.len()];
        let mut missing = Vec::new();
        let mut missing_idx = Vec::new();
        for (i, c) in crops.iter().enumerate() {
            match self.eoc.get(&pixel_hash(c)) {
                Some(v) => out[i] = *v,
                None => {
                    missing.push((*c).clone());
                    missing_idx.push(i);
                }
            }
        }
        if !missing.is_empty() {
            self.eoc_execs += 1;
            let probs = clf.classify(&missing)?;
            for (j, i) in missing_idx.into_iter().enumerate() {
                let conf = probs[j][1]; // P[class=1] = target present
                self.eoc.insert(pixel_hash(&missing[j]), conf);
                out[i] = conf;
            }
        }
        Ok(out)
    }

    /// COC top-1 classes for a batch of crops.
    pub fn coc_top1(&mut self, clf: &Classifier, crops: &[&Vec<f32>]) -> Result<Vec<u8>> {
        let mut out = vec![0u8; crops.len()];
        let mut missing = Vec::new();
        let mut missing_idx = Vec::new();
        for (i, c) in crops.iter().enumerate() {
            match self.coc.get(&pixel_hash(c)) {
                Some(v) => out[i] = *v,
                None => {
                    missing.push((*c).clone());
                    missing_idx.push(i);
                }
            }
        }
        if !missing.is_empty() {
            self.coc_execs += 1;
            let probs = clf.classify(&missing)?;
            for (j, i) in missing_idx.into_iter().enumerate() {
                let top = probs[j]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k as u8)
                    .unwrap_or(0);
                self.coc.insert(pixel_hash(&missing[j]), top);
                out[i] = top;
            }
        }
        Ok(out)
    }
}

impl Default for InferCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-crop trace record.
#[derive(Debug, Clone)]
struct CropRecord {
    ec: usize,
    t_od: SimTime,
    /// final predicted-positive (None until decided)
    predicted: Option<bool>,
    /// COC online label if it went to the cloud
    coc_label: Option<u8>,
    /// EIL (secs) once decided
    eil: Option<f64>,
    pixels: Rc<Vec<f32>>,
}

/// Compute substrate handed to the DES world. `None` models => a
/// synthetic oracle (unit tests without artifacts).
pub enum Compute {
    Real { bank: Rc<ModelBank>, cache: Rc<std::cell::RefCell<InferCache>> },
    /// (eoc_conf, coc_top1) oracles keyed by pixel hash
    Synthetic { target_bias: f32 },
}

impl Compute {
    fn eoc_conf(&self, crops: &[&Vec<f32>]) -> Result<Vec<f32>> {
        match self {
            Compute::Real { bank, cache } => cache.borrow_mut().eoc_conf(&bank.eoc, crops),
            Compute::Synthetic { target_bias } => Ok(crops
                .iter()
                .map(|c| {
                    let h = pixel_hash(c);
                    let u = (h >> 16) as u32 as f32 / u32::MAX as f32;
                    (u * 0.9 + target_bias).min(1.0)
                })
                .collect()),
        }
    }

    fn coc_top1(&self, crops: &[&Vec<f32>]) -> Result<Vec<u8>> {
        match self {
            Compute::Real { bank, cache } => cache.borrow_mut().coc_top1(&bank.coc, crops),
            Compute::Synthetic { .. } => Ok(crops
                .iter()
                .map(|c| (pixel_hash(c) % 8) as u8)
                .collect()),
        }
    }

    fn eoc_batches(&self) -> Vec<usize> {
        match self {
            Compute::Real { bank, .. } => bank.eoc.batch_sizes.clone(),
            Compute::Synthetic { .. } => vec![1, 2, 4, 8, 16],
        }
    }

    fn target_class(&self) -> u8 {
        match self {
            Compute::Real { bank, .. } => bank.manifest.target_class as u8,
            Compute::Synthetic { .. } => 1,
        }
    }
}

/// The DES world for one experiment cell.
pub struct World {
    cfg: CellConfig,
    net: EdgeCloudNet,
    cams: Vec<CameraStream>,
    od: ObjectDetector,
    records: Vec<CropRecord>,
    /// per-EC EOC queue of record ids + busy flag
    eoc_q: Vec<VecDeque<usize>>,
    eoc_busy: Vec<bool>,
    coc_q: VecDeque<usize>,
    coc_busy: bool,
    policies: Vec<Box<dyn QueryPolicy>>,
    svc: ServiceTimes,
    compute: Compute,
    sampling_done: bool,
    pub errors: Vec<String>,
}

const EIL_FEEDBACK_BYTES: u64 = sizes::META_BYTES;

impl World {
    pub fn new(cfg: CellConfig, svc: ServiceTimes, compute: Compute) -> Self {
        let net = EdgeCloudNet::new(&NetConfig {
            num_ecs: cfg.num_ecs,
            wan_delay: millis(cfg.wan_delay_ms),
            ..Default::default()
        });
        let mut cams = Vec::new();
        for ec in 0..cfg.num_ecs {
            for cam in 0..cfg.cams_per_ec {
                // one moving object slot per camera keeps the per-EC
                // crop rate at the highest load (~22/s) just under the
                // EOC's 44 ms-anchored capacity (~28/s) — the paper's
                // regime where EI/ACE EILs stay load-insensitive while
                // CI's COC queue explodes
                cams.push(CameraStream::new(
                    cfg.seed * 10_007 + (ec * 97 + cam) as u64,
                    1,
                ));
            }
        }
        let policies: Vec<Box<dyn QueryPolicy>> = (0..cfg.num_ecs)
            .map(|_| -> Box<dyn QueryPolicy> {
                match cfg.paradigm {
                    Paradigm::AceAp => Box::new(AdvancedPolicy::new(
                        PAPER_EOC_B1_SECS * 1.5,
                        PAPER_COC_B1_SECS * 1.5,
                    )),
                    _ => Box::new(BasicPolicy::default()),
                }
            })
            .collect();
        World {
            eoc_q: vec![VecDeque::new(); cfg.num_ecs],
            eoc_busy: vec![false; cfg.num_ecs],
            coc_q: VecDeque::new(),
            coc_busy: false,
            net,
            cams,
            od: ObjectDetector::new(OdConfig::default()),
            records: Vec::new(),
            policies,
            svc,
            compute,
            sampling_done: false,
            cfg,
            errors: Vec::new(),
        }
    }

    fn cam_ec(&self, cam_idx: usize) -> usize {
        cam_idx / self.cfg.cams_per_ec
    }

    /// Apply one validation-testbed channel phase to all WAN links.
    fn apply_phase(&mut self, phase: &crate::testbed::Phase) {
        for ec in 0..self.cfg.num_ecs {
            let up = &mut self.net.uplink[ec];
            up.set_bw_bps((phase.uplink_mbps * 1e6) as u64);
            up.delay = phase.delay_us();
            up.jitter = phase.jitter_us();
            let down = &mut self.net.downlink[ec];
            down.set_bw_bps((phase.downlink_mbps * 1e6) as u64);
            down.delay = phase.delay_us();
            down.jitter = phase.jitter_us();
        }
    }

    /// One OD sampling event on camera `cam_idx` at virtual time `now`.
    fn sample(&mut self, sch: &mut Scheduler<World>, cam_idx: usize) {
        let now = sch.now();
        let t = crate::util::to_secs(now);
        let ec = self.cam_ec(cam_idx);
        // OD takes three frames 0.1 s apart ending at t
        self.cams[cam_idx].advance_to(t);
        let f0 = self.cams[cam_idx].frame_at(t - 0.2);
        let f1 = self.cams[cam_idx].frame_at(t - 0.1);
        let f2 = self.cams[cam_idx].frame_at(t);
        let crops = self.od.detect(&f0, &f1, &f2);
        for crop in crops {
            let id = self.records.len();
            self.records.push(CropRecord {
                ec,
                t_od: now,
                predicted: None,
                coc_label: None,
                eil: None,
                pixels: Rc::new(crop.pixels),
            });
            match self.cfg.paradigm {
                Paradigm::Ci => self.upload_to_coc(sch, id),
                Paradigm::Ei | Paradigm::AceBp => self.send_to_eoc(sch, id),
                Paradigm::AceAp => match self.policies[ec].route_crop() {
                    Route::Eoc => self.send_to_eoc(sch, id),
                    Route::Coc => self.upload_to_coc(sch, id),
                },
            }
        }
    }

    /// OD -> EOC over the EC LAN.
    fn send_to_eoc(&mut self, sch: &mut Scheduler<World>, id: usize) {
        let ec = self.records[id].ec;
        let deliver = self.net.lan[ec].send(sch.now(), sizes::CROP_BYTES);
        sch.at(deliver, move |sch, w: &mut World| {
            w.eoc_q[ec].push_back(id);
            w.try_serve_eoc(sch, ec);
        });
    }

    /// crop -> COC over the EC's WAN uplink.
    fn upload_to_coc(&mut self, sch: &mut Scheduler<World>, id: usize) {
        let ec = self.records[id].ec;
        let deliver = self.net.uplink[ec].send(sch.now(), sizes::CROP_BYTES);
        sch.at(deliver, move |sch, w: &mut World| {
            w.coc_q.push_back(id);
            w.try_serve_coc(sch);
        });
    }

    fn try_serve_eoc(&mut self, sch: &mut Scheduler<World>, ec: usize) {
        if self.eoc_busy[ec] || self.eoc_q[ec].is_empty() {
            return;
        }
        let (b, svc_secs) =
            ServiceTimes::pick(&self.svc.eoc, self.eoc_q[ec].len(), self.cfg.eoc_max_batch);
        let take = b.min(self.eoc_q[ec].len());
        let batch: Vec<usize> = self.eoc_q[ec].drain(..take).collect();
        self.eoc_busy[ec] = true;
        let done = sch.now() + secs(svc_secs);
        sch.at(done, move |sch, w: &mut World| {
            w.finish_eoc_batch(sch, ec, &batch);
            w.eoc_busy[ec] = false;
            w.try_serve_eoc(sch, ec);
        });
    }

    fn finish_eoc_batch(&mut self, sch: &mut Scheduler<World>, ec: usize, batch: &[usize]) {
        let pixels: Vec<Rc<Vec<f32>>> =
            batch.iter().map(|&id| self.records[id].pixels.clone()).collect();
        let refs: Vec<&Vec<f32>> = pixels.iter().map(|p| p.as_ref()).collect();
        let confs = match self.compute.eoc_conf(&refs) {
            Ok(c) => c,
            Err(e) => {
                self.errors.push(format!("eoc: {e}"));
                return;
            }
        };
        let now = sch.now();
        for (&id, &conf) in batch.iter().zip(&confs) {
            let eil = crate::util::to_secs(now - self.records[id].t_od);
            self.policies[ec].observe_eoc_eil(eil);
            let decision = match self.cfg.paradigm {
                // EI: positive iff confident; everything else dropped
                Paradigm::Ei => {
                    if conf >= 0.8 {
                        EdgeDecision::Positive
                    } else {
                        EdgeDecision::Drop
                    }
                }
                _ => self.policies[ec].edge_decision(conf),
            };
            match decision {
                EdgeDecision::Positive => {
                    self.records[id].predicted = Some(true);
                    self.records[id].eil = Some(eil);
                    // metadata to RS on the CC (paper links ③⑥⑦)
                    self.net.uplink[ec].send(now, sizes::META_BYTES);
                }
                EdgeDecision::Drop => {
                    self.records[id].predicted = Some(false);
                    self.records[id].eil = Some(eil);
                }
                EdgeDecision::Upload => {
                    let deliver = self.net.uplink[ec].send(now, sizes::CROP_BYTES);
                    sch.at(deliver, move |sch, w: &mut World| {
                        w.coc_q.push_back(id);
                        w.try_serve_coc(sch);
                    });
                }
            }
        }
    }

    fn try_serve_coc(&mut self, sch: &mut Scheduler<World>) {
        if self.coc_busy || self.coc_q.is_empty() {
            return;
        }
        let (b, svc_secs) =
            ServiceTimes::pick(&self.svc.coc, self.coc_q.len(), self.cfg.coc_max_batch);
        let take = b.min(self.coc_q.len());
        let batch: Vec<usize> = self.coc_q.drain(..take).collect();
        self.coc_busy = true;
        let done = sch.now() + secs(svc_secs);
        sch.at(done, move |sch, w: &mut World| {
            w.finish_coc_batch(sch, &batch);
            w.coc_busy = false;
            w.try_serve_coc(sch);
        });
    }

    fn finish_coc_batch(&mut self, sch: &mut Scheduler<World>, batch: &[usize]) {
        let pixels: Vec<Rc<Vec<f32>>> =
            batch.iter().map(|&id| self.records[id].pixels.clone()).collect();
        let refs: Vec<&Vec<f32>> = pixels.iter().map(|p| p.as_ref()).collect();
        let tops = match self.compute.coc_top1(&refs) {
            Ok(t) => t,
            Err(e) => {
                self.errors.push(format!("coc: {e}"));
                return;
            }
        };
        let target = self.compute.target_class();
        let now = sch.now();
        let mut ecs_involved: Vec<usize> = Vec::new();
        for (&id, &top) in batch.iter().zip(&tops) {
            let eil = crate::util::to_secs(now - self.records[id].t_od);
            let rec = &mut self.records[id];
            rec.coc_label = Some(top);
            rec.predicted = Some(top == target);
            rec.eil = Some(eil);
            ecs_involved.push(rec.ec);
        }
        // AP feedback: the global IC reports COC EILs to each involved
        // EC's LIC over the downlink (paper ⑨⑪④).
        if self.cfg.paradigm == Paradigm::AceAp {
            ecs_involved.sort_unstable();
            ecs_involved.dedup();
            for ec in ecs_involved {
                self.net.downlink[ec].send(now, EIL_FEEDBACK_BYTES);
                // observe the mean EIL of this EC's crops in the batch
                let mut sum = 0.0;
                let mut n = 0;
                for (&id, _) in batch.iter().zip(&tops) {
                    if self.records[id].ec == ec {
                        sum += self.records[id].eil.unwrap_or(0.0);
                        n += 1;
                    }
                }
                if n > 0 {
                    self.policies[ec].observe_coc_eil(sum / n as f64);
                }
            }
        }
        let _ = self.compute.eoc_batches(); // (keep Compute API uniform)
    }

    /// Post-hoc ground truth (footnote 1): COC labels for every crop
    /// that did not already get one online.
    fn ground_truth(&mut self) -> Result<Vec<bool>> {
        let target = self.compute.target_class();
        let mut gt = vec![false; self.records.len()];
        let mut missing_px: Vec<Rc<Vec<f32>>> = Vec::new();
        let mut missing_idx = Vec::new();
        for (i, r) in self.records.iter().enumerate() {
            match r.coc_label {
                Some(l) => gt[i] = l == target,
                None => {
                    missing_px.push(r.pixels.clone());
                    missing_idx.push(i);
                }
            }
        }
        // chunk of 1: the interpret-mode COC's per-crop cost is lowest
        // at b=1 (batching is super-linear there — EXPERIMENTS.md §Perf
        // L1), so the post-hoc pass runs per-crop like the online COC.
        for (chunk_px, chunk_idx) in missing_px
            .chunks(1)
            .zip(missing_idx.chunks(1))
        {
            let refs: Vec<&Vec<f32>> = chunk_px.iter().map(|p| p.as_ref()).collect();
            let tops = self.compute.coc_top1(&refs)?;
            for (&i, &t) in chunk_idx.iter().zip(&tops) {
                gt[i] = t == target;
            }
        }
        Ok(gt)
    }
}

/// Run one experiment cell to completion and collect its metrics.
pub fn run_cell(cfg: CellConfig, svc: ServiceTimes, compute: Compute) -> Result<CellMetrics> {
    let mut sch: Scheduler<World> = Scheduler::new();
    let num_cams = cfg.num_ecs * cfg.cams_per_ec;
    let interval = secs(cfg.interval_s);
    let horizon = secs(cfg.duration_s);
    let mut world = World::new(cfg.clone(), svc, compute);

    // validation-testbed channel schedule (§4.2.2): apply each phase at
    // its start time
    if let Some(profile) = &cfg.channel {
        for phase in profile.phases.clone() {
            sch.at(secs(phase.start_s), move |_sch, w: &mut World| {
                w.apply_phase(&phase);
            });
        }
    }

    // periodic OD sampling per camera, staggered to avoid lockstep
    for cam in 0..num_cams {
        let offset = secs(0.3) + (cam as u64) * interval / num_cams as u64;
        fn tick(
            sch: &mut Scheduler<World>,
            w: &mut World,
            cam: usize,
            interval: SimTime,
            horizon: SimTime,
        ) {
            if sch.now() > horizon {
                w.sampling_done = true;
                return;
            }
            w.sample(sch, cam);
            sch.after(interval, move |sch, w: &mut World| {
                tick(sch, w, cam, interval, horizon);
            });
        }
        sch.at(offset, move |sch, w: &mut World| {
            tick(sch, w, cam, interval, horizon);
        });
    }

    // run to exhaustion (sampling stops at the horizon; queues drain)
    sch.run(&mut world, 50_000_000);
    if let Some(e) = world.errors.first() {
        anyhow::bail!("inference error during sim: {e}");
    }

    let gt = world.ground_truth()?;
    let mut f1 = F1::default();
    let mut eil = Percentiles::new();
    let mut edge_decided = 0u64;
    let mut cloud_decided = 0u64;
    for (r, &actual) in world.records.iter().zip(&gt) {
        let predicted = r.predicted.unwrap_or(false);
        f1.add(predicted, actual);
        if let Some(e) = r.eil {
            eil.add(e);
        }
        if r.coc_label.is_some() {
            cloud_decided += 1;
        } else if r.predicted.is_some() {
            edge_decided += 1;
        }
    }
    Ok(CellMetrics {
        paradigm: cfg.paradigm.name().to_string(),
        interval_s: cfg.interval_s,
        wan_delay_ms: cfg.wan_delay_ms,
        f1,
        eil,
        bwc_bytes: world.net.wan_bytes(),
        crops: world.records.len() as u64,
        edge_decided,
        cloud_decided,
        sim_duration_s: cfg.duration_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(p: Paradigm, interval: f64) -> CellConfig {
        CellConfig {
            paradigm: p,
            interval_s: interval,
            duration_s: 10.0,
            ..Default::default()
        }
    }

    fn run(p: Paradigm, interval: f64, delay: f64) -> CellMetrics {
        let mut cfg = quick_cfg(p, interval);
        cfg.wan_delay_ms = delay;
        run_cell(cfg, ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
            .unwrap()
    }

    #[test]
    fn all_paradigms_produce_crops_and_decisions() {
        for p in [Paradigm::Ci, Paradigm::Ei, Paradigm::AceBp, Paradigm::AceAp] {
            let m = run(p, 0.5, 0.0);
            assert!(m.crops > 10, "{:?}: {} crops", p, m.crops);
            assert_eq!(
                m.edge_decided + m.cloud_decided,
                m.crops,
                "{:?} left undecided crops",
                p
            );
            assert!(!m.eil.is_empty());
        }
    }

    #[test]
    fn ci_has_highest_bwc_ei_lowest() {
        let ci = run(Paradigm::Ci, 0.3, 0.0);
        let ei = run(Paradigm::Ei, 0.3, 0.0);
        let ace = run(Paradigm::AceBp, 0.3, 0.0);
        assert!(ci.bwc_bytes > ace.bwc_bytes, "CI {} !> ACE {}", ci.bwc_bytes, ace.bwc_bytes);
        assert!(ace.bwc_bytes > ei.bwc_bytes, "ACE {} !> EI {}", ace.bwc_bytes, ei.bwc_bytes);
    }

    #[test]
    fn ci_f1_is_perfect_by_construction() {
        // ground truth IS COC's post-hoc labels; CI sends all to COC
        let m = run(Paradigm::Ci, 0.5, 0.0);
        assert!((m.f1.f1() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ei_decides_everything_at_edge() {
        let m = run(Paradigm::Ei, 0.5, 0.0);
        assert_eq!(m.cloud_decided, 0);
        assert_eq!(m.edge_decided, m.crops);
    }

    #[test]
    fn wan_delay_raises_ci_eil() {
        let mut fast = run(Paradigm::Ci, 0.5, 0.0);
        let mut slow = run(Paradigm::Ci, 0.5, 50.0);
        assert!(
            slow.eil_ms() > fast.eil_ms() + 40.0,
            "delay not reflected: {} vs {}",
            slow.eil_ms(),
            fast.eil_ms()
        );
    }

    #[test]
    fn load_increases_ci_eil_via_backlog() {
        let mut low = run(Paradigm::Ci, 0.5, 0.0);
        let mut high = run(Paradigm::Ci, 0.1, 0.0);
        assert!(
            high.eil_ms() > low.eil_ms() * 1.5,
            "no backlog effect: {} vs {}",
            high.eil_ms(),
            low.eil_ms()
        );
    }

    #[test]
    fn ace_ap_load_balances_under_pressure() {
        let bp = run(Paradigm::AceBp, 0.1, 0.0);
        let ap = run(Paradigm::AceAp, 0.1, 0.0);
        // AP routes some crops straight to COC when EOC queues build
        assert!(ap.crops > 0 && bp.crops > 0);
        // and its mean EIL should not be (much) worse than BP's
        let mut bp2 = bp.clone();
        let mut ap2 = ap.clone();
        assert!(ap2.eil_ms() <= bp2.eil_ms() * 1.6, "AP {} vs BP {}", ap2.eil_ms(), bp2.eil_ms());
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = run(Paradigm::AceBp, 0.3, 0.0);
        let b = run(Paradigm::AceBp, 0.3, 0.0);
        assert_eq!(a.crops, b.crops);
        assert_eq!(a.bwc_bytes, b.bwc_bytes);
        assert_eq!(a.f1, b.f1);
    }
}
