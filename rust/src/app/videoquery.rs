//! The §5 intelligent video query application + Figure 5 experiment,
//! built on the generic `svcgraph` runtime.
//!
//! The cell no longer wires its world by hand: `run_cell` builds the
//! §5.1.1 infrastructure, parses the Figure-4 topology, lets the
//! platform orchestrator place every component, and deploys each placed
//! `Instance` as a `svcgraph::Component` bound to its node's local
//! message service:
//!
//!   DG  — synthetic camera stream per RPi (timer-driven sampling);
//!   OD  — frame differencing on three frames per sample, same node as
//!         its DG (zero-cost hand-off), routing crops per paradigm;
//!   EOC — edge binary classifier per EC mini PC (batched single-server
//!         queue, calibrated service times);
//!   LIC — per-EC in-app controller: BP/AP decisions, EIL observation;
//!   COC — cloud multi-class classifier on the CC (per-crop service);
//!   IC  — global in-app controller on the CC (AP's EIL feedback);
//!   RS  — result storage on the CC (metadata sink).
//!
//! Transport is entirely topic-based: OD→EOC rides the EC LAN, crop
//! uploads and result metadata ride the `cloud/#` bridge over each EC's
//! WAN uplink, and AP feedback rides `edge/ec<k>/#` back down — so BWC
//! is read from the simnet link counters instead of being hand-charged
//! per app. Classifier OUTPUTS are real: every crop is pushed through
//! the compiled HLO artifacts (with `Compute::Real`), so F1 is
//! measured, not modeled. Ground truth follows footnote 1 (COC post-hoc
//! labels over all extracted crops).

use crate::deploy::Instance;
use crate::inapp::{AdvancedPolicy, BasicPolicy, EdgeDecision, QueryPolicy, Route};
use crate::infra::{InfraBuilder, Infrastructure, NodeKind};
use crate::metrics::{CellMetrics, F1};
use crate::platform::orchestrator::{self, NetHints};
use crate::runtime::{Classifier, ModelBank};
use crate::simnet::{sizes, NetConfig, NetFabric};
use crate::svcgraph::lifecycle::{
    ControlPlane, ControlPlaneConfig, InstanceFactory, LifecycleReport, LifecycleScenario,
};
use crate::svcgraph::{ClusterRef, Component, Ctx, GraphMsg, GraphRuntime, Site, SvcWorld};
use crate::topology::{Topology, VIDEOQUERY_TOPOLOGY};
use crate::util::stats::Percentiles;
use crate::util::{millis, secs, to_secs, SimTime};
use crate::video::{CameraStream, Image, ObjectDetector, OdConfig};
use anyhow::Result;
use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::rc::Rc;
use std::sync::{Arc, Mutex};

/// Implementation paradigm under comparison (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Paradigm {
    /// Cloud Intelligence: every crop goes to COC.
    Ci,
    /// Edge Intelligence: EOC only; unconfident crops are dropped.
    Ei,
    /// ACE with the Basic Policy.
    AceBp,
    /// ACE with the customized Advanced Policy.
    AceAp,
}

impl Paradigm {
    pub fn name(self) -> &'static str {
        match self {
            Paradigm::Ci => "CI",
            Paradigm::Ei => "EI",
            Paradigm::AceBp => "ACE",
            Paradigm::AceAp => "ACE+",
        }
    }
}

/// Experiment cell configuration.
#[derive(Debug, Clone)]
pub struct CellConfig {
    pub paradigm: Paradigm,
    /// OD sampling interval in seconds — the system-load knob
    /// (paper sweeps 0.5 -> 0.1).
    pub interval_s: f64,
    /// One-way WAN delay in ms (0 ideal, 50 practical).
    pub wan_delay_ms: f64,
    /// Virtual experiment duration (paper: 5-minute clips).
    pub duration_s: f64,
    pub num_ecs: usize,
    pub cams_per_ec: usize,
    pub seed: u64,
    /// Classifier batch caps. The paper's COC serves crops individually
    /// (32.3 ms each — and our interpret-mode COC artifact has
    /// super-linear batch cost, see EXPERIMENTS.md §Perf L1), so COC
    /// runs per-crop; EOC batches up to 2 (its measured per-crop cost
    /// improves to ~36 ms there), leaving the EC borderline at peak
    /// load — which is what activates AP's load balancing, as in §5.2.
    pub eoc_max_batch: usize,
    pub coc_max_batch: usize,
    /// Optional §4.2.2 validation-testbed channel schedule; when set it
    /// overrides `wan_delay_ms` and reshapes the WAN links per phase.
    pub channel: Option<crate::testbed::ChannelProfile>,
    /// CC cluster size (1 = the degenerate single-workstation CC of
    /// §5.1.1; more nodes make the CC a real LAN-connected cluster).
    pub cc_nodes: usize,
    /// Optional full network shape (per-node NICs, CC LAN, link
    /// shaping). `None` = the degenerate flat model derived from
    /// `num_ecs`/`wan_delay_ms`. When set, its `num_ecs`/`wan_delay`
    /// must be kept consistent with this config by the caller.
    pub net: Option<NetConfig>,
    /// Scheduler event lanes (`--partitions`). The cell runs on one
    /// thread either way — the `Rc`-shared trace cannot cross threads —
    /// but laned runs exercise the per-cluster queues the parallel
    /// driver partitions on, and the k-way merge keeps every
    /// trajectory byte-identical to `partitions = 1`.
    pub partitions: usize,
}

impl Default for CellConfig {
    fn default() -> Self {
        CellConfig {
            paradigm: Paradigm::AceBp,
            interval_s: 0.5,
            wan_delay_ms: 0.0,
            duration_s: 30.0,
            num_ecs: 3,
            cams_per_ec: 3,
            seed: 1,
            eoc_max_batch: 2,
            coc_max_batch: 1,
            channel: None,
            cc_nodes: 1,
            net: None,
            partitions: 1,
        }
    }
}

/// Calibrated service times scaled to the paper's operating point.
#[derive(Debug, Clone)]
pub struct ServiceTimes {
    /// batch size -> seconds, EOC on a mini PC
    pub eoc: HashMap<usize, f64>,
    /// batch size -> seconds, COC on the CC workstation
    pub coc: HashMap<usize, f64>,
}

/// §5.2: "the inference time of COC is about 32.3 ms on CC, and that of
/// EOC on edge node is above 44 ms".
pub const PAPER_COC_B1_SECS: f64 = 0.0323;
pub const PAPER_EOC_B1_SECS: f64 = 0.0440;

impl ServiceTimes {
    /// Scale measured PJRT times so b=1 matches the paper's §5.2
    /// numbers; the batching-efficiency CURVE stays measured (see
    /// DESIGN.md §Substitutions).
    pub fn calibrated_to_paper(bank: &ModelBank) -> Self {
        let se = PAPER_EOC_B1_SECS / bank.eoc.service_time(1);
        let sc = PAPER_COC_B1_SECS / bank.coc.service_time(1);
        let eoc = bank
            .eoc
            .service_secs
            .iter()
            .map(|(b, t)| (*b, t * se))
            .collect();
        let coc = bank
            .coc
            .service_secs
            .iter()
            .map(|(b, t)| (*b, t * sc))
            .collect();
        ServiceTimes { eoc, coc }
    }

    /// Synthetic service-time table (unit tests without artifacts):
    /// linear-ish batching gains.
    pub fn synthetic() -> Self {
        let mk = |b1: f64| -> HashMap<usize, f64> {
            [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&b| (b, b1 * (0.55 + 0.45 * b as f64)))
                .collect()
        };
        ServiceTimes { eoc: mk(PAPER_EOC_B1_SECS), coc: mk(PAPER_COC_B1_SECS) }
    }

    fn pick(table: &HashMap<usize, f64>, n: usize, cap: usize) -> (usize, f64) {
        let mut best = *table.keys().min().unwrap();
        for &b in table.keys() {
            if b <= n.min(cap) && b > best {
                best = b;
            }
        }
        (best, table[&best])
    }
}

/// Classifier outputs for the DES: real XLA inference with a
/// cross-cell cache (identical crops recur across cells; caching the
/// OUTPUT changes nothing observable but cuts wall-clock ~4x). Under
/// the parallel sweep each worker owns one cache (`run_sweep`), so the
/// compute hot path never contends on a shared lock.
pub struct InferCache {
    /// pixel-hash -> EOC target-confidence
    eoc: HashMap<u64, f32>,
    /// pixel-hash -> COC top-1 class
    coc: HashMap<u64, u8>,
    pub eoc_execs: u64,
    pub coc_execs: u64,
}

fn pixel_hash(px: &[f32]) -> u64 {
    // FNV-1a over the f32 bit patterns
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in px {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

impl InferCache {
    pub fn new() -> Self {
        InferCache { eoc: HashMap::new(), coc: HashMap::new(), eoc_execs: 0, coc_execs: 0 }
    }

    /// EOC confidences (P[target]) for a batch of crops.
    pub fn eoc_conf(&mut self, clf: &Classifier, crops: &[&Vec<f32>]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; crops.len()];
        let mut missing = Vec::new();
        let mut missing_idx = Vec::new();
        for (i, c) in crops.iter().enumerate() {
            match self.eoc.get(&pixel_hash(c)) {
                Some(v) => out[i] = *v,
                None => {
                    missing.push((*c).clone());
                    missing_idx.push(i);
                }
            }
        }
        if !missing.is_empty() {
            self.eoc_execs += 1;
            let probs = clf.classify(&missing)?;
            for (j, i) in missing_idx.into_iter().enumerate() {
                let conf = probs[j][1]; // P[class=1] = target present
                self.eoc.insert(pixel_hash(&missing[j]), conf);
                out[i] = conf;
            }
        }
        Ok(out)
    }

    /// COC top-1 classes for a batch of crops.
    pub fn coc_top1(&mut self, clf: &Classifier, crops: &[&Vec<f32>]) -> Result<Vec<u8>> {
        let mut out = vec![0u8; crops.len()];
        let mut missing = Vec::new();
        let mut missing_idx = Vec::new();
        for (i, c) in crops.iter().enumerate() {
            match self.coc.get(&pixel_hash(c)) {
                Some(v) => out[i] = *v,
                None => {
                    missing.push((*c).clone());
                    missing_idx.push(i);
                }
            }
        }
        if !missing.is_empty() {
            self.coc_execs += 1;
            let probs = clf.classify(&missing)?;
            for (j, i) in missing_idx.into_iter().enumerate() {
                let top = probs[j]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k as u8)
                    .unwrap_or(0);
                self.coc.insert(pixel_hash(&missing[j]), top);
                out[i] = top;
            }
        }
        Ok(out)
    }
}

impl Default for InferCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-crop trace record (the experiment's measurement plane — the
/// in-memory twin of the metadata RS stores).
#[derive(Debug, Clone)]
struct CropRecord {
    ec: usize,
    t_od: SimTime,
    /// final predicted-positive (None until decided)
    predicted: Option<bool>,
    /// COC online label if it went to the cloud
    coc_label: Option<u8>,
    /// EIL (secs) once decided
    eil: Option<f64>,
    pixels: Rc<Vec<f32>>,
}

/// Compute substrate handed to the components. `Synthetic` is an
/// oracle keyed by pixel hash (unit tests without artifacts).
///
/// `Real` is thread-shareable (`Arc` bank + `Arc<Mutex>` cache) so
/// sweep workers can run cells concurrently against one loaded model
/// bank; cloning is a refcount bump.
#[derive(Clone)]
pub enum Compute {
    Real { bank: Arc<ModelBank>, cache: Arc<Mutex<InferCache>> },
    /// (eoc_conf, coc_top1) oracles keyed by pixel hash
    Synthetic { target_bias: f32 },
}

impl Compute {
    fn eoc_conf(&self, crops: &[&Vec<f32>]) -> Result<Vec<f32>> {
        match self {
            Compute::Real { bank, cache } => cache.lock().unwrap().eoc_conf(&bank.eoc, crops),
            Compute::Synthetic { target_bias } => Ok(crops
                .iter()
                .map(|c| {
                    let h = pixel_hash(c);
                    let u = (h >> 16) as u32 as f32 / u32::MAX as f32;
                    (u * 0.9 + target_bias).min(1.0)
                })
                .collect()),
        }
    }

    fn coc_top1(&self, crops: &[&Vec<f32>]) -> Result<Vec<u8>> {
        match self {
            Compute::Real { bank, cache } => cache.lock().unwrap().coc_top1(&bank.coc, crops),
            Compute::Synthetic { .. } => Ok(crops
                .iter()
                .map(|c| (pixel_hash(c) % 8) as u8)
                .collect()),
        }
    }

    fn target_class(&self) -> u8 {
        match self {
            Compute::Real { bank, .. } => bank.manifest.target_class as u8,
            Compute::Synthetic { .. } => 1,
        }
    }
}

const EIL_FEEDBACK_BYTES: u64 = sizes::META_BYTES;

/// Topics of the video-query graph (all rooted under `vq/` locally;
/// `cloud/…` rides the EC→CC bridge, `edge/ec<k>/…` the CC→EC one).
const COC_TOPIC: &str = "cloud/vq/coc/crop";
const RS_EDGE_TOPIC: &str = "cloud/vq/rs/meta";
const IC_TOPIC: &str = "vq/cc/ic/result";
const RS_CC_TOPIC: &str = "vq/cc/rs/meta";

fn frames_topic(seg: &str, node: &str) -> String {
    format!("vq/{seg}/od/{node}/frames")
}

fn eoc_topic(seg: &str) -> String {
    format!("vq/{seg}/eoc/crop")
}

fn verdict_topic(seg: &str) -> String {
    format!("vq/{seg}/lic/verdict")
}

fn eil_topic(seg: &str) -> String {
    format!("edge/{seg}/vq/eil")
}

// ---------------------------------------------------------------------------
// Message bodies
// ---------------------------------------------------------------------------

struct FramesBody {
    f0: Image,
    f1: Image,
    f2: Image,
}

/// Crop payload: pixels live in the shared trace; the wire size is
/// still charged as a full crop.
struct CropBody {
    id: usize,
}

struct VerdictBody {
    id: usize,
    conf: f32,
}

/// COC → IC batch report: per-EC mean EILs of the batch just decided.
struct CocDoneBody {
    ec_eils: Vec<(usize, f64)>,
}

struct EilBody {
    secs: f64,
}

struct MetaBody;

// ---------------------------------------------------------------------------
// Shared cell state
// ---------------------------------------------------------------------------

/// Experiment-wide state shared by the components: the measurement
/// trace, the per-EC in-app policies (the LIC owns decisions; OD reads
/// routing through the same handle — the in-app control channel without
/// a per-crop round trip), and the compute substrate.
struct CellState {
    cfg: CellConfig,
    svc: ServiceTimes,
    compute: Compute,
    records: RefCell<Vec<CropRecord>>,
    policies: Vec<RefCell<Box<dyn QueryPolicy>>>,
    errors: RefCell<Vec<String>>,
    rs_meta: Cell<u64>,
    horizon: SimTime,
    num_cams: usize,
}

type Shared = Rc<CellState>;

// ---------------------------------------------------------------------------
// Components
// ---------------------------------------------------------------------------

/// DG — synthetic camera stream on a camera RPi; publishes three-frame
/// windows to its co-located OD on a sampling timer.
struct DataGen {
    shared: Shared,
    cam: CameraStream,
    cam_global: usize,
    interval: SimTime,
    out_topic: String,
}

impl Component for DataGen {
    fn subscriptions(&self) -> Vec<String> {
        Vec::new()
    }

    fn on_start(&mut self, ctx: &mut Ctx) {
        // staggered to avoid lockstep across cameras
        let offset =
            secs(0.3) + (self.cam_global as u64) * self.interval / self.shared.num_cams as u64;
        ctx.set_timer(offset, 0);
    }

    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        if ctx.now() > self.shared.horizon {
            return; // sampling stops at the horizon; queues drain
        }
        let t = to_secs(ctx.now());
        self.cam.advance_to(t);
        let body = FramesBody {
            f0: self.cam.frame_at(t - 0.2),
            f1: self.cam.frame_at(t - 0.1),
            f2: self.cam.frame_at(t),
        };
        // same-node hand-off to OD: no link charge
        ctx.publish(&self.out_topic, 0, Rc::new(body));
        ctx.set_timer(self.interval, 0);
    }
}

/// OD — frame differencing + crop extraction; routes each crop per the
/// paradigm (CI → COC upload; EI/BP → EOC; AP → the LIC's balancer).
struct ObjectDet {
    shared: Shared,
    od: ObjectDetector,
    ec: usize,
    in_topic: String,
    eoc_topic: String,
}

impl Component for ObjectDet {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.in_topic.clone()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(frames) = msg.body_as::<FramesBody>() else {
            return;
        };
        let crops = self.od.detect(&frames.f0, &frames.f1, &frames.f2);
        for crop in crops {
            let id = {
                let mut recs = self.shared.records.borrow_mut();
                let id = recs.len();
                recs.push(CropRecord {
                    ec: self.ec,
                    t_od: ctx.now(),
                    predicted: None,
                    coc_label: None,
                    eil: None,
                    pixels: Rc::new(crop.pixels),
                });
                id
            };
            let route = match self.shared.cfg.paradigm {
                Paradigm::Ci => Route::Coc,
                Paradigm::AceAp => self.shared.policies[self.ec].borrow_mut().route_crop(),
                _ => Route::Eoc,
            };
            match route {
                // OD -> EOC over the EC LAN (paper link ①)
                Route::Eoc => {
                    ctx.publish(&self.eoc_topic, sizes::CROP_BYTES, Rc::new(CropBody { id }))
                }
                // crop -> COC over the EC's WAN uplink (bridged)
                Route::Coc => ctx.publish(COC_TOPIC, sizes::CROP_BYTES, Rc::new(CropBody { id })),
            }
        }
    }
}

/// EOC — single-server batched classifier on the EC mini PC.
struct EdgeClassifier {
    shared: Shared,
    ec: usize,
    in_topic: String,
    out_topic: String,
    q: VecDeque<usize>,
    busy: bool,
    in_flight: Vec<usize>,
}

impl EdgeClassifier {
    fn try_serve(&mut self, ctx: &mut Ctx) {
        if self.busy || self.q.is_empty() {
            return;
        }
        let (b, svc_secs) =
            ServiceTimes::pick(&self.shared.svc.eoc, self.q.len(), self.shared.cfg.eoc_max_batch);
        let take = b.min(self.q.len());
        self.in_flight = self.q.drain(..take).collect();
        self.busy = true;
        ctx.set_timer(secs(svc_secs), 0);
    }
}

impl Component for EdgeClassifier {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.in_topic.clone()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        if let Some(c) = msg.body_as::<CropBody>() {
            self.q.push_back(c.id);
            self.try_serve(ctx);
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        let batch = std::mem::take(&mut self.in_flight);
        let pixels: Vec<Rc<Vec<f32>>> = {
            let recs = self.shared.records.borrow();
            batch.iter().map(|&id| recs[id].pixels.clone()).collect()
        };
        let refs: Vec<&Vec<f32>> = pixels.iter().map(|p| p.as_ref()).collect();
        match self.shared.compute.eoc_conf(&refs) {
            Ok(confs) => {
                for (&id, &conf) in batch.iter().zip(&confs) {
                    // verdict to the co-located LIC (paper link ⑤)
                    ctx.publish(
                        &self.out_topic,
                        sizes::META_BYTES,
                        Rc::new(VerdictBody { id, conf }),
                    );
                }
            }
            Err(e) => self.shared.errors.borrow_mut().push(format!("eoc: {e}")),
        }
        self.busy = false;
        self.try_serve(ctx);
    }
}

/// LIC — the per-EC in-app controller: executes BP/AP on EOC verdicts
/// and ingests the global IC's EIL feedback.
struct LocalController {
    shared: Shared,
    ec: usize,
    verdict_topic: String,
    eil_topic: String,
}

impl Component for LocalController {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.verdict_topic.clone(), self.eil_topic.clone()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        if let Some(v) = msg.body_as::<VerdictBody>() {
            let t_od = self.shared.records.borrow()[v.id].t_od;
            let eil = to_secs(ctx.now() - t_od);
            let decision = {
                let mut policy = self.shared.policies[self.ec].borrow_mut();
                policy.observe_eoc_eil(eil);
                match self.shared.cfg.paradigm {
                    // EI: positive iff confident; everything else dropped
                    Paradigm::Ei => {
                        if v.conf >= 0.8 {
                            EdgeDecision::Positive
                        } else {
                            EdgeDecision::Drop
                        }
                    }
                    _ => policy.edge_decision(v.conf),
                }
            };
            match decision {
                EdgeDecision::Positive => {
                    {
                        let mut recs = self.shared.records.borrow_mut();
                        recs[v.id].predicted = Some(true);
                        recs[v.id].eil = Some(eil);
                    }
                    // metadata to RS on the CC (paper links ③⑥⑦):
                    // rides the uplink via the cloud/# bridge
                    ctx.publish(RS_EDGE_TOPIC, sizes::META_BYTES, Rc::new(MetaBody));
                }
                EdgeDecision::Drop => {
                    let mut recs = self.shared.records.borrow_mut();
                    recs[v.id].predicted = Some(false);
                    recs[v.id].eil = Some(eil);
                }
                EdgeDecision::Upload => {
                    // unconfident: full crop up to COC (bridged uplink)
                    ctx.publish(COC_TOPIC, sizes::CROP_BYTES, Rc::new(CropBody { id: v.id }));
                }
            }
        } else if let Some(f) = msg.body_as::<EilBody>() {
            self.shared.policies[self.ec].borrow_mut().observe_coc_eil(f.secs);
        }
    }
}

/// COC — single-server classifier on the CC (per-crop at the paper's
/// operating point).
struct CloudClassifier {
    shared: Shared,
    q: VecDeque<usize>,
    busy: bool,
    in_flight: Vec<usize>,
}

impl CloudClassifier {
    fn try_serve(&mut self, ctx: &mut Ctx) {
        if self.busy || self.q.is_empty() {
            return;
        }
        let (b, svc_secs) =
            ServiceTimes::pick(&self.shared.svc.coc, self.q.len(), self.shared.cfg.coc_max_batch);
        let take = b.min(self.q.len());
        self.in_flight = self.q.drain(..take).collect();
        self.busy = true;
        ctx.set_timer(secs(svc_secs), 0);
    }
}

impl Component for CloudClassifier {
    fn subscriptions(&self) -> Vec<String> {
        vec![COC_TOPIC.to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        if let Some(c) = msg.body_as::<CropBody>() {
            self.q.push_back(c.id);
            self.try_serve(ctx);
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        let batch = std::mem::take(&mut self.in_flight);
        let pixels: Vec<Rc<Vec<f32>>> = {
            let recs = self.shared.records.borrow();
            batch.iter().map(|&id| recs[id].pixels.clone()).collect()
        };
        let refs: Vec<&Vec<f32>> = pixels.iter().map(|p| p.as_ref()).collect();
        match self.shared.compute.coc_top1(&refs) {
            Ok(tops) => {
                let target = self.shared.compute.target_class();
                let now = ctx.now();
                let mut per_ec: BTreeMap<usize, (f64, u32)> = BTreeMap::new();
                {
                    let mut recs = self.shared.records.borrow_mut();
                    for (&id, &top) in batch.iter().zip(&tops) {
                        let eil = to_secs(now - recs[id].t_od);
                        let rec = &mut recs[id];
                        rec.coc_label = Some(top);
                        rec.predicted = Some(top == target);
                        rec.eil = Some(eil);
                        let e = per_ec.entry(rec.ec).or_insert((0.0, 0));
                        e.0 += eil;
                        e.1 += 1;
                    }
                }
                // result metadata to RS + batch report to the global IC
                // (CC-internal hops; no WAN charge)
                ctx.publish(RS_CC_TOPIC, sizes::META_BYTES, Rc::new(MetaBody));
                let ec_eils: Vec<(usize, f64)> = per_ec
                    .into_iter()
                    .map(|(ec, (sum, n))| (ec, sum / n as f64))
                    .collect();
                ctx.publish(IC_TOPIC, sizes::META_BYTES, Rc::new(CocDoneBody { ec_eils }));
            }
            Err(e) => self.shared.errors.borrow_mut().push(format!("coc: {e}")),
        }
        // the server stays up even after an inference error, like the
        // edge classifier — remaining queued crops keep draining
        self.busy = false;
        self.try_serve(ctx);
    }
}

/// IC — the global in-app controller on the CC. Under AP it reports
/// COC EILs back to each involved EC's LIC over the downlink (paper
/// links ⑨⑪④).
struct GlobalController {
    shared: Shared,
}

impl Component for GlobalController {
    fn subscriptions(&self) -> Vec<String> {
        vec![IC_TOPIC.to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(done) = msg.body_as::<CocDoneBody>() else {
            return;
        };
        if self.shared.cfg.paradigm != Paradigm::AceAp {
            return;
        }
        for &(ec, mean_eil) in &done.ec_eils {
            ctx.publish(
                &eil_topic(&ClusterRef::Ec(ec).seg()),
                EIL_FEEDBACK_BYTES,
                Rc::new(EilBody { secs: mean_eil }),
            );
        }
    }
}

/// RS — result storage on the CC: metadata sink.
struct ResultStore {
    shared: Shared,
}

impl Component for ResultStore {
    fn subscriptions(&self) -> Vec<String> {
        vec![RS_EDGE_TOPIC.to_string(), RS_CC_TOPIC.to_string()]
    }

    fn on_message(&mut self, _ctx: &mut Ctx, msg: &GraphMsg) {
        if msg.body_as::<MetaBody>().is_some() {
            self.shared.rs_meta.set(self.shared.rs_meta.get() + 1);
        }
    }
}

// ---------------------------------------------------------------------------
// Cell assembly
// ---------------------------------------------------------------------------

/// Build the cell's infrastructure: `num_ecs` ECs of one mini PC +
/// `cams_per_ec` camera RPis, plus the CC — the §5.1.1 testbed's one
/// GPU workstation, joined by `cc_nodes - 1` cloud servers when the
/// scenario makes the CC a real cluster.
fn cell_infra(cfg: &CellConfig) -> Infrastructure {
    let mut b = InfraBuilder::register("cell");
    for _ in 0..cfg.num_ecs {
        let ec = b.claim_ec();
        b.add_edge_node(&ec, "minipc", NodeKind::MiniPc, BTreeMap::new());
        for r in 1..=cfg.cams_per_ec {
            let mut labels = BTreeMap::new();
            labels.insert("camera".to_string(), "true".to_string());
            b.add_edge_node(&ec, &format!("rpi{r}"), NodeKind::RaspberryPi, labels);
        }
    }
    b.add_cloud_node("gpu-ws", NodeKind::GpuWorkstation, BTreeMap::new());
    for s in 1..cfg.cc_nodes.max(1) {
        b.add_cloud_node(&format!("srv{s}"), NodeKind::CloudServer, BTreeMap::new());
    }
    b.build()
}

/// The cell's network shape: the explicit `cfg.net` when given, else
/// the degenerate flat model (`num_ecs` shared LANs + WAN pairs, free
/// NICs, free CC backplane) that reproduces the pre-PR-5 trajectories.
fn cell_netcfg(cfg: &CellConfig) -> NetConfig {
    cfg.net.clone().unwrap_or_else(|| NetConfig {
        num_ecs: cfg.num_ecs,
        wan_delay: millis(cfg.wan_delay_ms),
        ..Default::default()
    })
}

fn apply_phase(net: &mut NetFabric, phase: &crate::testbed::Phase) {
    for ec in 0..net.uplink.len() {
        let up = &mut net.uplink[ec];
        up.set_bw_bps((phase.uplink_mbps * 1e6) as u64);
        up.delay = phase.delay_us();
        up.jitter = phase.jitter_us();
        let down = &mut net.downlink[ec];
        down.set_bw_bps((phase.downlink_mbps * 1e6) as u64);
        down.delay = phase.delay_us();
        down.jitter = phase.jitter_us();
    }
}

/// Post-hoc ground truth (footnote 1): COC labels for every crop that
/// did not already get one online.
fn ground_truth(compute: &Compute, records: &[CropRecord]) -> Result<Vec<bool>> {
    let target = compute.target_class();
    let mut gt = vec![false; records.len()];
    let mut missing_px: Vec<Rc<Vec<f32>>> = Vec::new();
    let mut missing_idx = Vec::new();
    for (i, r) in records.iter().enumerate() {
        match r.coc_label {
            Some(l) => gt[i] = l == target,
            None => {
                missing_px.push(r.pixels.clone());
                missing_idx.push(i);
            }
        }
    }
    // chunk of 1: the interpret-mode COC's per-crop cost is lowest at
    // b=1 (batching is super-linear there — EXPERIMENTS.md §Perf L1),
    // so the post-hoc pass runs per-crop like the online COC.
    for (chunk_px, chunk_idx) in missing_px.chunks(1).zip(missing_idx.chunks(1)) {
        let refs: Vec<&Vec<f32>> = chunk_px.iter().map(|p| p.as_ref()).collect();
        let tops = compute.coc_top1(&refs)?;
        for (&i, &t) in chunk_idx.iter().zip(&tops) {
            gt[i] = t == target;
        }
    }
    Ok(gt)
}

/// Build the shared cell state (trace, per-EC policies, compute).
fn make_shared(cfg: CellConfig, svc: ServiceTimes, compute: Compute) -> Shared {
    let policies: Vec<RefCell<Box<dyn QueryPolicy>>> = (0..cfg.num_ecs)
        .map(|_| -> RefCell<Box<dyn QueryPolicy>> {
            RefCell::new(match cfg.paradigm {
                Paradigm::AceAp => Box::new(AdvancedPolicy::new(
                    PAPER_EOC_B1_SECS * 1.5,
                    PAPER_COC_B1_SECS * 1.5,
                )),
                _ => Box::new(BasicPolicy::default()),
            })
        })
        .collect();
    Rc::new(CellState {
        svc,
        compute,
        records: RefCell::new(Vec::new()),
        policies,
        errors: RefCell::new(Vec::new()),
        rs_meta: Cell::new(0),
        horizon: secs(cfg.duration_s),
        num_cams: cfg.num_ecs * cfg.cams_per_ec,
        cfg,
    })
}

/// Camera ordinal within its EC, derived from the node name (`rpi3` →
/// 2) — stable across re-deploys, and identical to the deploy-order
/// counter it replaced for the standard `rpi1..rpiN` naming (per-label
/// placement visits camera nodes in registration order).
fn cam_index(node: &str) -> usize {
    node.trim_start_matches(|c: char| !c.is_ascii_digit())
        .parse::<usize>()
        .map(|n| n.saturating_sub(1))
        .unwrap_or(0)
}

/// Build the component for one placed instance (Figure 4 step ④) —
/// shared by `run_cell`'s static deploy and the virtual-time control
/// plane's factory, so a redeployed instance is built exactly like a
/// statically deployed one.
fn component_for(
    shared: &Shared,
    interval: SimTime,
    inst: &Instance,
    site: &Site,
) -> Result<Option<Box<dyn Component>>> {
    let cfg = &shared.cfg;
    let seg = site.cluster.seg();
    let ec = match site.cluster {
        ClusterRef::Ec(k) => k,
        ClusterRef::Cc => 0,
    };
    Ok(match inst.component.as_str() {
        "dg" => {
            let cam_in_ec = cam_index(&site.node);
            let cam_global = ec * cfg.cams_per_ec + cam_in_ec;
            Some(Box::new(DataGen {
                shared: shared.clone(),
                // one moving object slot per camera keeps the per-EC
                // crop rate at the highest load (~22/s) just under
                // the EOC's 44 ms-anchored capacity (~28/s) — the
                // paper's regime where EI/ACE EILs stay
                // load-insensitive while CI's COC queue explodes
                cam: CameraStream::new(cfg.seed * 10_007 + (ec * 97 + cam_in_ec) as u64, 1),
                cam_global,
                interval,
                out_topic: frames_topic(&seg, &site.node),
            }) as Box<dyn Component>)
        }
        "od" => Some(Box::new(ObjectDet {
            shared: shared.clone(),
            od: ObjectDetector::new(OdConfig::default()),
            ec,
            in_topic: frames_topic(&seg, &site.node),
            eoc_topic: eoc_topic(&seg),
        })),
        "eoc" => Some(Box::new(EdgeClassifier {
            shared: shared.clone(),
            ec,
            in_topic: eoc_topic(&seg),
            out_topic: verdict_topic(&seg),
            q: VecDeque::new(),
            busy: false,
            in_flight: Vec::new(),
        })),
        "lic" => Some(Box::new(LocalController {
            shared: shared.clone(),
            ec,
            verdict_topic: verdict_topic(&seg),
            eil_topic: eil_topic(&seg),
        })),
        "coc" => Some(Box::new(CloudClassifier {
            shared: shared.clone(),
            q: VecDeque::new(),
            busy: false,
            in_flight: Vec::new(),
        })),
        "ic" => Some(Box::new(GlobalController { shared: shared.clone() })),
        "rs" => Some(Box::new(ResultStore { shared: shared.clone() })),
        _ => None,
    })
}

/// Fold the trace into `CellMetrics` (F1 vs post-hoc ground truth, EIL
/// percentiles, BWC off the WAN link counters). Returns the metrics
/// plus the edge-positive count for `run_cell`'s RS-delivery
/// invariant.
fn finalize_metrics(
    cfg: &CellConfig,
    shared: &Shared,
    rt: &GraphRuntime,
) -> Result<(CellMetrics, u64)> {
    if let Some(e) = shared.errors.borrow().first() {
        anyhow::bail!("inference error during sim: {e}");
    }
    let records = shared.records.borrow();
    let gt = ground_truth(&shared.compute, &records)?;
    let mut f1 = F1::default();
    let mut eil = Percentiles::new();
    let mut edge_decided = 0u64;
    let mut cloud_decided = 0u64;
    let mut edge_positives = 0u64;
    for (r, &actual) in records.iter().zip(&gt) {
        let predicted = r.predicted.unwrap_or(false);
        f1.add(predicted, actual);
        if let Some(e) = r.eil {
            eil.add(e);
        }
        if r.coc_label.is_some() {
            cloud_decided += 1;
        } else if r.predicted.is_some() {
            edge_decided += 1;
            if predicted {
                edge_positives += 1;
            }
        }
    }
    let mut m = CellMetrics {
        paradigm: cfg.paradigm.name().to_string(),
        interval_s: cfg.interval_s,
        wan_delay_ms: cfg.wan_delay_ms,
        f1,
        eil,
        bwc_bytes: rt.net().wan_bytes(),
        crops: records.len() as u64,
        edge_decided,
        cloud_decided,
        sim_duration_s: cfg.duration_s,
        nic_util: rt.net().nic_utilization(),
    };
    // sort the quantile buffer once here, so every downstream reader
    // (tables, CSV, hashes) takes the O(1) indexed path through &self
    m.finalize();
    Ok((m, edge_positives))
}

/// Run one experiment cell to completion and collect its metrics.
///
/// Figure-4 lifecycle, end to end: infrastructure → topology →
/// orchestrator placement → every placed instance deployed as a
/// `svcgraph` component → pub/sub transport over bridged simnet links →
/// metrics (BWC straight off the WAN link counters).
pub fn run_cell(cfg: CellConfig, svc: ServiceTimes, compute: Compute) -> Result<CellMetrics> {
    // ① user submits the topology; the orchestrator binds components —
    // network-aware when the cell's fabric has constrained NICs (the
    // degenerate default reproduces the CPU-spread placement exactly)
    let infra = cell_infra(&cfg);
    let net = NetFabric::new(&cell_netcfg(&cfg));
    let hints = NetHints::from_net(&net);
    let mut topo = Topology::parse(VIDEOQUERY_TOPOLOGY)?;
    if let Some(od) = topo.components.iter_mut().find(|c| c.name == "od") {
        od.params.insert("interval".to_string(), format!("{}", cfg.interval_s));
    }
    let plan = orchestrator::place_with_net(&topo, &infra, Some(&hints))?;
    // the sampling interval flows through the topology, like a real
    // component parameter (Figure 4's `params`)
    let interval_s: f64 = topo
        .component("od")
        .and_then(|c| c.params.get("interval"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(cfg.interval_s);

    // ② transport: per-cluster message services bridged over the WAN,
    // hop-charged on the per-node link graph
    let mut rt = GraphRuntime::with_lanes(net, cfg.partitions.max(1));
    let shared = make_shared(cfg.clone(), svc, compute);

    // ③ every placed instance becomes a Component on its node
    let interval = secs(interval_s);
    rt.deploy(&plan, |inst, site| component_for(&shared, interval, inst, site))?;

    // validation-testbed channel schedule (§4.2.2): apply each phase at
    // its start time
    if let Some(profile) = &cfg.channel {
        for phase in profile.phases.clone() {
            rt.at(secs(phase.start_s), move |_sch, w: &mut SvcWorld| {
                apply_phase(&mut w.fabric.net, &phase);
            });
        }
    }

    // ④ run to exhaustion (sampling stops at the horizon; queues drain)
    rt.run(50_000_000);

    // ⑤ metrics: F1 vs post-hoc ground truth; BWC off the WAN links
    let (m, edge_positives) = finalize_metrics(&cfg, &shared, &rt)?;
    // transport invariant: every edge positive published result
    // metadata that must have reached RS over the bridge by the time
    // the event heap drained
    debug_assert!(
        shared.rs_meta.get() >= edge_positives,
        "RS missed result metadata: stored {} < {} edge positives",
        shared.rs_meta.get(),
        edge_positives
    );
    Ok(m)
}

/// Outcome of a lifecycle-scenario run: application metrics plus the
/// control plane's audit trail.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// The usual cell metrics. Crops still undecided when an op killed
    /// their pipeline stage count as predicted-negative, and BWC
    /// includes the platform's own instruction/heartbeat traffic.
    pub metrics: CellMetrics,
    /// The control plane's deterministic audit trail.
    pub report: LifecycleReport,
}

/// Run the video-query application under the VIRTUAL-TIME control
/// plane (DESIGN.md §Control-plane): the scenario's scripted
/// deploy/update/fail-node/remove ops drive the LIVE graph mid-run —
/// agents converge instances, heartbeats flow, failed nodes are
/// shielded and their instances re-placed — while transport, queues,
/// and policies behave exactly as in [`run_cell`]. One divergence
/// from `run_cell`: the OD sampling interval comes from
/// `cfg.interval_s` (the factory outlives any single topology), so an
/// `od` `interval` param inside a scenario topology is ignored.
#[deprecated(
    since = "0.1.0",
    note = "use svcgraph::scenario::run / run_with — the unified dispatcher for all apps"
)]
pub fn run_scenario(
    mut cfg: CellConfig,
    svc: ServiceTimes,
    compute: Compute,
    scenario: &LifecycleScenario,
) -> Result<ScenarioOutcome> {
    // the scenario's `network:` block reshapes the fabric (and may
    // grow the CC into a multi-node cluster) on top of the cell config
    let mut netcfg = cell_netcfg(&cfg);
    if let Some(ov) = &scenario.network {
        cfg.cc_nodes = ov.apply_with_cc(&mut netcfg, cfg.cc_nodes);
    }
    let infra = cell_infra(&cfg);
    let mut net = NetFabric::new(&netcfg);
    // chaos knobs arm BEFORE any traffic, so link fault processes see
    // every message from t=0 (loss/dup of 0 consumes no PRNG draws and
    // leaves the trajectory byte-identical to a fault-free run)
    if let Some(spec) = &scenario.faults {
        net.arm_faults(*spec);
    }
    let hints = NetHints::from_net(&net);
    let mut rt = GraphRuntime::with_lanes(net, cfg.partitions.max(1));
    let interval = secs(cfg.interval_s);
    let shared = make_shared(cfg.clone(), svc, compute);
    let factory: InstanceFactory = {
        let shared = shared.clone();
        Rc::new(move |inst, site| component_for(&shared, interval, inst, site))
    };
    let plane = ControlPlane::install(
        &mut rt,
        infra,
        factory,
        None,
        scenario,
        ControlPlaneConfig::default(),
        hints,
    )?;
    // the §4.2.2 channel schedule applies under scenarios too
    if let Some(profile) = &cfg.channel {
        for phase in profile.phases.clone() {
            rt.at(secs(phase.start_s), move |_sch, w: &mut SvcWorld| {
                apply_phase(&mut w.fabric.net, &phase);
            });
        }
    }
    rt.run_until(scenario.duration);
    let (metrics, _) = finalize_metrics(&cfg, &shared, &rt)?;
    let mut report = plane.report();
    report.msgs_lost = rt.net().msgs_lost();
    Ok(ScenarioOutcome { metrics, report })
}

// ---------------------------------------------------------------------------
// Multi-cell sweeps (Figure 5)
// ---------------------------------------------------------------------------

/// The Figure-5 cell grid: paradigm x load (OD interval) x WAN delay,
/// in the paper's sweep order (delay outermost, then load, then
/// paradigm) — the order `run_sweep` preserves in its results.
pub fn fig5_grid(intervals: &[f64], delays: &[f64], duration_s: f64, seed: u64) -> Vec<CellConfig> {
    let mut cfgs = Vec::with_capacity(delays.len() * intervals.len() * 4);
    for &delay in delays {
        for &interval in intervals {
            for paradigm in [Paradigm::Ci, Paradigm::Ei, Paradigm::AceBp, Paradigm::AceAp] {
                cfgs.push(CellConfig {
                    paradigm,
                    interval_s: interval,
                    wan_delay_ms: delay,
                    duration_s,
                    seed,
                    ..Default::default()
                });
            }
        }
    }
    cfgs
}

/// Run every cell of `cfgs` on a pool of `workers` threads
/// (`sweep::parallel_map_init`), returning metrics in `cfgs` order.
///
/// `make_compute` is called once per worker to build its
/// (service-times, compute) pair — with `Compute::Real` that means one
/// `InferCache` per worker sharing one `Arc<ModelBank>`, so workers
/// never block each other on inference. Cells are independent DES
/// worlds, so the parallel sweep is metric-identical to the serial
/// one (golden-tested in `tests/svcgraph_integration.rs`); only the
/// wall-clock drops from sum-of-cells to max-of-cells.
pub fn run_sweep<F>(
    cfgs: Vec<CellConfig>,
    workers: usize,
    make_compute: F,
) -> Result<Vec<CellMetrics>>
where
    F: Fn() -> (ServiceTimes, Compute) + Sync,
{
    crate::sweep::parallel_map_init(
        cfgs,
        workers,
        &make_compute,
        |state: &mut (ServiceTimes, Compute), cfg: CellConfig| {
            run_cell(cfg, state.0.clone(), state.1.clone())
        },
    )
    .into_iter()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(p: Paradigm, interval: f64) -> CellConfig {
        CellConfig {
            paradigm: p,
            interval_s: interval,
            duration_s: 10.0,
            ..Default::default()
        }
    }

    fn run(p: Paradigm, interval: f64, delay: f64) -> CellMetrics {
        let mut cfg = quick_cfg(p, interval);
        cfg.wan_delay_ms = delay;
        run_cell(cfg, ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
            .unwrap()
    }

    #[test]
    fn all_paradigms_produce_crops_and_decisions() {
        for p in [Paradigm::Ci, Paradigm::Ei, Paradigm::AceBp, Paradigm::AceAp] {
            let m = run(p, 0.5, 0.0);
            assert!(m.crops > 10, "{:?}: {} crops", p, m.crops);
            assert_eq!(
                m.edge_decided + m.cloud_decided,
                m.crops,
                "{:?} left undecided crops",
                p
            );
            assert!(!m.eil.is_empty());
        }
    }

    #[test]
    fn ci_has_highest_bwc_ei_lowest() {
        let ci = run(Paradigm::Ci, 0.3, 0.0);
        let ei = run(Paradigm::Ei, 0.3, 0.0);
        let ace = run(Paradigm::AceBp, 0.3, 0.0);
        assert!(ci.bwc_bytes > ace.bwc_bytes, "CI {} !> ACE {}", ci.bwc_bytes, ace.bwc_bytes);
        assert!(ace.bwc_bytes > ei.bwc_bytes, "ACE {} !> EI {}", ace.bwc_bytes, ei.bwc_bytes);
    }

    #[test]
    fn ci_f1_is_perfect_by_construction() {
        // ground truth IS COC's post-hoc labels; CI sends all to COC
        let m = run(Paradigm::Ci, 0.5, 0.0);
        assert!((m.f1.f1() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ei_decides_everything_at_edge() {
        let m = run(Paradigm::Ei, 0.5, 0.0);
        assert_eq!(m.cloud_decided, 0);
        assert_eq!(m.edge_decided, m.crops);
    }

    #[test]
    fn wan_delay_raises_ci_eil() {
        let fast = run(Paradigm::Ci, 0.5, 0.0);
        let slow = run(Paradigm::Ci, 0.5, 50.0);
        assert!(
            slow.eil_ms() > fast.eil_ms() + 40.0,
            "delay not reflected: {} vs {}",
            slow.eil_ms(),
            fast.eil_ms()
        );
    }

    #[test]
    fn load_increases_ci_eil_via_backlog() {
        let low = run(Paradigm::Ci, 0.5, 0.0);
        let high = run(Paradigm::Ci, 0.1, 0.0);
        assert!(
            high.eil_ms() > low.eil_ms() * 1.5,
            "no backlog effect: {} vs {}",
            high.eil_ms(),
            low.eil_ms()
        );
    }

    #[test]
    fn ace_ap_load_balances_under_pressure() {
        let bp = run(Paradigm::AceBp, 0.1, 0.0);
        let ap = run(Paradigm::AceAp, 0.1, 0.0);
        // AP routes some crops straight to COC when EOC queues build
        assert!(ap.crops > 0 && bp.crops > 0);
        // and its mean EIL should not be (much) worse than BP's
        assert!(ap.eil_ms() <= bp.eil_ms() * 1.6, "AP {} vs BP {}", ap.eil_ms(), bp.eil_ms());
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = run(Paradigm::AceBp, 0.3, 0.0);
        let b = run(Paradigm::AceBp, 0.3, 0.0);
        assert_eq!(a.crops, b.crops);
        assert_eq!(a.bwc_bytes, b.bwc_bytes);
        assert_eq!(a.f1, b.f1);
    }

    #[test]
    fn sweep_grid_order_and_parallel_equivalence() {
        let grid = fig5_grid(&[0.5], &[0.0, 50.0], 5.0, 3);
        assert_eq!(grid.len(), 8, "2 delays x 1 interval x 4 paradigms");
        assert_eq!(grid[0].wan_delay_ms, 0.0);
        assert_eq!(grid[4].wan_delay_ms, 50.0);
        assert_eq!(grid[0].paradigm, Paradigm::Ci);
        let mk = || (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 });
        let serial = run_sweep(grid.clone(), 1, mk).unwrap();
        let parallel = run_sweep(grid, 3, mk).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.paradigm, b.paradigm, "result order must be grid order");
            assert_eq!(a.crops, b.crops);
            assert_eq!(a.bwc_bytes, b.bwc_bytes);
            assert_eq!(a.f1, b.f1);
        }
    }

    #[test]
    fn custom_cell_shapes_place_and_run() {
        // generality: the orchestrated path works for non-paper shapes
        let cfg = CellConfig {
            paradigm: Paradigm::AceBp,
            interval_s: 0.5,
            duration_s: 6.0,
            num_ecs: 2,
            cams_per_ec: 2,
            ..Default::default()
        };
        let m = run_cell(cfg, ServiceTimes::synthetic(), Compute::Synthetic {
            target_bias: 0.05,
        })
        .unwrap();
        assert!(m.crops > 0);
        assert_eq!(m.edge_decided + m.cloud_decided, m.crops);
    }
}
