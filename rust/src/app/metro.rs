//! Metro-scale synthetic workload: N edge clusters of diurnal camera
//! load, escalating a fraction of frames to the cloud — the system
//! that exercises the conservative parallel DES end to end
//! (DESIGN.md §Parallel-DES).
//!
//! Each EC runs cameras (timer-driven, diurnal pacing), one
//! aggregator that escalates every k-th frame over the `cloud/#`
//! bridge, and one sink for the cloud's replies on `edge/ec<k>/#`.
//! The CC runs a stateless responder. Cross-cluster traffic rides the
//! WAN bridges ONLY, so a cluster-partitioned run has the WAN delay as
//! its lookahead and [`crate::des::par::run_partitioned`] can execute
//! the clusters on a worker pool without ever reordering an arrival.
//!
//! Partition mapping: the CC lands on partition 0 and EC `k` on
//! `k % partitions`; every shard builds the FULL `NetFabric` (unowned
//! links idle — each link is charged by exactly one shard, see
//! `svcgraph::ShardView`) and only its own clusters' components. The
//! metro network keeps the CC backplane free (`cc_lan_mbps: None`), so
//! a bridge absorbed on the CC shard reproduces the serial arrival
//! time exactly: application metrics are IDENTICAL for every partition
//! count, and window digests are identical for every thread count —
//! both pinned by `tests/par_des.rs`.

use crate::des::par::{self, Envelope, Partition, FNV_OFFSET};
use crate::json::Value;
use crate::simnet::{NetConfig, NetFabric, NicSpec};
use crate::svcgraph::{
    cidx, BridgeMsg, ClusterRef, Component, Ctx, GraphMsg, GraphRuntime, ShardCodec, Site,
};
use crate::util::prng;
use crate::util::{millis, secs, SimTime};
use crate::yamlite;
use anyhow::{anyhow, bail, Context, Result};
use std::any::Any;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// Relative frame periods over one diurnal cycle: the multiplier slots
/// a camera walks through (1 = rush hour, 4 = dead of night). Integer
/// pacing keeps every trajectory exact across partition/thread counts.
const DIURNAL: [u64; 8] = [1, 1, 2, 3, 4, 3, 2, 1];

/// The metro workload's knobs — plain `Clone + Send` data, so a config
/// can cross into the worker threads that build each shard.
#[derive(Debug, Clone, PartialEq)]
pub struct MetroConfig {
    /// Seeds camera base periods and phases.
    pub seed: u64,
    /// Edge clusters.
    pub ecs: usize,
    /// Camera nodes per EC.
    pub nodes_per_ec: usize,
    /// Cameras per node.
    pub cams_per_node: usize,
    /// Virtual runtime (seconds).
    pub duration_s: f64,
    /// Every k-th aggregated frame escalates to the cloud.
    pub escalate_every: u64,
    /// Rush-hour camera period floor (ms): each camera draws its base
    /// period uniformly from `[cam_period_ms, 2.5 * cam_period_ms)`,
    /// then the diurnal table stretches it. Lower = denser load (the
    /// bench row uses this to give each safe window real work).
    pub cam_period_ms: f64,
    /// Frame size on the wire (camera → aggregator, and the escalated
    /// crop on the uplink).
    pub frame_bytes: u64,
    /// One-way WAN delay (ms) — the partition lookahead.
    pub wan_delay_ms: f64,
    /// EC LAN segment bandwidth (Mbps).
    pub lan_mbps: f64,
    /// Per camera-node access link (Mbps); `<= 0` = unshaped.
    pub nic_mbps: f64,
    /// Length of one diurnal cycle (virtual seconds).
    pub diurnal_period_s: f64,
    /// Cluster partitions (clamped to `1..=ecs`); `ace` maps
    /// `--partitions 0` to the worker-pool default before calling in.
    pub partitions: usize,
    /// Worker threads driving the partitions (`<= 1` = the serial
    /// reference driver — same windows, same digests).
    pub threads: usize,
}

impl Default for MetroConfig {
    fn default() -> Self {
        MetroConfig {
            seed: 42,
            ecs: 4,
            nodes_per_ec: 4,
            cams_per_node: 2,
            duration_s: 30.0,
            escalate_every: 4,
            cam_period_ms: 40.0,
            frame_bytes: 20_000,
            wan_delay_ms: 20.0,
            lan_mbps: 1_000.0,
            nic_mbps: 100.0,
            diurnal_period_s: 10.0,
            partitions: 1,
            threads: 1,
        }
    }
}

impl MetroConfig {
    /// Named presets backing the generated `scenarios/metro_*.yaml`
    /// family (small = CI smoke, mid = bench row, large = headroom).
    pub fn preset(name: &str) -> Result<MetroConfig> {
        let base = MetroConfig::default();
        Ok(match name {
            "small" => MetroConfig { ecs: 4, nodes_per_ec: 2, duration_s: 10.0, ..base },
            "mid" => MetroConfig { ecs: 8, nodes_per_ec: 4, duration_s: 30.0, ..base },
            "large" => MetroConfig { ecs: 16, nodes_per_ec: 8, duration_s: 60.0, ..base },
            other => bail!("unknown metro preset '{other}' (small|mid|large)"),
        })
    }

    /// Parse an `app: metro` yamlite scenario. Absent keys fall back
    /// to the defaults; present keys must be numbers.
    pub fn from_yaml(src: &str) -> Result<MetroConfig> {
        let doc = yamlite::parse(src).map_err(|e| anyhow!("{e}"))?;
        Self::from_value(&doc)
    }

    /// Build a config from an already-parsed yamlite/JSON value.
    pub fn from_value(doc: &Value) -> Result<MetroConfig> {
        match doc.get("app").as_str() {
            Some("metro") => {}
            Some(other) => bail!("metro scenario: app is '{other}', expected 'metro'"),
            None => bail!("metro scenario: missing 'app: metro'"),
        }
        let mut cfg = MetroConfig::default();
        let num = |key: &str, into: &mut f64| -> Result<()> {
            match doc.get(key) {
                Value::Null => Ok(()),
                v => {
                    *into = v
                        .as_f64()
                        .with_context(|| format!("metro scenario: {key} must be a number"))?;
                    Ok(())
                }
            }
        };
        let uint = |key: &str, into: &mut u64| -> Result<()> {
            let mut f = *into as f64;
            num(key, &mut f)?;
            if f < 0.0 || f.fract() != 0.0 {
                bail!("metro scenario: {key} must be a non-negative integer, got {f}");
            }
            *into = f as u64;
            Ok(())
        };
        let mut v;
        uint("seed", &mut cfg.seed)?;
        v = cfg.ecs as u64;
        uint("ecs", &mut v)?;
        cfg.ecs = v as usize;
        v = cfg.nodes_per_ec as u64;
        uint("nodes_per_ec", &mut v)?;
        cfg.nodes_per_ec = v as usize;
        v = cfg.cams_per_node as u64;
        uint("cams_per_node", &mut v)?;
        cfg.cams_per_node = v as usize;
        num("duration_s", &mut cfg.duration_s)?;
        uint("escalate_every", &mut cfg.escalate_every)?;
        num("cam_period_ms", &mut cfg.cam_period_ms)?;
        uint("frame_bytes", &mut cfg.frame_bytes)?;
        num("wan_delay_ms", &mut cfg.wan_delay_ms)?;
        num("lan_mbps", &mut cfg.lan_mbps)?;
        num("nic_mbps", &mut cfg.nic_mbps)?;
        num("diurnal_period_s", &mut cfg.diurnal_period_s)?;
        v = cfg.partitions as u64;
        uint("partitions", &mut v)?;
        cfg.partitions = v as usize;
        v = cfg.threads as u64;
        uint("threads", &mut v)?;
        cfg.threads = v as usize;
        if cfg.ecs == 0 || cfg.nodes_per_ec == 0 || cfg.cams_per_node == 0 {
            bail!("metro scenario: ecs/nodes_per_ec/cams_per_node must be >= 1");
        }
        if cfg.escalate_every == 0 {
            bail!("metro scenario: escalate_every must be >= 1");
        }
        Ok(cfg)
    }

    /// Emit the scenario back as yamlite — `from_yaml(to_yaml(c)) == c`
    /// modulo the run-shape knobs (partitions/threads stay CLI-side).
    pub fn to_yaml(&self) -> String {
        let v = Value::obj(vec![
            ("app", Value::str("metro")),
            ("seed", Value::num(self.seed as f64)),
            ("ecs", Value::num(self.ecs as f64)),
            ("nodes_per_ec", Value::num(self.nodes_per_ec as f64)),
            ("cams_per_node", Value::num(self.cams_per_node as f64)),
            ("duration_s", Value::num(self.duration_s)),
            ("escalate_every", Value::num(self.escalate_every as f64)),
            ("cam_period_ms", Value::num(self.cam_period_ms)),
            ("frame_bytes", Value::num(self.frame_bytes as f64)),
            ("wan_delay_ms", Value::num(self.wan_delay_ms)),
            ("lan_mbps", Value::num(self.lan_mbps)),
            ("nic_mbps", Value::num(self.nic_mbps)),
            ("diurnal_period_s", Value::num(self.diurnal_period_s)),
        ]);
        format!(
            "# metro-scale workload (seeded topology: {} ECs x {} nodes x {} cams)\n\
             # generated by `ace metro-gen` — see app/metro.rs\n{}",
            self.ecs,
            self.nodes_per_ec,
            self.cams_per_node,
            yamlite::to_string(&v)
        )
    }

    /// Total camera count (generator shape).
    pub fn cams(&self) -> usize {
        self.ecs * self.nodes_per_ec * self.cams_per_node
    }
}

/// The simnet shape for a metro run. The CC backplane stays FREE
/// (`cc_lan_mbps: None`): the gateway hop is then the identity, so an
/// EC shard exporting a bridge copy (which defers the CC-side gateway
/// charge to absorb) lands at the exact serial arrival time — the
/// cross-partition-count exactness `tests/par_des.rs` pins.
fn netcfg(cfg: &MetroConfig) -> NetConfig {
    let mut nics = Vec::new();
    if cfg.nic_mbps > 0.0 && cfg.nic_mbps.is_finite() {
        for k in 0..cfg.ecs {
            for j in 0..cfg.nodes_per_ec {
                nics.push(NicSpec {
                    cluster: format!("ec-{}", k + 1),
                    node: format!("n{j}"),
                    mbps: cfg.nic_mbps,
                    delay_us: 200.0,
                });
            }
        }
    }
    NetConfig {
        num_ecs: cfg.ecs,
        lan_mbps: cfg.lan_mbps,
        uplink_mbps: 50.0,
        downlink_mbps: 100.0,
        wan_delay: millis(cfg.wan_delay_ms),
        lan_delay: 300,
        cc_lan_mbps: None,
        cc_lan_delay: 100,
        nics,
    }
}

/// Which partition owns cluster index `ci` (`cidx` convention: ECs
/// 0..ecs-1, CC at `ecs`): the CC pins to partition 0, ECs round-robin.
fn part_of(ci: usize, ecs: usize, parts: usize) -> usize {
    if ci == ecs {
        0
    } else {
        ci % parts
    }
}

/// Escalation request (EC → CC over `cloud/#`). Plain `Clone + Send`
/// data — the shard codec re-encodes it across thread boundaries.
#[derive(Clone)]
struct MetroReq {
    ec: usize,
    id: u64,
    t0: SimTime,
}

/// Cloud reply (CC → EC over `edge/ec<k>/#`).
#[derive(Clone)]
struct MetroRsp {
    ec: usize,
    id: u64,
    t0: SimTime,
}

/// Re-encode bridge payloads for a thread boundary. Frames (unit
/// bodies) never match a bridge rule, so only requests and replies
/// need to cross.
fn metro_codec() -> ShardCodec {
    Box::new(|body| {
        if let Some(r) = body.downcast_ref::<MetroReq>() {
            return Some(Box::new(r.clone()) as Box<dyn Any + Send>);
        }
        if let Some(r) = body.downcast_ref::<MetroRsp>() {
            return Some(Box::new(r.clone()) as Box<dyn Any + Send>);
        }
        None
    })
}

/// Per-shard counters, shared by the shard's components.
#[derive(Default)]
struct MetroStats {
    frames: u64,
    escalated: u64,
    replies: u64,
    latency_us_sum: u64,
    /// Order-sensitive reply fold (id × arrival time × EC).
    digest: u64,
}

/// A camera: publishes one frame per period to the EC-local
/// aggregator topic, with the period stretched by the diurnal table.
struct MetroCam {
    topic: String,
    frame_bytes: u64,
    /// Seeded per-camera rush-hour period (µs).
    base_period: SimTime,
    /// Seeded initial phase, decorrelating camera timers.
    phase: SimTime,
    /// One diurnal slot's length (µs).
    slot_len: SimTime,
    /// Cameras stop at `duration_s`, so the run drains: every
    /// in-flight escalation sees its reply inside the margin.
    stop: SimTime,
    stats: Rc<RefCell<MetroStats>>,
}

impl MetroCam {
    fn period_at(&self, now: SimTime) -> SimTime {
        let slot = (now / self.slot_len) as usize % DIURNAL.len();
        self.base_period.saturating_mul(DIURNAL[slot]).max(1)
    }
}

impl Component for MetroCam {
    fn subscriptions(&self) -> Vec<String> {
        Vec::new()
    }

    fn on_start(&mut self, ctx: &mut Ctx) {
        ctx.set_timer(self.phase, 0);
    }

    fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        if ctx.now() >= self.stop {
            return;
        }
        self.stats.borrow_mut().frames += 1;
        ctx.publish(&self.topic, self.frame_bytes, Rc::new(()));
        let next = self.period_at(ctx.now());
        ctx.set_timer(next, 0);
    }
}

/// Per-EC aggregator: consumes the cluster's frames, escalates every
/// k-th one to the cloud with a fresh request id.
struct MetroAgg {
    ec: usize,
    every: u64,
    seen: u64,
    next_id: u64,
    req_bytes: u64,
    topic_up: String,
    stats: Rc<RefCell<MetroStats>>,
}

impl Component for MetroAgg {
    fn subscriptions(&self) -> Vec<String> {
        vec![format!("metro/ec{}/agg", self.ec)]
    }

    fn on_message(&mut self, ctx: &mut Ctx, _msg: &GraphMsg) {
        self.seen += 1;
        if self.seen % self.every == 0 {
            let id = self.next_id;
            self.next_id += 1;
            self.stats.borrow_mut().escalated += 1;
            let req = MetroReq { ec: self.ec, id, t0: ctx.now() };
            ctx.publish(&self.topic_up, self.req_bytes, Rc::new(req));
        }
    }
}

/// The CC responder: stateless per request, one small reply back down
/// the requester's `edge/ec<k>/#` bridge.
struct MetroCloud {
    rsp_bytes: u64,
    /// Reply topics indexed by EC (no per-message formatting).
    rsp_topics: Vec<String>,
}

impl Component for MetroCloud {
    fn subscriptions(&self) -> Vec<String> {
        vec!["cloud/metro/req/#".to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        if let Some(req) = msg.body_as::<MetroReq>() {
            let rsp = MetroRsp { ec: req.ec, id: req.id, t0: req.t0 };
            let topic = &self.rsp_topics[req.ec];
            ctx.publish(topic, self.rsp_bytes, Rc::new(rsp));
        }
    }
}

/// Per-EC sink: counts replies and folds the order-sensitive digest.
struct MetroSink {
    ec: usize,
    stats: Rc<RefCell<MetroStats>>,
}

impl Component for MetroSink {
    fn subscriptions(&self) -> Vec<String> {
        vec![format!("edge/ec{}/metro/rsp", self.ec)]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        if let Some(rsp) = msg.body_as::<MetroRsp>() {
            let now = ctx.now();
            let mut s = self.stats.borrow_mut();
            s.replies += 1;
            s.latency_us_sum += now.saturating_sub(rsp.t0);
            s.digest = par::fnv_mix(s.digest, rsp.id ^ now ^ ((rsp.ec as u64) << 48));
        }
    }
}

/// `Send` blueprint a worker thread turns into a live shard.
struct MetroBlueprint {
    cfg: MetroConfig,
    part: usize,
    parts: usize,
}

/// One cluster-partition shard: an `Rc`-laden `GraphRuntime` built and
/// driven entirely inside its owning worker thread.
struct MetroShard {
    rt: GraphRuntime,
    stats: Rc<RefCell<MetroStats>>,
    look: SimTime,
    num_ecs: usize,
    parts: usize,
}

fn build_shard(b: MetroBlueprint) -> MetroShard {
    let cfg = &b.cfg;
    let ecs = cfg.ecs;
    let mut rt = GraphRuntime::new(NetFabric::new(&netcfg(cfg)));
    let owned: Vec<bool> = (0..=ecs).map(|ci| part_of(ci, ecs, b.parts) == b.part).collect();
    let stats = Rc::new(RefCell::new(MetroStats {
        digest: FNV_OFFSET,
        ..MetroStats::default()
    }));
    if owned[ecs] {
        rt.add(
            Site { cluster: ClusterRef::Cc, node: "srv".into() },
            Box::new(MetroCloud {
                rsp_bytes: 256,
                rsp_topics: (0..ecs).map(|k| format!("edge/ec{k}/metro/rsp")).collect(),
            }),
        );
    }
    let slot_len = (secs(cfg.diurnal_period_s) / DIURNAL.len() as u64).max(1);
    for (k, _) in owned.iter().enumerate().take(ecs).filter(|(_, o)| **o) {
        let hub = Site { cluster: ClusterRef::Ec(k), node: "n0".into() };
        rt.add(
            hub.clone(),
            Box::new(MetroAgg {
                ec: k,
                every: cfg.escalate_every,
                seen: 0,
                next_id: 0,
                req_bytes: cfg.frame_bytes,
                topic_up: format!("cloud/metro/req/ec{k}"),
                stats: stats.clone(),
            }),
        );
        rt.add(hub, Box::new(MetroSink { ec: k, stats: stats.clone() }));
        for j in 0..cfg.nodes_per_ec {
            for c in 0..cfg.cams_per_node {
                // the GLOBAL camera index seeds period/phase, so the
                // same camera paces identically whichever shard owns it
                let i = ((k * cfg.nodes_per_ec + j) * cfg.cams_per_node + c) as u64;
                let lo = millis(cfg.cam_period_ms).max(1);
                let base = lo + prng::u64_at(cfg.seed, i) % (lo * 3 / 2).max(1);
                let phase = prng::u64_at(cfg.seed ^ 0x9e37_79b9, i) % base;
                rt.add(
                    Site { cluster: ClusterRef::Ec(k), node: format!("n{j}").into() },
                    Box::new(MetroCam {
                        topic: format!("metro/ec{k}/agg"),
                        frame_bytes: cfg.frame_bytes,
                        base_period: base,
                        phase,
                        slot_len,
                        stop: secs(cfg.duration_s),
                        stats: stats.clone(),
                    }),
                );
            }
        }
    }
    rt.set_shard(owned, metro_codec());
    MetroShard {
        rt,
        stats,
        look: millis(cfg.wan_delay_ms) + 1,
        num_ecs: ecs,
        parts: b.parts,
    }
}

impl Partition for MetroShard {
    type Msg = BridgeMsg;

    fn peek(&mut self) -> Option<SimTime> {
        self.rt.peek_next()
    }

    fn lookahead(&self) -> SimTime {
        // the WAN leg is charged before export and ser_time floors
        // every charge at 1 µs, so arrivals land >= delay + 1 later
        self.look
    }

    fn run_window(&mut self, horizon: SimTime, out: &mut Vec<Envelope<BridgeMsg>>) {
        // run_until is inclusive; the window contract is `at < horizon`
        self.rt.run_until(horizon - 1);
        for bm in self.rt.take_shard_outbox() {
            let dst = part_of(cidx(bm.to, self.num_ecs), self.num_ecs, self.parts);
            out.push(Envelope { dst, at: bm.at, msg: bm });
        }
    }

    fn absorb(&mut self, at: SimTime, msg: BridgeMsg) {
        debug_assert_eq!(at, msg.at);
        self.rt.absorb_bridge(msg);
    }

    fn digest(&mut self) -> u64 {
        let s = self.stats.borrow();
        let mut h = FNV_OFFSET;
        for x in [
            s.frames,
            s.escalated,
            s.replies,
            s.latency_us_sum,
            s.digest,
            self.rt.executed(),
            self.rt.fabric().wan_bytes(),
        ] {
            h = par::fnv_mix(h, x);
        }
        h
    }
}

/// One shard's `Send` reduction, merged into [`MetroMetrics`].
struct ShardOut {
    frames: u64,
    escalated: u64,
    replies: u64,
    latency_us_sum: u64,
    executed: u64,
    wan_bytes: u64,
    bridged_up: u64,
    bridged_down: u64,
    digest: u64,
}

/// Whole-run results (application metrics + run-shape accounting).
#[derive(Debug, Clone)]
pub struct MetroMetrics {
    pub frames: u64,
    pub escalated: u64,
    pub replies: u64,
    /// Mean request→reply round trip (ms).
    pub mean_latency_ms: f64,
    /// Total DES events executed across all shards.
    pub events: u64,
    pub wan_bytes: u64,
    pub bridged_up: u64,
    pub bridged_down: u64,
    /// Conservative windows the run took.
    pub windows: u64,
    /// Partition-ordered digest fold after the LAST window (the
    /// serial-vs-parallel differential's final probe).
    pub digest: u64,
    pub virtual_secs: f64,
    pub wall_secs: f64,
    /// `events / wall_secs` — the number `benchkit::metro_scale` rows
    /// and the BENCH_*.json `metro_events_per_sec` gate compare.
    pub events_per_sec: f64,
    pub partitions: usize,
    pub threads: usize,
}

/// Run the metro workload under the conservative partitioned driver,
/// reporting every window's `(horizon, digest)` to `on_window`.
pub fn run_metro_with(
    cfg: &MetroConfig,
    mut on_window: impl FnMut(SimTime, u64),
) -> MetroMetrics {
    let parts = cfg.partitions.clamp(1, cfg.ecs.max(1));
    // margin past the last camera frame so in-flight escalations drain
    let until = secs(cfg.duration_s) + millis(cfg.wan_delay_ms).saturating_mul(4) + secs(1.0);
    let blueprints: Vec<MetroBlueprint> = (0..parts)
        .map(|part| MetroBlueprint { cfg: cfg.clone(), part, parts })
        .collect();
    let mut windows = 0u64;
    let mut digest = FNV_OFFSET;
    let t0 = Instant::now();
    let outs = par::run_partitioned(
        blueprints,
        cfg.threads.max(1),
        until,
        |_, b| build_shard(b),
        |_, shard: MetroShard| {
            let s = shard.stats.borrow();
            ShardOut {
                frames: s.frames,
                escalated: s.escalated,
                replies: s.replies,
                latency_us_sum: s.latency_us_sum,
                executed: shard.rt.executed(),
                wan_bytes: shard.rt.fabric().wan_bytes(),
                bridged_up: shard.rt.fabric().bridged_up,
                bridged_down: shard.rt.fabric().bridged_down,
                digest: s.digest,
            }
        },
        |h, d| {
            windows += 1;
            digest = d;
            on_window(h, d);
        },
    );
    let wall = t0.elapsed().as_secs_f64();
    let mut m = MetroMetrics {
        frames: 0,
        escalated: 0,
        replies: 0,
        mean_latency_ms: 0.0,
        events: 0,
        wan_bytes: 0,
        bridged_up: 0,
        bridged_down: 0,
        windows,
        digest,
        virtual_secs: until as f64 / 1e6,
        wall_secs: wall,
        events_per_sec: 0.0,
        partitions: parts,
        threads: cfg.threads.max(1),
    };
    let mut lat_sum = 0u64;
    for o in &outs {
        m.frames += o.frames;
        m.escalated += o.escalated;
        m.replies += o.replies;
        lat_sum += o.latency_us_sum;
        m.events += o.executed;
        m.wan_bytes += o.wan_bytes;
        m.bridged_up += o.bridged_up;
        m.bridged_down += o.bridged_down;
        // shard-count independent: fold per-shard reply digests only
        // for run_metro callers (the windowed fold covers the rest)
        m.digest = par::fnv_mix(m.digest, o.digest);
    }
    m.mean_latency_ms = lat_sum as f64 / m.replies.max(1) as f64 / 1e3;
    m.events_per_sec = m.events as f64 / wall.max(1e-9);
    m
}

/// [`run_metro_with`] without a window probe.
pub fn run_metro(cfg: &MetroConfig) -> MetroMetrics {
    run_metro_with(cfg, |_, _| {})
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MetroConfig {
        MetroConfig {
            ecs: 4,
            nodes_per_ec: 2,
            cams_per_node: 1,
            duration_s: 4.0,
            ..MetroConfig::default()
        }
    }

    #[test]
    fn metro_produces_end_to_end_traffic() {
        let m = run_metro(&tiny());
        assert!(m.frames > 0, "cameras must fire");
        assert!(m.escalated > 0, "aggregators must escalate");
        assert_eq!(m.replies, m.escalated, "every request drains to a reply");
        assert_eq!(m.bridged_up, m.escalated);
        assert_eq!(m.bridged_down, m.replies);
        assert!(m.mean_latency_ms >= 2.0 * 20.0, "round trip >= 2x WAN delay");
        assert!(m.windows > 0);
    }

    #[test]
    fn app_metrics_are_identical_across_partition_counts() {
        let base = run_metro(&tiny());
        for parts in [2, 3, 4] {
            let m = run_metro(&MetroConfig { partitions: parts, ..tiny() });
            assert_eq!(m.partitions, parts);
            assert_eq!(
                (m.frames, m.escalated, m.replies),
                (base.frames, base.escalated, base.replies),
                "{parts} partitions: counts diverged"
            );
            // exact up to same-microsecond tie reordering between a
            // local frame hop and a bridge arrival on one LAN segment
            assert!(
                (m.mean_latency_ms - base.mean_latency_ms).abs() < 0.5,
                "{parts} partitions: latency diverged ({} vs {})",
                m.mean_latency_ms,
                base.mean_latency_ms
            );
            assert_eq!(m.wan_bytes, base.wan_bytes);
        }
    }

    #[test]
    fn threaded_windows_match_the_serial_reference() {
        let cfg = MetroConfig { partitions: 4, ..tiny() };
        let mut w1 = Vec::new();
        let m1 = run_metro_with(&cfg, |h, d| w1.push((h, d)));
        for threads in [2, 4] {
            let mut wt = Vec::new();
            let mt = run_metro_with(&MetroConfig { threads, ..cfg.clone() }, |h, d| wt.push((h, d)));
            assert_eq!(w1, wt, "{threads} threads: window digests diverged");
            assert_eq!(m1.digest, mt.digest);
            assert_eq!(m1.replies, mt.replies);
        }
    }

    #[test]
    fn yaml_roundtrip_preserves_the_config() {
        let cfg = MetroConfig { seed: 7, ecs: 6, frame_bytes: 12_345, ..MetroConfig::default() };
        let parsed = MetroConfig::from_yaml(&cfg.to_yaml()).unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn presets_parse_and_scale() {
        let s = MetroConfig::preset("small").unwrap();
        let m = MetroConfig::preset("mid").unwrap();
        assert!(s.cams() < m.cams());
        assert!(MetroConfig::preset("bogus").is_err());
        let roundtrip = MetroConfig::from_yaml(&s.to_yaml()).unwrap();
        assert_eq!(roundtrip, s);
    }

    #[test]
    fn yaml_rejects_wrong_app_and_bad_numbers() {
        assert!(MetroConfig::from_yaml("app: videoquery\n").is_err());
        assert!(MetroConfig::from_yaml("ecs: 4\n").is_err());
        assert!(MetroConfig::from_yaml("app: metro\necs: nope\n").is_err());
        assert!(MetroConfig::from_yaml("app: metro\nescalate_every: 0\n").is_err());
    }
}
