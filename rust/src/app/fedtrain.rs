//! Federated training (§2's ECC *training* pattern) on the `svcgraph`
//! runtime — the second workload proving the runtime is generic.
//!
//! FedAvg over the ECs: a `coordinator` component on the CC broadcasts
//! the global model to a `trainer` on every EC (over the `edge/ec<k>/#`
//! bridge, charged on the downlinks), each trainer runs local SGD steps
//! on its private non-IID shard (virtual service time per step), and
//! uploads its update over the `cloud/#` bridge (charged on the
//! uplinks). The CC averages and starts the next round. BWC falls out
//! of the same simnet link counters the video-query app uses.
//!
//! The model is a tiny softmax regression trained natively (bit-exact
//! deterministic rust; no XLA needed), mirroring the math of the
//! `fl_train_step` HLO artifact exercised by
//! `examples/federated_training_sim.rs`.

use crate::deploy::Instance;
use crate::infra::{InfraBuilder, Infrastructure, NodeKind};
use crate::platform::orchestrator::{self, NetHints};
use crate::simnet::{NetConfig, NetFabric};
use crate::svcgraph::lifecycle::{
    ControlPlane, ControlPlaneConfig, InstanceFactory, LifecycleReport, LifecycleScenario,
    PlanHook,
};
use crate::svcgraph::{ClusterRef, Component, Ctx, GraphMsg, GraphRuntime, Site};
use crate::topology::Topology;
use crate::util::prng::Stream;
use crate::util::{millis, secs, to_secs};
use anyhow::Result;
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

/// Input dimensionality of the toy task (matches the FL artifact).
pub const DIM: usize = 16;

/// The Figure-4 topology of the federated-training app.
pub const FEDTRAIN_TOPOLOGY: &str = r#"
app: fedtrain
version: 1
components:
  - name: trainer
    image: ace/fl-trainer:1
    location: edge
    placement: per-ec
    resources:
      cpu: 2000
      mem: 1024
    connections: [coordinator]
  - name: coordinator
    image: ace/fl-coordinator:1
    location: cloud
    resources:
      cpu: 4000
      mem: 2048
    connections: []
"#;

#[derive(Debug, Clone)]
pub struct FedConfig {
    pub num_ecs: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub batch: usize,
    pub samples_per_ec: usize,
    pub lr: f32,
    /// One-way WAN delay in ms (0 ideal, 50 practical).
    pub wan_delay_ms: f64,
    pub seed: u64,
    /// Virtual service time of ONE local SGD step on a mini PC (ms).
    pub step_ms: f64,
    /// Lifecycle runs only: a round closes at this deadline with
    /// whoever reported (stragglers dropped), so trainer scale-downs /
    /// restarts mid-round never wedge the coordinator. Unused in plain
    /// runs (no deadline is armed).
    pub round_deadline_ms: f64,
    /// Scheduler event lanes (`--partitions`); the k-way merge keeps
    /// every trajectory byte-identical to `partitions = 1`.
    pub partitions: usize,
}

impl Default for FedConfig {
    fn default() -> Self {
        FedConfig {
            num_ecs: 3,
            rounds: 12,
            local_steps: 4,
            batch: 32,
            samples_per_ec: 256,
            lr: 0.3,
            wan_delay_ms: 0.0,
            seed: 42,
            step_ms: 2.0,
            round_deadline_ms: 2000.0,
            partitions: 1,
        }
    }
}

/// Softmax-regression model (2 classes over DIM features), the same
/// `w[j*2+c]` layout the FL artifact uses.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Model {
    pub fn zeros() -> Self {
        Model { w: vec![0.0; DIM * 2], b: vec![0.0; 2] }
    }

    /// Serialized size on the wire (weights + biases + framing).
    pub fn wire_bytes() -> u64 {
        ((DIM * 2 + 2) * 4 + 16) as u64
    }
}

/// One SGD step of softmax cross-entropy on a batch; returns the mean
/// loss. Native mirror of the `fl_train_step` artifact's math.
pub fn train_step(m: &mut Model, xs: &[f32], ys: &[i32], lr: f32) -> f32 {
    let bsz = ys.len();
    debug_assert_eq!(xs.len(), bsz * DIM);
    let mut gw = vec![0.0f32; DIM * 2];
    let mut gb = [0.0f32; 2];
    let mut loss = 0.0f32;
    for i in 0..bsz {
        let row = &xs[i * DIM..(i + 1) * DIM];
        let mut logits = [m.b[0], m.b[1]];
        for (j, v) in row.iter().enumerate() {
            logits[0] += v * m.w[j * 2];
            logits[1] += v * m.w[j * 2 + 1];
        }
        let mx = logits[0].max(logits[1]);
        let e0 = (logits[0] - mx).exp();
        let e1 = (logits[1] - mx).exp();
        let z = e0 + e1;
        let p = [e0 / z, e1 / z];
        let y = ys[i] as usize;
        loss += -(p[y].max(1e-12)).ln();
        for c in 0..2 {
            let d = p[c] - if c == y { 1.0 } else { 0.0 };
            gb[c] += d;
            for (j, v) in row.iter().enumerate() {
                gw[j * 2 + c] += v * d;
            }
        }
    }
    let scale = lr / bsz as f32;
    for (w, g) in m.w.iter_mut().zip(&gw) {
        *w -= scale * g;
    }
    for (b, g) in m.b.iter_mut().zip(&gb) {
        *b -= scale * g;
    }
    loss / bsz as f32
}

/// Synthetic non-IID binary task: y = sign(w*.x); EC k only sees
/// examples whose first feature falls in its band (same generator as
/// `examples/federated_training_sim.rs`).
pub fn make_shard(ec: usize, num_ecs: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut s = Stream::new(seed + ec as u64 * 1000);
    let mut x = Vec::with_capacity(n * DIM);
    let mut y = Vec::with_capacity(n);
    let mut kept = 0;
    while kept < n {
        let mut row = [0f32; DIM];
        for v in row.iter_mut() {
            *v = s.next_f32() * 2.0 - 1.0;
        }
        // non-IID band per EC on feature 0
        let band = (row[0] + 1.0) / 2.0 * num_ecs as f32;
        if band as usize % num_ecs != ec {
            continue;
        }
        // true concept: mix of features 0..3
        let score = row[0] * 1.5 - row[1] + 0.5 * row[2] + 0.25 * row[3];
        x.extend_from_slice(&row);
        y.push(if score > 0.0 { 1 } else { 0 });
        kept += 1;
    }
    (x, y)
}

pub fn accuracy(m: &Model, x: &[f32], y: &[i32]) -> f64 {
    let n = y.len();
    let mut correct = 0;
    for i in 0..n {
        let row = &x[i * DIM..(i + 1) * DIM];
        let mut logits = [m.b[0], m.b[1]];
        for (j, v) in row.iter().enumerate() {
            logits[0] += v * m.w[j * 2];
            logits[1] += v * m.w[j * 2 + 1];
        }
        let pred = if logits[1] > logits[0] { 1 } else { 0 };
        if pred == y[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// One completed FedAvg round.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    /// Round index (0-based).
    pub round: usize,
    /// Global-model accuracy on the cross-band test set after the
    /// round's average.
    pub accuracy: f64,
    /// Mean final local loss across the updates averaged this round.
    pub mean_loss: f32,
    /// Updates averaged — the live trainer count the round closed with
    /// (lifecycle runs scale this up and down mid-training).
    pub trainers: usize,
}

#[derive(Debug, Clone)]
pub struct FedMetrics {
    pub rounds: Vec<RoundRecord>,
    pub final_accuracy: f64,
    /// What each EC achieves alone with the same step budget.
    pub client_only_acc: Vec<f64>,
    /// WAN bytes (up + down) — read off the simnet link counters.
    pub wan_bytes: u64,
    pub bridged_up: u64,
    pub bridged_down: u64,
    pub virtual_secs: f64,
}

// ---------------------------------------------------------------------------
// Message bodies + topics
// ---------------------------------------------------------------------------

const UPDATE_TOPIC: &str = "cloud/fl/update";

fn model_topic(seg: &str) -> String {
    format!("edge/{seg}/fl/model")
}

struct ModelBody {
    round: usize,
    model: Model,
}

struct UpdateBody {
    ec: usize,
    round: usize,
    model: Model,
    loss: f32,
}

// ---------------------------------------------------------------------------
// Shared state + components
// ---------------------------------------------------------------------------

struct FedState {
    cfg: FedConfig,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
    rounds: RefCell<Vec<RoundRecord>>,
    /// Model after the last completed round (for post-run inspection).
    final_model: RefCell<Model>,
    /// Trainer count the platform currently intends (plan-driven; the
    /// lifecycle control plane updates it through its plan hook).
    expected_trainers: Cell<usize>,
    /// True under the lifecycle control plane: arms round deadlines so
    /// mid-round scaling cannot wedge the coordinator.
    lifecycle: bool,
}

type Shared = Rc<FedState>;

/// Per-EC trainer: local SGD on the private shard, charging virtual
/// service time per step before uploading the update.
struct Trainer {
    shared: Shared,
    ec: usize,
    in_topic: String,
    shard_x: Vec<f32>,
    shard_y: Vec<i32>,
    pending: Option<ModelBody>,
    /// Last round whose model this trainer accepted — dedupes the
    /// coordinator's recovery re-broadcasts (lifecycle runs).
    last_round: Option<usize>,
}

impl Component for Trainer {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.in_topic.clone()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(mb) = msg.body_as::<ModelBody>() else {
            return;
        };
        if self.last_round == Some(mb.round) {
            return; // recovery re-broadcast of a round already accepted
        }
        self.last_round = Some(mb.round);
        self.pending = Some(ModelBody { round: mb.round, model: mb.model.clone() });
        let cfg = &self.shared.cfg;
        // the timer token carries the round, so a stale timer from a
        // deadline-closed round cannot consume the NEXT round's model
        // early (which would undercharge its training time)
        ctx.set_timer(secs(cfg.local_steps as f64 * cfg.step_ms / 1e3), mb.round as u64);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if self.pending.as_ref().map(|p| p.round as u64) != Some(token) {
            return; // stale timer: this round was superseded mid-training
        }
        let Some(ModelBody { round, mut model }) = self.pending.take() else {
            return;
        };
        let cfg = &self.shared.cfg;
        let nb = self.shard_x.len() / (cfg.batch * DIM);
        let mut loss = 0.0;
        for s in 0..cfg.local_steps {
            let bi = (round * cfg.local_steps + s) % nb;
            let xs = &self.shard_x[bi * cfg.batch * DIM..(bi + 1) * cfg.batch * DIM];
            let ys = &self.shard_y[bi * cfg.batch..(bi + 1) * cfg.batch];
            loss = train_step(&mut model, xs, ys, cfg.lr);
        }
        // update rides the cloud/# bridge over this EC's uplink
        ctx.publish(
            UPDATE_TOPIC,
            Model::wire_bytes(),
            Rc::new(UpdateBody { ec: self.ec, round, model, loss }),
        );
    }
}

/// CC coordinator: broadcast → collect → FedAvg → next round.
struct Coordinator {
    shared: Shared,
    model: Model,
    round: usize,
    received: Vec<UpdateBody>,
}

impl Coordinator {
    fn broadcast(&self, ctx: &mut Ctx) {
        for k in 0..self.shared.cfg.num_ecs {
            ctx.publish(
                &model_topic(&ClusterRef::Ec(k).seg()),
                Model::wire_bytes(),
                Rc::new(ModelBody { round: self.round, model: self.model.clone() }),
            );
        }
    }

    /// Updates a round waits for: the platform's live trainer count
    /// (equal to `num_ecs` in plain runs; plan-driven under the
    /// lifecycle control plane).
    fn expected(&self) -> usize {
        self.shared.expected_trainers.get().max(1)
    }

    /// Lifecycle runs only: a timer token carrying the round number,
    /// so a deadline firing after the round already closed is ignored.
    fn arm_deadline(&self, ctx: &mut Ctx) {
        if self.shared.lifecycle {
            ctx.set_timer(millis(self.shared.cfg.round_deadline_ms), self.round as u64);
        }
    }

    /// FedAvg over whatever arrived, record the round, start the next.
    fn finalize_round(&mut self, ctx: &mut Ctx) {
        let n = self.received.len();
        if n == 0 {
            return;
        }
        let mut avg = Model::zeros();
        let mut loss_sum = 0.0f32;
        for upd in self.received.drain(..) {
            for (a, v) in avg.w.iter_mut().zip(&upd.model.w) {
                *a += v / n as f32;
            }
            for (a, v) in avg.b.iter_mut().zip(&upd.model.b) {
                *a += v / n as f32;
            }
            loss_sum += upd.loss;
        }
        self.model = avg;
        let acc = accuracy(&self.model, &self.shared.test_x, &self.shared.test_y);
        self.shared.rounds.borrow_mut().push(RoundRecord {
            round: self.round,
            accuracy: acc,
            mean_loss: loss_sum / n as f32,
            trainers: n,
        });
        *self.shared.final_model.borrow_mut() = self.model.clone();
        self.round += 1;
        if self.round < self.shared.cfg.rounds {
            self.broadcast(ctx);
            self.arm_deadline(ctx);
        }
    }
}

impl Component for Coordinator {
    fn subscriptions(&self) -> Vec<String> {
        vec![UPDATE_TOPIC.to_string()]
    }

    fn on_start(&mut self, ctx: &mut Ctx) {
        self.broadcast(ctx);
        self.arm_deadline(ctx);
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(u) = msg.body_as::<UpdateBody>() else {
            return;
        };
        if u.round != self.round {
            return; // stale update from an earlier round
        }
        self.received.push(UpdateBody {
            ec: u.ec,
            round: u.round,
            model: u.model.clone(),
            loss: u.loss,
        });
        if self.received.len() >= self.expected() {
            self.finalize_round(ctx);
        }
    }

    /// Round deadline (armed only in lifecycle runs): close the round
    /// on whoever reported, or — if NOBODY did, e.g. every trainer was
    /// replaced since the broadcast — re-broadcast the current model
    /// to the live trainer set and re-arm.
    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token != self.round as u64 {
            return; // deadline of an already-closed round
        }
        if self.received.is_empty() {
            self.broadcast(ctx);
            self.arm_deadline(ctx);
        } else {
            self.finalize_round(ctx);
        }
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// `cc_nodes` grows the CC beyond the single workstation (scenario
/// `network: cc_nodes` — same knob as videoquery's cell).
fn fed_infra(cfg: &FedConfig, cc_nodes: usize) -> Infrastructure {
    let mut b = InfraBuilder::register("fed");
    for _ in 0..cfg.num_ecs {
        let ec = b.claim_ec();
        b.add_edge_node(&ec, "minipc", NodeKind::MiniPc, BTreeMap::new());
    }
    b.add_cloud_node("gpu-ws", NodeKind::GpuWorkstation, BTreeMap::new());
    for s in 1..cc_nodes.max(1) {
        b.add_cloud_node(&format!("srv{s}"), NodeKind::CloudServer, BTreeMap::new());
    }
    b.build()
}

fn validate(cfg: &FedConfig) -> Result<()> {
    anyhow::ensure!(cfg.num_ecs >= 1, "fedtrain needs at least one EC");
    anyhow::ensure!(
        cfg.batch > 0 && cfg.samples_per_ec >= cfg.batch,
        "samples_per_ec ({}) must cover at least one batch ({})",
        cfg.samples_per_ec,
        cfg.batch
    );
    Ok(())
}

/// Cross-band global test set (same recipe as the example).
fn make_test_set(cfg: &FedConfig) -> (Vec<f32>, Vec<i32>) {
    let mut test_x = Vec::new();
    let mut test_y = Vec::new();
    for ec in 0..cfg.num_ecs {
        let (x, y) = make_shard(ec, cfg.num_ecs, 128, 777);
        test_x.extend(x);
        test_y.extend(y);
    }
    (test_x, test_y)
}

/// Build the component for one placed instance — shared by the static
/// deploy and the lifecycle control plane's factory, so a scaled-up
/// trainer is built exactly like an initial one. Trainers co-located
/// on one EC share that EC's data shard.
fn fed_component_for(
    shared: &Shared,
    inst: &Instance,
    site: &Site,
) -> Result<Option<Box<dyn Component>>> {
    let cfg = &shared.cfg;
    Ok(match inst.component.as_str() {
        "trainer" => {
            let ec = match site.cluster {
                ClusterRef::Ec(k) => k,
                ClusterRef::Cc => anyhow::bail!("trainer placed on the CC"),
            };
            let (shard_x, shard_y) = make_shard(ec, cfg.num_ecs, cfg.samples_per_ec, cfg.seed);
            Some(Box::new(Trainer {
                shared: shared.clone(),
                ec,
                in_topic: model_topic(&site.cluster.seg()),
                shard_x,
                shard_y,
                pending: None,
                last_round: None,
            }) as Box<dyn Component>)
        }
        "coordinator" => Some(Box::new(Coordinator {
            shared: shared.clone(),
            model: Model::zeros(),
            round: 0,
            received: Vec::new(),
        })),
        _ => None,
    })
}

/// TRUE client-only baselines: same step budget, own shard only, never
/// federated — what each EC could do without the CC.
fn client_only_baselines(cfg: &FedConfig, test_x: &[f32], test_y: &[i32]) -> Vec<f64> {
    let mut client_only_acc = Vec::new();
    for ec in 0..cfg.num_ecs {
        let (x, y) = make_shard(ec, cfg.num_ecs, cfg.samples_per_ec, cfg.seed);
        let nb = x.len() / (cfg.batch * DIM);
        let mut m = Model::zeros();
        for step_i in 0..cfg.rounds * cfg.local_steps {
            let bi = step_i % nb;
            let xs = &x[bi * cfg.batch * DIM..(bi + 1) * cfg.batch * DIM];
            let ys = &y[bi * cfg.batch..(bi + 1) * cfg.batch];
            train_step(&mut m, xs, ys, cfg.lr);
        }
        client_only_acc.push(accuracy(&m, test_x, test_y));
    }
    client_only_acc
}

fn collect_metrics(cfg: &FedConfig, shared: &Shared, rt: &GraphRuntime) -> FedMetrics {
    let client_only_acc = client_only_baselines(cfg, &shared.test_x, &shared.test_y);
    let rounds = shared.rounds.borrow().clone();
    // re-derive from the stored model: must agree with the last round
    let final_accuracy = if rounds.is_empty() {
        0.0
    } else {
        accuracy(&shared.final_model.borrow(), &shared.test_x, &shared.test_y)
    };
    FedMetrics {
        rounds,
        final_accuracy,
        client_only_acc,
        wan_bytes: rt.net().wan_bytes(),
        bridged_up: rt.fabric().bridged_up,
        bridged_down: rt.fabric().bridged_down,
        virtual_secs: to_secs(rt.now()),
    }
}

/// Run the federated-training app end-to-end on the svcgraph runtime:
/// topology → orchestrator placement → components → bridged transport.
pub fn run_fedtrain(cfg: FedConfig) -> Result<FedMetrics> {
    validate(&cfg)?;
    let infra = fed_infra(&cfg, 1);
    let topo = Topology::parse(FEDTRAIN_TOPOLOGY)?;
    let plan = orchestrator::place(&topo, &infra)?;

    let net = NetFabric::new(&NetConfig {
        num_ecs: cfg.num_ecs,
        wan_delay: millis(cfg.wan_delay_ms),
        ..Default::default()
    });
    let mut rt = GraphRuntime::with_lanes(net, cfg.partitions.max(1));

    let (test_x, test_y) = make_test_set(&cfg);
    let shared: Shared = Rc::new(FedState {
        test_x,
        test_y,
        rounds: RefCell::new(Vec::new()),
        final_model: RefCell::new(Model::zeros()),
        expected_trainers: Cell::new(plan.instances_of("trainer").len()),
        lifecycle: false,
        cfg: cfg.clone(),
    });

    rt.deploy(&plan, |inst, site| fed_component_for(&shared, inst, site))?;

    rt.run(10_000_000);

    Ok(collect_metrics(&cfg, &shared, &rt))
}

/// Run federated training under the VIRTUAL-TIME control plane
/// (DESIGN.md §Control-plane): the scenario deploys/updates the
/// fedtrain topology mid-run, scaling trainers up and down while
/// rounds are in flight. The coordinator learns the live trainer count
/// through the control plane's plan hook and closes each round on
/// whoever reports within the round deadline, so scale-downs and
/// instance restarts never wedge a round.
#[deprecated(
    since = "0.1.0",
    note = "use svcgraph::scenario::run / run_with — the unified dispatcher for all apps"
)]
pub fn run_fedtrain_scenario(
    cfg: FedConfig,
    scenario: &LifecycleScenario,
) -> Result<(FedMetrics, LifecycleReport)> {
    validate(&cfg)?;
    // the scenario's `network:` block reshapes the fabric (per-node
    // NICs, link shaping) and may grow the CC into a real cluster
    let mut netcfg = NetConfig {
        num_ecs: cfg.num_ecs,
        wan_delay: millis(cfg.wan_delay_ms),
        ..Default::default()
    };
    let mut cc_nodes = 1;
    if let Some(ov) = &scenario.network {
        cc_nodes = ov.apply_with_cc(&mut netcfg, cc_nodes);
    }
    let infra = fed_infra(&cfg, cc_nodes);
    let mut net = NetFabric::new(&netcfg);
    // chaos knobs arm BEFORE any traffic (loss/dup of 0 draws nothing,
    // keeping fault-free runs byte-identical)
    if let Some(spec) = &scenario.faults {
        net.arm_faults(*spec);
    }
    let hints = NetHints::from_net(&net);
    let mut rt = GraphRuntime::with_lanes(net, cfg.partitions.max(1));
    let (test_x, test_y) = make_test_set(&cfg);
    let shared: Shared = Rc::new(FedState {
        test_x,
        test_y,
        rounds: RefCell::new(Vec::new()),
        final_model: RefCell::new(Model::zeros()),
        expected_trainers: Cell::new(0),
        lifecycle: true,
        cfg: cfg.clone(),
    });
    let factory: InstanceFactory = {
        let shared = shared.clone();
        Rc::new(move |inst, site| fed_component_for(&shared, inst, site))
    };
    // platform intent → coordinator expectation (trainer count)
    let hook: PlanHook = {
        let shared = shared.clone();
        Rc::new(move |_app, plan| {
            shared
                .expected_trainers
                .set(plan.instances_of("trainer").len());
        })
    };
    let plane = ControlPlane::install(
        &mut rt,
        infra,
        factory,
        Some(hook),
        scenario,
        ControlPlaneConfig::default(),
        hints,
    )?;
    rt.run_until(scenario.duration);
    let mut report = plane.report();
    report.msgs_lost = rt.net().msgs_lost();
    Ok((collect_metrics(&cfg, &shared, &rt), report))
}

/// Run `base` once per seed on a pool of `workers` threads, results in
/// `seeds` order. Each run is an independent DES world (the usual
/// multi-seed robustness sweep), so this is the same
/// max-of-cells-not-sum wall-clock win the Figure-5 sweep gets from
/// `sweep::parallel_map`.
pub fn run_fedtrain_seeds(
    base: &FedConfig,
    seeds: &[u64],
    workers: usize,
) -> Result<Vec<FedMetrics>> {
    let cfgs: Vec<FedConfig> = seeds
        .iter()
        .map(|&seed| FedConfig { seed, ..base.clone() })
        .collect();
    crate::sweep::parallel_map(cfgs, workers, run_fedtrain)
        .into_iter()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> FedConfig {
        FedConfig::default()
    }

    #[test]
    fn topology_places_one_trainer_per_ec() {
        let cfg = quick();
        let topo = Topology::parse(FEDTRAIN_TOPOLOGY).unwrap();
        let plan = orchestrator::place(&topo, &fed_infra(&cfg, 1)).unwrap();
        assert_eq!(plan.instances_of("trainer").len(), cfg.num_ecs);
        assert_eq!(plan.instances_of("coordinator").len(), 1);
    }

    #[test]
    fn federation_beats_client_only_mean() {
        let m = run_fedtrain(quick()).unwrap();
        assert_eq!(m.rounds.len(), 12, "all rounds must complete");
        let mean_client =
            m.client_only_acc.iter().sum::<f64>() / m.client_only_acc.len() as f64;
        assert!(
            m.final_accuracy > mean_client,
            "federated {:.3} failed to beat client-only mean {:.3}",
            m.final_accuracy,
            mean_client
        );
        assert!(m.final_accuracy > 0.7, "final acc {:.3}", m.final_accuracy);
    }

    #[test]
    fn training_traffic_rides_the_wan_links() {
        let cfg = quick();
        let m = run_fedtrain(cfg.clone()).unwrap();
        // every round: num_ecs model broadcasts down + num_ecs updates up
        let per_round = cfg.num_ecs as u64;
        assert_eq!(m.bridged_down, per_round * cfg.rounds as u64);
        assert_eq!(m.bridged_up, per_round * cfg.rounds as u64);
        assert_eq!(
            m.wan_bytes,
            2 * per_round * cfg.rounds as u64 * Model::wire_bytes(),
            "BWC must equal the bridged model traffic"
        );
        assert!(m.virtual_secs > 0.0);
    }

    #[test]
    fn wan_delay_stretches_wall_clock_but_not_learning() {
        let fast = run_fedtrain(quick()).unwrap();
        let mut slow_cfg = quick();
        slow_cfg.wan_delay_ms = 50.0;
        let slow = run_fedtrain(slow_cfg).unwrap();
        assert!(slow.virtual_secs > fast.virtual_secs + 0.9,
            "50 ms RTTs over 12 rounds must cost > 1.2 virtual secs: {} vs {}",
            slow.virtual_secs, fast.virtual_secs);
        assert!((slow.final_accuracy - fast.final_accuracy).abs() < 1e-12,
            "delay must not change the math");
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = run_fedtrain(quick()).unwrap();
        let b = run_fedtrain(quick()).unwrap();
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.wan_bytes, b.wan_bytes);
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits());
        }
    }

    #[test]
    fn parallel_seeds_match_serial_runs() {
        let base = FedConfig { rounds: 4, ..Default::default() };
        let seeds = [42u64, 43, 44];
        let parallel = run_fedtrain_seeds(&base, &seeds, 3).unwrap();
        assert_eq!(parallel.len(), 3);
        for (i, &seed) in seeds.iter().enumerate() {
            let serial = run_fedtrain(FedConfig { seed, ..base.clone() }).unwrap();
            assert_eq!(
                serial.final_accuracy.to_bits(),
                parallel[i].final_accuracy.to_bits(),
                "seed {seed} diverged between serial and parallel"
            );
            assert_eq!(serial.wan_bytes, parallel[i].wan_bytes);
        }
        // different shards ⇒ the sweep actually varies by seed
        assert!(
            seeds.len() > 1
                && (parallel[0].final_accuracy != parallel[1].final_accuracy
                    || parallel[0].rounds[0].mean_loss != parallel[1].rounds[0].mean_loss),
            "seeds produced identical trajectories"
        );
    }

    #[test]
    fn degenerate_configs_error_cleanly() {
        // batch larger than the shard used to hit a modulo-by-zero in
        // the trainer; now it is a validation error
        let err = run_fedtrain(FedConfig { samples_per_ec: 16, batch: 32, ..Default::default() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch"), "{err}");
        assert!(run_fedtrain(FedConfig { num_ecs: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn loss_decreases_over_rounds() {
        let m = run_fedtrain(quick()).unwrap();
        let first = m.rounds.first().unwrap().mean_loss;
        let last = m.rounds.last().unwrap().mean_loss;
        assert!(last < first, "loss did not drop: {first} -> {last}");
    }
}
