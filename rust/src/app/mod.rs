//! Application layer — concrete ECCI applications built on the
//! platform. `videoquery` is the paper's §5 evaluation application.

pub mod videoquery;

pub use videoquery::{run_cell, CellConfig, Compute, InferCache, Paradigm, ServiceTimes};
