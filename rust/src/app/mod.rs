//! Application layer — concrete ECCI applications built on the
//! generic `svcgraph` runtime. `videoquery` is the paper's §5
//! evaluation application; `fedtrain` is the §2 training pattern,
//! proving the runtime generalizes beyond one workload; `metro` is
//! the metro-scale synthetic load driving the conservative parallel
//! DES (DESIGN.md §Parallel-DES).

pub mod fedtrain;
pub mod metro;
pub mod videoquery;

pub use fedtrain::{run_fedtrain, run_fedtrain_seeds, FedConfig, FedMetrics};
pub use metro::{run_metro, run_metro_with, MetroConfig, MetroMetrics};
pub use videoquery::{
    fig5_grid, run_cell, run_sweep, CellConfig, Compute, InferCache, Paradigm, ServiceTimes,
};
