//! Application layer — concrete ECCI applications built on the
//! generic `svcgraph` runtime. `videoquery` is the paper's §5
//! evaluation application; `fedtrain` is the §2 training pattern,
//! proving the runtime generalizes beyond one workload.

pub mod fedtrain;
pub mod videoquery;

pub use fedtrain::{run_fedtrain, run_fedtrain_seeds, FedConfig, FedMetrics};
pub use videoquery::{
    fig5_grid, run_cell, run_sweep, CellConfig, Compute, InferCache, Paradigm, ServiceTimes,
};
