//! Application layer — concrete ECCI applications built on the
//! generic `svcgraph` runtime. `videoquery` is the paper's §5
//! evaluation application; `fedtrain` is the §2 training pattern,
//! proving the runtime generalizes beyond one workload.

pub mod fedtrain;
pub mod videoquery;

pub use fedtrain::{run_fedtrain, FedConfig, FedMetrics};
pub use videoquery::{run_cell, CellConfig, Compute, InferCache, Paradigm, ServiceTimes};
