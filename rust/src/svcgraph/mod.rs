//! Generic service-graph runtime: execute an orchestrated application
//! end-to-end inside the DES.
//!
//! This is the layer that makes ACE's core claim (§4, Figures 2/4)
//! operational in the simulation: applications are *component graphs*
//! the platform places, deploys, and wires user-transparently.
//!
//! ```text
//! Topology ──► Orchestrator ──► DeploymentPlan
//!                                    │  deploy(plan, factory)
//!                                    ▼
//!                     Component instances (one per placed Instance)
//!                                    │  publish/subscribe on the
//!                                    ▼  LOCAL cluster bus only
//!      per-EC bus ◄──── bridges ────► CC bus        (§4.3.2, Fig. 2 ②)
//!                                    │
//!                                    ▼
//!            simnet links (LAN / WAN up / WAN down) charge virtual
//!            time + bytes ──► BWC falls out of the transport layer
//! ```
//!
//! Components implement [`Component`]: they receive `on_message` /
//! `on_timer` callbacks under virtual time and talk to the world only
//! through [`Ctx`] (publish to the local bus, set timers). Routing
//! charges HOP BY HOP on the [`NetFabric`] link graph (every node may
//! have its own access link in front of its cluster's shared LAN —
//! the CC included, since PR 5 a real multi-node cluster):
//!
//!   * same node            → delivered instantly (in-process hand-off);
//!   * same cluster, other node → src NIC → cluster LAN → dst NIC;
//!   * `cloud/#` from an EC → src NIC, then bridged to the CC bus over
//!     that EC's WAN uplink (serialization + delay + jitter, FIFO
//!     queueing), then the CC backbone LAN (the border router sits on
//!     it; free when the CC LAN is unmodelled); CC-side fan-out pays
//!     each receiver's NIC;
//!   * `edge/ec<k>/#` from the CC → src NIC, then the CC backbone LAN
//!     out to the border router, then EC k's downlink, then each
//!     receiver's NIC.
//!
//! The sender's NIC is paid AT MOST ONCE per publish (the single
//! transmit up to the cluster message service); receivers and bridges
//! fan out from that egress time.
//!
//! In the degenerate configuration (no NICs, free single-node CC) all
//! NIC legs are free and this is exactly the pre-PR-5 flat model —
//! every golden trajectory replays byte-for-byte.
//!
//! Byte counters on the links ARE the paper's BWC metric — applications
//! no longer hand-compute bandwidth, they just send messages.
//!
//! Hot path (DESIGN.md §Event-engine): every steady-state step —
//! publish, route, deliver, timer — is a typed [`Event`] stored BY
//! VALUE in the scheduler's calendar queue, topics are interned once
//! into an `Rc<str>` PLUS a dense symbol sequence (`Rc<[Sym]>`) that
//! the topic tries match on — integer compares, no string walks — and
//! `route` reuses scratch buffers, so publish→deliver performs zero
//! heap allocations (enforced by `tests/zero_alloc.rs`).
//!
//! Lifecycle (DESIGN.md §Control-plane): component graphs are no longer
//! frozen at deploy time. [`SvcWorld::spawn`] / [`SvcWorld::retire`]
//! add and remove components MID-RUN — a retired component id is never
//! reused, its subscriptions are unindexed from the topic trie, and
//! in-flight events addressed to it are dropped on delivery, so
//! components untouched by a lifecycle op keep their exact `(at, seq)`
//! event trajectory. The [`lifecycle`] module drives this from scripted
//! scenarios through a virtual-time control plane (controller → node
//! agents → monitor, Figure 4 steps ②→④).

pub mod lifecycle;
pub mod scenario;

use crate::deploy::{DeploymentPlan, Instance};
use crate::des::{Scheduler, SimEvent};
use crate::pubsub::topic::{Sym, SymbolTable, TopicTrie};
use crate::simnet::faults::Verdict;
use crate::simnet::NetFabric;
use crate::util::SimTime;
use anyhow::{anyhow, bail, Result};
use std::any::Any;
use std::collections::HashMap;
use std::rc::Rc;

/// Which per-cluster message service an instance is bound to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterRef {
    Ec(usize),
    Cc,
}

impl ClusterRef {
    /// Topic segment naming this cluster (`ec0`, `ec1`, ... / `cc`).
    pub fn seg(self) -> String {
        match self {
            ClusterRef::Ec(k) => format!("ec{k}"),
            ClusterRef::Cc => "cc".to_string(),
        }
    }
}

/// Where a component instance runs: its cluster + node (leaf name).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Site {
    /// Which cluster bus the instance is bound to.
    pub cluster: ClusterRef,
    /// Node leaf name within the cluster (e.g. `rpi1`).
    pub node: Rc<str>,
}

/// Derive a site from a hierarchical node id
/// (`infra-x/ec-N/node` → EC N-1; `infra-x/cc/node` → CC).
pub fn site_of_node(node: &crate::util::AceId) -> Result<Site> {
    let cluster_id = node
        .parent()
        .ok_or_else(|| anyhow!("node id '{node}' too shallow"))?;
    let leaf = cluster_id.leaf().to_string();
    // the shared `ec-N`/`cc` leaf convention (simnet::parse_ec_leaf)
    let cluster = if leaf == "cc" {
        ClusterRef::Cc
    } else if let Some(n) = crate::simnet::parse_ec_leaf(&leaf) {
        ClusterRef::Ec(n - 1)
    } else {
        bail!("node '{node}': unknown cluster '{leaf}'");
    };
    Ok(Site { cluster, node: node.leaf().into() })
}

/// Derive a site from a placed instance's node id (see
/// [`site_of_node`]).
pub fn site_of(inst: &Instance) -> Result<Site> {
    site_of_node(&inst.node).map_err(|e| anyhow!("instance '{}': {e}", inst.id))
}

/// A message travelling the service graph.
#[derive(Clone)]
pub struct GraphMsg {
    /// Interned topic name.
    pub topic: Rc<str>,
    /// The topic's interned level symbols (same interning event as
    /// `topic`); what the routing tries match against — cloning a
    /// message is two refcount bumps, never a string walk.
    pub syms: Rc<[Sym]>,
    /// Component index of the sender (see [`GraphRuntime::deploy`]).
    pub from: usize,
    /// Bytes charged to simnet links when this message crosses nodes.
    pub wire_bytes: u64,
    /// In-memory payload; receivers downcast to the concrete type.
    pub body: Rc<dyn Any>,
}

impl GraphMsg {
    pub fn body_as<T: 'static>(&self) -> Option<&T> {
        self.body.downcast_ref::<T>()
    }
}

/// An application component instance executing under the DES.
///
/// Mirrors §4.4's programming model: the platform binds the instance to
/// its node's local message service; the component never addresses
/// peers directly, only topics.
pub trait Component {
    /// Topic filters this component consumes from its LOCAL cluster bus.
    fn subscriptions(&self) -> Vec<String>;

    /// Called once at t=0 when the deployment comes up.
    fn on_start(&mut self, _ctx: &mut Ctx) {}

    /// A subscribed message arrived (after transport charging).
    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg);

    /// A timer set through [`Ctx::set_timer`] fired.
    fn on_timer(&mut self, _ctx: &mut Ctx, _token: u64) {}
}

/// Dense bus index of a cluster: ECs `0..num_ecs-1`, then the CC.
/// The same index orders the per-cluster subscription tries, the
/// scheduler lanes, and a shard view's `owned` flags.
pub fn cidx(c: ClusterRef, num_ecs: usize) -> usize {
    match c {
        ClusterRef::Ec(k) => k,
        ClusterRef::Cc => num_ecs,
    }
}

/// Encodes a payload for a thread boundary: a `Send` re-encoding of
/// the concrete body (typically a clone of the app's payload struct),
/// or `None` when the type is not meant to cross shards.
pub type ShardCodec = Box<dyn Fn(&Rc<dyn Any>) -> Option<Box<dyn Any + Send>>>;

/// A message crossing a shard boundary under the conservative parallel
/// driver (DESIGN.md §Parallel-DES). Everything here is `Send`: the
/// payload was re-encoded by the shard's [`ShardCodec`], and the topic
/// travels as a plain string to be re-interned into the destination
/// shard's own symbol table (each shard keeps its own interner and
/// routing scratch — nothing `Rc`-shaped leaks across threads).
pub struct BridgeMsg {
    /// Cluster the message first entered (loop prevention).
    pub origin: ClusterRef,
    /// Destination cluster — owned by the receiving shard.
    pub to: ClusterRef,
    /// Topic name (re-interned on absorb).
    pub topic: String,
    /// Bytes charged to the links this message still has to cross.
    pub wire_bytes: u64,
    /// Delivery time at the shard boundary: the WAN leg is already
    /// charged by the exporting shard, so `at >= export_now + WAN
    /// delay` — the lookahead the conservative horizon relies on.
    pub at: SimTime,
    /// Re-encoded payload.
    pub body: Box<dyn Any + Send>,
}

/// Shard view of a fabric: which clusters THIS runtime owns, plus the
/// outbox bridge copies bound for un-owned clusters leave through.
/// Every simnet link is charged by exactly one shard — each EC shard
/// owns its uplink, the CC shard owns the backbone LAN and every
/// downlink — so link state never diverges between shards.
struct ShardView {
    /// Indexed like `cidx`: ECs 0..num_ecs-1, then the CC.
    owned: Vec<bool>,
    codec: ShardCodec,
    outbox: Vec<BridgeMsg>,
}

/// The transport fabric: per-cluster subscription tables, bridge rules,
/// and the simnet links that charge virtual time and count BWC bytes.
pub struct Fabric {
    /// The simulated link graph (per-node NICs + per-cluster LAN
    /// segments + WAN pairs to the CC).
    pub net: NetFabric,
    num_ecs: usize,
    /// Per cluster bus: ECs 0..num_ecs-1, then the CC at index num_ecs.
    /// Topic-trie index of component subscriptions (value = component
    /// index): one publish routes in O(topic depth), not O(subs).
    subs: Vec<TopicTrie<usize>>,
    /// Per FROM-cluster index of bridge rules (value = destination
    /// cluster), so bridge matching is trie-indexed too.
    bridge_subs: Vec<TopicTrie<ClusterRef>>,
    sites: Vec<Site>,
    /// Each component's access-link slot in its cluster's NIC slab,
    /// parallel to `sites` ([`crate::simnet::NO_NIC`] when the node has
    /// no modelled NIC). Resolved once at bind time so the per-message
    /// hot path charges links by dense index, never by name lookup.
    /// Slots are append-only; [`Fabric::refresh_nic_slots`] re-resolves
    /// after an admin op creates a NIC mid-run.
    nic_slots: Vec<u32>,
    /// Per-component subscription filters, parallel to `sites` — kept
    /// so [`SvcWorld::retire`] can unindex exactly the retired
    /// component's trie entries (cleared on retirement).
    sub_filters: Vec<Vec<String>>,
    /// ONE level-symbol table for the whole fabric: every subscription
    /// trie (per-cluster AND bridge rules) and every cached topic draw
    /// from the same dense vocabulary, so a symbol sequence interned at
    /// publish time is valid against any trie.
    table: SymbolTable,
    /// Interned published topics → their level-symbol sequences:
    /// steady-state publishes of a known topic reuse one `Rc<str>` and
    /// one `Rc<[Sym]>` (refcount bumps) instead of allocating a fresh
    /// topic string — or re-walking it — per message. Bounded by the
    /// number of distinct topics the application publishes.
    topics: HashMap<Rc<str>, Rc<[Sym]>>,
    /// Reusable match scratch for `route` (DESIGN.md §Event-engine:
    /// the publish path performs zero steady-state allocations).
    target_scratch: Vec<(u64, usize)>,
    bridge_scratch: Vec<(u64, ClusterRef)>,
    /// Messages forwarded over the EC→CC / CC→EC bridges.
    pub bridged_up: u64,
    pub bridged_down: u64,
    /// `Some` when this fabric is one shard of a partitioned run.
    shard: Option<ShardView>,
}

impl Fabric {
    /// One `(Rc<str>, Rc<[Sym]>)` pair per distinct published topic.
    /// Levels are INTERNED (never just probed) so a cached symbol
    /// sequence can never go stale: the same level maps to the same
    /// symbol however many subscriptions arrive later.
    fn intern(&mut self, topic: &str) -> (Rc<str>, Rc<[Sym]>) {
        if let Some((t, s)) = self.topics.get_key_value(topic) {
            return (t.clone(), s.clone());
        }
        let t: Rc<str> = topic.into();
        let syms: Vec<Sym> = topic.split('/').map(|l| self.table.intern(l)).collect();
        let s: Rc<[Sym]> = syms.into();
        self.topics.insert(t.clone(), s.clone());
        (t, s)
    }

    /// Route `msg` on `cluster`'s bus: deliver to local subscribers
    /// (charging the LAN when the hop crosses nodes) and forward over
    /// matching bridges (charging the WAN links). `from_site` is the
    /// sender's site for a locally published message, or `None` when
    /// the message just arrived over a bridge. `origin` is the cluster
    /// the message FIRST entered (loop prevention, like the threaded
    /// `pubsub::Bridge`).
    fn route(
        &mut self,
        sch: &mut SvcScheduler,
        origin: ClusterRef,
        cluster: ClusterRef,
        from_site: Option<&Site>,
        msg: &GraphMsg,
    ) {
        let now = sch.now();
        let ci = cidx(cluster, self.num_ecs);
        // A locally published message pays its sender's access link AT
        // MOST ONCE — the single physical transmit up to the cluster
        // message service — however many receivers/bridges fan out
        // from the bus. Charged lazily on the first hop that actually
        // leaves the node (same-node-only publishes never touch it);
        // bridge re-entries (`from_site == None`) have no modelled
        // src. In the degenerate config this is `now` either way.
        let mut src_at: Option<SimTime> = None;
        // trie walk fills the reused scratch in subscription-insertion
        // order — the exact order the old linear scan delivered in,
        // which the DES scheduler's insertion-sequence tie-breaking
        // turns into an identical event trajectory. The buffers are
        // swapped out of `self` so the loop bodies can charge links
        // through `&mut self` (and a re-entrant route could not alias
        // them); they go back afterwards, keeping their capacity.
        // `from_site == Some` only on the publish path, where
        // `msg.from` is the live publishing component — its cached NIC
        // slot is the sender's access link (no name lookup)
        let src_slot = if from_site.is_some() { self.nic_slots[msg.from] } else { crate::simnet::NO_NIC };
        let mut targets = std::mem::take(&mut self.target_scratch);
        self.subs[ci].collect_matches_into_syms(&msg.syms, &mut targets);
        for &(_, target) in &targets {
            let arrival = match from_site {
                // bridge arrivals fan out from the cluster message
                // service: only the receiver's access link is charged,
                // and no fault verdict is consulted — the bridged copy
                // already survived (or didn't) its WAN link's process
                None => self.net.ingress_slot(ci, self.nic_slots[target], now, msg.wire_bytes),
                Some(f) => {
                    if self.sites[target].node == f.node {
                        now // node-internal hand-off: never faulted
                    } else {
                        // hop-by-hop: src NIC (once) → LAN → dst NIC
                        // (free legs are exactly the flat model)
                        let at = match src_at {
                            Some(t) => t,
                            None => {
                                let t = self.net.egress_slot(ci, src_slot, now, msg.wire_bytes);
                                src_at = Some(t);
                                t
                            }
                        };
                        let d = self.net.lan_hop_slot(
                            ci,
                            self.nic_slots[target],
                            at,
                            msg.wire_bytes,
                        );
                        // per-delivery fault verdict on the cluster
                        // segment (the link charged either way: a lost
                        // frame still occupied the medium)
                        match self.net.lan_verdict(ci, at) {
                            Verdict::Drop => continue,
                            Verdict::Duplicate => {
                                sch.push_at_lane(ci, d, Event::Msg { target, msg: msg.clone() });
                            }
                            Verdict::Deliver => {}
                        }
                        d
                    }
                }
            };
            // typed by-value event: Rc refcount bumps, no Box. Lane =
            // the target's cluster — deliveries never leave the bus
            // they were routed on (merged lanes pop in identical
            // global (at, seq) order; sharded runs own one lane each)
            sch.push_at_lane(ci, arrival, Event::Msg { target, msg: msg.clone() });
        }
        self.target_scratch = targets;
        // bridge rules are indexed per FROM-cluster, so only this
        // cluster's rules are even considered
        let mut rules = std::mem::take(&mut self.bridge_scratch);
        self.bridge_subs[ci].collect_matches_into_syms(&msg.syms, &mut rules);
        for &(_, to) in &rules {
            if to == origin {
                continue; // loop prevention, like the threaded Bridge
            }
            let at = match (src_at, from_site) {
                (Some(t), _) => t,
                (None, Some(_)) => {
                    let t = self.net.egress_slot(ci, src_slot, now, msg.wire_bytes);
                    src_at = Some(t);
                    t
                }
                (None, None) => now,
            };
            // A shard exports bridge copies bound for clusters it does
            // not own instead of scheduling them locally; it still
            // charges (and rules on) exactly the links it owns.
            let foreign = self
                .shard
                .as_ref()
                .is_some_and(|s| !s.owned[cidx(to, self.num_ecs)]);
            let (arrival, verdict) = match (cluster, to) {
                (ClusterRef::Ec(k), ClusterRef::Cc) => {
                    self.bridged_up += 1;
                    // WAN, then the CC backbone LAN: the border router
                    // sits on the CC's segment, so bridged traffic
                    // crosses it to reach the CC message service (free
                    // when the CC LAN is unmodelled — the degenerate
                    // config is unchanged). Under sharding the backbone
                    // LAN belongs to the CC shard: the importer charges
                    // it at absorb time instead.
                    let t = self.net.wan_up(k, at, msg.wire_bytes);
                    let v = self.net.up_verdict(k, at);
                    let t = if foreign { t } else { self.net.gateway_hop(t, msg.wire_bytes) };
                    (t, v)
                }
                (ClusterRef::Cc, ClusterRef::Ec(k)) => {
                    self.bridged_down += 1;
                    // CC backbone LAN out to the border router first,
                    // then the downlink — both CC-owned, so the export
                    // time is the final delivery time
                    let t = self.net.gateway_hop(at, msg.wire_bytes);
                    (self.net.wan_down(k, t, msg.wire_bytes), self.net.down_verdict(k, at))
                }
                // EC↔EC bridges have no modelled WAN link: the egress
                // leg (already paid) is the whole cost, and there is no
                // named link to carry a fault process. Zero WAN delay
                // means zero lookahead — a shard boundary must never
                // cut one (DESIGN.md §Parallel-DES).
                _ => {
                    assert!(!foreign, "EC–EC bridge rule crosses a shard boundary");
                    (at, Verdict::Deliver)
                }
            };
            if foreign {
                let copies = match verdict {
                    Verdict::Drop => 0,
                    Verdict::Deliver => 1,
                    Verdict::Duplicate => 2,
                };
                let shard = self.shard.as_mut().expect("foreign implies a shard view");
                for _ in 0..copies {
                    let body = (shard.codec)(&msg.body).unwrap_or_else(|| {
                        panic!("shard codec cannot encode payload on '{}'", msg.topic)
                    });
                    shard.outbox.push(BridgeMsg {
                        origin,
                        to,
                        topic: msg.topic.to_string(),
                        wire_bytes: msg.wire_bytes,
                        at: arrival,
                        body,
                    });
                }
                continue;
            }
            match verdict {
                Verdict::Drop => continue,
                Verdict::Duplicate => {
                    let lane = cidx(to, self.num_ecs);
                    sch.push_at_lane(lane, arrival, Event::Bridge { origin, to, msg: msg.clone() });
                }
                Verdict::Deliver => {}
            }
            let lane = cidx(to, self.num_ecs);
            sch.push_at_lane(lane, arrival, Event::Bridge { origin, to, msg: msg.clone() });
        }
        self.bridge_scratch = rules;
    }

    /// Restrict this fabric to the clusters marked `true` in `owned`
    /// (indexed like the busses: ECs `0..num_ecs-1`, then the CC).
    /// From here on, bridge copies bound for un-owned clusters are
    /// re-encoded through `codec` and collected in the shard outbox
    /// ([`Fabric::take_shard_outbox`]) instead of being scheduled.
    pub fn set_shard(&mut self, owned: Vec<bool>, codec: ShardCodec) {
        assert_eq!(owned.len(), self.num_ecs + 1, "one owned flag per cluster");
        self.shard = Some(ShardView { owned, codec, outbox: Vec::new() });
    }

    /// Drain the bridge copies that left this shard since the last
    /// call (export order — deterministic, route-generation order).
    pub fn take_shard_outbox(&mut self) -> Vec<BridgeMsg> {
        match &mut self.shard {
            Some(s) => std::mem::take(&mut s.outbox),
            None => Vec::new(),
        }
    }

    /// Absorb a bridge message exported by another shard: charge the
    /// legs THIS shard owns (the CC backbone LAN on the EC→CC path —
    /// deferred by the exporter), re-intern the topic into this
    /// shard's own table, and schedule the bridge re-entry.
    pub fn absorb_bridge(&mut self, sch: &mut SvcScheduler, bm: BridgeMsg) {
        let arrival = match bm.to {
            ClusterRef::Cc => self.net.gateway_hop(bm.at, bm.wire_bytes),
            ClusterRef::Ec(_) => bm.at,
        };
        let (topic, syms) = self.intern(&bm.topic);
        let body: Box<dyn Any> = bm.body;
        let msg = GraphMsg {
            topic,
            syms,
            from: usize::MAX,
            wire_bytes: bm.wire_bytes,
            body: Rc::from(body),
        };
        let lane = cidx(bm.to, self.num_ecs);
        sch.push_at_lane(lane, arrival, Event::Bridge { origin: bm.origin, to: bm.to, msg });
    }

    /// Re-resolve every component's cached access-link slot. Slots are
    /// append-only, so this is only needed after an admin op CREATES a
    /// NIC mid-run (`degrade_nic` on a previously unshaped node).
    pub fn refresh_nic_slots(&mut self) {
        for (i, site) in self.sites.iter().enumerate() {
            self.nic_slots[i] = self.net.nic_slot(cidx(site.cluster, self.num_ecs), &site.node);
        }
    }

    /// Bytes bridged across the WAN so far (both directions) — reads
    /// straight off the simnet link counters.
    pub fn wan_bytes(&self) -> u64 {
        self.net.wan_bytes()
    }
}

/// The closure lane's payload (rare setup events; see [`Event::Call`]).
pub type SvcCall = Box<dyn FnOnce(&mut SvcScheduler, &mut SvcWorld)>;

/// The svcgraph scheduler: typed events, stored by value in the heap.
pub type SvcScheduler = Scheduler<SvcWorld, Event>;

/// Typed DES event (DESIGN.md §Event-engine). The steady-state
/// variants (`Msg`, `Timer`, `Bridge`) carry their payload by value —
/// scheduling one is a heap push plus `Rc` refcount bumps, never a
/// `Box` allocation. `Call` is the boxed closure lane for rare setup
/// work (validation-testbed channel phases).
pub enum Event {
    /// Deliver `on_start` to a component.
    Start { target: usize },
    /// Deliver a routed message to a component.
    Msg { target: usize, msg: GraphMsg },
    /// Deliver `on_timer(token)` to a component.
    Timer { target: usize, token: u64 },
    /// A message crossing a bridge re-enters `Fabric::route` at `to`.
    Bridge { origin: ClusterRef, to: ClusterRef, msg: GraphMsg },
    /// Boxed closure lane (setup / testbed phases only).
    Call(SvcCall),
}

impl SimEvent<SvcWorld> for Event {
    fn fire(self, sch: &mut SvcScheduler, w: &mut SvcWorld) {
        match self {
            Event::Start { target } => {
                SvcWorld::with_component(sch, w, target, |c, ctx| c.on_start(ctx));
            }
            Event::Msg { target, msg } => {
                SvcWorld::with_component(sch, w, target, |c, ctx| c.on_message(ctx, &msg));
            }
            Event::Timer { target, token } => {
                SvcWorld::with_component(sch, w, target, |c, ctx| c.on_timer(ctx, token));
            }
            Event::Bridge { origin, to, msg } => w.fabric.route(sch, origin, to, None, &msg),
            Event::Call(f) => f(sch, w),
        }
    }
}

/// DES world: the deployed components plus the transport fabric.
pub struct SvcWorld {
    comps: Vec<Option<Box<dyn Component>>>,
    pub fabric: Fabric,
}

impl SvcWorld {
    /// Bind one component at `site` WITHOUT scheduling its `on_start`:
    /// registers its subscriptions on the site's cluster bus and
    /// returns the component index. Setup-time path —
    /// [`GraphRuntime::add`]/[`GraphRuntime::deploy`] use it and
    /// `on_start` fires when the runtime starts; mid-run callers want
    /// [`SvcWorld::spawn`] instead.
    pub fn bind(&mut self, site: Site, comp: Box<dyn Component>) -> usize {
        let idx = self.comps.len();
        let ci = cidx(site.cluster, self.fabric.num_ecs);
        let filters = comp.subscriptions();
        let (subs, table) = (&mut self.fabric.subs, &mut self.fabric.table);
        for filter in &filters {
            subs[ci].insert(table, filter, idx);
        }
        self.fabric.sub_filters.push(filters);
        // resolve the node's access-link slot once; `route` charges by
        // dense index from here on
        self.fabric.nic_slots.push(self.fabric.net.nic_slot(ci, &site.node));
        self.fabric.sites.push(site);
        self.comps.push(Some(comp));
        idx
    }

    /// Add a component to a RUNNING graph: bind it and deliver its
    /// `on_start` at the current virtual time (Figure 4 step ④, an
    /// agent bringing an instance up mid-run). New subscriptions get
    /// fresh (higher) trie insertion sequences, so existing
    /// subscribers' delivery order — and therefore their `(at, seq)`
    /// trajectories — are untouched.
    pub fn spawn(&mut self, sch: &mut SvcScheduler, site: Site, comp: Box<dyn Component>) -> usize {
        let lane = cidx(site.cluster, self.fabric.num_ecs);
        let idx = self.bind(site, comp);
        sch.push_at_lane(lane, sch.now(), Event::Start { target: idx });
        idx
    }

    /// Remove a live component: its subscriptions are unindexed from
    /// the topic trie (targeted path removals) and its id is RETIRED —
    /// never reused, so in-flight events addressed to it are dropped on
    /// delivery instead of reaching a stranger. Untouched components
    /// keep their trie insertion sequences, hence their exact delivery
    /// order. Returns false if `idx` was never bound or already
    /// retired.
    pub fn retire(&mut self, idx: usize) -> bool {
        if self.comps.get(idx).is_none_or(|c| c.is_none()) {
            return false;
        }
        self.comps[idx] = None;
        let ci = cidx(self.fabric.sites[idx].cluster, self.fabric.num_ecs);
        let filters = std::mem::take(&mut self.fabric.sub_filters[idx]);
        let (subs, table) = (&mut self.fabric.subs, &self.fabric.table);
        for filter in &filters {
            subs[ci].remove(table, filter, |&v| v == idx);
        }
        true
    }

    /// Is component `idx` bound and not retired?
    pub fn is_live(&self, idx: usize) -> bool {
        self.comps.get(idx).is_some_and(|c| c.is_some())
    }

    /// Number of live (non-retired) components.
    pub fn live_count(&self) -> usize {
        self.comps.iter().filter(|c| c.is_some()).count()
    }

    /// The site a component was bound at (also for retired ids).
    pub fn component_site(&self, idx: usize) -> Option<&Site> {
        self.fabric.sites.get(idx)
    }

    /// Run one component callback with a `Ctx` over the world. The
    /// component is taken out for the duration so the callback can
    /// borrow the rest of the world mutably.
    fn with_component(
        sch: &mut SvcScheduler,
        w: &mut SvcWorld,
        idx: usize,
        f: impl FnOnce(&mut dyn Component, &mut Ctx),
    ) {
        let Some(mut c) = w.comps[idx].take() else {
            return;
        };
        {
            let mut ctx = Ctx { sch, fabric: &mut w.fabric, self_idx: idx };
            f(&mut *c, &mut ctx);
        }
        w.comps[idx] = Some(c);
    }
}

/// The component's handle onto the world during a callback.
pub struct Ctx<'a> {
    sch: &'a mut SvcScheduler,
    fabric: &'a mut Fabric,
    self_idx: usize,
}

impl Ctx<'_> {
    /// Current virtual time (µs).
    pub fn now(&self) -> SimTime {
        self.sch.now()
    }

    /// This component's placement site.
    pub fn site(&self) -> &Site {
        &self.fabric.sites[self.self_idx]
    }

    /// Publish to this component's LOCAL cluster message service;
    /// transport (LAN / bridged WAN) is charged by the fabric. The
    /// topic is interned (no per-publish string allocation) and every
    /// resulting delivery is a typed by-value event.
    pub fn publish(&mut self, topic: &str, wire_bytes: u64, body: Rc<dyn Any>) {
        let (topic, syms) = self.fabric.intern(topic);
        let site = self.fabric.sites[self.self_idx].clone();
        let msg = GraphMsg { topic, syms, from: self.self_idx, wire_bytes, body };
        self.fabric
            .route(self.sch, site.cluster, site.cluster, Some(&site), &msg);
    }

    /// Fire `on_timer(token)` on this component after `delay` µs.
    pub fn set_timer(&mut self, delay: SimTime, token: u64) {
        let lane = cidx(self.fabric.sites[self.self_idx].cluster, self.fabric.num_ecs);
        self.sch
            .push_after_lane(lane, delay, Event::Timer { target: self.self_idx, token });
    }

    /// Schedule a raw closure over the whole world after `delay` µs —
    /// the boxed [`Event::Call`] lane. This is the lifecycle escape
    /// hatch: a component (e.g. a node agent applying a deployment
    /// instruction) cannot mutate the component table from inside its
    /// own callback, so it defers the [`SvcWorld::spawn`] /
    /// [`SvcWorld::retire`] to a `Call` event at the same virtual time
    /// (later sequence). Rare ops only; not for per-message hot paths.
    pub fn call(
        &mut self,
        delay: SimTime,
        f: impl FnOnce(&mut SvcScheduler, &mut SvcWorld) + 'static,
    ) {
        self.sch.push_after(delay, Event::Call(Box::new(f)));
    }

    /// Read-only view of the network (for introspection/policies).
    pub fn net(&self) -> &NetFabric {
        &self.fabric.net
    }
}

/// Executes a deployed component graph under the DES.
pub struct GraphRuntime {
    world: SvcWorld,
    sch: SvcScheduler,
    started: bool,
}

impl GraphRuntime {
    /// A runtime over `net` (per-node NICs + one LAN segment per
    /// cluster + WAN pairs to the CC), with the standard bridge rules
    /// of §4.3.2: `cloud/#` EC→CC and `edge/ec<k>/#` CC→EC k.
    pub fn new(net: NetFabric) -> Self {
        Self::with_lanes(net, 1)
    }

    /// Like [`GraphRuntime::new`] but with `lanes` per-cluster event
    /// lanes in the scheduler. Events are laned by destination cluster
    /// (`cidx` modulo the lane count); the sequential k-way merge pops
    /// in global `(at, seq)` order, so every trajectory is
    /// byte-identical whatever the lane count — this is what lets the
    /// lifecycle goldens replay exactly under `--partitions 2/4`.
    pub fn with_lanes(net: NetFabric, lanes: usize) -> Self {
        let num_ecs = net.num_ecs();
        let mut table = SymbolTable::new();
        let mut bridge_subs: Vec<TopicTrie<ClusterRef>> =
            (0..=num_ecs).map(|_| TopicTrie::new()).collect();
        for k in 0..num_ecs {
            bridge_subs[cidx(ClusterRef::Ec(k), num_ecs)].insert(
                &mut table,
                "cloud/#",
                ClusterRef::Cc,
            );
            bridge_subs[cidx(ClusterRef::Cc, num_ecs)].insert(
                &mut table,
                &format!("edge/ec{k}/#"),
                ClusterRef::Ec(k),
            );
        }
        GraphRuntime {
            world: SvcWorld {
                comps: Vec::new(),
                fabric: Fabric {
                    net,
                    num_ecs,
                    subs: (0..=num_ecs).map(|_| TopicTrie::new()).collect(),
                    bridge_subs,
                    sites: Vec::new(),
                    sub_filters: Vec::new(),
                    table,
                    topics: HashMap::new(),
                    nic_slots: Vec::new(),
                    target_scratch: Vec::new(),
                    bridge_scratch: Vec::new(),
                    bridged_up: 0,
                    bridged_down: 0,
                    shard: None,
                },
            },
            sch: Scheduler::with_lanes(lanes),
            started: false,
        }
    }

    /// Bind one component at `site`; registers its subscriptions on the
    /// site's cluster bus. Returns the component index. Setup-time
    /// path (`on_start` fires when the runtime starts); for mid-run
    /// additions use [`SvcWorld::spawn`] from a [`Event::Call`]
    /// closure.
    pub fn add(&mut self, site: Site, comp: Box<dyn Component>) -> usize {
        self.world.bind(site, comp)
    }

    /// Retire a live component mid-run (see [`SvcWorld::retire`]).
    pub fn remove(&mut self, idx: usize) -> bool {
        self.world.retire(idx)
    }

    /// Instantiate every placed instance of `plan` through `factory`
    /// (Figure 4 step ②: plan → per-node components). The factory may
    /// return `None` for instances the experiment does not model.
    /// Pre-sizes the event heap from the plan's instance count (each
    /// instance keeps a bounded handful of events in flight — timers
    /// plus fan-out deliveries), so steady state never regrows it.
    /// Returns the number of components deployed.
    pub fn deploy<F>(&mut self, plan: &DeploymentPlan, mut factory: F) -> Result<usize>
    where
        F: FnMut(&Instance, &Site) -> Result<Option<Box<dyn Component>>>,
    {
        self.sch.reserve_events(plan.instances.len() * 8 + 64);
        let mut n = 0;
        for inst in &plan.instances {
            let site = site_of(inst)?;
            if let Some(c) = factory(inst, &site)? {
                self.add(site, c);
                n += 1;
            }
        }
        Ok(n)
    }

    /// Schedule a raw closure event (testbed channel phases etc.) —
    /// the boxed [`Event::Call`] lane; fine for setup, not for the
    /// per-message hot path.
    pub fn at(
        &mut self,
        at: SimTime,
        ev: impl FnOnce(&mut SvcScheduler, &mut SvcWorld) + 'static,
    ) {
        self.sch.push_at(at, Event::Call(Box::new(ev)));
    }

    fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for idx in 0..self.world.comps.len() {
            let lane = cidx(self.world.fabric.sites[idx].cluster, self.world.fabric.num_ecs);
            self.sch.push_at_lane(lane, 0, Event::Start { target: idx });
        }
    }

    /// Deliver `on_start` to every component, then run to exhaustion
    /// under the event-count safety valve. Returns events executed.
    pub fn run(&mut self, max_events: u64) -> u64 {
        self.start();
        self.sch.run(&mut self.world, max_events)
    }

    /// Run until virtual time `until` (starting components first).
    pub fn run_until(&mut self, until: SimTime) -> u64 {
        self.start();
        self.sch.run_until(&mut self.world, until)
    }

    /// Current virtual time (µs).
    pub fn now(&self) -> SimTime {
        self.sch.now()
    }

    /// Earliest pending event time, starting components first — the
    /// conservative driver's per-partition `peek`.
    pub fn peek_next(&mut self) -> Option<SimTime> {
        self.start();
        self.sch.peek_next()
    }

    /// Turn this runtime into one shard of a partitioned run (see
    /// [`Fabric::set_shard`]).
    pub fn set_shard(&mut self, owned: Vec<bool>, codec: ShardCodec) {
        self.world.fabric.set_shard(owned, codec);
    }

    /// Drain bridge messages exported since the last call.
    pub fn take_shard_outbox(&mut self) -> Vec<BridgeMsg> {
        self.world.fabric.take_shard_outbox()
    }

    /// Absorb a bridge message exported by another shard (see
    /// [`Fabric::absorb_bridge`]).
    pub fn absorb_bridge(&mut self, bm: BridgeMsg) {
        self.world.fabric.absorb_bridge(&mut self.sch, bm);
    }

    /// Total DES events executed so far.
    pub fn executed(&self) -> u64 {
        self.sch.executed()
    }

    /// The simulated network (link graph + byte counters).
    pub fn net(&self) -> &NetFabric {
        &self.world.fabric.net
    }

    /// The transport fabric (subscription tables + bridge counters).
    pub fn fabric(&self) -> &Fabric {
        &self.world.fabric
    }

    /// The component world (live-component queries for tests/tools).
    pub fn world(&self) -> &SvcWorld {
        &self.world
    }

    /// Event-heap capacity (pre-sizing / no-regrowth assertions; see
    /// `des::Scheduler::reserve_events`).
    pub fn event_heap_capacity(&self) -> usize {
        self.sch.heap_capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{NetConfig, NicSpec};
    use crate::util::millis;
    use std::cell::RefCell;

    /// Records (arrival µs, topic) of everything it receives.
    struct Probe {
        filters: Vec<String>,
        log: Rc<RefCell<Vec<(SimTime, String)>>>,
    }

    impl Component for Probe {
        fn subscriptions(&self) -> Vec<String> {
            self.filters.clone()
        }
        fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
            self.log.borrow_mut().push((ctx.now(), msg.topic.to_string()));
        }
    }

    /// Publishes one message at start.
    struct Shot {
        topic: String,
        bytes: u64,
    }

    impl Component for Shot {
        fn subscriptions(&self) -> Vec<String> {
            Vec::new()
        }
        fn on_start(&mut self, ctx: &mut Ctx) {
            ctx.publish(&self.topic, self.bytes, Rc::new(()));
        }
        fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
    }

    fn site(cluster: ClusterRef, node: &str) -> Site {
        Site { cluster, node: node.into() }
    }

    fn rt(wan_delay_ms: f64) -> GraphRuntime {
        GraphRuntime::new(NetFabric::new(&NetConfig {
            num_ecs: 2,
            wan_delay: millis(wan_delay_ms),
            ..Default::default()
        }))
    }

    #[test]
    fn same_node_delivery_is_instant() {
        let mut r = rt(0.0);
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Shot { topic: "a/x".into(), bytes: 10_000 }),
        );
        r.run(1000);
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 0, "same-node hop must not be charged");
        assert_eq!(r.net().wan_bytes(), 0);
    }

    #[test]
    fn cross_node_ec_hop_rides_the_lan() {
        let mut r = rt(0.0);
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Ec(0), "minipc"),
            Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Shot { topic: "a/x".into(), bytes: 12_500 }),
        );
        r.run(1000);
        // 12.5 kB on a 100 Mbps LAN = 1 ms serialization + 0.5 ms delay
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 1500);
        assert_eq!(r.net().lan(0).unwrap().bytes_sent, 12_500);
        assert_eq!(r.net().wan_bytes(), 0, "LAN hop must not touch the WAN");
    }

    #[test]
    fn cloud_topics_bridge_over_the_uplink() {
        let mut r = rt(50.0);
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(1), "rpi1"),
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        r.run(1000);
        // 2.5 kB at 20 Mbps = 1 ms, + 50 ms one-way delay
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 51_000);
        assert_eq!(r.net().uplink[1].bytes_sent, 2_500);
        assert_eq!(r.net().wan_bytes(), 2_500);
        assert_eq!(r.fabric().bridged_up, 1);
    }

    /// A runtime whose EC-0 nodes have shaped access links and whose
    /// CC is a two-node cluster with a real LAN segment.
    fn rt_per_node() -> GraphRuntime {
        GraphRuntime::new(NetFabric::new(&NetConfig {
            num_ecs: 2,
            lan_delay: 500,
            cc_lan_mbps: Some(1000.0),
            cc_lan_delay: 100,
            nics: vec![
                NicSpec {
                    cluster: "ec-1".into(),
                    node: "rpi1".into(),
                    mbps: 10.0,
                    delay_us: 100.0,
                },
                NicSpec {
                    cluster: "ec-1".into(),
                    node: "minipc".into(),
                    mbps: 100.0,
                    delay_us: 50.0,
                },
                NicSpec {
                    cluster: "cc".into(),
                    node: "srv2".into(),
                    mbps: 1000.0,
                    delay_us: 10.0,
                },
            ],
            ..Default::default()
        }))
    }

    #[test]
    fn cross_node_hop_pays_src_nic_lan_and_dst_nic() {
        let mut r = rt_per_node();
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Ec(0), "minipc"),
            Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Shot { topic: "a/x".into(), bytes: 12_500 }),
        );
        r.run(1000);
        // src NIC: 12.5 kB at 10 Mbps = 10 ms + 0.1 ms  → 10_100
        // LAN:     12.5 kB at 100 Mbps = 1 ms + 0.5 ms  → 11_600
        // dst NIC: 12.5 kB at 100 Mbps = 1 ms + 0.05 ms → 12_650
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 12_650);
        assert_eq!(r.net().nic(0, "rpi1").unwrap().link.bytes_sent, 12_500);
        assert_eq!(r.net().lan(0).unwrap().bytes_sent, 12_500);
        assert_eq!(r.net().nic(0, "minipc").unwrap().link.bytes_sent, 12_500);
        assert_eq!(r.net().wan_bytes(), 0);
    }

    #[test]
    fn uplink_bridge_pays_the_senders_nic_first() {
        let mut r = rt_per_node();
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        r.run(1000);
        // src NIC: 2.5 kB at 10 Mbps = 2 ms + 0.1 ms   → 2_100
        // uplink:  2.5 kB at 20 Mbps = 1 ms            → 3_100
        // CC LAN:  2.5 kB at 1000 Mbps = 20 µs + 100 µs → 3_220
        // gpu-ws has no NIC: CC-side fan-out is free
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 3_220);
        assert_eq!(r.net().nic(0, "rpi1").unwrap().link.bytes_sent, 2_500);
        assert_eq!(r.net().wan_bytes(), 2_500);
        assert_eq!(
            r.net().lan(r.net().cc_index()).unwrap().bytes_sent,
            2_500,
            "bridged traffic must cross the CC backbone LAN"
        );
    }

    #[test]
    fn fanout_pays_the_senders_nic_exactly_once() {
        // one publish matching 2 cross-node receivers AND the cloud/#
        // bridge: the sender's access link serializes ONCE (the single
        // physical transmit to the cluster message service); receivers
        // queue on the LAN from that egress time, the WAN leg starts
        // there too
        let mut r = rt_per_node();
        let log = Rc::new(RefCell::new(Vec::new()));
        for node in ["minipc", "nix"] {
            r.add(
                site(ClusterRef::Ec(0), node),
                Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
            );
        }
        r.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        r.run(1000);
        let nic = r.net().nic(0, "rpi1").unwrap();
        assert_eq!(nic.link.msgs_sent, 1, "src NIC must serialize the publish once");
        assert_eq!(nic.link.bytes_sent, 2_500);
        // egress: 2.5 kB at 10 Mbps = 2 ms + 0.1 ms → 2_100
        // receiver 1 (minipc): LAN 0.2 ms + 0.5 ms → 2_800, NIC
        //   0.2 ms + 0.05 ms → 3_050
        // receiver 2 (nix, no NIC): second LAN send → 3_000
        // CC probe: uplink 1 ms from 2_100 → 3_100, then the CC
        //   backbone LAN 20 µs + 100 µs → 3_220
        let mut ats: Vec<SimTime> = log.borrow().iter().map(|&(at, _)| at).collect();
        ats.sort_unstable();
        assert_eq!(ats, vec![3_000, 3_050, 3_220]);
        assert_eq!(r.net().lan(0).unwrap().msgs_sent, 2, "one LAN copy per receiver");
    }

    #[test]
    fn bridge_arrival_pays_the_receivers_nic() {
        let mut r = rt_per_node();
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Cc, "srv2"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(1), "nix"), // EC 1 has no NICs
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        r.run(1000);
        // uplink: 1 ms → 1_000; CC LAN (border router → CC bus): 20 µs
        // + 100 µs → 1_120; srv2 NIC: 2.5 kB at 1000 Mbps = 20 µs
        // + 10 µs → 1_150
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 1_150);
        assert_eq!(r.net().nic(r.net().cc_index(), "srv2").unwrap().link.bytes_sent, 2_500);
    }

    #[test]
    fn cc_cross_node_hop_rides_the_cc_lan() {
        let mut r = rt_per_node();
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Cc, "srv2"),
            Box::new(Probe { filters: vec!["cc/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Shot { topic: "cc/x".into(), bytes: 125_000 }),
        );
        r.run(1000);
        // gpu-ws has no NIC; CC LAN: 125 kB at 1000 Mbps = 1 ms +
        // 0.1 ms → 1_100; srv2 NIC: 1 ms + 10 µs → 2_110
        assert_eq!(log.borrow().len(), 1);
        assert_eq!(log.borrow()[0].0, 2_110);
        assert_eq!(r.net().lan(r.net().cc_index()).unwrap().bytes_sent, 125_000);
        assert_eq!(r.net().wan_bytes(), 0, "CC-internal traffic must stay off the WAN");
    }

    #[test]
    fn edge_topics_bridge_down_to_the_right_ec_only() {
        let mut r = rt(0.0);
        let log0 = Rc::new(RefCell::new(Vec::new()));
        let log1 = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Ec(0), "minipc"),
            Box::new(Probe { filters: vec!["edge/ec0/#".into()], log: log0.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(1), "minipc"),
            Box::new(Probe { filters: vec!["edge/#".into()], log: log1.clone() }),
        );
        r.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Shot { topic: "edge/ec0/ctl".into(), bytes: 128 }),
        );
        r.run(1000);
        assert_eq!(log0.borrow().len(), 1, "EC 0 must receive its control message");
        assert!(log1.borrow().is_empty(), "EC 1 must not see EC 0 traffic");
        assert!(r.net().downlink[0].bytes_sent > 0);
        assert_eq!(r.net().downlink[1].bytes_sent, 0);
        assert_eq!(r.fabric().bridged_down, 1);
    }

    #[test]
    fn timers_fire_in_order_and_carry_tokens() {
        struct Ticker {
            seen: Rc<RefCell<Vec<(SimTime, u64)>>>,
        }
        impl Component for Ticker {
            fn subscriptions(&self) -> Vec<String> {
                Vec::new()
            }
            fn on_start(&mut self, ctx: &mut Ctx) {
                ctx.set_timer(300, 3);
                ctx.set_timer(100, 1);
                ctx.set_timer(200, 2);
            }
            fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
            fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
                self.seen.borrow_mut().push((ctx.now(), token));
            }
        }
        let mut r = rt(0.0);
        let seen = Rc::new(RefCell::new(Vec::new()));
        r.add(site(ClusterRef::Cc, "gpu-ws"), Box::new(Ticker { seen: seen.clone() }));
        r.run(1000);
        assert_eq!(*seen.borrow(), vec![(100, 1), (200, 2), (300, 3)]);
    }

    /// Publishes one message every `period` µs, forever.
    struct Pulser {
        topic: String,
        period: SimTime,
        horizon: SimTime,
    }

    impl Component for Pulser {
        fn subscriptions(&self) -> Vec<String> {
            Vec::new()
        }
        fn on_start(&mut self, ctx: &mut Ctx) {
            ctx.set_timer(self.period, 0);
        }
        fn on_message(&mut self, _ctx: &mut Ctx, _msg: &GraphMsg) {}
        fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
            if ctx.now() > self.horizon {
                return;
            }
            ctx.publish(&self.topic, 0, Rc::new(()));
            ctx.set_timer(self.period, 0);
        }
    }

    #[test]
    fn retired_component_stops_receiving_and_id_is_never_reused() {
        let mut r = rt(0.0);
        let log = Rc::new(RefCell::new(Vec::new()));
        let probe = r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
        );
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Pulser { topic: "a/x".into(), period: 1000, horizon: 10_000 }),
        );
        // retire the probe at t=5500: deliveries after that are dropped
        r.at(5500, move |_sch, w: &mut SvcWorld| {
            assert!(w.retire(probe));
            assert!(!w.retire(probe), "double retire must be a no-op");
        });
        r.run(100_000);
        let seen = log.borrow().len();
        assert_eq!(seen, 5, "only pre-retire pulses may arrive: {seen}");
        assert!(!r.world().is_live(probe));
        // a spawn after the retirement gets a FRESH id
        let log2 = Rc::new(RefCell::new(Vec::new()));
        let l2 = log2.clone();
        r.at(r.now(), move |sch, w: &mut SvcWorld| {
            let idx = w.spawn(
                sch,
                Site { cluster: ClusterRef::Ec(0), node: "rpi1".into() },
                Box::new(Probe { filters: vec!["a/#".into()], log: l2.clone() }),
            );
            assert!(idx > probe, "retired ids are never reused");
        });
        r.run(100);
    }

    #[test]
    fn spawned_component_starts_and_receives_mid_run() {
        let mut r = rt(0.0);
        let log = Rc::new(RefCell::new(Vec::new()));
        r.add(
            site(ClusterRef::Ec(0), "rpi1"),
            Box::new(Pulser { topic: "a/x".into(), period: 1000, horizon: 10_000 }),
        );
        let l = log.clone();
        r.at(4500, move |sch, w: &mut SvcWorld| {
            w.spawn(
                sch,
                Site { cluster: ClusterRef::Ec(0), node: "rpi1".into() },
                Box::new(Probe { filters: vec!["a/#".into()], log: l.clone() }),
            );
        });
        r.run(100_000);
        // pulses at 5000..=10000 arrive; 1000..=4000 predate the spawn
        assert_eq!(log.borrow().len(), 6);
        assert!(log.borrow().iter().all(|&(at, _)| at >= 5000));
    }

    #[test]
    fn lifecycle_ops_do_not_disturb_untouched_component_trajectories() {
        // the acceptance property: spawning/retiring components in EC 1
        // leaves an EC-0 component's (time, topic) delivery log
        // byte-identical to a run without any lifecycle op
        let run = |with_ops: bool| {
            let mut r = rt(0.0);
            let log = Rc::new(RefCell::new(Vec::new()));
            r.add(
                site(ClusterRef::Ec(0), "rpi1"),
                Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
            );
            r.add(
                site(ClusterRef::Ec(0), "rpi1"),
                Box::new(Pulser { topic: "a/x".into(), period: 700, horizon: 20_000 }),
            );
            // bystander traffic in EC 1 that the ops churn
            let victim = r.add(
                site(ClusterRef::Ec(1), "rpi1"),
                Box::new(Pulser { topic: "b/x".into(), period: 500, horizon: 20_000 }),
            );
            if with_ops {
                r.at(6000, move |_sch, w: &mut SvcWorld| {
                    w.retire(victim);
                });
                r.at(9000, |sch, w: &mut SvcWorld| {
                    w.spawn(
                        sch,
                        Site { cluster: ClusterRef::Ec(1), node: "rpi2".into() },
                        Box::new(Pulser { topic: "b/x".into(), period: 300, horizon: 20_000 }),
                    );
                });
            }
            r.run(1_000_000);
            log.borrow().clone()
        };
        let quiet = run(false);
        let churned = run(true);
        assert!(!quiet.is_empty());
        assert_eq!(quiet, churned, "untouched trajectory must be identical");
    }

    #[test]
    fn lane_count_never_changes_a_trajectory() {
        // the merged-lane exactness property behind the partitioned
        // golden replays: deliveries pop in global (at, seq) order
        // whatever the lane count
        let run = |lanes: usize| {
            let mut r = GraphRuntime::with_lanes(
                NetFabric::new(&NetConfig {
                    num_ecs: 2,
                    wan_delay: millis(20.0),
                    ..Default::default()
                }),
                lanes,
            );
            let log = Rc::new(RefCell::new(Vec::new()));
            r.add(
                site(ClusterRef::Cc, "gpu-ws"),
                Box::new(Probe { filters: vec!["cloud/#".into()], log: log.clone() }),
            );
            r.add(
                site(ClusterRef::Ec(0), "rpi1"),
                Box::new(Probe { filters: vec!["a/#".into()], log: log.clone() }),
            );
            r.add(
                site(ClusterRef::Ec(0), "rpi2"),
                Box::new(Pulser { topic: "a/x".into(), period: 700, horizon: 50_000 }),
            );
            r.add(
                site(ClusterRef::Ec(1), "rpi1"),
                Box::new(Pulser { topic: "cloud/m".into(), period: 1100, horizon: 50_000 }),
            );
            r.run(1_000_000);
            log.borrow().clone()
        };
        let one = run(1);
        assert!(!one.is_empty());
        for lanes in 2..=4 {
            assert_eq!(one, run(lanes), "trajectory must not depend on lanes={lanes}");
        }
    }

    #[test]
    fn shard_export_and_absorb_match_the_serial_bridge() {
        // serial reference: EC 1 → CC over the uplink in one runtime
        let mut s = rt(50.0);
        let slog = Rc::new(RefCell::new(Vec::new()));
        s.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: slog.clone() }),
        );
        s.add(
            site(ClusterRef::Ec(1), "rpi1"),
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        s.run(1000);
        assert_eq!(slog.borrow().len(), 1);

        // sharded: the EC shard exports after charging its own uplink;
        // the CC shard absorbs (gateway hop is free here) and delivers
        let cfg = NetConfig { num_ecs: 2, wan_delay: millis(50.0), ..Default::default() };
        let unit_codec = || -> ShardCodec {
            Box::new(|b| b.downcast_ref::<()>().map(|_| Box::new(()) as Box<dyn Any + Send>))
        };
        let mut ec = GraphRuntime::new(NetFabric::new(&cfg));
        ec.set_shard(vec![true, true, false], unit_codec());
        ec.add(
            site(ClusterRef::Ec(1), "rpi1"),
            Box::new(Shot { topic: "cloud/up".into(), bytes: 2_500 }),
        );
        let mut cc = GraphRuntime::new(NetFabric::new(&cfg));
        cc.set_shard(vec![false, false, true], unit_codec());
        let clog = Rc::new(RefCell::new(Vec::new()));
        cc.add(
            site(ClusterRef::Cc, "gpu-ws"),
            Box::new(Probe { filters: vec!["cloud/#".into()], log: clog.clone() }),
        );
        assert_eq!(ec.peek_next(), Some(0));
        ec.run_until(10);
        let out = ec.take_shard_outbox();
        assert_eq!(out.len(), 1, "the bridge copy must leave through the outbox");
        assert_eq!(ec.fabric().bridged_up, 1);
        assert_eq!(ec.net().wan_bytes(), 2_500, "the exporter charges its own uplink");
        for bm in out {
            assert_eq!(bm.at, 51_000, "exported at the WAN delivery time");
            cc.absorb_bridge(bm);
        }
        cc.run_until(60_000);
        assert_eq!(*clog.borrow(), *slog.borrow(), "sharded arrival must match serial");
    }

    #[test]
    fn site_of_parses_plan_node_ids() {
        use crate::infra::paper_testbed;
        use crate::platform::orchestrator;
        use crate::topology::{Topology, VIDEOQUERY_TOPOLOGY};
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let plan = orchestrator::place(&topo, &paper_testbed("sg")).unwrap();
        for inst in &plan.instances {
            let s = site_of(inst).unwrap();
            match inst.component.as_str() {
                "coc" | "ic" | "rs" => assert_eq!(s.cluster, ClusterRef::Cc, "{}", inst.id),
                _ => assert!(matches!(s.cluster, ClusterRef::Ec(k) if k < 3), "{}", inst.id),
            }
        }
    }

    #[test]
    fn deploy_instantiates_every_modelled_instance() {
        use crate::infra::paper_testbed;
        use crate::platform::orchestrator;
        use crate::topology::{Topology, VIDEOQUERY_TOPOLOGY};
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let plan = orchestrator::place(&topo, &paper_testbed("sg")).unwrap();
        let mut r = GraphRuntime::new(NetFabric::new(&NetConfig::default()));
        let log = Rc::new(RefCell::new(Vec::new()));
        let n = r
            .deploy(&plan, |inst, _site| {
                Ok(if inst.component == "rs" {
                    None // not modelled
                } else {
                    Some(Box::new(Probe { filters: Vec::new(), log: log.clone() })
                        as Box<dyn Component>)
                })
            })
            .unwrap();
        assert_eq!(n, plan.instances.len() - 1);
    }
}
