//! One scenario format, one entry point.
//!
//! Every application driver used to expose its own run function with
//! its own config plumbing (`app::videoquery::run_scenario`,
//! `app::fedtrain::run_fedtrain_scenario`, `app::metro::run_metro_with`)
//! and every caller — `ace svcrun`, the CI scenario matrix, now the
//! `ace serve` `scenario` op — re-implemented the dispatch. This
//! module is the single seam: [`Scenario::parse`] resolves WHICH
//! application a yamlite document drives, [`run`] executes it, and
//! [`Report`] carries the per-app result behind one type with a
//! wire-ready [`Report::summary`].
//!
//! App resolution, in order:
//!
//!   1. a top-level `app:` key (`metro` documents are plain workload
//!      configs, not lifecycle scripts, and MUST name themselves;
//!      lifecycle documents may name their app explicitly too);
//!   2. the app of the first `deploy`/`update` op
//!      ([`LifecycleScenario::first_app`]);
//!   3. the caller-provided fallback (the CLI's `--app`, default
//!      `videoquery`).
//!
//! [`Knobs`] are the CLI-flag overrides: every field is an `Option`
//! and `None` means "the same default `ace svcrun --scenario` always
//! used", so a knob-free [`run`] (e.g. from a connected serve client)
//! behaves exactly like the bare CLI invocation.

use super::lifecycle::{LifecycleReport, LifecycleScenario};
use crate::app::fedtrain::{FedConfig, FedMetrics};
use crate::app::metro::{MetroConfig, MetroMetrics};
use crate::app::videoquery::{CellConfig, Compute, Paradigm, ScenarioOutcome, ServiceTimes};
use crate::json::Value;
use crate::util::to_secs;
use crate::yamlite;
use anyhow::{anyhow, bail, Result};

/// A parsed scenario document, dispatch already resolved.
#[derive(Debug, Clone)]
pub enum Scenario {
    /// A metro workload config (`app: metro`) — no lifecycle ops.
    Metro(MetroConfig),
    /// A lifecycle script driving `app` (videoquery | fedtrain).
    Lifecycle {
        app: String,
        scenario: LifecycleScenario,
    },
}

impl Scenario {
    /// Parse a yamlite scenario document, resolving the app with the
    /// default `videoquery` fallback.
    pub fn parse(src: &str) -> Result<Scenario> {
        Self::parse_with_fallback(src, "videoquery")
    }

    /// Parse with an explicit fallback app for lifecycle documents
    /// that neither carry an `app:` key nor deploy a topology (the
    /// CLI passes its `--app` flag here).
    pub fn parse_with_fallback(src: &str, fallback_app: &str) -> Result<Scenario> {
        let doc = yamlite::parse(src).map_err(|e| anyhow!("{e}"))?;
        if doc.get("app").as_str() == Some("metro") {
            return Ok(Scenario::Metro(MetroConfig::from_value(&doc)?));
        }
        let scenario = LifecycleScenario::from_value(&doc)?;
        let app = doc
            .get("app")
            .as_str()
            .or_else(|| scenario.first_app())
            .unwrap_or(fallback_app)
            .to_string();
        Ok(Scenario::Lifecycle { app, scenario })
    }

    /// The application this scenario drives.
    pub fn app(&self) -> &str {
        match self {
            Scenario::Metro(_) => "metro",
            Scenario::Lifecycle { app, .. } => app,
        }
    }
}

/// CLI-flag overrides; `None` = the flag's `ace svcrun` default.
/// Fields that do not apply to the dispatched app are ignored (the
/// same contract the CLI flags always had).
#[derive(Clone, Default)]
pub struct Knobs {
    /// videoquery: serving paradigm (default ACE basic policy).
    pub paradigm: Option<Paradigm>,
    /// videoquery: OD sampling interval, seconds (default 0.2).
    pub interval_s: Option<f64>,
    /// videoquery + fedtrain: one-way WAN delay, ms (default 0).
    pub wan_delay_ms: Option<f64>,
    /// videoquery: sampling horizon, seconds (default: the scenario's
    /// `duration`, so post-redeploy phases still produce crops).
    pub duration_s: Option<f64>,
    /// videoquery seed (default 1) / fedtrain seed (default 42).
    pub seed: Option<u64>,
    /// videoquery + fedtrain: edge clusters (default 3).
    pub num_ecs: Option<usize>,
    /// videoquery: cameras per EC (default 3).
    pub cams_per_ec: Option<usize>,
    /// fedtrain: FL rounds (default 12).
    pub rounds: Option<usize>,
    /// fedtrain: virtual ms per local SGD step (default 200).
    pub step_ms: Option<f64>,
    /// Scheduler lanes / metro cluster partitions (default 1; metro
    /// documents may set their own).
    pub partitions: Option<usize>,
    /// metro: worker threads driving the partitions.
    pub threads: Option<usize>,
    /// videoquery: real compiled-model compute instead of the
    /// synthetic oracle (`ace svcrun --real`).
    pub video_compute: Option<(ServiceTimes, Compute)>,
}

/// What a scenario run produced, per app.
pub enum Report {
    Video(ScenarioOutcome),
    Fed {
        metrics: FedMetrics,
        lifecycle: LifecycleReport,
    },
    Metro(MetroMetrics),
}

impl Report {
    /// The application that produced this report.
    pub fn app(&self) -> &'static str {
        match self {
            Report::Video(_) => "videoquery",
            Report::Fed { .. } => "fedtrain",
            Report::Metro(_) => "metro",
        }
    }

    /// A small wire-ready summary (the `scenario_ok` payload): the
    /// headline numbers each app's CLI output leads with.
    pub fn summary(&self) -> Value {
        match self {
            Report::Video(out) => {
                let m = &out.metrics;
                Value::obj(vec![
                    ("paradigm", Value::str(&m.paradigm)),
                    ("crops", Value::num(m.crops as f64)),
                    ("f1", Value::num(m.f1.f1())),
                    ("bwcMb", Value::num(m.bwc_mb())),
                    ("edgeDecided", Value::num(m.edge_decided as f64)),
                    ("cloudDecided", Value::num(m.cloud_decided as f64)),
                ])
            }
            Report::Fed { metrics, .. } => Value::obj(vec![
                ("rounds", Value::num(metrics.rounds.len() as f64)),
                ("finalAccuracy", Value::num(metrics.final_accuracy)),
                ("wanMb", Value::num(metrics.wan_bytes as f64 / 1e6)),
                ("virtualSecs", Value::num(metrics.virtual_secs)),
            ]),
            Report::Metro(m) => Value::obj(vec![
                ("frames", Value::num(m.frames as f64)),
                ("escalated", Value::num(m.escalated as f64)),
                ("replies", Value::num(m.replies as f64)),
                ("meanLatencyMs", Value::num(m.mean_latency_ms)),
                ("windows", Value::num(m.windows as f64)),
            ]),
        }
    }
}

/// Run a parsed scenario with all-default knobs — what a scenario
/// arriving over the serve protocol gets.
pub fn run(sc: &Scenario) -> Result<Report> {
    run_with(sc, Knobs::default())
}

/// Run a parsed scenario with explicit CLI-flag overrides.
pub fn run_with(sc: &Scenario, knobs: Knobs) -> Result<Report> {
    match sc {
        Scenario::Metro(cfg) => {
            let mut cfg = cfg.clone();
            if let Some(p) = knobs.partitions {
                cfg.partitions = p;
            }
            if let Some(t) = knobs.threads {
                cfg.threads = t;
            }
            Ok(Report::Metro(crate::app::metro::run_metro_with(
                &cfg,
                |_, _| {},
            )))
        }
        Scenario::Lifecycle { app, scenario } => match app.as_str() {
            "videoquery" => {
                let cfg = CellConfig {
                    paradigm: knobs.paradigm.unwrap_or(Paradigm::AceBp),
                    interval_s: knobs.interval_s.unwrap_or(0.2),
                    wan_delay_ms: knobs.wan_delay_ms.unwrap_or(0.0),
                    // default: sample right up to the scenario horizon
                    // so post-redeploy phases still produce crops
                    duration_s: knobs
                        .duration_s
                        .unwrap_or_else(|| to_secs(scenario.duration)),
                    seed: knobs.seed.unwrap_or(1),
                    num_ecs: knobs.num_ecs.unwrap_or(3),
                    cams_per_ec: knobs.cams_per_ec.unwrap_or(3),
                    partitions: knobs.partitions.unwrap_or(1),
                    ..Default::default()
                };
                let (svc, compute) = knobs.video_compute.unwrap_or((
                    ServiceTimes::synthetic(),
                    Compute::Synthetic { target_bias: 0.05 },
                ));
                #[allow(deprecated)] // the wrapped per-app entry point
                let out = crate::app::videoquery::run_scenario(cfg, svc, compute, scenario)?;
                Ok(Report::Video(out))
            }
            "fedtrain" => {
                let cfg = FedConfig {
                    rounds: knobs.rounds.unwrap_or(12),
                    num_ecs: knobs.num_ecs.unwrap_or(3),
                    wan_delay_ms: knobs.wan_delay_ms.unwrap_or(0.0),
                    seed: knobs.seed.unwrap_or(42),
                    step_ms: knobs.step_ms.unwrap_or(200.0),
                    partitions: knobs.partitions.unwrap_or(1),
                    ..Default::default()
                };
                #[allow(deprecated)] // the wrapped per-app entry point
                let (metrics, lifecycle) =
                    crate::app::fedtrain::run_fedtrain_scenario(cfg, scenario)?;
                Ok(Report::Fed { metrics, lifecycle })
            }
            other => bail!("scenario deploys unknown app '{other}' (videoquery|fedtrain|metro)"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FED_DOC: &str = "\
app: fedtrain
duration: 12
ops:
  - at: 0
    op: deploy
    topology:
      app: fedtrain
      components:
        - name: trainer
          image: ace/fl-trainer:1
          location: edge
          replicas: 2
          connections: [coordinator]
        - name: coordinator
          image: ace/fl-coordinator:1
          location: cloud
          connections: []
";

    #[test]
    fn metro_documents_dispatch_before_the_lifecycle_parser() {
        let sc = Scenario::parse("app: metro\nduration_s: 1\n").unwrap();
        assert_eq!(sc.app(), "metro");
        match sc {
            Scenario::Metro(cfg) => assert_eq!(cfg.duration_s, 1.0),
            other => panic!("expected a metro scenario, got {other:?}"),
        }
    }

    #[test]
    fn explicit_app_key_wins_and_unknowns_fail_loud() {
        let sc = Scenario::parse(FED_DOC).unwrap();
        assert_eq!(sc.app(), "fedtrain");
        let doc = FED_DOC.replace("app: fedtrain\n", "app: warp\n");
        let sc = Scenario::parse(&doc).unwrap();
        // the topology still says fedtrain, but the explicit key wins
        assert_eq!(sc.app(), "warp");
        let err = run(&sc).unwrap_err().to_string();
        assert!(err.contains("unknown app 'warp'"), "got: {err}");
    }

    #[test]
    fn dispatcher_runs_a_fedtrain_scenario_end_to_end() {
        let sc = Scenario::parse(FED_DOC).unwrap();
        let report = run_with(
            &sc,
            Knobs {
                rounds: Some(2),
                num_ecs: Some(2),
                step_ms: Some(1.0),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.app(), "fedtrain");
        match &report {
            Report::Fed { metrics, .. } => assert_eq!(metrics.rounds.len(), 2),
            _ => panic!("expected a fedtrain report"),
        }
        assert_eq!(report.summary().get("rounds").as_f64(), Some(2.0));
    }
}
