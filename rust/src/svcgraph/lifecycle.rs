//! Virtual-time control plane: the platform manages application
//! lifecycle INSIDE the DES (Figure 4 steps ②→④ under virtual time).
//!
//! Before this module the threaded `platform::{Controller, Monitor}` /
//! `infra::Agent` ran only on the wall-clock broker plane, disconnected
//! from the `svcgraph` DES where applications actually execute. Here
//! the same ②→④ loop is simulated end to end:
//!
//! ```text
//! LifecycleScenario (yamlite script: deploy / update / fail-node /
//!                    remove at virtual times)
//!    │  Event::Call at each op's time
//!    ▼
//! ControlPlane  ── orchestrator::place ──► DeploymentPlan     (②)
//!    │  diff_plans vs the stored plan → per-node compose
//!    │  instructions (yamlite docs, the real wire format)
//!    ▼
//! `ace/deploy/<node>` on the node's cluster bus                (③)
//!    │  (downlink-charged for EC nodes — the platform reaches
//!    │   EC message services over the WAN, §4.3.2)
//!    ▼
//! NodeAgent (a simulated Component on every registered node)   (④)
//!    │  converges: SvcWorld::spawn / SvcWorld::retire via the
//!    │  Event::Call lane; heartbeats + instance status on
//!    │  `cloud/ace/status/<node>` (uplink-charged)
//!    ▼
//! MonitorTap on the CC ──► ApiServer `node-status` entities
//!    │  (virtual-ms heartbeat stamps)
//!    ▼
//! monitor sweep every P seconds: stale heartbeat ⇒ node shielded
//! (marked Failed) ⇒ re-place each app ⇒ diff ⇒ instructions to
//! touched nodes — the §4.2.1 shield/redeploy loop, deterministic.
//! ```
//!
//! Determinism: every step above is a DES event (ops and sweeps on the
//! boxed `Call` lane, transport on the typed lanes), so the same
//! scenario replays bit-identically; `tests/lifecycle.rs` pins the
//! trajectory hash. Components untouched by an op keep their exact
//! `(at, seq)` trajectories (see DESIGN.md §Control-plane).
//!
//! Scenario file format (yamlite; `ace svcrun --scenario <FILE>`):
//!
//! ```yaml
//! duration: 110          # virtual seconds to simulate
//! network:               # OPTIONAL NetFabric overrides (per-node
//!   cc_nodes: 2          # NICs, CC cluster shape, link shaping —
//!   nics:                # see simnet::NetOverrides for the grammar)
//!     - cluster: ec-1
//!       node: rpi1
//!       mbps: 2
//! ops:
//!   - at: 0              # virtual seconds
//!     op: deploy         # deploy | update | fail-node | remove
//!     topology:          # a full topology document, inline
//!       app: videoquery
//!       version: 1
//!       components:
//!         - name: od
//!           image: ace/object-detector:1
//!           ...
//!   - at: 60
//!     op: fail-node
//!     node: infra-cell/ec-1/minipc
//!   - at: 90
//!     op: remove
//!     app: videoquery
//! ```

use super::{
    site_of_node, ClusterRef, Component, Ctx, Event, GraphMsg, GraphRuntime, Site, SvcScheduler,
    SvcWorld,
};
use crate::deploy::{diff_plans, DeploymentPlan, Instance};
use crate::infra::agent::{compose_instruction, deploy_topic, status_topic};
use crate::infra::{Infrastructure, NodeStatus};
use crate::json::{self, Value};
use crate::platform::api::{kinds, ApiServer};
use crate::platform::controller::plan_to_value;
use crate::platform::orchestrator::{self, NetHints};
use crate::simnet::NetOverrides;
use crate::topology::Topology;
use crate::util::{secs, to_millis, AceId, SimTime};
use crate::yamlite;
use anyhow::{anyhow, bail, Context, Result};
use std::any::Any;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Builds the component for a placed instance — the application half of
/// Figure 4 step ④. `None` means "not modelled" (the instance is
/// tracked by the platform but runs no DES logic).
pub type InstanceFactory = Rc<dyn Fn(&Instance, &Site) -> Result<Option<Box<dyn Component>>>>;

/// Called whenever the control plane stores a new plan for an app
/// (deploy, update, shield/redeploy, remove — remove passes an empty
/// plan). Lets applications track platform intent, e.g. fedtrain's
/// coordinator learning the live trainer count.
pub type PlanHook = Rc<dyn Fn(&str, &DeploymentPlan)>;

/// One scripted lifecycle operation.
#[derive(Debug, Clone)]
pub enum LifecycleOp {
    /// Submit a topology for a fresh application (§4.4.3).
    Deploy(Topology),
    /// Submit an updated topology: the controller diffs plans and only
    /// touches changed nodes (incremental update, §4.4.3).
    Update(Topology),
    /// Crash a node: everything running on it dies silently; the
    /// platform must NOTICE via missed heartbeats and shield it.
    FailNode(AceId),
    /// Remove a deployed application entirely.
    Remove(String),
}

/// A lifecycle op pinned to a virtual time.
#[derive(Debug, Clone)]
pub struct ScenarioStep {
    /// Virtual time (µs) the op is applied at.
    pub at: SimTime,
    /// The operation.
    pub op: LifecycleOp,
}

/// A scripted application-lifecycle scenario (see the module docs for
/// the yamlite file format).
#[derive(Debug, Clone)]
pub struct LifecycleScenario {
    /// Ops in script order (times need not be sorted; the DES orders
    /// them).
    pub steps: Vec<ScenarioStep>,
    /// Virtual horizon (µs): the run stops here.
    pub duration: SimTime,
    /// Optional `network:` overrides (per-node NICs, CC cluster shape,
    /// link shaping) the app driver applies to its base `NetConfig`.
    pub network: Option<NetOverrides>,
}

impl LifecycleScenario {
    /// Parse a yamlite scenario document.
    pub fn parse(src: &str) -> Result<LifecycleScenario> {
        let doc = yamlite::parse(src).map_err(|e| anyhow!("{e}"))?;
        Self::from_value(&doc)
    }

    /// Build a scenario from an already-parsed yamlite/JSON value.
    pub fn from_value(doc: &Value) -> Result<LifecycleScenario> {
        let duration = secs(
            doc.get("duration")
                .as_f64()
                .context("scenario: missing 'duration' (virtual seconds)")?,
        );
        let ops = doc.get("ops").as_arr().context("scenario: missing 'ops'")?;
        let mut steps = Vec::new();
        for (i, o) in ops.iter().enumerate() {
            let at = secs(
                o.get("at")
                    .as_f64()
                    .with_context(|| format!("op #{i}: missing 'at' (virtual seconds)"))?,
            );
            let kind = o
                .get("op")
                .as_str()
                .with_context(|| format!("op #{i}: missing 'op'"))?;
            let op = match kind {
                "deploy" | "update" => {
                    let topo = Topology::from_value(o.get("topology"))
                        .with_context(|| format!("op #{i}: bad 'topology'"))?;
                    if kind == "deploy" {
                        LifecycleOp::Deploy(topo)
                    } else {
                        LifecycleOp::Update(topo)
                    }
                }
                "fail-node" => LifecycleOp::FailNode(AceId::parse(
                    o.get("node")
                        .as_str()
                        .with_context(|| format!("op #{i}: missing 'node'"))?,
                )),
                "remove" => LifecycleOp::Remove(
                    o.get("app")
                        .as_str()
                        .with_context(|| format!("op #{i}: missing 'app'"))?
                        .to_string(),
                ),
                other => bail!("op #{i}: unknown op '{other}' (deploy|update|fail-node|remove)"),
            };
            steps.push(ScenarioStep { at, op });
        }
        if steps.is_empty() {
            bail!("scenario has no ops");
        }
        let network = match doc.get("network") {
            Value::Null => None,
            v => Some(NetOverrides::from_value(v).context("scenario: bad 'network'")?),
        };
        Ok(LifecycleScenario { steps, duration, network })
    }

    /// App named by the first deploy/update op (CLI dispatch).
    pub fn first_app(&self) -> Option<&str> {
        self.steps.iter().find_map(|s| match &s.op {
            LifecycleOp::Deploy(t) | LifecycleOp::Update(t) => Some(t.app.as_str()),
            _ => None,
        })
    }
}

/// Timing knobs of the simulated platform services.
#[derive(Debug, Clone, Copy)]
pub struct ControlPlaneConfig {
    /// Agent heartbeat period (virtual seconds).
    pub heartbeat_period_s: f64,
    /// A node whose last heartbeat is older than this is shielded.
    pub failure_timeout_s: f64,
    /// Monitor sweep period (virtual seconds).
    pub sweep_period_s: f64,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        ControlPlaneConfig {
            heartbeat_period_s: 2.0,
            failure_timeout_s: 5.0,
            sweep_period_s: 5.0,
        }
    }
}

/// Deterministic audit trail of everything the control plane did —
/// hashed by the lifecycle goldens.
#[derive(Debug, Clone, Default)]
pub struct LifecycleReport {
    /// `(virtual µs, event)` in execution order.
    pub events: Vec<(SimTime, String)>,
    /// Component instances started by agents.
    pub spawned: u64,
    /// Component instances stopped (converged away, or died with their
    /// node).
    pub retired: u64,
    /// Status reports ingested by the monitor tap.
    pub status_reports: u64,
    /// Nodes shielded after missed heartbeats, in shield order.
    pub shielded: Vec<String>,
    /// Shield-triggered re-placements that changed a plan.
    pub redeploys: u64,
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

impl LifecycleReport {
    fn log(&mut self, at: SimTime, msg: String) {
        self.events.push((at, msg));
    }

    /// FNV digest over the full audit trail (times, messages,
    /// counters) — two runs of the same scenario must agree.
    pub fn hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (at, msg) in &self.events {
            fnv(&mut h, &at.to_le_bytes());
            fnv(&mut h, msg.as_bytes());
        }
        for v in [self.spawned, self.retired, self.status_reports, self.redeploys] {
            fnv(&mut h, &v.to_le_bytes());
        }
        for s in &self.shielded {
            fnv(&mut h, s.as_bytes());
        }
        h
    }
}

/// Shared control-plane state, reachable from scenario `Call` closures
/// and the simulated agents/monitor alike.
struct PlaneState {
    api: ApiServer,
    infra: RefCell<Infrastructure>,
    factory: InstanceFactory,
    plan_hook: Option<PlanHook>,
    /// app → (submitted topology, current plan).
    apps: RefCell<BTreeMap<String, (Topology, DeploymentPlan)>>,
    /// instance id → live component index.
    registry: RefCell<BTreeMap<String, usize>>,
    /// node → its agent's component index (removed when the node dies).
    agents: RefCell<BTreeMap<AceId, usize>>,
    report: RefCell<LifecycleReport>,
    heartbeat_period: SimTime,
    failure_timeout: SimTime,
    /// Per-node NIC bandwidths for network-aware placement (degenerate
    /// hints reproduce the CPU-spread-only scoring byte-for-byte).
    net_hints: NetHints,
}

/// Handle onto an installed control plane (post-run inspection).
pub struct ControlPlane {
    state: Rc<PlaneState>,
}

/// Status reports cross the wire as JSON (the threaded plane's format).
struct StatusBody {
    json: String,
}

/// Deployment instructions cross the wire as compose-style yamlite —
/// the same documents `infra::agent::compose_instruction` renders for
/// the threaded plane.
struct InstructionBody {
    doc: String,
}

/// Topic filter the CC monitor tap listens on: EC agents publish
/// `cloud/ace/status/<node>` so reports ride the existing `cloud/#`
/// uplink bridge.
const MONITOR_FILTER: &str = "cloud/ace/status/#";

impl ControlPlane {
    /// Install the control plane into a NOT-yet-started runtime: one
    /// node-agent component per registered node, a monitor tap on the
    /// CC, every scenario op as a `Call` event at its time, and
    /// recurring monitor sweeps until the scenario horizon. Placement
    /// (initial and shield/redeploy) scores through `net_hints` —
    /// derive them from the runtime's `NetFabric` so the orchestrator
    /// sees the same access links the transport charges. Drive the
    /// runtime with `run_until(scenario.duration)` afterwards.
    pub fn install(
        rt: &mut GraphRuntime,
        infra: Infrastructure,
        factory: InstanceFactory,
        plan_hook: Option<PlanHook>,
        scenario: &LifecycleScenario,
        cfg: ControlPlaneConfig,
        net_hints: NetHints,
    ) -> Result<ControlPlane> {
        anyhow::ensure!(
            cfg.heartbeat_period_s > 0.0 && cfg.failure_timeout_s > 0.0 && cfg.sweep_period_s > 0.0,
            "control-plane periods must be positive"
        );
        let state = Rc::new(PlaneState {
            api: ApiServer::new(),
            infra: RefCell::new(infra),
            factory,
            plan_hook,
            apps: RefCell::new(BTreeMap::new()),
            registry: RefCell::new(BTreeMap::new()),
            agents: RefCell::new(BTreeMap::new()),
            report: RefCell::new(LifecycleReport::default()),
            heartbeat_period: secs(cfg.heartbeat_period_s),
            failure_timeout: secs(cfg.failure_timeout_s),
            net_hints,
        });
        // one agent per registered node (§4.3.1: agents are deployed at
        // node registration, before any application exists)
        let nodes: Vec<AceId> = state
            .infra
            .borrow()
            .all_nodes()
            .map(|(_, n)| n.id.clone())
            .collect();
        for node in nodes {
            let site = site_of_node(&node)?;
            let agent = NodeAgent {
                state: state.clone(),
                node: node.clone(),
                site: site.clone(),
                deploy_filter: deploy_topic(&node),
                status_wire_topic: format!("cloud/{}", status_topic(&node)),
                running: BTreeMap::new(),
            };
            let idx = rt.add(site, Box::new(agent));
            state.agents.borrow_mut().insert(node, idx);
        }
        // the monitoring service's ingest point on the CC
        let tap_node: Rc<str> = state
            .infra
            .borrow()
            .cc
            .nodes
            .first()
            .map(|n| n.id.leaf().into())
            .unwrap_or_else(|| "monitor".into());
        rt.add(
            Site { cluster: ClusterRef::Cc, node: tap_node },
            Box::new(MonitorTap { state: state.clone() }),
        );
        // scripted ops ride the closure lane at their virtual times
        for step in &scenario.steps {
            let st = state.clone();
            let op = step.op.clone();
            rt.at(step.at, move |sch, w| apply_op(&st, sch, w, op));
        }
        // recurring monitor sweeps (§4.2.1 failure shielding): ONE
        // self-rescheduling Call keeps exactly one sweep event in the
        // heap at a time, however long the scenario runs. Min 1 µs so
        // a degenerate period can never loop in place.
        let sweep = secs(cfg.sweep_period_s).max(1);
        if sweep <= scenario.duration {
            let st = state.clone();
            let horizon = scenario.duration;
            rt.at(sweep, move |sch, w| sweep_chain(st, sweep, horizon, sch, w));
        }
        Ok(ControlPlane { state })
    }

    /// The platform's entity store (plans, app states, node statuses).
    pub fn api(&self) -> ApiServer {
        self.state.api.clone()
    }

    /// Snapshot of the audit trail.
    pub fn report(&self) -> LifecycleReport {
        self.state.report.borrow().clone()
    }

    /// Current stored plan for `app`, if deployed.
    pub fn plan(&self, app: &str) -> Option<DeploymentPlan> {
        self.state.apps.borrow().get(app).map(|(_, p)| p.clone())
    }

    /// Snapshot of the (possibly shielded) infrastructure.
    pub fn infra(&self) -> Infrastructure {
        self.state.infra.borrow().clone()
    }
}

fn apply_op(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, op: LifecycleOp) {
    match op {
        LifecycleOp::Deploy(topo) | LifecycleOp::Update(topo) => submit_topology(st, sch, w, topo),
        LifecycleOp::FailNode(node) => fail_node(st, sch, w, &node),
        LifecycleOp::Remove(app) => remove_app(st, sch, w, &app),
    }
}

/// §4.4.3: submitting a topology deploys the app if new, otherwise
/// triggers an incremental update (diff the plans, touch only changed
/// nodes).
fn submit_topology(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, topo: Topology) {
    let now = sch.now();
    let new_plan =
        match orchestrator::place_with_net(&topo, &st.infra.borrow(), Some(&st.net_hints)) {
            Ok(p) => p,
            Err(e) => {
                st.report
                    .borrow_mut()
                    .log(now, format!("ERROR placing '{}' v{}: {e}", topo.app, topo.version));
                return;
            }
        };
    let old = st.apps.borrow().get(&topo.app).map(|(_, p)| p.clone());
    let touched: Vec<AceId> = match &old {
        None => {
            st.report.borrow_mut().log(
                now,
                format!(
                    "deploy '{}' v{}: {} instances placed",
                    topo.app,
                    topo.version,
                    new_plan.instances.len()
                ),
            );
            new_plan.nodes()
        }
        Some(old_plan) => {
            let diff = diff_plans(old_plan, &new_plan);
            let touched = diff.touched_nodes();
            st.report.borrow_mut().log(
                now,
                format!(
                    "update '{}' v{}: +{} -{} ~{}, {} nodes touched",
                    topo.app,
                    topo.version,
                    diff.add.len(),
                    diff.remove.len(),
                    diff.replace.len(),
                    touched.len()
                ),
            );
            touched
        }
    };
    store_plan(st, &topo.app, Some((topo.clone(), new_plan.clone())));
    for node in &touched {
        send_node_instruction(st, sch, w, node);
    }
    if let Some(hook) = &st.plan_hook {
        hook(&topo.app, &new_plan);
    }
}

/// Crash a node: the agent and every application instance on it die
/// silently. The platform only learns of it through missed heartbeats.
fn fail_node(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, node: &AceId) {
    let now = sch.now();
    st.report
        .borrow_mut()
        .log(now, format!("FAULT injected: node {node} crashes"));
    if let Some(agent_idx) = st.agents.borrow_mut().remove(node) {
        w.retire(agent_idx);
    }
    let Ok(site) = site_of_node(node) else { return };
    let dead: Vec<(String, usize)> = st
        .registry
        .borrow()
        .iter()
        .filter(|(_, &idx)| w.component_site(idx).is_some_and(|s| *s == site))
        .map(|(id, &idx)| (id.clone(), idx))
        .collect();
    for (id, idx) in dead {
        w.retire(idx);
        st.registry.borrow_mut().remove(&id);
        let mut rep = st.report.borrow_mut();
        rep.retired += 1;
        rep.log(now, format!("instance '{id}' died with {node}"));
    }
}

fn remove_app(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, app: &str) {
    let now = sch.now();
    let Some(plan) = st.apps.borrow().get(app).map(|(_, p)| p.clone()) else {
        st.report
            .borrow_mut()
            .log(now, format!("ERROR remove '{app}': not deployed"));
        return;
    };
    store_plan(st, app, None);
    st.report.borrow_mut().log(
        now,
        format!("remove '{app}': {} instances wound down", plan.instances.len()),
    );
    for node in plan.nodes() {
        send_node_instruction(st, sch, w, &node);
    }
    if let Some(hook) = &st.plan_hook {
        hook(
            app,
            &DeploymentPlan { app: app.to_string(), version: plan.version, instances: Vec::new() },
        );
    }
}

/// Persist (or clear) an app's topology + plan in the state and the
/// API server (the dashboard/CLI view of platform intent).
fn store_plan(st: &Rc<PlaneState>, app: &str, entry: Option<(Topology, DeploymentPlan)>) {
    match entry {
        Some((topo, plan)) => {
            st.api.put(kinds::PLAN, app, plan_to_value(&plan));
            st.api.put(
                kinds::APP,
                app,
                Value::obj(vec![
                    ("state", Value::str("deployed")),
                    ("version", Value::num(plan.version as f64)),
                ]),
            );
            st.apps.borrow_mut().insert(app.to_string(), (topo, plan));
        }
        None => {
            let _ = st.api.delete(kinds::PLAN, app);
            let _ = st.api.delete(kinds::APP, app);
            st.apps.borrow_mut().remove(app);
        }
    }
}

/// Figure 4 step ③: render the node's full convergent instruction
/// (every instance of every stored app bound to it) as a compose
/// document and deliver it on the node's cluster bus, charging the EC
/// downlink — the platform reaches EC message services over the WAN.
///
/// Known limitation (shared with the threaded controller's
/// `sync_node`): `compose_instruction` stamps ONE app label on the
/// whole document, so when instances of several apps co-locate on a
/// node, status reports attribute them all to the first app.
fn send_node_instruction(
    st: &Rc<PlaneState>,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
    node: &AceId,
) {
    let now = sch.now();
    let mut services: Vec<(String, String, String)> = Vec::new();
    let mut app_label = String::new();
    for (app, (_topo, plan)) in st.apps.borrow().iter() {
        for inst in &plan.instances {
            if &inst.node == node {
                services.push((inst.id.clone(), inst.component.clone(), inst.image.clone()));
                if app_label.is_empty() {
                    app_label = app.clone();
                }
            }
        }
    }
    let doc = compose_instruction(&app_label, &services);
    let Ok(site) = site_of_node(node) else {
        st.report
            .borrow_mut()
            .log(now, format!("ERROR instruction for malformed node id {node}"));
        return;
    };
    let bytes = doc.len() as u64;
    // the WAN downlink is charged here; the Bridge delivery then pays
    // the TARGET NODE's access link in `Fabric::route` (bridge-arrival
    // ingress), so instructions contend on the real node's NIC
    let arrival = match site.cluster {
        ClusterRef::Ec(k) if k < w.fabric.net.num_ecs() => {
            // CC backbone LAN out to the border router first, then the
            // downlink (mirrors `Fabric::route`'s CC→EC bridge arm)
            let at = w.fabric.net.gateway_hop(now, bytes);
            w.fabric.net.wan_down(k, at, bytes)
        }
        ClusterRef::Ec(_) => {
            st.report
                .borrow_mut()
                .log(now, format!("ERROR no downlink for {node}'s cluster"));
            return;
        }
        ClusterRef::Cc => now,
    };
    let (topic, syms) = w.fabric.intern(&deploy_topic(node));
    let body: Rc<dyn Any> = Rc::new(InstructionBody { doc });
    let msg = GraphMsg { topic, syms, from: usize::MAX, wire_bytes: bytes, body };
    sch.push_at(arrival, Event::Bridge { origin: ClusterRef::Cc, to: site.cluster, msg });
    st.report.borrow_mut().log(
        now,
        format!("instruction → {node} ({} services, {bytes} B)", services.len()),
    );
}

/// Run one monitor sweep, then re-arm the next one until the horizon
/// (a single outstanding boxed Call per control plane).
fn sweep_chain(
    st: Rc<PlaneState>,
    period: SimTime,
    horizon: SimTime,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
) {
    monitor_sweep(&st, sch, w);
    let next = sch.now() + period;
    if next <= horizon {
        sch.push_at(
            next,
            Event::Call(Box::new(move |sch2: &mut SvcScheduler, w2: &mut SvcWorld| {
                sweep_chain(st, period, horizon, sch2, w2)
            })),
        );
    }
}

/// §4.2.1 monitoring + shielding: nodes whose heartbeat went stale are
/// marked Failed; every deployed app is then re-placed around them and
/// only the changed nodes receive new instructions.
fn monitor_sweep(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld) {
    let now = sch.now();
    let now_ms = to_millis(now);
    let timeout_ms = to_millis(st.failure_timeout);
    let mut shielded: Vec<AceId> = Vec::new();
    {
        let mut infra = st.infra.borrow_mut();
        let ready: Vec<AceId> = infra
            .all_nodes()
            .filter(|(_, n)| n.status == NodeStatus::Ready)
            .map(|(_, n)| n.id.clone())
            .collect();
        for id in ready {
            let key = id.to_string().replace('/', ".");
            let last = st
                .api
                .get(kinds::NODE_STATUS, &key)
                .and_then(|e| e.doc.get("last_seen_ms").as_f64());
            let stale = match last {
                Some(ms) => ms < now_ms - timeout_ms,
                // never seen at all: give it one full timeout of grace
                None => now_ms > timeout_ms,
            };
            if stale {
                if let Some(n) = infra.find_node_mut(&id) {
                    n.status = NodeStatus::Failed;
                }
                shielded.push(id);
            }
        }
    }
    if shielded.is_empty() {
        return;
    }
    for id in &shielded {
        let mut rep = st.report.borrow_mut();
        rep.shielded.push(id.to_string());
        rep.log(now, format!("monitor: heartbeat lost, node {id} shielded"));
    }
    let apps: Vec<(String, Topology, DeploymentPlan)> = st
        .apps
        .borrow()
        .iter()
        .map(|(a, (t, p))| (a.clone(), t.clone(), p.clone()))
        .collect();
    for (app, topo, old_plan) in apps {
        let new_plan =
            match orchestrator::place_with_net(&topo, &st.infra.borrow(), Some(&st.net_hints)) {
                Ok(p) => p,
                Err(e) => {
                    st.report
                        .borrow_mut()
                        .log(now, format!("ERROR re-placing '{app}' after shield: {e}"));
                    continue;
                }
            };
        let diff = diff_plans(&old_plan, &new_plan);
        if diff.is_noop() {
            continue;
        }
        let touched = diff.touched_nodes();
        {
            let mut rep = st.report.borrow_mut();
            rep.redeploys += 1;
            rep.log(
                now,
                format!(
                    "shield/redeploy '{app}': +{} -{} ~{} across {} nodes",
                    diff.add.len(),
                    diff.remove.len(),
                    diff.replace.len(),
                    touched.len()
                ),
            );
        }
        store_plan(st, &app, Some((topo, new_plan.clone())));
        for node in touched {
            send_node_instruction(st, sch, w, &node);
        }
        if let Some(hook) = &st.plan_hook {
            hook(&app, &new_plan);
        }
    }
}

/// What the agent believes one of its instances looks like.
#[derive(Debug, Clone, PartialEq)]
struct RunningInst {
    component: String,
    image: String,
    app: String,
}

/// The simulated node agent (§4.3.1): subscribed to its node's deploy
/// topic, converges running instances to each instruction, heartbeats
/// its status.
struct NodeAgent {
    state: Rc<PlaneState>,
    node: AceId,
    site: Site,
    deploy_filter: String,
    status_wire_topic: String,
    running: BTreeMap<String, RunningInst>,
}

impl NodeAgent {
    fn report_status(&self, ctx: &mut Ctx) {
        let instances: Vec<Value> = self
            .running
            .iter()
            .map(|(id, r)| {
                Value::obj(vec![
                    ("instance", Value::str(id)),
                    ("component", Value::str(&r.component)),
                    ("app", Value::str(&r.app)),
                    ("state", Value::str("running")),
                ])
            })
            .collect();
        let status = Value::obj(vec![
            ("node", Value::str(self.node.to_string())),
            ("instances", Value::Arr(instances)),
        ]);
        let payload = json::to_string(&status);
        let bytes = payload.len() as u64;
        ctx.publish(&self.status_wire_topic, bytes, Rc::new(StatusBody { json: payload }));
    }
}

impl Component for NodeAgent {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.deploy_filter.clone()]
    }

    fn on_start(&mut self, ctx: &mut Ctx) {
        // first heartbeat at registration, then periodically
        self.report_status(ctx);
        ctx.set_timer(self.state.heartbeat_period, 0);
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(ib) = msg.body_as::<InstructionBody>() else {
            return;
        };
        let Ok(doc) = yamlite::parse(&ib.doc) else {
            return; // malformed instruction: ignored, status unchanged
        };
        let mut target: BTreeMap<String, RunningInst> = BTreeMap::new();
        if let Some(obj) = doc.get("services").as_obj() {
            for (name, svc) in obj {
                target.insert(
                    name.clone(),
                    RunningInst {
                        component: svc
                            .get("labels")
                            .get("ace.component")
                            .as_str()
                            .unwrap_or(name)
                            .to_string(),
                        image: svc.get("image").as_str().unwrap_or("").to_string(),
                        app: svc.get("labels").get("ace.app").as_str().unwrap_or("").to_string(),
                    },
                );
            }
        }
        // converge DOWN: instances absent from the instruction (or with
        // a changed image — in-place redeploy) are stopped
        let stale: Vec<String> = self
            .running
            .iter()
            .filter(|(id, r)| {
                target
                    .get(id.as_str())
                    .is_none_or(|t| t.image != r.image || t.component != r.component)
            })
            .map(|(id, _)| id.clone())
            .collect();
        for id in stale {
            self.running.remove(&id);
            let st = self.state.clone();
            let node = self.node.clone();
            // the agent cannot mutate the component table from inside
            // its own callback: defer to the Call lane (same virtual
            // time, later sequence)
            ctx.call(0, move |sch, w| {
                if let Some(idx) = st.registry.borrow_mut().remove(&id) {
                    if w.retire(idx) {
                        let mut rep = st.report.borrow_mut();
                        rep.retired += 1;
                        rep.log(sch.now(), format!("agent {node}: stopped '{id}'"));
                    }
                }
            });
        }
        // converge UP: new instances are built through the factory
        for (id, t) in &target {
            if self.running.contains_key(id) {
                continue;
            }
            self.running.insert(id.clone(), t.clone());
            let st = self.state.clone();
            let inst = Instance {
                id: id.clone(),
                component: t.component.clone(),
                node: self.node.clone(),
                image: t.image.clone(),
            };
            let site = self.site.clone();
            let node = self.node.clone();
            ctx.call(0, move |sch, w| match (st.factory)(&inst, &site) {
                Ok(Some(c)) => {
                    let idx = w.spawn(sch, site.clone(), c);
                    st.registry.borrow_mut().insert(inst.id.clone(), idx);
                    let mut rep = st.report.borrow_mut();
                    rep.spawned += 1;
                    let line = format!("agent {node}: started '{}' ({})", inst.id, inst.image);
                    rep.log(sch.now(), line);
                }
                Ok(None) => {
                    let line = format!("agent {node}: '{}' not modelled, skipped", inst.id);
                    st.report.borrow_mut().log(sch.now(), line);
                }
                Err(e) => {
                    st.report
                        .borrow_mut()
                        .log(sch.now(), format!("ERROR agent {node}: spawning '{}': {e}", inst.id));
                }
            });
        }
        // immediate status report reflecting the convergence
        self.report_status(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        self.report_status(ctx);
        ctx.set_timer(self.state.heartbeat_period, 0);
    }
}

/// The monitoring service's ingest point (§4.2.1) as a CC component:
/// folds every status report into the API server with a VIRTUAL-time
/// heartbeat stamp the shielding sweep reads.
struct MonitorTap {
    state: Rc<PlaneState>,
}

impl Component for MonitorTap {
    fn subscriptions(&self) -> Vec<String> {
        vec![MONITOR_FILTER.to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(sb) = msg.body_as::<StatusBody>() else {
            return;
        };
        let Ok(v) = json::parse(&sb.json) else {
            return;
        };
        let node = v.get("node").as_str().unwrap_or("?").to_string();
        let key = node.replace('/', ".");
        let Value::Obj(mut obj) = v else {
            return;
        };
        obj.insert("last_seen_ms".to_string(), Value::num(to_millis(ctx.now())));
        self.state.api.put(kinds::NODE_STATUS, &key, Value::Obj(obj));
        self.state.report.borrow_mut().status_reports += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCENARIO: &str = "
duration: 20
ops:
  - at: 0
    op: deploy
    topology:
      app: mini
      version: 1
      components:
        - name: solo
          image: img:1
          location: cloud
  - at: 5
    op: update
    topology:
      app: mini
      version: 2
      components:
        - name: solo
          image: img:2
          location: cloud
  - at: 10
    op: fail-node
    node: infra-u/ec-1/rpi1
  - at: 15
    op: remove
    app: mini
";

    #[test]
    fn scenario_parses_all_op_kinds() {
        let s = LifecycleScenario::parse(SCENARIO).unwrap();
        assert_eq!(s.duration, secs(20.0));
        assert!(s.network.is_none(), "no network block in this script");
        assert_eq!(s.steps.len(), 4);
        assert_eq!(s.first_app(), Some("mini"));
        assert!(matches!(&s.steps[0].op, LifecycleOp::Deploy(t) if t.version == 1));
        assert!(matches!(&s.steps[1].op, LifecycleOp::Update(t) if t.version == 2
            && t.component("solo").unwrap().image == "img:2"));
        assert!(matches!(&s.steps[2].op, LifecycleOp::FailNode(n)
            if n.to_string() == "infra-u/ec-1/rpi1"));
        assert!(matches!(&s.steps[3].op, LifecycleOp::Remove(a) if a == "mini"));
        assert_eq!(s.steps[2].at, secs(10.0));
    }

    #[test]
    fn scenario_parses_network_overrides() {
        let s = LifecycleScenario::parse(
            "
duration: 5
network:
  cc_nodes: 2
  cc_lan_mbps: 1000
  nics:
    - cluster: ec-1
      node: rpi1
      mbps: 2
      delay_ms: 0.2
ops:
  - at: 0
    op: remove
    app: x
",
        )
        .unwrap();
        let net = s.network.expect("network block parsed");
        assert_eq!(net.cc_nodes, Some(2));
        assert_eq!(net.cc_lan_mbps, Some(1000.0));
        assert_eq!(net.nics.len(), 1);
        assert_eq!(net.nics[0].node, "rpi1");
        assert_eq!(net.nics[0].mbps, 2.0);
        // and a malformed block is an error, not silently ignored
        let bad = "
duration: 5
network:
  nics:
    - node: rpi1
ops:
  - at: 0
    op: remove
    app: x
";
        let err = LifecycleScenario::parse(bad).unwrap_err().to_string();
        assert!(err.contains("network"), "{err}");
    }

    #[test]
    fn scenario_rejects_garbage() {
        assert!(LifecycleScenario::parse("duration: 5\nops: []\n").is_err());
        assert!(LifecycleScenario::parse("ops:\n  - at: 0\n    op: deploy\n").is_err());
        let bad_op = "
duration: 5
ops:
  - at: 0
    op: reboot
";
        let err = LifecycleScenario::parse(bad_op).unwrap_err().to_string();
        assert!(err.contains("unknown op"), "{err}");
        let no_topo = "
duration: 5
ops:
  - at: 0
    op: deploy
";
        assert!(LifecycleScenario::parse(no_topo).is_err());
    }
}
