//! Virtual-time control plane: the platform manages application
//! lifecycle INSIDE the DES (Figure 4 steps ②→④ under virtual time).
//!
//! Before this module the threaded `platform::{Controller, Monitor}` /
//! `infra::Agent` ran only on the wall-clock broker plane, disconnected
//! from the `svcgraph` DES where applications actually execute. Here
//! the same ②→④ loop is simulated end to end:
//!
//! ```text
//! LifecycleScenario (yamlite script: deploy / update / fail-node /
//!                    remove at virtual times)
//!    │  Event::Call at each op's time
//!    ▼
//! ControlPlane  ── orchestrator::place ──► DeploymentPlan     (②)
//!    │  diff_plans vs the stored plan → per-node compose
//!    │  instructions (yamlite docs, the real wire format)
//!    ▼
//! `ace/deploy/<node>` on the node's cluster bus                (③)
//!    │  (downlink-charged for EC nodes — the platform reaches
//!    │   EC message services over the WAN, §4.3.2)
//!    ▼
//! NodeAgent (a simulated Component on every registered node)   (④)
//!    │  converges: SvcWorld::spawn / SvcWorld::retire via the
//!    │  Event::Call lane; heartbeats + instance status on
//!    │  `cloud/ace/status/<node>` (uplink-charged)
//!    ▼
//! MonitorTap on the CC ──► ApiServer `node-status` entities
//!    │  (virtual-ms heartbeat stamps)
//!    ▼
//! monitor sweep every P seconds: stale heartbeat ⇒ node shielded
//! (marked Failed) ⇒ re-place each app ⇒ diff ⇒ instructions to
//! touched nodes — the §4.2.1 shield/redeploy loop, deterministic.
//! ```
//!
//! Determinism: every step above is a DES event (ops and sweeps on the
//! boxed `Call` lane, transport on the typed lanes), so the same
//! scenario replays bit-identically; `tests/lifecycle.rs` pins the
//! trajectory hash. Components untouched by an op keep their exact
//! `(at, seq)` trajectories (see DESIGN.md §Control-plane).
//!
//! Scenario file format (yamlite; `ace svcrun --scenario <FILE>`):
//!
//! ```yaml
//! duration: 110          # virtual seconds to simulate
//! network:               # OPTIONAL NetFabric overrides (per-node
//!   cc_nodes: 2          # NICs, CC cluster shape, link shaping —
//!   nics:                # see simnet::NetOverrides for the grammar)
//!     - cluster: ec-1
//!       node: rpi1
//!       mbps: 2
//! ops:
//!   - at: 0              # virtual seconds
//!     op: deploy         # deploy | update | fail-node | remove
//!     topology:          # a full topology document, inline
//!       app: videoquery
//!       version: 1
//!       components:
//!         - name: od
//!           image: ace/object-detector:1
//!           ...
//!   - at: 60
//!     op: fail-node
//!     node: infra-cell/ec-1/minipc
//!   - at: 90
//!     op: remove
//!     app: videoquery
//! ```

use super::{
    site_of_node, ClusterRef, Component, Ctx, Event, GraphMsg, GraphRuntime, Site, SvcScheduler,
    SvcWorld,
};
use crate::deploy::{diff_plans, DeploymentPlan, Instance};
use crate::infra::agent::{ack_topic, compose_instruction_seq, deploy_topic, status_topic};
use crate::infra::{Infrastructure, NodeStatus};
use crate::json::{self, Value};
use crate::platform::api::{kinds, ApiServer};
use crate::platform::controller::plan_to_value;
use crate::platform::orchestrator::{self, NetHints};
use crate::simnet::faults::{FaultSpec, Verdict};
use crate::simnet::NetOverrides;
use crate::topology::Topology;
use crate::util::{secs, to_millis, to_secs, AceId, SimTime};
use crate::yamlite;
use anyhow::{anyhow, bail, Context, Result};
use std::any::Any;
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

/// Builds the component for a placed instance — the application half of
/// Figure 4 step ④. `None` means "not modelled" (the instance is
/// tracked by the platform but runs no DES logic).
pub type InstanceFactory = Rc<dyn Fn(&Instance, &Site) -> Result<Option<Box<dyn Component>>>>;

/// Called whenever the control plane stores a new plan for an app
/// (deploy, update, shield/redeploy, remove — remove passes an empty
/// plan). Lets applications track platform intent, e.g. fedtrain's
/// coordinator learning the live trainer count.
pub type PlanHook = Rc<dyn Fn(&str, &DeploymentPlan)>;

/// One scripted lifecycle operation.
#[derive(Debug, Clone)]
pub enum LifecycleOp {
    /// Submit a topology for a fresh application (§4.4.3).
    Deploy(Topology),
    /// Submit an updated topology: the controller diffs plans and only
    /// touches changed nodes (incremental update, §4.4.3).
    Update(Topology),
    /// Crash a node: everything running on it dies silently; the
    /// platform must NOTICE via missed heartbeats and shield it.
    FailNode(AceId),
    /// Bring a previously failed/shielded node back: mark it Ready,
    /// restart its agent, and re-place every app (plan rebalance).
    RejoinNode(AceId),
    /// Take a named shared link (`lan-ecN` / `up-ecN` / `down-ecN` /
    /// `lan-cc`) fully down for a duration: every delivery sent inside
    /// the window is dropped — platform traffic included.
    FailLink {
        link: String,
        /// Outage duration (µs).
        for_us: SimTime,
    },
    /// Re-shape a node's access link mid-run (partial degradation).
    DegradeNic { cluster: String, node: String, mbps: f64 },
    /// Remove a deployed application entirely.
    Remove(String),
}

/// A lifecycle op pinned to a virtual time.
#[derive(Debug, Clone)]
pub struct ScenarioStep {
    /// Virtual time (µs) the op is applied at.
    pub at: SimTime,
    /// The operation.
    pub op: LifecycleOp,
}

/// A scripted application-lifecycle scenario (see the module docs for
/// the yamlite file format).
#[derive(Debug, Clone)]
pub struct LifecycleScenario {
    /// Ops in script order (times need not be sorted; the DES orders
    /// them).
    pub steps: Vec<ScenarioStep>,
    /// Virtual horizon (µs): the run stops here.
    pub duration: SimTime,
    /// Optional `network:` overrides (per-node NICs, CC cluster shape,
    /// link shaping) the app driver applies to its base `NetConfig`.
    pub network: Option<NetOverrides>,
    /// Optional `faults:` block (seeded i.i.d. loss/duplication on
    /// every link) the app driver arms on its `NetFabric`.
    pub faults: Option<FaultSpec>,
}

impl LifecycleScenario {
    /// Parse a yamlite scenario document.
    pub fn parse(src: &str) -> Result<LifecycleScenario> {
        let doc = yamlite::parse(src).map_err(|e| anyhow!("{e}"))?;
        Self::from_value(&doc)
    }

    /// Build a scenario from an already-parsed yamlite/JSON value.
    ///
    /// Validation is loud and names WHERE: unknown top-level keys,
    /// unknown per-op keys, and non-monotonic `at:` times are errors
    /// carrying the op index and its virtual time — bad scripts fail
    /// here, not deep inside the DES.
    pub fn from_value(doc: &Value) -> Result<LifecycleScenario> {
        let top = doc.as_obj().context("scenario: expected a mapping")?;
        for key in top.keys() {
            // `app` is the svcgraph::scenario dispatch key; accepted
            // here so one document drives both layers
            if !matches!(key.as_str(), "app" | "duration" | "ops" | "network" | "faults") {
                bail!("scenario: unknown field '{key}' (app|duration|ops|network|faults)");
            }
        }
        let duration = secs(
            doc.get("duration")
                .as_f64()
                .context("scenario: missing 'duration' (virtual seconds)")?,
        );
        let ops = doc.get("ops").as_arr().context("scenario: missing 'ops'")?;
        let mut steps: Vec<ScenarioStep> = Vec::new();
        for (i, o) in ops.iter().enumerate() {
            let at_s = o
                .get("at")
                .as_f64()
                .with_context(|| format!("op #{i}: missing 'at' (virtual seconds)"))?;
            let at = secs(at_s);
            if let Some(prev) = steps.last() {
                if at < prev.at {
                    bail!(
                        "op #{i} at t={at_s}s: 'at' times must be non-decreasing \
                         (op #{} is at t={}s)",
                        i - 1,
                        to_secs(prev.at)
                    );
                }
            }
            let kind = o
                .get("op")
                .as_str()
                .with_context(|| format!("op #{i} at t={at_s}s: missing 'op'"))?;
            // every op accepts exactly {at, op} + its own fields; a
            // stray key is a loud error naming the op
            let allowed: &[&str] = match kind {
                "deploy" | "update" => &["topology"],
                "fail-node" | "rejoin-node" => &["node"],
                "fail-link" => &["link", "for"],
                "degrade-nic" => &["cluster", "node", "mbps"],
                "remove" => &["app"],
                other => bail!(
                    "op #{i} at t={at_s}s: unknown op '{other}' \
                     (deploy|update|fail-node|rejoin-node|fail-link|degrade-nic|remove)"
                ),
            };
            if let Some(obj) = o.as_obj() {
                for key in obj.keys() {
                    if key != "at" && key != "op" && !allowed.contains(&key.as_str()) {
                        bail!(
                            "op #{i} ('{kind}' at t={at_s}s): unknown field '{key}' \
                             (expected {allowed:?})"
                        );
                    }
                }
            }
            let node_field = || -> Result<AceId> {
                Ok(AceId::parse(o.get("node").as_str().with_context(|| {
                    format!("op #{i} ('{kind}' at t={at_s}s): missing 'node'")
                })?))
            };
            let op = match kind {
                "deploy" | "update" => {
                    let topo = Topology::from_value(o.get("topology"))
                        .with_context(|| format!("op #{i} at t={at_s}s: bad 'topology'"))?;
                    if kind == "deploy" {
                        LifecycleOp::Deploy(topo)
                    } else {
                        LifecycleOp::Update(topo)
                    }
                }
                "fail-node" => LifecycleOp::FailNode(node_field()?),
                "rejoin-node" => LifecycleOp::RejoinNode(node_field()?),
                "fail-link" => {
                    let link = o
                        .get("link")
                        .as_str()
                        .with_context(|| format!("op #{i} at t={at_s}s: missing 'link'"))?
                        .to_string();
                    let for_s = o
                        .get("for")
                        .as_f64()
                        .with_context(|| {
                            format!("op #{i} at t={at_s}s: missing 'for' (outage seconds)")
                        })?;
                    if !(for_s.is_finite() && for_s > 0.0) {
                        bail!("op #{i} at t={at_s}s: 'for' must be positive, got {for_s}");
                    }
                    LifecycleOp::FailLink { link, for_us: secs(for_s) }
                }
                "degrade-nic" => {
                    let cluster = o
                        .get("cluster")
                        .as_str()
                        .with_context(|| format!("op #{i} at t={at_s}s: missing 'cluster'"))?
                        .to_string();
                    let node = o
                        .get("node")
                        .as_str()
                        .with_context(|| format!("op #{i} at t={at_s}s: missing 'node'"))?
                        .to_string();
                    let mbps = o
                        .get("mbps")
                        .as_f64()
                        .with_context(|| format!("op #{i} at t={at_s}s: missing 'mbps'"))?;
                    LifecycleOp::DegradeNic { cluster, node, mbps }
                }
                "remove" => LifecycleOp::Remove(
                    o.get("app")
                        .as_str()
                        .with_context(|| format!("op #{i} at t={at_s}s: missing 'app'"))?
                        .to_string(),
                ),
                _ => unreachable!("kind validated above"),
            };
            steps.push(ScenarioStep { at, op });
        }
        if steps.is_empty() {
            bail!("scenario has no ops");
        }
        let network = match doc.get("network") {
            Value::Null => None,
            v => Some(NetOverrides::from_value(v).context("scenario: bad 'network'")?),
        };
        let faults = match doc.get("faults") {
            Value::Null => None,
            v => Some(FaultSpec::from_value(v).context("scenario: bad 'faults'")?),
        };
        Ok(LifecycleScenario { steps, duration, network, faults })
    }

    /// App named by the first deploy/update op (CLI dispatch).
    pub fn first_app(&self) -> Option<&str> {
        self.steps.iter().find_map(|s| match &s.op {
            LifecycleOp::Deploy(t) | LifecycleOp::Update(t) => Some(t.app.as_str()),
            _ => None,
        })
    }
}

/// Timing knobs of the simulated platform services.
#[derive(Debug, Clone, Copy)]
pub struct ControlPlaneConfig {
    /// Agent heartbeat period (virtual seconds).
    pub heartbeat_period_s: f64,
    /// A node whose last heartbeat is older than this is shielded.
    pub failure_timeout_s: f64,
    /// Monitor sweep period (virtual seconds).
    pub sweep_period_s: f64,
    /// First instruction-retry delay (virtual seconds); each further
    /// attempt doubles it up to `retry_cap_s` (at-least-once channel).
    pub retry_base_s: f64,
    /// Ceiling on the exponential retry backoff (virtual seconds).
    pub retry_cap_s: f64,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        ControlPlaneConfig {
            heartbeat_period_s: 2.0,
            failure_timeout_s: 5.0,
            sweep_period_s: 5.0,
            retry_base_s: 0.5,
            retry_cap_s: 8.0,
        }
    }
}

/// Give up redelivering an instruction after this many sends (the node
/// is almost certainly dead; the monitor sweep will shield it anyway).
const MAX_SEND_ATTEMPTS: u32 = 10;

/// Deterministic audit trail of everything the control plane did —
/// hashed by the lifecycle goldens.
#[derive(Debug, Clone, Default)]
pub struct LifecycleReport {
    /// `(virtual µs, event)` in execution order.
    pub events: Vec<(SimTime, String)>,
    /// Component instances started by agents.
    pub spawned: u64,
    /// Component instances stopped (converged away, or died with their
    /// node).
    pub retired: u64,
    /// Status reports ingested by the monitor tap.
    pub status_reports: u64,
    /// Nodes shielded after missed heartbeats, in shield order.
    pub shielded: Vec<String>,
    /// Shield-triggered re-placements that changed a plan.
    pub redeploys: u64,
    /// Instruction retries sent by the at-least-once channel.
    pub retries: u64,
    /// Redelivered instructions the agents suppressed by seq-dedupe.
    pub dup_suppressed: u64,
    /// Messages the fault plane dropped (merged from the `NetFabric`
    /// counters by the app driver after the run).
    pub msgs_lost: u64,
    /// Convergence samples (virtual µs): fault injected → every
    /// outstanding instruction acked, one entry per completed
    /// fault/recovery episode.
    pub convergence_us: Vec<SimTime>,
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

impl LifecycleReport {
    fn log(&mut self, at: SimTime, msg: String) {
        self.events.push((at, msg));
    }

    /// FNV digest over the full audit trail (times, messages,
    /// counters) — two runs of the same scenario must agree.
    pub fn hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (at, msg) in &self.events {
            fnv(&mut h, &at.to_le_bytes());
            fnv(&mut h, msg.as_bytes());
        }
        for v in [
            self.spawned,
            self.retired,
            self.status_reports,
            self.redeploys,
            self.retries,
            self.dup_suppressed,
            self.msgs_lost,
        ] {
            fnv(&mut h, &v.to_le_bytes());
        }
        for s in &self.shielded {
            fnv(&mut h, s.as_bytes());
        }
        for c in &self.convergence_us {
            fnv(&mut h, &c.to_le_bytes());
        }
        h
    }

    /// Worst (largest) convergence sample in virtual ms, if any fault
    /// episode completed — the headline churn metric.
    pub fn max_convergence_ms(&self) -> Option<f64> {
        self.convergence_us.iter().max().map(|&us| to_millis(us))
    }
}

/// Shared control-plane state, reachable from scenario `Call` closures
/// and the simulated agents/monitor alike.
struct PlaneState {
    api: ApiServer,
    infra: RefCell<Infrastructure>,
    factory: InstanceFactory,
    plan_hook: Option<PlanHook>,
    /// app → (submitted topology, current plan).
    apps: RefCell<BTreeMap<String, (Topology, DeploymentPlan)>>,
    /// instance id → live component index.
    registry: RefCell<BTreeMap<String, usize>>,
    /// node → its agent's component index (removed when the node dies).
    agents: RefCell<BTreeMap<AceId, usize>>,
    report: RefCell<LifecycleReport>,
    heartbeat_period: SimTime,
    failure_timeout: SimTime,
    /// Per-node NIC bandwidths for network-aware placement (degenerate
    /// hints reproduce the CPU-spread-only scoring byte-for-byte).
    net_hints: NetHints,
    /// Monotonic instruction sequence number (at-least-once channel):
    /// every rendered compose doc carries the next value.
    instr_seq: Cell<u64>,
    /// node → its newest unacked instruction. An entry is cleared by a
    /// matching ack, a give-up, or the node being failed/shielded.
    pending: RefCell<BTreeMap<AceId, PendingInstr>>,
    /// Start of the oldest unresolved fault episode: set by fail-node /
    /// rejoin-node / shield, cleared (into a convergence sample) when
    /// `pending` drains.
    fault_at: Cell<Option<SimTime>>,
    /// First retry delay (µs); doubles per attempt up to `retry_cap`.
    retry_base: SimTime,
    retry_cap: SimTime,
}

/// One node's outstanding (sent, not yet acked) instruction.
#[derive(Debug, Clone, Copy)]
struct PendingInstr {
    /// Sequence number stamped into the compose doc.
    seq: u64,
    /// Send attempts so far for this convergence target (0 = first).
    attempt: u32,
}

/// Handle onto an installed control plane (post-run inspection).
pub struct ControlPlane {
    state: Rc<PlaneState>,
}

/// Status reports cross the wire as JSON (the threaded plane's format).
struct StatusBody {
    json: String,
}

/// Deployment instructions cross the wire as compose-style yamlite —
/// the same documents `infra::agent::compose_instruction` renders for
/// the threaded plane.
struct InstructionBody {
    doc: String,
}

/// Instruction acknowledgements (at-least-once channel): agents
/// publish `{node, seq}` on `cloud/ace/ack/<node>` after converging.
struct AckBody {
    node: AceId,
    seq: u64,
}

/// Topic filter the CC monitor tap listens on: EC agents publish
/// `cloud/ace/status/<node>` so reports ride the existing `cloud/#`
/// uplink bridge.
const MONITOR_FILTER: &str = "cloud/ace/status/#";

/// Topic filter the CC ack tap listens on (same `cloud/#` bridge).
const ACK_FILTER: &str = "cloud/ace/ack/#";

impl ControlPlane {
    /// Install the control plane into a NOT-yet-started runtime: one
    /// node-agent component per registered node, a monitor tap on the
    /// CC, every scenario op as a `Call` event at its time, and
    /// recurring monitor sweeps until the scenario horizon. Placement
    /// (initial and shield/redeploy) scores through `net_hints` —
    /// derive them from the runtime's `NetFabric` so the orchestrator
    /// sees the same access links the transport charges. Drive the
    /// runtime with `run_until(scenario.duration)` afterwards.
    pub fn install(
        rt: &mut GraphRuntime,
        infra: Infrastructure,
        factory: InstanceFactory,
        plan_hook: Option<PlanHook>,
        scenario: &LifecycleScenario,
        cfg: ControlPlaneConfig,
        net_hints: NetHints,
    ) -> Result<ControlPlane> {
        anyhow::ensure!(
            cfg.heartbeat_period_s > 0.0 && cfg.failure_timeout_s > 0.0 && cfg.sweep_period_s > 0.0,
            "control-plane periods must be positive"
        );
        anyhow::ensure!(
            cfg.retry_base_s > 0.0 && cfg.retry_cap_s >= cfg.retry_base_s,
            "retry backoff must be positive and capped at >= the base"
        );
        let state = Rc::new(PlaneState {
            api: ApiServer::new(),
            infra: RefCell::new(infra),
            factory,
            plan_hook,
            apps: RefCell::new(BTreeMap::new()),
            registry: RefCell::new(BTreeMap::new()),
            agents: RefCell::new(BTreeMap::new()),
            report: RefCell::new(LifecycleReport::default()),
            heartbeat_period: secs(cfg.heartbeat_period_s),
            failure_timeout: secs(cfg.failure_timeout_s),
            net_hints,
            instr_seq: Cell::new(0),
            pending: RefCell::new(BTreeMap::new()),
            fault_at: Cell::new(None),
            retry_base: secs(cfg.retry_base_s).max(1),
            retry_cap: secs(cfg.retry_cap_s).max(1),
        });
        // one agent per registered node (§4.3.1: agents are deployed at
        // node registration, before any application exists)
        let nodes: Vec<AceId> = state
            .infra
            .borrow()
            .all_nodes()
            .map(|(_, n)| n.id.clone())
            .collect();
        for node in nodes {
            let agent = NodeAgent::new(state.clone(), node.clone())?;
            let site = agent.site.clone();
            let idx = rt.add(site, Box::new(agent));
            state.agents.borrow_mut().insert(node, idx);
        }
        // the monitoring service's ingest point on the CC, plus the
        // at-least-once channel's ack sink next to it
        let tap_node: Rc<str> = state
            .infra
            .borrow()
            .cc
            .nodes
            .first()
            .map(|n| n.id.leaf().into())
            .unwrap_or_else(|| "monitor".into());
        rt.add(
            Site { cluster: ClusterRef::Cc, node: tap_node.clone() },
            Box::new(MonitorTap { state: state.clone() }),
        );
        rt.add(
            Site { cluster: ClusterRef::Cc, node: tap_node },
            Box::new(AckTap { state: state.clone() }),
        );
        // scripted ops ride the closure lane at their virtual times
        for step in &scenario.steps {
            let st = state.clone();
            let op = step.op.clone();
            rt.at(step.at, move |sch, w| apply_op(&st, sch, w, op));
        }
        // recurring monitor sweeps (§4.2.1 failure shielding): ONE
        // self-rescheduling Call keeps exactly one sweep event in the
        // heap at a time, however long the scenario runs. Min 1 µs so
        // a degenerate period can never loop in place.
        let sweep = secs(cfg.sweep_period_s).max(1);
        if sweep <= scenario.duration {
            let st = state.clone();
            let horizon = scenario.duration;
            rt.at(sweep, move |sch, w| sweep_chain(st, sweep, horizon, sch, w));
        }
        Ok(ControlPlane { state })
    }

    /// The platform's entity store (plans, app states, node statuses).
    pub fn api(&self) -> ApiServer {
        self.state.api.clone()
    }

    /// Snapshot of the audit trail.
    pub fn report(&self) -> LifecycleReport {
        self.state.report.borrow().clone()
    }

    /// Current stored plan for `app`, if deployed.
    pub fn plan(&self, app: &str) -> Option<DeploymentPlan> {
        self.state.apps.borrow().get(app).map(|(_, p)| p.clone())
    }

    /// Snapshot of the (possibly shielded) infrastructure.
    pub fn infra(&self) -> Infrastructure {
        self.state.infra.borrow().clone()
    }
}

fn apply_op(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, op: LifecycleOp) {
    match op {
        LifecycleOp::Deploy(topo) | LifecycleOp::Update(topo) => submit_topology(st, sch, w, topo),
        LifecycleOp::FailNode(node) => fail_node(st, sch, w, &node),
        LifecycleOp::RejoinNode(node) => rejoin_node(st, sch, w, &node),
        LifecycleOp::FailLink { link, for_us } => {
            let now = sch.now();
            match w.fabric.net.fail_link(&link, now, now + for_us) {
                Ok(()) => st.report.borrow_mut().log(
                    now,
                    format!("FAULT injected: link {link} down for {}s", to_secs(for_us)),
                ),
                Err(e) => st.report.borrow_mut().log(now, format!("ERROR {e}")),
            }
        }
        LifecycleOp::DegradeNic { cluster, node, mbps } => {
            let now = sch.now();
            match w.fabric.net.degrade_nic(&cluster, &node, mbps) {
                Ok(()) => {
                    // the op may have CREATED a NIC for a previously
                    // unshaped node: re-resolve the cached slots
                    w.fabric.refresh_nic_slots();
                    st.report.borrow_mut().log(
                        now,
                        format!("FAULT injected: NIC {cluster}/{node} reshaped to {mbps} Mbps"),
                    )
                }
                Err(e) => st.report.borrow_mut().log(now, format!("ERROR {e}")),
            }
        }
        LifecycleOp::Remove(app) => remove_app(st, sch, w, &app),
    }
}

/// §4.4.3: submitting a topology deploys the app if new, otherwise
/// triggers an incremental update (diff the plans, touch only changed
/// nodes).
fn submit_topology(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, topo: Topology) {
    let now = sch.now();
    let new_plan =
        match orchestrator::place_with_net(&topo, &st.infra.borrow(), Some(&st.net_hints)) {
            Ok(p) => p,
            Err(e) => {
                st.report
                    .borrow_mut()
                    .log(now, format!("ERROR placing '{}' v{}: {e}", topo.app, topo.version));
                return;
            }
        };
    let old = st.apps.borrow().get(&topo.app).map(|(_, p)| p.clone());
    let touched: Vec<AceId> = match &old {
        None => {
            st.report.borrow_mut().log(
                now,
                format!(
                    "deploy '{}' v{}: {} instances placed",
                    topo.app,
                    topo.version,
                    new_plan.instances.len()
                ),
            );
            new_plan.nodes()
        }
        Some(old_plan) => {
            let diff = diff_plans(old_plan, &new_plan);
            let touched = diff.touched_nodes();
            st.report.borrow_mut().log(
                now,
                format!(
                    "update '{}' v{}: +{} -{} ~{}, {} nodes touched",
                    topo.app,
                    topo.version,
                    diff.add.len(),
                    diff.remove.len(),
                    diff.replace.len(),
                    touched.len()
                ),
            );
            touched
        }
    };
    store_plan(st, &topo.app, Some((topo.clone(), new_plan.clone())));
    for node in &touched {
        send_node_instruction(st, sch, w, node);
    }
    if let Some(hook) = &st.plan_hook {
        hook(&topo.app, &new_plan);
    }
}

/// Crash a node: the agent and every application instance on it die
/// silently. The platform only learns of it through missed heartbeats.
fn fail_node(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, node: &AceId) {
    let now = sch.now();
    // idempotence: a node that is already shielded (or cordoned) has
    // nothing left to kill — a second fail-node must NOT queue another
    // shield/redeploy pass
    let status = st.infra.borrow().find_node(node).map(|n| n.status);
    if matches!(status, Some(NodeStatus::Failed) | Some(NodeStatus::Cordoned)) {
        st.report
            .borrow_mut()
            .log(now, format!("fail-node {node}: already shielded, no-op"));
        return;
    }
    st.report
        .borrow_mut()
        .log(now, format!("FAULT injected: node {node} crashes"));
    if st.fault_at.get().is_none() {
        st.fault_at.set(Some(now));
    }
    // an unacked instruction to a crashed node will never be acked:
    // drop it so the retry loop gives up immediately
    st.pending.borrow_mut().remove(node);
    if let Some(agent_idx) = st.agents.borrow_mut().remove(node) {
        w.retire(agent_idx);
    }
    let Ok(site) = site_of_node(node) else { return };
    let dead: Vec<(String, usize)> = st
        .registry
        .borrow()
        .iter()
        .filter(|(_, &idx)| w.component_site(idx).is_some_and(|s| *s == site))
        .map(|(id, &idx)| (id.clone(), idx))
        .collect();
    for (id, idx) in dead {
        w.retire(idx);
        st.registry.borrow_mut().remove(&id);
        let mut rep = st.report.borrow_mut();
        rep.retired += 1;
        rep.log(now, format!("instance '{id}' died with {node}"));
    }
}

/// Bring a previously failed node back (the REJOIN half of §4.2.1
/// churn): mark it Ready, re-stamp its heartbeat so the very next
/// sweep cannot instantly re-shield it, restart its agent (clean
/// state — a rebooted node runs nothing and has seen no seq), and
/// re-place every app so the planner can rebalance onto it.
fn rejoin_node(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, node: &AceId) {
    let now = sch.now();
    let status = st.infra.borrow().find_node(node).map(|n| n.status);
    match status {
        None => {
            st.report
                .borrow_mut()
                .log(now, format!("ERROR rejoin-node: unknown node {node}"));
            return;
        }
        Some(NodeStatus::Ready) => {
            // idempotence mirror of fail-node: rejoining a live node
            // must not queue a redundant rebalance pass
            st.report
                .borrow_mut()
                .log(now, format!("rejoin-node {node}: already Ready, no-op"));
            return;
        }
        Some(_) => {}
    }
    let Ok(agent) = NodeAgent::new(st.clone(), node.clone()) else {
        st.report
            .borrow_mut()
            .log(now, format!("ERROR rejoin-node: malformed node id {node}"));
        return;
    };
    if let Some(n) = st.infra.borrow_mut().find_node_mut(node) {
        n.status = NodeStatus::Ready;
    }
    // the rejoin trap: the sweep only scans Ready nodes, so without a
    // fresh stamp the node's pre-crash heartbeat age would re-shield
    // it on the very next sweep, before its restarted agent's first
    // status report crosses the WAN
    let key = node.to_string().replace('/', ".");
    st.api.put(
        kinds::NODE_STATUS,
        &key,
        Value::obj(vec![
            ("node", Value::str(node.to_string())),
            ("last_seen_ms", Value::num(to_millis(now))),
        ]),
    );
    // stale in-flight instructions addressed to the pre-crash agent
    // are drained: the fresh agent starts at seq 0 and the next
    // convergence pass below re-renders current intent under a new seq
    st.pending.borrow_mut().remove(node);
    if st.fault_at.get().is_none() {
        st.fault_at.set(Some(now));
    }
    st.report
        .borrow_mut()
        .log(now, format!("rejoin: node {node} back, agent restarted"));
    let site = agent.site.clone();
    let idx = w.spawn(sch, site, Box::new(agent));
    st.agents.borrow_mut().insert(node.clone(), idx);
    // re-place every app around the recovered capacity (plan
    // rebalance through the same diff/instruction path as shielding)
    let apps: Vec<(String, Topology, DeploymentPlan)> = st
        .apps
        .borrow()
        .iter()
        .map(|(a, (t, p))| (a.clone(), t.clone(), p.clone()))
        .collect();
    for (app, topo, old_plan) in apps {
        let new_plan =
            match orchestrator::place_with_net(&topo, &st.infra.borrow(), Some(&st.net_hints)) {
                Ok(p) => p,
                Err(e) => {
                    st.report
                        .borrow_mut()
                        .log(now, format!("ERROR re-placing '{app}' after rejoin: {e}"));
                    continue;
                }
            };
        let diff = diff_plans(&old_plan, &new_plan);
        if diff.is_noop() {
            continue;
        }
        let touched = diff.touched_nodes();
        {
            let mut rep = st.report.borrow_mut();
            rep.redeploys += 1;
            rep.log(
                now,
                format!(
                    "rejoin/rebalance '{app}': +{} -{} ~{} across {} nodes",
                    diff.add.len(),
                    diff.remove.len(),
                    diff.replace.len(),
                    touched.len()
                ),
            );
        }
        store_plan(st, &app, Some((topo, new_plan.clone())));
        for n in touched {
            send_node_instruction(st, sch, w, &n);
        }
        if let Some(hook) = &st.plan_hook {
            hook(&app, &new_plan);
        }
    }
}

fn remove_app(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld, app: &str) {
    let now = sch.now();
    let Some(plan) = st.apps.borrow().get(app).map(|(_, p)| p.clone()) else {
        st.report
            .borrow_mut()
            .log(now, format!("ERROR remove '{app}': not deployed"));
        return;
    };
    store_plan(st, app, None);
    st.report.borrow_mut().log(
        now,
        format!("remove '{app}': {} instances wound down", plan.instances.len()),
    );
    for node in plan.nodes() {
        send_node_instruction(st, sch, w, &node);
    }
    if let Some(hook) = &st.plan_hook {
        hook(
            app,
            &DeploymentPlan { app: app.to_string(), version: plan.version, instances: Vec::new() },
        );
    }
}

/// Persist (or clear) an app's topology + plan in the state and the
/// API server (the dashboard/CLI view of platform intent).
fn store_plan(st: &Rc<PlaneState>, app: &str, entry: Option<(Topology, DeploymentPlan)>) {
    match entry {
        Some((topo, plan)) => {
            st.api.put(kinds::PLAN, app, plan_to_value(&plan));
            st.api.put(
                kinds::APP,
                app,
                Value::obj(vec![
                    ("state", Value::str("deployed")),
                    ("version", Value::num(plan.version as f64)),
                ]),
            );
            st.apps.borrow_mut().insert(app.to_string(), (topo, plan));
        }
        None => {
            let _ = st.api.delete(kinds::PLAN, app);
            let _ = st.api.delete(kinds::APP, app);
            st.apps.borrow_mut().remove(app);
        }
    }
}

/// Figure 4 step ③: render the node's full convergent instruction
/// (every instance of every stored app bound to it) as a compose
/// document and deliver it on the node's cluster bus, charging the EC
/// downlink — the platform reaches EC message services over the WAN.
///
/// Known limitation (shared with the threaded controller's
/// `sync_node`): `compose_instruction` stamps ONE app label on the
/// whole document, so when instances of several apps co-locate on a
/// node, status reports attribute them all to the first app.
fn send_node_instruction(
    st: &Rc<PlaneState>,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
    node: &AceId,
) {
    dispatch_instruction(st, sch, w, node, 0);
}

/// Render + send attempt number `attempt` of the node's convergent
/// instruction. Every send (first or retry) re-renders CURRENT intent
/// under a FRESH seq — retries are convergent, never a stale replay —
/// records the node as pending, and arms a backoff retry timer.
fn dispatch_instruction(
    st: &Rc<PlaneState>,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
    node: &AceId,
    attempt: u32,
) {
    let now = sch.now();
    let mut services: Vec<(String, String, String)> = Vec::new();
    let mut app_label = String::new();
    for (app, (_topo, plan)) in st.apps.borrow().iter() {
        for inst in &plan.instances {
            if &inst.node == node {
                services.push((inst.id.clone(), inst.component.clone(), inst.image.clone()));
                if app_label.is_empty() {
                    app_label = app.clone();
                }
            }
        }
    }
    let seq = st.instr_seq.get() + 1;
    st.instr_seq.set(seq);
    let doc = compose_instruction_seq(&app_label, &services, seq);
    let Ok(site) = site_of_node(node) else {
        st.report
            .borrow_mut()
            .log(now, format!("ERROR instruction for malformed node id {node}"));
        return;
    };
    st.pending
        .borrow_mut()
        .insert(node.clone(), PendingInstr { seq, attempt });
    let bytes = doc.len() as u64;
    // the WAN downlink is charged here; the Bridge delivery then pays
    // the TARGET NODE's access link in `Fabric::route` (bridge-arrival
    // ingress), so instructions contend on the real node's NIC. The
    // fault plane rules on the downlink delivery the same way
    // `Fabric::route` rules on bridged app traffic — the platform's
    // own channel is NOT exempt from loss.
    let (arrival, verdict) = match site.cluster {
        ClusterRef::Ec(k) if k < w.fabric.net.num_ecs() => {
            // CC backbone LAN out to the border router first, then the
            // downlink (mirrors `Fabric::route`'s CC→EC bridge arm)
            let at = w.fabric.net.gateway_hop(now, bytes);
            (w.fabric.net.wan_down(k, at, bytes), w.fabric.net.down_verdict(k, at))
        }
        ClusterRef::Ec(_) => {
            st.report
                .borrow_mut()
                .log(now, format!("ERROR no downlink for {node}'s cluster"));
            return;
        }
        // CC-local instructions never cross a fault-bearing link
        ClusterRef::Cc => (now, Verdict::Deliver),
    };
    if verdict != Verdict::Drop {
        let (topic, syms) = w.fabric.intern(&deploy_topic(node));
        let body: Rc<dyn Any> = Rc::new(InstructionBody { doc });
        let msg = GraphMsg { topic, syms, from: usize::MAX, wire_bytes: bytes, body };
        if verdict == Verdict::Duplicate {
            let dup = msg.clone();
            sch.push_at(
                arrival,
                Event::Bridge { origin: ClusterRef::Cc, to: site.cluster, msg: dup },
            );
        }
        sch.push_at(arrival, Event::Bridge { origin: ClusterRef::Cc, to: site.cluster, msg });
    }
    // the controller cannot see the verdict: it logs the send and
    // relies on the ack/retry loop either way
    st.report.borrow_mut().log(
        now,
        format!("instruction → {node} ({} services, {bytes} B)", services.len()),
    );
    arm_retry(st, sch, node.clone(), seq);
}

/// Exponential backoff for attempt `n` (0-based): `base * 2^n`, capped.
fn backoff(base: SimTime, cap: SimTime, attempt: u32) -> SimTime {
    base.saturating_mul(1u64 << attempt.min(30)).min(cap)
}

/// Arm the retry timer for the instruction just sent: if the node has
/// not acked seq >= `seq` by then, re-send (with doubled backoff) up
/// to [`MAX_SEND_ATTEMPTS`] total attempts.
fn arm_retry(st: &Rc<PlaneState>, sch: &mut SvcScheduler, node: AceId, seq: u64) {
    let attempt = match st.pending.borrow().get(&node) {
        Some(p) if p.seq == seq => p.attempt,
        _ => return,
    };
    let delay = backoff(st.retry_base, st.retry_cap, attempt);
    let stc = st.clone();
    sch.push_at(
        sch.now() + delay,
        Event::Call(Box::new(move |sch2: &mut SvcScheduler, w2: &mut SvcWorld| {
            retry_instruction(&stc, sch2, w2, &node, seq);
        })),
    );
}

/// The retry timer body: abandoned when the instruction was acked,
/// superseded by a newer send (which armed its own timer), or the
/// node was failed/shielded in the meantime.
fn retry_instruction(
    st: &Rc<PlaneState>,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
    node: &AceId,
    seq: u64,
) {
    let now = sch.now();
    let current = match st.pending.borrow().get(node) {
        Some(p) if p.seq == seq => *p,
        _ => return, // acked, cancelled, or superseded
    };
    if current.attempt + 1 >= MAX_SEND_ATTEMPTS {
        st.pending.borrow_mut().remove(node);
        st.report.borrow_mut().log(
            now,
            format!(
                "ERROR instruction to {node} undeliverable after {} attempts",
                current.attempt + 1
            ),
        );
        return;
    }
    {
        let mut rep = st.report.borrow_mut();
        rep.retries += 1;
        rep.log(now, format!("retry #{}: instruction → {node}", current.attempt + 1));
    }
    dispatch_instruction(st, sch, w, node, current.attempt + 1);
}

/// Record a convergence sample when the LAST outstanding instruction
/// of a fault episode is acked (or cancelled with the faulty node).
fn note_converged(st: &Rc<PlaneState>, now: SimTime) {
    if !st.pending.borrow().is_empty() {
        return;
    }
    if let Some(t0) = st.fault_at.get() {
        st.fault_at.set(None);
        let mut rep = st.report.borrow_mut();
        rep.convergence_us.push(now - t0);
        rep.log(
            now,
            format!("converged: all instructions acked {:.1} ms after fault", to_millis(now - t0)),
        );
    }
}

/// Run one monitor sweep, then re-arm the next one until the horizon
/// (a single outstanding boxed Call per control plane).
fn sweep_chain(
    st: Rc<PlaneState>,
    period: SimTime,
    horizon: SimTime,
    sch: &mut SvcScheduler,
    w: &mut SvcWorld,
) {
    monitor_sweep(&st, sch, w);
    let next = sch.now() + period;
    if next <= horizon {
        sch.push_at(
            next,
            Event::Call(Box::new(move |sch2: &mut SvcScheduler, w2: &mut SvcWorld| {
                sweep_chain(st, period, horizon, sch2, w2)
            })),
        );
    }
}

/// §4.2.1 monitoring + shielding: nodes whose heartbeat went stale are
/// marked Failed; every deployed app is then re-placed around them and
/// only the changed nodes receive new instructions.
fn monitor_sweep(st: &Rc<PlaneState>, sch: &mut SvcScheduler, w: &mut SvcWorld) {
    let now = sch.now();
    let now_ms = to_millis(now);
    let timeout_ms = to_millis(st.failure_timeout);
    let mut shielded: Vec<AceId> = Vec::new();
    {
        let mut infra = st.infra.borrow_mut();
        let ready: Vec<AceId> = infra
            .all_nodes()
            .filter(|(_, n)| n.status == NodeStatus::Ready)
            .map(|(_, n)| n.id.clone())
            .collect();
        for id in ready {
            let key = id.to_string().replace('/', ".");
            let last = st
                .api
                .get(kinds::NODE_STATUS, &key)
                .and_then(|e| e.doc.get("last_seen_ms").as_f64());
            let stale = match last {
                Some(ms) => ms < now_ms - timeout_ms,
                // never seen at all: give it one full timeout of grace
                None => now_ms > timeout_ms,
            };
            if stale {
                if let Some(n) = infra.find_node_mut(&id) {
                    n.status = NodeStatus::Failed;
                }
                shielded.push(id);
            }
        }
    }
    if shielded.is_empty() {
        return;
    }
    if st.fault_at.get().is_none() {
        st.fault_at.set(Some(now));
    }
    for id in &shielded {
        {
            let mut rep = st.report.borrow_mut();
            rep.shielded.push(id.to_string());
            rep.log(now, format!("monitor: heartbeat lost, node {id} shielded"));
        }
        // an unacked instruction to a shielded node will never ack:
        // cancel it so the episode can converge on the survivors
        st.pending.borrow_mut().remove(id);
    }
    let apps: Vec<(String, Topology, DeploymentPlan)> = st
        .apps
        .borrow()
        .iter()
        .map(|(a, (t, p))| (a.clone(), t.clone(), p.clone()))
        .collect();
    for (app, topo, old_plan) in apps {
        let new_plan =
            match orchestrator::place_with_net(&topo, &st.infra.borrow(), Some(&st.net_hints)) {
                Ok(p) => p,
                Err(e) => {
                    st.report
                        .borrow_mut()
                        .log(now, format!("ERROR re-placing '{app}' after shield: {e}"));
                    continue;
                }
            };
        let diff = diff_plans(&old_plan, &new_plan);
        if diff.is_noop() {
            continue;
        }
        let touched = diff.touched_nodes();
        {
            let mut rep = st.report.borrow_mut();
            rep.redeploys += 1;
            rep.log(
                now,
                format!(
                    "shield/redeploy '{app}': +{} -{} ~{} across {} nodes",
                    diff.add.len(),
                    diff.remove.len(),
                    diff.replace.len(),
                    touched.len()
                ),
            );
        }
        store_plan(st, &app, Some((topo, new_plan.clone())));
        for node in touched {
            send_node_instruction(st, sch, w, &node);
        }
        if let Some(hook) = &st.plan_hook {
            hook(&app, &new_plan);
        }
    }
}

/// What the agent believes one of its instances looks like.
#[derive(Debug, Clone, PartialEq)]
struct RunningInst {
    component: String,
    image: String,
    app: String,
}

/// The simulated node agent (§4.3.1): subscribed to its node's deploy
/// topic, converges running instances to each instruction, heartbeats
/// its status.
struct NodeAgent {
    state: Rc<PlaneState>,
    node: AceId,
    site: Site,
    deploy_filter: String,
    status_wire_topic: String,
    ack_wire_topic: String,
    running: BTreeMap<String, RunningInst>,
    /// Highest instruction seq applied — the at-least-once dedupe
    /// watermark. A fresh agent (registration or rejoin) starts at 0:
    /// a rebooted node has no memory of earlier instructions.
    last_applied: u64,
}

impl NodeAgent {
    fn new(state: Rc<PlaneState>, node: AceId) -> Result<NodeAgent> {
        let site = site_of_node(&node)?;
        Ok(NodeAgent {
            state,
            deploy_filter: deploy_topic(&node),
            status_wire_topic: format!("cloud/{}", status_topic(&node)),
            ack_wire_topic: format!("cloud/{}", ack_topic(&node)),
            node,
            site,
            running: BTreeMap::new(),
            last_applied: 0,
        })
    }

    /// Acknowledge instruction `seq` on the uplink — the controller
    /// retries until this lands, so it rides the same lossy WAN.
    fn send_ack(&self, ctx: &mut Ctx, seq: u64) {
        // sized like the real wire format would be, carried typed
        let bytes = format!("{{\"node\":\"{}\",\"seq\":{seq}}}", self.node).len() as u64;
        ctx.publish(
            &self.ack_wire_topic,
            bytes,
            Rc::new(AckBody { node: self.node.clone(), seq }),
        );
    }

    fn report_status(&self, ctx: &mut Ctx) {
        let instances: Vec<Value> = self
            .running
            .iter()
            .map(|(id, r)| {
                Value::obj(vec![
                    ("instance", Value::str(id)),
                    ("component", Value::str(&r.component)),
                    ("app", Value::str(&r.app)),
                    ("state", Value::str("running")),
                ])
            })
            .collect();
        let status = Value::obj(vec![
            ("node", Value::str(self.node.to_string())),
            ("instances", Value::Arr(instances)),
        ]);
        let payload = json::to_string(&status);
        let bytes = payload.len() as u64;
        ctx.publish(&self.status_wire_topic, bytes, Rc::new(StatusBody { json: payload }));
    }
}

impl Component for NodeAgent {
    fn subscriptions(&self) -> Vec<String> {
        vec![self.deploy_filter.clone()]
    }

    fn on_start(&mut self, ctx: &mut Ctx) {
        // first heartbeat at registration, then periodically
        self.report_status(ctx);
        ctx.set_timer(self.state.heartbeat_period, 0);
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(ib) = msg.body_as::<InstructionBody>() else {
            return;
        };
        let Ok(doc) = yamlite::parse(&ib.doc) else {
            return; // malformed instruction: ignored, status unchanged
        };
        // at-least-once dedupe: a redelivered (or duplicated-in-flight)
        // instruction whose seq is not newer than the watermark changes
        // nothing — but is ALWAYS re-acked, because the controller may
        // have retried precisely because the first ack was lost
        let seq = doc.get("seq").as_f64().map(|s| s as u64);
        if let Some(seq) = seq {
            if seq <= self.last_applied {
                self.state.report.borrow_mut().dup_suppressed += 1;
                self.send_ack(ctx, seq);
                return;
            }
            self.last_applied = seq;
        }
        let mut target: BTreeMap<String, RunningInst> = BTreeMap::new();
        if let Some(obj) = doc.get("services").as_obj() {
            for (name, svc) in obj {
                target.insert(
                    name.clone(),
                    RunningInst {
                        component: svc
                            .get("labels")
                            .get("ace.component")
                            .as_str()
                            .unwrap_or(name)
                            .to_string(),
                        image: svc.get("image").as_str().unwrap_or("").to_string(),
                        app: svc.get("labels").get("ace.app").as_str().unwrap_or("").to_string(),
                    },
                );
            }
        }
        // converge DOWN: instances absent from the instruction (or with
        // a changed image — in-place redeploy) are stopped
        let stale: Vec<String> = self
            .running
            .iter()
            .filter(|(id, r)| {
                target
                    .get(id.as_str())
                    .is_none_or(|t| t.image != r.image || t.component != r.component)
            })
            .map(|(id, _)| id.clone())
            .collect();
        for id in stale {
            self.running.remove(&id);
            let st = self.state.clone();
            let node = self.node.clone();
            // the agent cannot mutate the component table from inside
            // its own callback: defer to the Call lane (same virtual
            // time, later sequence)
            ctx.call(0, move |sch, w| {
                if let Some(idx) = st.registry.borrow_mut().remove(&id) {
                    if w.retire(idx) {
                        let mut rep = st.report.borrow_mut();
                        rep.retired += 1;
                        rep.log(sch.now(), format!("agent {node}: stopped '{id}'"));
                    }
                }
            });
        }
        // converge UP: new instances are built through the factory
        for (id, t) in &target {
            if self.running.contains_key(id) {
                continue;
            }
            self.running.insert(id.clone(), t.clone());
            let st = self.state.clone();
            let inst = Instance {
                id: id.clone(),
                component: t.component.clone(),
                node: self.node.clone(),
                image: t.image.clone(),
            };
            let site = self.site.clone();
            let node = self.node.clone();
            ctx.call(0, move |sch, w| match (st.factory)(&inst, &site) {
                Ok(Some(c)) => {
                    let idx = w.spawn(sch, site.clone(), c);
                    st.registry.borrow_mut().insert(inst.id.clone(), idx);
                    let mut rep = st.report.borrow_mut();
                    rep.spawned += 1;
                    let line = format!("agent {node}: started '{}' ({})", inst.id, inst.image);
                    rep.log(sch.now(), line);
                }
                Ok(None) => {
                    let line = format!("agent {node}: '{}' not modelled, skipped", inst.id);
                    st.report.borrow_mut().log(sch.now(), line);
                }
                Err(e) => {
                    st.report
                        .borrow_mut()
                        .log(sch.now(), format!("ERROR agent {node}: spawning '{}': {e}", inst.id));
                }
            });
        }
        // immediate status report reflecting the convergence, then the
        // ack closing the at-least-once loop
        self.report_status(ctx);
        if let Some(seq) = seq {
            self.send_ack(ctx, seq);
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        self.report_status(ctx);
        ctx.set_timer(self.state.heartbeat_period, 0);
    }
}

/// The monitoring service's ingest point (§4.2.1) as a CC component:
/// folds every status report into the API server with a VIRTUAL-time
/// heartbeat stamp the shielding sweep reads.
struct MonitorTap {
    state: Rc<PlaneState>,
}

impl Component for MonitorTap {
    fn subscriptions(&self) -> Vec<String> {
        vec![MONITOR_FILTER.to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(sb) = msg.body_as::<StatusBody>() else {
            return;
        };
        let Ok(v) = json::parse(&sb.json) else {
            return;
        };
        let node = v.get("node").as_str().unwrap_or("?").to_string();
        let key = node.replace('/', ".");
        let Value::Obj(mut obj) = v else {
            return;
        };
        obj.insert("last_seen_ms".to_string(), Value::num(to_millis(ctx.now())));
        self.state.api.put(kinds::NODE_STATUS, &key, Value::Obj(obj));
        self.state.report.borrow_mut().status_reports += 1;
    }
}

/// The at-least-once channel's controller-side sink: clears a node's
/// pending entry when its ack (for the CURRENT seq or newer) arrives,
/// and closes the fault episode's convergence clock when the last
/// pending entry drains.
struct AckTap {
    state: Rc<PlaneState>,
}

impl Component for AckTap {
    fn subscriptions(&self) -> Vec<String> {
        vec![ACK_FILTER.to_string()]
    }

    fn on_message(&mut self, ctx: &mut Ctx, msg: &GraphMsg) {
        let Some(ack) = msg.body_as::<AckBody>() else {
            return;
        };
        let cleared = {
            let mut pending = self.state.pending.borrow_mut();
            match pending.get(&ack.node) {
                // acks are cumulative: seq >= the outstanding send
                // confirms the node converged to at-least-current
                // intent (stale acks for superseded sends are ignored)
                Some(p) if ack.seq >= p.seq => {
                    pending.remove(&ack.node);
                    true
                }
                _ => false,
            }
        };
        if cleared {
            note_converged(&self.state, ctx.now());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCENARIO: &str = "
duration: 20
ops:
  - at: 0
    op: deploy
    topology:
      app: mini
      version: 1
      components:
        - name: solo
          image: img:1
          location: cloud
  - at: 5
    op: update
    topology:
      app: mini
      version: 2
      components:
        - name: solo
          image: img:2
          location: cloud
  - at: 10
    op: fail-node
    node: infra-u/ec-1/rpi1
  - at: 15
    op: remove
    app: mini
";

    #[test]
    fn scenario_parses_all_op_kinds() {
        let s = LifecycleScenario::parse(SCENARIO).unwrap();
        assert_eq!(s.duration, secs(20.0));
        assert!(s.network.is_none(), "no network block in this script");
        assert_eq!(s.steps.len(), 4);
        assert_eq!(s.first_app(), Some("mini"));
        assert!(matches!(&s.steps[0].op, LifecycleOp::Deploy(t) if t.version == 1));
        assert!(matches!(&s.steps[1].op, LifecycleOp::Update(t) if t.version == 2
            && t.component("solo").unwrap().image == "img:2"));
        assert!(matches!(&s.steps[2].op, LifecycleOp::FailNode(n)
            if n.to_string() == "infra-u/ec-1/rpi1"));
        assert!(matches!(&s.steps[3].op, LifecycleOp::Remove(a) if a == "mini"));
        assert_eq!(s.steps[2].at, secs(10.0));
    }

    #[test]
    fn scenario_parses_network_overrides() {
        let s = LifecycleScenario::parse(
            "
duration: 5
network:
  cc_nodes: 2
  cc_lan_mbps: 1000
  nics:
    - cluster: ec-1
      node: rpi1
      mbps: 2
      delay_ms: 0.2
ops:
  - at: 0
    op: remove
    app: x
",
        )
        .unwrap();
        let net = s.network.expect("network block parsed");
        assert_eq!(net.cc_nodes, Some(2));
        assert_eq!(net.cc_lan_mbps, Some(1000.0));
        assert_eq!(net.nics.len(), 1);
        assert_eq!(net.nics[0].node, "rpi1");
        assert_eq!(net.nics[0].mbps, 2.0);
        // and a malformed block is an error, not silently ignored
        let bad = "
duration: 5
network:
  nics:
    - node: rpi1
ops:
  - at: 0
    op: remove
    app: x
";
        let err = LifecycleScenario::parse(bad).unwrap_err().to_string();
        assert!(err.contains("network"), "{err}");
    }

    #[test]
    fn scenario_parses_chaos_ops_and_faults_block() {
        let s = LifecycleScenario::parse(
            "
duration: 30
faults:
  seed: 7
  loss: 0.1
  dup: 0.02
ops:
  - at: 0
    op: remove
    app: x
  - at: 5
    op: fail-link
    link: up-ec0
    for: 3
  - at: 8
    op: degrade-nic
    cluster: ec-1
    node: rpi1
    mbps: 2
  - at: 10
    op: fail-node
    node: infra-u/ec-1/minipc
  - at: 20
    op: rejoin-node
    node: infra-u/ec-1/minipc
",
        )
        .unwrap();
        let f = s.faults.expect("faults block parsed");
        assert_eq!((f.seed, f.loss, f.dup), (7, 0.1, 0.02));
        assert!(matches!(&s.steps[1].op,
            LifecycleOp::FailLink { link, for_us } if link == "up-ec0" && *for_us == secs(3.0)));
        assert!(matches!(&s.steps[2].op,
            LifecycleOp::DegradeNic { cluster, node, mbps }
                if cluster == "ec-1" && node == "rpi1" && *mbps == 2.0));
        assert!(matches!(&s.steps[4].op, LifecycleOp::RejoinNode(n)
            if n.to_string() == "infra-u/ec-1/minipc"));
    }

    #[test]
    fn scenario_rejects_non_monotonic_times_naming_the_op() {
        let err = LifecycleScenario::parse(
            "
duration: 30
ops:
  - at: 10
    op: remove
    app: x
  - at: 5
    op: remove
    app: y
",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("op #1"), "{err}");
        assert!(err.contains("t=5"), "{err}");
        assert!(err.contains("non-decreasing"), "{err}");
        // equal times are allowed (the DES breaks ties by op order)
        let same_tick = "
duration: 9
ops:
  - at: 3
    op: remove
    app: x
  - at: 3
    op: remove
    app: y
";
        assert!(LifecycleScenario::parse(same_tick).is_ok());
    }

    #[test]
    fn scenario_rejects_unknown_fields_naming_the_op() {
        let err = LifecycleScenario::parse(
            "
duration: 30
ops:
  - at: 2
    op: fail-node
    node: infra-u/ec-1/rpi1
    topology: x
",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("op #0"), "{err}");
        assert!(err.contains("t=2"), "{err}");
        assert!(err.contains("'topology'"), "{err}");
        let err = LifecycleScenario::parse(
            "duration: 9\nopps: []\nops:\n  - at: 0\n    op: remove\n    app: x\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown field 'opps'"), "{err}");
        let err = LifecycleScenario::parse(
            "duration: 9\nfaults:\n  loss: 2\nops:\n  - at: 0\n    op: remove\n    app: x\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("faults"), "{err}");
    }

    #[test]
    fn scenario_rejects_garbage() {
        assert!(LifecycleScenario::parse("duration: 5\nops: []\n").is_err());
        assert!(LifecycleScenario::parse("ops:\n  - at: 0\n    op: deploy\n").is_err());
        let bad_op = "
duration: 5
ops:
  - at: 0
    op: reboot
";
        let err = LifecycleScenario::parse(bad_op).unwrap_err().to_string();
        assert!(err.contains("unknown op"), "{err}");
        let no_topo = "
duration: 5
ops:
  - at: 0
    op: deploy
";
        assert!(LifecycleScenario::parse(no_topo).is_err());
    }
}
