//! `ace` — the platform CLI (the paper's §4.2.1 "User Interfaces").
//!
//! Subcommands:
//!   ace info                       — artifacts + model summary
//!   ace calibrate [--reps N]       — measure PJRT service times
//!   ace classify --model eoc|coc --cls C --seed S
//!                                  — render one synthetic crop and
//!                                    classify it through the runtime
//!   ace plan [--topology FILE]     — orchestrate a topology onto the
//!                                    paper testbed, print the plan
//!   ace fig5 [--fast] [--seconds N] [--out DIR] [--workers N]
//!            [--synthetic]         — run the Figure 5 sweep on a
//!                                    parallel worker pool (cells are
//!                                    independent DES worlds; results
//!                                    are order- and bit-identical to
//!                                    the serial sweep)
//!   ace run --paradigm P [--interval I] [--delay D] [--seconds N]
//!                                  — run one experiment cell
//!   ace svcrun --app videoquery|fedtrain [flags]
//!                                  — run an application END-TO-END on
//!                                    the generic svcgraph runtime
//!                                    (topology -> orchestrator ->
//!                                    components -> bridged pub/sub)
//!   ace svcrun --scenario FILE     — run an app under the VIRTUAL-TIME
//!                                    control plane: a scripted
//!                                    lifecycle (deploy / incremental
//!                                    update / node failure with
//!                                    shield+redeploy / node rejoin /
//!                                    fail-link / degrade-nic / remove,
//!                                    optionally under a seeded fault
//!                                    plane) drives the live graph
//!                                    mid-run
//!   ace bench [--json] [--events N] [--subs N] [--pubs N] [--comps N]
//!             [--storm-pubs N] [--broker-subs N] [--broker-pubs N]
//!             [--retained N] [--replay-subs N] [--hop-pubs N]
//!             [--hop-sinks N] [--timers N] [--timer-events N]
//!             [--churn-nodes N] [--churn-loss P] [--churn-runs N]
//!             [--check BASELINE.json] [--floor FLOOR.json]
//!             [--tolerance T]
//!                                  — hot-path micro-benchmarks on BOTH
//!                                    planes (typed vs boxed DES
//!                                    events, calendar-queue vs heap
//!                                    timer storm, scratch-reuse
//!                                    routing, fabric storm, hop-charged
//!                                    NetFabric routing, broker
//!                                    throughput + retained replay,
//!                                    chaos churn cycles under seeded
//!                                    message loss);
//!                                    --json emits the machine-readable
//!                                    BENCH_*.json perf-trajectory
//!                                    record CI logs; --check compares
//!                                    the fresh run against a committed
//!                                    BENCH_*.json (or a rolling-window
//!                                    directory) and exits nonzero on
//!                                    throughput regressions beyond
//!                                    --tolerance (default 0.25);
//!                                    --floor anchors that baseline to
//!                                    a committed NUMERIC record via a
//!                                    per-metric max — the CI bench gate
//!   ace serve [--port P] [--addr HOST:PORT] [--shards N]
//!             [--max-frame BYTES] [--name NAME] [--pool N]
//!             [--federate HOST:PORT] [--fed-pull F] [--fed-push F]
//!                                  — the sharded broker behind a
//!                                    length-framed JSON TCP front end
//!                                    (one poll loop + a fixed worker
//!                                    pool); blocks until a client
//!                                    sends a shutdown op; --federate
//!                                    bridges the topic space to a
//!                                    peer `ace serve` over the same
//!                                    protocol
//!   ace serve-probe [--addr HOST:PORT] [--no-shutdown]
//!                                  — in-repo smoke client asserting
//!                                    pub/sub, retained replay and
//!                                    malformed-frame recovery against
//!                                    a live `ace serve`
//!
//! clap is unavailable offline; argument parsing is a ~60-line hand
//! rolled matcher (DESIGN.md §Substitutions).

use ace::app::fedtrain::{run_fedtrain, run_fedtrain_seeds, FedConfig};
use ace::app::metro::{run_metro, MetroConfig, MetroMetrics};
use ace::app::videoquery::{
    fig5_grid, run_cell, run_sweep, CellConfig, Compute, InferCache, Paradigm, ServiceTimes,
};
use ace::infra::paper_testbed;
use ace::platform::orchestrator;
use ace::runtime::{artifacts_dir, Engine, ModelBank};
use ace::svcgraph::lifecycle::LifecycleReport;
use ace::svcgraph::scenario::{self, Knobs, Report, Scenario};
use ace::topology::{Topology, VIDEOQUERY_TOPOLOGY};
use ace::util::to_secs;
use ace::video::synth;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    let mut key: Option<String> = None;
    for a in args {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some(k) = key.take() {
                flags.insert(k, "true".to_string()); // boolean flag
            }
            key = Some(stripped.to_string());
        } else if let Some(k) = key.take() {
            flags.insert(k, a);
        }
    }
    if let Some(k) = key.take() {
        flags.insert(k, "true".to_string());
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn f64_or(&self, k: &str, d: f64) -> f64 {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }

    fn usize_or(&self, k: &str, d: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

/// Resolve `--partitions` (scheduler lanes / cluster partitions):
/// absent = `default`, `0` = auto-detect cores like `--workers`.
fn partitions_flag(args: &Args, default: usize) -> usize {
    match args.usize_or("partitions", default) {
        0 => ace::sweep::default_workers(),
        p => p,
    }
}

fn cmd_info() -> Result<()> {
    let dir = artifacts_dir()?;
    let manifest = ace::runtime::Manifest::load(&dir.join("manifest.json"))?;
    println!("artifacts: {}", dir.display());
    println!("crop {}x{} | frame {}x{} | classes {:?}",
        manifest.crop, manifest.crop, manifest.frame_h, manifest.frame_w, manifest.classes);
    println!("target class: {} ({})", manifest.target_class,
        manifest.classes[manifest.target_class]);
    for (name, m) in &manifest.models {
        println!(
            "model {name}: {} params, batches {:?}, accuracy {:.4}",
            m.params, m.batch_sizes, m.accuracy
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    let mut bank = ModelBank::load(&engine, &artifacts_dir()?)?;
    let reps = args.usize_or("reps", 5);
    bank.calibrate(reps)?;
    println!("| model | batch | total ms | ms/crop |");
    println!("|---|---|---|---|");
    for clf in [&bank.eoc, &bank.coc] {
        for &b in &clf.batch_sizes {
            let t = clf.service_time(b);
            println!("| {} | {b} | {:.3} | {:.3} |", clf.name, t * 1e3, t * 1e3 / b as f64);
        }
    }
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let cls: u8 = args
        .get("cls")
        .context("--cls <0..7> required")?
        .parse()?;
    let seed: u64 = args.f64_or("seed", 42.0) as u64;
    let model = args.get("model").unwrap_or("coc");
    let engine = Engine::cpu()?;
    let bank = ModelBank::load(&engine, &artifacts_dir()?)?;
    let crop = synth::make_crop(cls, seed);
    let clf = if model == "eoc" { &bank.eoc } else { &bank.coc };
    let probs = &clf.classify(std::slice::from_ref(&crop.data))?[0];
    println!(
        "rendered class {} ({}), seed {seed}",
        cls, synth::CLASSES[cls as usize]
    );
    if model == "eoc" {
        println!("eoc P[target present] = {:.4}", probs[1]);
    } else {
        for (i, p) in probs.iter().enumerate() {
            println!("  {:>12}: {:.4}{}", synth::CLASSES[i], p,
                if i == cls as usize { "  <- true" } else { "" });
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let topo = match args.get("topology") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            Topology::parse(&text)?
        }
        None => Topology::parse(VIDEOQUERY_TOPOLOGY)?,
    };
    let infra = paper_testbed("cli");
    let plan = orchestrator::place(&topo, &infra)?;
    println!("app '{}' v{}: {} instances", plan.app, plan.version, plan.instances.len());
    for (node, instances) in plan.by_node() {
        println!("  {node}:");
        for i in instances {
            println!("    {} ({})", i.id, i.image);
        }
    }
    Ok(())
}

fn paradigm_of(s: &str) -> Result<Paradigm> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "ci" => Paradigm::Ci,
        "ei" => Paradigm::Ei,
        "ace" | "bp" => Paradigm::AceBp,
        "ace+" | "ap" => Paradigm::AceAp,
        other => bail!("unknown paradigm '{other}' (ci|ei|ace|ace+)"),
    })
}

fn load_real() -> Result<(Arc<ModelBank>, ServiceTimes)> {
    let engine = Engine::cpu()?;
    let mut bank = ModelBank::load(&engine, &artifacts_dir()?)?;
    bank.calibrate(3)?;
    let svc = ServiceTimes::calibrated_to_paper(&bank);
    Ok((Arc::new(bank), svc))
}

fn cmd_run(args: &Args) -> Result<()> {
    let paradigm = paradigm_of(args.get("paradigm").unwrap_or("ace"))?;
    let cfg = CellConfig {
        paradigm,
        interval_s: args.f64_or("interval", 0.2),
        wan_delay_ms: args.f64_or("delay", 0.0),
        duration_s: args.f64_or("seconds", 30.0),
        seed: args.f64_or("seed", 1.0) as u64,
        ..Default::default()
    };
    let (bank, svc) = load_real()?;
    let cache = Arc::new(Mutex::new(InferCache::new()));
    let m = run_cell(cfg, svc, Compute::Real { bank, cache })?;
    let eil = m.eil_ms();
    let p99 = m.eil_p99_ms();
    println!(
        "{}: crops={} F1={:.3} (P {:.3} / R {:.3}) BWC={:.2}MB EIL mean {eil:.1}ms p99 {p99:.1}ms",
        m.paradigm, m.crops, m.f1.f1(), m.f1.precision(), m.f1.recall(), m.bwc_mb()
    );
    Ok(())
}

/// Per-NIC traffic/occupancy table (nothing printed when the run
/// models no NICs — the degenerate flat configuration).
fn print_nic_util(m: &ace::metrics::CellMetrics) {
    if m.nic_util.is_empty() {
        return;
    }
    let dur_us = (m.sim_duration_s * 1e6) as u64;
    println!("| NIC | bw | bytes | msgs | busy | util |");
    println!("|---|---|---|---|---|---|");
    for u in &m.nic_util {
        let bw = match u.mbps {
            Some(mbps) => format!("{mbps:.0} Mbps"),
            None => "unlimited".to_string(),
        };
        println!(
            "| {}/{} | {bw} | {} | {} | {:.1} ms | {:.2}% |",
            u.cluster,
            u.node,
            u.bytes,
            u.msgs,
            u.busy_us as f64 / 1e3,
            u.busy_share(dur_us) * 100.0,
        );
    }
}

fn print_report(report: &LifecycleReport) {
    for (at, msg) in &report.events {
        println!("[{:>9.3}s] {msg}", to_secs(*at));
    }
    println!(
        "lifecycle: {} spawned / {} retired / {} status reports / {} redeploys / shielded {:?}",
        report.spawned, report.retired, report.status_reports, report.redeploys, report.shielded,
    );
    // the chaos line only appears when something chaotic happened, so
    // fault-free runs keep their pre-fault-plane output byte-for-byte
    if report.retries > 0
        || report.dup_suppressed > 0
        || report.msgs_lost > 0
        || !report.convergence_us.is_empty()
    {
        println!(
            "chaos: {} msgs lost / {} instr retries / {} dups suppressed / \
             convergence max {:.0} ms over {} fault episode(s)",
            report.msgs_lost,
            report.retries,
            report.dup_suppressed,
            report.max_convergence_ms(),
            report.convergence_us.len(),
        );
    }
}

/// `--scenario FILE`: run an app under the virtual-time control plane
/// (deploy/update/fail-node/remove ops driving the live graph). The
/// dispatch itself lives in `svcgraph::scenario` — this function only
/// translates CLI flags into [`Knobs`] and prints the per-app report.
fn cmd_svcrun_scenario(args: &Args, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let sc = Scenario::parse_with_fallback(&text, args.get("app").unwrap_or("videoquery"))?;
    let mut knobs = Knobs::default();
    match &sc {
        Scenario::Metro(cfg) => {
            let partitions = partitions_flag(args, cfg.partitions.max(1));
            knobs.partitions = Some(partitions);
            knobs.threads = Some(match args.usize_or("threads", partitions) {
                0 => ace::sweep::default_workers(),
                t => t,
            });
        }
        Scenario::Lifecycle { app, .. } if app == "videoquery" => {
            knobs.paradigm = Some(paradigm_of(args.get("paradigm").unwrap_or("ace"))?);
            knobs.interval_s = Some(args.f64_or("interval", 0.2));
            knobs.wan_delay_ms = Some(args.f64_or("delay", 0.0));
            // without --seconds the dispatcher samples right up to the
            // scenario horizon, so post-redeploy phases produce crops
            knobs.duration_s = args.get("seconds").and_then(|v| v.parse().ok());
            knobs.seed = Some(args.f64_or("seed", 1.0) as u64);
            knobs.num_ecs = Some(args.usize_or("ecs", 3));
            knobs.cams_per_ec = Some(args.usize_or("cams", 3));
            knobs.partitions = Some(partitions_flag(args, 1));
            knobs.video_compute = Some(if args.has("real") {
                let (bank, svc) = load_real()?;
                let cache = Arc::new(Mutex::new(InferCache::new()));
                (svc, Compute::Real { bank, cache })
            } else {
                (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
            });
        }
        Scenario::Lifecycle { .. } => {
            // fedtrain flags; unknown apps fail inside the dispatcher
            knobs.rounds = Some(args.usize_or("rounds", 12));
            knobs.num_ecs = Some(args.usize_or("ecs", 3));
            knobs.wan_delay_ms = Some(args.f64_or("delay", 0.0));
            knobs.seed = Some(args.f64_or("seed", 42.0) as u64);
            knobs.step_ms = Some(args.f64_or("step-ms", 200.0));
            knobs.partitions = Some(partitions_flag(args, 1));
        }
    }
    match scenario::run_with(&sc, knobs)? {
        Report::Video(out) => {
            print_report(&out.report);
            let m = &out.metrics;
            println!(
                "scenario/videoquery {}: crops={} F1={:.3} BWC={:.2}MB \
                 (incl. platform traffic) edge/cloud decided {}/{}",
                m.paradigm,
                m.crops,
                m.f1.f1(),
                m.bwc_mb(),
                m.edge_decided,
                m.cloud_decided,
            );
            print_nic_util(m);
        }
        Report::Fed { metrics: m, lifecycle } => {
            print_report(&lifecycle);
            println!("| round | trainers | mean loss | global acc |");
            println!("|---|---|---|---|");
            for r in &m.rounds {
                println!(
                    "| {:>2} | {} | {:.3} | {:.3} |",
                    r.round, r.trainers, r.mean_loss, r.accuracy
                );
            }
            println!(
                "scenario/fedtrain: {} rounds, final acc {:.3}, BWC {:.3} MB, {:.2} virtual s",
                m.rounds.len(),
                m.final_accuracy,
                m.wan_bytes as f64 / 1e6,
                m.virtual_secs,
            );
        }
        Report::Metro(m) => {
            let Scenario::Metro(cfg) = &sc else {
                bail!("metro report from a non-metro scenario");
            };
            print_metro(cfg, &m);
        }
    }
    Ok(())
}

fn cmd_svcrun(args: &Args) -> Result<()> {
    if let Some(path) = args.get("scenario") {
        let path = path.to_string();
        return cmd_svcrun_scenario(args, &path);
    }
    match args.get("app").unwrap_or("videoquery") {
        "videoquery" => {
            let paradigm = paradigm_of(args.get("paradigm").unwrap_or("ace"))?;
            let cfg = CellConfig {
                paradigm,
                interval_s: args.f64_or("interval", 0.2),
                wan_delay_ms: args.f64_or("delay", 0.0),
                duration_s: args.f64_or("seconds", 30.0),
                seed: args.f64_or("seed", 1.0) as u64,
                num_ecs: args.usize_or("ecs", 3),
                cams_per_ec: args.usize_or("cams", 3),
                partitions: partitions_flag(args, 1),
                ..Default::default()
            };
            // --real pushes every crop through the compiled HLO
            // artifacts; the default synthetic oracle needs nothing
            let (svc, compute) = if args.has("real") {
                let (bank, svc) = load_real()?;
                let cache = Arc::new(Mutex::new(InferCache::new()));
                (svc, Compute::Real { bank, cache })
            } else {
                (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
            };
            let m = run_cell(cfg, svc, compute)?;
            let eil = m.eil_ms();
            let p99 = m.eil_p99_ms();
            println!(
                "svcgraph/videoquery {}: crops={} F1={:.3} (P {:.3} / R {:.3}) \
                 BWC={:.2}MB (from simnet link counters) EIL mean {eil:.1}ms p99 {p99:.1}ms \
                 edge/cloud decided {}/{}",
                m.paradigm,
                m.crops,
                m.f1.f1(),
                m.f1.precision(),
                m.f1.recall(),
                m.bwc_mb(),
                m.edge_decided,
                m.cloud_decided,
            );
            print_nic_util(&m);
            Ok(())
        }
        "fedtrain" => {
            let cfg = FedConfig {
                rounds: args.usize_or("rounds", 12),
                num_ecs: args.usize_or("ecs", 3),
                wan_delay_ms: args.f64_or("delay", 0.0),
                seed: args.f64_or("seed", 42.0) as u64,
                step_ms: args.f64_or("step-ms", 2.0),
                partitions: partitions_flag(args, 1),
                ..Default::default()
            };
            let num_seeds = args.usize_or("seeds", 1);
            if num_seeds > 1 {
                // multi-seed robustness sweep on the worker pool
                let workers = args.usize_or("workers", ace::sweep::default_workers());
                let seeds: Vec<u64> = (0..num_seeds as u64).map(|i| cfg.seed + i).collect();
                let t0 = Instant::now();
                let runs = run_fedtrain_seeds(&cfg, &seeds, workers)?;
                let wall = t0.elapsed().as_secs_f64();
                println!("| seed | federated acc | client-only mean | BWC MB | virtual s |");
                println!("|---|---|---|---|---|");
                for (seed, m) in seeds.iter().zip(&runs) {
                    let mean_client = m.client_only_acc.iter().sum::<f64>()
                        / m.client_only_acc.len().max(1) as f64;
                    println!(
                        "| {seed} | {:.3} | {:.3} | {:.3} | {:.2} |",
                        m.final_accuracy,
                        mean_client,
                        m.wan_bytes as f64 / 1e6,
                        m.virtual_secs,
                    );
                }
                let accs: Vec<f64> = runs.iter().map(|m| m.final_accuracy).collect();
                let mean = accs.iter().sum::<f64>() / accs.len() as f64;
                let min = accs.iter().cloned().fold(f64::INFINITY, f64::min);
                let max = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                println!(
                    "svcgraph/fedtrain: {} seeds on {workers} workers in {wall:.2}s wall; \
                     federated acc mean {mean:.3} (min {min:.3} / max {max:.3})",
                    seeds.len(),
                );
                return Ok(());
            }
            let m = run_fedtrain(cfg)?;
            println!("| round | mean loss | global acc |");
            println!("|---|---|---|");
            for r in &m.rounds {
                println!("| {:>2} | {:.3} | {:.3} |", r.round, r.mean_loss, r.accuracy);
            }
            let mean_client =
                m.client_only_acc.iter().sum::<f64>() / m.client_only_acc.len().max(1) as f64;
            println!(
                "svcgraph/fedtrain: federated {:.3} vs client-only mean {:.3}; \
                 BWC {:.3} MB over {} up + {} down bridged messages; {:.2} virtual s",
                m.final_accuracy,
                mean_client,
                m.wan_bytes as f64 / 1e6,
                m.bridged_up,
                m.bridged_down,
                m.virtual_secs,
            );
            Ok(())
        }
        "metro" => {
            let mut cfg = match args.get("preset") {
                Some(p) => MetroConfig::preset(p)?,
                None => MetroConfig::default(),
            };
            cfg.seed = args.f64_or("seed", cfg.seed as f64) as u64;
            cfg.ecs = args.usize_or("ecs", cfg.ecs);
            cfg.duration_s = args.f64_or("seconds", cfg.duration_s);
            cfg.wan_delay_ms = args.f64_or("delay", cfg.wan_delay_ms);
            cfg.partitions = partitions_flag(args, cfg.partitions.max(1));
            cfg.threads = match args.usize_or("threads", cfg.partitions) {
                0 => ace::sweep::default_workers(),
                t => t,
            };
            run_and_print_metro(&cfg)
        }
        other => bail!("unknown app '{other}' (videoquery|fedtrain|metro)"),
    }
}

/// Shared reporter for `svcrun --app metro` and metro scenario files.
fn run_and_print_metro(cfg: &MetroConfig) -> Result<()> {
    let m = run_metro(cfg);
    print_metro(cfg, &m);
    Ok(())
}

/// The metro summary lines (topology shape comes from the config, the
/// partition/thread counts the run actually used from the metrics).
fn print_metro(cfg: &MetroConfig, m: &MetroMetrics) {
    println!(
        "svcgraph/metro: {} ECs x {} nodes x {} cams -> frames={} escalated={} replies={} \
         mean RTT {:.1}ms BWC {:.2}MB",
        cfg.ecs,
        cfg.nodes_per_ec,
        cfg.cams_per_node,
        m.frames,
        m.escalated,
        m.replies,
        m.mean_latency_ms,
        m.wan_bytes as f64 / 1e6,
    );
    println!(
        "metro run: {} DES events over {} conservative windows in {:.2}s wall \
         ({:.0} ev/s on {} partition(s) x {} thread(s))",
        m.events, m.windows, m.wall_secs, m.events_per_sec, m.partitions, m.threads,
    );
}

/// `ace metro-gen`: emit a seeded `scenarios/metro_*.yaml` workload.
fn cmd_metro_gen(args: &Args) -> Result<()> {
    let preset = args.get("preset").unwrap_or("small");
    let mut cfg = MetroConfig::preset(preset)?;
    cfg.seed = args.f64_or("seed", cfg.seed as f64) as u64;
    cfg.ecs = args.usize_or("ecs", cfg.ecs);
    cfg.duration_s = args.f64_or("seconds", cfg.duration_s);
    let yaml = cfg.to_yaml();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &yaml).with_context(|| format!("writing {path}"))?;
            println!(
                "wrote {path} ({preset}: {} ECs x {} nodes x {} cams, seed {})",
                cfg.ecs, cfg.nodes_per_ec, cfg.cams_per_node, cfg.seed
            );
        }
        None => print!("{yaml}"),
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use ace::benchkit;
    use ace::json::Value;

    let events = args.usize_or("events", 1_000_000) as u64;
    let subs = args.usize_or("subs", 10_000);
    let pubs = args.usize_or("pubs", 20_000);
    let comps = args.usize_or("comps", 10_000);
    let storm_pubs = args.usize_or("storm-pubs", 500);
    let broker_subs = args.usize_or("broker-subs", 2_000);
    let broker_pubs = args.usize_or("broker-pubs", 20_000);
    let retained = args.usize_or("retained", 2_000);
    let replay_subs = args.usize_or("replay-subs", 500);
    let hop_pubs = args.usize_or("hop-pubs", 20_000);
    let hop_sinks = args.usize_or("hop-sinks", 64);
    let timers = args.usize_or("timers", 10_000);
    let timer_events = args.usize_or("timer-events", 1_000_000) as u64;
    let churn_nodes = args.usize_or("churn-nodes", 4);
    let churn_loss = args.f64_or("churn-loss", 0.2);
    let churn_runs = args.usize_or("churn-runs", 10) as u64;
    let metro_ecs = args.usize_or("metro-ecs", 8);
    let metro_secs = args.f64_or("metro-seconds", 20.0);
    // --partitions caps the parallel metro rows (0 = auto cores)
    let metro_pmax = partitions_flag(args, 8);

    let des = benchkit::des_throughput(events);
    let tstorm = benchkit::des_timer_storm(timers, timer_events);
    let route = benchkit::route_scratch(subs, pubs);
    let storm = benchkit::fabric_storm(comps, storm_pubs);
    let broker = benchkit::broker_throughput(broker_subs, broker_pubs, retained, replay_subs);
    let contention = benchkit::broker_contention(
        args.usize_or("contention-producers", 4),
        args.usize_or("contention-pubs", 20_000),
    );
    let rtt = benchkit::serve_rtt(args.usize_or("rtt-pubs", 2_000));
    let hops = benchkit::netfabric_hops(hop_pubs, hop_sinks);
    let churn = benchkit::churn_convergence(churn_nodes, churn_loss, churn_runs);
    let metro_counts: Vec<usize> = [2usize, 4, 8].into_iter().filter(|&p| p <= metro_pmax).collect();
    // denser-than-default metro: fast cameras and a long WAN lookahead
    // give every safe window enough work to amortize the per-window
    // barrier, so the parallel rows measure scaling rather than sync
    let metro = benchkit::metro_scale(
        &ace::app::MetroConfig {
            ecs: metro_ecs,
            nodes_per_ec: 8,
            cams_per_node: 4,
            cam_period_ms: 10.0,
            wan_delay_ms: 50.0,
            duration_s: metro_secs,
            ..Default::default()
        },
        &metro_counts,
    );

    // one measurement pass serves both renderings: the table goes to
    // stderr so `--json` output stays pipeable AND the log stays
    // human-readable without a second (noisier) bench run
    eprintln!("| measurement | boxed/alloc | typed/scratch | speedup |");
    eprintln!("|---|---|---|---|");
    eprintln!(
        "| DES chained ticks ({events} ev) | {:.0}/s | {:.0}/s | {:.2}x |",
        des.boxed_chain_eps,
        des.typed_chain_eps,
        des.typed_chain_eps / des.boxed_chain_eps
    );
    eprintln!(
        "| DES random heap ({events} ev) | {:.0}/s | {:.0}/s | {:.2}x |",
        des.boxed_heap_eps,
        des.typed_heap_eps,
        des.typed_heap_eps / des.boxed_heap_eps
    );
    eprintln!(
        "| DES timer storm ({timers} timers, {timer_events} ev, heap vs wheel) \
         | {:.0}/s | {:.0}/s | {:.2}x |",
        tstorm.heap_events_per_sec,
        tstorm.wheel_events_per_sec,
        tstorm.wheel_events_per_sec / tstorm.heap_events_per_sec
    );
    eprintln!(
        "| route matches ({subs} subs, {pubs} pubs) | {:.0}/s | {:.0}/s | {:.2}x |",
        route.alloc_pubs_per_s,
        route.scratch_pubs_per_s,
        route.scratch_pubs_per_s / route.alloc_pubs_per_s
    );
    eprintln!(
        "fabric storm: {} comps, {} publishes -> {} deliveries, {} DES events, {:.0} pubs/s",
        storm.components, storm.publishes, storm.deliveries, storm.des_events, storm.pubs_per_s
    );
    eprintln!(
        "broker: {} subs, {} publishes -> {} deliveries, {:.0} pubs/s, {:.0} delivers/s",
        broker.subs, broker.pubs, broker.delivered, broker.publish_per_s, broker.deliver_per_s
    );
    eprintln!(
        "broker retained replay: {} retained, {} subscribes -> {} replayed, {:.0} subscribes/s",
        broker.retained_topics,
        broker.replay_subscribes,
        broker.replayed,
        broker.replay_subscribes_per_s
    );
    eprintln!(
        "broker contention: {} shards, {} lanes, {} pubs/producer; \
         1 producer {:.0} pubs/s vs {} producers {:.0} pubs/s aggregate ({:.2}x)",
        contention.shards,
        contention.lanes,
        contention.pubs_per_producer,
        contention.single_producer_per_sec,
        contention.producers,
        contention.publishes_per_sec,
        contention.publishes_per_sec / contention.single_producer_per_sec.max(1.0)
    );
    eprintln!(
        "serve rtt: {} publish round-trips through the TCP front end -> {:.0} rtt/s",
        rtt.pubs, rtt.rtt_per_sec
    );
    eprintln!(
        "netfabric hops: {} pubs x {} sinks -> {} deliveries; \
         flat {:.0} pubs/s vs hop-charged {:.0} pubs/s ({:.2}x overhead)",
        hops.pubs,
        hops.sinks,
        hops.deliveries,
        hops.flat_pubs_per_s,
        hops.hop_pubs_per_s,
        hops.flat_pubs_per_s / hops.hop_pubs_per_s.max(1.0)
    );
    eprintln!(
        "churn convergence: {} runs of deploy->fail->rejoin on 2x{} nodes at {:.0}% loss \
         -> {:.1} runs/s; per cycle: {} msgs lost, {} retries, convergence max {:.0} ms",
        churn.runs,
        churn.nodes,
        churn.loss * 100.0,
        churn.runs_per_sec,
        churn.msgs_lost,
        churn.retries,
        churn.convergence_ms
    );
    for r in &metro.rows {
        eprintln!(
            "metro scale: {} ECs x {} cams, {:.0} virtual s -> {} events at {} partition(s) \
             x {} thread(s): {:.0} ev/s{}",
            metro.ecs,
            metro.cams,
            metro.virtual_secs,
            r.events,
            r.partitions,
            r.threads,
            r.events_per_sec,
            if r.partitions == 1 { " (serial reference)" } else { "" },
        );
    }
    eprintln!(
        "metro scale: best parallel {:.0} ev/s at {} partitions vs serial {:.0} ev/s ({:.2}x)",
        metro.best_events_per_sec,
        metro.best_partitions,
        metro.serial_events_per_sec,
        metro.best_events_per_sec / metro.serial_events_per_sec.max(1.0)
    );

    {
        // the BENCH_*.json perf-trajectory record (one object per PR,
        // emitted by CI so numbers always come from a real toolchain)
        let num = |f: f64| Value::Num((f as u64) as f64); // whole units
        let obj = Value::obj;
        let v = obj(vec![
            ("bench_schema", Value::Num(1.0)),
            (
                "des_events_per_sec",
                obj(vec![
                    ("events", Value::Num(des.events as f64)),
                    ("typed_chain", num(des.typed_chain_eps)),
                    ("boxed_chain", num(des.boxed_chain_eps)),
                    ("typed_heap", num(des.typed_heap_eps)),
                    ("boxed_heap", num(des.boxed_heap_eps)),
                ]),
            ),
            (
                "des_timer_storm",
                obj(vec![
                    ("timers", Value::Num(tstorm.timers as f64)),
                    ("events", Value::Num(tstorm.events as f64)),
                    ("wheel_events_per_sec", num(tstorm.wheel_events_per_sec)),
                    ("heap_events_per_sec", num(tstorm.heap_events_per_sec)),
                ]),
            ),
            (
                "route_match_collection",
                obj(vec![
                    ("subs", Value::Num(route.subs as f64)),
                    ("pubs", Value::Num(route.pubs as f64)),
                    ("hits", Value::Num(route.hits as f64)),
                    ("alloc_pubs_per_sec", num(route.alloc_pubs_per_s)),
                    ("scratch_pubs_per_sec", num(route.scratch_pubs_per_s)),
                ]),
            ),
            (
                "fabric_storm",
                obj(vec![
                    ("components", Value::Num(storm.components as f64)),
                    ("publishes", Value::Num(storm.publishes as f64)),
                    ("deliveries", Value::Num(storm.deliveries as f64)),
                    ("des_events", Value::Num(storm.des_events as f64)),
                    ("pubs_per_sec", num(storm.pubs_per_s)),
                ]),
            ),
            (
                "broker",
                obj(vec![
                    ("subs", Value::Num(broker.subs as f64)),
                    ("pubs", Value::Num(broker.pubs as f64)),
                    ("delivered", Value::Num(broker.delivered as f64)),
                    ("publish_per_sec", num(broker.publish_per_s)),
                    ("deliver_per_sec", num(broker.deliver_per_s)),
                    ("retained_topics", Value::Num(broker.retained_topics as f64)),
                    ("replay_subscribes", Value::Num(broker.replay_subscribes as f64)),
                    ("replayed", Value::Num(broker.replayed as f64)),
                    ("replay_subscribes_per_sec", num(broker.replay_subscribes_per_s)),
                ]),
            ),
            (
                "broker_contention",
                obj(vec![
                    ("shards", Value::Num(contention.shards as f64)),
                    ("lanes", Value::Num(contention.lanes as f64)),
                    (
                        "pubs_per_producer",
                        Value::Num(contention.pubs_per_producer as f64),
                    ),
                    ("producers", Value::Num(contention.producers as f64)),
                    // gated (higher is better): aggregate multi-producer rate
                    ("publishes_per_sec", num(contention.publishes_per_sec)),
                    // gated: publish round-trips through the `ace serve`
                    // TCP front end (single client, loopback)
                    ("serve_rtt_pubs", Value::Num(rtt.pubs as f64)),
                    ("serve_rtt_per_sec", num(rtt.rtt_per_sec)),
                    // informational: the single-producer reference CI's
                    // parallel>serial check reads
                    (
                        "single_producer_per_sec",
                        num(contention.single_producer_per_sec),
                    ),
                    (
                        "rows",
                        Value::Arr(
                            contention
                                .rows
                                .iter()
                                .map(|r| {
                                    obj(vec![
                                        ("producers", Value::Num(r.producers as f64)),
                                        ("pubs", Value::Num(r.pubs as f64)),
                                        ("publishes_per_sec", num(r.publishes_per_sec)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "netfabric",
                obj(vec![
                    ("pubs", Value::Num(hops.pubs as f64)),
                    ("sinks", Value::Num(hops.sinks as f64)),
                    ("deliveries", Value::Num(hops.deliveries as f64)),
                    ("flat_pubs_per_sec", num(hops.flat_pubs_per_s)),
                    ("hop_pubs_per_sec", num(hops.hop_pubs_per_s)),
                ]),
            ),
            (
                "churn_convergence",
                obj(vec![
                    ("nodes", Value::Num(churn.nodes as f64)),
                    ("loss", Value::Num(churn.loss)),
                    ("runs", Value::Num(churn.runs as f64)),
                    // gated (higher is better)
                    ("runs_per_sec", Value::Num(churn.runs_per_sec)),
                    // informational: virtual-time chaos metrics, fixed
                    // by the fault seed (lower-is-better convergence is
                    // NOT a throughput, so the gate skips it)
                    ("convergence_ms", num(churn.convergence_ms)),
                    ("retries", Value::Num(churn.retries as f64)),
                    ("msgs_lost", Value::Num(churn.msgs_lost as f64)),
                ]),
            ),
            (
                "metro_scale",
                obj(vec![
                    ("ecs", Value::Num(metro.ecs as f64)),
                    ("cams", Value::Num(metro.cams as f64)),
                    ("duration_s", Value::Num(metro.virtual_secs)),
                    // gated (higher is better): the best parallel rate
                    ("metro_events_per_sec", num(metro.best_events_per_sec)),
                    // informational: the serial reference and the full
                    // scaling curve CI's parallel>serial check reads
                    ("serial_events_per_sec", num(metro.serial_events_per_sec)),
                    ("best_partitions", Value::Num(metro.best_partitions as f64)),
                    (
                        "rows",
                        Value::Arr(
                            metro
                                .rows
                                .iter()
                                .map(|r| {
                                    obj(vec![
                                        ("partitions", Value::Num(r.partitions as f64)),
                                        ("threads", Value::Num(r.threads as f64)),
                                        ("events", Value::Num(r.events as f64)),
                                        ("events_per_sec", num(r.events_per_sec)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ]);
        if args.has("json") {
            println!("{}", ace::json::to_string(&v));
        }

        // `--check BASELINE.json`: the CI bench-regression gate — exit
        // nonzero when any throughput metric falls below
        // baseline * (1 - tolerance). Metrics the baseline carries no
        // number for (placeholder records) are skipped.
        if let Some(baseline_path) = args.get("check") {
            let tolerance = args.f64_or("tolerance", 0.25);
            if !(0.0..1.0).contains(&tolerance) {
                bail!("--tolerance must be in [0, 1), got {tolerance}");
            }
            // a FILE is used verbatim; a DIRECTORY is a rolling window
            // of records folded to a per-metric median (robust to a
            // single fast/slow-runner outlier — see
            // benchkit::median_baseline)
            let baseline = if std::path::Path::new(baseline_path).is_dir() {
                let mut paths: Vec<_> = std::fs::read_dir(baseline_path)
                    .with_context(|| format!("reading baseline dir {baseline_path}"))?
                    .filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
                    .collect();
                paths.sort();
                let mut records = Vec::new();
                for p in &paths {
                    let text = std::fs::read_to_string(p)
                        .with_context(|| format!("reading baseline record {}", p.display()))?;
                    records.push(
                        ace::json::parse(&text)
                            .with_context(|| format!("parsing baseline record {}", p.display()))?,
                    );
                }
                eprintln!(
                    "bench-check: median baseline over {} record(s) in {baseline_path}",
                    records.len()
                );
                benchkit::median_baseline(&records)
            } else {
                let text = std::fs::read_to_string(baseline_path)
                    .with_context(|| format!("reading baseline {baseline_path}"))?;
                ace::json::parse(&text)
                    .with_context(|| format!("parsing baseline {baseline_path}"))?
            };
            // `--floor FLOOR.json`: anchor the (rolling) baseline to a
            // committed NUMERIC record via a per-metric max, so a slow
            // streak of CI runs can never walk the gate's floor down
            // (see benchkit::max_baseline). A placeholder floor
            // contributes nothing.
            let baseline = match args.get("floor") {
                Some(floor_path) => {
                    let text = std::fs::read_to_string(floor_path)
                        .with_context(|| format!("reading floor {floor_path}"))?;
                    let floor = ace::json::parse(&text)
                        .with_context(|| format!("parsing floor {floor_path}"))?;
                    eprintln!("bench-check: baseline anchored to committed floor {floor_path}");
                    benchkit::max_baseline(&baseline, &floor)
                }
                None => baseline,
            };
            let check = benchkit::check_regression(&baseline, &v, tolerance);
            for path in &check.skipped {
                eprintln!("bench-check: no baseline number for {path}, skipped");
            }
            for (path, base, fresh) in &check.compared {
                eprintln!("bench-check: {path} {fresh:.0}/s vs baseline {base:.0}/s");
            }
            if !check.regressions.is_empty() {
                bail!(
                    "bench regression vs {baseline_path}:\n  {}",
                    check.regressions.join("\n  ")
                );
            }
            if check.compared.is_empty() {
                // a placeholder baseline makes the gate vacuous: say so
                // LOUDLY (CI's rolling-baseline cache arms the gate
                // from the second run onward); --require-baseline turns
                // this into a hard failure for strict setups
                let msg = format!(
                    "bench-check: WARNING — {baseline_path} carries no comparable numbers; \
                     every metric skipped, the regression gate is VACUOUS this run"
                );
                if args.has("require-baseline") {
                    bail!("{msg}");
                }
                eprintln!("{msg}");
            } else {
                eprintln!(
                    "bench-check: {} metric(s) within {:.0}% of {baseline_path} ({} skipped)",
                    check.compared.len(),
                    tolerance * 100.0,
                    check.skipped.len()
                );
            }
        }
    }
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let intervals: Vec<f64> = if args.has("fast") {
        vec![0.5, 0.2, 0.1]
    } else {
        vec![0.5, 0.33, 0.2, 0.14, 0.1]
    };
    let duration = args.f64_or("seconds", if args.has("fast") { 15.0 } else { 30.0 });
    let workers = args.usize_or("workers", ace::sweep::default_workers());
    let cfgs = fig5_grid(&intervals, &[0.0, 50.0], duration, 1);
    let n = cfgs.len();
    // load + calibrate BEFORE the timer, so the printed wall-clock
    // measures the sweep alone (the number the serial-vs-parallel
    // comparison in the CI smoke step reads)
    let real = if args.has("synthetic") { None } else { Some(load_real()?) };
    let t0 = Instant::now();
    // cells run on the worker pool; with real compute each worker gets
    // its own InferCache over one shared Arc<ModelBank>, so inference
    // never serializes across workers
    let cells = match real {
        None => run_sweep(cfgs, workers, || {
            (ServiceTimes::synthetic(), Compute::Synthetic { target_bias: 0.05 })
        })?,
        Some((bank, svc)) => run_sweep(cfgs, workers, move || {
            let cache = Arc::new(Mutex::new(InferCache::new()));
            (svc.clone(), Compute::Real { bank: bank.clone(), cache })
        })?,
    };
    let wall = t0.elapsed().as_secs_f64();
    for m in &cells {
        eprintln!(
            "[fig5] {} i={} d={}: F1={:.3} BWC={:.2}MB",
            m.paradigm,
            m.interval_s,
            m.wan_delay_ms,
            m.f1.f1(),
            m.bwc_mb()
        );
    }
    // stderr like the per-cell lines: stdout stays the tables only
    eprintln!("[fig5] {n} cells on {workers} worker(s) in {wall:.2}s wall");
    let tables = ace::metrics::figure5_tables(&cells);
    println!("{tables}");
    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out)?;
        std::fs::write(format!("{out}/results_fig5.md"), &tables)?;
        std::fs::write(
            format!("{out}/results_fig5.csv"),
            ace::metrics::figure5_csv(&cells),
        )?;
        println!("wrote {out}/results_fig5.{{md,csv}}");
    }
    Ok(())
}

/// A comma-separated filter flag (`--fed-pull "a/#,b/+"`); absent or
/// empty means the match-all `#`.
fn filter_list(flag: Option<&str>) -> Vec<String> {
    let filters: Vec<String> = flag
        .unwrap_or("")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if filters.is_empty() {
        vec!["#".to_string()]
    } else {
        filters
    }
}

/// `ace serve`: the sharded broker behind a length-framed JSON TCP
/// front end. Blocks in the poll loop until a client sends a
/// `shutdown` op (the CI smoke job does exactly that via serve-probe).
fn cmd_serve(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 7878);
    let addr = args
        .get("addr")
        .map(str::to_string)
        .unwrap_or_else(|| format!("127.0.0.1:{port}"));
    // --federate HOST:PORT bridges this server to a peer; --fed-pull /
    // --fed-push narrow the bridged filters (comma-separated, both
    // default to the match-all "#")
    let federate = args.get("federate").map(|peer| ace::serve::federate::FederateConfig {
        peer: peer.to_string(),
        pull: filter_list(args.get("fed-pull")),
        push: filter_list(args.get("fed-push")),
    });
    let cfg = ace::serve::ServeConfig {
        shards: args.usize_or("shards", 8),
        max_frame: args.usize_or("max-frame", ace::serve::frame::DEFAULT_MAX_FRAME),
        broker_name: args.get("name").unwrap_or("serve").to_string(),
        pool: args.usize_or("pool", 4),
        federate,
    };
    let server = ace::serve::Server::bind(&addr, &cfg)
        .with_context(|| format!("binding serve listener on {addr}"))?;
    eprintln!(
        "ace serve: listening on {} ({} shards, {} max frame, pool {}{})",
        server.local_addr(),
        cfg.shards,
        cfg.max_frame,
        cfg.pool,
        match &cfg.federate {
            Some(f) => format!(", federating with {}", f.peer),
            None => String::new(),
        }
    );
    server.run().context("serve accept loop failed")?;
    eprintln!("ace serve: shutdown complete");
    Ok(())
}

/// `ace serve-probe`: the in-repo smoke client — publish/subscribe/
/// retained-replay/malformed-frame assertions against a live server,
/// then (unless --no-shutdown) a clean shutdown op.
fn cmd_serve_probe(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .map(str::to_string)
        .unwrap_or_else(|| format!("127.0.0.1:{}", args.usize_or("port", 7878)));
    match ace::serve::probe(&addr, !args.has("no-shutdown")) {
        Ok(()) => {
            eprintln!("serve-probe: all checks passed against {addr}");
            Ok(())
        }
        Err(e) => bail!("serve-probe failed against {addr}: {e}"),
    }
}

fn help() {
    println!(
        "ace — Application-Centric Edge-Cloud Collaborative Intelligence

USAGE: ace <command> [flags]

COMMANDS:
  info         artifacts + model summary
  calibrate    measure PJRT service times     [--reps N]
  classify     classify a synthetic crop      --cls C [--seed S] [--model eoc|coc]
  plan         orchestrate a topology         [--topology FILE]
  run          one experiment cell            --paradigm ci|ei|ace|ace+
               [--interval S] [--delay MS] [--seconds N] [--seed S]
  fig5         the full Figure 5 sweep on a   [--fast] [--seconds N] [--out DIR]
               parallel worker pool           [--workers N] [--synthetic]
  svcrun       an app end-to-end on the       --app videoquery|fedtrain|metro
               generic svcgraph runtime       [--paradigm P] [--interval S]
                                              [--delay MS] [--seconds N]
                                              [--ecs N] [--cams N] [--rounds N]
                                              [--seed S] [--seeds N] [--workers N]
                                              [--real] [--partitions N]
               --partitions N: per-cluster    (0 = auto-detect cores;
               event lanes; for --app metro   trajectories are byte-identical
               the clusters also RUN in       whatever the partition count)
               parallel on a worker pool      [--threads N] [--preset P]
               under conservative windows
               with --scenario FILE: a        [--scenario FILE] [--step-ms MS]
               scripted lifecycle (deploy,
               incremental update, node
               failure -> shield/redeploy,
               node rejoin, fail-link /
               degrade-nic chaos with a
               seeded faults block) drives
               the live graph under virtual
               time
  bench        hot-path micro-benchmarks,     [--json] [--events N] [--subs N]
               both planes                    [--pubs N] [--comps N]
               (BENCH_*.json perf trajectory) [--storm-pubs N] [--broker-subs N]
                                              [--broker-pubs N] [--retained N]
                                              [--replay-subs N] [--hop-pubs N]
                                              [--hop-sinks N] [--timers N]
                                              [--timer-events N]
                                              [--churn-nodes N] [--churn-loss P]
                                              [--churn-runs N] [--metro-ecs N]
                                              [--metro-seconds N]
                                              [--partitions N]
                                              [--contention-producers N]
                                              [--contention-pubs N]
                                              [--rtt-pubs N]
               with --check FILE: exit        [--check BASELINE.json]
               nonzero on throughput          [--tolerance T]
               regressions beyond T (0.25);   [--require-baseline]
               --floor anchors the baseline   [--floor FLOOR.json]
               to a committed numeric record
               (per-metric max);
               --require-baseline also
               fails when the baseline has
               no comparable numbers
  serve        the sharded broker behind a    [--port P] [--addr HOST:PORT]
               length-framed JSON TCP front   [--shards N] [--max-frame BYTES]
               end (poll loop + worker pool); [--name NAME] [--pool N]
               runs until a client sends a    [--federate HOST:PORT]
               shutdown op; --federate        [--fed-pull FILTERS]
               bridges to a peer server       [--fed-push FILTERS]
  serve-probe  in-repo smoke client: pub/sub, [--addr HOST:PORT] [--port P]
               retained replay, malformed-    [--no-shutdown]
               frame recovery asserted
               against a live `ace serve`
  metro-gen    generate a seeded metro        [--preset small|mid|large]
               workload yaml                  [--seed S] [--ecs N] [--seconds N]
               (scenarios/metro_*.yaml)       [--out FILE]
  help         this message"
    );
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.cmd.as_str() {
        "info" => cmd_info(),
        "calibrate" => cmd_calibrate(&args),
        "classify" => cmd_classify(&args),
        "plan" => cmd_plan(&args),
        "run" => cmd_run(&args),
        "fig5" => cmd_fig5(&args),
        "svcrun" => cmd_svcrun(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "serve-probe" => cmd_serve_probe(&args),
        "metro-gen" => cmd_metro_gen(&args),
        _ => {
            help();
            Ok(())
        }
    }
}
