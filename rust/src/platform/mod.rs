//! Platform layer (§4.2): controller, orchestrator, API server,
//! monitoring service. (The Pub/Sub service itself lives in `pubsub`;
//! user interfaces are the CLI in `main.rs`.)

pub mod api;
pub mod controller;
pub mod monitor;
pub mod orchestrator;

pub use api::{ApiServer, Entity};
pub use controller::Controller;
pub use monitor::Monitor;
