//! Platform controller (§4.2.1, Figure 4 step ②).
//!
//! Transforms deployment plans into per-node compose-style instructions
//! and publishes them on the message service for node agents; manages
//! application lifecycle (deploy / thorough update / incremental update
//! / remove) and shields failed nodes based on monitoring heartbeats.

use crate::deploy::{diff_plans, DeploymentPlan};
use crate::infra::agent::{compose_instruction, deploy_topic};
use crate::infra::Infrastructure;
use crate::json::Value;
use crate::platform::api::{kinds, ApiServer};
use crate::platform::orchestrator;
use crate::pubsub::Broker;
use crate::topology::Topology;
use crate::util::AceId;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// The controller talks to node agents through per-cluster brokers
/// (each EC + the CC runs its own message service; the platform reaches
/// them over the bridged links).
pub struct Controller {
    /// The platform's entity store (plans, app states, node statuses).
    pub api: ApiServer,
    /// cluster leaf ("ec-1", "cc") -> broker handle
    brokers: BTreeMap<String, Broker>,
}

/// Serialize a deployment plan as the API server's wire document
/// (shared by the threaded controller and the virtual-time
/// `svcgraph::lifecycle` control plane).
pub fn plan_to_value(plan: &DeploymentPlan) -> Value {
    Value::obj(vec![
        ("app", Value::str(&plan.app)),
        ("version", Value::num(plan.version as f64)),
        (
            "instances",
            Value::Arr(
                plan.instances
                    .iter()
                    .map(|i| {
                        Value::obj(vec![
                            ("id", Value::str(&i.id)),
                            ("component", Value::str(&i.component)),
                            ("node", Value::str(i.node.to_string())),
                            ("image", Value::str(&i.image)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse a deployment plan back out of its API-server document
/// (inverse of [`plan_to_value`]).
pub fn plan_from_value(v: &Value) -> Result<DeploymentPlan> {
    let instances = v
        .get("instances")
        .as_arr()
        .context("plan: instances")?
        .iter()
        .map(|i| {
            Ok(crate::deploy::Instance {
                id: i.get("id").as_str().context("id")?.to_string(),
                component: i.get("component").as_str().context("component")?.to_string(),
                node: AceId::parse(i.get("node").as_str().context("node")?),
                image: i.get("image").as_str().context("image")?.to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DeploymentPlan {
        app: v.get("app").as_str().context("app")?.to_string(),
        version: v.get("version").as_i64().unwrap_or(1) as u64,
        instances,
    })
}

impl Controller {
    /// A controller over `api` talking to `brokers` (cluster leaf →
    /// broker handle).
    pub fn new(api: ApiServer, brokers: BTreeMap<String, Broker>) -> Self {
        Controller { api, brokers }
    }

    fn broker_for(&self, node: &AceId) -> Result<&Broker> {
        let cluster = node.parent().ok_or_else(|| anyhow!("node id too shallow"))?;
        self.brokers
            .get(cluster.leaf())
            .ok_or_else(|| anyhow!("no broker for cluster '{}'", cluster.leaf()))
    }

    /// Send the current full instruction set for `node` given all
    /// stored plans (agents converge to the instruction).
    fn sync_node(&self, node: &AceId) -> Result<()> {
        // gather every instance of every app bound to this node
        let mut services: Vec<(String, String, String)> = Vec::new();
        let mut app_names: Vec<String> = Vec::new();
        for e in self.api.list(kinds::PLAN) {
            let plan = plan_from_value(&e.doc)?;
            for inst in &plan.instances {
                if &inst.node == node {
                    services.push((inst.id.clone(), inst.component.clone(), inst.image.clone()));
                    app_names.push(plan.app.clone());
                }
            }
        }
        let app_label = app_names.first().cloned().unwrap_or_default();
        let doc = compose_instruction(&app_label, &services);
        let broker = self.broker_for(node)?;
        broker
            .publish(&deploy_topic(node), doc.into_bytes())
            .map_err(|e| anyhow!("{e}"))?;
        Ok(())
    }

    /// Deploy an application: orchestrate, persist topology + plan,
    /// push instructions to every bound node. Returns the plan.
    pub fn deploy(&self, topo: &Topology, infra: &Infrastructure) -> Result<DeploymentPlan> {
        let plan = orchestrator::place(topo, infra)?;
        self.api.put(
            kinds::TOPOLOGY,
            &topo.app,
            crate::json::parse(&format!("{{\"version\": {}}}", topo.version)).unwrap(),
        );
        self.api.put(kinds::PLAN, &plan.app, plan_to_value(&plan));
        self.api.put(
            kinds::APP,
            &plan.app,
            Value::obj(vec![
                ("state", Value::str("deployed")),
                ("version", Value::num(plan.version as f64)),
            ]),
        );
        for node in plan.nodes() {
            self.sync_node(&node)?;
        }
        Ok(plan)
    }

    /// Incremental update (§4.4.3): only nodes whose instance set
    /// changed receive a new instruction. Returns (plan, touched-node
    /// count).
    pub fn update_incremental(
        &self,
        topo: &Topology,
        infra: &Infrastructure,
    ) -> Result<(DeploymentPlan, usize)> {
        let old = self
            .api
            .get(kinds::PLAN, &topo.app)
            .ok_or_else(|| anyhow!("app '{}' not deployed", topo.app))?;
        let old_plan = plan_from_value(&old.doc)?;
        let new_plan = orchestrator::place(topo, infra)?;
        let diff = diff_plans(&old_plan, &new_plan);
        self.api.put(kinds::PLAN, &new_plan.app, plan_to_value(&new_plan));
        self.api.put(
            kinds::APP,
            &new_plan.app,
            Value::obj(vec![
                ("state", Value::str("deployed")),
                ("version", Value::num(new_plan.version as f64)),
            ]),
        );
        let touched = diff.touched_nodes();
        for node in &touched {
            self.sync_node(node)?;
        }
        Ok((new_plan, touched.len()))
    }

    /// Thorough update (§4.4.3): delete + full redeploy.
    pub fn update_thorough(
        &self,
        topo: &Topology,
        infra: &Infrastructure,
    ) -> Result<DeploymentPlan> {
        let _ = self.remove(&topo.app);
        self.deploy(topo, infra)
    }

    /// Remove an application: clear its plan and re-sync every node it
    /// touched (agents converge to instance removal).
    pub fn remove(&self, app: &str) -> Result<()> {
        let plan_e = self
            .api
            .get(kinds::PLAN, app)
            .ok_or_else(|| anyhow!("app '{app}' not deployed"))?;
        let plan = plan_from_value(&plan_e.doc)?;
        self.api.delete(kinds::PLAN, app).map_err(|e| anyhow!("{e}"))?;
        let _ = self.api.delete(kinds::APP, app);
        let _ = self.api.delete(kinds::TOPOLOGY, app);
        for node in plan.nodes() {
            self.sync_node(&node)?;
        }
        Ok(())
    }

    /// Shield nodes whose last heartbeat is older than `cutoff_unix_ms`
    /// (monitoring writes `node-status` entities). Marks them Failed in
    /// `infra`; returns shielded ids (§4.2.1 "shields failed nodes").
    pub fn shield_failed(
        &self,
        infra: &mut Infrastructure,
        cutoff_unix_ms: u64,
    ) -> Vec<AceId> {
        let mut shielded = Vec::new();
        let node_ids: Vec<AceId> =
            infra.all_nodes().map(|(_, n)| n.id.clone()).collect();
        for id in node_ids {
            let key = id.to_string().replace('/', ".");
            let stale = match self.api.get(kinds::NODE_STATUS, &key) {
                Some(e) => {
                    (e.doc.get("last_seen_ms").as_f64().unwrap_or(0.0) as u64) < cutoff_unix_ms
                }
                None => true,
            };
            if stale {
                if let Some(n) = infra.find_node_mut(&id) {
                    if n.status == crate::infra::NodeStatus::Ready {
                        n.status = crate::infra::NodeStatus::Failed;
                        shielded.push(id);
                    }
                }
            }
        }
        shielded
    }

    /// Stored plan for an app (if deployed).
    pub fn plan(&self, app: &str) -> Option<DeploymentPlan> {
        self.api
            .get(kinds::PLAN, app)
            .and_then(|e| plan_from_value(&e.doc).ok())
    }
}

/// Record a heartbeat (normally done by the monitoring service).
pub fn record_heartbeat(api: &ApiServer, node: &AceId, unix_ms: u64, doc: Value) {
    let key = node.to_string().replace('/', ".");
    let mut obj = match doc {
        Value::Obj(o) => o,
        _ => Default::default(),
    };
    obj.insert("last_seen_ms".to_string(), Value::num(unix_ms as f64));
    api.put(kinds::NODE_STATUS, &key, Value::Obj(obj));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::agent::{status_topic, Agent};
    use crate::infra::paper_testbed;
    use crate::topology::VIDEOQUERY_TOPOLOGY;
    use std::time::Duration;

    fn brokers_for(infra: &Infrastructure) -> BTreeMap<String, Broker> {
        infra
            .clusters()
            .map(|c| (c.id.leaf().to_string(), Broker::new(c.id.leaf())))
            .collect()
    }

    fn wait_for<F: Fn() -> bool>(f: F) {
        for _ in 0..300 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("condition not reached");
    }

    #[test]
    fn deploy_reaches_agents() {
        let infra = paper_testbed("u1");
        let brokers = brokers_for(&infra);
        let ctl = Controller::new(ApiServer::new(), brokers.clone());
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();

        // start agents on one EC's camera node and the CC node
        let cam = infra.ecs[0].nodes[1].id.clone();
        let cc = infra.cc.nodes[0].id.clone();
        let a1 = Agent::start(cam.clone(), brokers["ec-1"].clone()).unwrap();
        let a2 = Agent::start(cc.clone(), brokers["cc"].clone()).unwrap();

        let plan = ctl.deploy(&topo, &infra).unwrap();
        assert_eq!(plan.instances_of("od").len(), 9);

        wait_for(|| a1.running().iter().any(|r| r.component == "od"));
        wait_for(|| a2.running().iter().any(|r| r.component == "coc"));
        assert!(a1.running().iter().any(|r| r.component == "dg"));
        assert_eq!(
            a2.running().len(),
            3, // coc + ic + rs all bind to the single CC node
        );
    }

    #[test]
    fn incremental_update_touches_minimal_nodes() {
        let infra = paper_testbed("u1");
        let ctl = Controller::new(ApiServer::new(), brokers_for(&infra));
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        ctl.deploy(&topo, &infra).unwrap();

        // bump only od's image
        let mut topo2 = topo.clone();
        topo2.version = 2;
        for c in &mut topo2.components {
            if c.name == "od" {
                c.image = "ace/object-detector:2".into();
            }
        }
        let (_plan, touched) = ctl.update_incremental(&topo2, &infra).unwrap();
        assert_eq!(touched, 9); // only the 9 camera nodes
    }

    #[test]
    fn remove_clears_plan_and_instructions() {
        let infra = paper_testbed("u1");
        let brokers = brokers_for(&infra);
        let ctl = Controller::new(ApiServer::new(), brokers.clone());
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let cam = infra.ecs[0].nodes[1].id.clone();
        let agent = Agent::start(cam.clone(), brokers["ec-1"].clone()).unwrap();
        ctl.deploy(&topo, &infra).unwrap();
        wait_for(|| !agent.running().is_empty());
        ctl.remove("videoquery").unwrap();
        wait_for(|| agent.running().is_empty());
        assert!(ctl.plan("videoquery").is_none());
        assert!(ctl.remove("videoquery").is_err());
    }

    #[test]
    fn shield_failed_marks_stale_nodes() {
        let mut infra = paper_testbed("u1");
        let ctl = Controller::new(ApiServer::new(), brokers_for(&infra));
        // heartbeat only the CC node at t=1000
        let cc = infra.cc.nodes[0].id.clone();
        record_heartbeat(&ctl.api, &cc, 1000, Value::obj(vec![]));
        let shielded = ctl.shield_failed(&mut infra, 500);
        // all 12 edge nodes never heartbeated -> shielded; CC survives
        assert_eq!(shielded.len(), 12);
        assert!(infra.find_node(&cc).unwrap().schedulable());
    }

    #[test]
    fn agent_status_flows_back() {
        let infra = paper_testbed("u1");
        let brokers = brokers_for(&infra);
        let ctl = Controller::new(ApiServer::new(), brokers.clone());
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let cam = infra.ecs[0].nodes[1].id.clone();
        let sub = brokers["ec-1"].subscribe(&status_topic(&cam)).unwrap();
        let _agent = Agent::start(cam.clone(), brokers["ec-1"].clone()).unwrap();
        ctl.deploy(&topo, &infra).unwrap();
        let status = sub.rx.recv_timeout(Duration::from_secs(2)).unwrap();
        let v = crate::json::parse(&status.utf8()).unwrap();
        assert!(v.get("instances").as_arr().unwrap().len() >= 1);
    }
}
