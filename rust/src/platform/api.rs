//! API server (§4.2.1): uniform CRUD over ACE entities.
//!
//! "Provides uniform APIs for querying and manipulating the status of
//! ACE entities (users, nodes, applications) to other platform manager
//! components (orchestrator, controller)." Entities are stored as
//! `json::Value` documents under (kind, id) with optimistic-concurrency
//! revisions; a monotonically increasing store revision supports cheap
//! change detection (the dashboard/CLI poll it).

use crate::json::Value;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// One stored ACE entity: a JSON document under `(kind, id)` with an
/// optimistic-concurrency revision.
#[derive(Debug, Clone, PartialEq)]
pub struct Entity {
    /// Entity kind (see [`kinds`]).
    pub kind: String,
    /// Id, unique within the kind.
    pub id: String,
    /// Revision assigned by the last write (CAS token).
    pub revision: u64,
    /// The document itself.
    pub doc: Value,
}

#[derive(Default)]
struct Inner {
    entities: BTreeMap<(String, String), Entity>,
    revision: u64,
}

/// Thread-safe entity store.
#[derive(Clone, Default)]
pub struct ApiServer {
    inner: Arc<Mutex<Inner>>,
}

/// API-server errors (CRUD over entities).
#[derive(Debug, PartialEq)]
pub enum ApiError {
    /// No entity under that `(kind, id)`.
    NotFound,
    /// CAS lost: the entity's current revision is `have`.
    Conflict {
        /// The revision actually stored.
        have: u64,
    },
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::NotFound => write!(f, "entity not found"),
            ApiError::Conflict { have } => write!(f, "revision conflict (have {have})"),
        }
    }
}

impl std::error::Error for ApiError {}

impl ApiServer {
    /// An empty store at revision 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create or replace unconditionally. Returns the new revision.
    pub fn put(&self, kind: &str, id: &str, doc: Value) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.revision += 1;
        let rev = inner.revision;
        inner.entities.insert(
            (kind.to_string(), id.to_string()),
            Entity { kind: kind.to_string(), id: id.to_string(), revision: rev, doc },
        );
        rev
    }

    /// Compare-and-swap update: succeeds only if the entity's current
    /// revision equals `expect`.
    pub fn cas(&self, kind: &str, id: &str, expect: u64, doc: Value) -> Result<u64, ApiError> {
        let mut inner = self.inner.lock().unwrap();
        let key = (kind.to_string(), id.to_string());
        match inner.entities.get(&key) {
            None => Err(ApiError::NotFound),
            Some(e) if e.revision != expect => Err(ApiError::Conflict { have: e.revision }),
            Some(_) => {
                inner.revision += 1;
                let rev = inner.revision;
                inner.entities.insert(
                    key,
                    Entity { kind: kind.to_string(), id: id.to_string(), revision: rev, doc },
                );
                Ok(rev)
            }
        }
    }

    /// Read one entity.
    pub fn get(&self, kind: &str, id: &str) -> Option<Entity> {
        self.inner
            .lock()
            .unwrap()
            .entities
            .get(&(kind.to_string(), id.to_string()))
            .cloned()
    }

    /// Delete one entity (bumps the store revision on success).
    pub fn delete(&self, kind: &str, id: &str) -> Result<(), ApiError> {
        let mut inner = self.inner.lock().unwrap();
        inner
            .entities
            .remove(&(kind.to_string(), id.to_string()))
            .map(|_| {
                inner.revision += 1;
            })
            .ok_or(ApiError::NotFound)
    }

    /// All entities of a kind, ordered by id.
    pub fn list(&self, kind: &str) -> Vec<Entity> {
        self.inner
            .lock()
            .unwrap()
            .entities
            .range((kind.to_string(), String::new())..)
            .take_while(|((k, _), _)| k == kind)
            .map(|(_, e)| e.clone())
            .collect()
    }

    /// Global store revision (bumps on every mutation).
    pub fn revision(&self) -> u64 {
        self.inner.lock().unwrap().revision
    }
}

/// Entity kind names used across the platform.
pub mod kinds {
    pub const USER: &str = "user";
    pub const INFRA: &str = "infrastructure";
    pub const TOPOLOGY: &str = "topology";
    pub const PLAN: &str = "plan";
    pub const NODE_STATUS: &str = "node-status";
    pub const APP: &str = "application";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crud_lifecycle() {
        let api = ApiServer::new();
        let rev = api.put(kinds::USER, "u1", Value::obj(vec![("name", Value::str("alice"))]));
        let e = api.get(kinds::USER, "u1").unwrap();
        assert_eq!(e.revision, rev);
        assert_eq!(e.doc.get("name").as_str(), Some("alice"));
        assert!(api.delete(kinds::USER, "u1").is_ok());
        assert!(api.get(kinds::USER, "u1").is_none());
        assert_eq!(api.delete(kinds::USER, "u1"), Err(ApiError::NotFound));
    }

    #[test]
    fn cas_enforces_revisions() {
        let api = ApiServer::new();
        let rev = api.put("t", "x", Value::num(1));
        assert!(api.cas("t", "x", rev, Value::num(2)).is_ok());
        // stale revision rejected
        assert!(matches!(
            api.cas("t", "x", rev, Value::num(3)),
            Err(ApiError::Conflict { .. })
        ));
        assert_eq!(api.get("t", "x").unwrap().doc.as_f64(), Some(2.0));
        assert_eq!(api.cas("t", "ghost", 1, Value::Null), Err(ApiError::NotFound));
    }

    #[test]
    fn list_is_kind_scoped_and_ordered() {
        let api = ApiServer::new();
        api.put("a", "2", Value::Null);
        api.put("a", "1", Value::Null);
        api.put("b", "0", Value::Null);
        let ids: Vec<String> = api.list("a").into_iter().map(|e| e.id).collect();
        assert_eq!(ids, vec!["1", "2"]);
        assert_eq!(api.list("b").len(), 1);
        assert_eq!(api.list("zz").len(), 0);
    }

    #[test]
    fn revision_increases_monotonically() {
        let api = ApiServer::new();
        let r1 = api.put("k", "1", Value::Null);
        let r2 = api.put("k", "2", Value::Null);
        assert!(r2 > r1);
        api.delete("k", "1").unwrap();
        assert!(api.revision() > r2);
    }
}
