//! Monitoring service (§4.2.1): collects status, performance metrics,
//! and runtime logs of nodes + application components.
//!
//! Subscribes `ace/status/#` on every cluster broker; each report is
//! folded into the API server as a `node-status` entity (with a
//! `last_seen_ms` stamp the controller's failure shielding reads) and
//! into in-memory metric counters queryable by the CLI/dashboard.

use crate::json::{self, Value};
use crate::platform::api::{kinds, ApiServer};
use crate::pubsub::Broker;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{SystemTime, UNIX_EPOCH};

fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Live health of one component, folded from agent status reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ComponentHealth {
    /// Running instance count.
    pub running: usize,
    /// Nodes currently reporting an instance of the component.
    pub nodes: Vec<String>,
}

/// The monitoring service (collection threads, one per cluster
/// broker); see the module docs.
pub struct Monitor {
    api: ApiServer,
    reports: Arc<AtomicU64>,
    components: Arc<Mutex<BTreeMap<String, ComponentHealth>>>,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl Monitor {
    /// Start collection threads, one per cluster broker.
    pub fn start(api: ApiServer, brokers: &BTreeMap<String, Broker>) -> Result<Monitor, String> {
        let stop = Arc::new(AtomicBool::new(false));
        let reports = Arc::new(AtomicU64::new(0));
        let components: Arc<Mutex<BTreeMap<String, ComponentHealth>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let mut threads = Vec::new();
        for broker in brokers.values() {
            let sub = broker.subscribe("ace/status/#")?;
            let api = api.clone();
            let stop = stop.clone();
            let reports = reports.clone();
            let components = components.clone();
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match sub.rx.recv_timeout(std::time::Duration::from_millis(20)) {
                        Ok(msg) => {
                            if let Ok(v) = json::parse(&msg.utf8()) {
                                Self::ingest(&api, &components, &v);
                                reports.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            }));
        }
        Ok(Monitor { api, reports, components, stop, threads })
    }

    fn ingest(
        api: &ApiServer,
        components: &Arc<Mutex<BTreeMap<String, ComponentHealth>>>,
        v: &Value,
    ) {
        let node = v.get("node").as_str().unwrap_or("?").to_string();
        let key = node.replace('/', ".");
        let mut doc = match v.clone() {
            Value::Obj(o) => o,
            _ => return,
        };
        doc.insert("last_seen_ms".to_string(), Value::num(unix_ms() as f64));
        api.put(kinds::NODE_STATUS, &key, Value::Obj(doc));
        // fold per-component health
        let mut comp = components.lock().unwrap();
        // remove this node from all entries, then re-add from the report
        for h in comp.values_mut() {
            h.nodes.retain(|n| n != &node);
            h.running = h.nodes.len();
        }
        if let Some(instances) = v.get("instances").as_arr() {
            for inst in instances {
                if let Some(c) = inst.get("component").as_str() {
                    let h = comp.entry(c.to_string()).or_default();
                    h.nodes.push(node.clone());
                    h.running = h.nodes.len();
                }
            }
        }
        comp.retain(|_, h| h.running > 0);
    }

    /// Total status reports ingested.
    pub fn reports(&self) -> u64 {
        self.reports.load(Ordering::Relaxed)
    }

    /// Health snapshot per component.
    pub fn component_health(&self) -> BTreeMap<String, ComponentHealth> {
        self.components.lock().unwrap().clone()
    }

    /// Node-status entities currently known (from the API server).
    pub fn node_statuses(&self) -> Vec<(String, Value)> {
        self.api
            .list(kinds::NODE_STATUS)
            .into_iter()
            .map(|e| (e.id, e.doc))
            .collect()
    }

    /// Stop the collection threads and wait for them to exit.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::agent::{compose_instruction, deploy_topic, Agent};
    use crate::util::AceId;
    use std::time::Duration;

    fn wait_for<F: Fn() -> bool>(f: F) {
        for _ in 0..300 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("condition not reached");
    }

    #[test]
    fn monitor_ingests_agent_reports() {
        let broker = Broker::new("ec-1");
        let mut brokers = BTreeMap::new();
        brokers.insert("ec-1".to_string(), broker.clone());
        let api = ApiServer::new();
        let monitor = Monitor::start(api.clone(), &brokers).unwrap();

        let node = AceId::parse("infra-1/ec-1/rpi1");
        let _agent = Agent::start(node.clone(), broker.clone()).unwrap();
        let doc = compose_instruction("vq", &[("od-1".into(), "od".into(), "img".into())]);
        broker.publish(&deploy_topic(&node), doc.into_bytes()).unwrap();

        wait_for(|| monitor.reports() >= 1);
        wait_for(|| monitor.component_health().contains_key("od"));
        let health = monitor.component_health();
        assert_eq!(health["od"].running, 1);
        assert_eq!(health["od"].nodes, vec!["infra-1/ec-1/rpi1".to_string()]);

        // node-status entity exists with a heartbeat stamp
        let statuses = monitor.node_statuses();
        assert_eq!(statuses.len(), 1);
        assert!(statuses[0].1.get("last_seen_ms").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn component_health_updates_on_removal() {
        let broker = Broker::new("ec-1");
        let mut brokers = BTreeMap::new();
        brokers.insert("ec-1".to_string(), broker.clone());
        let monitor = Monitor::start(ApiServer::new(), &brokers).unwrap();
        let node = AceId::parse("infra-1/ec-1/rpi2");
        let _agent = Agent::start(node.clone(), broker.clone()).unwrap();
        let d1 = compose_instruction("vq", &[("x-1".into(), "x".into(), "i".into())]);
        broker.publish(&deploy_topic(&node), d1.into_bytes()).unwrap();
        wait_for(|| monitor.component_health().contains_key("x"));
        let d2 = compose_instruction("vq", &[]);
        broker.publish(&deploy_topic(&node), d2.into_bytes()).unwrap();
        wait_for(|| !monitor.component_health().contains_key("x"));
    }
}
