//! Platform-layer orchestrator (§4.2.1, §4.4.3, Figure 4 step ①).
//!
//! Binds every component of a topology to concrete nodes such that all
//! resource (cpu/mem) and user (edge/cloud location, node labels)
//! requirements hold. Placement:
//!
//!   * filter: schedulable + location + label + resources fit;
//!   * score: spread — pick the candidate with the most free CPU after
//!     allocation (keeps ECs balanced, mirrors the paper's goal of not
//!     hand-mapping components to nodes);
//!   * NETWORK-AWARE scoring (PR 5): when the infrastructure has
//!     bandwidth-constrained access links ([`NetHints`]), the score
//!     additionally prefers co-locating chatty component pairs (the
//!     topology's connection edges — the same edges the svcgraph
//!     transport charges) and penalizes NICs already committed to
//!     carry traffic relative to their bandwidth. With DEGENERATE
//!     hints (no constrained NIC anywhere) the scoring reduces
//!     byte-for-byte to the CPU-spread rule, so every pre-PR-5
//!     placement — and therefore every golden trajectory — is
//!     unchanged;
//!   * `per-label` pins one instance on EVERY matching node, `per-ec`
//!     one per EC, `replicas(n)` the n best nodes.
//!
//! Resources are deducted on a scratch copy as instances are placed, so
//! co-located components contend for the same capacity (Principle
//! Three: multiple applications can share an infrastructure — call
//! `place_onto` with the live infrastructure to persist allocations).

use crate::deploy::{DeploymentPlan, Instance};
use crate::infra::{Cluster, ClusterKind, Infrastructure, Node};
use crate::simnet::NetFabric;
use crate::topology::{ComponentSpec, Location, Placement, Topology};
use crate::util::AceId;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Per-node access-link bandwidths, as placement sees them: only
/// CONSTRAINED NICs appear (unlimited NICs and unlisted nodes are
/// free). Keyed cluster leaf → node leaf — `"ec-1"`/`"rpi1"`,
/// `"cc"`/`"gpu-ws"` — matching the infra id layers; the nesting keeps
/// the scoring-loop lookups allocation-free (`&str` probes).
#[derive(Debug, Clone, Default)]
pub struct NetHints {
    nic_mbps: BTreeMap<String, BTreeMap<String, f64>>,
}

impl NetHints {
    /// Derive hints from the simulated link graph, so the orchestrator
    /// scores against exactly the links the transport will charge.
    pub fn from_net(net: &NetFabric) -> NetHints {
        let mut nic_mbps: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
        let num_ecs = net.num_ecs();
        for (ci, cluster) in net.clusters.iter().enumerate() {
            let leaf = crate::simnet::cluster_leaf(ci, num_ecs);
            for (node, nic) in cluster.iter_nics() {
                if let Some(mbps) = nic.mbps() {
                    nic_mbps.entry(leaf.clone()).or_default().insert(node.to_string(), mbps);
                }
            }
        }
        NetHints { nic_mbps }
    }

    /// Degenerate = no constrained NIC anywhere ⇒ scoring reduces to
    /// the pure CPU-spread rule.
    pub fn is_degenerate(&self) -> bool {
        self.nic_mbps.values().all(|nodes| nodes.is_empty())
    }

    /// The constrained access bandwidth of `node`, if any.
    pub fn nic_mbps(&self, cluster_leaf: &str, node_leaf: &str) -> Option<f64> {
        self.nic_mbps.get(cluster_leaf)?.get(node_leaf).copied()
    }
}

fn label_matches(node: &Node, label: &Option<String>) -> bool {
    match label {
        None => true,
        Some(l) => match l.split_once('=') {
            Some((k, v)) => node.has_label(k, Some(v)),
            None => node.has_label(l, None),
        },
    }
}

fn location_matches(cluster: &Cluster, loc: Location) -> bool {
    match loc {
        Location::Any => true,
        Location::Edge => cluster.kind == ClusterKind::EdgeCloud,
        Location::Cloud => cluster.kind == ClusterKind::CentralCloud,
    }
}

fn instance_id(component: &str, node: &AceId) -> String {
    let mut parts: Vec<&str> = Vec::new();
    let s = node.to_string();
    for p in s.split('/').skip(1) {
        parts.push(p);
    }
    format!("{component}-{}", parts.join("-"))
}

/// Orchestrate `topo` onto (a scratch copy of) `infra`.
pub fn place(topo: &Topology, infra: &Infrastructure) -> Result<DeploymentPlan> {
    place_with_net(topo, infra, None)
}

/// Orchestrate and DEDUCT allocations from `infra` (persistent form,
/// used when several applications share the infrastructure).
pub fn place_onto(topo: &Topology, infra: &mut Infrastructure) -> Result<DeploymentPlan> {
    place_onto_with_net(topo, infra, None)
}

/// [`place`] with network-aware scoring (see the module docs). `None`
/// or degenerate hints reproduce the CPU-spread placement exactly.
pub fn place_with_net(
    topo: &Topology,
    infra: &Infrastructure,
    hints: Option<&NetHints>,
) -> Result<DeploymentPlan> {
    let mut scratch = infra.clone();
    place_onto_with_net(topo, &mut scratch, hints)
}

/// [`place_onto`] with network-aware scoring.
pub fn place_onto_with_net(
    topo: &Topology,
    infra: &mut Infrastructure,
    hints: Option<&NetHints>,
) -> Result<DeploymentPlan> {
    let mut placer = Placer::new(topo, hints);
    let mut instances = Vec::new();
    for comp in &topo.components {
        let placed = placer.place_component(comp, infra)?;
        instances.extend(placed);
    }
    Ok(DeploymentPlan { app: topo.app.clone(), version: topo.version, instances })
}

fn candidates<'a>(
    comp: &ComponentSpec,
    infra: &'a Infrastructure,
) -> Vec<(&'a Cluster, &'a Node)> {
    infra
        .clusters()
        .filter(|c| location_matches(c, comp.location))
        .flat_map(|c| c.nodes.iter().map(move |n| (c, n)))
        .filter(|(_, n)| n.schedulable())
        .filter(|(_, n)| label_matches(n, &comp.label))
        .filter(|(_, n)| n.allocatable.fits(&comp.resources))
        .collect()
}

/// Placement state threaded through one `place_onto_with_net` run:
/// what has been placed so far (for co-location affinity) and how much
/// traffic each node's NIC is already committed to carry (for the
/// saturation penalty).
struct Placer<'a> {
    hints: Option<&'a NetHints>,
    /// Undirected component adjacency (the topology's connection
    /// edges — what the svcgraph transport will charge).
    adj: BTreeMap<String, BTreeSet<String>>,
    /// Per-component edge-weight units: the `traffic` topology param
    /// when present, else the component's connection degree.
    units: BTreeMap<String, u64>,
    /// Instances placed so far, in placement order.
    placed: Vec<Instance>,
    /// node id → committed traffic units.
    committed: BTreeMap<AceId, u64>,
}

impl<'a> Placer<'a> {
    fn new(topo: &Topology, hints: Option<&'a NetHints>) -> Self {
        let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        for (a, b) in topo.edges() {
            adj.entry(a.clone()).or_default().insert(b.clone());
            adj.entry(b).or_default().insert(a);
        }
        let mut units = BTreeMap::new();
        for c in &topo.components {
            let degree = adj.get(&c.name).map_or(1, |p| p.len().max(1)) as u64;
            let u = c
                .params
                .get("traffic")
                .and_then(|s| s.parse::<u64>().ok())
                .unwrap_or(degree);
            units.insert(c.name.clone(), u.max(1));
        }
        Placer { hints, adj, units, placed: Vec::new(), committed: BTreeMap::new() }
    }

    /// Network-aware scoring active? Only with genuinely constrained
    /// hints — the degenerate config must reproduce the CPU-spread
    /// placement byte-for-byte.
    fn net_active(&self) -> bool {
        self.hints.is_some_and(|h| !h.is_degenerate())
    }

    /// The network score of putting `comp` on `node`: co-location
    /// affinity with already-placed connected components, minus a
    /// penalty proportional to the traffic already committed to a
    /// constrained NIC relative to its bandwidth.
    fn net_score(&self, comp: &ComponentSpec, node: &Node) -> i64 {
        let cluster = node.id.parent();
        let mut score = 0i64;
        if let Some(peers) = self.adj.get(&comp.name) {
            for inst in &self.placed {
                if !peers.contains(&inst.component) {
                    continue;
                }
                if inst.node == node.id {
                    score += 1000; // same node: the hop is free
                } else if inst.node.parent() == cluster {
                    score += 250; // same cluster: LAN, not WAN
                }
            }
        }
        if let (Some(h), Some(cl)) = (self.hints, &cluster) {
            if let Some(mbps) = h.nic_mbps(cl.leaf(), node.id.leaf()) {
                let units = self.committed.get(&node.id).copied().unwrap_or(0)
                    + self.units.get(&comp.name).copied().unwrap_or(1);
                // integer milli-penalty: committed units per Mbps
                score -= ((units as f64 * 1000.0) / mbps.max(1e-3)) as i64;
            }
        }
        score
    }

    /// Best candidate under the active scoring rule. Both arms keep
    /// `max_by_key` (LAST maximum wins) so the degenerate arm is
    /// byte-identical to the historical choice.
    fn best(&self, comp: &ComponentSpec, cands: Vec<(&Cluster, &Node)>) -> Option<AceId> {
        if self.net_active() {
            cands
                .into_iter()
                .max_by_key(|(_, n)| (self.net_score(comp, n), n.allocatable.cpu_millis))
                .map(|(_, n)| n.id.clone())
        } else {
            cands
                .into_iter()
                .max_by_key(|(_, n)| n.allocatable.cpu_millis)
                .map(|(_, n)| n.id.clone())
        }
    }

    fn commit(
        &mut self,
        infra: &mut Infrastructure,
        comp: &ComponentSpec,
        node_id: &AceId,
    ) -> Instance {
        let node = infra.find_node_mut(node_id).expect("placed node exists");
        node.allocatable.sub(&comp.resources);
        *self.committed.entry(node_id.clone()).or_insert(0) +=
            self.units.get(&comp.name).copied().unwrap_or(1);
        let inst = Instance {
            id: instance_id(&comp.name, node_id),
            component: comp.name.clone(),
            node: node_id.clone(),
            image: comp.image.clone(),
        };
        self.placed.push(inst.clone());
        inst
    }

    fn place_component(
        &mut self,
        comp: &ComponentSpec,
        infra: &mut Infrastructure,
    ) -> Result<Vec<Instance>> {
        match &comp.placement {
            Placement::PerLabel => {
                let ids: Vec<_> = candidates(comp, infra)
                    .into_iter()
                    .map(|(_, n)| n.id.clone())
                    .collect();
                if ids.is_empty() {
                    bail!(
                        "component '{}': no node matches label {:?} with {:?} free",
                        comp.name,
                        comp.label,
                        comp.resources
                    );
                }
                Ok(ids.iter().map(|id| self.commit(infra, comp, id)).collect())
            }
            Placement::PerEc => {
                // best node in each EC under the active scoring rule
                let mut picks = Vec::new();
                let ec_leafs: Vec<String> =
                    infra.ecs.iter().map(|c| c.id.leaf().to_string()).collect();
                for leaf in ec_leafs {
                    let cands: Vec<_> = candidates(comp, infra)
                        .into_iter()
                        .filter(|(c, _)| c.id.leaf() == leaf)
                        .collect();
                    match self.best(comp, cands) {
                        Some(id) => picks.push(self.commit(infra, comp, &id)),
                        None => bail!(
                            "component '{}': EC '{leaf}' has no feasible node (need {:?})",
                            comp.name,
                            comp.resources
                        ),
                    }
                }
                Ok(picks)
            }
            Placement::Replicas(n) => {
                let mut placed = Vec::new();
                for i in 0..*n {
                    let cands = candidates(comp, infra);
                    match self.best(comp, cands) {
                        Some(id) => {
                            let mut inst = self.commit(infra, comp, &id);
                            if *n > 1 {
                                inst.id = format!("{}-{i}", inst.id);
                                // keep the stored copy id-consistent
                                if let Some(last) = self.placed.last_mut() {
                                    last.id = inst.id.clone();
                                }
                            }
                            placed.push(inst);
                        }
                        None => bail!(
                            "component '{}': replica {i}/{n} unplaceable (need {:?})",
                            comp.name,
                            comp.resources
                        ),
                    }
                }
                Ok(placed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::paper_testbed;
    use crate::simnet::{NetConfig, NicSpec};
    use crate::topology::{Topology, VIDEOQUERY_TOPOLOGY};

    #[test]
    fn videoquery_places_on_paper_testbed() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        // od + dg on each of 9 camera RPis; eoc + lic per EC (3); coc,
        // ic, rs on CC
        assert_eq!(plan.instances_of("od").len(), 9);
        assert_eq!(plan.instances_of("dg").len(), 9);
        assert_eq!(plan.instances_of("eoc").len(), 3);
        assert_eq!(plan.instances_of("lic").len(), 3);
        assert_eq!(plan.instances_of("coc").len(), 1);
        for inst in plan.instances_of("od") {
            let node = infra.find_node(&inst.node).unwrap();
            assert!(node.has_label("camera", None));
            assert!(node.is_edge());
        }
        for inst in plan.instances_of("coc") {
            assert_eq!(inst.node.parent().unwrap().leaf(), "cc");
        }
        // eoc lands on the mini PCs (most free cpu in each EC)
        for inst in plan.instances_of("eoc") {
            assert_eq!(inst.node.leaf(), "minipc");
        }
    }

    #[test]
    fn resources_are_deducted() {
        let topo = Topology::parse(
            "
app: greedy
components:
  - name: big
    location: cloud
    replicas: 2
    resources:
      cpu: 20000
      mem: 1024
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        // CC has 32000 cpu_millis: first replica fits, second cannot
        let err = place(&topo, &infra).unwrap_err().to_string();
        assert!(err.contains("replica 1/2"), "{err}");
    }

    #[test]
    fn label_value_filters() {
        let topo = Topology::parse(
            "
app: x
components:
  - name: cam
    location: edge
    placement: per-label
    label: camera=true
    resources:
      cpu: 100
      mem: 64
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        assert_eq!(plan.instances.len(), 9);
    }

    #[test]
    fn failed_nodes_are_shielded_from_placement() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let mut infra = paper_testbed("u1");
        // fail one camera node -> od lands on only 8
        let id = infra.ecs[0].nodes[1].id.clone();
        infra.find_node_mut(&id).unwrap().status = crate::infra::NodeStatus::Failed;
        let plan = place(&topo, &infra).unwrap();
        assert_eq!(plan.instances_of("od").len(), 8);
        assert!(plan.instances.iter().all(|i| i.node != id));
    }

    #[test]
    fn cloud_component_never_on_edge() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        for name in ["coc", "ic", "rs"] {
            for inst in plan.instances_of(name) {
                assert_eq!(inst.node.parent().unwrap().leaf(), "cc", "{name}");
            }
        }
    }

    #[test]
    fn multi_app_contention_via_place_onto() {
        let topo = Topology::parse(
            "
app: hog
components:
  - name: svc
    location: cloud
    resources:
      cpu: 30000
      mem: 1024
",
        )
        .unwrap();
        let mut infra = paper_testbed("u1");
        assert!(place_onto(&topo, &mut infra).is_ok());
        // second app no longer fits on the CC
        assert!(place_onto(&topo, &mut infra).is_err());
    }

    // -- network-aware scoring ------------------------------------------------

    #[test]
    fn degenerate_hints_reproduce_the_plan_byte_for_byte() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let infra = paper_testbed("u1");
        let flat = place(&topo, &infra).unwrap();
        let net = NetFabric::new(&NetConfig::default());
        let hints = NetHints::from_net(&net);
        assert!(hints.is_degenerate());
        let hinted = place_with_net(&topo, &infra, Some(&hints)).unwrap();
        assert_eq!(flat, hinted, "degenerate hints must not move anything");
        // explicit UNLIMITED nics are still degenerate for placement
        let net = NetFabric::new(&NetConfig {
            nics: vec![NicSpec {
                cluster: "ec-1".into(),
                node: "rpi1".into(),
                mbps: f64::INFINITY,
                delay_us: 0.0,
            }],
            ..Default::default()
        });
        let hints = NetHints::from_net(&net);
        assert!(hints.is_degenerate());
        assert_eq!(flat, place_with_net(&topo, &infra, Some(&hints)).unwrap());
    }

    fn hints_with(nics: Vec<NicSpec>) -> NetHints {
        NetHints::from_net(&NetFabric::new(&NetConfig { nics, ..Default::default() }))
    }

    #[test]
    fn chatty_pairs_co_locate_under_constrained_nics() {
        // cam is pinned per-label on the RPis; agg connects to cam and
        // fits anywhere on the edge. With a constrained NIC in the
        // infra (anywhere — it activates scoring), agg must land next
        // to its cams rather than on the fattest-CPU mini PC.
        let topo = Topology::parse(
            "
app: chatty
components:
  - name: cam
    location: edge
    placement: per-label
    label: camera
    resources:
      cpu: 100
      mem: 64
    connections: [agg]
  - name: agg
    location: edge
    placement: per-ec
    resources:
      cpu: 500
      mem: 128
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        // flat scoring: the mini PC has the most free CPU
        let flat = place(&topo, &infra).unwrap();
        for inst in flat.instances_of("agg") {
            assert_eq!(inst.node.leaf(), "minipc");
        }
        // a constrained NIC somewhere activates network-aware scoring
        let hints = hints_with(vec![NicSpec {
            cluster: "ec-1".into(),
            node: "minipc".into(),
            mbps: 10.0,
            delay_us: 0.0,
        }]);
        assert!(!hints.is_degenerate());
        let net_plan = place_with_net(&topo, &infra, Some(&hints)).unwrap();
        for inst in net_plan.instances_of("agg") {
            assert!(
                inst.node.leaf().starts_with("rpi"),
                "agg must co-locate with a cam, got {}",
                inst.node
            );
        }
    }

    #[test]
    fn saturated_nics_are_penalized() {
        // all three EC-1 RPis host a cam; rpi1's NIC is starved, so the
        // per-EC agg (equal affinity on every RPi) must avoid rpi1
        let topo = Topology::parse(
            "
app: chatty
components:
  - name: cam
    location: edge
    placement: per-label
    label: camera
    resources:
      cpu: 100
      mem: 64
    connections: [agg]
  - name: agg
    location: edge
    placement: per-ec
    resources:
      cpu: 500
      mem: 128
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        let hints = hints_with(vec![NicSpec {
            cluster: "ec-1".into(),
            node: "rpi1".into(),
            mbps: 1.0,
            delay_us: 0.0,
        }]);
        let plan = place_with_net(&topo, &infra, Some(&hints)).unwrap();
        let ec1_agg = plan
            .instances_of("agg")
            .into_iter()
            .find(|i| i.node.parent().unwrap().leaf() == "ec-1")
            .unwrap()
            .clone();
        assert_ne!(ec1_agg.node.leaf(), "minipc", "affinity still prefers the cams");
        assert_ne!(ec1_agg.node.leaf(), "rpi1", "the starved NIC must repel placement");
    }

    #[test]
    fn traffic_param_weights_the_penalty() {
        // one replica, two candidate nodes with equally-starved NICs;
        // the `traffic` param drives the committed-units bookkeeping
        let topo = Topology::parse(
            "
app: heavy
components:
  - name: pump
    location: cloud
    params:
      traffic: \"50\"
  - name: sink
    location: cloud
    connections: [pump]
",
        )
        .unwrap();
        let mut infra = paper_testbed("u1");
        // give the CC a second node so there is a real choice
        let mut b = crate::infra::InfraBuilder::register("u2");
        b.add_cloud_node("gpu-ws", crate::infra::NodeKind::GpuWorkstation, Default::default());
        b.add_cloud_node("srv2", crate::infra::NodeKind::GpuWorkstation, Default::default());
        infra.cc = b.build().cc;
        let hints = NetHints::from_net(&NetFabric::new(&NetConfig {
            nics: vec![
                NicSpec { cluster: "cc".into(), node: "gpu-ws".into(), mbps: 10.0, delay_us: 0.0 },
                NicSpec { cluster: "cc".into(), node: "srv2".into(), mbps: 10.0, delay_us: 0.0 },
            ],
            ..Default::default()
        }));
        let plan = place_with_net(&topo, &infra, Some(&hints)).unwrap();
        let pump = &plan.instances_of("pump")[0].node;
        let sink = &plan.instances_of("sink")[0].node;
        // pump's `traffic: 50` commits 50 units to its node's 10 Mbps
        // NIC, so co-locating sink there scores 1000 - 5100 while the
        // other node scores 250 - 100: the saturation term must beat a
        // single co-location bonus and push sink to the other server
        assert_ne!(pump.leaf(), sink.leaf(), "sink must avoid the pump-saturated NIC");
    }
}
