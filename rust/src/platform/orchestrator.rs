//! Platform-layer orchestrator (§4.2.1, §4.4.3, Figure 4 step ①).
//!
//! Binds every component of a topology to concrete nodes such that all
//! resource (cpu/mem) and user (edge/cloud location, node labels)
//! requirements hold. Placement:
//!
//!   * filter: schedulable + location + label + resources fit;
//!   * score: spread — pick the candidate with the most free CPU after
//!     allocation (keeps ECs balanced, mirrors the paper's goal of not
//!     hand-mapping components to nodes);
//!   * `per-label` pins one instance on EVERY matching node, `per-ec`
//!     one per EC, `replicas(n)` the n best nodes.
//!
//! Resources are deducted on a scratch copy as instances are placed, so
//! co-located components contend for the same capacity (Principle
//! Three: multiple applications can share an infrastructure — call
//! `place_onto` with the live infrastructure to persist allocations).

use crate::deploy::{DeploymentPlan, Instance};
use crate::infra::{Cluster, ClusterKind, Infrastructure, Node};
use crate::topology::{ComponentSpec, Location, Placement, Topology};
use anyhow::{bail, Result};

fn label_matches(node: &Node, label: &Option<String>) -> bool {
    match label {
        None => true,
        Some(l) => match l.split_once('=') {
            Some((k, v)) => node.has_label(k, Some(v)),
            None => node.has_label(l, None),
        },
    }
}

fn location_matches(cluster: &Cluster, loc: Location) -> bool {
    match loc {
        Location::Any => true,
        Location::Edge => cluster.kind == ClusterKind::EdgeCloud,
        Location::Cloud => cluster.kind == ClusterKind::CentralCloud,
    }
}

fn instance_id(component: &str, node: &crate::util::AceId) -> String {
    let mut parts: Vec<&str> = Vec::new();
    let s = node.to_string();
    for p in s.split('/').skip(1) {
        parts.push(p);
    }
    format!("{component}-{}", parts.join("-"))
}

/// Orchestrate `topo` onto (a scratch copy of) `infra`.
pub fn place(topo: &Topology, infra: &Infrastructure) -> Result<DeploymentPlan> {
    let mut scratch = infra.clone();
    place_onto(topo, &mut scratch)
}

/// Orchestrate and DEDUCT allocations from `infra` (persistent form,
/// used when several applications share the infrastructure).
pub fn place_onto(topo: &Topology, infra: &mut Infrastructure) -> Result<DeploymentPlan> {
    let mut instances = Vec::new();
    for comp in &topo.components {
        let placed = place_component(comp, infra)?;
        instances.extend(placed);
    }
    Ok(DeploymentPlan { app: topo.app.clone(), version: topo.version, instances })
}

fn candidates<'a>(
    comp: &ComponentSpec,
    infra: &'a Infrastructure,
) -> Vec<(&'a Cluster, &'a Node)> {
    infra
        .clusters()
        .filter(|c| location_matches(c, comp.location))
        .flat_map(|c| c.nodes.iter().map(move |n| (c, n)))
        .filter(|(_, n)| n.schedulable())
        .filter(|(_, n)| label_matches(n, &comp.label))
        .filter(|(_, n)| n.allocatable.fits(&comp.resources))
        .collect()
}

fn commit(
    infra: &mut Infrastructure,
    comp: &ComponentSpec,
    node_id: &crate::util::AceId,
) -> Instance {
    let node = infra.find_node_mut(node_id).expect("placed node exists");
    node.allocatable.sub(&comp.resources);
    Instance {
        id: instance_id(&comp.name, node_id),
        component: comp.name.clone(),
        node: node_id.clone(),
        image: comp.image.clone(),
    }
}

fn place_component(comp: &ComponentSpec, infra: &mut Infrastructure) -> Result<Vec<Instance>> {
    match &comp.placement {
        Placement::PerLabel => {
            let ids: Vec<_> = candidates(comp, infra)
                .into_iter()
                .map(|(_, n)| n.id.clone())
                .collect();
            if ids.is_empty() {
                bail!(
                    "component '{}': no node matches label {:?} with {:?} free",
                    comp.name,
                    comp.label,
                    comp.resources
                );
            }
            Ok(ids.iter().map(|id| commit(infra, comp, id)).collect())
        }
        Placement::PerEc => {
            // best (most free cpu) node in each EC
            let mut picks = Vec::new();
            let ec_leafs: Vec<String> =
                infra.ecs.iter().map(|c| c.id.leaf().to_string()).collect();
            for leaf in ec_leafs {
                let best = candidates(comp, infra)
                    .into_iter()
                    .filter(|(c, _)| c.id.leaf() == leaf)
                    .max_by_key(|(_, n)| n.allocatable.cpu_millis)
                    .map(|(_, n)| n.id.clone());
                match best {
                    Some(id) => picks.push(commit(infra, comp, &id)),
                    None => bail!(
                        "component '{}': EC '{leaf}' has no feasible node (need {:?})",
                        comp.name,
                        comp.resources
                    ),
                }
            }
            Ok(picks)
        }
        Placement::Replicas(n) => {
            let mut placed = Vec::new();
            for i in 0..*n {
                let best = candidates(comp, infra)
                    .into_iter()
                    .max_by_key(|(_, nd)| nd.allocatable.cpu_millis)
                    .map(|(_, nd)| nd.id.clone());
                match best {
                    Some(id) => {
                        let mut inst = commit(infra, comp, &id);
                        if *n > 1 {
                            inst.id = format!("{}-{i}", inst.id);
                        }
                        placed.push(inst);
                    }
                    None => bail!(
                        "component '{}': replica {i}/{n} unplaceable (need {:?})",
                        comp.name,
                        comp.resources
                    ),
                }
            }
            Ok(placed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::paper_testbed;
    use crate::topology::{Topology, VIDEOQUERY_TOPOLOGY};

    #[test]
    fn videoquery_places_on_paper_testbed() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        // od + dg on each of 9 camera RPis; eoc + lic per EC (3); coc,
        // ic, rs on CC
        assert_eq!(plan.instances_of("od").len(), 9);
        assert_eq!(plan.instances_of("dg").len(), 9);
        assert_eq!(plan.instances_of("eoc").len(), 3);
        assert_eq!(plan.instances_of("lic").len(), 3);
        assert_eq!(plan.instances_of("coc").len(), 1);
        for inst in plan.instances_of("od") {
            let node = infra.find_node(&inst.node).unwrap();
            assert!(node.has_label("camera", None));
            assert!(node.is_edge());
        }
        for inst in plan.instances_of("coc") {
            assert_eq!(inst.node.parent().unwrap().leaf(), "cc");
        }
        // eoc lands on the mini PCs (most free cpu in each EC)
        for inst in plan.instances_of("eoc") {
            assert_eq!(inst.node.leaf(), "minipc");
        }
    }

    #[test]
    fn resources_are_deducted() {
        let topo = Topology::parse(
            "
app: greedy
components:
  - name: big
    location: cloud
    replicas: 2
    resources:
      cpu: 20000
      mem: 1024
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        // CC has 32000 cpu_millis: first replica fits, second cannot
        let err = place(&topo, &infra).unwrap_err().to_string();
        assert!(err.contains("replica 1/2"), "{err}");
    }

    #[test]
    fn label_value_filters() {
        let topo = Topology::parse(
            "
app: x
components:
  - name: cam
    location: edge
    placement: per-label
    label: camera=true
    resources:
      cpu: 100
      mem: 64
",
        )
        .unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        assert_eq!(plan.instances.len(), 9);
    }

    #[test]
    fn failed_nodes_are_shielded_from_placement() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let mut infra = paper_testbed("u1");
        // fail one camera node -> od lands on only 8
        let id = infra.ecs[0].nodes[1].id.clone();
        infra.find_node_mut(&id).unwrap().status = crate::infra::NodeStatus::Failed;
        let plan = place(&topo, &infra).unwrap();
        assert_eq!(plan.instances_of("od").len(), 8);
        assert!(plan.instances.iter().all(|i| i.node != id));
    }

    #[test]
    fn cloud_component_never_on_edge() {
        let topo = Topology::parse(VIDEOQUERY_TOPOLOGY).unwrap();
        let infra = paper_testbed("u1");
        let plan = place(&topo, &infra).unwrap();
        for name in ["coc", "ic", "rs"] {
            for inst in plan.instances_of(name) {
                assert_eq!(inst.node.parent().unwrap().leaf(), "cc", "{name}");
            }
        }
    }

    #[test]
    fn multi_app_contention_via_place_onto() {
        let topo = Topology::parse(
            "
app: hog
components:
  - name: svc
    location: cloud
    resources:
      cpu: 30000
      mem: 1024
",
        )
        .unwrap();
        let mut infra = paper_testbed("u1");
        assert!(place_onto(&topo, &mut infra).is_ok());
        // second app no longer fits on the CC
        assert!(place_onto(&topo, &mut infra).is_err());
    }
}
