//! Standard base64 (RFC 4648, with padding) — hand-rolled like the
//! rest of the offline substitutions (DESIGN.md §Substitutions).
//!
//! The serve wire protocol is JSON, and JSON strings cannot carry
//! arbitrary bytes; `publish` payloads and `message` pushes travel
//! base64-encoded.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode `data` with standard alphabet + `=` padding.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b1 = *chunk.first().unwrap_or(&0);
        let b2 = *chunk.get(1).unwrap_or(&0);
        let b3 = *chunk.get(2).unwrap_or(&0);
        let n = (u32::from(b1) << 16) | (u32::from(b2) << 8) | u32::from(b3);
        out.push(ALPHABET[((n >> 18) & 63) as usize] as char);
        out.push(ALPHABET[((n >> 12) & 63) as usize] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[((n >> 6) & 63) as usize] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[(n & 63) as usize] as char
        } else {
            '='
        });
    }
    out
}

fn sextet(c: u8) -> Result<u32, String> {
    Ok(u32::from(match c {
        b'A'..=b'Z' => c - b'A',
        b'a'..=b'z' => c - b'a' + 26,
        b'0'..=b'9' => c - b'0' + 52,
        b'+' => 62,
        b'/' => 63,
        _ => return Err(format!("invalid base64 byte 0x{c:02x}")),
    }))
}

/// Decode standard padded base64. Rejects bad lengths, foreign bytes,
/// and `=` anywhere but the final chunk's tail.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.is_empty() {
        return Ok(Vec::new());
    }
    if bytes.len() % 4 != 0 {
        return Err(format!(
            "base64 length {} is not a multiple of 4",
            bytes.len()
        ));
    }
    let chunks = bytes.len() / 4;
    let mut out = Vec::with_capacity(chunks * 3);
    for (ci, chunk) in bytes.chunks(4).enumerate() {
        let pad = if chunk[3] == b'=' {
            if chunk[2] == b'=' {
                2
            } else {
                1
            }
        } else {
            0
        };
        if pad > 0 && ci != chunks - 1 {
            return Err("padding '=' before the final base64 chunk".into());
        }
        let data = &chunk[..4 - pad];
        if data.contains(&b'=') {
            return Err("stray '=' inside a base64 chunk".into());
        }
        let mut n = 0u32;
        for &c in data {
            n = (n << 6) | sextet(c)?;
        }
        n <<= 6 * pad;
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        // the RFC 4648 §10 test vectors, both directions
        let vectors: &[(&str, &str)] = &[
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ];
        for (plain, enc) in vectors {
            assert_eq!(encode(plain.as_bytes()), *enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn all_byte_values_roundtrip() {
        let data: Vec<u8> = (0..=255u8).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
        for cut in [1, 2, 3, 100, 255] {
            assert_eq!(decode(&encode(&data[..cut])).unwrap(), &data[..cut]);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode("abc").is_err(), "length not a multiple of 4");
        assert!(decode("ab!d").is_err(), "foreign byte");
        assert!(decode("a=bc").is_err(), "stray padding mid-chunk");
        assert!(decode("ab==cdef").is_err(), "padding before final chunk");
        assert!(decode("====").is_err(), "all padding");
    }
}
