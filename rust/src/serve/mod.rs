//! `ace serve` — a TCP front end on the sharded threaded broker.
//!
//! The paper's platform claim is user-transparent edge-cloud
//! *services* (§3), not a simulator with a broker inside: external
//! processes must be able to publish, subscribe, and read stats
//! against a LIVE broker. This module is that byte-level surface — a
//! std-thread TCP server speaking the length-framed JSON protocol of
//! [`proto`] (`type`/`timestamp`/`requestId` envelopes) over the
//! codec in [`frame`].
//!
//! Threading (all std threads, no runtime):
//!
//! * one ACCEPT loop ([`Server::run`], usually the main thread);
//! * per connection, a READER thread owning the request half and a
//!   WRITER thread owning the response half, joined by an mpsc queue
//!   of pre-serialized frames — so delivery pushes and responses
//!   never interleave mid-frame;
//! * per subscription, a FORWARDER thread draining the broker's mpsc
//!   receiver into `message` envelopes on the writer queue.
//!
//! Error containment: a malformed frame gets a typed `error` envelope
//! and the connection LIVES ON; an oversized frame gets the error
//! envelope and then a close (the stream cannot be resynced past an
//! unread body) — other clients are never affected. A disconnecting
//! client's subscriptions are torn down by its reader thread.
//!
//! Shutdown: the `shutdown` op acknowledges, then flushes and closes
//! its own connection, sets the stop flag, and pokes the listener with
//! a wake-up connection; `run` then closes every live connection and
//! joins all reader threads before returning, so `ace serve` exits
//! cleanly (the CI smoke `wait`s on exactly this).

pub mod b64;
pub mod client;
pub mod frame;
pub mod proto;

use crate::json::{self, Value};
use crate::pubsub::{Broker, Message};
use frame::{read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME};
use proto::{Envelope, ProtoError, Request};
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Server tuning knobs (`ace serve --shards --max-frame`).
pub struct ServeConfig {
    /// Literal-shard count for the underlying broker.
    pub shards: usize,
    /// Frame-size cap, bytes (see [`frame`]).
    pub max_frame: usize,
    /// Broker (and `Message::origin`) name.
    pub broker_name: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 8,
            max_frame: DEFAULT_MAX_FRAME,
            broker_name: "serve".into(),
        }
    }
}

/// A bound (but not yet serving) server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    broker: Broker,
    stop: Arc<AtomicBool>,
    max_frame: usize,
}

fn now_ts() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 for an ephemeral
    /// port — the integration tests do this).
    pub fn bind(addr: &str, cfg: &ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            broker: Broker::with_shards(cfg.broker_name.as_str(), cfg.shards),
            stop: Arc::new(AtomicBool::new(false)),
            max_frame: cfg.max_frame,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A handle to the underlying broker (for in-process assertions).
    pub fn broker(&self) -> Broker {
        self.broker.clone()
    }

    /// Accept and serve until a client sends `shutdown`. Joins every
    /// connection thread before returning.
    pub fn run(self) -> io::Result<()> {
        // reader-side clones of every live connection, so shutdown can
        // unblock readers parked in `read_frame`
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let mut readers = Vec::new();
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            if let Ok(clone) = stream.try_clone() {
                live.lock().unwrap().push(clone);
            }
            let broker = self.broker.clone();
            let stop = self.stop.clone();
            let addr = self.addr;
            let max_frame = self.max_frame;
            readers.push(thread::spawn(move || {
                handle_conn(stream, broker, stop, addr, max_frame);
            }));
        }
        // stop flag is set: sever every live connection so blocked
        // readers return, then join them (their writers flush first)
        for s in live.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for r in readers {
            let _ = r.join();
        }
        Ok(())
    }
}

/// Serialize an envelope onto a writer queue (best effort — a gone
/// writer means the connection is already tearing down).
fn send(wtx: &Sender<Vec<u8>>, v: &Value) {
    let _ = wtx.send(json::to_string(v).into_bytes());
}

fn handle_conn(
    stream: TcpStream,
    broker: Broker,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    max_frame: usize,
) {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = stream;
    let (wtx, wrx) = channel::<Vec<u8>>();
    let writer_thread = thread::spawn(move || {
        for body in wrx {
            if write_frame(&mut writer, &body).is_err() {
                break;
            }
        }
        let _ = writer.shutdown(Shutdown::Both);
    });
    let mut sub_ids: Vec<u64> = Vec::new();
    let mut shutting_down = false;
    loop {
        let bytes = match read_frame(&mut reader, max_frame) {
            Ok(Some(bytes)) => bytes,
            // clean close (or severed by shutdown)
            Ok(None) | Err(FrameError::Io(_)) => break,
            Err(e @ FrameError::Oversized { .. }) => {
                // the unread body makes the stream unresumable: answer,
                // then close THIS connection only
                send(
                    &wtx,
                    &proto::error(
                        None,
                        now_ts(),
                        "oversized-frame",
                        &format!("{e}; closing this connection"),
                    ),
                );
                break;
            }
        };
        let env = match proto::parse_request(&bytes) {
            Ok(env) => env,
            Err(ProtoError {
                code,
                message,
                request_id,
            }) => {
                // malformed CONTENT is recoverable: typed error, keep
                // serving this connection
                send(
                    &wtx,
                    &proto::error(request_id.as_deref(), now_ts(), code, &message),
                );
                continue;
            }
        };
        if dispatch(env, &broker, &wtx, &mut sub_ids) {
            shutting_down = true;
            break;
        }
    }
    // tear down this connection's subscriptions (forwarder threads see
    // their channels close and exit), then let the writer drain
    for id in sub_ids {
        broker.unsubscribe(id);
    }
    drop(wtx);
    let _ = writer_thread.join();
    if shutting_down {
        // only AFTER our writer flushed the shutdown_ok: stop the
        // accept loop and poke it awake
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr);
    }
}

/// Handle one request; returns true when the server should shut down.
fn dispatch(env: Envelope, broker: &Broker, wtx: &Sender<Vec<u8>>, sub_ids: &mut Vec<u64>) -> bool {
    let rid = env.request_id.as_deref();
    match env.req {
        Request::Publish {
            topic,
            payload,
            retain,
        } => match broker.publish_opts(Message::new(topic, payload), retain) {
            Ok(reached) => send(wtx, &proto::publish_ok(rid, now_ts(), reached)),
            Err(e) => send(wtx, &proto::error(rid, now_ts(), "invalid-topic", &e)),
        },
        Request::Subscribe { filter } => match broker.subscribe(&filter) {
            Ok(handle) => {
                sub_ids.push(handle.id);
                // ack BEFORE spawning the forwarder, so the client sees
                // subscribe_ok ahead of any retained replays
                send(wtx, &proto::subscribe_ok(rid, now_ts(), handle.id));
                let ftx = wtx.clone();
                let sub_id = handle.id;
                thread::spawn(move || {
                    for m in handle.rx.iter() {
                        let body = json::to_string(&proto::message(now_ts(), sub_id, &m));
                        if ftx.send(body.into_bytes()).is_err() {
                            break;
                        }
                    }
                });
            }
            Err(e) => send(wtx, &proto::error(rid, now_ts(), "invalid-filter", &e)),
        },
        Request::Unsubscribe { id } => {
            // only ids owned by THIS connection are removable — one
            // client cannot sever another's subscription
            let removed = if let Some(pos) = sub_ids.iter().position(|&s| s == id) {
                sub_ids.remove(pos);
                broker.unsubscribe(id);
                true
            } else {
                false
            };
            send(wtx, &proto::unsubscribe_ok(rid, now_ts(), removed));
        }
        Request::Stats => send(
            wtx,
            &proto::stats_ok(
                rid,
                now_ts(),
                &broker.name(),
                broker.shard_count(),
                &broker.stats(),
            ),
        ),
        Request::Shutdown => {
            send(wtx, &proto::shutdown_ok(rid, now_ts()));
            return true;
        }
    }
    false
}

/// The in-repo smoke client `ace serve-probe` runs against a live
/// server: exercises every op end-to-end over localhost, asserts the
/// results, and (by default) sends `shutdown` so the server exits
/// cleanly. Returns an error on ANY mismatch — the CI job fails on a
/// non-zero exit.
pub fn probe(addr: &str, send_shutdown: bool) -> Result<(), String> {
    use client::Client;
    let retry = Duration::from_millis(250);
    let mut c1 = Client::connect_retry(addr, 40, retry)
        .map_err(|e| format!("probe could not connect to {addr}: {e}"))?;
    println!("probe: connected to {addr}");

    let st0 = c1.stats()?;
    let pubs0 = st0.get("stats").get("pubCount").as_f64().unwrap_or(-1.0);
    if pubs0 < 0.0 {
        return Err(format!("malformed stats_ok: {st0}"));
    }
    println!(
        "probe: broker '{}' with {} shards, {} publishes so far",
        st0.get("broker").as_str().unwrap_or("?"),
        st0.get("shards").as_f64().unwrap_or(0.0) as usize,
        pubs0 as u64
    );

    // live pub/sub across two connections
    let sub_id = c1.subscribe("probe/#")?;
    let mut c2 = Client::connect(addr).map_err(|e| format!("second connect failed: {e}"))?;
    let reached = c2.publish("probe/x/y", b"hello-from-c2", false)?;
    if reached != 1 {
        return Err(format!("expected to reach 1 subscriber, reached {reached}"));
    }
    let d = c1
        .recv_message(Duration::from_secs(5))?
        .ok_or("no delivery within 5s")?;
    if d.subscription_id != sub_id || d.topic != "probe/x/y" || d.payload != b"hello-from-c2" {
        return Err(format!("wrong delivery: {d:?}"));
    }
    println!("probe: cross-connection delivery OK ({} -> {})", d.origin, d.topic);

    // retained replay for a late subscriber on a third connection
    c2.publish("probe/cfg/threshold", b"0.8", true)?;
    if c1
        .recv_message(Duration::from_secs(5))?
        .ok_or("no retained-publish delivery within 5s")?
        .payload
        != b"0.8"
    {
        return Err("wildcard subscriber missed the retained publish".into());
    }
    let mut c3 = Client::connect(addr).map_err(|e| format!("third connect failed: {e}"))?;
    c3.subscribe("probe/cfg/+")?;
    let replay = c3
        .recv_message(Duration::from_secs(5))?
        .ok_or("no retained replay within 5s")?;
    if replay.topic != "probe/cfg/threshold" || replay.payload != b"0.8" {
        return Err(format!("wrong retained replay: {replay:?}"));
    }
    println!("probe: retained replay to a late subscriber OK");

    // unsubscribe stops delivery
    if !c1.unsubscribe(sub_id)? {
        return Err("unsubscribe of a live id reported removed=false".into());
    }
    let reached = c2.publish("probe/x/y", b"nobody-home", false)?;
    if reached != 0 {
        return Err(format!("expected 0 subscribers after unsubscribe, reached {reached}"));
    }

    // protocol robustness: malformed JSON answers a typed error and
    // the connection keeps working
    c2.send_raw(b"{definitely not json")
        .map_err(|e| format!("raw send failed: {e}"))?;
    match c2.read_response() {
        Err(e) if e.starts_with("bad-json") => {}
        other => return Err(format!("expected a bad-json error envelope, got {other:?}")),
    }
    c2.stats()
        .map_err(|e| format!("connection died after a malformed frame: {e}"))?;
    println!("probe: malformed frame answered with a typed error; connection survived");

    // totals: exactly the 3 publishes this probe made
    let st1 = c1.stats()?;
    let pubs1 = st1.get("stats").get("pubCount").as_f64().unwrap_or(-1.0);
    if pubs1 - pubs0 != 3.0 {
        return Err(format!("expected 3 new publishes, stats says {}", pubs1 - pubs0));
    }

    if send_shutdown {
        c1.shutdown()?;
        println!("probe: shutdown acknowledged");
    }
    println!("probe: all checks passed");
    Ok(())
}
