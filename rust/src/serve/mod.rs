//! `ace serve` — a TCP front end on the sharded threaded broker.
//!
//! The paper's platform claim is user-transparent edge-cloud
//! *services* (§3), not a simulator with a broker inside: external
//! processes must be able to publish, subscribe, run scenarios, and
//! read stats against a LIVE broker. This module is that byte-level
//! surface — the length-framed JSON protocol of [`proto`]
//! (`type`/`timestamp`/`requestId` envelopes) over the codec in
//! [`frame`], served by a fixed-size pooled engine.
//!
//! # Engine (fixed threads, no runtime, no per-connection threads)
//!
//! * One POLL LOOP ([`Server::run`], usually the main thread) owns ALL
//!   socket I/O: it multiplexes the listener, a wake pipe, and every
//!   connection through the hand-rolled `poll(2)` wrapper in [`poll`],
//!   reads nonblocking sockets into per-connection buffers, slices
//!   complete frames out, and drains per-connection outbound queues.
//!   Being the only writer, it can never tear a frame.
//! * A WORKER POOL of `ServeConfig::pool` threads parses and
//!   dispatches complete frames. A connection is processed by at most
//!   one worker at a time (an atomic `scheduled` claim), so responses
//!   leave in request order; different connections proceed in
//!   parallel. A `scenario` op occupies its worker for the whole DES
//!   run — size the pool accordingly.
//! * Subscription fan-out is SHARD-SIDE: `subscribe` registers a
//!   `Broker::subscribe_sink` closure that serializes the delivery and
//!   appends it to the connection's outbound queue — no forwarder
//!   thread, no channel hop. Sinks run inline under shard locks, so
//!   they only enqueue and wake the poll loop; a gate buffers retained
//!   replays until `subscribe_ok` is queued, keeping the ack ahead of
//!   every delivery. Lock order is gate → out, everywhere.
//!
//! Error containment: a malformed frame gets a typed `error` envelope
//! and the connection LIVES ON; an oversized frame gets the error
//! envelope (in request order, via the same inbound queue) and then a
//! close (the stream cannot be resynced past an unread body) — other
//! clients are never affected. A disconnecting client's subscriptions
//! are torn down by the poll loop; its sinks then refuse further
//! deliveries and are pruned by the broker.
//!
//! Shutdown: the `shutdown` op queues `shutdown_ok`, marks its
//! connection close-after-flush, and sets the stop flag. The poll loop
//! stops accepting, flushes every outbound queue (bounded by a grace
//! deadline), closes all connections, and joins the pool — so
//! `ace serve` exits cleanly (the CI smoke `wait`s on exactly this).
//!
//! Federation: with `ServeConfig::federate` set, the server runs a
//! [`federate::Link`] — a protocol client of a PEER server that pulls
//! matching messages into the local broker and pushes local matches to
//! the peer, suppressing loops by `Message::origin` (see [`federate`]).

pub mod b64;
pub mod client;
pub mod federate;
pub mod frame;
pub mod poll;
pub mod proto;

use crate::json::{self, Value};
use crate::pubsub::{Broker, Message};
use crate::svcgraph::scenario as svcscenario;
use frame::{FrameError, DEFAULT_MAX_FRAME};
use poll::{poll_fds, PollFd, POLLERR, POLLIN, POLLOUT};
use proto::{Envelope, ProtoError, Request};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Server tuning knobs (`ace serve --shards --max-frame --pool ...`).
pub struct ServeConfig {
    /// Literal-shard count for the underlying broker.
    pub shards: usize,
    /// Frame-size cap, bytes (see [`frame`]).
    pub max_frame: usize,
    /// Broker (and `Message::origin`) name.
    pub broker_name: String,
    /// Worker-pool size: the fixed number of dispatch threads. Socket
    /// I/O does not scale with this — it all lives on the poll loop.
    pub pool: usize,
    /// Run a federation link against a peer server (see [`federate`]).
    pub federate: Option<federate::FederateConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 8,
            max_frame: DEFAULT_MAX_FRAME,
            broker_name: "serve".into(),
            pool: 4,
            federate: None,
        }
    }
}

fn now_ts() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Wake the poll loop from any thread: one byte down a nonblocking
/// pipe (a full pipe means a wake is already pending — dropping the
/// byte is correct).
#[derive(Clone)]
struct Waker(Arc<UnixStream>);

impl Waker {
    fn wake(&self) {
        let _ = (&*self.0).write(&[1]);
    }
}

/// Outbound frames for one connection: full wire frames (header +
/// body) plus the partial-write offset into the front frame. Only the
/// poll loop writes, so frames never interleave.
struct OutBuf {
    frames: VecDeque<Vec<u8>>,
    offset: usize,
}

/// One complete inbound item, queued for a worker IN ORDER — so even
/// the oversized-frame error leaves after the responses to the frames
/// that preceded it.
enum Inbound {
    Frame(Vec<u8>),
    /// Declared length that tripped the cap; answered, then the
    /// connection closes (the unread body makes the stream unresumable).
    Oversized(u64),
}

/// The connection state shared between the poll loop, the worker pool,
/// and subscription sinks.
struct ConnShared {
    out: Mutex<OutBuf>,
    pending: Mutex<VecDeque<Inbound>>,
    /// Claimed by at most one worker at a time (per-connection request
    /// ordering without dedicating a thread).
    scheduled: AtomicBool,
    /// Subscription ids owned by this connection.
    subs: Mutex<Vec<u64>>,
    /// Torn down: sinks must refuse deliveries so the broker prunes them.
    closed: AtomicBool,
    /// Flush the outbound queue, then close (shutdown, oversized, EOF).
    close_after_flush: AtomicBool,
    waker: Waker,
}

impl ConnShared {
    fn new(waker: Waker) -> Arc<ConnShared> {
        Arc::new(ConnShared {
            out: Mutex::new(OutBuf {
                frames: VecDeque::new(),
                offset: 0,
            }),
            pending: Mutex::new(VecDeque::new()),
            scheduled: AtomicBool::new(false),
            subs: Mutex::new(Vec::new()),
            closed: AtomicBool::new(false),
            close_after_flush: AtomicBool::new(false),
            waker,
        })
    }

    /// Queue one already-serialized body as a wire frame and wake the
    /// poll loop. Callable from any thread (workers, sinks).
    fn send_bytes(&self, body: Vec<u8>) {
        let mut wire = Vec::with_capacity(body.len() + 4);
        wire.extend_from_slice(&(body.len() as u32).to_be_bytes());
        wire.extend_from_slice(&body);
        self.out.lock().unwrap().frames.push_back(wire);
        self.waker.wake();
    }

    fn send(&self, v: &Value) {
        self.send_bytes(json::to_string(v).into_bytes());
    }

    fn out_empty(&self) -> bool {
        self.out.lock().unwrap().frames.is_empty()
    }

    /// Nothing queued in, nothing queued out, no worker mid-request —
    /// a close-after-flush connection in this state can be retired.
    fn idle(&self) -> bool {
        self.out_empty()
            && self.pending.lock().unwrap().is_empty()
            && !self.scheduled.load(Ordering::SeqCst)
    }
}

/// Buffers a subscription's deliveries until its `subscribe_ok` is
/// queued, so the ack always precedes the retained replays that
/// `subscribe_sink` fires during registration. Lock order: gate → out.
struct SubGate {
    state: Mutex<GateState>,
}

enum GateState {
    Buffering(Vec<Vec<u8>>),
    Open,
}

impl SubGate {
    fn new() -> Arc<SubGate> {
        Arc::new(SubGate {
            state: Mutex::new(GateState::Buffering(Vec::new())),
        })
    }
}

/// The fixed-size worker pool: a job is a connection with pending
/// inbound items.
struct Pool {
    jobs: Mutex<VecDeque<Arc<ConnShared>>>,
    ready: Condvar,
    done: AtomicBool,
}

impl Pool {
    fn new() -> Arc<Pool> {
        Arc::new(Pool {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            done: AtomicBool::new(false),
        })
    }

    fn push(&self, job: Arc<ConnShared>) {
        self.jobs.lock().unwrap().push_back(job);
        self.ready.notify_one();
    }

    /// Blocks for work; `None` once shut down and drained.
    fn pop(&self) -> Option<Arc<ConnShared>> {
        let mut jobs = self.jobs.lock().unwrap();
        loop {
            if let Some(j) = jobs.pop_front() {
                return Some(j);
            }
            if self.done.load(Ordering::SeqCst) {
                return None;
            }
            jobs = self.ready.wait(jobs).unwrap();
        }
    }

    fn shutdown(&self) {
        self.done.store(true, Ordering::SeqCst);
        self.ready.notify_all();
    }
}

/// Hand a connection to the pool unless a worker already holds it.
fn schedule(pool: &Pool, conn: &Arc<ConnShared>) {
    if !conn.scheduled.swap(true, Ordering::SeqCst) {
        pool.push(conn.clone());
    }
}

/// What each worker thread needs to dispatch requests.
struct WorkerCtx {
    pool: Arc<Pool>,
    broker: Broker,
    stop: Arc<AtomicBool>,
    waker: Waker,
    max_frame: usize,
}

fn worker_loop(ctx: WorkerCtx) {
    while let Some(conn) = ctx.pool.pop() {
        loop {
            let item = conn.pending.lock().unwrap().pop_front();
            let Some(item) = item else {
                conn.scheduled.store(false, Ordering::SeqCst);
                // an enqueue racing the store above would be lost:
                // re-claim if work reappeared and nobody else has
                if conn.pending.lock().unwrap().is_empty()
                    || conn.scheduled.swap(true, Ordering::SeqCst)
                {
                    break;
                }
                continue;
            };
            match item {
                Inbound::Frame(body) => handle_frame(&ctx, &conn, &body),
                Inbound::Oversized(len) => {
                    let e = FrameError::Oversized {
                        len,
                        max: ctx.max_frame,
                    };
                    conn.send(&proto::error(
                        None,
                        now_ts(),
                        "oversized-frame",
                        &format!("{e}; closing this connection"),
                    ));
                    conn.close_after_flush.store(true, Ordering::SeqCst);
                    ctx.waker.wake();
                }
            }
        }
    }
}

fn handle_frame(ctx: &WorkerCtx, conn: &Arc<ConnShared>, body: &[u8]) {
    match proto::parse_request(body) {
        Ok(env) => dispatch(ctx, conn, env),
        Err(ProtoError {
            code,
            message,
            request_id,
        }) => {
            // malformed CONTENT is recoverable: typed error, keep
            // serving this connection
            conn.send(&proto::error(request_id.as_deref(), now_ts(), code, &message));
        }
    }
}

/// Handle one request on a worker thread.
fn dispatch(ctx: &WorkerCtx, conn: &Arc<ConnShared>, env: Envelope) {
    let rid = env.request_id.as_deref();
    match env.req {
        Request::Publish {
            topic,
            payload,
            retain,
            origin,
        } => {
            let mut msg = Message::new(topic, payload);
            if let Some(o) = origin {
                if !o.is_empty() {
                    // federation passthrough: keep the broker name the
                    // message FIRST entered (loop suppression)
                    msg.origin = Arc::from(o);
                }
            }
            match ctx.broker.publish_opts(msg, retain) {
                Ok(reached) => conn.send(&proto::publish_ok(rid, now_ts(), reached)),
                Err(e) => conn.send(&proto::error(rid, now_ts(), "invalid-topic", &e)),
            }
        }
        Request::Subscribe { filter } => {
            let gate = SubGate::new();
            let sink_conn = conn.clone();
            let sink_gate = gate.clone();
            let res = ctx.broker.subscribe_sink(&filter, move |id, m, retained| {
                if sink_conn.closed.load(Ordering::SeqCst) {
                    return false; // connection gone: let the broker prune us
                }
                let body = json::to_string(&proto::message(now_ts(), id, m, retained)).into_bytes();
                let mut st = sink_gate.state.lock().unwrap();
                match &mut *st {
                    GateState::Buffering(buf) => buf.push(body),
                    GateState::Open => sink_conn.send_bytes(body),
                }
                true
            });
            match res {
                Ok(id) => {
                    conn.subs.lock().unwrap().push(id);
                    // ack FIRST, then the buffered retained replays, all
                    // under the gate so a live publish cannot jump in
                    {
                        let mut st = gate.state.lock().unwrap();
                        conn.send(&proto::subscribe_ok(rid, now_ts(), id));
                        if let GateState::Buffering(buf) =
                            std::mem::replace(&mut *st, GateState::Open)
                        {
                            for body in buf {
                                conn.send_bytes(body);
                            }
                        }
                    }
                    if conn.closed.load(Ordering::SeqCst) {
                        // lost the race with teardown: nobody will
                        // unsubscribe this id for us
                        ctx.broker.unsubscribe(id);
                    }
                }
                Err(e) => conn.send(&proto::error(rid, now_ts(), "invalid-filter", &e)),
            }
        }
        Request::Unsubscribe { id } => {
            // only ids owned by THIS connection are removable — one
            // client cannot sever another's subscription
            let owned = {
                let mut subs = conn.subs.lock().unwrap();
                subs.iter().position(|&s| s == id).map(|pos| subs.remove(pos))
            };
            let removed = owned.is_some();
            if removed {
                ctx.broker.unsubscribe(id);
            }
            conn.send(&proto::unsubscribe_ok(rid, now_ts(), removed));
        }
        Request::Stats => conn.send(&proto::stats_ok(
            rid,
            now_ts(),
            &ctx.broker.name(),
            ctx.broker.shard_count(),
            &ctx.broker.stats(),
        )),
        Request::Scenario { doc } => match svcscenario::Scenario::parse(&doc) {
            Err(e) => conn.send(&proto::error(rid, now_ts(), "bad-scenario", &e.to_string())),
            Ok(sc) => match svcscenario::run(&sc) {
                Ok(report) => conn.send(&proto::scenario_ok(
                    rid,
                    now_ts(),
                    report.app(),
                    report.summary(),
                )),
                Err(e) => {
                    conn.send(&proto::error(rid, now_ts(), "scenario-failed", &e.to_string()))
                }
            },
        },
        Request::Shutdown => {
            conn.send(&proto::shutdown_ok(rid, now_ts()));
            conn.close_after_flush.store(true, Ordering::SeqCst);
            ctx.stop.store(true, Ordering::SeqCst);
            ctx.waker.wake();
        }
    }
}

/// Poll-loop-private connection state (the shared part lives in
/// [`ConnShared`]).
struct Conn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    /// Raw inbound bytes not yet sliced into frames.
    inbuf: Vec<u8>,
    /// Reading stopped (EOF or an oversized header); writes continue
    /// until the outbound queue drains.
    input_dead: bool,
    dead: bool,
}

/// A bound (but not yet serving) server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    broker: Broker,
    stop: Arc<AtomicBool>,
    max_frame: usize,
    pool_size: usize,
    federate: Option<federate::FederateConfig>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 for an ephemeral
    /// port — the integration tests do this).
    pub fn bind(addr: &str, cfg: &ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            broker: Broker::with_shards(cfg.broker_name.as_str(), cfg.shards),
            stop: Arc::new(AtomicBool::new(false)),
            max_frame: cfg.max_frame,
            pool_size: cfg.pool.max(1),
            federate: cfg.federate.clone(),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A handle to the underlying broker (for in-process assertions and
    /// the federation differential test).
    pub fn broker(&self) -> Broker {
        self.broker.clone()
    }

    /// Serve until a client sends `shutdown`: spawn the worker pool
    /// (and the federation link, if configured), then run the poll loop
    /// on THIS thread. Flushes, closes every connection, and joins all
    /// pool threads before returning.
    pub fn run(self) -> io::Result<()> {
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        let waker = Waker(Arc::new(wake_tx));
        self.listener.set_nonblocking(true)?;

        let pool = Pool::new();
        let mut workers = Vec::with_capacity(self.pool_size);
        for i in 0..self.pool_size {
            let ctx = WorkerCtx {
                pool: pool.clone(),
                broker: self.broker.clone(),
                stop: self.stop.clone(),
                waker: waker.clone(),
                max_frame: self.max_frame,
            };
            workers.push(
                thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(ctx))?,
            );
        }
        let link = self
            .federate
            .as_ref()
            .map(|cfg| federate::Link::start(cfg.clone(), self.broker.clone(), self.stop.clone()));

        let mut conns: Vec<Conn> = Vec::new();
        let mut scratch = vec![0u8; 64 * 1024];
        let mut flush_deadline: Option<Instant> = None;
        loop {
            let stopping = self.stop.load(Ordering::SeqCst);
            if stopping {
                // stop accepting; leave once every queue is flushed (or
                // a client stopped reading and the grace period expires)
                let deadline =
                    *flush_deadline.get_or_insert_with(|| Instant::now() + Duration::from_secs(2));
                if conns.iter().all(|c| c.shared.idle()) || Instant::now() >= deadline {
                    break;
                }
            }

            let mut fds = Vec::with_capacity(conns.len() + 2);
            fds.push(PollFd::new(wake_rx.as_raw_fd(), POLLIN));
            let listener_slot = if stopping {
                None
            } else {
                fds.push(PollFd::new(self.listener.as_raw_fd(), POLLIN));
                Some(fds.len() - 1)
            };
            let conn_base = fds.len();
            let n_polled = conns.len();
            for c in &conns {
                let mut ev = 0i16;
                if !c.input_dead {
                    ev |= POLLIN;
                }
                if !c.shared.out_empty() {
                    ev |= POLLOUT;
                }
                fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
            }
            poll_fds(&mut fds, 250)?;

            if fds[0].has(POLLIN) {
                drain_wake_pipe(&wake_rx, &mut scratch);
            }

            for idx in 0..n_polled {
                let pf = fds[conn_base + idx];
                let c = &mut conns[idx];
                if pf.has(POLLERR) {
                    c.dead = true;
                    continue;
                }
                if pf.has(POLLOUT) && flush_out(&mut c.stream, &c.shared).is_err() {
                    c.dead = true;
                    continue;
                }
                if pf.has(POLLIN) && !c.input_dead {
                    read_conn(c, &mut scratch, self.max_frame, &pool);
                }
            }

            // retire dead connections and flushed-out closers
            let mut idx = 0;
            while idx < conns.len() {
                let retire = conns[idx].dead
                    || (conns[idx].shared.close_after_flush.load(Ordering::SeqCst)
                        && conns[idx].shared.idle());
                if retire {
                    teardown(conns.swap_remove(idx), &self.broker);
                } else {
                    idx += 1;
                }
            }

            if let Some(slot) = listener_slot {
                if fds[slot].has(POLLIN) {
                    accept_all(&self.listener, &waker, &mut conns);
                }
            }
        }

        for c in conns.drain(..) {
            teardown(c, &self.broker);
        }
        pool.shutdown();
        for w in workers {
            let _ = w.join();
        }
        if let Some(link) = link {
            link.shutdown();
        }
        Ok(())
    }
}

fn drain_wake_pipe(wake_rx: &UnixStream, scratch: &mut [u8]) {
    loop {
        match (&*wake_rx).read(scratch) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break, // WouldBlock: drained
        }
    }
}

fn accept_all(listener: &TcpListener, waker: &Waker, conns: &mut Vec<Conn>) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                conns.push(Conn {
                    stream,
                    shared: ConnShared::new(waker.clone()),
                    inbuf: Vec::new(),
                    input_dead: false,
                    dead: false,
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
}

/// Drain a readable socket, slice complete frames into the pending
/// queue (in order), and schedule a worker. EOF and oversized headers
/// stop input; queued work still completes and flushes before the
/// close.
fn read_conn(c: &mut Conn, scratch: &mut [u8], max_frame: usize, pool: &Pool) {
    let mut eof = false;
    loop {
        match c.stream.read(scratch) {
            Ok(0) => {
                eof = true;
                break;
            }
            Ok(n) => c.inbuf.extend_from_slice(&scratch[..n]),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    let mut queued = false;
    {
        let mut pending = c.shared.pending.lock().unwrap();
        while c.inbuf.len() >= 4 {
            let len = u32::from_be_bytes([c.inbuf[0], c.inbuf[1], c.inbuf[2], c.inbuf[3]]) as usize;
            if len > max_frame {
                pending.push_back(Inbound::Oversized(len as u64));
                queued = true;
                c.input_dead = true;
                c.inbuf.clear();
                break;
            }
            if c.inbuf.len() < 4 + len {
                break;
            }
            pending.push_back(Inbound::Frame(c.inbuf[4..4 + len].to_vec()));
            queued = true;
            c.inbuf.drain(..4 + len);
        }
    }
    if queued {
        schedule(pool, &c.shared);
    }
    if eof {
        c.input_dead = true;
        c.shared.close_after_flush.store(true, Ordering::SeqCst);
    }
}

/// Write queued frames until the socket would block. Partial writes
/// park their offset in [`OutBuf`]; only this (poll-loop) path writes,
/// so frames cannot interleave.
fn flush_out(stream: &mut TcpStream, shared: &ConnShared) -> io::Result<()> {
    let mut out = shared.out.lock().unwrap();
    loop {
        let front_len;
        let res = match out.frames.front() {
            None => break,
            Some(front) => {
                front_len = front.len();
                stream.write(&front[out.offset..])
            }
        };
        match res {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "socket write of 0")),
            Ok(n) => {
                out.offset += n;
                if out.offset == front_len {
                    out.frames.pop_front();
                    out.offset = 0;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Close a connection: mark it so sinks refuse deliveries (the broker
/// prunes them), unsubscribe everything it owned, sever the socket.
fn teardown(c: Conn, broker: &Broker) {
    c.shared.closed.store(true, Ordering::SeqCst);
    let subs: Vec<u64> = std::mem::take(&mut *c.shared.subs.lock().unwrap());
    for id in subs {
        broker.unsubscribe(id);
    }
    let _ = c.stream.shutdown(Shutdown::Both);
}

/// The in-repo smoke client `ace serve-probe` runs against a live
/// server: exercises every op end-to-end over localhost, asserts the
/// results, and (by default) sends `shutdown` so the server exits
/// cleanly. Returns an error on ANY mismatch — the CI job fails on a
/// non-zero exit.
pub fn probe(addr: &str, send_shutdown: bool) -> Result<(), String> {
    use client::{Client, ErrorCode, ServeError};
    let mut c1 = Client::connect(addr)
        .retries(40, Duration::from_millis(250))
        .open()
        .map_err(|e| format!("probe could not connect to {addr}: {e}"))?;
    println!("probe: connected to {addr}");

    let st0 = c1.stats().map_err(|e| format!("stats failed: {e}"))?;
    println!(
        "probe: broker '{}' with {} shards speaks v{} [{}], {} publishes so far",
        st0.broker,
        st0.shards,
        st0.v,
        st0.capabilities.join(", "),
        st0.pub_count
    );
    for cap in ["federation", "scenario"] {
        if !st0.has_capability(cap) {
            return Err(format!("server does not advertise the '{cap}' capability"));
        }
    }

    // live pub/sub across two connections
    let sub_id = c1.subscribe("probe/#").map_err(|e| format!("subscribe failed: {e}"))?;
    let mut c2 = Client::connect(addr)
        .open()
        .map_err(|e| format!("second connect failed: {e}"))?;
    let reached = c2
        .publish("probe/x/y", b"hello-from-c2", false)
        .map_err(|e| format!("publish failed: {e}"))?;
    if reached != 1 {
        return Err(format!("expected to reach 1 subscriber, reached {reached}"));
    }
    let d = c1
        .recv_message(Duration::from_secs(5))
        .map_err(|e| format!("recv failed: {e}"))?
        .ok_or("no delivery within 5s")?;
    if d.subscription_id != sub_id || d.topic != "probe/x/y" || d.payload != b"hello-from-c2" {
        return Err(format!("wrong delivery: {d:?}"));
    }
    println!("probe: cross-connection delivery OK ({} -> {})", d.origin, d.topic);

    // retained replay for a late subscriber on a third connection
    c2.publish("probe/cfg/threshold", b"0.8", true)
        .map_err(|e| format!("retained publish failed: {e}"))?;
    let live = c1
        .recv_message(Duration::from_secs(5))
        .map_err(|e| format!("recv failed: {e}"))?
        .ok_or("no retained-publish delivery within 5s")?;
    if live.payload != b"0.8" || !live.retained {
        return Err(format!(
            "wildcard subscriber missed the retained publish (or its retained flag): {live:?}"
        ));
    }
    let mut c3 = Client::connect(addr)
        .open()
        .map_err(|e| format!("third connect failed: {e}"))?;
    c3.subscribe("probe/cfg/+").map_err(|e| format!("subscribe failed: {e}"))?;
    let replay = c3
        .recv_message(Duration::from_secs(5))
        .map_err(|e| format!("recv failed: {e}"))?
        .ok_or("no retained replay within 5s")?;
    if replay.topic != "probe/cfg/threshold" || replay.payload != b"0.8" || !replay.retained {
        return Err(format!("wrong retained replay: {replay:?}"));
    }
    println!("probe: retained replay to a late subscriber OK");

    // unsubscribe stops delivery
    if !c1.unsubscribe(sub_id).map_err(|e| format!("unsubscribe failed: {e}"))? {
        return Err("unsubscribe of a live id reported removed=false".into());
    }
    let reached = c2
        .publish("probe/x/y", b"nobody-home", false)
        .map_err(|e| format!("publish failed: {e}"))?;
    if reached != 0 {
        return Err(format!("expected 0 subscribers after unsubscribe, reached {reached}"));
    }

    // protocol robustness: malformed JSON answers a typed error and
    // the connection keeps working
    c2.send_raw(b"{definitely not json")
        .map_err(|e| format!("raw send failed: {e}"))?;
    match c2.read_response() {
        Err(ServeError::Protocol {
            code: ErrorCode::BadJson,
            ..
        }) => {}
        other => return Err(format!("expected a bad-json error envelope, got {other:?}")),
    }
    c2.stats()
        .map_err(|e| format!("connection died after a malformed frame: {e}"))?;
    println!("probe: malformed frame answered with a typed error; connection survived");

    // totals: exactly the 3 publishes this probe made
    let st1 = c1.stats().map_err(|e| format!("stats failed: {e}"))?;
    if st1.pub_count - st0.pub_count != 3 {
        return Err(format!(
            "expected 3 new publishes, stats says {}",
            st1.pub_count - st0.pub_count
        ));
    }

    if send_shutdown {
        c1.shutdown().map_err(|e| format!("shutdown failed: {e}"))?;
        println!("probe: shutdown acknowledged");
    }
    println!("probe: all checks passed");
    Ok(())
}
