//! The serve wire protocol: JSON request/response envelopes.
//!
//! Every frame carries one JSON object with a `type` discriminator.
//! Requests may carry a client-chosen `requestId` (any string), which
//! the matching response echoes verbatim; every server-built envelope
//! carries a `timestamp` (unix seconds, f64). Deliveries are pushed as
//! unsolicited `message` envelopes, so a client must be prepared to
//! see them interleaved with responses (see `serve::client`).
//!
//! Ops (request `type` → response `type`):
//!
//! | request       | fields                                   | response         |
//! |---------------|------------------------------------------|------------------|
//! | `publish`     | `topic`, `payload` (base64), `retain`?, `origin`? | `publish_ok` (`reached`) |
//! | `subscribe`   | `filter`                                 | `subscribe_ok` (`subscriptionId`) |
//! | `unsubscribe` | `subscriptionId`                         | `unsubscribe_ok` (`removed`) |
//! | `stats`       | —                                        | `stats_ok` (`stats`, `broker`, `shards`, `v`, `capabilities`) |
//! | `scenario`    | `scenario` (base64 yamlite)              | `scenario_ok` (`app`, `report`) |
//! | `shutdown`    | —                                        | `shutdown_ok`    |
//!
//! Versioning (negotiable without breaking v1 goldens): every request
//! may carry an integer `v`; ABSENT means v1, so every pre-`v` client
//! keeps working byte-for-byte. A `v` the server does not speak is
//! answered with an `unsupported-version` error. The `stats_ok` reply
//! advertises the server's `v` plus a `capabilities` string list
//! ([`CAPABILITIES`]) — how a federation link or a `scenario`-driving
//! client discovers what the peer can do before using it.
//!
//! `publish.origin` is a federation-only passthrough: it pre-stamps
//! `Message::origin` so a forwarded message keeps the broker name it
//! FIRST entered (loop suppression, `serve::federate`). Delivery
//! pushes carry `retained: true` when the message is retain-as-
//! published (a retained replay, or a live publish that asked to
//! retain) and omit the field otherwise — v1 pushes are unchanged.
//!
//! Any failure becomes an `error` envelope: `code` (stable
//! machine-readable slug), `message` (human text), plus the echoed
//! `requestId` when the request got far enough to surface one.
//! Subscription ids fit exactly in a JSON f64 by construction
//! (`pubsub::shard` caps shards so ids stay below 2^53).

use super::b64;
use crate::json::{self, Value};
use crate::pubsub::{BrokerStats, Message};

/// The protocol version this server speaks (absent `v` ⇒ 1).
pub const PROTO_V: u64 = 1;

/// Capabilities advertised in `stats_ok` — stable slugs a client or
/// federation peer switches on instead of sniffing version numbers.
pub const CAPABILITIES: &[&str] = &["federation", "origin-publish", "retained-flag", "scenario"];

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Publish {
        topic: String,
        payload: Vec<u8>,
        retain: bool,
        /// Pre-stamped `Message::origin` (federation passthrough);
        /// `None` lets the receiving broker stamp its own name.
        origin: Option<String>,
    },
    Subscribe {
        filter: String,
    },
    Unsubscribe {
        id: u64,
    },
    Stats,
    /// Run a `svcgraph::scenario` document (yamlite text) to completion
    /// inside the server and report the per-app summary.
    Scenario {
        doc: String,
    },
    Shutdown,
}

/// A request plus its envelope metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Client-chosen correlation id, echoed in the response.
    pub request_id: Option<String>,
    pub req: Request,
}

/// A typed protocol error — becomes an `error` envelope on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ProtoError {
    /// Stable machine-readable slug (`bad-json`, `bad-type`, ...).
    pub code: &'static str,
    pub message: String,
    /// Echoed when the envelope parsed far enough to surface one.
    pub request_id: Option<String>,
}

impl ProtoError {
    fn new(code: &'static str, message: impl Into<String>) -> Self {
        ProtoError {
            code,
            message: message.into(),
            request_id: None,
        }
    }
}

fn required_str(v: &Value, field: &str, op: &str) -> Result<String, ProtoError> {
    v.get(field).as_str().map(str::to_string).ok_or_else(|| {
        ProtoError::new(
            "missing-field",
            format!("'{op}' needs a string '{field}' field"),
        )
    })
}

/// Parse one frame body into a request envelope.
pub fn parse_request(bytes: &[u8]) -> Result<Envelope, ProtoError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| ProtoError::new("bad-utf8", format!("frame is not UTF-8: {e}")))?;
    let v = json::parse(text)
        .map_err(|e| ProtoError::new("bad-json", format!("frame is not JSON: {e}")))?;
    if v.as_obj().is_none() {
        return Err(ProtoError::new("bad-envelope", "frame is not a JSON object"));
    }
    let request_id = v.get("requestId").as_str().map(str::to_string);
    let fail = |e: ProtoError| ProtoError {
        request_id: request_id.clone(),
        ..e
    };
    match v.get("v") {
        // absent ⇒ v1: pre-`v` clients keep working unchanged
        Value::Null => {}
        other => {
            let ver = other.as_f64().filter(|f| *f >= 0.0 && f.fract() == 0.0);
            match ver {
                Some(f) if f as u64 == PROTO_V => {}
                Some(f) => {
                    return Err(fail(ProtoError::new(
                        "unsupported-version",
                        format!("this server speaks v{PROTO_V}, request asked for v{f}"),
                    )))
                }
                None => {
                    return Err(fail(ProtoError::new(
                        "bad-envelope",
                        "'v' must be a non-negative integer",
                    )))
                }
            }
        }
    }
    let Some(kind) = v.get("type").as_str() else {
        return Err(fail(ProtoError::new(
            "bad-envelope",
            "envelope needs a string 'type' field",
        )));
    };
    let req = match kind {
        "publish" => {
            let topic = required_str(&v, "topic", "publish").map_err(&fail)?;
            let payload = match v.get("payload") {
                Value::Null => Vec::new(),
                Value::Str(s) => b64::decode(s).map_err(|e| {
                    fail(ProtoError::new(
                        "bad-payload",
                        format!("'payload' is not base64: {e}"),
                    ))
                })?,
                _ => {
                    return Err(fail(ProtoError::new(
                        "bad-payload",
                        "'payload' must be a base64 string",
                    )))
                }
            };
            let retain = match v.get("retain") {
                Value::Null => false,
                other => other.as_bool().ok_or_else(|| {
                    fail(ProtoError::new("bad-envelope", "'retain' must be a boolean"))
                })?,
            };
            let origin = match v.get("origin") {
                Value::Null => None,
                other => Some(
                    other
                        .as_str()
                        .ok_or_else(|| {
                            fail(ProtoError::new("bad-envelope", "'origin' must be a string"))
                        })?
                        .to_string(),
                ),
            };
            Request::Publish {
                topic,
                payload,
                retain,
                origin,
            }
        }
        "subscribe" => Request::Subscribe {
            filter: required_str(&v, "filter", "subscribe").map_err(&fail)?,
        },
        "unsubscribe" => {
            let id = v.get("subscriptionId").as_f64().ok_or_else(|| {
                fail(ProtoError::new(
                    "missing-field",
                    "'unsubscribe' needs a numeric 'subscriptionId' field",
                ))
            })?;
            if id < 0.0 || id.fract() != 0.0 {
                return Err(fail(ProtoError::new(
                    "bad-envelope",
                    "'subscriptionId' must be a non-negative integer",
                )));
            }
            Request::Unsubscribe { id: id as u64 }
        }
        "stats" => Request::Stats,
        "scenario" => {
            let doc64 = required_str(&v, "scenario", "scenario").map_err(&fail)?;
            let bytes = b64::decode(&doc64).map_err(|e| {
                fail(ProtoError::new(
                    "bad-scenario",
                    format!("'scenario' is not base64: {e}"),
                ))
            })?;
            let doc = String::from_utf8(bytes).map_err(|e| {
                fail(ProtoError::new(
                    "bad-scenario",
                    format!("'scenario' is not UTF-8 yamlite: {e}"),
                ))
            })?;
            Request::Scenario { doc }
        }
        "shutdown" => Request::Shutdown,
        other => {
            return Err(fail(ProtoError::new(
                "bad-type",
                format!(
                    "unknown op '{other}' (expected publish, subscribe, \
                     unsubscribe, stats, scenario, or shutdown)"
                ),
            )))
        }
    };
    Ok(Envelope { request_id, req })
}

fn envelope(kind: &str, request_id: Option<&str>, ts: f64, mut extra: Vec<(&str, Value)>) -> Value {
    let mut pairs = vec![
        ("type", Value::str(kind)),
        ("timestamp", Value::Num(ts)),
    ];
    if let Some(rid) = request_id {
        pairs.push(("requestId", Value::str(rid)));
    }
    pairs.append(&mut extra);
    Value::obj(pairs)
}

/// `publish` succeeded; `reached` subscribers got the message now.
pub fn publish_ok(request_id: Option<&str>, ts: f64, reached: usize) -> Value {
    envelope(
        "publish_ok",
        request_id,
        ts,
        vec![("reached", Value::num(reached as f64))],
    )
}

/// `subscribe` succeeded; deliveries will carry `subscriptionId`.
pub fn subscribe_ok(request_id: Option<&str>, ts: f64, id: u64) -> Value {
    envelope(
        "subscribe_ok",
        request_id,
        ts,
        vec![("subscriptionId", Value::num(id as f64))],
    )
}

/// `unsubscribe` response; `removed` is false for unknown ids.
pub fn unsubscribe_ok(request_id: Option<&str>, ts: f64, removed: bool) -> Value {
    envelope(
        "unsubscribe_ok",
        request_id,
        ts,
        vec![("removed", Value::Bool(removed))],
    )
}

/// `stats` response: the broker's lock-free counter snapshot, plus the
/// protocol version and capability list (the negotiation surface a
/// federation link reads before subscribing).
pub fn stats_ok(
    request_id: Option<&str>,
    ts: f64,
    broker: &str,
    shards: usize,
    st: &BrokerStats,
) -> Value {
    envelope(
        "stats_ok",
        request_id,
        ts,
        vec![
            ("broker", Value::str(broker)),
            ("shards", Value::num(shards as f64)),
            ("v", Value::num(PROTO_V as f64)),
            (
                "capabilities",
                Value::Arr(CAPABILITIES.iter().map(|c| Value::str(*c)).collect()),
            ),
            (
                "stats",
                Value::obj(vec![
                    ("pubCount", Value::num(st.pub_count as f64)),
                    ("pubBytes", Value::num(st.pub_bytes as f64)),
                    ("deliverCount", Value::num(st.deliver_count as f64)),
                    ("deliverBytes", Value::num(st.deliver_bytes as f64)),
                    ("subscriptions", Value::num(st.subscriptions as f64)),
                ]),
            ),
        ],
    )
}

/// `scenario` finished: the app it dispatched to and its summary
/// object (see `svcgraph::scenario::Report::summary`).
pub fn scenario_ok(request_id: Option<&str>, ts: f64, app: &str, report: Value) -> Value {
    envelope(
        "scenario_ok",
        request_id,
        ts,
        vec![("app", Value::str(app)), ("report", report)],
    )
}

/// `shutdown` acknowledged; the server stops accepting and exits.
pub fn shutdown_ok(request_id: Option<&str>, ts: f64) -> Value {
    envelope("shutdown_ok", request_id, ts, vec![])
}

/// Any failure, as a typed envelope the client can switch on.
pub fn error(request_id: Option<&str>, ts: f64, code: &str, message: &str) -> Value {
    envelope(
        "error",
        request_id,
        ts,
        vec![("code", Value::str(code)), ("message", Value::str(message))],
    )
}

/// An asynchronous delivery push for subscription `sub_id`.
///
/// `retained` is retain-as-published (a retained replay, or a live
/// publish that asked to retain); the field is only emitted when true
/// so v1 pushes for ordinary publishes are byte-identical.
pub fn message(ts: f64, sub_id: u64, m: &Message, retained: bool) -> Value {
    let mut extra = vec![
        ("subscriptionId", Value::num(sub_id as f64)),
        ("topic", Value::str(m.topic.as_str())),
        ("payload", Value::str(b64::encode(&m.payload))),
        ("origin", Value::str(&*m.origin)),
    ];
    if retained {
        extra.push(("retained", Value::Bool(true)));
    }
    envelope("message", None, ts, extra)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_id_survives_op_level_failures() {
        let e = parse_request(br#"{"type":"publish","requestId":"r9"}"#).unwrap_err();
        assert_eq!(e.code, "missing-field");
        assert_eq!(e.request_id.as_deref(), Some("r9"));
        let e = parse_request(br#"{"type":"warp","requestId":"r10"}"#).unwrap_err();
        assert_eq!(e.code, "bad-type");
        assert_eq!(e.request_id.as_deref(), Some("r10"));
    }

    #[test]
    fn envelope_level_failures_are_typed() {
        assert_eq!(parse_request(b"\xff\xfe").unwrap_err().code, "bad-utf8");
        assert_eq!(parse_request(b"{oops").unwrap_err().code, "bad-json");
        assert_eq!(parse_request(b"[1,2]").unwrap_err().code, "bad-envelope");
        assert_eq!(parse_request(b"{}").unwrap_err().code, "bad-envelope");
        assert_eq!(
            parse_request(br#"{"type":"publish","topic":"a","payload":"!!"}"#)
                .unwrap_err()
                .code,
            "bad-payload"
        );
        assert_eq!(
            parse_request(br#"{"type":"unsubscribe","subscriptionId":-1}"#)
                .unwrap_err()
                .code,
            "bad-envelope"
        );
    }

    #[test]
    fn optional_fields_default() {
        let env = parse_request(br#"{"type":"publish","topic":"a/b"}"#).unwrap();
        assert_eq!(
            env.req,
            Request::Publish {
                topic: "a/b".into(),
                payload: vec![],
                retain: false,
                origin: None
            }
        );
        assert_eq!(env.request_id, None);
    }

    #[test]
    fn version_field_negotiates() {
        // absent and explicit v1 both parse
        assert!(parse_request(br#"{"type":"stats"}"#).is_ok());
        assert!(parse_request(br#"{"type":"stats","v":1}"#).is_ok());
        // a future version is refused with a stable slug, echoing the id
        let e = parse_request(br#"{"type":"stats","v":9,"requestId":"r2"}"#).unwrap_err();
        assert_eq!(e.code, "unsupported-version");
        assert_eq!(e.request_id.as_deref(), Some("r2"));
        // malformed versions are envelope errors
        for bad in [
            br#"{"type":"stats","v":1.5}"#.as_slice(),
            br#"{"type":"stats","v":-1}"#.as_slice(),
            br#"{"type":"stats","v":"1"}"#.as_slice(),
        ] {
            assert_eq!(parse_request(bad).unwrap_err().code, "bad-envelope");
        }
    }

    #[test]
    fn origin_passthrough_and_scenario_decode() {
        let env =
            parse_request(br#"{"type":"publish","topic":"t","origin":"ec-broker"}"#).unwrap();
        match env.req {
            Request::Publish { origin, .. } => assert_eq!(origin.as_deref(), Some("ec-broker")),
            other => panic!("unexpected request {other:?}"),
        }
        assert_eq!(
            parse_request(br#"{"type":"publish","topic":"t","origin":7}"#)
                .unwrap_err()
                .code,
            "bad-envelope"
        );
        // scenario docs ride as base64 yamlite
        let doc64 = b64::encode(b"duration: 5\nops: []\n");
        let body = format!(r#"{{"type":"scenario","scenario":"{doc64}"}}"#);
        match parse_request(body.as_bytes()).unwrap().req {
            Request::Scenario { doc } => assert_eq!(doc, "duration: 5\nops: []\n"),
            other => panic!("unexpected request {other:?}"),
        }
        assert_eq!(
            parse_request(br#"{"type":"scenario","scenario":"!!"}"#)
                .unwrap_err()
                .code,
            "bad-scenario"
        );
    }

    #[test]
    fn retained_flag_is_omitted_when_false() {
        let m = Message::new("a/b", b"hi".to_vec());
        let plain = json::to_string(&message(1.0, 3, &m, false));
        assert!(!plain.contains("retained"));
        let kept = json::to_string(&message(1.0, 3, &m, true));
        assert!(kept.contains(r#""retained":true"#));
    }
}
