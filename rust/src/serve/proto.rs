//! The serve wire protocol: JSON request/response envelopes.
//!
//! Every frame carries one JSON object with a `type` discriminator.
//! Requests may carry a client-chosen `requestId` (any string), which
//! the matching response echoes verbatim; every server-built envelope
//! carries a `timestamp` (unix seconds, f64). Deliveries are pushed as
//! unsolicited `message` envelopes, so a client must be prepared to
//! see them interleaved with responses (see `serve::client`).
//!
//! Ops (request `type` → response `type`):
//!
//! | request       | fields                                   | response         |
//! |---------------|------------------------------------------|------------------|
//! | `publish`     | `topic`, `payload` (base64), `retain`?   | `publish_ok` (`reached`) |
//! | `subscribe`   | `filter`                                 | `subscribe_ok` (`subscriptionId`) |
//! | `unsubscribe` | `subscriptionId`                         | `unsubscribe_ok` (`removed`) |
//! | `stats`       | —                                        | `stats_ok` (`stats`, `broker`, `shards`) |
//! | `shutdown`    | —                                        | `shutdown_ok`    |
//!
//! Any failure becomes an `error` envelope: `code` (stable
//! machine-readable slug), `message` (human text), plus the echoed
//! `requestId` when the request got far enough to surface one.
//! Subscription ids fit exactly in a JSON f64 by construction
//! (`pubsub::shard` caps shards so ids stay below 2^53).

use super::b64;
use crate::json::{self, Value};
use crate::pubsub::{BrokerStats, Message};

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Publish {
        topic: String,
        payload: Vec<u8>,
        retain: bool,
    },
    Subscribe {
        filter: String,
    },
    Unsubscribe {
        id: u64,
    },
    Stats,
    Shutdown,
}

/// A request plus its envelope metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Client-chosen correlation id, echoed in the response.
    pub request_id: Option<String>,
    pub req: Request,
}

/// A typed protocol error — becomes an `error` envelope on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ProtoError {
    /// Stable machine-readable slug (`bad-json`, `bad-type`, ...).
    pub code: &'static str,
    pub message: String,
    /// Echoed when the envelope parsed far enough to surface one.
    pub request_id: Option<String>,
}

impl ProtoError {
    fn new(code: &'static str, message: impl Into<String>) -> Self {
        ProtoError {
            code,
            message: message.into(),
            request_id: None,
        }
    }
}

fn required_str(v: &Value, field: &str, op: &str) -> Result<String, ProtoError> {
    v.get(field).as_str().map(str::to_string).ok_or_else(|| {
        ProtoError::new(
            "missing-field",
            format!("'{op}' needs a string '{field}' field"),
        )
    })
}

/// Parse one frame body into a request envelope.
pub fn parse_request(bytes: &[u8]) -> Result<Envelope, ProtoError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| ProtoError::new("bad-utf8", format!("frame is not UTF-8: {e}")))?;
    let v = json::parse(text)
        .map_err(|e| ProtoError::new("bad-json", format!("frame is not JSON: {e}")))?;
    if v.as_obj().is_none() {
        return Err(ProtoError::new("bad-envelope", "frame is not a JSON object"));
    }
    let request_id = v.get("requestId").as_str().map(str::to_string);
    let fail = |e: ProtoError| ProtoError {
        request_id: request_id.clone(),
        ..e
    };
    let Some(kind) = v.get("type").as_str() else {
        return Err(fail(ProtoError::new(
            "bad-envelope",
            "envelope needs a string 'type' field",
        )));
    };
    let req = match kind {
        "publish" => {
            let topic = required_str(&v, "topic", "publish").map_err(&fail)?;
            let payload = match v.get("payload") {
                Value::Null => Vec::new(),
                Value::Str(s) => b64::decode(s).map_err(|e| {
                    fail(ProtoError::new(
                        "bad-payload",
                        format!("'payload' is not base64: {e}"),
                    ))
                })?,
                _ => {
                    return Err(fail(ProtoError::new(
                        "bad-payload",
                        "'payload' must be a base64 string",
                    )))
                }
            };
            let retain = match v.get("retain") {
                Value::Null => false,
                other => other.as_bool().ok_or_else(|| {
                    fail(ProtoError::new("bad-envelope", "'retain' must be a boolean"))
                })?,
            };
            Request::Publish {
                topic,
                payload,
                retain,
            }
        }
        "subscribe" => Request::Subscribe {
            filter: required_str(&v, "filter", "subscribe").map_err(&fail)?,
        },
        "unsubscribe" => {
            let id = v.get("subscriptionId").as_f64().ok_or_else(|| {
                fail(ProtoError::new(
                    "missing-field",
                    "'unsubscribe' needs a numeric 'subscriptionId' field",
                ))
            })?;
            if id < 0.0 || id.fract() != 0.0 {
                return Err(fail(ProtoError::new(
                    "bad-envelope",
                    "'subscriptionId' must be a non-negative integer",
                )));
            }
            Request::Unsubscribe { id: id as u64 }
        }
        "stats" => Request::Stats,
        "shutdown" => Request::Shutdown,
        other => {
            return Err(fail(ProtoError::new(
                "bad-type",
                format!(
                    "unknown op '{other}' (expected publish, subscribe, \
                     unsubscribe, stats, or shutdown)"
                ),
            )))
        }
    };
    Ok(Envelope { request_id, req })
}

fn envelope(kind: &str, request_id: Option<&str>, ts: f64, mut extra: Vec<(&str, Value)>) -> Value {
    let mut pairs = vec![
        ("type", Value::str(kind)),
        ("timestamp", Value::Num(ts)),
    ];
    if let Some(rid) = request_id {
        pairs.push(("requestId", Value::str(rid)));
    }
    pairs.append(&mut extra);
    Value::obj(pairs)
}

/// `publish` succeeded; `reached` subscribers got the message now.
pub fn publish_ok(request_id: Option<&str>, ts: f64, reached: usize) -> Value {
    envelope(
        "publish_ok",
        request_id,
        ts,
        vec![("reached", Value::num(reached as f64))],
    )
}

/// `subscribe` succeeded; deliveries will carry `subscriptionId`.
pub fn subscribe_ok(request_id: Option<&str>, ts: f64, id: u64) -> Value {
    envelope(
        "subscribe_ok",
        request_id,
        ts,
        vec![("subscriptionId", Value::num(id as f64))],
    )
}

/// `unsubscribe` response; `removed` is false for unknown ids.
pub fn unsubscribe_ok(request_id: Option<&str>, ts: f64, removed: bool) -> Value {
    envelope(
        "unsubscribe_ok",
        request_id,
        ts,
        vec![("removed", Value::Bool(removed))],
    )
}

/// `stats` response: the broker's lock-free counter snapshot.
pub fn stats_ok(
    request_id: Option<&str>,
    ts: f64,
    broker: &str,
    shards: usize,
    st: &BrokerStats,
) -> Value {
    envelope(
        "stats_ok",
        request_id,
        ts,
        vec![
            ("broker", Value::str(broker)),
            ("shards", Value::num(shards as f64)),
            (
                "stats",
                Value::obj(vec![
                    ("pubCount", Value::num(st.pub_count as f64)),
                    ("pubBytes", Value::num(st.pub_bytes as f64)),
                    ("deliverCount", Value::num(st.deliver_count as f64)),
                    ("deliverBytes", Value::num(st.deliver_bytes as f64)),
                    ("subscriptions", Value::num(st.subscriptions as f64)),
                ]),
            ),
        ],
    )
}

/// `shutdown` acknowledged; the server stops accepting and exits.
pub fn shutdown_ok(request_id: Option<&str>, ts: f64) -> Value {
    envelope("shutdown_ok", request_id, ts, vec![])
}

/// Any failure, as a typed envelope the client can switch on.
pub fn error(request_id: Option<&str>, ts: f64, code: &str, message: &str) -> Value {
    envelope(
        "error",
        request_id,
        ts,
        vec![("code", Value::str(code)), ("message", Value::str(message))],
    )
}

/// An asynchronous delivery push for subscription `sub_id`.
pub fn message(ts: f64, sub_id: u64, m: &Message) -> Value {
    envelope(
        "message",
        None,
        ts,
        vec![
            ("subscriptionId", Value::num(sub_id as f64)),
            ("topic", Value::str(m.topic.as_str())),
            ("payload", Value::str(b64::encode(&m.payload))),
            ("origin", Value::str(&*m.origin)),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_id_survives_op_level_failures() {
        let e = parse_request(br#"{"type":"publish","requestId":"r9"}"#).unwrap_err();
        assert_eq!(e.code, "missing-field");
        assert_eq!(e.request_id.as_deref(), Some("r9"));
        let e = parse_request(br#"{"type":"warp","requestId":"r10"}"#).unwrap_err();
        assert_eq!(e.code, "bad-type");
        assert_eq!(e.request_id.as_deref(), Some("r10"));
    }

    #[test]
    fn envelope_level_failures_are_typed() {
        assert_eq!(parse_request(b"\xff\xfe").unwrap_err().code, "bad-utf8");
        assert_eq!(parse_request(b"{oops").unwrap_err().code, "bad-json");
        assert_eq!(parse_request(b"[1,2]").unwrap_err().code, "bad-envelope");
        assert_eq!(parse_request(b"{}").unwrap_err().code, "bad-envelope");
        assert_eq!(
            parse_request(br#"{"type":"publish","topic":"a","payload":"!!"}"#)
                .unwrap_err()
                .code,
            "bad-payload"
        );
        assert_eq!(
            parse_request(br#"{"type":"unsubscribe","subscriptionId":-1}"#)
                .unwrap_err()
                .code,
            "bad-envelope"
        );
    }

    #[test]
    fn optional_fields_default() {
        let env = parse_request(br#"{"type":"publish","topic":"a/b"}"#).unwrap();
        assert_eq!(
            env.req,
            Request::Publish {
                topic: "a/b".into(),
                payload: vec![],
                retain: false
            }
        );
        assert_eq!(env.request_id, None);
    }
}
